//! Fig. 21: basis rotation generalizes to Mixture-of-Experts blocks —
//! rotation applies per expert (expert axis folded into the batched
//! optimizer executables), the pipeline schedule is unchanged.
//!
//!     cargo run --release --example moe_training

use abrot::config::{Method, TrainCfg};
use abrot::coordinator::{Coordinator, Experiment};

fn main() -> anyhow::Result<()> {
    let mut coord = Coordinator::new("artifacts");
    let base = TrainCfg { stages: 4, steps: 100, lr: 1e-2, seed: 3, ..Default::default() };
    for method in [Method::PipeDream, Method::PipeDreamLr, Method::br_default()] {
        let r = coord.run(&Experiment {
            model: "moe_pico".into(),
            train: TrainCfg { method, ..base.clone() },
        })?;
        println!("{:<16} loss {:.3} -> {:.3}  ({:.1}s)",
                 r.method, r.losses[0], r.final_loss(), r.wall_secs);
    }
    Ok(())
}
