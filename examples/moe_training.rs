//! Fig. 21: basis rotation generalizes to Mixture-of-Experts blocks —
//! rotation applies per expert (expert axis folded into the batched
//! optimizer executables), the pipeline schedule is unchanged.
//!
//!     cargo run --release --example moe_training

use abrot::config::{Method, TrainCfg};
use abrot::coordinator::{Coordinator, Experiment};

fn main() -> anyhow::Result<()> {
    let mut coord = Coordinator::new("artifacts");
    let base = TrainCfg { stages: 4, steps: 100, lr: 1e-2, seed: 3, ..Default::default() };
    for method in [Method::PipeDream, Method::PipeDreamLr, Method::br_default()] {
        let r = coord.run(&Experiment {
            model: "moe_pico".into(),
            train: TrainCfg { method, ..base.clone() },
        })?;
        println!("{:<16} loss {:.3} -> {:.3}  ({:.1}s)",
                 r.method, r.losses[0], r.final_loss(), r.wall_secs);
    }

    // The real threaded 1F1B engine runs the MoE blocks too, with each
    // stage owning its method's optimizer (here: per-expert rotation).
    println!("\n-- threaded engine, MoE --");
    for method in [Method::PipeDream, Method::br_default()] {
        let r = coord.run_engine(&Experiment {
            model: "moe_pico".into(),
            train: TrainCfg { method, steps: 40, ..base.clone() },
        })?;
        println!("engine {:<16} loss {:.3} -> {:.3}  ({:.0} tokens/s, bubble {:.1}%)",
                 r.method, r.losses[0], r.final_loss(),
                 r.tokens_per_sec, r.bubble_frac * 100.0);
    }
    Ok(())
}
