//! Quickstart: train a small transformer under asynchronous pipeline
//! parallelism (P=4), first with vanilla async Adam (PipeDream), then
//! with the paper's basis rotation — and watch staleness stop hurting.
//!
//!     cargo run --release --example quickstart

use abrot::config::{Method, TrainCfg};
use abrot::coordinator::{Coordinator, Experiment};

fn main() -> anyhow::Result<()> {
    let mut coord = Coordinator::new("artifacts");
    let base = TrainCfg {
        stages: 4,
        steps: 120,
        lr: 1e-2,
        seed: 7,
        ..Default::default()
    };

    println!("== PipeDream (async Adam, delayed gradients) ==");
    let pd = coord.run(&Experiment {
        model: "pico8".into(),
        train: TrainCfg { method: Method::PipeDream, ..base.clone() },
    })?;
    println!("loss {:.3} -> {:.3}", pd.losses[0], pd.final_loss());

    println!("== Basis rotation (S=2nd, bilateral, freq 10) ==");
    let br = coord.run(&Experiment {
        model: "pico8".into(),
        train: TrainCfg { method: Method::br_default(), ..base },
    })?;
    println!("loss {:.3} -> {:.3}", br.losses[0], br.final_loss());

    println!("\nstep  pipedream  basis_rotation");
    for i in (9..pd.losses.len()).step_by(10) {
        println!("{:>4}  {:>9.4}  {:>14.4}", i + 1, pd.losses[i], br.losses[i]);
    }
    Ok(())
}
