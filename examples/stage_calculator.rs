//! Appendix A / Table 1: how deep must the pipeline be?  The analytic
//! memory model behind the paper's motivation — staleness grows with P,
//! and P grows fast with model size on commodity GPUs.
//!
//!     cargo run --release --example stage_calculator [seq] [batch]

use abrot::analysis::{block_bytes, gpus, llama_models, required_stages, table2_rows};
use abrot::config::{Geometry, Source};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let s: u64 = args.get(1).and_then(|x| x.parse().ok()).unwrap_or(4096);
    let b: u64 = args.get(2).and_then(|x| x.parse().ok()).unwrap_or(1);

    println!("Table 1: minimum pipeline stages (seq={s}, batch={b})");
    print!("{:<16}", "model");
    for g in gpus() {
        print!(" {:>10}", g.name.split(' ').next().unwrap());
    }
    println!();
    for m in llama_models() {
        print!("{:<16}", m.name);
        for g in gpus() {
            let (p, lb) = required_stages(&m, &g, s, b);
            print!(" {:>10}", if lb { format!(">={p}*") } else { p.to_string() });
        }
        println!("   ({:.1} GB/block)", block_bytes(m.w, s, b, m.h, m.a) as f64 / 1e9);
    }
    println!("* = a single block does not fit (paper reports >= 2L)");

    println!("\nTable 2: basis-rotation memory overhead on Llama-3-8B (GB per matrix)");
    for r in table2_rows() {
        let sname = match r.source { Source::Second => "2nd", Source::First => "1st" };
        let gname = match r.geometry { Geometry::Bilateral => "bilateral", Geometry::Unilateral => "unilateral" };
        println!("  S={sname:<4} G={gname:<10} attn {:>5.2}  mlp {:>5.2}", r.attn_gb, r.mlp_gb);
    }
}
