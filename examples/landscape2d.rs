//! Figs. 3–4: the 2-D diagnostics behind the paper's mechanism —
//! basis misalignment makes Adam oscillate, oscillation makes delayed
//! gradients stale, rotation fixes both.
//!
//!     cargo run --release --example landscape2d

use abrot::landscape::*;

fn main() {
    println!("== Fig 3: quadratic (lambda = [100, 1], delay = 2) ==");
    println!("{:<10} {:>8} {:>6} {:>12}", "optimizer", "aligned", "delay", "tail_loss");
    for r in fig3_grid(2) {
        println!("{:<10} {:>8} {:>6} {:>12.4}", r.opt, r.aligned, r.delay, r.tail_loss);
    }

    println!("\n== Fig 4: spiral-loss slowdown under delay 1 ==");
    let samples = spiral_slowdowns(30, 7);
    let mean: f64 = samples.iter().map(|s| s.slowdown).sum::<f64>() / samples.len() as f64;
    for s in &samples {
        let bar = "#".repeat((s.slowdown * 4.0) as usize);
        println!("angle {:>7.1}deg  slowdown {:>5.2}x {bar}", s.angle_deg, s.slowdown);
    }
    println!("mean slowdown {mean:.2}x over {} samples", samples.len());
}
