//! Memory-stability check: dispatching thousands of executions must not
//! grow RSS. Originally a regression guard for a PJRT input-buffer leak
//! (worked around in `runtime::pjrt` via `execute_b`); under the
//! default native backend it guards the value-conversion and dispatch
//! paths the same way.
//!
//!     cargo run --release --example memcheck

use abrot::runtime::{tensor_to_value, tokens_to_value, Runtime, Value};
use abrot::tensor::Tensor;

fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/status").unwrap();
    let line = s.lines().find(|l| l.starts_with("VmRSS")).unwrap();
    line.split_whitespace().nth(1).unwrap().parse::<f64>().unwrap() / 1024.0
}

fn main() {
    let t = Tensor::ones(&[512, 512]); // 1MB
    println!("start rss {:.0} MB", rss_mb());
    for i in 0..2000 {
        let v = tensor_to_value(&t).unwrap();
        drop(v);
        if i % 500 == 499 {
            println!("after {} value conversions rss {:.0} MB", i + 1, rss_mb());
        }
    }
    let rt = Runtime::open("artifacts/micro").unwrap();
    println!("backend: {}", rt.backend_kind());
    let cfg = rt.cfg().clone();
    let params = abrot::model::init_params(&rt.manifest, 0);
    let toks: Vec<i32> =
        (0..cfg.batch * cfg.seq).map(|i| (i % cfg.vocab) as i32).collect();
    let mut inputs: Vec<Value> =
        params.iter().map(|p| tensor_to_value(p).unwrap()).collect();
    inputs.push(tokens_to_value(&toks, cfg.batch, cfg.seq).unwrap());
    inputs.push(tokens_to_value(&toks, cfg.batch, cfg.seq).unwrap());
    println!("before exec loop rss {:.0} MB", rss_mb());
    for i in 0..1500 {
        let outs = rt.exec("fwdbwd", &inputs).unwrap();
        drop(outs);
        if i % 500 == 499 {
            println!("after {} execs rss {:.0} MB", i + 1, rss_mb());
        }
    }
}
