//! End-to-end driver (deliverable (e2e) of DESIGN.md): train the
//! deepest config on the synthetic corpus for a few hundred steps under
//! real asynchronous pipeline parallelism and log the loss curve,
//! proving all three layers compose:
//!
//!   L3 threaded 1F1B engine (per-block HLO executables, weight
//!      stashing, immediate updates)  → throughput & bubble metrics
//!   L3 delay-accurate simulator + HLO-backed basis rotation
//!      (L2 graphs embedding the L1 kernels) → loss-curve comparison
//!
//! Default scale targets the single-core CPU testbed (see DESIGN.md §5
//! for the substitution from the paper's 95M-3B GPU models):
//!
//!     cargo run --release --example train_e2e -- [steps] [model] [P] [--replicas R] [--schedule S]
//!     cargo run --release --example train_e2e -- 300 tiny32 32   # full
//!     cargo run --release --example train_e2e -- 60 pico8 4 --replicas 2  # DP x PP
//!     cargo run --release --example train_e2e -- 60 pico8 4 --schedule interleaved:2
//!     cargo run --release --example train_e2e                    # quick
//!
//! Fault-tolerance knobs (engine phase only):
//!
//!     --checkpoint-every K   snapshot the engine run every K updates
//!     --kill STEP:REPLICA[:WORKER]  deterministically kill that worker
//!                            after update STEP; the driver re-shards the
//!                            surviving replicas from the last checkpoint
//!     --delay STEP:REPLICA:WORKER:MILLIS  inject a straggler sleep
//!     --dp-async --max-skew K  bounded-skew asynchronous DP: replicas
//!                            fold peer gradients up to K steps stale
//!                            and stall only at the bound, so a --delay
//!                            straggler no longer stalls the group
//!                            (K=0 is bit-exact with synchronous DP)
//!
//! Observability knobs (engine phase writes wall-clock spans; the sim
//! phases write the virtual-clock schedule model):
//!
//!     --trace PATH           Chrome trace_event span timeline JSON
//!     --metrics PATH         per-step run metrics JSONL
//!
//! Threading:
//!
//!     --threads N            kernel pool budget (default auto; the
//!                            engine splits it across P x R stage
//!                            workers; results are bit-identical)

use abrot::config::{Method, ScheduleKind, TrainCfg};
use abrot::coordinator::{Coordinator, Experiment};
use abrot::metrics::{iter_reduction_vs, write_losses};

fn main() -> anyhow::Result<()> {
    let mut args: Vec<String> = std::env::args().collect();
    // --replicas R (data-parallel pipeline replicas) can appear anywhere
    let mut replicas: usize = 1;
    if let Some(i) = args.iter().position(|a| a == "--replicas") {
        match args.get(i + 1).and_then(|x| x.parse::<usize>().ok()) {
            Some(r) => {
                replicas = r.max(1);
                args.drain(i..i + 2);
            }
            None => {
                eprintln!("--replicas expects a number; running with R=1");
                args.remove(i);
            }
        }
    }
    // --checkpoint-every K (engine snapshots every K updates)
    let mut checkpoint_every: u32 = 0;
    if let Some(i) = args.iter().position(|a| a == "--checkpoint-every") {
        match args.get(i + 1).and_then(|x| x.parse::<u32>().ok()) {
            Some(k) => {
                checkpoint_every = k;
                args.drain(i..i + 2);
            }
            None => {
                eprintln!("--checkpoint-every expects a number; checkpointing off");
                args.remove(i);
            }
        }
    }
    // --kill STEP:REPLICA[:WORKER] (deterministic fault injection; repeatable)
    let mut plan = abrot::checkpoint::FaultPlan::default();
    while let Some(i) = args.iter().position(|a| a == "--kill") {
        match args
            .get(i + 1)
            .and_then(|x| abrot::checkpoint::FaultPlan::parse_kill(x).ok())
        {
            Some(k) => {
                plan.kills.push(k);
                args.drain(i..i + 2);
            }
            None => {
                eprintln!("--kill expects STEP:REPLICA[:WORKER]; ignoring");
                args.remove(i);
            }
        }
    }
    // --delay STEP:REPLICA:WORKER:MILLIS (straggler injection; repeatable)
    while let Some(i) = args.iter().position(|a| a == "--delay") {
        match args
            .get(i + 1)
            .and_then(|x| abrot::checkpoint::FaultPlan::parse_delay(x).ok())
        {
            Some(d) => {
                plan.delays.push(d);
                args.drain(i..i + 2);
            }
            None => {
                eprintln!("--delay expects STEP:REPLICA:WORKER:MILLIS; ignoring");
                args.remove(i);
            }
        }
    }
    // --dp-async [--max-skew K] (bounded-skew asynchronous DP)
    let mut dp_async = false;
    if let Some(i) = args.iter().position(|a| a == "--dp-async") {
        dp_async = true;
        args.remove(i);
    }
    let mut max_skew: u32 = 0;
    if let Some(i) = args.iter().position(|a| a == "--max-skew") {
        match args.get(i + 1).and_then(|x| x.parse::<u32>().ok()) {
            Some(k) => {
                max_skew = k;
                args.drain(i..i + 2);
            }
            None => {
                eprintln!("--max-skew expects a number; using 0");
                args.remove(i);
            }
        }
    }
    // --trace PATH / --metrics PATH (observability outputs)
    let mut trace: Option<String> = None;
    if let Some(i) = args.iter().position(|a| a == "--trace") {
        match args.get(i + 1) {
            Some(p) => {
                trace = Some(p.clone());
                args.drain(i..i + 2);
            }
            None => {
                eprintln!("--trace expects a path; tracing off");
                args.remove(i);
            }
        }
    }
    let mut metrics: Option<String> = None;
    if let Some(i) = args.iter().position(|a| a == "--metrics") {
        match args.get(i + 1) {
            Some(p) => {
                metrics = Some(p.clone());
                args.drain(i..i + 2);
            }
            None => {
                eprintln!("--metrics expects a path; metrics off");
                args.remove(i);
            }
        }
    }
    // --threads N (kernel pool budget; 0/absent = auto)
    let mut threads: usize = 0;
    if let Some(i) = args.iter().position(|a| a == "--threads") {
        match args.get(i + 1).and_then(|x| x.parse::<usize>().ok()) {
            Some(n) => {
                threads = n;
                args.drain(i..i + 2);
            }
            None => {
                eprintln!("--threads expects a number; using auto");
                args.remove(i);
            }
        }
    }
    // --schedule S (gpipe | 1f1b | interleaved[:V] | amdp)
    let mut schedule = ScheduleKind::OneFOneB;
    if let Some(i) = args.iter().position(|a| a == "--schedule") {
        match args.get(i + 1).map(|x| x.as_str()).and_then(ScheduleKind::parse) {
            Some(s) => {
                schedule = s;
                args.drain(i..i + 2);
            }
            None => {
                eprintln!("--schedule expects gpipe|1f1b|interleaved[:V]|amdp; using 1f1b");
                args.remove(i);
            }
        }
    }
    let steps: u32 = args.get(1).and_then(|x| x.parse().ok()).unwrap_or(200);
    let model = args.get(2).cloned().unwrap_or_else(|| "pico32".to_string());
    let stages: usize = args.get(3).and_then(|x| x.parse().ok()).unwrap_or(32);

    abrot::runtime::pool::set_global_threads(abrot::runtime::pool::ThreadCfg::new(threads));

    let mut coord = Coordinator::new("artifacts");
    let base = TrainCfg {
        stages,
        replicas,
        steps,
        schedule,
        threads,
        lr: 1e-2,
        seed: 1234,
        eval_every: (steps / 6).max(1),
        trace,
        metrics,
        dp_async,
        max_skew,
        ..Default::default()
    };

    println!(
        "=== e2e: {model}, P={stages}, R={replicas}, schedule={}, threads={}, {steps} steps/microbatches ===\n",
        schedule.name(),
        abrot::runtime::pool::kernel_threads()
    );

    // 1. Real pipelined engine (async PipeDream execution model),
    //    sampling validation losses through the pipeline.
    println!("[1/3] threaded {} engine (PipeDream)...", schedule.name());
    let eng_steps = steps.min(60);
    let eng_exp = Experiment {
        model: model.clone(),
        train: TrainCfg {
            method: Method::PipeDream,
            steps: eng_steps,
            eval_every: (eng_steps / 3).max(1),
            checkpoint_every,
            ..base.clone()
        },
    };
    let eng = if checkpoint_every > 0 || !plan.is_empty() {
        if checkpoint_every > 0 {
            println!("  (checkpointing every {checkpoint_every} updates)");
        }
        for k in &plan.kills {
            println!(
                "  (will kill replica {} worker {} after update {})",
                k.replica, k.worker, k.at_update
            );
        }
        for d in &plan.delays {
            println!(
                "  (will delay replica {} worker {} by {} ms after update {})",
                d.replica, d.worker, d.millis, d.at_update
            );
        }
        if dp_async {
            println!("  (bounded-skew async DP, max skew {max_skew})");
        }
        coord.run_engine_elastic(&eng_exp, &plan)?
    } else {
        coord.run_engine(&eng_exp)?
    };
    println!(
        "  engine: {} microbatches, loss {:.3} -> {:.3}, {:.0} tokens/s, bubble {:.1}%",
        eng.losses.len(), eng.losses[0], eng.final_loss(),
        eng.tokens_per_sec, eng.bubble_frac * 100.0
    );
    for (t, v) in &eng.val_losses {
        println!("  engine val@{t}: {v:.4}");
    }
    if !plan.is_empty() {
        println!("  engine survived the fault plan with {} replica(s)", eng.replicas);
    }
    println!();

    // 2. Full-length async baseline (simulator, same semantics).
    println!("[2/3] async baseline (PipeDream, {steps} steps)...");
    let pd = coord.run(&Experiment {
        model: model.clone(),
        train: TrainCfg { method: Method::PipeDream, ..base.clone() },
    })?;
    println!("  pipedream: loss {:.3} -> {:.3} in {:.0}s\n",
             pd.losses[0], pd.final_loss(), pd.wall_secs);

    // 3. Basis rotation (the paper's fix) — same budget.
    println!("[3/3] basis rotation (S=2nd/bilateral, freq 10)...");
    let br = coord.run(&Experiment {
        model: model.clone(),
        train: TrainCfg { method: Method::br_default(), ..base },
    })?;
    println!("  basis rotation: loss {:.3} -> {:.3} in {:.0}s\n",
             br.losses[0], br.final_loss(), br.wall_secs);

    println!("loss curve (every {} steps):", (steps / 20).max(1));
    println!("{:>6} {:>11} {:>11}", "step", "pipedream", "basis_rot");
    for i in (0..pd.losses.len()).step_by(((steps / 20).max(1)) as usize) {
        println!("{:>6} {:>11.4} {:>11.4}", i + 1, pd.losses[i], br.losses[i]);
    }
    if let Some(red) = iter_reduction_vs(&br, &pd) {
        println!(
            "\nbasis rotation reaches pipedream's final loss with {:.1}% fewer iterations",
            red * 100.0
        );
    }
    for (t, v) in &br.val_losses {
        println!("val@{t}: {v:.4}");
    }
    std::fs::create_dir_all("results").ok();
    write_losses("results/e2e_losses.csv", &[&pd, &br])?;
    println!("\nloss curves -> results/e2e_losses.csv");
    Ok(())
}
