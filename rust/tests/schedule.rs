//! Schedule-conformance harness: the pluggable pipeline schedules
//! (gpipe / 1f1b / interleaved:V / amdp) against their own analytic
//! models and against the real engine.
//!
//! Fast tests (prefix `schedule_`, pure computation on the virtual
//! clock — no engine threads) run on every push via the CI fast-path
//! job; the `#[ignore]`d tests spawn the threaded engine and run in
//! the nightly lane:
//!
//! * (a) measured bubble vs the declared analytic `bubble_frac`,
//! * (b) realized per-chunk gradient delay vs the declared profile,
//!   via the engine's instrumented update counters,
//! * (c) engine-vs-simulator trajectory equivalence at P = 4 for all
//!   four schedules.

use std::path::PathBuf;

use abrot::config::{Method, ScheduleKind, StashMode, TrainCfg};
use abrot::pipeline::engine::train_engine;
use abrot::pipeline::schedule::{self, Action, Schedule};
use abrot::pipeline::train_sim;
use abrot::rngs::Rng;
use abrot::runtime::Runtime;

fn root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn all_kinds() -> Vec<ScheduleKind> {
    vec![
        ScheduleKind::Gpipe,
        ScheduleKind::OneFOneB,
        ScheduleKind::Interleaved { v: 2 },
        ScheduleKind::Amdp,
    ]
}

/// The M the declared analytic `bubble_frac(p, m)` expects: per-update
/// M for the synchronous schedules, the whole finite run's microbatch
/// count for the asynchronous ones (their fill/drain amortizes over
/// the run, not over one update).
fn analytic_m(
    kind: ScheduleKind,
    sched: &dyn Schedule,
    p: usize,
    cfg_m: usize,
    n_updates: u64,
) -> usize {
    match kind {
        ScheduleKind::OneFOneB | ScheduleKind::Amdp => {
            n_updates as usize * sched.micro_per_update(p, cfg_m)
        }
        _ => sched.effective_m(p, cfg_m),
    }
}

#[test]
fn schedule_bubble_model_matches_analytic_p4_m8() {
    // Acceptance: at P=4, M=8 the measured (virtual-clock) bubble of
    // each schedule's emitted action streams matches its analytic
    // formula within 10% relative tolerance.
    let (p, cfg_m, n_updates) = (4usize, 8usize, 12u64);
    for kind in all_kinds() {
        let s = schedule::build(kind);
        let stats = schedule::simulate(s.as_ref(), p, cfg_m, n_updates)
            .unwrap_or_else(|e| panic!("{kind:?}: {e}"));
        let m = analytic_m(kind, s.as_ref(), p, cfg_m, n_updates);
        let want = s.bubble_frac(p, m);
        let denom = want.abs().max(1e-9);
        assert!(
            (stats.bubble - want).abs() / denom <= 0.10,
            "{kind:?}: measured bubble {} vs analytic {} (>10% off)",
            stats.bubble,
            want
        );
    }
}

#[test]
fn schedule_bubble_model_tracks_analytic_across_grid() {
    // The same conformance over a (P, M) grid; the gpipe/1f1b/
    // interleaved measurements are exact (fill+drain of P-1 slots per
    // wave), amdp is self-consistent by construction.
    for kind in all_kinds() {
        for p in [2usize, 4, 6] {
            for cfg_m in [4usize, 8] {
                let n_updates = 10u64;
                let s = schedule::build(kind);
                let stats = schedule::simulate(s.as_ref(), p, cfg_m, n_updates)
                    .unwrap_or_else(|e| panic!("{kind:?} P={p} M={cfg_m}: {e}"));
                let m = analytic_m(kind, s.as_ref(), p, cfg_m, n_updates);
                let want = s.bubble_frac(p, m);
                let denom = want.abs().max(1e-9);
                assert!(
                    (stats.bubble - want).abs() / denom <= 0.10,
                    "{kind:?} P={p} M={cfg_m}: measured {} vs analytic {}",
                    stats.bubble,
                    want
                );
            }
        }
    }
}

#[test]
fn schedule_realized_delays_match_declared_profiles() {
    // (b) on the virtual clock: in steady state every chunk's realized
    // gradient delay equals its declared delay; fill microbatches only
    // clamp below it.
    let n_updates = 12u64;
    for kind in all_kinds() {
        for p in [2usize, 4] {
            let s = schedule::build(kind);
            let stats = schedule::simulate(s.as_ref(), p, 8, n_updates).unwrap();
            let chunks = s.chunks(p);
            let n_streams = s.n_streams() as u64;
            for (chunk, mb, delay) in &stats.delays {
                let spec = chunks.iter().find(|c| c.id == *chunk).unwrap();
                let local = mb / n_streams;
                if local >= (p - 1) as u64 && local < n_updates - p as u64 {
                    assert_eq!(
                        *delay, spec.delay,
                        "{kind:?} P={p} chunk {chunk} mb {mb}: steady delay"
                    );
                } else {
                    assert!(
                        *delay <= spec.delay,
                        "{kind:?} P={p} chunk {chunk} mb {mb}: fill delay clamps"
                    );
                }
            }
            // the per-stage profile the simulator consumes agrees with
            // the per-chunk declarations
            let prof = s.delay_profile(p);
            for c in &chunks {
                if s.n_parts(p) == p {
                    assert_eq!(c.delay, prof[c.part], "{kind:?} chunk {}", c.id);
                }
            }
        }
    }
}

#[test]
fn schedule_property_random_streams_well_formed() {
    // Property-style sweep over random (P ≤ 8, M ≤ 16, schedule, V):
    // the emitted action streams are well-formed — every microbatch
    // gets exactly one fwd and one bwd per chunk of its stream, the
    // bwd never precedes the fwd, every chunk updates exactly
    // n_updates times, and the in-flight stash never exceeds the
    // declared max (the executor validates the stash cap and the
    // cross-chunk dependencies; the counts are re-checked directly).
    let mut rng = Rng::new(0xC0FFEE);
    for case in 0..24 {
        let kind = match rng.below(4) {
            0 => ScheduleKind::Gpipe,
            1 => ScheduleKind::OneFOneB,
            2 => ScheduleKind::Interleaved { v: 1 + rng.below(3) },
            _ => ScheduleKind::Amdp,
        };
        let p = match kind {
            // amdp pairs stage k with P-1-k across streams: even P only
            ScheduleKind::Amdp => 2 * (1 + rng.below(4)),
            _ => 1 + rng.below(8),
        };
        let cfg_m = 1 + rng.below(16);
        let n_updates = 1 + rng.below(3) as u64;
        let s = schedule::build(kind);
        if s.n_parts(p) > 64 {
            continue; // keep the executor cheap
        }

        // executor validation: dependencies, duplicates, stash cap
        schedule::simulate(s.as_ref(), p, cfg_m, n_updates)
            .unwrap_or_else(|e| panic!("case {case} {kind:?} P={p} M={cfg_m}: {e}"));

        // direct count check, independent of the executor
        let m = s.effective_m(p, cfg_m);
        let mpu = s.micro_per_update(p, cfg_m) as u64;
        let n_streams = s.n_streams() as u64;
        let total_micro = n_updates * mpu;
        let chunks = s.chunks(p);
        for w in 0..p {
            let acts = s.worker_actions(p, m, n_updates, w);
            for spec in chunks.iter().filter(|c| c.worker == w) {
                let mut fwd_pos = std::collections::HashMap::new();
                let mut bwd_pos = std::collections::HashMap::new();
                let mut updates = 0u64;
                for (i, a) in acts.iter().enumerate() {
                    match *a {
                        Action::Fwd { mb, chunk } if chunk == spec.id => {
                            assert!(
                                fwd_pos.insert(mb, i).is_none(),
                                "{kind:?} chunk {} mb {mb}: duplicate fwd",
                                spec.id
                            );
                        }
                        Action::Bwd { mb, chunk } if chunk == spec.id => {
                            assert!(
                                bwd_pos.insert(mb, i).is_none(),
                                "{kind:?} chunk {} mb {mb}: duplicate bwd",
                                spec.id
                            );
                        }
                        Action::Update { chunk } if chunk == spec.id => updates += 1,
                        _ => {}
                    }
                }
                assert_eq!(updates, n_updates, "{kind:?} chunk {}", spec.id);
                let expected: Vec<u64> = (0..total_micro)
                    .filter(|mb| mb % n_streams == spec.stream as u64)
                    .collect();
                assert_eq!(fwd_pos.len(), expected.len(), "{kind:?} chunk {}", spec.id);
                assert_eq!(bwd_pos.len(), expected.len(), "{kind:?} chunk {}", spec.id);
                for mb in expected {
                    let f = fwd_pos[&mb];
                    let b = bwd_pos[&mb];
                    assert!(
                        f < b,
                        "{kind:?} chunk {} mb {mb}: bwd precedes fwd",
                        spec.id
                    );
                }
            }
        }
    }
}

#[test]
fn schedule_kind_parses_and_roundtrips() {
    for (txt, kind) in [
        ("gpipe", ScheduleKind::Gpipe),
        ("1f1b", ScheduleKind::OneFOneB),
        ("pipedream", ScheduleKind::OneFOneB),
        ("amdp", ScheduleKind::Amdp),
        ("interleaved", ScheduleKind::Interleaved { v: 2 }),
        ("interleaved:3", ScheduleKind::Interleaved { v: 3 }),
    ] {
        assert_eq!(ScheduleKind::parse(txt), Some(kind), "{txt}");
    }
    assert_eq!(ScheduleKind::parse("interleaved:0"), None);
    assert_eq!(ScheduleKind::parse("zigzag"), None);
    // name() → parse() roundtrip for every kind
    for kind in all_kinds() {
        assert_eq!(ScheduleKind::parse(&kind.name()), Some(kind));
    }
}

#[test]
fn schedule_predict_stash_error_names_the_schedule_flag() {
    // StashMode::Predict is simulator-only; the engine's refusal must
    // tell the user which schedules are affected (all of them) and
    // point at --schedule.
    let cfg = TrainCfg {
        method: Method::PipeDream,
        stash: StashMode::Predict,
        stages: 2,
        steps: 2,
        ..Default::default()
    };
    let err = train_engine(root().join("micro"), &cfg).unwrap_err().to_string();
    assert!(err.contains("Predict"), "{err}");
    assert!(err.contains("--schedule"), "{err}");
    for name in ["gpipe", "1f1b", "interleaved", "amdp"] {
        assert!(err.contains(name), "error should enumerate {name}: {err}");
    }
}

// ---------------------------------------------------------------------------
// Engine conformance (threaded runs — nightly lane)
// ---------------------------------------------------------------------------

/// Model preset per schedule at P = 4: interleaved v=2 needs P·V = 8
/// blocks, the linear schedules partition pico4's 4 blocks 1:1.
fn model_for(kind: ScheduleKind) -> &'static str {
    match kind {
        ScheduleKind::Interleaved { .. } => "pico8",
        _ => "pico4",
    }
}

fn engine_cfg(kind: ScheduleKind, steps: u32) -> TrainCfg {
    TrainCfg {
        method: Method::PipeDream,
        schedule: kind,
        stages: 4,
        steps,
        lr: 5e-3,
        grad_clip: 1e9, // engine clips per-chunk, sim globally
        seed: 2025,
        ..Default::default()
    }
}

#[test]
#[ignore = "spawns engine threads; nightly lane"]
fn schedule_engine_bubble_conformance_all_schedules() {
    // (a) on the real engine: the run's deterministic schedule-model
    // bubble must match the declared analytic value within 10%.
    for kind in all_kinds() {
        let cfg = engine_cfg(kind, 12);
        let r = train_engine(root().join(model_for(kind)), &cfg)
            .unwrap_or_else(|e| panic!("{kind:?}: {e}"));
        assert_eq!(r.schedule, kind.name(), "{kind:?}");
        let denom = r.bubble_frac_analytic.abs().max(1e-9);
        assert!(
            (r.bubble_frac_model - r.bubble_frac_analytic).abs() / denom <= 0.10,
            "{kind:?}: model bubble {} vs analytic {}",
            r.bubble_frac_model,
            r.bubble_frac_analytic
        );
    }
}

#[test]
#[ignore = "spawns engine threads; nightly lane"]
fn schedule_engine_realized_delays_match_declared() {
    // (b) on the real engine: each chunk's instrumented update
    // counters realize exactly the declared steady-state delay (steps
    // comfortably past the P-deep fill, so the max realized delay is
    // the steady value; it can never exceed the declaration).
    for kind in all_kinds() {
        let cfg = engine_cfg(kind, 12);
        let r = train_engine(root().join(model_for(kind)), &cfg)
            .unwrap_or_else(|e| panic!("{kind:?}: {e}"));
        let s = schedule::build(kind);
        let chunks = s.chunks(4);
        assert_eq!(r.realized_delays.len(), chunks.len(), "{kind:?}");
        for (chunk, mbs, max_delay) in &r.realized_delays {
            let spec = chunks.iter().find(|c| c.id == *chunk).unwrap();
            assert!(*mbs > 0, "{kind:?} chunk {chunk}: no microbatches observed");
            assert_eq!(
                *max_delay, spec.delay,
                "{kind:?} chunk {chunk}: realized max delay vs declared"
            );
        }
    }
}

#[test]
#[ignore = "spawns engine threads; nightly lane"]
fn schedule_engine_matches_sim_trajectory_all_schedules_p4() {
    // (c) engine vs simulator at P = 4 for every schedule: same seeds,
    // same per-stage delay profile, same microbatch accumulation order
    // => same loss trajectory (per-block vs monolithic executables
    // leave a small numeric residue, same tolerance as the 1f1b
    // equivalence tests).
    for kind in all_kinds() {
        let cfg = engine_cfg(kind, 8);
        let model = model_for(kind);
        let rt = Runtime::open(root().join(model)).unwrap();
        let sim = train_sim(&rt, &cfg).unwrap();
        let eng = train_engine(root().join(model), &cfg)
            .unwrap_or_else(|e| panic!("{kind:?}: {e}"));
        assert_eq!(sim.losses.len(), eng.losses.len(), "{kind:?}");
        assert!(!eng.diverged, "{kind:?}");
        for (i, (a, b)) in sim.losses.iter().zip(&eng.losses).enumerate() {
            assert!(
                (a - b).abs() < 5e-3 * a.abs().max(1.0),
                "{kind:?} step {i}: sim {a} vs engine {b}"
            );
        }
    }
}

#[test]
#[ignore = "spawns engine threads; nightly lane"]
fn schedule_engine_1f1b_reproduces_legacy_behaviour_bit_level() {
    // The schedule-driven engine must be indistinguishable from the
    // original hard-coded 1F1B loop: same losses, same eval labels,
    // same per-stage counters. (The 20-step golden fixtures pin the
    // trajectories across sessions; this pins the in-process run.)
    let cfg = TrainCfg {
        method: Method::PipeDream,
        schedule: ScheduleKind::OneFOneB,
        stages: 4,
        steps: 12,
        lr: 5e-3,
        eval_every: 3,
        seed: 41,
        ..Default::default()
    };
    let a = train_engine(root().join("pico4"), &cfg).unwrap();
    let b = train_engine(root().join("pico4"), &cfg).unwrap();
    // deterministic across runs
    assert_eq!(a.losses, b.losses);
    assert_eq!(a.losses.len(), 12);
    let labels: Vec<u32> = a.val_losses.iter().map(|(t, _)| *t).collect();
    assert_eq!(labels, vec![3, 6, 9, 12]);
    assert!(a.stage_counters.iter().all(|c| c.updates == 12));
    assert_eq!(a.stage_counters.len(), 4);
}
