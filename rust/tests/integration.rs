//! Cross-layer integration tests:
//!
//! * Backend optimizer executables vs the independent Rust reference
//!   implementations (the same assertions pin the HLO/Pallas path when
//!   built with `--features pjrt` against real artifacts).
//! * Threaded 1F1B engine vs the delay-accurate simulator (same seeds,
//!   same staleness semantics => same loss trajectory).
//! * Split-weight (no-stash) graph consistency with the fused graph.
//! * Determinism and staleness-sensitivity properties of the simulator.

use std::path::PathBuf;

use abrot::config::{Method, StashMode, TrainCfg};
use abrot::coordinator::{Coordinator, Experiment};
use abrot::data::{replica_stream, BatchIter, Corpus, TRAIN_STREAM};
use abrot::model::{init_params, StagePartition};
use abrot::optim::{self, clip_global_norm, StepCtx};
use abrot::optim::reference::{self, Scalars};
use abrot::pipeline::train_sim;
use abrot::rngs::Rng;
use abrot::runtime::{
    tensor_to_value, tokens_to_value, value_scalar_f32, value_to_tensor, Runtime,
    Value,
};
use abrot::tensor::{stack, unstack, Tensor};

fn root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn randn(rng: &mut Rng, shape: &[usize], std: f32) -> Tensor {
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(&mut t.data, std);
    t
}

fn orth(rng: &mut Rng, n: usize) -> Tensor {
    reference::cgs2_qr(&randn(rng, &[n, n], 1.0))
}

fn scalars_stack(nb: usize, sc: Scalars, mask: f32) -> Tensor {
    let mut t = Tensor::zeros(&[nb, 8]);
    for i in 0..nb {
        t.data[i * 8..(i + 1) * 8].copy_from_slice(&sc.to_row(mask));
    }
    t
}

struct RotCase {
    w: Vec<Tensor>,
    g: Vec<Tensor>,
    m: Vec<Tensor>,
    vt: Vec<Tensor>,
    u: Vec<Tensor>,
    v: Vec<Tensor>,
}

fn rot_case(rng: &mut Rng, nb: usize, mm: usize, nn: usize) -> RotCase {
    RotCase {
        w: (0..nb).map(|_| randn(rng, &[mm, nn], 1.0)).collect(),
        g: (0..nb).map(|_| randn(rng, &[mm, nn], 1.0)).collect(),
        m: (0..nb).map(|_| randn(rng, &[mm, nn], 0.5)).collect(),
        vt: (0..nb).map(|_| randn(rng, &[mm, nn], 0.5).map(f32::abs)).collect(),
        u: (0..nb).map(|_| orth(rng, mm)).collect(),
        v: (0..nb).map(|_| orth(rng, nn)).collect(),
    }
}

fn stack_refs(ts: &[Tensor]) -> Tensor {
    let refs: Vec<&Tensor> = ts.iter().collect();
    stack(&refs)
}

#[test]
fn backend_rot_adam_matches_rust_reference() {
    let rt = Runtime::open(root().join("micro")).unwrap();
    // micro class wqkv: count 2, 16x48
    let mut rng = Rng::new(42);
    let case = rot_case(&mut rng, 2, 16, 48);
    let sc = Scalars { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, wd: 0.01, t: 3.0 };
    for (exec, uni) in [("rot_adam_bi_wqkv", false), ("rot_adam_uni_wqkv", true)] {
        let inputs = vec![
            tensor_to_value(&stack_refs(&case.w)).unwrap(),
            tensor_to_value(&stack_refs(&case.g)).unwrap(),
            tensor_to_value(&stack_refs(&case.m)).unwrap(),
            tensor_to_value(&stack_refs(&case.vt)).unwrap(),
            tensor_to_value(&stack_refs(&case.u)).unwrap(),
            tensor_to_value(&stack_refs(&case.v)).unwrap(),
            tensor_to_value(&scalars_stack(2, sc, 1.0)).unwrap(),
        ];
        let outs = rt.exec_tensors(exec, &inputs).unwrap();
        let w_new = unstack(&outs[0]);
        let m_new = unstack(&outs[1]);
        let v_new = unstack(&outs[2]);
        for i in 0..2 {
            let (wr, mr, vr) = reference::rotated_adam(
                &case.w[i], &case.g[i], &case.m[i], &case.vt[i], &case.u[i],
                &case.v[i], sc, uni,
            );
            assert!(w_new[i].sub(&wr).max_abs() < 1e-4, "{exec} w[{i}]");
            assert!(m_new[i].sub(&mr).max_abs() < 1e-5, "{exec} m[{i}]");
            assert!(v_new[i].sub(&vr).max_abs() < 1e-4, "{exec} v[{i}]");
        }
    }
}

#[test]
fn backend_soap_matches_rust_reference() {
    let rt = Runtime::open(root().join("micro")).unwrap();
    let mut rng = Rng::new(43);
    let case = rot_case(&mut rng, 2, 16, 48);
    let sc = Scalars { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, wd: 0.0, t: 2.0 };
    let inputs = vec![
        tensor_to_value(&stack_refs(&case.w)).unwrap(),
        tensor_to_value(&stack_refs(&case.g)).unwrap(),
        tensor_to_value(&stack_refs(&case.m)).unwrap(),
        tensor_to_value(&stack_refs(&case.vt)).unwrap(),
        tensor_to_value(&stack_refs(&case.u)).unwrap(),
        tensor_to_value(&stack_refs(&case.v)).unwrap(),
        tensor_to_value(&scalars_stack(2, sc, 1.0)).unwrap(),
    ];
    let outs = rt.exec_tensors("soap_bi_wqkv", &inputs).unwrap();
    for i in 0..2 {
        let (wr, mr, vr) = reference::soap_update(
            &case.w[i], &case.g[i], &case.m[i], &case.vt[i], &case.u[i],
            &case.v[i], sc, false,
        );
        assert!(unstack(&outs[0])[i].sub(&wr).max_abs() < 1e-4);
        assert!(unstack(&outs[1])[i].sub(&mr).max_abs() < 1e-5);
        assert!(unstack(&outs[2])[i].sub(&vr).max_abs() < 1e-4);
    }
}

#[test]
fn backend_eigen2nd_matches_rust_reference() {
    let rt = Runtime::open(root().join("micro")).unwrap();
    let mut rng = Rng::new(44);
    let nb = 2;
    let (mm, nn) = (16, 48);
    let case = rot_case(&mut rng, nb, mm, nn);
    let l: Vec<Tensor> = case.g.iter().map(|g| g.matmul(&g.transpose())).collect();
    let r: Vec<Tensor> = case.g.iter().map(|g| g.transpose().matmul(g)).collect();
    let sc = Scalars { lr: 0.0, beta1: 0.9, beta2: 0.99, eps: 0.0, wd: 0.0, t: 1.0 };
    let inputs = vec![
        tensor_to_value(&stack_refs(&l)).unwrap(),
        tensor_to_value(&stack_refs(&r)).unwrap(),
        tensor_to_value(&stack_refs(&case.g)).unwrap(),
        tensor_to_value(&stack_refs(&case.u)).unwrap(),
        tensor_to_value(&stack_refs(&case.v)).unwrap(),
        tensor_to_value(&scalars_stack(nb, sc, 1.0)).unwrap(),
    ];
    let outs = rt.exec_tensors("eigen2nd_bi_wqkv", &inputs).unwrap();
    for i in 0..nb {
        let l_new = l[i].scale(0.99).add(&case.g[i].matmul(&case.g[i].transpose()).scale(0.01));
        let u_new = reference::power_qr(&l_new, &case.u[i]);
        assert!(unstack(&outs[0])[i].sub(&l_new).max_abs() < 1e-3);
        assert!(unstack(&outs[2])[i].sub(&u_new).max_abs() < 2e-3, "U[{i}]");
        // orthogonality of the produced basis
        let u = &unstack(&outs[2])[i];
        assert!(u.matmul(&u.transpose()).sub(&Tensor::eye(mm)).max_abs() < 1e-3);
    }
}

#[test]
fn backend_muon_matches_rust_reference() {
    let rt = Runtime::open(root().join("micro")).unwrap();
    let mut rng = Rng::new(45);
    let case = rot_case(&mut rng, 2, 16, 48);
    let sc = Scalars { lr: 0.0, beta1: 0.95, beta2: 0.0, eps: 0.0, wd: 0.0, t: 1.0 };
    let inputs = vec![
        tensor_to_value(&stack_refs(&case.m)).unwrap(),
        tensor_to_value(&stack_refs(&case.g)).unwrap(),
        tensor_to_value(&scalars_stack(2, sc, 0.0)).unwrap(),
    ];
    let outs = rt.exec_tensors("muon_wqkv", &inputs).unwrap();
    for i in 0..2 {
        let mom_new = case.m[i].scale(0.95).add(&case.g[i]);
        let o = reference::ns_orthonormalize(&mom_new);
        assert!(unstack(&outs[0])[i].sub(&mom_new).max_abs() < 1e-5);
        assert!(unstack(&outs[1])[i].sub(&o).max_abs() < 5e-3, "O[{i}]");
    }
}

/// The same rotated update exported through the interpret-mode Pallas
/// kernels and through native XLA dots must produce identical numerics
/// when executed by the PJRT client. Needs real artifacts + a real xla
/// crate, so it only asserts when the PJRT backend actually opened.
#[cfg(feature = "pjrt")]
#[test]
fn pallas_and_jnp_lowerings_agree_on_pjrt() {
    let rt = match Runtime::open(root().join("micro")) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping pallas cross-check: {e}");
            return;
        }
    };
    if rt.backend_kind() != "pjrt" || !rt.has_executable("rot_adam_bi_wqkv_pallas") {
        eprintln!("skipping pallas cross-check: no pjrt artifacts available");
        return;
    }
    let mut rng = Rng::new(46);
    let case = rot_case(&mut rng, 2, 16, 48);
    let sc = Scalars { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, wd: 0.01, t: 5.0 };
    let inputs: Vec<Value> = vec![
        tensor_to_value(&stack_refs(&case.w)).unwrap(),
        tensor_to_value(&stack_refs(&case.g)).unwrap(),
        tensor_to_value(&stack_refs(&case.m)).unwrap(),
        tensor_to_value(&stack_refs(&case.vt)).unwrap(),
        tensor_to_value(&stack_refs(&case.u)).unwrap(),
        tensor_to_value(&stack_refs(&case.v)).unwrap(),
        tensor_to_value(&scalars_stack(2, sc, 1.0)).unwrap(),
    ];
    let a = rt.exec_tensors("rot_adam_bi_wqkv", &inputs).unwrap();
    let b = rt.exec_tensors("rot_adam_bi_wqkv_pallas", &inputs).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert!(x.sub(y).max_abs() < 1e-5);
    }
}

#[test]
fn split_graph_consistent_with_fused() {
    let rt = Runtime::open(root().join("micro")).unwrap();
    let cfg = rt.cfg().clone();
    let params = init_params(&rt.manifest, 3);
    let toks: Vec<i32> =
        (0..cfg.batch * cfg.seq).map(|i| ((i * 7) % cfg.vocab) as i32).collect();
    let tok_val = || tokens_to_value(&toks, cfg.batch, cfg.seq).unwrap();
    let mut auto_in: Vec<Value> =
        params.iter().map(|p| tensor_to_value(p).unwrap()).collect();
    auto_in.push(tok_val());
    auto_in.push(tok_val());
    let auto = rt.exec("fwdbwd", &auto_in).unwrap();
    let mut split_in: Vec<Value> = Vec::new();
    for p in &params {
        split_in.push(tensor_to_value(p).unwrap());
    }
    for p in &params {
        split_in.push(tensor_to_value(p).unwrap());
    }
    split_in.push(tok_val());
    split_in.push(tok_val());
    let split = rt.exec("fwdbwd_split", &split_in).unwrap();
    let la = abrot::runtime::value_scalar_f32(&auto[0]).unwrap();
    let ls = abrot::runtime::value_scalar_f32(&split[0]).unwrap();
    assert!((la - ls).abs() < 1e-5, "{la} vs {ls}");
    for (i, p) in rt.manifest.params.iter().enumerate() {
        let ga = abrot::runtime::value_to_tensor(&auto[1 + i], &p.shape).unwrap();
        let gs = abrot::runtime::value_to_tensor(&split[1 + i], &p.shape).unwrap();
        let denom = ga.max_abs().max(1e-3);
        assert!(ga.sub(&gs).max_abs() / denom < 1e-2, "param {}", p.name);
    }
}

#[test]
fn engine_matches_simulator_trajectory() {
    // Same seeds + same staleness semantics => the threaded 1F1B engine
    // and the single-process simulator trace the same loss curve.
    // (Clipping disabled: the engine clips per-stage, the sim globally.)
    // DelayComp additionally pins the stash-fed Taylor correction: the
    // engine feeds the optimizer the per-microbatch weight snapshot the
    // gradient was computed at, the sim its stash-ring view.
    let steps = 14;
    for method in [Method::PipeDream, Method::DelayComp { lambda: 0.5 }] {
        let mk = |_: ()| TrainCfg {
            method,
            stages: 2,
            steps,
            lr: 5e-3,
            grad_clip: 1e9,
            seed: 77,
            ..Default::default()
        };
        let rt = Runtime::open(root().join("micro")).unwrap();
        let sim = train_sim(&rt, &mk(())).unwrap();
        let mut coord = Coordinator::new(root());
        let eng = coord
            .run_engine(&Experiment { model: "micro".into(), train: mk(()) })
            .unwrap();
        assert_eq!(sim.losses.len(), eng.losses.len(), "{}", method.name());
        for (i, (a, b)) in sim.losses.iter().zip(&eng.losses).enumerate() {
            assert!(
                (a - b).abs() < 2e-3 * a.abs().max(1.0),
                "{} step {i}: sim {a} vs engine {b}",
                method.name()
            );
        }
    }
}

#[test]
fn engine_matches_simulator_trajectory_br_and_nesterov() {
    // Tentpole acceptance: every stage owns its method's *real*
    // optimizer over a stage-local manifest, so the engine must trace
    // the simulator's loss curve step-for-step for the paper's method
    // (basis rotation, S=2nd/bilateral) and the Nesterov baseline on a
    // P=4 dense preset (clipping disabled).
    let steps = 10;
    for method in [Method::br_default(), Method::Nesterov] {
        let mk = |_: ()| TrainCfg {
            method,
            stages: 4,
            steps,
            lr: 5e-3,
            grad_clip: 1e9,
            seed: 123,
            ..Default::default()
        };
        let rt = Runtime::open(root().join("pico4")).unwrap();
        let sim = train_sim(&rt, &mk(())).unwrap();
        let mut coord = Coordinator::new(root());
        let eng = coord
            .run_engine(&Experiment { model: "pico4".into(), train: mk(()) })
            .unwrap();
        assert_eq!(sim.losses.len(), eng.losses.len(), "{}", method.name());
        for (i, (a, b)) in sim.losses.iter().zip(&eng.losses).enumerate() {
            assert!(
                (a - b).abs() < 5e-3 * a.abs().max(1.0),
                "{} step {i}: sim {a} vs engine {b}",
                method.name()
            );
        }
    }
}

#[test]
fn moe_engine_trains_end_to_end() {
    // Acceptance: an MoE preset trains on the real engine (per-block
    // MoE executables in the per-stage forward/backward path) without
    // bailing, for both a baseline and the paper's method.
    let mut coord = Coordinator::new(root());
    for method in [Method::PipeDream, Method::br_default()] {
        let cfg = TrainCfg {
            method,
            stages: 2,
            steps: 10,
            lr: 5e-3,
            seed: 7,
            eval_every: 5,
            ..Default::default()
        };
        let r = coord
            .run_engine(&Experiment { model: "moe_micro".into(), train: cfg })
            .unwrap_or_else(|e| panic!("moe engine {}: {e}", method.name()));
        assert_eq!(r.losses.len(), 10, "{}", method.name());
        assert!(r.losses.iter().all(|l| l.is_finite()));
        assert!(!r.diverged);
        assert_eq!(r.val_losses.len(), 2, "{}", method.name());
        assert!(r.val_losses.iter().all(|(_, v)| v.is_finite()));
    }
}

#[test]
fn moe_engine_matches_simulator_trajectory() {
    // The per-block MoE composition (incl. the per-block share of the
    // Switch auxiliary gradient) reproduces the monolithic MoE fwdbwd,
    // so engine and simulator agree on MoE exactly as on dense.
    let mk = |_: ()| TrainCfg {
        method: Method::PipeDream,
        stages: 2,
        steps: 8,
        lr: 5e-3,
        grad_clip: 1e9,
        seed: 19,
        ..Default::default()
    };
    let rt = Runtime::open(root().join("moe_micro")).unwrap();
    let sim = train_sim(&rt, &mk(())).unwrap();
    let mut coord = Coordinator::new(root());
    let eng = coord
        .run_engine(&Experiment { model: "moe_micro".into(), train: mk(()) })
        .unwrap();
    assert_eq!(sim.losses.len(), eng.losses.len());
    for (i, (a, b)) in sim.losses.iter().zip(&eng.losses).enumerate() {
        assert!(
            (a - b).abs() < 2e-3 * a.abs().max(1.0),
            "step {i}: sim {a} vs engine {b}"
        );
    }
}

#[test]
fn engine_runs_every_method_on_dense_and_moe() {
    // No silent fallback: every Method constructs and steps its real
    // per-stage optimizer on the engine, dense and MoE alike.
    let methods = [
        Method::PipeDream,
        Method::PipeDreamLr,
        Method::Nesterov,
        Method::DelayComp { lambda: 0.1 },
        Method::br_default(),
        Method::Soap { freq: 5 },
        Method::Muon,
        Method::Scion,
    ];
    let mut coord = Coordinator::new(root());
    for model in ["micro", "moe_micro"] {
        for m in methods {
            let cfg = TrainCfg {
                method: m,
                stages: 2,
                steps: 4,
                seed: 21,
                ..Default::default()
            };
            let r = coord
                .run_engine(&Experiment { model: model.into(), train: cfg })
                .unwrap_or_else(|e| panic!("{model} {}: {e}", m.name()));
            assert_eq!(r.losses.len(), 4, "{model} {}", m.name());
            assert!(r.losses.iter().all(|l| l.is_finite()), "{model} {}", m.name());
            assert!(r.optimizer_state_elems > 0, "{model} {}", m.name());
        }
    }
}

#[test]
fn engine_detects_divergence_and_stops() {
    // Unlike the old engine (which pushed non-finite losses forever),
    // the last stage now mirrors train_sim: flag, skip the update, stop.
    let mut coord = Coordinator::new(root());
    let cfg = TrainCfg {
        method: Method::PipeDream,
        stages: 2,
        steps: 12,
        lr: 1e9, // guaranteed blow-up
        grad_clip: 1e12,
        warmup_frac: 0.0,
        seed: 3,
        ..Default::default()
    };
    let r = coord
        .run_engine(&Experiment { model: "micro".into(), train: cfg })
        .unwrap();
    assert!(r.diverged, "expected divergence at lr=1e9");
    assert!(r.losses.len() < 12, "run should stop early, got {}", r.losses.len());
    assert!(r.losses.iter().all(|l| l.is_finite()), "non-finite loss recorded");
}

#[test]
fn engine_val_losses_match_simulator_at_p1() {
    // With one stage the engine's validation pass is the simulator's:
    // post-update weights, same deterministic validation stream.
    let mk = |_: ()| TrainCfg {
        method: Method::PipeDream,
        stages: 1,
        steps: 12,
        lr: 5e-3,
        eval_every: 4,
        seed: 31,
        ..Default::default()
    };
    let rt = Runtime::open(root().join("micro")).unwrap();
    let sim = train_sim(&rt, &mk(())).unwrap();
    let mut coord = Coordinator::new(root());
    let eng = coord
        .run_engine(&Experiment { model: "micro".into(), train: mk(()) })
        .unwrap();
    assert_eq!(sim.val_losses.len(), 3);
    assert_eq!(eng.val_losses.len(), 3);
    for ((ts, vs), (te, ve)) in sim.val_losses.iter().zip(&eng.val_losses) {
        assert_eq!(ts, te);
        assert!(
            (vs - ve).abs() < 1e-5 * vs.abs().max(1.0),
            "val@{ts}: sim {vs} vs engine {ve}"
        );
    }
}

#[test]
fn engine_samples_val_losses_through_the_pipeline() {
    // P>1: stage 0 threads eval forwards through the pipeline, the last
    // stage scores them — val_losses labelled by update step, in order.
    let mut coord = Coordinator::new(root());
    let cfg = TrainCfg {
        method: Method::PipeDream,
        stages: 2,
        steps: 12,
        lr: 5e-3,
        eval_every: 3,
        seed: 41,
        ..Default::default()
    };
    let r = coord
        .run_engine(&Experiment { model: "micro".into(), train: cfg })
        .unwrap();
    let labels: Vec<u32> = r.val_losses.iter().map(|(t, _)| *t).collect();
    assert_eq!(labels, vec![3, 6, 9, 12]);
    assert!(r.val_losses.iter().all(|(_, v)| v.is_finite()));
}

#[test]
fn engine_single_stage_works() {
    let mut coord = Coordinator::new(root());
    let cfg = TrainCfg {
        method: Method::PipeDream,
        stages: 1,
        steps: 8,
        lr: 5e-3,
        seed: 5,
        ..Default::default()
    };
    let r = coord
        .run_engine(&Experiment { model: "micro".into(), train: cfg })
        .unwrap();
    assert_eq!(r.losses.len(), 8);
    assert!(r.losses.iter().all(|l| l.is_finite()));
}

#[test]
fn sim_is_deterministic() {
    let rt = Runtime::open(root().join("micro")).unwrap();
    let cfg = TrainCfg {
        method: Method::br_default(),
        stages: 2,
        steps: 10,
        seed: 9,
        ..Default::default()
    };
    let a = train_sim(&rt, &cfg).unwrap();
    let b = train_sim(&rt, &cfg).unwrap();
    assert_eq!(a.losses, b.losses);
}

#[test]
fn staleness_changes_trajectory_and_p1_does_not_stash() {
    let rt = Runtime::open(root().join("micro")).unwrap();
    let base = TrainCfg {
        method: Method::PipeDream,
        stages: 1,
        steps: 12,
        seed: 11,
        ..Default::default()
    };
    let p1 = train_sim(&rt, &base).unwrap();
    let p2 = train_sim(&rt, &TrainCfg { stages: 2, ..base.clone() }).unwrap();
    // first step identical (pipeline not yet filled), later steps diverge
    assert!((p1.losses[0] - p2.losses[0]).abs() < 1e-6);
    assert!(p1.losses[8..] != p2.losses[8..]);
}

#[test]
fn nostash_and_predict_modes_run() {
    let rt = Runtime::open(root().join("micro")).unwrap();
    for stash in [StashMode::NoStash, StashMode::Predict] {
        let cfg = TrainCfg {
            method: Method::PipeDream,
            stages: 2,
            steps: 10,
            stash,
            seed: 13,
            ..Default::default()
        };
        let r = train_sim(&rt, &cfg).unwrap();
        assert_eq!(r.losses.len(), 10);
        assert!(r.losses.iter().all(|l| l.is_finite()));
    }
}

#[test]
fn all_methods_run_one_step_on_moe_and_dense() {
    let methods = [
        Method::PipeDream,
        Method::PipeDreamLr,
        Method::Nesterov,
        Method::DelayComp { lambda: 0.1 },
        Method::br_default(),
        Method::Soap { freq: 5 },
        Method::Muon,
        Method::Scion,
    ];
    for model in ["micro", "moe_micro"] {
        let rt = Runtime::open(root().join(model)).unwrap();
        for m in methods {
            let cfg = TrainCfg {
                method: m,
                stages: 2,
                steps: 6,
                seed: 21,
                ..Default::default()
            };
            let r = train_sim(&rt, &cfg)
                .unwrap_or_else(|e| panic!("{model} {}: {e}", m.name()));
            assert!(r.losses.iter().all(|l| l.is_finite()), "{model} {}", m.name());
        }
    }
}

/// Independent sequential large-batch reference for the DP axis: at
/// P = 1 (no staleness) compute the R shard gradients one after the
/// other, fold them in replica order exactly like `pipeline::dp`
/// (clone the first set, add the rest, scale by 1/R), clip, and take
/// one optimizer step. `replicas = R` in the simulator must reproduce
/// this trajectory *bit for bit* — DP at P=1 is just a bigger batch.
fn seq_large_batch_ref(rt: &Runtime, cfg: &TrainCfg) -> Vec<f32> {
    let man = &rt.manifest;
    let mcfg = rt.cfg().clone();
    let r_count = cfg.dp_replicas();
    let part = StagePartition::new(man, 1);
    let mut params = init_params(man, cfg.seed);
    let mut opt = optim::build(&cfg.method, rt, cfg);
    let corpus = Corpus::new(mcfg.vocab, cfg.seed ^ 0xDA7A);
    let mut iters: Vec<BatchIter> = (0..r_count)
        .map(|r| {
            BatchIter::new(
                corpus.clone(),
                mcfg.batch,
                mcfg.seq,
                replica_stream(TRAIN_STREAM, r),
            )
        })
        .collect();
    let mut losses = Vec::new();
    for t in 1..=cfg.steps as u64 {
        let mut acc: Option<Vec<Tensor>> = None;
        let mut loss_sum = 0.0f32;
        for it in iters.iter_mut() {
            let (toks, tgts) = it.next_batch();
            let mut ins: Vec<Value> =
                params.iter().map(|p| tensor_to_value(p).unwrap()).collect();
            ins.push(tokens_to_value(&toks, mcfg.batch, mcfg.seq).unwrap());
            ins.push(tokens_to_value(&tgts, mcfg.batch, mcfg.seq).unwrap());
            let outs = rt.exec("fwdbwd", &ins).unwrap();
            loss_sum += value_scalar_f32(&outs[0]).unwrap();
            let grads: Vec<Tensor> = outs[1..]
                .iter()
                .zip(man.params.iter())
                .map(|(v, p)| value_to_tensor(v, &p.shape).unwrap())
                .collect();
            if acc.is_none() {
                acc = Some(grads);
            } else {
                let folded = acc.as_mut().unwrap();
                for (a, g) in folded.iter_mut().zip(&grads) {
                    for (x, &y) in a.data.iter_mut().zip(&g.data) {
                        *x += y;
                    }
                }
            }
        }
        let mut grads = acc.unwrap();
        if r_count > 1 {
            let inv = 1.0 / r_count as f32;
            for g in grads.iter_mut() {
                for x in g.data.iter_mut() {
                    *x *= inv;
                }
            }
        }
        clip_global_norm(&mut grads, cfg.grad_clip);
        // DelayComp's Taylor reference: at P=1 the "stale" view is the
        // current weights (zero delay), like the simulator's stash.
        let stale_view = params.clone();
        let ctx = StepCtx {
            t,
            lr: cfg.lr_at(t as u32),
            cfg,
            part: &part,
            stale: Some(&stale_view),
            rt,
        };
        opt.step(&ctx, &mut params, &grads).unwrap();
        losses.push(loss_sum / r_count as f32);
    }
    losses
}

#[test]
fn dp_at_p1_exactly_reproduces_sequential_large_batch_every_method() {
    // Tentpole acceptance: replicas = R at P = 1 is the sequential
    // R x b large-batch run, bit for bit, for every optimizer method.
    let methods = [
        Method::PipeDream,
        Method::PipeDreamLr,
        Method::Nesterov,
        Method::DelayComp { lambda: 0.1 },
        Method::br_default(),
        Method::Soap { freq: 3 },
        Method::Muon,
        Method::Scion,
    ];
    let rt = Runtime::open(root().join("micro")).unwrap();
    for m in methods {
        for replicas in [1usize, 2, 4] {
            let cfg = TrainCfg {
                method: m,
                stages: 1,
                replicas,
                steps: 6,
                lr: 5e-3,
                seed: 55,
                ..Default::default()
            };
            let sim = train_sim(&rt, &cfg).unwrap();
            let want = seq_large_batch_ref(&rt, &cfg);
            assert_eq!(sim.losses.len(), want.len(), "{} R={replicas}", m.name());
            for (i, (a, b)) in sim.losses.iter().zip(&want).enumerate() {
                assert!(
                    a == b,
                    "{} R={replicas} step {}: sim {a} vs sequential {b}",
                    m.name(),
                    i + 1
                );
            }
        }
    }
}

#[test]
fn dp_engine_matches_simulator_trajectory_p4_r2() {
    // The DP axis composes with staleness on the real engine: at
    // P=4 x R=2 the threaded pipelines (per-replica 1F1B stashes,
    // channel-based all-reduce per stage) trace the simulator's
    // replica-mean loss curve for the baseline and the paper's method.
    // (Clipping disabled: the engine clips per-stage, the sim globally.)
    let steps = 10;
    for method in [Method::PipeDream, Method::br_default()] {
        let mk = |_: ()| TrainCfg {
            method,
            stages: 4,
            replicas: 2,
            steps,
            lr: 5e-3,
            grad_clip: 1e9,
            seed: 321,
            ..Default::default()
        };
        let rt = Runtime::open(root().join("pico4")).unwrap();
        let sim = train_sim(&rt, &mk(())).unwrap();
        let mut coord = Coordinator::new(root());
        let eng = coord
            .run_engine(&Experiment { model: "pico4".into(), train: mk(()) })
            .unwrap();
        assert_eq!(eng.replicas, 2);
        assert_eq!(sim.losses.len(), eng.losses.len(), "{}", method.name());
        for (i, (a, b)) in sim.losses.iter().zip(&eng.losses).enumerate() {
            assert!(
                (a - b).abs() < 5e-3 * a.abs().max(1.0),
                "{} step {i}: sim {a} vs engine {b}",
                method.name()
            );
        }
        // per-(replica x stage) counters cover the whole R x P grid
        let mut cells: Vec<(usize, usize)> =
            eng.stage_counters.iter().map(|c| (c.replica, c.stage)).collect();
        cells.sort_unstable();
        cells.dedup();
        assert_eq!(cells.len(), 2 * 4, "{}", method.name());
        assert!(eng.stage_counters.iter().all(|c| c.updates == steps as u64));
    }
}

#[test]
fn dp_engine_replicas_share_validation_and_divergence_contracts() {
    // R=2 engine run with validation: only replica 0 samples the val
    // stream, labels match the R=1 behaviour; loss count unchanged.
    let mut coord = Coordinator::new(root());
    let cfg = TrainCfg {
        method: Method::PipeDream,
        stages: 2,
        replicas: 2,
        steps: 12,
        lr: 5e-3,
        eval_every: 3,
        seed: 41,
        ..Default::default()
    };
    let r = coord
        .run_engine(&Experiment { model: "micro".into(), train: cfg })
        .unwrap();
    assert_eq!(r.losses.len(), 12);
    let labels: Vec<u32> = r.val_losses.iter().map(|(t, _)| *t).collect();
    assert_eq!(labels, vec![3, 6, 9, 12]);
    assert!(!r.diverged);

    // divergence in any replica stops the whole DP group
    let blow_up = TrainCfg {
        method: Method::PipeDream,
        stages: 2,
        replicas: 2,
        steps: 12,
        lr: 1e9,
        grad_clip: 1e12,
        warmup_frac: 0.0,
        seed: 3,
        ..Default::default()
    };
    let r = coord
        .run_engine(&Experiment { model: "micro".into(), train: blow_up })
        .unwrap();
    assert!(r.diverged, "expected divergence at lr=1e9");
    assert!(r.losses.len() < 12, "run should stop early, got {}", r.losses.len());
    assert!(r.losses.iter().all(|l| l.is_finite()));
}

/// Property-style sweep: for random (P, seed) the stash ring always
/// serves versions exactly tau behind, via the public simulator
/// behaviour: with lr=0 every version is identical so delayed and fresh
/// runs agree; with lr>0 and P>1 they must differ.
#[test]
fn property_delay_semantics_random_cases() {
    let rt = Runtime::open(root().join("micro")).unwrap();
    let mut rng = Rng::new(12345);
    for _case in 0..4 {
        let stages = 1 + rng.below(2); // micro has 2 blocks
        let seed = rng.next_u64();
        let zero_lr = TrainCfg {
            method: Method::PipeDream,
            stages,
            steps: 6,
            lr: 0.0,
            warmup_frac: 0.0,
            weight_decay: 0.0,
            seed,
            ..Default::default()
        };
        let r0 = train_sim(&rt, &zero_lr).unwrap();
        let r1 = train_sim(&rt, &TrainCfg { stages: 1, ..zero_lr.clone() }).unwrap();
        // zero lr => losses independent of staleness
        for (a, b) in r0.losses.iter().zip(&r1.losses) {
            assert!((a - b).abs() < 1e-6);
        }
    }
}
