//! Randomized equivalence suite for the pooled, cache-tiled kernel
//! layer (`runtime::pool` + the shared `tensor` kernels): the parallel
//! kernels must be **bit-identical** to their single-threaded `*_ref`
//! oracles at every thread budget, for matmul/transpose, attention
//! forward/backward, and the fused batched optimizer dispatches — plus
//! an engine-vs-simulator trajectory check at `--threads 4`.
//!
//! Thread budgets are exercised through `pool::install_budget`, the
//! same thread-local override the engine's stage workers use, so the
//! suite covers the exact dispatch path of `--threads N` without
//! spawning a CLI.

use std::path::PathBuf;

use abrot::config::{Method, TrainCfg};
use abrot::coordinator::{Coordinator, Experiment};
use abrot::optim::reference::{self, Scalars};
use abrot::pipeline::train_sim;
use abrot::rngs::Rng;
use abrot::runtime::native::{dense, exec_optimizer};
use abrot::runtime::pool::{auto_threads, install_budget};
use abrot::runtime::{ModelCfg, Runtime, Value};
use abrot::tensor::{stack, Tensor};

fn root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// The thread budgets every equivalence check runs under: serial, the
/// smallest parallel split, a prime that never divides the row counts
/// evenly, and whatever this host resolves to.
fn budgets() -> Vec<usize> {
    let mut b = vec![1usize, 2, 7];
    let auto = auto_threads();
    if !b.contains(&auto) {
        b.push(auto);
    }
    b
}

fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v, 1.0);
    v
}

fn randn(rng: &mut Rng, shape: &[usize]) -> Tensor {
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(&mut t.data, 1.0);
    t
}

#[test]
fn matmul_variants_bit_exact_vs_ref_across_shapes_and_threads() {
    // Shapes straddle the parallel threshold and include degenerate,
    // odd, and tile-boundary-crossing sizes.
    let shapes = [
        (1usize, 1usize, 1usize),
        (3, 5, 4),
        (17, 31, 13),
        (33, 129, 65),
        (64, 64, 64),
        (130, 300, 96),
    ];
    let mut rng = Rng::new(0xbead);
    for &(m, k, n) in &shapes {
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let bt = randv(&mut rng, n * k); // B stored (n, k) for mm_bt
        let at = randv(&mut rng, k * m); // A stored (k, m) for mm_at
        let want_mm = dense::mm_ref(&a, &b, m, k, n);
        let want_bt = dense::mm_bt_ref(&a, &bt, m, k, n);
        let want_at = dense::mm_at_ref(&at, &b, k, m, n);
        for threads in budgets() {
            let _b = install_budget(threads);
            assert_eq!(dense::mm(&a, &b, m, k, n), want_mm, "mm {m}x{k}x{n} t={threads}");
            assert_eq!(
                dense::mm_bt(&a, &bt, m, k, n),
                want_bt,
                "mm_bt {m}x{k}x{n} t={threads}"
            );
            assert_eq!(
                dense::mm_at(&at, &b, k, m, n),
                want_at,
                "mm_at {m}x{k}x{n} t={threads}"
            );
        }
    }
}

#[test]
fn tensor_matmul_and_transpose_bit_exact_across_threads() {
    let mut rng = Rng::new(0x7a11);
    for &(m, k, n) in &[(5usize, 3usize, 4usize), (65, 130, 48), (128, 64, 128)] {
        let a = randn(&mut rng, &[m, k]);
        let b = randn(&mut rng, &[k, n]);
        let want = a.matmul_ref(&b);
        let want_t = a.transpose_ref();
        for threads in budgets() {
            let _g = install_budget(threads);
            assert_eq!(a.matmul(&b).data, want.data, "matmul {m}x{k}x{n} t={threads}");
            assert_eq!(a.transpose().data, want_t.data, "transpose {m}x{k} t={threads}");
        }
    }
}

fn attn_cfg(batch: usize, seq: usize, d_model: usize, n_heads: usize) -> ModelCfg {
    ModelCfg {
        name: "kernels-test".into(),
        vocab: 64,
        seq,
        d_model,
        n_heads,
        n_blocks: 1,
        d_ff: 4 * d_model,
        batch,
        moe: None,
    }
}

#[test]
fn attention_fwd_bwd_bit_exact_vs_ref_across_threads() {
    // (4, 32, 32, 4): b*h*s^2*hd = 131072 — well above the parallel
    // threshold. (1, 9, 12, 3): stays on the inline path. Both must be
    // bit-identical to the reference either way.
    let configs = [attn_cfg(4, 32, 32, 4), attn_cfg(1, 9, 12, 3)];
    let mut rng = Rng::new(0xa77e);
    for cfg in &configs {
        let t = cfg.batch * cfg.seq;
        let qkv = randv(&mut rng, t * 3 * cfg.d_model);
        let doc = randv(&mut rng, t * cfg.d_model);
        let (oc_ref, cache_ref) = dense::attention_fwd_ref(cfg, &qkv);
        let dqkv_ref = dense::attention_bwd_ref(cfg, &cache_ref, &doc);
        for threads in budgets() {
            let _g = install_budget(threads);
            let (oc, cache) = dense::attention_fwd(cfg, &qkv);
            assert_eq!(oc, oc_ref, "{} attention_fwd t={threads}", cfg.name);
            assert_eq!(cache.q, cache_ref.q, "cache.q t={threads}");
            assert_eq!(cache.k, cache_ref.k, "cache.k t={threads}");
            assert_eq!(cache.v, cache_ref.v, "cache.v t={threads}");
            assert_eq!(cache.p, cache_ref.p, "cache.p t={threads}");
            let dqkv = dense::attention_bwd(cfg, &cache, &doc);
            assert_eq!(dqkv, dqkv_ref, "{} attention_bwd t={threads}", cfg.name);
        }
    }
}

fn stack_tensors(ts: &[Tensor]) -> Tensor {
    let refs: Vec<&Tensor> = ts.iter().collect();
    stack(&refs)
}

fn scalars() -> Scalars {
    Scalars { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, wd: 0.01, t: 3.0 }
}

fn scalar_rows(nb: usize, mask_of: impl Fn(usize) -> f32) -> Tensor {
    let mut sc = Tensor::zeros(&[nb, 8]);
    for i in 0..nb {
        sc.data[i * 8..(i + 1) * 8].copy_from_slice(&scalars().to_row(mask_of(i)));
    }
    sc
}

/// Stacked (w, g, m, vt, u, v, sc) inputs for the rotated-Adam / SOAP
/// executables: `nb` slots of (m x n), big enough to cross the fused
/// dispatch's parallel threshold.
fn rot_inputs(rng: &mut Rng, nb: usize, m: usize, n: usize) -> Vec<Value> {
    let mk = |rng: &mut Rng| -> Vec<Tensor> { (0..nb).map(|_| randn(rng, &[m, n])).collect() };
    let w = mk(rng);
    let g = mk(rng);
    let mo = mk(rng);
    let vt: Vec<Tensor> = mk(rng).iter().map(|t| t.map(f32::abs)).collect();
    let u: Vec<Tensor> = (0..nb).map(|_| reference::cgs2_qr(&randn(rng, &[m, m]))).collect();
    let v: Vec<Tensor> = (0..nb).map(|_| reference::cgs2_qr(&randn(rng, &[n, n]))).collect();
    vec![
        Value::F32(stack_tensors(&w)),
        Value::F32(stack_tensors(&g)),
        Value::F32(stack_tensors(&mo)),
        Value::F32(stack_tensors(&vt)),
        Value::F32(stack_tensors(&u)),
        Value::F32(stack_tensors(&v)),
        Value::F32(scalar_rows(nb, |i| (i % 2) as f32)),
    ]
}

#[test]
fn fused_rot_adam_matches_serial_reference_loop() {
    // The fused dispatch vs a hand-rolled serial loop over the shared
    // single-matrix reference — exact equality, every output.
    let mut rng = Rng::new(0x0ad3);
    let (nb, m, n) = (8usize, 32usize, 40usize);
    let inputs = rot_inputs(&mut rng, nb, m, n);
    let outs = {
        let _g = install_budget(7);
        exec_optimizer("rot_adam_bi_2d", &inputs).unwrap()
    };
    let s = scalars();
    for i in 0..nb {
        let slot = |j: usize| inputs[j].as_tensor().unwrap().index_axis0(i);
        let (wr, mr, vr) = reference::rotated_adam(
            &slot(0),
            &slot(1),
            &slot(2),
            &slot(3),
            &slot(4),
            &slot(5),
            s,
            false,
        );
        assert_eq!(outs[0].as_tensor().unwrap().index_axis0(i).data, wr.data, "w slot {i}");
        assert_eq!(outs[1].as_tensor().unwrap().index_axis0(i).data, mr.data, "m slot {i}");
        assert_eq!(outs[2].as_tensor().unwrap().index_axis0(i).data, vr.data, "vt slot {i}");
    }
}

#[test]
fn fused_optimizer_dispatches_bit_exact_across_threads() {
    // Every batched optimizer executable must produce identical bits at
    // every thread budget (serial baseline = budget 1).
    let mut rng = Rng::new(0x50a9);
    let (nb, m, n) = (8usize, 32usize, 40usize);
    let rot = rot_inputs(&mut rng, nb, m, n);
    let mk = |rng: &mut Rng| -> Vec<Tensor> { (0..nb).map(|_| randn(rng, &[m, n])).collect() };
    let g = mk(&mut rng);
    let l: Vec<Tensor> = (0..nb).map(|_| randn(&mut rng, &[m, m])).collect();
    let r: Vec<Tensor> = (0..nb).map(|_| randn(&mut rng, &[n, n])).collect();
    let u: Vec<Tensor> =
        (0..nb).map(|_| reference::cgs2_qr(&randn(&mut rng, &[m, m]))).collect();
    let v: Vec<Tensor> =
        (0..nb).map(|_| reference::cgs2_qr(&randn(&mut rng, &[n, n]))).collect();
    let sc = scalar_rows(nb, |i| (i % 2) as f32);
    let eigen2 = vec![
        Value::F32(stack_tensors(&l)),
        Value::F32(stack_tensors(&r)),
        Value::F32(stack_tensors(&g)),
        Value::F32(stack_tensors(&u)),
        Value::F32(stack_tensors(&v)),
        Value::F32(sc.clone()),
    ];
    let eigen1 = vec![
        Value::F32(stack_tensors(&g)),
        Value::F32(stack_tensors(&u)),
        Value::F32(stack_tensors(&v)),
        Value::F32(sc.clone()),
    ];
    let muon = vec![
        Value::F32(stack_tensors(&mk(&mut rng))),
        Value::F32(stack_tensors(&g)),
        Value::F32(sc),
    ];
    let cases: Vec<(&str, &[Value])> = vec![
        ("rot_adam_bi_2d", &rot),
        ("soap_uni_2d", &rot),
        ("eigen2nd_bi_2d", &eigen2),
        ("eigen1st_uni_2d", &eigen1),
        ("muon_2d", &muon),
    ];
    for (name, inputs) in cases {
        let baseline = {
            let _g = install_budget(1);
            exec_optimizer(name, inputs).unwrap()
        };
        for threads in budgets() {
            let _g = install_budget(threads);
            let outs = exec_optimizer(name, inputs).unwrap();
            assert_eq!(outs.len(), baseline.len(), "{name} arity t={threads}");
            for (o, b) in outs.iter().zip(&baseline) {
                assert_eq!(
                    o.as_tensor().unwrap().data,
                    b.as_tensor().unwrap().data,
                    "{name} t={threads}"
                );
            }
        }
    }
}

#[test]
fn simulator_trajectory_bit_exact_across_thread_budgets() {
    // The whole training loop — not just individual kernels — must not
    // move a single bit when the kernel budget changes.
    let rt = Runtime::open(root().join("micro")).unwrap();
    let mk = |threads: usize| TrainCfg {
        method: Method::br_default(),
        stages: 2,
        steps: 8,
        lr: 5e-3,
        seed: 99,
        threads,
        ..Default::default()
    };
    let base = train_sim(&rt, &mk(1)).unwrap();
    for threads in [2usize, 4, 7] {
        let run = train_sim(&rt, &mk(threads)).unwrap();
        assert_eq!(base.losses, run.losses, "threads={threads}");
        assert_eq!(run.threads, threads);
    }
}

#[test]
fn engine_matches_simulator_trajectory_at_threads_4() {
    // The parallel-kernel engine at --threads 4 traces the same loss
    // curve as the simulator at --threads 4 (which itself is bit-equal
    // to --threads 1 by the test above). Same shape as the existing
    // engine-vs-sim checks: clipping disabled, relative tolerance.
    let steps = 12;
    let mk = |_: ()| TrainCfg {
        method: Method::PipeDream,
        stages: 2,
        steps,
        lr: 5e-3,
        grad_clip: 1e9,
        seed: 77,
        threads: 4,
        ..Default::default()
    };
    let rt = Runtime::open(root().join("micro")).unwrap();
    let sim = train_sim(&rt, &mk(())).unwrap();
    let mut coord = Coordinator::new(root());
    let eng = coord
        .run_engine(&Experiment { model: "micro".into(), train: mk(()) })
        .unwrap();
    assert_eq!(sim.losses.len(), eng.losses.len());
    assert_eq!(eng.threads, 4);
    for (i, (a, b)) in sim.losses.iter().zip(&eng.losses).enumerate() {
        assert!(
            (a - b).abs() < 2e-3 * a.abs().max(1.0),
            "step {i}: sim {a} vs engine {b}"
        );
    }
}
