//! Integration tests for the observability subsystem (`rust/src/trace/`,
//! `rust/src/metrics/registry.rs`, `rust/src/bench/` snapshots):
//!
//! * Engine span timelines (`--trace`): the Chrome trace_event JSON
//!   parses with the in-crate parser, spans never overlap within a
//!   worker thread, per-span dispatch counts sum to the run's total,
//!   and the busy/idle split agrees with the wall-clock `bubble_frac`.
//! * Staleness accounting: the per-chunk realized-delay histogram's
//!   steady-state mode equals the schedule's declared chunk delay.
//! * Step metrics (`--metrics`): JSONL rows parse, carry monotone
//!   1-based steps, and cover every optimizer step.
//! * Virtual-clock traces: the schedule model executor emits the same
//!   span format (slot-aligned timestamps, `model/w{w}` thread rows).
//! * Committed perf baselines: `benchmarks/BENCH_*.json` load through
//!   the vendored serde path, validate, and self-compare clean.
//!
//! All test names carry the `trace_` prefix so the CI fast-path job
//! can run exactly this battery (`cargo test --release -q trace_`).

use std::collections::HashMap;
use std::path::PathBuf;

use abrot::bench;
use abrot::config::{Method, ScheduleKind, TrainCfg};
use abrot::coordinator::{Coordinator, Experiment};
use abrot::jsonio::Json;
use abrot::metrics::RunResult;
use abrot::pipeline::schedule;

fn root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Per-test scratch dir, wiped on entry so a crashed previous run
/// cannot leak stale trace files into this one.
fn tdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("abrot_trace_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

const STEPS: u32 = 12;
const P: usize = 4;

/// Run the threaded engine on pico8 with tracing + metrics enabled.
/// `eval_every: 0` keeps every runtime dispatch inside some span, so
/// the per-span `n_disp` counts must sum to `RunResult.dispatches`.
fn engine_run(kind: ScheduleKind, dir: &std::path::Path) -> (RunResult, String, String) {
    let trace_path = dir.join("trace.json").to_string_lossy().into_owned();
    let metrics_path = dir.join("metrics.jsonl").to_string_lossy().into_owned();
    let cfg = TrainCfg {
        method: Method::PipeDream,
        stages: P,
        steps: STEPS,
        lr: 1e-2,
        seed: 7,
        eval_every: 0,
        log_every: 0,
        schedule: kind,
        trace: Some(trace_path.clone()),
        metrics: Some(metrics_path.clone()),
        ..Default::default()
    };
    let mut coord = Coordinator::new(root());
    let res = coord
        .run_engine(&Experiment { model: "pico8".to_string(), train: cfg })
        .unwrap();
    (res, trace_path, metrics_path)
}

/// Shared assertion battery over an engine run's trace + metrics files.
fn check_engine_observability(kind: ScheduleKind, tag: &str) {
    let dir = tdir(tag);
    let (res, trace_path, metrics_path) = engine_run(kind, &dir);
    assert!(res.dispatches > 0);
    assert_eq!(res.losses.len(), STEPS as usize);

    // ---- trace file: parse with the in-crate parser ----------------
    let text = std::fs::read_to_string(&trace_path).unwrap();
    let parsed = Json::parse(&text).unwrap();
    assert_eq!(parsed.at("displayTimeUnit").as_str(), "ms");
    let evs = parsed.at("traceEvents").as_arr();

    let mut by_thread: HashMap<(usize, usize), Vec<(f64, f64)>> = HashMap::new();
    let mut n_disp_sum = 0u64;
    let (mut busy_us, mut idle_us) = (0.0f64, 0.0f64);
    let mut names_seen: Vec<String> = Vec::new();
    for e in evs.iter() {
        if e.at("ph").as_str() != "X" {
            continue;
        }
        let name = e.at("name").as_str();
        if !names_seen.iter().any(|n| n == name) {
            names_seen.push(name.to_string());
        }
        let ts = e.at("ts").as_f64();
        let dur = e.at("dur").as_f64();
        assert!(ts >= 0.0 && dur >= 0.0, "negative span geometry");
        n_disp_sum += e.at("args").at("n_disp").as_usize() as u64;
        if name == "Idle" || name == "Reduce" {
            idle_us += dur;
        } else {
            busy_us += dur;
        }
        by_thread
            .entry((e.at("pid").as_usize(), e.at("tid").as_usize()))
            .or_default()
            .push((ts, dur));
    }
    // R=1 => one timeline row per worker thread.
    assert_eq!(by_thread.len(), P, "expected {P} worker timelines");
    assert!(names_seen.iter().any(|n| n == "Fwd"));
    assert!(names_seen.iter().any(|n| n == "Bwd"));
    assert!(names_seen.iter().any(|n| n == "Update"));

    // Spans on one thread never overlap (0.5 µs float slack).
    for ((pid, tid), spans) in by_thread.iter_mut() {
        spans.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for w in spans.windows(2) {
            assert!(
                w[1].0 >= w[0].0 + w[0].1 - 0.5,
                "overlapping spans on r{pid}/w{tid}: [{} +{}] then [{}]",
                w[0].0,
                w[0].1,
                w[1].0
            );
        }
    }

    // Every dispatch happened inside some span (eval is off).
    assert_eq!(n_disp_sum, res.dispatches, "span n_disp must sum to RunResult.dispatches");

    // The trace's busy/idle split is the same measurement the engine
    // folds into bubble_frac; they agree within 5 points.
    let span_bubble = idle_us / (busy_us + idle_us);
    assert!(
        (span_bubble - res.bubble_frac).abs() < 0.05,
        "span bubble {span_bubble:.4} vs wall-clock bubble {:.4}",
        res.bubble_frac
    );

    // RunResult.stage_spans is the same data aggregated per worker.
    assert_eq!(res.stage_spans.len(), P);
    let busy_rs: f64 = res.stage_spans.iter().map(|s| s.busy_s).sum();
    let idle_rs: f64 = res.stage_spans.iter().map(|s| s.idle_s).sum();
    assert!((busy_rs - busy_us / 1e6).abs() < 1e-6, "stage_spans busy != trace busy");
    assert!((idle_rs - idle_us / 1e6).abs() < 1e-6, "stage_spans idle != trace idle");
    for sp in &res.stage_spans {
        assert!(sp.spans > 0, "worker {} recorded no spans", sp.worker);
    }

    // ---- staleness histogram: steady-state mode == declared delay --
    // `staleness_histogram` is the per-chunk merge (Hist::merge) of the
    // per-replica rows; at R=1 it must equal replica 0's rows exactly.
    let sched = schedule::build(kind);
    let specs = sched.chunks(P);
    assert_eq!(res.staleness_histogram.len(), specs.len());
    assert_eq!(
        res.staleness_by_replica.len(),
        specs.len(),
        "one staleness row per (replica, chunk)"
    );
    for (rep, chunk, counts) in &res.staleness_by_replica {
        assert_eq!(*rep, 0, "R=1 run sampled a phantom replica");
        let (_, merged) = res
            .staleness_histogram
            .iter()
            .find(|(c, _)| c == chunk)
            .unwrap_or_else(|| panic!("chunk {chunk} missing from merged view"));
        assert_eq!(counts, merged, "R=1: merged view == replica-0 rows");
    }
    for (chunk, hist) in &res.staleness_histogram {
        let spec = specs.iter().find(|s| s.id == *chunk).unwrap();
        assert!(hist.iter().sum::<u64>() > 0, "chunk {chunk} histogram is empty");
        let mode = hist
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(&a.0)))
            .map(|(i, _)| i)
            .unwrap();
        assert_eq!(
            mode, spec.delay as usize,
            "chunk {chunk}: histogram mode {mode} != declared delay {}",
            spec.delay
        );
    }

    // ---- metrics JSONL: monotone 1-based steps covering the run ----
    let mtext = std::fs::read_to_string(&metrics_path).unwrap();
    let mut prev = 0u64;
    let mut rows = 0usize;
    for line in mtext.lines() {
        let row = Json::parse(line).unwrap();
        let step = row.at("step").as_usize() as u64;
        assert!(step > prev, "steps must be strictly monotone");
        prev = step;
        assert!(row.at("loss").as_f64().is_finite());
        assert!(row.at("lr").as_f64() > 0.0);
        rows += 1;
    }
    assert_eq!(rows, res.losses.len());
    assert_eq!(prev, STEPS as u64);

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn trace_engine_1f1b_timeline_consistent() {
    check_engine_observability(ScheduleKind::OneFOneB, "eng_1f1b");
}

#[test]
fn trace_engine_interleaved_timeline_consistent() {
    check_engine_observability(ScheduleKind::Interleaved { v: 2 }, "eng_il2");
}

#[test]
fn trace_sim_virtual_clock_timeline() {
    let dir = tdir("sim");
    let trace_path = dir.join("trace.json").to_string_lossy().into_owned();
    let metrics_path = dir.join("metrics.jsonl").to_string_lossy().into_owned();
    let cfg = TrainCfg {
        method: Method::PipeDream,
        stages: P,
        steps: STEPS,
        lr: 1e-2,
        seed: 7,
        eval_every: 0,
        log_every: 0,
        trace: Some(trace_path.clone()),
        metrics: Some(metrics_path.clone()),
        ..Default::default()
    };
    let mut coord = Coordinator::new(root());
    let res = coord
        .run(&Experiment { model: "pico8".to_string(), train: cfg })
        .unwrap();
    assert_eq!(res.losses.len(), STEPS as usize);

    let parsed = Json::parse(&std::fs::read_to_string(&trace_path).unwrap()).unwrap();
    let evs = parsed.at("traceEvents").as_arr();
    // One `model/w{w}` metadata row per worker.
    let mut meta_names: Vec<String> = Vec::new();
    let mut n_x = 0usize;
    for e in evs.iter() {
        match e.at("ph").as_str() {
            "M" => meta_names.push(e.at("args").at("name").as_str().to_string()),
            "X" => {
                // virtual clock: 1 unit-cost slot = 1 ms, so every
                // timestamp/duration is a whole number of 1000 µs slots
                let ts = e.at("ts").as_f64();
                let dur = e.at("dur").as_f64();
                assert!((ts % 1000.0).abs() < 1e-9, "off-slot ts {ts}");
                assert!((dur % 1000.0).abs() < 1e-9, "off-slot dur {dur}");
                n_x += 1;
            }
            _ => {}
        }
    }
    assert!(n_x > 0, "virtual-clock trace has no spans");
    for w in 0..P {
        let want = format!("model/w{w}");
        assert!(meta_names.iter().any(|n| n == &want), "missing thread row {want}");
    }

    // Sim metrics rows: monotone steps with loss + lr.
    let mut prev = 0u64;
    let mut rows = 0usize;
    for line in std::fs::read_to_string(&metrics_path).unwrap().lines() {
        let row = Json::parse(line).unwrap();
        let step = row.at("step").as_usize() as u64;
        assert!(step > prev);
        prev = step;
        assert!(row.at("loss").as_f64().is_finite());
        rows += 1;
    }
    assert_eq!(rows, STEPS as usize);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn trace_bench_baselines_validate_and_self_compare() {
    for name in [
        "BENCH_engine.json",
        "BENCH_kernels.json",
        "BENCH_dp_async.json",
        "BENCH_engine_pr8_baseline.json",
        "BENCH_kernels_pr8_baseline.json",
    ] {
        let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("benchmarks").join(name);
        let snap = bench::load_snapshot(&path).unwrap_or_else(|e| panic!("{name}: {e}"));
        bench::validate_snapshot(&snap).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(!snap.results.is_empty());
        // Current snapshots record the thread budget they were taken
        // at; the frozen pre-pool baselines predate the field and must
        // keep loading as `threads: None` through the vendored serde.
        assert_eq!(snap.threads.is_some(), !name.contains("pr8"));
        // A snapshot compared against itself is regression-free and
        // fully matched — pins the comparison helper's plumbing.
        let cmp = bench::compare_snapshots(&snap, &snap, 1.5);
        assert!(cmp.host_match);
        assert!(cmp.regressions().is_empty());
        assert!(cmp.only_baseline.is_empty());
        assert!(cmp.only_current.is_empty());
        for d in &cmp.deltas {
            assert!((d.ratio - 1.0).abs() < 1e-12);
        }
    }
}

/// The committed perf trajectory itself: the pooled-kernel snapshots
/// must stay at least 4x faster than the frozen PR 8 serial baseline
/// on the deep-pipeline anchor (tiny32 at P=8) and at least 3x faster
/// on every fwdbwd kernel microbench — the refactor's acceptance bar,
/// pinned so a future "refresh" cannot silently erase the speedup.
#[test]
fn trace_bench_trajectory_records_pooled_kernel_speedup() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("benchmarks");
    let load = |name: &str| bench::load_snapshot(dir.join(name)).unwrap();
    let ratio = |old: &bench::BenchSnapshot, new: &bench::BenchSnapshot, row: &str| -> f64 {
        let find = |s: &bench::BenchSnapshot| {
            s.results
                .iter()
                .find(|r| r.name == row)
                .unwrap_or_else(|| panic!("missing bench row {row}"))
                .median_us
        };
        find(old) / find(new)
    };

    let (eng_old, eng_new) = (load("BENCH_engine_pr8_baseline.json"), load("BENCH_engine.json"));
    assert!(ratio(&eng_old, &eng_new, "engine step tiny32 P=8") >= 4.0);

    let (ker_old, ker_new) =
        (load("BENCH_kernels_pr8_baseline.json"), load("BENCH_kernels.json"));
    for row in ["fwdbwd dispatch micro", "fwdbwd dispatch pico8", "fwdbwd dispatch pico32"] {
        assert!(ratio(&ker_old, &ker_new, row) >= 3.0, "{row} below 3x");
    }

    // Cross-era comparison is informational only: the old snapshot has
    // no recorded thread budget, so the host gate alone applies, and
    // the faster current rows are improvements, never regressions.
    let cmp = bench::compare_snapshots(&eng_old, &eng_new, 1.5);
    assert!(cmp.regressions().is_empty());
}

/// The async-DP acceptance row: with alternating stragglers on both
/// replicas, the recorded `--dp-async --max-skew 2` run must beat the
/// synchronous all-reduce run (which serializes every injected sleep),
/// while the no-straggler rows stay within noise of each other.
#[test]
fn trace_bench_dp_async_straggler_beats_sync() {
    let path =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("benchmarks/BENCH_dp_async.json");
    let snap = bench::load_snapshot(&path).unwrap();
    let median = |row: &str| -> f64 {
        snap.results
            .iter()
            .find(|r| r.name == row)
            .unwrap_or_else(|| panic!("missing bench row {row}"))
            .median_us
    };
    let sync_s = median("engine dp sync P=4 R=2 straggler");
    let async_s = median("engine dp async K=2 P=4 R=2 straggler");
    assert!(
        async_s < sync_s,
        "async DP must beat sync DP under a straggler: {async_s} vs {sync_s}"
    );
    // Without stragglers the two modes do the same work; the async row
    // must not record a large regression (2x guard, generous to noise).
    let sync_c = median("engine dp sync P=4 R=2");
    let async_c = median("engine dp async K=2 P=4 R=2");
    assert!(async_c < 2.0 * sync_c, "clean async row regressed: {async_c} vs {sync_c}");
}
