//! Integration tests for the crash-consistent checkpoint subsystem
//! (`rust/src/checkpoint/`): property-style serde round trips, the
//! pinned non-finite/overflow JSON policy, bit-exact simulator
//! save/resume, deterministic fault injection and elastic rosters on
//! the threaded engine, and the nightly golden kill+resume equivalence
//! matrix.
//!
//! Fast tests run in the CI `checkpoint` fast-path job
//! (`cargo test --release -q checkpoint_`); the `#[ignore]`d matrix
//! runs in the nightly `cargo test -q -- --ignored` job.

use std::path::PathBuf;

use abrot::checkpoint::{self, FaultPlan, ReplicaJoin, ReplicaKill, TensorState, WorkerDelay};
use abrot::config::{Method, ScheduleKind, StashMode, TrainCfg};
use abrot::pipeline::{train_sim, train_sim_observed};
use abrot::rngs::Rng;
use abrot::runtime::Runtime;
use serde::Serialize;

fn artifacts(model: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(model)
}

/// Per-test scratch dir for snapshots, wiped on entry so a crashed
/// previous run cannot leak stale checkpoints into this one.
fn tdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("abrot_ckpt_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn dir_string(d: &std::path::Path) -> String {
    d.to_string_lossy().into_owned()
}

// ---------------------------------------------------------------------
// Serde subset: property-style round trips and the pinned edge policy
// ---------------------------------------------------------------------

/// Optimizer-moment-shaped leaf: numeric vectors, counters, options.
#[derive(Clone, Debug, PartialEq, serde::Serialize, serde::Deserialize)]
struct Moments {
    count: u64,
    m: Vec<f32>,
    v: Vec<f64>,
    decay: Option<f64>,
}

/// Snapshot-shaped nesting: strings (with escapes), tuples, vectors of
/// structs, empty containers, options — the shapes `RunState` uses.
#[derive(Clone, Debug, PartialEq, serde::Serialize, serde::Deserialize)]
struct Shard {
    name: String,
    id: (u32, i64),
    alive: bool,
    moments: Vec<Moments>,
    spans: Vec<(u32, f32)>,
    note: Option<String>,
    empty: Vec<u32>,
}

#[test]
fn checkpoint_serde_round_trips_randomized_nested_structs() {
    // Values that stress the f32 -> f64 -> shortest-text -> f64 -> f32
    // path: zero, signed zero, subnormal, min-normal, max-finite.
    let edge_f32 = [0.0f32, -0.0, 1e-45, f32::MIN_POSITIVE, f32::MAX, -3.25];
    for iter in 0..40u64 {
        let mut rng = Rng::new(0xC0FFEE ^ iter);
        let shard = Shard {
            name: format!("s{}-\"quoted\"\n\t\\", rng.below(1000)),
            id: (
                rng.next_u64() as u32,
                // keep magnitudes under 2^53: integers ride f64 in JSON
                (rng.next_u64() as i64) >> 14,
            ),
            alive: rng.next_u64() % 2 == 0,
            moments: (0..rng.below(4))
                .map(|k| Moments {
                    count: rng.next_u64() >> 12,
                    m: (0..5)
                        .map(|i| {
                            if i == 0 {
                                edge_f32[(iter as usize + k) % edge_f32.len()]
                            } else {
                                rng.normal()
                            }
                        })
                        .collect(),
                    v: (0..3).map(|_| rng.normal() as f64 * 1e-3).collect(),
                    decay: if k % 2 == 0 { Some(rng.uniform() as f64) } else { None },
                })
                .collect(),
            spans: (0..rng.below(5))
                .map(|_| (rng.next_u64() as u32, rng.normal()))
                .collect(),
            note: if iter % 3 == 0 { None } else { Some("x".repeat(rng.below(8))) },
            empty: Vec::new(),
        };
        let back: Shard = serde::from_str(&shard.to_json())
            .unwrap_or_else(|e| panic!("iter {iter}: {e}\njson: {}", shard.to_json()));
        assert_eq!(shard, back, "iter {iter}");
        // a vector of them must round-trip too (RunState holds lists)
        let many = vec![shard.clone(), shard];
        let back: Vec<Shard> = serde::from_str(&many.to_json()).unwrap();
        assert_eq!(many, back, "iter {iter} (vec)");
    }
}

#[test]
fn checkpoint_serde_pins_nonfinite_and_overflow_policy() {
    // Standard JSON has no NaN/inf: non-finite floats serialize as
    // `null`; bare floats revive null as NaN (sign/inf collapsed)...
    assert_eq!(f32::NAN.to_json(), "null");
    assert_eq!(f64::INFINITY.to_json(), "null");
    assert!(serde::from_str::<f32>("null").unwrap().is_nan());
    assert!(serde::from_str::<f64>(&f64::NEG_INFINITY.to_json()).unwrap().is_nan());
    // ...while Option<f32> claims null for None, so Some(NaN) collapses
    // to None — a checkpoint must not store meaningful NaNs in options.
    let o: Option<f32> = serde::from_str(&Some(f32::NAN).to_json()).unwrap();
    assert_eq!(o, None);
    // A diverged run's tensors revive as NaN, not as silent garbage.
    let t = TensorState {
        shape: vec![3],
        data: vec![f32::NEG_INFINITY, f32::NAN, 2.5],
    };
    let back: TensorState = serde::from_str(&t.to_json()).unwrap();
    assert!(back.data[0].is_nan() && back.data[1].is_nan());
    assert_eq!(back.data[2], 2.5);
    assert_eq!(back.shape, vec![3]);
    // Integers ride through f64: magnitudes near u64::MAX fail loudly
    // at load instead of materializing a rounded counter.
    assert!(serde::from_str::<u64>(&u64::MAX.to_json()).is_err());
}

// ---------------------------------------------------------------------
// Simulator: bit-exact save/resume and loud config-drift rejection
// ---------------------------------------------------------------------

#[test]
fn checkpoint_sim_resume_is_bit_exact() {
    let rt = Runtime::open(artifacts("micro")).unwrap();
    let dir = tdir("sim_exact");
    let mk = || TrainCfg {
        method: Method::PipeDream,
        stages: 2,
        steps: 12,
        lr: 5e-3,
        seed: 77,
        eval_every: 4,
        log_every: 0,
        ..Default::default()
    };
    let mut full_cfg = mk();
    full_cfg.checkpoint_every = 6;
    full_cfg.checkpoint_dir = Some(dir_string(&dir));
    let (full, params_full) = train_sim_observed(&rt, &full_cfg, &mut |_, _| {}).unwrap();
    assert_eq!(full.losses.len(), 12);

    // "Crash" after step 6: resume from the snapshot and the continued
    // run must be indistinguishable from the uninterrupted one —
    // losses, validation samples and final parameters all bit-equal.
    let snap = checkpoint::step_path(&dir, 6);
    assert!(snap.exists(), "missing {}", snap.display());
    let mut res_cfg = mk();
    res_cfg.resume = Some(dir_string(&snap));
    let (res, params_res) = train_sim_observed(&rt, &res_cfg, &mut |_, _| {}).unwrap();
    assert_eq!(full.losses, res.losses);
    assert_eq!(full.val_losses, res.val_losses);
    assert_eq!(params_full.len(), params_res.len());
    for (i, (a, b)) in params_full.iter().zip(&params_res).enumerate() {
        assert_eq!(a.data, b.data, "param {i} diverged after resume");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn checkpoint_sim_resume_rejects_config_drift() {
    let rt = Runtime::open(artifacts("micro")).unwrap();
    let dir = tdir("sim_drift");
    let mk = || TrainCfg {
        method: Method::PipeDream,
        stages: 2,
        steps: 4,
        lr: 5e-3,
        seed: 77,
        log_every: 0,
        ..Default::default()
    };
    let mut cfg = mk();
    cfg.checkpoint_every = 4;
    cfg.checkpoint_dir = Some(dir_string(&dir));
    train_sim(&rt, &cfg).unwrap();
    let snap = dir_string(&checkpoint::step_path(&dir, 4));

    // Every identity drift fails loudly naming the drifted field; a
    // silent resume under the wrong config would train a plausible-
    // looking but meaningless trajectory.
    let drifts: Vec<(&str, TrainCfg)> = vec![
        ("seed", TrainCfg { seed: 78, ..mk() }),
        ("total steps", TrainCfg { steps: 8, ..mk() }),
        ("method", TrainCfg { method: Method::Nesterov, ..mk() }),
        ("schedule", TrainCfg { schedule: ScheduleKind::Gpipe, ..mk() }),
        ("replicas", TrainCfg { replicas: 2, ..mk() }),
        ("Predict", TrainCfg { stash: StashMode::Predict, ..mk() }),
    ];
    for (what, mut bad) in drifts {
        bad.resume = Some(snap.clone());
        let err = train_sim(&rt, &bad).unwrap_err().to_string();
        assert!(err.contains(what), "{what}: {err}");
    }
    // ...and checkpointing a Predict run is refused up front: the
    // predictor's velocity EMA is live state the snapshot omits.
    let mut pred = mk();
    pred.stash = StashMode::Predict;
    pred.checkpoint_every = 2;
    let err = train_sim(&rt, &pred).unwrap_err().to_string();
    assert!(err.contains("StashMode::Predict"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// Engine: deterministic fault injection and elastic rosters
// ---------------------------------------------------------------------

#[test]
fn checkpoint_engine_replica_death_reshards_and_completes() {
    // Worker 0 of replica 1 dies after update 4, mid-segment between
    // the checkpoints at steps 3 and 6. The crash winds down every
    // worker (closed channels, dropped all-reduce handles); the driver
    // drops the dead replica, re-partitions the data shards over the
    // survivor and re-runs the segment from the step-3 snapshot.
    let dir = tdir("eng_kill");
    let cfg = TrainCfg {
        method: Method::PipeDream,
        stages: 2,
        replicas: 2,
        steps: 8,
        lr: 5e-3,
        seed: 77,
        log_every: 0,
        checkpoint_every: 3,
        checkpoint_dir: Some(dir_string(&dir)),
        ..Default::default()
    };
    let plan = FaultPlan {
        kills: vec![ReplicaKill { at_update: 4, replica: 1, worker: 0 }],
        ..Default::default()
    };
    let res = checkpoint::run_engine_elastic(&artifacts("micro"), &cfg, &plan).unwrap();
    assert_eq!(res.losses.len(), 8, "the run must complete all 8 updates");
    assert!(!res.diverged);
    assert!(res.final_loss().is_finite());
    assert_eq!(res.replicas, 1, "the dead replica must leave the roster");
    // the post-death snapshot records the shrunken roster
    let snap = checkpoint::load(&checkpoint::step_path(&dir, 6)).unwrap();
    assert_eq!(snap.step, 6);
    assert_eq!(snap.replicas, 1);
    assert_eq!(snap.losses.len(), 6);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn checkpoint_engine_clean_departure_and_join_resize_roster() {
    // A kill landing exactly on a segment boundary is a clean
    // departure: nothing crashes, no work is re-run, the replica just
    // leaves the roster. A planned join grows it the same way, seeded
    // from the snapshot.
    let dir = tdir("eng_roster");
    let cfg = TrainCfg {
        method: Method::PipeDream,
        stages: 2,
        replicas: 2,
        steps: 8,
        lr: 5e-3,
        seed: 77,
        log_every: 0,
        checkpoint_every: 3,
        checkpoint_dir: Some(dir_string(&dir)),
        ..Default::default()
    };
    let plan = FaultPlan {
        kills: vec![ReplicaKill { at_update: 3, replica: 1, worker: 0 }],
        joins: vec![ReplicaJoin { at_update: 6, count: 2 }],
        ..Default::default()
    };
    let res = checkpoint::run_engine_elastic(&artifacts("micro"), &cfg, &plan).unwrap();
    assert_eq!(res.losses.len(), 8);
    assert!(res.final_loss().is_finite());
    // R: 2 -> 1 (departure at 3) -> 3 (two join at 6)
    assert_eq!(res.replicas, 3);
    let snap = checkpoint::load(&checkpoint::step_path(&dir, 6)).unwrap();
    assert_eq!(snap.replicas, 3);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn checkpoint_engine_dp_async_kill_reshards_and_resumes() {
    // Kill-during-async-reduce: under --dp-async --max-skew 1 the
    // replicas drain checkpoints with divergent weights, so snapshots
    // carry per-replica skew state. Worker 0 of replica 1 dies after
    // update 4 (mid-segment, mid-reduce from replica 0's perspective:
    // its mesh peer hangs up); the driver must collapse the skew state
    // onto the replica-0 copy, re-shard and complete.
    let dir = tdir("eng_async_kill");
    let cfg = TrainCfg {
        method: Method::PipeDream,
        stages: 2,
        replicas: 2,
        steps: 8,
        lr: 5e-3,
        seed: 77,
        log_every: 0,
        checkpoint_every: 3,
        checkpoint_dir: Some(dir_string(&dir)),
        dp_async: true,
        max_skew: 1,
        ..Default::default()
    };
    let plan = FaultPlan {
        kills: vec![ReplicaKill { at_update: 4, replica: 1, worker: 0 }],
        ..Default::default()
    };
    let res = checkpoint::run_engine_elastic(&artifacts("micro"), &cfg, &plan).unwrap();
    assert_eq!(res.losses.len(), 8, "the run must complete all 8 updates");
    assert!(!res.diverged);
    assert!(res.final_loss().is_finite());
    assert_eq!(res.replicas, 1, "the dead replica must leave the roster");

    // The pre-kill snapshot (step 3, R=2) records the DP mode and both
    // replicas' in-flight skew state...
    let snap3 = checkpoint::load(&checkpoint::step_path(&dir, 3)).unwrap();
    assert_eq!(snap3.replicas, 2);
    assert_eq!(snap3.dp_mode.as_deref(), Some("async:1"));
    let states = snap3.dp_replica_states.as_ref().expect("skew state saved");
    let mut ids: Vec<usize> = states.iter().map(|s| s.replica).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 1]);
    // ...and the post-kill snapshot (step 6, R=1) has collapsed it.
    let snap6 = checkpoint::load(&checkpoint::step_path(&dir, 6)).unwrap();
    assert_eq!(snap6.replicas, 1);
    assert!(snap6.dp_replica_states.is_none(), "roster change collapses skew state");

    // Resume from the R=2 snapshot with its in-flight skew state and no
    // fault plan: both replicas restart from their own drained copies
    // and the run completes at full roster.
    let mut res_cfg = cfg.clone();
    res_cfg.checkpoint_dir = None;
    res_cfg.checkpoint_every = 0;
    res_cfg.resume = Some(dir_string(&checkpoint::step_path(&dir, 3)));
    let resumed =
        checkpoint::run_engine_elastic(&artifacts("micro"), &res_cfg, &FaultPlan::default())
            .unwrap();
    assert_eq!(resumed.losses.len(), 8);
    assert!(resumed.final_loss().is_finite());
    assert_eq!(resumed.replicas, 2);

    // Resuming under a different DP mode is config drift, loudly.
    let mut bad = cfg.clone();
    bad.dp_async = false;
    bad.max_skew = 0;
    bad.resume = Some(dir_string(&checkpoint::step_path(&dir, 3)));
    let err = checkpoint::run_engine_elastic(&artifacts("micro"), &bad, &FaultPlan::default())
        .unwrap_err()
        .to_string();
    assert!(err.contains("DP mode"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn checkpoint_engine_delay_injection_does_not_change_losses() {
    // The schedules are deterministic in message order, not arrival
    // time: a worker sleeping mid-run is a pure timing perturbation and
    // every recorded value must be bit-identical to the undisturbed run.
    let mk = || TrainCfg {
        method: Method::PipeDream,
        stages: 2,
        steps: 6,
        lr: 5e-3,
        seed: 77,
        log_every: 0,
        ..Default::default()
    };
    let art = artifacts("micro");
    let plain = checkpoint::run_engine_elastic(&art, &mk(), &FaultPlan::default()).unwrap();
    let plan = FaultPlan {
        delays: vec![WorkerDelay { at_update: 3, replica: 0, worker: 1, millis: 40 }],
        ..Default::default()
    };
    let delayed = checkpoint::run_engine_elastic(&art, &mk(), &plan).unwrap();
    assert_eq!(plain.losses, delayed.losses);
}

#[test]
fn checkpoint_engine_bails_when_plan_kills_whole_roster() {
    // Killing the only replica can never complete; the driver must fail
    // loudly instead of spinning on a segment it can never finish.
    let dir = tdir("eng_wipe");
    let cfg = TrainCfg {
        method: Method::PipeDream,
        stages: 2,
        replicas: 1,
        steps: 6,
        lr: 5e-3,
        seed: 77,
        log_every: 0,
        checkpoint_every: 3,
        checkpoint_dir: Some(dir_string(&dir)),
        ..Default::default()
    };
    let plan = FaultPlan {
        kills: vec![ReplicaKill { at_update: 4, replica: 0, worker: 0 }],
        ..Default::default()
    };
    let err = checkpoint::run_engine_elastic(&artifacts("micro"), &cfg, &plan)
        .unwrap_err()
        .to_string();
    assert!(err.contains("every replica"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// Nightly: golden kill+resume equivalence matrix (sim) and the
// synchronous-schedule engine resume equivalence
// ---------------------------------------------------------------------

/// Golden constants of `rust/tests/golden.rs`: the resumed trajectories
/// below continue the exact runs whose first 20 steps the golden
/// fixtures pin, so resume correctness is checked against the same
/// reference the rest of the repo regresses against.
fn golden_cfg(method: Method, schedule: ScheduleKind, replicas: usize) -> TrainCfg {
    TrainCfg {
        method,
        schedule,
        stages: 4,
        replicas,
        steps: 20,
        lr: 5e-3,
        seed: 2024,
        log_every: 0,
        ..Default::default()
    }
}

/// Run the 20-step golden config to completion with a snapshot at step
/// 10, then "kill" it (discard everything after 10) and resume: the
/// resumed half must reproduce the uninterrupted trajectory within
/// 1e-10 and the final parameters bit-exactly.
fn assert_kill_resume_matches_golden(
    tag: &str,
    method: Method,
    schedule: ScheduleKind,
    replicas: usize,
) {
    let rt = Runtime::open(artifacts("pico4")).unwrap();
    let dir = tdir(tag);
    let mut full_cfg = golden_cfg(method, schedule, replicas);
    full_cfg.checkpoint_every = 10;
    full_cfg.checkpoint_dir = Some(dir_string(&dir));
    let (full, params_full) =
        train_sim_observed(&rt, &full_cfg, &mut |_, _| {}).unwrap();
    assert_eq!(full.losses.len(), 20, "{tag}");

    let snap = checkpoint::step_path(&dir, 10);
    assert!(snap.exists(), "{tag}: missing snapshot {}", snap.display());
    let mut res_cfg = golden_cfg(method, schedule, replicas);
    res_cfg.resume = Some(dir_string(&snap));
    let (res, params_res) = train_sim_observed(&rt, &res_cfg, &mut |_, _| {}).unwrap();
    assert_eq!(res.losses.len(), 20, "{tag}");
    for (i, (a, b)) in full.losses.iter().zip(&res.losses).enumerate() {
        assert!(
            (*a as f64 - *b as f64).abs() < 1e-10,
            "{tag} step {}: uninterrupted {a} vs resumed {b}",
            i + 1
        );
    }
    for (i, (a, b)) in params_full.iter().zip(&params_res).enumerate() {
        assert_eq!(a.data, b.data, "{tag}: param {i} diverged after resume");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
#[ignore = "slow golden matrix; nightly job executes with -- --ignored"]
fn checkpoint_kill_resume_matches_golden_p4() {
    for method in [Method::PipeDream, Method::br_default()] {
        for (schedule, tag) in [
            (ScheduleKind::OneFOneB, "1f1b"),
            (ScheduleKind::Interleaved { v: 2 }, "il2"),
        ] {
            assert_kill_resume_matches_golden(
                &format!("p4_{tag}_{}", method.name()),
                method,
                schedule,
                1,
            );
        }
    }
}

#[test]
#[ignore = "slow golden matrix; nightly job executes with -- --ignored"]
fn checkpoint_kill_resume_matches_golden_p4_r2() {
    for method in [Method::PipeDream, Method::br_default()] {
        assert_kill_resume_matches_golden(
            &format!("p4r2_{}", method.name()),
            method,
            ScheduleKind::OneFOneB,
            2,
        );
    }
}

#[test]
#[ignore = "slow engine equivalence; nightly job executes with -- --ignored"]
fn checkpoint_engine_gpipe_resume_matches_uninterrupted() {
    // GPipe drains the pipeline at every update, so the engine's
    // segment boundaries coincide with its natural drain points:
    // segmented and JSON-resumed runs must match the uninterrupted
    // trajectory within 1e-10 (the asynchronous schedules are only
    // drain-consistent across a resume and are smoke-tested above).
    let dir = tdir("eng_gpipe");
    let mk = || TrainCfg {
        method: Method::PipeDream,
        schedule: ScheduleKind::Gpipe,
        stages: 4,
        steps: 20,
        lr: 5e-3,
        seed: 2024,
        log_every: 0,
        ..Default::default()
    };
    let art = artifacts("pico4");
    let base = checkpoint::run_engine_elastic(&art, &mk(), &FaultPlan::default()).unwrap();
    assert_eq!(base.losses.len(), 20);

    let mut seg_cfg = mk();
    seg_cfg.checkpoint_every = 10;
    seg_cfg.checkpoint_dir = Some(dir_string(&dir));
    let seg = checkpoint::run_engine_elastic(&art, &seg_cfg, &FaultPlan::default()).unwrap();
    assert_eq!(seg.losses.len(), 20);
    for (i, (a, b)) in base.losses.iter().zip(&seg.losses).enumerate() {
        assert!(
            (*a as f64 - *b as f64).abs() < 1e-10,
            "segmented step {}: {a} vs {b}",
            i + 1
        );
    }

    let mut res_cfg = mk();
    res_cfg.resume = Some(dir_string(&checkpoint::step_path(&dir, 10)));
    let res = checkpoint::run_engine_elastic(&art, &res_cfg, &FaultPlan::default()).unwrap();
    assert_eq!(res.losses.len(), 20);
    for (i, (a, b)) in base.losses.iter().zip(&res.losses).enumerate() {
        assert!(
            (*a as f64 - *b as f64).abs() < 1e-10,
            "resumed step {}: {a} vs {b}",
            i + 1
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}
