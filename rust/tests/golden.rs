//! Golden-trajectory fixtures: the first-20-step training losses of
//! every method at P=4 (and at P=4 x R=2 on the DP axis) are pinned to
//! JSON fixtures under `rust/tests/fixtures/`, diffed within 1e-10 —
//! so a trajectory regression fails loudly instead of silently
//! shifting every downstream figure.
//!
//! Regeneration: `BLESS=1 cargo test --test golden -- --ignored`
//! rewrites the fixtures from the current code (a missing fixture is
//! also blessed on first run, so a fresh checkout bootstraps itself).
//! These runs are slow for a PR gate and are `#[ignore]`d; CI executes
//! them in the nightly `cargo test -q -- --ignored` job.

use std::path::PathBuf;

use abrot::config::{Method, ScheduleKind, TrainCfg};
use abrot::jsonio::{arr, num, obj, s, Json};
use abrot::pipeline::train_sim;
use abrot::runtime::Runtime;

const MODEL: &str = "pico4";
const STEPS: u32 = 20;
const SEED: u64 = 2024;
const LR: f32 = 5e-3;

fn all_methods() -> [Method; 8] {
    [
        Method::PipeDream,
        Method::PipeDreamLr,
        Method::Nesterov,
        Method::DelayComp { lambda: 0.1 },
        Method::br_default(),
        Method::Soap { freq: 5 },
        Method::Muon,
        Method::Scion,
    ]
}

fn fixture_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust")
        .join("tests")
        .join("fixtures")
}

fn run(method: Method, stages: usize, replicas: usize) -> Vec<f32> {
    run_sched(method, ScheduleKind::OneFOneB, stages, replicas)
}

fn run_sched(
    method: Method,
    schedule: ScheduleKind,
    stages: usize,
    replicas: usize,
) -> Vec<f32> {
    let rt = Runtime::open(
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(MODEL),
    )
    .unwrap();
    let cfg = TrainCfg {
        method,
        schedule,
        stages,
        replicas,
        steps: STEPS,
        lr: LR,
        seed: SEED,
        log_every: 0,
        ..Default::default()
    };
    let res = train_sim(&rt, &cfg).unwrap_or_else(|e| {
        panic!("{} {} P={stages} R={replicas}: {e}", method.name(), schedule.name())
    });
    assert_eq!(res.losses.len(), STEPS as usize, "{}", method.name());
    res.losses
}

/// Diff `losses` against the named fixture within 1e-10, or (re)write
/// it when `BLESS=1` is set or the fixture does not exist yet. In CI
/// (the `CI` env var is set) a missing fixture is a hard failure, not
/// an auto-bless — otherwise the nightly gate could never catch a
/// regression: it would re-bless the regressed trajectory every run.
fn check_or_bless(name: &str, losses: &[f32]) {
    let path = fixture_dir().join(format!("{name}.json"));
    let bless = std::env::var("BLESS").as_deref() == Ok("1");
    if !path.exists() && !bless && std::env::var("CI").is_ok() {
        panic!(
            "{name}: fixture {} missing in CI; generate locally with \
             `BLESS=1 cargo test --test golden -- --ignored` and commit it",
            path.display()
        );
    }
    if bless || !path.exists() {
        let j = obj(vec![
            ("model", s(MODEL)),
            ("steps", num(STEPS as f64)),
            ("seed", num(SEED as f64)),
            ("lr", num(LR as f64)),
            ("losses", arr(losses.iter().map(|&l| num(l as f64)).collect())),
        ]);
        std::fs::create_dir_all(fixture_dir()).unwrap();
        std::fs::write(&path, j.to_string()).unwrap();
        eprintln!("golden: blessed {}", path.display());
        return;
    }
    let text = std::fs::read_to_string(&path).unwrap();
    let j = Json::parse(&text).unwrap_or_else(|e| panic!("{name}: bad fixture: {e}"));
    let stored: Vec<f64> =
        j.at("losses").as_arr().iter().map(|x| x.as_f64()).collect();
    assert_eq!(
        stored.len(),
        losses.len(),
        "{name}: trajectory length changed; rerun with BLESS=1 if intended"
    );
    for (i, (&want, &got)) in stored.iter().zip(losses).enumerate() {
        assert!(
            (want - got as f64).abs() < 1e-10,
            "{name} step {}: fixture {want} vs current {got} \
             (rerun with BLESS=1 if this change is intended)",
            i + 1
        );
    }
}

#[test]
#[ignore = "slow golden run; nightly job executes with -- --ignored"]
fn golden_trajectories_every_method_p4() {
    for m in all_methods() {
        check_or_bless(&format!("p4_{}", m.name()), &run(m, 4, 1));
    }
}

#[test]
#[ignore = "slow golden run; nightly job executes with -- --ignored"]
fn golden_trajectories_every_method_p4_r2() {
    for m in all_methods() {
        check_or_bless(&format!("p4_r2_{}", m.name()), &run(m, 4, 2));
    }
}

#[test]
#[ignore = "slow golden run; nightly job executes with -- --ignored"]
fn golden_trajectories_schedules_p4() {
    // Schedule axis: the zero-staleness gpipe baseline and the
    // reduced-staleness interleaved(v=2) trajectories for plain Adam
    // (PipeDream is vanilla async Adam; under gpipe its delay profile
    // is zero, i.e. synchronous Adam) and the paper's method. The
    // schedule name goes in the fixture name; `:` stays out of
    // filenames.
    let scheds = [
        (ScheduleKind::Gpipe, "gpipe"),
        (ScheduleKind::Interleaved { v: 2 }, "interleaved2"),
    ];
    for (kind, tag) in scheds {
        for m in [Method::PipeDream, Method::br_default()] {
            check_or_bless(
                &format!("p4_{tag}_{}", m.name()),
                &run_sched(m, kind, 4, 1),
            );
        }
    }
}

#[test]
fn blessing_round_trips_through_fixture_format() {
    // Fast self-check of the fixture writer/reader pair (not ignored):
    // a blessed file must read back bit-identically, including values
    // that stress the f32 -> f64 -> text -> f64 path.
    let dir = std::env::temp_dir().join(format!("abrot_golden_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let losses = [2.7182817f32, 1.0e-7, 3.25, 0.1];
    let j = obj(vec![
        ("model", s(MODEL)),
        ("losses", arr(losses.iter().map(|&l| num(l as f64)).collect())),
    ]);
    let path = dir.join("roundtrip.json");
    std::fs::write(&path, j.to_string()).unwrap();
    let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    for (x, &l) in back.at("losses").as_arr().iter().zip(&losses) {
        assert_eq!(x.as_f64(), l as f64);
    }
    std::fs::remove_dir_all(&dir).ok();
}
