//! Bounded-skew asynchronous DP conformance (acceptance gates of the
//! async-DP PR):
//!
//! * `--dp-async --max-skew 0` is **bit-exact** with the synchronous
//!   all-reduce path for every optimizer method at P = 4 × R = 2 — the
//!   async mesh at skew 0 stalls until every peer's step-t gradient has
//!   arrived and folds them in the same replica-id order, so the two
//!   code paths must produce identical float trajectories.
//! * Under an injected straggler the realized per-replica skew never
//!   exceeds the configured bound K (pinned via the engine's
//!   per-replica skew histograms), and — because the delay is
//!   timing-only — the losses still match the undelayed run bit-for-bit.
//!
//! All tests are prefixed `dp_async_` so the CI fast-path job
//! (`cargo test --release -q dp_async_`) picks them up together with
//! the reducer unit tests in `pipeline/dp_async.rs`.

use std::path::PathBuf;

use abrot::checkpoint::{self, FaultPlan, WorkerDelay};
use abrot::config::{Method, TrainCfg};
use abrot::pipeline::engine::train_engine;

fn root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn all_methods() -> Vec<Method> {
    vec![
        Method::PipeDream,
        Method::PipeDreamLr,
        Method::Nesterov,
        Method::DelayComp { lambda: 0.1 },
        Method::br_default(),
        Method::Soap { freq: 10 },
        Method::Muon,
        Method::Scion,
    ]
}

fn base_cfg(method: Method) -> TrainCfg {
    TrainCfg {
        method,
        stages: 4,
        replicas: 2,
        steps: 6,
        lr: 5e-3,
        grad_clip: 1e9,
        seed: 2026,
        ..Default::default()
    }
}

#[test]
fn dp_async_skew0_bit_exact_with_sync_all_methods_p4_r2() {
    for method in all_methods() {
        let name = method.name();
        let sync_cfg = base_cfg(method);
        let mut async_cfg = sync_cfg.clone();
        async_cfg.dp_async = true;
        async_cfg.max_skew = 0;

        let sync = train_engine(root().join("pico4"), &sync_cfg)
            .unwrap_or_else(|e| panic!("{name} sync: {e}"));
        let asyn = train_engine(root().join("pico4"), &async_cfg)
            .unwrap_or_else(|e| panic!("{name} async: {e}"));

        assert!(!sync.diverged && !asyn.diverged, "{name}");
        assert_eq!(
            sync.losses, asyn.losses,
            "{name}: skew-0 async DP must be bit-exact with sync DP"
        );
        assert_eq!(
            sync.val_losses, asyn.val_losses,
            "{name}: eval trajectories must match too"
        );
        assert!(asyn.dp_async && asyn.max_skew == 0, "{name}: result stamping");
        assert!(!sync.dp_async, "{name}: sync run must not be stamped async");
        // At skew 0 every fold uses only step-fresh peers.
        for c in &asyn.replica_counters {
            assert_eq!(c.dp_max_skew, 0, "{name} replica {}", c.replica);
            assert!(
                c.dp_skew_hist.iter().skip(1).all(|&n| n == 0),
                "{name} replica {}: non-zero skew observed at K=0: {:?}",
                c.replica,
                c.dp_skew_hist
            );
        }
    }
}

#[test]
fn dp_async_straggler_skew_bounded_and_losses_unchanged() {
    // One replica gets repeated injected sleeps; the other keeps
    // stepping ahead but must stall at the bound. The realized skew is
    // read back from the per-replica counters; the delay is pure
    // timing so the loss trajectory is unchanged vs the undelayed run.
    let k = 2u32;
    let mut cfg = base_cfg(Method::PipeDream);
    cfg.stages = 2;
    cfg.steps = 8;
    cfg.dp_async = true;
    cfg.max_skew = k;

    let baseline =
        checkpoint::run_engine_elastic(&root().join("micro"), &cfg, &FaultPlan::default())
            .unwrap();

    let plan = FaultPlan {
        delays: vec![
            WorkerDelay { at_update: 2, replica: 1, worker: 0, millis: 30 },
            WorkerDelay { at_update: 5, replica: 1, worker: 1, millis: 30 },
        ],
        ..Default::default()
    };
    let delayed =
        checkpoint::run_engine_elastic(&root().join("micro"), &cfg, &plan).unwrap();

    assert_eq!(
        baseline.losses, delayed.losses,
        "stragglers are timing-only; the fold selection is step-tagged"
    );
    assert_eq!(delayed.replica_counters.len(), 2);
    for c in &delayed.replica_counters {
        assert!(
            c.dp_max_skew <= k,
            "replica {}: realized skew {} exceeds the bound {k}",
            c.replica,
            c.dp_max_skew
        );
        assert!(
            c.dp_skew_hist.len() <= k as usize + 1,
            "replica {}: skew histogram has a bucket past the bound: {:?}",
            c.replica,
            c.dp_skew_hist
        );
        assert!(c.updates > 0 && c.wall_s >= 0.0, "replica {}", c.replica);
    }
}

#[test]
fn dp_async_per_replica_staleness_rows_cover_roster() {
    // The per-replica PP-staleness histograms (the fix for the old
    // replica-0-only sampling) carry one row set per replica; the
    // merged `staleness_histogram` stays the conformance view.
    let mut cfg = base_cfg(Method::PipeDream);
    cfg.dp_async = true;
    cfg.max_skew = 1;
    let res = train_engine(root().join("pico4"), &cfg).unwrap();

    let reps: std::collections::BTreeSet<usize> =
        res.staleness_by_replica.iter().map(|(r, _, _)| *r).collect();
    assert_eq!(reps, [0usize, 1].into_iter().collect(), "both replicas sampled");
    let chunks: std::collections::BTreeSet<usize> =
        res.staleness_by_replica.iter().map(|(_, c, _)| *c).collect();
    let merged: std::collections::BTreeSet<usize> =
        res.staleness_histogram.iter().map(|(c, _)| *c).collect();
    assert_eq!(chunks, merged, "merged view covers the same chunks");
    // Merged counts are the per-replica sums.
    for (chunk, counts) in &res.staleness_histogram {
        let mut sum = vec![0u64; counts.len()];
        for (_, c, row) in res.staleness_by_replica.iter().filter(|(_, c, _)| c == chunk)
        {
            assert!(c == chunk);
            for (i, n) in row.iter().enumerate() {
                if i < sum.len() {
                    sum[i] += n;
                } else {
                    assert_eq!(*n, 0, "chunk {chunk}: replica row wider than merged");
                }
            }
        }
        assert_eq!(&sum, counts, "chunk {chunk}: merged = sum of replica rows");
    }
}

#[test]
fn dp_async_worker_budgets_cover_all_workers() {
    // The remainder-aware thread split (fix for the floor-division
    // budget bug) is recorded in the result: one budget per P × R
    // worker, none of them zero, and the extras go to the lowest
    // indices.
    let mut cfg = base_cfg(Method::PipeDream);
    cfg.threads = 6; // 6 threads over 8 workers: floor would give 0
    cfg.dp_async = true;
    let res = train_engine(root().join("pico4"), &cfg).unwrap();
    assert_eq!(res.worker_budgets.len(), 4 * 2);
    assert!(res.worker_budgets.iter().all(|&b| b >= 1), "{:?}", res.worker_budgets);
    for w in res.worker_budgets.windows(2) {
        assert!(w[0] >= w[1], "extras must go to the lowest indices: {:?}", res.worker_budgets);
    }
}
