//! Property-style randomized tests (seeded `rngs`, no external crates)
//! for the manifest-restriction machinery every parallelism axis leans
//! on (`Manifest::restrict` / `Runtime::restricted`):
//!
//! * for random stage partitions, the restricted parameter lists, shape
//!   class slot counts and optimizer-state element counts must
//!   partition the full manifest exactly;
//! * restrict-then-merge gradient sets must round-trip bit-for-bit.

use std::path::PathBuf;

use abrot::config::{Method, TrainCfg};
use abrot::model::{init_params, StagePartition};
use abrot::optim;
use abrot::pipeline::dp;
use abrot::rngs::Rng;
use abrot::runtime::{Manifest, Runtime};
use abrot::tensor::Tensor;

fn root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

const MODELS: [&str; 3] = ["micro", "pico4", "moe_micro"];

/// Random stage count in 1..=n_blocks.
fn random_stages(rng: &mut Rng, man: &Manifest) -> usize {
    1 + rng.below(man.cfg.n_blocks)
}

#[test]
fn random_stage_partitions_cover_params_and_classes_exactly() {
    let mut rng = Rng::new(0xC0FFEE);
    for model in MODELS {
        let man = Manifest::builtin(model).unwrap();
        for _case in 0..6 {
            let p = random_stages(&mut rng, &man);
            let part = StagePartition::new(&man, p);

            // every parameter appears in exactly one stage
            let mut covered = vec![0usize; man.params.len()];
            for k in 0..p {
                for i in part.params_of_stage(k) {
                    covered[i] += 1;
                }
            }
            assert!(
                covered.iter().all(|&c| c == 1),
                "{model} P={p}: {covered:?}"
            );

            // restricted shape-class slot counts partition the full
            // class counts (classes with no resident slot disappear)
            for sc in &man.shape_classes {
                let total: usize = (0..p)
                    .map(|k| {
                        let r = man.restrict(&part.params_of_stage(k));
                        r.shape_classes
                            .iter()
                            .find(|c| c.name == sc.name)
                            .map_or(0, |c| c.count)
                    })
                    .sum();
                assert_eq!(total, sc.count, "{model} P={p} class {}", sc.name);
            }
        }
    }
}

#[test]
fn restricted_optimizer_state_partitions_full_state() {
    let methods = [
        Method::PipeDream,
        Method::DelayComp { lambda: 0.1 },
        Method::br_default(),
        Method::Soap { freq: 5 },
        Method::Muon,
    ];
    let mut rng = Rng::new(0xBA5E);
    for model in MODELS {
        let full_rt = Runtime::open(root().join(model)).unwrap();
        for _case in 0..3 {
            let p = random_stages(&mut rng, &full_rt.manifest);
            let part = StagePartition::new(&full_rt.manifest, p);
            let cfg = TrainCfg { stages: p, ..Default::default() };
            for m in methods {
                let full = optim::build(&m, &full_rt, &cfg).state_elems();
                let split: usize = (0..p)
                    .map(|k| {
                        let rt = Runtime::open_restricted(
                            root().join(model),
                            &part.params_of_stage(k),
                        )
                        .unwrap();
                        optim::build(&m, &rt, &cfg).state_elems()
                    })
                    .sum();
                assert_eq!(
                    split, full,
                    "{model} P={p} {}: per-stage state must sum to full",
                    m.name()
                );
            }
        }
    }
}

#[test]
fn restrict_then_merge_gradients_round_trip() {
    let mut rng = Rng::new(0xD1CE);
    for model in MODELS {
        let man = Manifest::builtin(model).unwrap();
        let full: Vec<Tensor> = init_params(&man, 17);
        for _case in 0..4 {
            let p = random_stages(&mut rng, &man);
            let part = StagePartition::new(&man, p);
            let parts: Vec<(Vec<usize>, Vec<Tensor>)> = (0..p)
                .map(|k| {
                    let keep = part.params_of_stage(k);
                    let local: Vec<Tensor> =
                        keep.iter().map(|&i| full[i].clone()).collect();
                    // the restricted manifest sees the same shapes in
                    // the same (preserved) order
                    let r = man.restrict(&keep);
                    for (spec, t) in r.params.iter().zip(&local) {
                        assert_eq!(spec.shape, t.shape);
                    }
                    (keep, local)
                })
                .collect();
            let merged = dp::merge_restricted(man.params.len(), &parts).unwrap();
            for (a, b) in merged.iter().zip(&full) {
                assert_eq!(a.data, b.data, "{model} P={p}");
            }
        }
    }
}

#[test]
fn random_subset_restriction_keeps_slot_accounting() {
    // Not just stage-contiguous cuts: restrict to arbitrary random
    // subsets and check the regenerated classes/executables stay
    // consistent with the surviving parameters.
    let mut rng = Rng::new(0xFACE);
    for model in MODELS {
        let man = Manifest::builtin(model).unwrap();
        for _case in 0..6 {
            let keep: Vec<usize> = (0..man.params.len())
                .filter(|_| rng.below(2) == 1)
                .collect();
            let r = man.restrict(&keep);
            assert_eq!(r.params.len(), keep.len());
            for sc in &r.shape_classes {
                let slots: usize =
                    r.params.iter().map(|p| p.slots_in_class(&sc.name)).sum();
                assert_eq!(slots, sc.count, "{model} class {}", sc.name);
                assert!(sc.count > 0, "empty classes must be dropped");
                // regenerated batched executables sized to local counts
                let exec = &r.executables[&format!("muon_{}", sc.name)];
                assert_eq!(exec.inputs[0].shape[0], sc.count);
            }
            // dropped classes keep no stale optimizer executables
            for sc in &man.shape_classes {
                if !r.shape_classes.iter().any(|c| c.name == sc.name) {
                    assert!(
                        !r.executables.contains_key(&format!("muon_{}", sc.name)),
                        "{model} stale exec for dropped class {}",
                        sc.name
                    );
                }
            }
        }
    }
}
