//! Optimizer benchmarks: element-wise Adam throughput, batched rotated
//! update + eigen refresh dispatch latency through the active backend
//! (native by default; HLO/Pallas with `--features pjrt` + artifacts).
//!
//!     cargo bench --bench bench_optim
//!     cargo bench --bench bench_optim -- --json BENCH_optim.json

use abrot::bench::{bench, write_snapshot, BenchResult, BenchSnapshot};
use abrot::optim::reference::{self, Scalars};
use abrot::optim::ElementAdam;
use abrot::rngs::Rng;
use abrot::runtime::{tensor_to_value, Runtime, Value};
use abrot::tensor::{stack, Tensor};

fn randn(rng: &mut Rng, shape: &[usize]) -> Tensor {
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(&mut t.data, 1.0);
    t
}

fn json_path() -> Option<String> {
    let argv: Vec<String> = std::env::args().collect();
    argv.iter().position(|a| a == "--json").and_then(|i| argv.get(i + 1).cloned())
}

fn main() {
    println!("== bench_optim ==");
    let mut results: Vec<BenchResult> = Vec::new();
    let mut rng = Rng::new(1);

    // element-wise Adam (1M params)
    let shapes = vec![vec![1_000_000]];
    let mut adam = ElementAdam::new(&shapes);
    let mut w = randn(&mut rng, &[1_000_000]);
    let g = randn(&mut rng, &[1_000_000]);
    results.push(bench("element_adam 1M params", 2, 20, || {
        adam.update(0, &mut w, &g, 1e-3, 0.9, 0.999, 1e-8, 0.01, 3, false);
    }));

    // rust-reference rotated update (pico32 wqkv-sized: 32x96)
    let wr = randn(&mut rng, &[32, 96]);
    let gr = randn(&mut rng, &[32, 96]);
    let mr = randn(&mut rng, &[32, 96]);
    let vr = randn(&mut rng, &[32, 96]).map(f32::abs);
    let u = reference::cgs2_qr(&randn(&mut rng, &[32, 32]));
    let v = reference::cgs2_qr(&randn(&mut rng, &[96, 96]));
    let sc = Scalars { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, wd: 0.01, t: 3.0 };
    results.push(bench("rust rotated_adam 32x96", 5, 100, || {
        std::hint::black_box(reference::rotated_adam(&wr, &gr, &mr, &vr, &u, &v, sc, false));
    }));
    results.push(bench("rust power_qr 96x96", 5, 50, || {
        std::hint::black_box(reference::power_qr(&v.matmul(&v.transpose()), &v));
    }));

    // Backend-dispatched batched rotated update + eigen on micro
    // (NB=2, 16x48).
    let rt = Runtime::open("artifacts/micro").unwrap();
    println!("backend: {}", rt.backend_kind());
    let nb = 2;
    let mk = |rng: &mut Rng| {
        let mats: Vec<Tensor> = (0..nb).map(|_| randn(rng, &[16, 48])).collect();
        let refs: Vec<&Tensor> = mats.iter().collect();
        stack(&refs)
    };
    let w2 = mk(&mut rng);
    let g2 = mk(&mut rng);
    let m2 = mk(&mut rng);
    let v2 = mk(&mut rng).map(f32::abs);
    let us: Vec<Tensor> = (0..nb).map(|_| reference::cgs2_qr(&randn(&mut rng, &[16, 16]))).collect();
    let vs: Vec<Tensor> = (0..nb).map(|_| reference::cgs2_qr(&randn(&mut rng, &[48, 48]))).collect();
    let u2 = stack(&us.iter().collect::<Vec<_>>());
    let v2s = stack(&vs.iter().collect::<Vec<_>>());
    let mut scs = Tensor::zeros(&[nb, 8]);
    for i in 0..nb {
        scs.data[i * 8..(i + 1) * 8].copy_from_slice(&sc.to_row(1.0));
    }
    let inputs: Vec<Value> = [&w2, &g2, &m2, &v2, &u2, &v2s, &scs]
        .iter()
        .map(|t| tensor_to_value(t).unwrap())
        .collect();
    rt.exec("rot_adam_bi_wqkv", &inputs).unwrap();
    results.push(bench("backend rot_adam dispatch", 3, 50, || {
        std::hint::black_box(rt.exec("rot_adam_bi_wqkv", &inputs).unwrap());
    }));
    if rt.has_executable("rot_adam_bi_wqkv_pallas") {
        rt.exec("rot_adam_bi_wqkv_pallas", &inputs).unwrap();
        bench("HLO rot_adam (pallas interp)", 1, 10, || {
            std::hint::black_box(rt.exec("rot_adam_bi_wqkv_pallas", &inputs).unwrap());
        });
    }
    let eig_inputs: Vec<Value> = [
        &stack(&(0..nb).map(|i| us[i].matmul(&us[i].transpose())).collect::<Vec<_>>().iter().collect::<Vec<_>>()),
        &stack(&(0..nb).map(|i| vs[i].matmul(&vs[i].transpose())).collect::<Vec<_>>().iter().collect::<Vec<_>>()),
        &g2, &u2, &v2s, &scs,
    ]
    .iter()
    .map(|t| tensor_to_value(t).unwrap())
    .collect();
    rt.exec("eigen2nd_bi_wqkv", &eig_inputs).unwrap();
    results.push(bench("backend eigen2nd refresh", 3, 30, || {
        std::hint::black_box(rt.exec("eigen2nd_bi_wqkv", &eig_inputs).unwrap());
    }));

    if let Some(path) = json_path() {
        let snap = BenchSnapshot::new("optim", results);
        write_snapshot(&path, &snap).unwrap();
        println!("snapshot -> {path}");
    }
}
