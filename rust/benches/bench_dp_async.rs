//! Sync-vs-async DP under a straggler: the wall-clock case for
//! `--dp-async` (bounded-skew asynchronous data parallelism).
//!
//!     cargo bench --bench bench_dp_async
//!     cargo bench --bench bench_dp_async -- --json BENCH_dp_async.json
//!
//! Scenario: P = 4 × R = 2 on pico4 with *alternating* injected sleeps
//! on both replicas. Under synchronous DP every sleep stalls the whole
//! group at the next all-reduce, so the run pays the **sum** of all
//! delays; under `--dp-async --max-skew 2` each replica folds its
//! peer's slightly stale gradients and keeps stepping, so the run pays
//! roughly the **max** of the per-replica delay sums. (A single
//! one-sided delay would not separate the two modes — both would pay it
//! once — which is why the plan alternates sides.)
//!
//! Compare against the committed baseline with
//! `abrot benchcmp --baseline benchmarks/BENCH_dp_async.json --current PATH`.

use abrot::bench::{time_once, write_snapshot, BenchResult, BenchSnapshot};
use abrot::checkpoint::{self, FaultPlan, WorkerDelay};
use abrot::config::{Method, TrainCfg};
use abrot::runtime::pool::{set_global_threads, ThreadCfg};

fn arg_after(key: &str) -> Option<String> {
    let argv: Vec<String> = std::env::args().collect();
    argv.iter().position(|a| a == key).and_then(|i| argv.get(i + 1).cloned())
}

fn json_path() -> Option<String> {
    arg_after("--json")
}

fn once_result(name: &str, per_iter_us: f64, iters: usize) -> BenchResult {
    BenchResult {
        name: name.to_string(),
        iters,
        median_us: per_iter_us,
        p10_us: per_iter_us,
        p90_us: per_iter_us,
    }
}

const STEPS: u32 = 12;

fn cfg(dp_async: bool, max_skew: u32, threads: usize) -> TrainCfg {
    TrainCfg {
        method: Method::PipeDream,
        stages: 4,
        replicas: 2,
        steps: STEPS,
        lr: 5e-3,
        seed: 3,
        threads,
        dp_async,
        max_skew,
        ..Default::default()
    }
}

/// Alternating straggler plan: each replica sleeps twice, interleaved,
/// so sync DP serializes 4 × 60 ms while async DP overlaps each sleep
/// with the other replica's compute.
fn straggler_plan() -> FaultPlan {
    FaultPlan {
        delays: vec![
            WorkerDelay { at_update: 2, replica: 0, worker: 0, millis: 60 },
            WorkerDelay { at_update: 4, replica: 1, worker: 0, millis: 60 },
            WorkerDelay { at_update: 6, replica: 0, worker: 0, millis: 60 },
            WorkerDelay { at_update: 8, replica: 1, worker: 0, millis: 60 },
        ],
        ..Default::default()
    }
}

fn main() {
    println!("== bench_dp_async ==");
    let bench_threads: usize =
        arg_after("--threads").and_then(|s| s.parse().ok()).unwrap_or(0);
    set_global_threads(ThreadCfg::new(bench_threads));
    println!("threads: {}", abrot::runtime::pool::kernel_threads());
    let artifacts = std::path::PathBuf::from("artifacts/pico4");
    let mut results: Vec<BenchResult> = Vec::new();

    for (tag, dp_async, k, plan) in [
        ("sync P=4 R=2", false, 0u32, FaultPlan::default()),
        ("async K=2 P=4 R=2", true, 2, FaultPlan::default()),
        ("sync P=4 R=2 straggler", false, 0, straggler_plan()),
        ("async K=2 P=4 R=2 straggler", true, 2, straggler_plan()),
    ] {
        let c = cfg(dp_async, k, bench_threads);
        let (r, secs) = time_once(&format!("engine dp {tag}"), || {
            checkpoint::run_engine_elastic(&artifacts, &c, &plan).unwrap()
        });
        let skew = r
            .replica_counters
            .iter()
            .map(|rc| rc.dp_max_skew)
            .max()
            .unwrap_or(0);
        println!(
            "  -> {:.1} ms/step, bubble {:.1}%, realized max skew {}",
            secs * 1000.0 / STEPS as f64,
            r.bubble_frac * 100.0,
            skew
        );
        assert!(skew <= k, "{tag}: realized skew {skew} exceeds the bound {k}");
        results.push(once_result(
            &format!("engine dp {tag}"),
            secs * 1e6 / STEPS as f64,
            STEPS as usize,
        ));
    }

    // The headline: the async straggler row must beat the sync one.
    let median = |results: &[BenchResult], name: &str| -> f64 {
        results.iter().find(|r| r.name == name).unwrap().median_us
    };
    let sync_s = median(&results, "engine dp sync P=4 R=2 straggler");
    let async_s = median(&results, "engine dp async K=2 P=4 R=2 straggler");
    println!(
        "straggler speedup (sync/async): {:.2}x ({:.1} -> {:.1} ms/step)",
        sync_s / async_s,
        sync_s / 1e3,
        async_s / 1e3
    );

    if let Some(path) = json_path() {
        let snap = BenchSnapshot::new("dp_async", results);
        write_snapshot(&path, &snap).unwrap();
        println!("snapshot -> {path}");
    }
}
