//! End-to-end figure benches: one bench per paper table/figure family,
//! at micro scale so `cargo bench` stays fast. The full-scale versions
//! run through `abrot repro` (see Makefile `figures` target).
//!
//!     cargo bench --bench bench_figures

use abrot::bench::time_once;
use abrot::config::{Method, TrainCfg};
use abrot::coordinator::figures::{FigOpts, Harness};
use abrot::coordinator::Coordinator;
use abrot::landscape;

fn main() {
    println!("== bench_figures (micro-scale smoke of every table/figure) ==");

    time_once("fig3 grid", || landscape::fig3_grid(2));
    time_once("fig4 spiral (8 samples)", || landscape::spiral_slowdowns(8, 3));

    let mut coord = Coordinator::new("artifacts");
    let opts = FigOpts {
        out: std::path::PathBuf::from("results/bench_smoke"),
        steps: 24,
        stages: vec![1, 2],
        seed: 5,
        lr: 1e-2,
    };
    let mut h = Harness::new(&mut coord, opts);
    time_once("tables 1+2 (analytic)", || h.tables12().unwrap());
    time_once("fig5 sweep (micro, P in {1,2})", || h.fig5("micro").unwrap());
    time_once("fig8 strategies (micro)", || h.fig8("micro").unwrap());
    time_once("fig9c stage-aware (micro)", || h.fig9c("micro").unwrap());
    time_once("fig10 no-stash (micro)", || h.fig10("micro").unwrap());
    time_once("fig19 delay-comp (micro)", || h.fig19("micro").unwrap());
    time_once("table3 preconditioned (micro)", || h.table3("micro").unwrap());
    time_once("engine smoke (micro, P=2)", || h.engine("micro", 2).unwrap());

    // per-method single-step latency summary (Fig 9a basis)
    let rt = abrot::runtime::Runtime::open("artifacts/micro").unwrap();
    for m in [Method::PipeDream, Method::br_default(), Method::Soap { freq: 10 },
              Method::Muon, Method::Scion] {
        let cfg = TrainCfg { method: m, stages: 2, steps: 10, seed: 3, ..Default::default() };
        let (_, secs) = time_once(&format!("10 steps micro {}", cfg.method.name()),
                                  || abrot::pipeline::train_sim(&rt, &cfg).unwrap());
        println!("  -> {:.1} ms/step", secs * 100.0);
    }
}
