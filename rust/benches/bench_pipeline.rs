//! Pipeline benchmarks: simulator step latency per method (the Fig.-9a
//! wall-clock basis), stash-ring overhead, data pipeline, and the
//! threaded engine's throughput/bubble at several depths.
//!
//!     cargo bench --bench bench_pipeline
//!     cargo bench --bench bench_pipeline -- --json BENCH_engine.json
//!
//! With `--json PATH` the run additionally writes a `BenchSnapshot`
//! (schema in `abrot::bench`); compare against the committed baseline
//! with `abrot benchcmp --baseline benchmarks/BENCH_engine.json
//! --current PATH`.

use abrot::bench::{bench, time_once, write_snapshot, BenchResult, BenchSnapshot};
use abrot::config::{Method, TrainCfg};
use abrot::coordinator::{Coordinator, Experiment};
use abrot::data::{BatchIter, Corpus};
use abrot::pipeline::{train_sim, StashRing};
use abrot::runtime::pool::{set_global_threads, ThreadCfg};
use abrot::runtime::Runtime;
use abrot::tensor::Tensor;

fn arg_after(key: &str) -> Option<String> {
    let argv: Vec<String> = std::env::args().collect();
    argv.iter().position(|a| a == key).and_then(|i| argv.get(i + 1).cloned())
}

/// `--json PATH` from the post-`--` bench args.
fn json_path() -> Option<String> {
    arg_after("--json")
}

/// A single timed run folded into the snapshot schema (degenerate
/// quantiles: one sample).
fn once_result(name: &str, per_iter_us: f64, iters: usize) -> BenchResult {
    BenchResult {
        name: name.to_string(),
        iters,
        median_us: per_iter_us,
        p10_us: per_iter_us,
        p90_us: per_iter_us,
    }
}

fn main() {
    println!("== bench_pipeline ==");
    // `--threads N` pins the kernel pool budget (0/absent = auto); the
    // resolved value is recorded in the snapshot for benchcmp's gate.
    let bench_threads: usize =
        arg_after("--threads").and_then(|s| s.parse().ok()).unwrap_or(0);
    set_global_threads(ThreadCfg::new(bench_threads));
    println!("threads: {}", abrot::runtime::pool::kernel_threads());
    let mut results: Vec<BenchResult> = Vec::new();

    // data pipeline
    let corpus = Corpus::new(256, 1);
    let mut it = BatchIter::new(corpus, 4, 48, 0);
    results.push(bench("data next_batch 4x48", 10, 500, || {
        std::hint::black_box(it.next_batch());
    }));

    // stash ring push (1M params across 8 tensors, delays 0..7)
    let params: Vec<Tensor> = (0..8).map(|_| Tensor::ones(&[125_000])).collect();
    let delays: Vec<u32> = (0..8).collect();
    let mut ring = StashRing::new(&params, &delays);
    results.push(bench("stash_ring push 1M params", 3, 50, || {
        ring.push(&params);
    }));

    // simulator step latency per method (pico8, P=4)
    let rt = Runtime::open("artifacts/pico8").unwrap();
    for m in [Method::PipeDream, Method::br_default(), Method::Muon] {
        let cfg = TrainCfg {
            method: m,
            stages: 4,
            steps: 12,
            seed: 3,
            threads: bench_threads,
            ..Default::default()
        };
        let (r, secs) = time_once(&format!("sim 12 steps pico8 {}", cfg.method.name()),
                                  || train_sim(&rt, &cfg).unwrap());
        println!("  -> {:.1} ms/step, {} dispatches", secs * 1000.0 / 12.0, r.dispatches);
        results.push(once_result(
            &format!("sim step pico8 {}", r.method),
            secs * 1e6 / 12.0,
            12,
        ));
    }

    // threaded engine throughput/bubble
    let mut coord = Coordinator::new("artifacts");
    for p in [1usize, 2, 4] {
        let cfg = TrainCfg {
            method: Method::PipeDream,
            stages: p,
            steps: 16,
            seed: 3,
            threads: bench_threads,
            ..Default::default()
        };
        let model = if p <= 2 { "micro" } else { "pico8" };
        let r = coord
            .run_engine(&Experiment { model: model.into(), train: cfg })
            .unwrap();
        println!(
            "engine {model} P={p}: {:.0} tokens/s, bubble {:.1}%, wall {:.2}s",
            r.tokens_per_sec, r.bubble_frac * 100.0, r.wall_secs
        );
        results.push(once_result(
            &format!("engine step {model} P={p}"),
            r.wall_secs * 1e6 / 16.0,
            16,
        ));
    }

    // deep-pipeline throughput anchor: the repro preset (tiny32 at
    // P=8) — the row the pooled-kernel acceptance target is measured on
    {
        let cfg = TrainCfg {
            method: Method::PipeDream,
            stages: 8,
            steps: 8,
            seed: 3,
            threads: bench_threads,
            ..Default::default()
        };
        let r = coord
            .run_engine(&Experiment { model: "tiny32".into(), train: cfg })
            .unwrap();
        println!(
            "engine tiny32 P=8: {:.0} tokens/s, bubble {:.1}%, wall {:.2}s",
            r.tokens_per_sec, r.bubble_frac * 100.0, r.wall_secs
        );
        results.push(once_result("engine step tiny32 P=8", r.wall_secs * 1e6 / 8.0, 8));
    }

    // engine with per-stage optimizers beyond Adam: the paper's method
    // (stage-local eigen dispatches) and an MoE config
    for (model, m) in [("pico8", Method::br_default()), ("moe_pico", Method::PipeDream)] {
        let cfg = TrainCfg {
            method: m,
            stages: 4,
            steps: 16,
            seed: 3,
            threads: bench_threads,
            ..Default::default()
        };
        let r = coord
            .run_engine(&Experiment { model: model.into(), train: cfg })
            .unwrap();
        println!(
            "engine {model} P=4 {}: {:.0} tokens/s, bubble {:.1}%, {} dispatches",
            r.method, r.tokens_per_sec, r.bubble_frac * 100.0, r.dispatches
        );
        results.push(once_result(
            &format!("engine step {model} P=4 {}", r.method),
            r.wall_secs * 1e6 / 16.0,
            16,
        ));
    }

    if let Some(path) = json_path() {
        let snap = BenchSnapshot::new("engine", results);
        write_snapshot(&path, &snap).unwrap();
        println!("snapshot -> {path}");
    }
}
