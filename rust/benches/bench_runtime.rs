//! Runtime hot-path microbenchmarks: value conversion, executable
//! dispatch, whole-step fwdbwd latency per config.
//!
//!     cargo bench --bench bench_runtime
//!     cargo bench --bench bench_runtime -- --json BENCH_kernels.json
//!
//! With `--json PATH` the run writes a `BenchSnapshot` comparable to
//! the committed `benchmarks/BENCH_kernels.json` via `abrot benchcmp`.

use abrot::bench::{bench, write_snapshot, BenchResult, BenchSnapshot};
use abrot::model::init_params;
use abrot::runtime::pool::{set_global_threads, ThreadCfg};
use abrot::runtime::{tensor_to_value, tokens_to_value, Runtime, Value};
use abrot::tensor::Tensor;

fn arg_after(key: &str) -> Option<String> {
    let argv: Vec<String> = std::env::args().collect();
    argv.iter().position(|a| a == key).and_then(|i| argv.get(i + 1).cloned())
}

fn json_path() -> Option<String> {
    arg_after("--json")
}

fn main() {
    println!("== bench_runtime ==");
    // `--threads N` pins the kernel pool budget (0/absent = auto); the
    // resolved value is recorded in the snapshot for benchcmp's gate.
    let threads: usize = arg_after("--threads").and_then(|s| s.parse().ok()).unwrap_or(0);
    set_global_threads(ThreadCfg::new(threads));
    println!("threads: {}", abrot::runtime::pool::kernel_threads());
    let mut results: Vec<BenchResult> = Vec::new();
    let rt = Runtime::open("artifacts/micro").unwrap();
    println!("backend: {}", rt.backend_kind());
    let cfg = rt.cfg().clone();
    let params = init_params(&rt.manifest, 0);

    let big = Tensor::ones(&[256, 256]);
    results.push(bench("tensor_to_value 256x256", 10, 200, || {
        std::hint::black_box(tensor_to_value(&big).unwrap());
    }));
    let val = tensor_to_value(&big).unwrap();
    results.push(bench("value_to_vec 256x256", 10, 200, || {
        std::hint::black_box(val.to_f32().unwrap());
    }));

    let toks: Vec<i32> = (0..cfg.batch * cfg.seq).map(|i| (i % cfg.vocab) as i32).collect();
    let mut inputs: Vec<Value> =
        params.iter().map(|p| tensor_to_value(p).unwrap()).collect();
    inputs.push(tokens_to_value(&toks, cfg.batch, cfg.seq).unwrap());
    inputs.push(tokens_to_value(&toks, cfg.batch, cfg.seq).unwrap());
    rt.exec("fwdbwd", &inputs).unwrap(); // warm (compiles under pjrt)
    results.push(bench("fwdbwd dispatch micro", 3, 50, || {
        std::hint::black_box(rt.exec("fwdbwd", &inputs).unwrap());
    }));
    // eval_loss takes params + tok + tgt (same arity as fwdbwd)
    results.push(bench("eval_loss dispatch micro", 3, 50, || {
        std::hint::black_box(rt.exec("eval_loss", &inputs).unwrap());
    }));

    for model in ["pico8", "pico32"] {
        let rt = Runtime::open(format!("artifacts/{model}")).unwrap();
        let cfg = rt.cfg().clone();
        let params = init_params(&rt.manifest, 0);
        let toks: Vec<i32> =
            (0..cfg.batch * cfg.seq).map(|i| (i % cfg.vocab) as i32).collect();
        let mut inputs: Vec<Value> =
            params.iter().map(|p| tensor_to_value(p).unwrap()).collect();
        inputs.push(tokens_to_value(&toks, cfg.batch, cfg.seq).unwrap());
        inputs.push(tokens_to_value(&toks, cfg.batch, cfg.seq).unwrap());
        rt.exec("fwdbwd", &inputs).unwrap();
        results.push(bench(&format!("fwdbwd dispatch {model}"), 2, 20, || {
            std::hint::black_box(rt.exec("fwdbwd", &inputs).unwrap());
        }));
    }

    if let Some(path) = json_path() {
        let snap = BenchSnapshot::new("kernels", results);
        write_snapshot(&path, &snap).unwrap();
        println!("snapshot -> {path}");
    }
}
