//! Runtime hot-path microbenchmarks: literal conversion, executable
//! dispatch, whole-step fwdbwd latency per config.
//!
//!     cargo bench --bench bench_runtime

use abrot::bench::bench;
use abrot::model::init_params;
use abrot::runtime::{tensor_to_literal, tokens_to_literal, Runtime};
use abrot::tensor::Tensor;

fn main() {
    println!("== bench_runtime ==");
    let rt = Runtime::open("artifacts/micro").unwrap();
    let cfg = rt.cfg().clone();
    let params = init_params(&rt.manifest, 0);

    let big = Tensor::ones(&[256, 256]);
    bench("tensor_to_literal 256x256", 10, 200, || {
        std::hint::black_box(tensor_to_literal(&big).unwrap());
    });
    let lit = tensor_to_literal(&big).unwrap();
    bench("literal_to_vec 256x256", 10, 200, || {
        std::hint::black_box(lit.to_vec::<f32>().unwrap());
    });

    let toks: Vec<i32> = (0..cfg.batch * cfg.seq).map(|i| (i % cfg.vocab) as i32).collect();
    let mut inputs: Vec<xla::Literal> =
        params.iter().map(|p| tensor_to_literal(p).unwrap()).collect();
    inputs.push(tokens_to_literal(&toks, cfg.batch, cfg.seq).unwrap());
    inputs.push(tokens_to_literal(&toks, cfg.batch, cfg.seq).unwrap());
    rt.exec("fwdbwd", &inputs).unwrap(); // compile
    bench("fwdbwd dispatch micro", 3, 50, || {
        std::hint::black_box(rt.exec("fwdbwd", &inputs).unwrap());
    });
    let mut ev_inputs = inputs.clone();
    ev_inputs.pop();
    rt.exec("eval_loss", &ev_inputs[..]).unwrap_or_default();
    // eval_loss takes params + tok + tgt (same arity as fwdbwd)
    bench("eval_loss dispatch micro", 3, 50, || {
        std::hint::black_box(rt.exec("eval_loss", &inputs).unwrap());
    });

    for model in ["pico8", "pico32"] {
        let rt = Runtime::open(format!("artifacts/{model}")).unwrap();
        let cfg = rt.cfg().clone();
        let params = init_params(&rt.manifest, 0);
        let toks: Vec<i32> =
            (0..cfg.batch * cfg.seq).map(|i| (i % cfg.vocab) as i32).collect();
        let mut inputs: Vec<xla::Literal> =
            params.iter().map(|p| tensor_to_literal(p).unwrap()).collect();
        inputs.push(tokens_to_literal(&toks, cfg.batch, cfg.seq).unwrap());
        inputs.push(tokens_to_literal(&toks, cfg.batch, cfg.seq).unwrap());
        rt.exec("fwdbwd", &inputs).unwrap();
        bench(&format!("fwdbwd dispatch {model}"), 2, 20, || {
            std::hint::black_box(rt.exec("fwdbwd", &inputs).unwrap());
        });
    }
}
