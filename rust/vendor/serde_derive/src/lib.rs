//! `#[derive(Serialize)]` / `#[derive(Deserialize)]` for the vendored
//! `serde` subset: `Serialize` writes every named field of a struct to
//! JSON in declaration order by delegating to
//! `serde::Serialize::to_json` on each field value; `Deserialize`
//! revives the struct from a parsed `serde::Value` object by looking
//! each field up by name and delegating to
//! `serde::Deserialize::from_json` (so extra keys are ignored and a
//! missing key behaves like an explicit `null`).
//!
//! No `syn`/`quote` (the build is offline): the input token stream is
//! scanned directly. Supported shape: `struct Name { fields... }` with
//! named fields; doc comments, attributes and `pub(...)` modifiers on
//! fields are skipped. Tuple structs / enums / generics are out of
//! scope and produce a compile error pointing here.

use proc_macro::{Delimiter, TokenStream, TokenTree};

/// Extract (struct name, named field idents in declaration order).
fn parse_struct(input: TokenStream) -> Result<(String, Vec<String>), String> {
    let mut name: Option<String> = None;
    let mut saw_struct = false;
    let mut body: Option<TokenStream> = None;
    for tt in input {
        match tt {
            TokenTree::Ident(id) => {
                let s = id.to_string();
                if s == "struct" {
                    saw_struct = true;
                } else if saw_struct && name.is_none() {
                    name = Some(s);
                }
            }
            TokenTree::Group(g)
                if g.delimiter() == Delimiter::Brace && name.is_some() =>
            {
                body = Some(g.stream());
                break;
            }
            TokenTree::Punct(p) if p.as_char() == '<' && name.is_some() => {
                return Err("generic structs are not supported".to_string());
            }
            _ => {}
        }
    }
    let name = name.ok_or("expected a struct definition")?;
    let body = body.ok_or("only structs with named fields are supported")?;

    // Walk the brace body: a field is the first ident of each
    // comma-separated entry (commas inside `<...>` belong to the type).
    let mut fields = Vec::new();
    let mut at_field_start = true;
    let mut expect_colon = false;
    let mut candidate = String::new();
    let mut angle_depth = 0i32;
    let mut toks = body.into_iter().peekable();
    while let Some(tt) = toks.next() {
        if at_field_start {
            match tt {
                // attribute / doc comment: `#` followed by `[...]`
                TokenTree::Punct(p) if p.as_char() == '#' => {
                    if matches!(toks.peek(), Some(TokenTree::Group(_))) {
                        toks.next();
                    }
                }
                TokenTree::Ident(id) if id.to_string() == "pub" => {
                    // optional visibility scope: `pub(crate)` etc.
                    if let Some(TokenTree::Group(g)) = toks.peek() {
                        if g.delimiter() == Delimiter::Parenthesis {
                            toks.next();
                        }
                    }
                }
                TokenTree::Ident(id) => {
                    candidate = id.to_string();
                    at_field_start = false;
                    expect_colon = true;
                }
                _ => {}
            }
        } else if expect_colon {
            match tt {
                TokenTree::Punct(p) if p.as_char() == ':' => {
                    fields.push(candidate.clone());
                    expect_colon = false;
                }
                _ => return Err(format!("expected `:` after field {candidate}")),
            }
        } else {
            // consuming the field type until a top-level comma
            match tt {
                TokenTree::Punct(p) => match p.as_char() {
                    '<' => angle_depth += 1,
                    '>' => angle_depth -= 1,
                    ',' if angle_depth == 0 => at_field_start = true,
                    _ => {}
                },
                _ => {}
            }
        }
    }
    Ok((name, fields))
}

#[proc_macro_derive(Serialize)]
pub fn derive_serialize(input: TokenStream) -> TokenStream {
    let (name, fields) = match parse_struct(input) {
        Ok(x) => x,
        Err(e) => {
            let msg = format!(
                "compile_error!(\"#[derive(serde::Serialize)] (vendored subset): {e}\");"
            );
            return msg.parse().unwrap();
        }
    };
    let mut pushes = String::new();
    for (i, f) in fields.iter().enumerate() {
        if i > 0 {
            pushes.push_str("out.push(',');\n");
        }
        pushes.push_str(&format!(
            "out.push_str(\"\\\"{f}\\\":\");\n\
             out.push_str(&serde::Serialize::to_json(&self.{f}));\n"
        ));
    }
    let code = format!(
        "impl serde::Serialize for {name} {{\n\
             fn to_json(&self) -> String {{\n\
                 let mut out = String::from(\"{{\");\n\
                 {pushes}\
                 out.push('}}');\n\
                 out\n\
             }}\n\
         }}\n"
    );
    code.parse().unwrap()
}

#[proc_macro_derive(Deserialize)]
pub fn derive_deserialize(input: TokenStream) -> TokenStream {
    let (name, fields) = match parse_struct(input) {
        Ok(x) => x,
        Err(e) => {
            let msg = format!(
                "compile_error!(\"#[derive(serde::Deserialize)] (vendored subset): {e}\");"
            );
            return msg.parse().unwrap();
        }
    };
    let mut inits = String::new();
    for f in &fields {
        inits.push_str(&format!(
            "{f}: serde::Deserialize::from_json(\
                 v.get(\"{f}\").unwrap_or(&serde::Value::Null))\
                 .map_err(|e| format!(\"{name}.{f}: {{e}}\"))?,\n"
        ));
    }
    let code = format!(
        "impl serde::Deserialize for {name} {{\n\
             fn from_json(v: &serde::Value) -> Result<Self, String> {{\n\
                 if !matches!(v, serde::Value::Obj(_)) {{\n\
                     return Err(format!(\
                         \"{name}: expected object, got {{}}\", v.kind()));\n\
                 }}\n\
                 Ok({name} {{\n\
                     {inits}\
                 }})\n\
             }}\n\
         }}\n"
    );
    code.parse().unwrap()
}
