//! Minimal offline-vendored subset of the `serde` serialization API.
//!
//! Like the vendored `anyhow` subset, this keeps the default build
//! fully offline: the real crates.io `serde` cannot be fetched in the
//! sandboxed build environment. The subset covers exactly what this
//! repo needs — `#[derive(serde::Serialize)]` on named-field structs,
//! producing JSON text — and mirrors the real crate's shape (`serde`
//! re-exporting the derive from `serde_derive`), so swapping in the
//! real dependency later only widens the API.
//!
//! The single trait method is [`Serialize::to_json`]; the derive
//! serializes every named field in declaration order. Non-finite
//! floats serialize as `null` (standard JSON has no NaN/inf).

// The derive emits `impl serde::Serialize for ...`; make that path
// resolve inside this crate too (serde proper does the same).
extern crate self as serde;

pub use serde_derive::Serialize;

/// A value serializable to JSON text (subset of serde's `Serialize`).
pub trait Serialize {
    /// Serialize `self` as a JSON value.
    fn to_json(&self) -> String;
}

fn json_f64(x: f64) -> String {
    if !x.is_finite() {
        return "null".to_string();
    }
    if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        // shortest round-trip representation
        format!("{x}")
    }
}

impl Serialize for f32 {
    fn to_json(&self) -> String {
        json_f64(f64::from(*self))
    }
}

impl Serialize for f64 {
    fn to_json(&self) -> String {
        json_f64(*self)
    }
}

macro_rules! impl_int {
    ($($t:ty),*) => {$(
        impl Serialize for $t {
            fn to_json(&self) -> String {
                format!("{}", self)
            }
        }
    )*};
}

impl_int!(u8, u16, u32, u64, usize, i8, i16, i32, i64, isize);

impl Serialize for bool {
    fn to_json(&self) -> String {
        if *self { "true".to_string() } else { "false".to_string() }
    }
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

impl Serialize for String {
    fn to_json(&self) -> String {
        json_str(self)
    }
}

impl Serialize for &str {
    fn to_json(&self) -> String {
        json_str(self)
    }
}

impl<T: Serialize> Serialize for Vec<T> {
    fn to_json(&self) -> String {
        let cells: Vec<String> = self.iter().map(|x| x.to_json()).collect();
        format!("[{}]", cells.join(","))
    }
}

impl<T: Serialize> Serialize for Option<T> {
    fn to_json(&self) -> String {
        match self {
            Some(x) => x.to_json(),
            None => "null".to_string(),
        }
    }
}

impl<A: Serialize, B: Serialize> Serialize for (A, B) {
    fn to_json(&self) -> String {
        format!("[{},{}]", self.0.to_json(), self.1.to_json())
    }
}

impl<A: Serialize, B: Serialize, C: Serialize> Serialize for (A, B, C) {
    fn to_json(&self) -> String {
        format!("[{},{},{}]", self.0.to_json(), self.1.to_json(), self.2.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_and_strings() {
        assert_eq!(3u32.to_json(), "3");
        assert_eq!(1.5f32.to_json(), "1.5");
        assert_eq!(2.0f64.to_json(), "2");
        assert_eq!(f32::NAN.to_json(), "null");
        assert_eq!(true.to_json(), "true");
        assert_eq!("a\"b".to_string().to_json(), "\"a\\\"b\"");
    }

    #[test]
    fn containers() {
        assert_eq!(vec![1u32, 2, 3].to_json(), "[1,2,3]");
        assert_eq!((4u32, 0.5f32).to_json(), "[4,0.5]");
        assert_eq!(Option::<u32>::None.to_json(), "null");
        assert_eq!(Some(7u32).to_json(), "7");
    }

    #[derive(Serialize)]
    struct Demo {
        /// doc comments on fields must be skipped by the derive
        pub steps: u32,
        loss: f32,
        tags: Vec<(u32, f32)>,
        name: String,
        ok: bool,
    }

    #[test]
    fn derive_serializes_named_fields_in_order() {
        let d = Demo {
            steps: 20,
            loss: 2.25,
            tags: vec![(1, 0.5), (2, 0.25)],
            name: "run".to_string(),
            ok: true,
        };
        assert_eq!(
            d.to_json(),
            "{\"steps\":20,\"loss\":2.25,\"tags\":[[1,0.5],[2,0.25]],\
             \"name\":\"run\",\"ok\":true}"
        );
    }
}
