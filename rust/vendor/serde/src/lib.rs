//! Minimal offline-vendored subset of the `serde` serialization API.
//!
//! Like the vendored `anyhow` subset, this keeps the default build
//! fully offline: the real crates.io `serde` cannot be fetched in the
//! sandboxed build environment. The subset covers exactly what this
//! repo needs — `#[derive(serde::Serialize)]` on named-field structs,
//! producing JSON text — and mirrors the real crate's shape (`serde`
//! re-exporting the derive from `serde_derive`), so swapping in the
//! real dependency later only widens the API.
//!
//! The single trait method is [`Serialize::to_json`]; the derive
//! serializes every named field in declaration order. Non-finite
//! floats serialize as `null` (standard JSON has no NaN/inf).
//!
//! The inverse direction mirrors `serde_json`'s shape at subset scale:
//! [`Value`] is a parsed JSON tree, [`Deserialize::from_json`] revives
//! a value from it, and [`from_str`] composes the two. Pinned policy
//! for the lossy corners:
//!   - non-finite floats serialized as `null` revive as `NaN` on bare
//!     `f32`/`f64` fields, while `Option<f32>` revives `null` as `None`
//!     (so `Some(NaN)` cannot round-trip — it collapses to `None`);
//!   - integers ride through an `f64`, so magnitudes above 2^53 lose
//!     precision and fail the range check instead of rounding silently;
//!   - a field missing from the object deserializes as `null` (errors
//!     for ints/bool/string/containers, `None` for `Option`, `NaN` for
//!     bare floats).

// The derive emits `impl serde::Serialize for ...`; make that path
// resolve inside this crate too (serde proper does the same).
extern crate self as serde;

pub use serde_derive::{Deserialize, Serialize};

/// A value serializable to JSON text (subset of serde's `Serialize`).
pub trait Serialize {
    /// Serialize `self` as a JSON value.
    fn to_json(&self) -> String;
}

fn json_f64(x: f64) -> String {
    if !x.is_finite() {
        return "null".to_string();
    }
    if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        // shortest round-trip representation
        format!("{x}")
    }
}

impl Serialize for f32 {
    fn to_json(&self) -> String {
        json_f64(f64::from(*self))
    }
}

impl Serialize for f64 {
    fn to_json(&self) -> String {
        json_f64(*self)
    }
}

macro_rules! impl_int {
    ($($t:ty),*) => {$(
        impl Serialize for $t {
            fn to_json(&self) -> String {
                format!("{}", self)
            }
        }
    )*};
}

impl_int!(u8, u16, u32, u64, usize, i8, i16, i32, i64, isize);

impl Serialize for bool {
    fn to_json(&self) -> String {
        if *self { "true".to_string() } else { "false".to_string() }
    }
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

impl Serialize for String {
    fn to_json(&self) -> String {
        json_str(self)
    }
}

impl Serialize for &str {
    fn to_json(&self) -> String {
        json_str(self)
    }
}

impl<T: Serialize> Serialize for Vec<T> {
    fn to_json(&self) -> String {
        let cells: Vec<String> = self.iter().map(|x| x.to_json()).collect();
        format!("[{}]", cells.join(","))
    }
}

impl<T: Serialize> Serialize for Option<T> {
    fn to_json(&self) -> String {
        match self {
            Some(x) => x.to_json(),
            None => "null".to_string(),
        }
    }
}

impl<A: Serialize, B: Serialize> Serialize for (A, B) {
    fn to_json(&self) -> String {
        format!("[{},{}]", self.0.to_json(), self.1.to_json())
    }
}

impl<A: Serialize, B: Serialize, C: Serialize> Serialize for (A, B, C) {
    fn to_json(&self) -> String {
        format!("[{},{},{}]", self.0.to_json(), self.1.to_json(), self.2.to_json())
    }
}

/// A parsed JSON value (subset mirror of `serde_json::Value`).
///
/// Objects preserve key order as a `Vec` of pairs — the subset never
/// needs hashed lookup, and ordered entries keep `to_json ∘ parse`
/// reproducible for the round-trip tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Parse JSON text into a [`Value`] tree.
    pub fn parse(text: &str) -> Result<Value, String> {
        let mut p = Parser { chars: text.chars().collect(), pos: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.chars.len() {
            return Err(format!("trailing characters at offset {}", p.pos));
        }
        Ok(v)
    }

    /// Look up `key` in an object value (first match; `None` otherwise).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(entries) => {
                entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    /// Short type tag for error messages.
    pub fn kind(&self) -> &'static str {
        match self {
            Value::Null => "null",
            Value::Bool(_) => "bool",
            Value::Num(_) => "number",
            Value::Str(_) => "string",
            Value::Arr(_) => "array",
            Value::Obj(_) => "object",
        }
    }
}

struct Parser {
    chars: Vec<char>,
    pos: usize,
}

impl Parser {
    fn skip_ws(&mut self) {
        while let Some(c) = self.chars.get(self.pos) {
            if !c.is_ascii_whitespace() {
                break;
            }
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, String> {
        for w in word.chars() {
            if self.bump() != Some(w) {
                return Err(format!("bad literal near offset {}", self.pos));
            }
        }
        Ok(v)
    }

    fn value(&mut self) -> Result<Value, String> {
        self.skip_ws();
        match self.peek() {
            Some('n') => self.lit("null", Value::Null),
            Some('t') => self.lit("true", Value::Bool(true)),
            Some('f') => self.lit("false", Value::Bool(false)),
            Some('"') => Ok(Value::Str(self.string()?)),
            Some('[') => {
                self.bump();
                let mut items = Vec::new();
                self.skip_ws();
                if self.peek() == Some(']') {
                    self.bump();
                    return Ok(Value::Arr(items));
                }
                loop {
                    items.push(self.value()?);
                    self.skip_ws();
                    match self.bump() {
                        Some(',') => {}
                        Some(']') => break,
                        _ => {
                            return Err(format!(
                                "expected ',' or ']' at offset {}",
                                self.pos
                            ));
                        }
                    }
                }
                Ok(Value::Arr(items))
            }
            Some('{') => {
                self.bump();
                let mut entries = Vec::new();
                self.skip_ws();
                if self.peek() == Some('}') {
                    self.bump();
                    return Ok(Value::Obj(entries));
                }
                loop {
                    self.skip_ws();
                    let key = self.string()?;
                    self.skip_ws();
                    if self.bump() != Some(':') {
                        return Err(format!(
                            "expected ':' at offset {}",
                            self.pos
                        ));
                    }
                    entries.push((key, self.value()?));
                    self.skip_ws();
                    match self.bump() {
                        Some(',') => {}
                        Some('}') => break,
                        _ => {
                            return Err(format!(
                                "expected ',' or '}}' at offset {}",
                                self.pos
                            ));
                        }
                    }
                }
                Ok(Value::Obj(entries))
            }
            Some(c) if c == '-' || c.is_ascii_digit() => {
                let start = self.pos;
                while let Some(c) = self.peek() {
                    if c.is_ascii_digit()
                        || matches!(c, '-' | '+' | '.' | 'e' | 'E')
                    {
                        self.pos += 1;
                    } else {
                        break;
                    }
                }
                let text: String = self.chars[start..self.pos].iter().collect();
                text.parse::<f64>()
                    .map(Value::Num)
                    .map_err(|_| format!("bad number {text:?}"))
            }
            Some(c) => {
                Err(format!("unexpected character {c:?} at offset {}", self.pos))
            }
            None => Err("unexpected end of input".to_string()),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        if self.bump() != Some('"') {
            return Err(format!("expected '\"' at offset {}", self.pos));
        }
        let mut out = String::new();
        loop {
            match self.bump() {
                Some('"') => return Ok(out),
                Some('\\') => match self.bump() {
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    Some('/') => out.push('/'),
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('r') => out.push('\r'),
                    Some('b') => out.push('\u{0008}'),
                    Some('f') => out.push('\u{000c}'),
                    Some('u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self
                                .bump()
                                .ok_or_else(|| "truncated \\u escape".to_string())?;
                            let d = c
                                .to_digit(16)
                                .ok_or_else(|| format!("bad hex digit {c:?}"))?;
                            code = code * 16 + d;
                        }
                        out.push(char::from_u32(code).ok_or_else(|| {
                            format!("bad \\u{code:04x} escape")
                        })?);
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(c) => out.push(c),
                None => return Err("unterminated string".to_string()),
            }
        }
    }
}

/// A value revivable from a parsed JSON [`Value`] (subset of serde's
/// `Deserialize`).
pub trait Deserialize: Sized {
    /// Deserialize `Self` from a parsed JSON value.
    fn from_json(v: &Value) -> Result<Self, String>;
}

/// Parse JSON text and deserialize a `T` from it.
pub fn from_str<T: Deserialize>(s: &str) -> Result<T, String> {
    T::from_json(&Value::parse(s)?)
}

impl Deserialize for f64 {
    fn from_json(v: &Value) -> Result<f64, String> {
        match v {
            Value::Num(x) => Ok(*x),
            // non-finite floats serialize as `null`; bare floats revive
            // them as NaN (the sign/inf distinction is not preserved)
            Value::Null => Ok(f64::NAN),
            _ => Err(format!("expected number, got {}", v.kind())),
        }
    }
}

impl Deserialize for f32 {
    fn from_json(v: &Value) -> Result<f32, String> {
        // f32 -> f64 widening is exact and the serializer emits the
        // shortest round-trip f64 text, so this narrowing is bit-exact.
        f64::from_json(v).map(|x| x as f32)
    }
}

macro_rules! impl_de_int {
    ($($t:ty),*) => {$(
        impl Deserialize for $t {
            fn from_json(v: &Value) -> Result<$t, String> {
                match v {
                    Value::Num(x) if x.fract() == 0.0 => {
                        <$t>::try_from(*x as i128)
                            .map_err(|_| format!("number {x} out of range"))
                    }
                    _ => Err(format!("expected integer, got {}", v.kind())),
                }
            }
        }
    )*};
}

impl_de_int!(u8, u16, u32, u64, usize, i8, i16, i32, i64, isize);

impl Deserialize for bool {
    fn from_json(v: &Value) -> Result<bool, String> {
        match v {
            Value::Bool(b) => Ok(*b),
            _ => Err(format!("expected bool, got {}", v.kind())),
        }
    }
}

impl Deserialize for String {
    fn from_json(v: &Value) -> Result<String, String> {
        match v {
            Value::Str(s) => Ok(s.clone()),
            _ => Err(format!("expected string, got {}", v.kind())),
        }
    }
}

impl<T: Deserialize> Deserialize for Vec<T> {
    fn from_json(v: &Value) -> Result<Vec<T>, String> {
        match v {
            Value::Arr(items) => items.iter().map(T::from_json).collect(),
            _ => Err(format!("expected array, got {}", v.kind())),
        }
    }
}

impl<T: Deserialize> Deserialize for Option<T> {
    fn from_json(v: &Value) -> Result<Option<T>, String> {
        match v {
            Value::Null => Ok(None),
            other => T::from_json(other).map(Some),
        }
    }
}

impl<A: Deserialize, B: Deserialize> Deserialize for (A, B) {
    fn from_json(v: &Value) -> Result<(A, B), String> {
        match v {
            Value::Arr(items) if items.len() == 2 => {
                Ok((A::from_json(&items[0])?, B::from_json(&items[1])?))
            }
            _ => Err(format!("expected 2-element array, got {}", v.kind())),
        }
    }
}

impl<A: Deserialize, B: Deserialize, C: Deserialize> Deserialize for (A, B, C) {
    fn from_json(v: &Value) -> Result<(A, B, C), String> {
        match v {
            Value::Arr(items) if items.len() == 3 => Ok((
                A::from_json(&items[0])?,
                B::from_json(&items[1])?,
                C::from_json(&items[2])?,
            )),
            _ => Err(format!("expected 3-element array, got {}", v.kind())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_and_strings() {
        assert_eq!(3u32.to_json(), "3");
        assert_eq!(1.5f32.to_json(), "1.5");
        assert_eq!(2.0f64.to_json(), "2");
        assert_eq!(f32::NAN.to_json(), "null");
        assert_eq!(true.to_json(), "true");
        assert_eq!("a\"b".to_string().to_json(), "\"a\\\"b\"");
    }

    #[test]
    fn containers() {
        assert_eq!(vec![1u32, 2, 3].to_json(), "[1,2,3]");
        assert_eq!((4u32, 0.5f32).to_json(), "[4,0.5]");
        assert_eq!(Option::<u32>::None.to_json(), "null");
        assert_eq!(Some(7u32).to_json(), "7");
    }

    #[derive(Serialize)]
    struct Demo {
        /// doc comments on fields must be skipped by the derive
        pub steps: u32,
        loss: f32,
        tags: Vec<(u32, f32)>,
        name: String,
        ok: bool,
    }

    #[test]
    fn derive_serializes_named_fields_in_order() {
        let d = Demo {
            steps: 20,
            loss: 2.25,
            tags: vec![(1, 0.5), (2, 0.25)],
            name: "run".to_string(),
            ok: true,
        };
        assert_eq!(
            d.to_json(),
            "{\"steps\":20,\"loss\":2.25,\"tags\":[[1,0.5],[2,0.25]],\
             \"name\":\"run\",\"ok\":true}"
        );
    }

    #[test]
    fn parse_scalars_and_containers() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse(" true ").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-2.5e1").unwrap(), Value::Num(-25.0));
        assert_eq!(
            Value::parse("\"a\\\"b\\u0041\"").unwrap(),
            Value::Str("a\"bA".to_string())
        );
        assert_eq!(
            Value::parse("[1, 2,3]").unwrap(),
            Value::Arr(vec![Value::Num(1.0), Value::Num(2.0), Value::Num(3.0)])
        );
        let obj = Value::parse("{\"a\": 1, \"b\": [true, null]}").unwrap();
        assert_eq!(obj.get("a"), Some(&Value::Num(1.0)));
        assert_eq!(
            obj.get("b"),
            Some(&Value::Arr(vec![Value::Bool(true), Value::Null]))
        );
        assert!(Value::parse("[1,2").is_err());
        assert!(Value::parse("{\"a\" 1}").is_err());
        assert!(Value::parse("1 junk").is_err());
    }

    #[test]
    fn deserialize_scalars() {
        assert_eq!(from_str::<u32>("3").unwrap(), 3);
        assert_eq!(from_str::<f32>("1.5").unwrap(), 1.5);
        assert!(from_str::<f32>("null").unwrap().is_nan());
        assert_eq!(from_str::<Option<f32>>("null").unwrap(), None);
        assert!(from_str::<bool>("true").unwrap());
        assert_eq!(from_str::<String>("\"hi\"").unwrap(), "hi");
        assert_eq!(from_str::<Vec<u32>>("[1,2]").unwrap(), vec![1, 2]);
        assert_eq!(from_str::<(u32, f64)>("[4,0.5]").unwrap(), (4, 0.5));
        assert!(from_str::<u32>("1.5").is_err());
        assert!(from_str::<u8>("300").is_err());
        assert!(from_str::<i32>("-1e19").is_err());
    }

    #[derive(Serialize, Deserialize, Debug, PartialEq)]
    struct DemoRt {
        steps: u32,
        loss: f32,
        tags: Vec<(u32, f32)>,
        label: Option<String>,
        ok: bool,
    }

    #[test]
    fn derive_round_trips_named_fields() {
        let d = DemoRt {
            steps: 20,
            loss: 2.25,
            tags: vec![(1, 0.5), (2, 0.25)],
            label: None,
            ok: true,
        };
        let j = d.to_json();
        let back: DemoRt = from_str(&j).unwrap();
        assert_eq!(back, d);
        // to_json . from_str . to_json is the identity on the text too
        assert_eq!(back.to_json(), j);
        // missing non-optional field errors with a field path
        let err = from_str::<DemoRt>("{\"steps\":1}").unwrap_err();
        assert!(err.contains("DemoRt."), "{err}");
    }
}
