//! Compile-only stub of the `xla` (xla_extension / PJRT) client API.
//!
//! The `abrot` crate's `pjrt` cargo feature compiles `runtime::pjrt`
//! against this surface. Host-side `Literal` handling is fully
//! functional (it is just shape + bytes); every operation that would
//! need the native `xla_extension` library — client creation, HLO
//! compilation, execution — returns [`Error::Unavailable`] at runtime.
//!
//! To actually execute HLO artifacts, patch in the real crate, e.g. in
//! the workspace `Cargo.toml`:
//!
//! ```toml
//! [patch."crates-io"]
//! # or point the `xla` path dependency at a checkout of xla-rs built
//! # against xla_extension 0.5.1
//! ```
//!
//! The stub intentionally mirrors the signatures `abrot` uses so the
//! swap is source-compatible.

use std::fmt;

/// Stub error type.
#[derive(Debug)]
pub enum Error {
    /// The native xla_extension library is not linked into this build.
    Unavailable(&'static str),
    /// Host-side literal error (shape/dtype mismatch).
    Literal(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(op) => write!(
                f,
                "xla stub: `{op}` needs the native xla_extension library; \
                 this build uses the compile-only stub (see rust/vendor/xla)"
            ),
            Error::Literal(m) => write!(f, "xla literal: {m}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(op: &'static str) -> Result<T> {
    Err(Error::Unavailable(op))
}

/// Element dtypes `abrot` exchanges with PJRT.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    fn byte_width(self) -> usize {
        4
    }
}

/// Plain-old-data element types a [`Literal`] can be viewed as.
pub trait NativeType: Copy {
    fn element_type() -> ElementType;
    fn from_le_bytes(b: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    fn element_type() -> ElementType {
        ElementType::F32
    }
    fn from_le_bytes(b: [u8; 4]) -> f32 {
        f32::from_le_bytes(b)
    }
}

impl NativeType for i32 {
    fn element_type() -> ElementType {
        ElementType::S32
    }
    fn from_le_bytes(b: [u8; 4]) -> i32 {
        i32::from_le_bytes(b)
    }
}

/// Host-side literal: dtype + shape + raw little-endian bytes.
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    shape: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        shape: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = shape.iter().product();
        if elems * ty.byte_width() != data.len() {
            return Err(Error::Literal(format!(
                "shape {shape:?} needs {} bytes, got {}",
                elems * ty.byte_width(),
                data.len()
            )));
        }
        Ok(Literal { ty, shape: shape.to_vec(), bytes: data.to_vec() })
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::element_type() != self.ty {
            return Err(Error::Literal(format!(
                "dtype mismatch: literal is {:?}",
                self.ty
            )));
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| T::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Tuple decomposition needs the native runtime (tuple literals are
    /// only ever produced by executions, which the stub cannot run).
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        unavailable("Literal::decompose_tuple")
    }
}

/// Stub PJRT CPU client — construction fails at runtime.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_literal")
    }
}

/// Stub device buffer.
pub struct PjRtBuffer {
    _private: (),
}

impl AsRef<PjRtBuffer> for PjRtBuffer {
    fn as_ref(&self) -> &PjRtBuffer {
        self
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Stub compiled executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<B: AsRef<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// Stub HLO module proto (text-parsed).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Stub XLA computation.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let xs = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = xs.iter().flat_map(|x| x.to_le_bytes()).collect();
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[3],
            &bytes,
        )
        .unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), xs);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn client_unavailable() {
        assert!(PjRtClient::cpu().is_err());
    }
}
