//! Minimal, dependency-free subset of the `anyhow` error-handling API.
//!
//! Vendored so the `abrot` workspace builds fully offline (no crates.io
//! access). Only the surface the crate actually uses is provided:
//!
//! * [`Error`] — a message-carrying error type (context is folded into
//!   the message chain instead of a source chain).
//! * [`Result`] — `std::result::Result` defaulted to [`Error`].
//! * [`anyhow!`] / [`bail!`] — format-style construction / early return.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on results.
//!
//! Drop-in compatible with the real `anyhow` for this subset: deleting
//! this vendor directory and depending on crates.io `anyhow = "1"`
//! compiles unchanged.

use std::fmt;

/// A boxed-message error. Unlike the real `anyhow::Error` it stores the
/// rendered message chain, not the source errors themselves.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`;
// that keeps the blanket `From` below coherent (same trick as the real
// anyhow crate).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `Result` with the error type defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Attach context to the error branch of a `Result`.
pub trait Context<T, E> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{ctx}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T, Error> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error { msg: ctx.to_string() })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error { msg: f().to_string() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("broken {}", 42)
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "broken 42");
    }

    #[test]
    fn io_error_converts() {
        let r: Result<String> =
            std::fs::read_to_string("/definitely/not/a/file").map_err(Error::from);
        assert!(r.is_err());
    }

    #[test]
    fn context_wraps() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("while formatting").unwrap_err();
        assert!(e.to_string().starts_with("while formatting: "));
    }
}
