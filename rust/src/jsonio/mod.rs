//! Minimal JSON parser/writer (no external crates) — enough for the
//! artifact manifests emitted by `python/compile/aot.py`, run configs
//! and result files. Not a general-purpose library: numbers are f64,
//! strings support the standard escapes, and input is assumed UTF-8.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let b = s.as_bytes();
        let mut i = 0usize;
        let v = parse_value(b, &mut i)?;
        skip_ws(b, &mut i);
        if i != b.len() {
            return Err(format!("trailing data at byte {i}"));
        }
        Ok(v)
    }

    // ---- typed accessors ----

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn at(&self, key: &str) -> &Json {
        self.get(key).unwrap_or_else(|| panic!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> f64 {
        match self {
            Json::Num(x) => *x,
            _ => panic!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> usize {
        self.as_f64() as usize
    }

    pub fn as_i64(&self) -> i64 {
        self.as_f64() as i64
    }

    pub fn as_str(&self) -> &str {
        match self {
            Json::Str(s) => s,
            _ => panic!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> &[Json] {
        match self {
            Json::Arr(a) => a,
            _ => panic!("not an array: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> bool {
        match self {
            Json::Bool(b) => *b,
            _ => panic!("not a bool: {self:?}"),
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ---- writer ----

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        write_value(self, &mut s);
        s
    }
}

fn skip_ws(b: &[u8], i: &mut usize) {
    while *i < b.len() && matches!(b[*i], b' ' | b'\t' | b'\n' | b'\r') {
        *i += 1;
    }
}

fn parse_value(b: &[u8], i: &mut usize) -> Result<Json, String> {
    skip_ws(b, i);
    if *i >= b.len() {
        return Err("unexpected end".into());
    }
    match b[*i] {
        b'{' => parse_obj(b, i),
        b'[' => parse_arr(b, i),
        b'"' => Ok(Json::Str(parse_string(b, i)?)),
        b't' => lit(b, i, "true", Json::Bool(true)),
        b'f' => lit(b, i, "false", Json::Bool(false)),
        b'n' => lit(b, i, "null", Json::Null),
        _ => parse_num(b, i),
    }
}

fn lit(b: &[u8], i: &mut usize, word: &str, v: Json) -> Result<Json, String> {
    if b[*i..].starts_with(word.as_bytes()) {
        *i += word.len();
        Ok(v)
    } else {
        Err(format!("bad literal at byte {i}", i = *i))
    }
}

fn parse_num(b: &[u8], i: &mut usize) -> Result<Json, String> {
    let start = *i;
    while *i < b.len()
        && matches!(b[*i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *i += 1;
    }
    std::str::from_utf8(&b[start..*i])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or_else(|| format!("bad number at byte {start}"))
}

fn parse_string(b: &[u8], i: &mut usize) -> Result<String, String> {
    debug_assert_eq!(b[*i], b'"');
    *i += 1;
    let mut out = String::new();
    while *i < b.len() {
        match b[*i] {
            b'"' => {
                *i += 1;
                return Ok(out);
            }
            b'\\' => {
                *i += 1;
                if *i >= b.len() {
                    break;
                }
                match b[*i] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        let hex = std::str::from_utf8(&b[*i + 1..*i + 5])
                            .map_err(|_| "bad \\u")?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| "bad \\u hex")?;
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        *i += 4;
                    }
                    c => return Err(format!("bad escape \\{}", c as char)),
                }
                *i += 1;
            }
            _ => {
                // copy a full UTF-8 scalar
                let s = &b[*i..];
                let ch_len = utf8_len(s[0]);
                let ch = std::str::from_utf8(&s[..ch_len])
                    .map_err(|_| "bad utf8")?;
                out.push_str(ch);
                *i += ch_len;
            }
        }
    }
    Err("unterminated string".into())
}

fn utf8_len(b0: u8) -> usize {
    match b0 {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

fn parse_arr(b: &[u8], i: &mut usize) -> Result<Json, String> {
    *i += 1; // '['
    let mut arr = Vec::new();
    skip_ws(b, i);
    if *i < b.len() && b[*i] == b']' {
        *i += 1;
        return Ok(Json::Arr(arr));
    }
    loop {
        arr.push(parse_value(b, i)?);
        skip_ws(b, i);
        match b.get(*i) {
            Some(b',') => *i += 1,
            Some(b']') => {
                *i += 1;
                return Ok(Json::Arr(arr));
            }
            _ => return Err(format!("expected , or ] at byte {}", *i)),
        }
    }
}

fn parse_obj(b: &[u8], i: &mut usize) -> Result<Json, String> {
    *i += 1; // '{'
    let mut map = BTreeMap::new();
    skip_ws(b, i);
    if *i < b.len() && b[*i] == b'}' {
        *i += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, i);
        let key = parse_string(b, i)?;
        skip_ws(b, i);
        if b.get(*i) != Some(&b':') {
            return Err(format!("expected : at byte {}", *i));
        }
        *i += 1;
        map.insert(key, parse_value(b, i)?);
        skip_ws(b, i);
        match b.get(*i) {
            Some(b',') => *i += 1,
            Some(b'}') => {
                *i += 1;
                return Ok(Json::Obj(map));
            }
            _ => return Err(format!("expected , or }} at byte {}", *i)),
        }
    }
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                let _ = write!(out, "{}", *x as i64);
            } else {
                let _ = write!(out, "{x}");
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '\r' => out.push_str("\\r"),
                    c if (c as u32) < 0x20 => {
                        let _ = write!(out, "\\u{:04x}", c as u32);
                    }
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(&Json::Str(k.clone()), out);
                out.push(':');
                write_value(x, out);
            }
            out.push('}');
        }
    }
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.at("a").as_arr()[1], Json::Num(2.0));
        assert_eq!(j.at("a").as_arr()[2].at("b").as_str(), "c");
        assert!(j.at("d").is_null());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"config":{"batch":4,"name":"tiny32"},"xs":[1,2.5,true,null,"s\"q"]}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn parses_real_manifest_fragment() {
        let frag = r#"{
 "config": {"name": "micro", "vocab": 64, "moe": null},
 "params": [{"name": "tok_emb", "shape": [64, 16], "rotated": false}],
 "executables": {"fwdbwd": {"file": "fwdbwd.hlo.txt", "inputs": []}}
}"#;
        let j = Json::parse(frag).unwrap();
        assert_eq!(j.at("config").at("vocab").as_usize(), 64);
        assert!(j.at("config").at("moe").is_null());
        assert_eq!(j.at("params").as_arr()[0].at("shape").as_arr()[0].as_usize(), 64);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }
}
