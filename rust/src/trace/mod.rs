//! Dependency-free observability: per-worker span timelines flushed to
//! Chrome `trace_event` JSON (loadable in `chrome://tracing` or
//! Perfetto), plus the stderr progress-log layer the CLI routes
//! human-readable status lines through so stdout stays clean for
//! piped CSV/JSON.
//!
//! Span model: every engine worker owns a [`Recorder`] — a per-thread
//! buffer with no locks or atomics; spans are pushed by the owning
//! thread only and handed back to the driver when the thread joins.
//! The virtual-clock executor in `pipeline::schedule::simulate` emits
//! the same [`Span`] type (1 unit-cost slot = 1 ms of virtual time),
//! so model and wall-clock timelines are directly diffable.

use std::io::Write;
use std::path::Path;
use std::time::Instant;

/// What a span measures. `Idle` covers blocking channel receives and
/// the data-parallel all-reduce wait (`Reduce`), which is accounted
/// separately so DP sync cost is visible; everything else is busy time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    Fwd,
    Bwd,
    Update,
    Reduce,
    Idle,
    Checkpoint,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Fwd => "Fwd",
            SpanKind::Bwd => "Bwd",
            SpanKind::Update => "Update",
            SpanKind::Reduce => "Reduce",
            SpanKind::Idle => "Idle",
            SpanKind::Checkpoint => "Checkpoint",
        }
    }

    pub fn is_busy(self) -> bool {
        !matches!(self, SpanKind::Idle | SpanKind::Reduce)
    }
}

/// One closed interval on a worker's timeline. `chunk`/`mb` are -1 when
/// not applicable (e.g. idle waits), `step` is the optimizer update the
/// work belongs to, and `n_disp` counts runtime executable dispatches
/// performed inside the span (sums to `RunResult.dispatches` when eval
/// is off, since every dispatch happens inside some span).
#[derive(Clone, Debug)]
pub struct Span {
    pub kind: SpanKind,
    pub chunk: i64,
    pub mb: i64,
    pub step: i64,
    pub ts_us: f64,
    pub dur_us: f64,
    pub n_disp: u64,
}

impl serde::Serialize for Span {
    fn to_json(&self) -> String {
        format!(
            "{{\"kind\":\"{}\",\"chunk\":{},\"mb\":{},\"step\":{},\"ts_us\":{},\"dur_us\":{},\"n_disp\":{}}}",
            self.kind.name(),
            self.chunk,
            self.mb,
            self.step,
            crate::jsonio::num(self.ts_us).to_string(),
            crate::jsonio::num(self.dur_us).to_string(),
            self.n_disp
        )
    }
}

/// Per-thread span buffer. Owned by exactly one thread; push is a plain
/// `Vec::push` (no locks, no atomics). The shared `epoch` Instant is
/// captured once by the driver before spawning so all threads' `ts_us`
/// share an origin.
pub struct Recorder {
    epoch: Instant,
    spans: Vec<Span>,
}

impl Recorder {
    pub fn new(epoch: Instant) -> Recorder {
        Recorder { epoch, spans: Vec::new() }
    }

    /// Timestamp helper: callers grab `Instant::now()` themselves when
    /// they already measure (so span and metric share one clock read).
    pub fn now(&self) -> Instant {
        Instant::now()
    }

    /// Record a span that started at `t0` and ends now.
    pub fn push(&mut self, kind: SpanKind, chunk: i64, mb: i64, step: i64, t0: Instant, n_disp: u64) {
        let ts_us = t0.duration_since(self.epoch).as_secs_f64() * 1e6;
        let dur_us = t0.elapsed().as_secs_f64() * 1e6;
        self.spans.push(Span { kind, chunk, mb, step, ts_us, dur_us, n_disp });
    }

    /// Record a span with explicit (virtual-clock) timestamps in µs.
    pub fn push_virtual(&mut self, kind: SpanKind, chunk: i64, mb: i64, step: i64, ts_us: f64, dur_us: f64) {
        self.spans.push(Span { kind, chunk, mb, step, ts_us, dur_us, n_disp: 0 });
    }

    pub fn into_spans(self) -> Vec<Span> {
        self.spans
    }

    pub fn take_spans(&mut self) -> Vec<Span> {
        std::mem::take(&mut self.spans)
    }

    pub fn spans(&self) -> &[Span] {
        &self.spans
    }
}

/// One timeline row in the output: a (pid, tid) pair plus its spans.
/// The engine maps replica -> pid and worker -> tid; the virtual-clock
/// executor uses pid 0.
pub struct ThreadTrace {
    pub pid: u64,
    pub tid: u64,
    pub name: String,
    pub spans: Vec<Span>,
}

/// A full run's trace: every thread's spans plus process metadata,
/// writable as Chrome `trace_event` JSON.
#[derive(Default)]
pub struct Trace {
    pub threads: Vec<ThreadTrace>,
}

/// One Chrome `trace_event` entry ("X" = complete event). Serialized
/// with the vendored serde derive; field names match the trace_event
/// spec (`ph`, `ts`, `dur` in µs).
#[derive(serde::Serialize)]
struct Event {
    name: String,
    cat: String,
    ph: String,
    ts: f64,
    dur: f64,
    pid: u64,
    tid: u64,
    args: EventArgs,
}

#[derive(serde::Serialize)]
struct EventArgs {
    chunk: i64,
    mb: i64,
    step: i64,
    n_disp: u64,
}

impl Trace {
    pub fn push_thread(&mut self, pid: u64, tid: u64, name: impl Into<String>, spans: Vec<Span>) {
        self.threads.push(ThreadTrace { pid, tid, name: name.into(), spans });
    }

    /// Sum of busy (Fwd/Bwd/Update/Checkpoint) and idle (Idle/Reduce)
    /// span seconds per thread, in `threads` order.
    pub fn busy_idle(&self) -> Vec<(f64, f64)> {
        self.threads
            .iter()
            .map(|t| {
                let mut busy = 0.0;
                let mut idle = 0.0;
                for s in &t.spans {
                    if s.kind.is_busy() {
                        busy += s.dur_us / 1e6;
                    } else {
                        idle += s.dur_us / 1e6;
                    }
                }
                (busy, idle)
            })
            .collect()
    }

    /// Serialize to Chrome `trace_event` JSON (object form with a
    /// `traceEvents` array plus `thread_name` metadata events).
    pub fn to_chrome_json(&self) -> String {
        use serde::Serialize;
        let mut events: Vec<String> = Vec::new();
        for t in &self.threads {
            // thread_name metadata event ("M" phase) labels the row.
            events.push(format!(
                "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{},\"tid\":{},\"args\":{{\"name\":{}}}}}",
                t.pid,
                t.tid,
                t.name.to_json()
            ));
            for s in &t.spans {
                let ev = Event {
                    name: s.kind.name().to_string(),
                    cat: "abrot".to_string(),
                    ph: "X".to_string(),
                    ts: s.ts_us,
                    dur: s.dur_us,
                    pid: t.pid,
                    tid: t.tid,
                    args: EventArgs { chunk: s.chunk, mb: s.mb, step: s.step, n_disp: s.n_disp },
                };
                events.push(ev.to_json());
            }
        }
        format!(
            "{{\"displayTimeUnit\":\"ms\",\"traceEvents\":[{}]}}",
            events.join(",")
        )
    }

    pub fn write_chrome(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_chrome_json().as_bytes())?;
        Ok(())
    }
}

/// Append extra events (e.g. driver-side `Checkpoint` spans recorded
/// after per-segment traces were flushed) to an existing Chrome trace
/// file, re-parsing it with the in-crate JSON parser. Creates the file
/// if it does not exist.
pub fn append_events(path: impl AsRef<Path>, pid: u64, tid: u64, name: &str, spans: &[Span]) -> anyhow::Result<()> {
    let path = path.as_ref();
    let mut extra = Trace::default();
    extra.push_thread(pid, tid, name, spans.to_vec());
    if !path.exists() {
        return extra.write_chrome(path);
    }
    let text = std::fs::read_to_string(path)?;
    let parsed = crate::jsonio::Json::parse(&text).map_err(anyhow::Error::msg)?;
    let existing = parsed.at("traceEvents");
    let mut events: Vec<String> = existing.as_arr().iter().map(|e| e.to_string()).collect();
    let extra_json = extra.to_chrome_json();
    let extra_parsed = crate::jsonio::Json::parse(&extra_json).map_err(anyhow::Error::msg)?;
    for e in extra_parsed.at("traceEvents").as_arr() {
        events.push(e.to_string());
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(
        format!("{{\"displayTimeUnit\":\"ms\",\"traceEvents\":[{}]}}", events.join(",")).as_bytes(),
    )?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Progress log layer
// ---------------------------------------------------------------------------

/// Human-readable progress line on stderr. Everything that used to
/// `println!` status mid-run (`[ckpt] step …`, `[elastic] …`) routes
/// through here so stdout stays machine-parseable (piped CSV/JSON).
pub fn progress(msg: impl AsRef<str>) {
    eprintln!("{}", msg.as_ref());
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(kind: SpanKind, ts: f64, dur: f64) -> Span {
        Span { kind, chunk: 0, mb: 1, step: 2, ts_us: ts, dur_us: dur, n_disp: 3 }
    }

    #[test]
    fn trace_chrome_json_roundtrips_through_jsonio() {
        let mut tr = Trace::default();
        tr.push_thread(0, 1, "r0/w1", vec![span(SpanKind::Fwd, 10.0, 5.0), span(SpanKind::Idle, 15.0, 2.0)]);
        let json = tr.to_chrome_json();
        let parsed = crate::jsonio::Json::parse(&json).unwrap();
        let evs = parsed.at("traceEvents").as_arr();
        // 1 metadata + 2 spans
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].at("ph").as_str(), "M");
        assert_eq!(evs[1].at("name").as_str(), "Fwd");
        assert_eq!(evs[1].at("ph").as_str(), "X");
        assert!((evs[1].at("ts").as_f64() - 10.0).abs() < 1e-9);
        assert!((evs[1].at("dur").as_f64() - 5.0).abs() < 1e-9);
        assert_eq!(evs[1].at("args").at("mb").as_i64(), 1);
        assert_eq!(evs[1].at("args").at("n_disp").as_usize(), 3);
        assert_eq!(evs[2].at("name").as_str(), "Idle");
    }

    #[test]
    fn trace_busy_idle_split() {
        let mut tr = Trace::default();
        tr.push_thread(
            0,
            0,
            "w0",
            vec![
                span(SpanKind::Fwd, 0.0, 3e6),
                span(SpanKind::Idle, 3e6, 1e6),
                span(SpanKind::Reduce, 4e6, 1e6),
            ],
        );
        let bi = tr.busy_idle();
        assert_eq!(bi.len(), 1);
        assert!((bi[0].0 - 3.0).abs() < 1e-9);
        assert!((bi[0].1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn trace_append_events_merges() {
        let dir = std::env::temp_dir().join("abrot_trace_append");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.json");
        std::fs::remove_file(&p).ok();
        let mut tr = Trace::default();
        tr.push_thread(0, 0, "w0", vec![span(SpanKind::Fwd, 0.0, 1.0)]);
        tr.write_chrome(&p).unwrap();
        append_events(&p, 9, 9, "ckpt", &[span(SpanKind::Checkpoint, 5.0, 1.0)]).unwrap();
        let parsed = crate::jsonio::Json::parse(&std::fs::read_to_string(&p).unwrap()).unwrap();
        let evs = parsed.at("traceEvents").as_arr();
        // (meta + Fwd) + (meta + Checkpoint)
        assert_eq!(evs.len(), 4);
        assert_eq!(evs[3].at("name").as_str(), "Checkpoint");
        assert_eq!(evs[3].at("pid").as_usize(), 9);
    }

    #[test]
    fn trace_recorder_spans_are_ordered() {
        let epoch = Instant::now();
        let mut rec = Recorder::new(epoch);
        let t0 = rec.now();
        std::thread::sleep(std::time::Duration::from_millis(2));
        rec.push(SpanKind::Fwd, 0, 0, 1, t0, 4);
        let t1 = rec.now();
        rec.push(SpanKind::Idle, -1, -1, 1, t1, 0);
        let spans = rec.into_spans();
        assert_eq!(spans.len(), 2);
        assert!(spans[0].dur_us >= 1000.0);
        // second span starts at or after the first ends
        assert!(spans[1].ts_us >= spans[0].ts_us + spans[0].dur_us - 1.0);
    }
}
