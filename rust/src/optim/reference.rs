//! Independent Rust implementations of the rotated update and eigen
//! estimation — used by integration tests to cross-check the HLO/Pallas
//! path, and by the threaded pipeline engine (whose per-stage batch
//! counts don't match the full-model batched executables).

use crate::tensor::Tensor;

/// Scalars vector layout shared with the exported graphs:
/// [lr, beta1, beta2, eps, wd, t, mask, _]
#[derive(Clone, Copy, Debug)]
pub struct Scalars {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub wd: f32,
    pub t: f32,
}

impl Scalars {
    pub fn to_row(self, mask: f32) -> [f32; 8] {
        [self.lr, self.beta1, self.beta2, self.eps, self.wd, self.t, mask, 0.0]
    }
}

fn uni_left(m: usize, n: usize) -> bool {
    m <= n
}

fn rot(x: &Tensor, u: Option<&Tensor>, v: Option<&Tensor>) -> Tensor {
    let mut y = match u {
        Some(u) => u.transpose().matmul(x),
        None => x.clone(),
    };
    if let Some(v) = v {
        y = y.matmul(v);
    }
    y
}

fn unrot(x: &Tensor, u: Option<&Tensor>, v: Option<&Tensor>) -> Tensor {
    let mut y = match u {
        Some(u) => u.matmul(x),
        None => x.clone(),
    };
    if let Some(v) = v {
        y = y.matmul(&v.transpose());
    }
    y
}

fn pick_uv<'a>(
    u: &'a Tensor,
    v: &'a Tensor,
    unilateral: bool,
    shape: (usize, usize),
) -> (Option<&'a Tensor>, Option<&'a Tensor>) {
    if !unilateral {
        (Some(u), Some(v))
    } else if uni_left(shape.0, shape.1) {
        (Some(u), None)
    } else {
        (None, Some(v))
    }
}

/// One basis-rotation Adam step (paper Algorithm 1 lines 3–11).
/// Returns (w', m', vt').
#[allow(clippy::too_many_arguments)]
pub fn rotated_adam(
    w: &Tensor,
    g: &Tensor,
    m: &Tensor,
    vt: &Tensor,
    u: &Tensor,
    v: &Tensor,
    sc: Scalars,
    unilateral: bool,
) -> (Tensor, Tensor, Tensor) {
    let (mm, nn) = w.dims2();
    let m_new = m.scale(sc.beta1).add(&g.scale(1.0 - sc.beta1));
    let (uu, vv) = pick_uv(u, v, unilateral, (mm, nn));
    let g_rot = rot(g, uu, vv);
    let m_rot = rot(&m_new, uu, vv);
    let bc1 = 1.0 - sc.beta1.powf(sc.t);
    let bc2 = 1.0 - sc.beta2.powf(sc.t);
    let mut vt_new = vt.clone();
    let mut dir = g_rot.clone();
    for i in 0..vt_new.data.len() {
        let gr = g_rot.data[i];
        vt_new.data[i] = sc.beta2 * vt.data[i] + (1.0 - sc.beta2) * gr * gr;
        let mhat = m_rot.data[i] / bc1;
        let vhat = vt_new.data[i] / bc2;
        dir.data[i] = mhat / (vhat.sqrt() + sc.eps);
    }
    let upd = unrot(&dir, uu, vv);
    let mut w_new = w.clone();
    for i in 0..w_new.data.len() {
        w_new.data[i] -= sc.lr * (upd.data[i] + sc.wd * w.data[i]);
    }
    (w_new, m_new, vt_new)
}

/// SOAP variant: momentum accumulated in the rotated space.
#[allow(clippy::too_many_arguments)]
pub fn soap_update(
    w: &Tensor,
    g: &Tensor,
    m_rot_prev: &Tensor,
    vt: &Tensor,
    u: &Tensor,
    v: &Tensor,
    sc: Scalars,
    unilateral: bool,
) -> (Tensor, Tensor, Tensor) {
    let (mm, nn) = w.dims2();
    let (uu, vv) = pick_uv(u, v, unilateral, (mm, nn));
    let g_rot = rot(g, uu, vv);
    let m_new = m_rot_prev.scale(sc.beta1).add(&g_rot.scale(1.0 - sc.beta1));
    let bc1 = 1.0 - sc.beta1.powf(sc.t);
    let bc2 = 1.0 - sc.beta2.powf(sc.t);
    let mut vt_new = vt.clone();
    let mut dir = g_rot.clone();
    for i in 0..vt_new.data.len() {
        let gr = g_rot.data[i];
        vt_new.data[i] = sc.beta2 * vt.data[i] + (1.0 - sc.beta2) * gr * gr;
        let mhat = m_new.data[i] / bc1;
        let vhat = vt_new.data[i] / bc2;
        dir.data[i] = mhat / (vhat.sqrt() + sc.eps);
    }
    let upd = unrot(&dir, uu, vv);
    let mut w_new = w.clone();
    for i in 0..w_new.data.len() {
        w_new.data[i] -= sc.lr * (upd.data[i] + sc.wd * w.data[i]);
    }
    (w_new, m_new, vt_new)
}

/// CGS2 QR (Q factor) — mirrors `optim_graphs.cgs2_qr` exactly.
pub fn cgs2_qr(x: &Tensor) -> Tensor {
    let (n, k) = x.dims2();
    let mut q = Tensor::zeros(&[n, k]);
    for j in 0..k {
        let mut a: Vec<f32> = (0..n).map(|i| x.data[i * k + j]).collect();
        for _pass in 0..2 {
            // coeff = Qᵀ a (columns ≥ j are zero)
            let mut coeff = vec![0.0f32; k];
            for (i, &ai) in a.iter().enumerate() {
                let row = &q.data[i * k..(i + 1) * k];
                for (c, &qv) in coeff.iter_mut().zip(row) {
                    *c += qv * ai;
                }
            }
            for (i, ai) in a.iter_mut().enumerate() {
                let row = &q.data[i * k..(i + 1) * k];
                let mut proj = 0.0f32;
                for (c, &qv) in coeff.iter().zip(row) {
                    proj += c * qv;
                }
                *ai -= proj;
            }
        }
        let norm = a.iter().map(|x| x * x).sum::<f32>().sqrt() + 1e-30;
        for (i, &ai) in a.iter().enumerate() {
            q.data[i * k + j] = ai / norm;
        }
    }
    q
}

/// One power-iteration + QR step with the scale-aware ridge, matching
/// `optim_graphs.power_qr`.
pub fn power_qr(stat: &Tensor, basis: &Tensor) -> Tensor {
    let n = stat.shape[0];
    let trace: f32 = (0..n).map(|i| stat.data[i * n + i]).sum();
    let ridge = 1e-3 * trace / n as f32 + 1e-12;
    let mut x = stat.matmul(basis);
    x.axpy(ridge, basis);
    cgs2_qr(&x)
}

/// Newton–Schulz orthogonalization (Muon): 4 quintic + 4 cubic steps.
pub fn ns_orthonormalize(x: &Tensor) -> Tensor {
    let (m, n) = x.dims2();
    let transpose = m > n;
    let mut y = if transpose { x.transpose() } else { x.clone() };
    let norm = y.norm() + 1e-7;
    y = y.scale(1.0 / norm);
    const A: f32 = 3.4445;
    const B: f32 = -4.7750;
    const C: f32 = 2.0315;
    for _ in 0..4 {
        let s = y.matmul(&y.transpose());
        let s2 = s.matmul(&s);
        let poly = s.scale(B).add(&s2.scale(C));
        y = y.scale(A).add(&poly.matmul(&y));
    }
    for _ in 0..4 {
        let s = y.matmul(&y.transpose());
        y = y.scale(1.5).sub(&s.matmul(&y).scale(0.5));
    }
    if transpose {
        y.transpose()
    } else {
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngs::Rng;

    fn randn(rng: &mut Rng, shape: &[usize]) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, 1.0);
        t
    }

    fn orth(rng: &mut Rng, n: usize) -> Tensor {
        cgs2_qr(&randn(rng, &[n, n]))
    }

    #[test]
    fn cgs2_qr_orthonormal() {
        let mut rng = Rng::new(4);
        let x = randn(&mut rng, &[12, 12]);
        let q = cgs2_qr(&x);
        let qqt = q.matmul(&q.transpose());
        let err = qqt.sub(&Tensor::eye(12)).max_abs();
        assert!(err < 1e-4, "{err}");
    }

    #[test]
    fn power_qr_converges_to_eigenbasis() {
        let mut rng = Rng::new(5);
        let n = 10;
        let q0 = orth(&mut rng, n);
        // SPD with distinct spectrum
        let mut lam = Tensor::zeros(&[n, n]);
        for i in 0..n {
            lam.data[i * n + i] = 10.0 - i as f32;
        }
        let stat = q0.matmul(&lam).matmul(&q0.transpose());
        let mut u = orth(&mut rng, n);
        for _ in 0..80 {
            u = power_qr(&stat, &u);
        }
        let d = u.transpose().matmul(&stat).matmul(&u);
        let mut off = 0.0f32;
        let mut tot = 0.0f32;
        for i in 0..n {
            for j in 0..n {
                let v = d.data[i * n + j].abs();
                tot += v;
                if i != j {
                    off += v;
                }
            }
        }
        assert!(off / tot < 0.05, "off/tot {}", off / tot);
    }

    #[test]
    fn rotated_adam_identity_rotation_is_adam() {
        let mut rng = Rng::new(6);
        let (m, n) = (6, 8);
        let w = randn(&mut rng, &[m, n]);
        let g = randn(&mut rng, &[m, n]);
        let mom = Tensor::zeros(&[m, n]);
        let vt = Tensor::zeros(&[m, n]);
        let sc = Scalars { lr: 1e-2, beta1: 0.9, beta2: 0.999, eps: 1e-8, wd: 0.0, t: 1.0 };
        let (w1, _, _) = rotated_adam(&w, &g, &mom, &vt, &Tensor::eye(m),
                                      &Tensor::eye(n), sc, false);
        // first step == lr*sign(g)
        for i in 0..w1.data.len() {
            let step = w1.data[i] - w.data[i];
            assert!((step + 1e-2 * g.data[i].signum()).abs() < 1e-4);
        }
    }

    #[test]
    fn rotated_adam_equivariance() {
        // Rotating with any fixed orthogonal U,V and then projecting the
        // update back equals Adam run natively in the rotated space.
        let mut rng = Rng::new(7);
        let (m, n) = (6, 6);
        let w = randn(&mut rng, &[m, n]);
        let g = randn(&mut rng, &[m, n]);
        let u = orth(&mut rng, m);
        let v = orth(&mut rng, n);
        let sc = Scalars { lr: 1e-2, beta1: 0.9, beta2: 0.999, eps: 1e-8, wd: 0.0, t: 1.0 };
        let (w1, _, _) = rotated_adam(
            &w, &g, &Tensor::zeros(&[m, n]), &Tensor::zeros(&[m, n]), &u, &v,
            sc, false,
        );
        // native rotated-space Adam step
        let wr = u.transpose().matmul(&w).matmul(&v);
        let gr = u.transpose().matmul(&g).matmul(&v);
        let mut wr_new = wr.clone();
        for i in 0..wr.data.len() {
            let mhat = (1.0 - sc.beta1) * gr.data[i] / (1.0 - sc.beta1);
            let vhat = (1.0 - sc.beta2) * gr.data[i] * gr.data[i] / (1.0 - sc.beta2);
            wr_new.data[i] -= sc.lr * mhat / (vhat.sqrt() + sc.eps);
        }
        let back = u.matmul(&wr_new).matmul(&v.transpose());
        assert!(w1.sub(&back).max_abs() < 1e-4);
    }

    #[test]
    fn ns_orthonormalize_orthogonal() {
        let mut rng = Rng::new(8);
        let x = randn(&mut rng, &[8, 20]);
        let o = ns_orthonormalize(&x);
        let err = o.matmul(&o.transpose()).sub(&Tensor::eye(8)).max_abs();
        assert!(err < 1e-2, "{err}");
    }

    #[test]
    fn unilateral_side_matches_shape() {
        let mut rng = Rng::new(9);
        // wide matrix (m < n): left rotation only; V must be unused.
        let (m, n) = (4, 10);
        let w = randn(&mut rng, &[m, n]);
        let g = randn(&mut rng, &[m, n]);
        let u = orth(&mut rng, m);
        let v_garbage = Tensor::full(&[n, n], f32::NAN);
        let sc = Scalars { lr: 1e-2, beta1: 0.9, beta2: 0.999, eps: 1e-8, wd: 0.0, t: 1.0 };
        let (w1, _, _) = rotated_adam(
            &w, &g, &Tensor::zeros(&[m, n]), &Tensor::zeros(&[m, n]), &u,
            &v_garbage, sc, true,
        );
        assert!(w1.all_finite());
    }
}
