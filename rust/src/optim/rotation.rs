//! Basis rotation (paper Algorithms 1 & 2) and SOAP — the
//! backend-dispatched matrix optimizers.
//!
//! Rotated matrices are updated through the batched per-shape-class
//! executables (one dispatch per class per step) served by the
//! runtime's backend: native Rust reference kernels by default, or the
//! `aot.py`-exported HLO graphs whose hot path is the L1 Pallas kernels
//! under the `pjrt` feature. Everything that is not a rotated matrix
//! (embeddings, gains, head, MoE routers) falls back to the
//! element-wise Rust Adam, matching the paper's setup ("we only perform
//! rotation to the MLP and attention layers").
//!
//! Stage-aware frequency allocation (paper Fig. 9c/17) is expressed as
//! the per-slot `mask` scalar: the eigen executables always advance the
//! Fisher EMAs but refresh U/V only where mask = 1.

use anyhow::{bail, Result};

use crate::config::{stage_aware_freq, FreqAlloc, Geometry, Source, TrainCfg};
use crate::model::{class_maps, set_slot_matrix, slot_matrix, ClassMap};
use crate::runtime::{tensor_to_value, Runtime};
use crate::tensor::{stack, unstack, Tensor};

use super::{ElementAdam, OptSlice, OptState, Optimizer, StepCtx};

/// Per-class batched optimizer state.
struct ClassState {
    map: ClassMap,
    /// First moment: original space (basis rotation) or rotated space (SOAP).
    m: Tensor, // (NB, m, n)
    /// Second moment in the rotated space.
    vt: Tensor, // (NB, m, n)
    u: Tensor,  // (NB, m, m)
    v: Tensor,  // (NB, n, n)
    /// Fisher-factor EMAs (S = 2nd only).
    l: Option<Tensor>, // (NB, m, m)
    r: Option<Tensor>, // (NB, n, n)
    /// Per-slot basis refresh period.
    freqs: Vec<u32>,
}

pub struct BasisRotation {
    source: Source,
    geometry: Geometry,
    freq: u32,
    alloc: FreqAlloc,
    /// SOAP mode: momentum accumulated in the rotated space + basis
    /// refreshed *after* the parameter update (Appendix G).
    soap: bool,
    classes: Vec<ClassState>,
    fallback: ElementAdam,
    /// manifest indices of params handled by the fallback Adam.
    fallback_idx: Vec<usize>,
    /// cached count of eigen-executable dispatches (perf accounting).
    pub eigen_dispatches: u64,
}

impl BasisRotation {
    pub fn new(
        rt: &Runtime,
        cfg: &TrainCfg,
        source: Source,
        geometry: Geometry,
        freq: u32,
        alloc: FreqAlloc,
        soap: bool,
    ) -> Self {
        let man = &rt.manifest;
        let maps = class_maps(man);
        let part = crate::model::StagePartition::new(man, cfg.stages);
        let classes = maps
            .into_iter()
            .map(|map| {
                let (nb, m, n) = (map.class.count, map.class.m, map.class.n);
                let eye_m = Tensor::eye(m);
                let eye_n = Tensor::eye(n);
                let u = stack(&vec![&eye_m; nb]);
                let v = stack(&vec![&eye_n; nb]);
                let (l, r) = if source == Source::Second {
                    (Some(Tensor::zeros(&[nb, m, m])), Some(Tensor::zeros(&[nb, n, n])))
                } else {
                    (None, None)
                };
                let freqs = map
                    .slots
                    .iter()
                    .map(|s| {
                        let delay = part.delay_of[s.param];
                        match alloc {
                            FreqAlloc::Uniform => freq,
                            FreqAlloc::StageAware => {
                                stage_aware_freq(freq, delay, cfg.stages)
                            }
                            FreqAlloc::InverseStageAware => stage_aware_freq(
                                freq,
                                part.max_delay() - delay,
                                cfg.stages,
                            ),
                        }
                    })
                    .collect();
                ClassState {
                    m: Tensor::zeros(&[nb, m, n]),
                    vt: Tensor::zeros(&[nb, m, n]),
                    u,
                    v,
                    l,
                    r,
                    freqs,
                    map,
                }
            })
            .collect();
        // fallback params: everything not covered by a rotated class
        let fallback_idx = super::fallback_indices(man);
        let shapes: Vec<Vec<usize>> =
            fallback_idx.iter().map(|&i| man.params[i].shape.clone()).collect();
        BasisRotation {
            source,
            geometry,
            freq,
            alloc,
            soap,
            classes,
            fallback: ElementAdam::new(&shapes),
            fallback_idx,
            eigen_dispatches: 0,
        }
    }

    fn geo_tag(&self) -> &'static str {
        match self.geometry {
            Geometry::Unilateral => "uni",
            Geometry::Bilateral => "bi",
        }
    }

    fn scalars_stack(&self, cs: &ClassState, ctx: &StepCtx, masks: &[f32]) -> Tensor {
        let nb = cs.map.class.count;
        let mut sc = Tensor::zeros(&[nb, 8]);
        for (i, s) in cs.map.slots.iter().enumerate() {
            let row = [
                ctx.lr_for(s.param),
                ctx.cfg.beta1,
                ctx.cfg.beta2,
                ctx.cfg.eps,
                ctx.cfg.weight_decay,
                ctx.t as f32,
                masks[i],
                0.0,
            ];
            sc.data[i * 8..(i + 1) * 8].copy_from_slice(&row);
        }
        sc
    }

    /// Refresh bases for slots whose mask=1 via the eigen executables.
    fn eigen_step(
        &mut self,
        ci: usize,
        ctx: &StepCtx,
        g_stack: &Tensor,
        masks: &[f32],
    ) -> Result<()> {
        if masks.iter().all(|&m| m == 0.0) && self.source == Source::First {
            return Ok(()); // S=1st has no EMA state to advance
        }
        let cs = &self.classes[ci];
        let cls = cs.map.class.name.clone();
        let tag = self.geo_tag();
        let sc = self.scalars_stack(cs, ctx, masks);
        match self.source {
            Source::Second => {
                let name = format!("eigen2nd_{tag}_{cls}");
                let cs = &mut self.classes[ci];
                let inputs = vec![
                    tensor_to_value(cs.l.as_ref().unwrap())?,
                    tensor_to_value(cs.r.as_ref().unwrap())?,
                    tensor_to_value(g_stack)?,
                    tensor_to_value(&cs.u)?,
                    tensor_to_value(&cs.v)?,
                    tensor_to_value(&sc)?,
                ];
                let outs = ctx.rt.exec_tensors(&name, &inputs)?;
                cs.l = Some(outs[0].clone());
                cs.r = Some(outs[1].clone());
                cs.u = outs[2].clone();
                cs.v = outs[3].clone();
            }
            Source::First => {
                // Algorithm 1 line 6 passes the *updated* momentum M_t;
                // compute it here (cheap, element-wise) — the rot_adam
                // executable recomputes the identical update internally.
                let cs = &mut self.classes[ci];
                let b1 = ctx.cfg.beta1;
                let mut m_upd = cs.m.clone();
                for (mi, &gi) in m_upd.data.iter_mut().zip(&g_stack.data) {
                    *mi = b1 * *mi + (1.0 - b1) * gi;
                }
                let name = format!("eigen1st_{tag}_{cls}");
                let inputs = vec![
                    tensor_to_value(&m_upd)?,
                    tensor_to_value(&cs.u)?,
                    tensor_to_value(&cs.v)?,
                    tensor_to_value(&sc)?,
                ];
                let outs = ctx.rt.exec_tensors(&name, &inputs)?;
                cs.u = outs[0].clone();
                cs.v = outs[1].clone();
            }
        }
        self.eigen_dispatches += 1;
        Ok(())
    }
}

impl Optimizer for BasisRotation {
    fn step(&mut self, ctx: &StepCtx, params: &mut [Tensor], grads: &[Tensor])
        -> Result<()> {
        // 1. Non-rotated params: plain element-wise Adam.
        for (slot, &pi) in self.fallback_idx.clone().iter().enumerate() {
            self.fallback.update(
                slot,
                &mut params[pi],
                &grads[pi],
                ctx.lr_for(pi),
                ctx.cfg.beta1,
                ctx.cfg.beta2,
                ctx.cfg.eps,
                ctx.cfg.weight_decay,
                ctx.t,
                false,
            );
        }

        // 2. Rotated classes: eigen refresh (Alg. 2) + rotated update
        //    (Alg. 1) through the batched executables.
        for ci in 0..self.classes.len() {
            let (g_stack, masks, cls_name, tag) = {
                let cs = &self.classes[ci];
                let mats: Vec<Tensor> = cs
                    .map
                    .slots
                    .iter()
                    .map(|s| {
                        let mut g = slot_matrix(grads, s);
                        g.shape = vec![cs.map.class.m, cs.map.class.n];
                        g
                    })
                    .collect();
                let refs: Vec<&Tensor> = mats.iter().collect();
                let g_stack = stack(&refs);
                // Refresh on t = 1, f+1, 2f+1, ... : the *first* step
                // already leaves the identity basis (Algorithm 2 line 1
                // initializes from the first gradient); `t % f == 0`
                // would sit on the identity for the first f-1 steps.
                let masks: Vec<f32> = cs
                    .freqs
                    .iter()
                    .map(|&f| {
                        if f == 1 || ctx.t % f as u64 == 1 { 1.0 } else { 0.0 }
                    })
                    .collect();
                (g_stack, masks, cs.map.class.name.clone(), self.geo_tag())
            };

            // Basis rotation refreshes the basis *before* the update
            // (Alg. 1 line 5); SOAP refreshes after (Appendix G).
            let refresh_now = masks.iter().any(|&m| m == 1.0)
                || self.source == Source::Second; // EMAs advance every step
            if !self.soap && refresh_now {
                self.eigen_step(ci, ctx, &g_stack, &masks)?;
            }

            {
                let cs = &self.classes[ci];
                let exec = if self.soap {
                    format!("soap_{tag}_{cls_name}")
                } else {
                    format!("rot_adam_{tag}_{cls_name}")
                };
                let w_mats: Vec<Tensor> = cs
                    .map
                    .slots
                    .iter()
                    .map(|s| {
                        let mut w = slot_matrix(params, s);
                        w.shape = vec![cs.map.class.m, cs.map.class.n];
                        w
                    })
                    .collect();
                let refs: Vec<&Tensor> = w_mats.iter().collect();
                let w_stack = stack(&refs);
                let sc = self.scalars_stack(cs, ctx, &masks);
                let inputs = vec![
                    tensor_to_value(&w_stack)?,
                    tensor_to_value(&g_stack)?,
                    tensor_to_value(&cs.m)?,
                    tensor_to_value(&cs.vt)?,
                    tensor_to_value(&cs.u)?,
                    tensor_to_value(&cs.v)?,
                    tensor_to_value(&sc)?,
                ];
                let outs = ctx.rt.exec_tensors(&exec, &inputs)?;
                let w_new = unstack(&outs[0]);
                let cs = &mut self.classes[ci];
                cs.m = outs[1].clone();
                cs.vt = outs[2].clone();
                for (s, w) in cs.map.slots.iter().zip(&w_new) {
                    let mut w = w.clone();
                    if params[s.param].rank() == 3 {
                        // expert slot
                        set_slot_matrix(params, s, &w);
                    } else {
                        w.shape = params[s.param].shape.clone();
                        params[s.param] = w;
                    }
                }
            }

            if self.soap && refresh_now {
                self.eigen_step(ci, ctx, &g_stack, &masks)?;
            }
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        if self.soap { "soap" } else { "basis_rotation" }
    }

    fn state_elems(&self) -> usize {
        let mut total = self.fallback.state_elems();
        for cs in &self.classes {
            total += cs.m.len() + cs.vt.len() + cs.u.len() + cs.v.len();
            if let Some(l) = &cs.l {
                total += l.len();
            }
            if let Some(r) = &cs.r {
                total += r.len();
            }
        }
        total
    }

    // Everything live is exported: per-class moments, bases and Fisher
    // EMAs, the fallback Adam moments, and the dispatch counter. The
    // per-slot refresh periods (`freqs`) are *not* state — `new()`
    // rebuilds them deterministically from the config.
    fn state_export(&self) -> Result<OptState> {
        let mut slices = Vec::new();
        for cs in &self.classes {
            let cls = &cs.map.class.name;
            slices.push(OptSlice::of(format!("cls:{cls}:m"), &cs.m));
            slices.push(OptSlice::of(format!("cls:{cls}:vt"), &cs.vt));
            slices.push(OptSlice::of(format!("cls:{cls}:u"), &cs.u));
            slices.push(OptSlice::of(format!("cls:{cls}:v"), &cs.v));
            if let Some(l) = &cs.l {
                slices.push(OptSlice::of(format!("cls:{cls}:l"), l));
            }
            if let Some(r) = &cs.r {
                slices.push(OptSlice::of(format!("cls:{cls}:r"), r));
            }
        }
        self.fallback.export_slices("fb:", &mut slices);
        Ok(OptState {
            kind: self.name().to_string(),
            slices,
            counters: vec![("eigen_dispatches".to_string(), self.eigen_dispatches)],
        })
    }

    fn state_import(&mut self, state: &OptState) -> Result<()> {
        if state.kind != self.name() {
            bail!(
                "optimizer state kind {:?} does not match live {:?}",
                state.kind, self.name()
            );
        }
        for cs in self.classes.iter_mut() {
            let cls = cs.map.class.name.clone();
            state.slice(&format!("cls:{cls}:m"))?.restore(&mut cs.m)?;
            state.slice(&format!("cls:{cls}:vt"))?.restore(&mut cs.vt)?;
            state.slice(&format!("cls:{cls}:u"))?.restore(&mut cs.u)?;
            state.slice(&format!("cls:{cls}:v"))?.restore(&mut cs.v)?;
            if let Some(l) = cs.l.as_mut() {
                state.slice(&format!("cls:{cls}:l"))?.restore(l)?;
            }
            if let Some(r) = cs.r.as_mut() {
                state.slice(&format!("cls:{cls}:r"))?.restore(r)?;
            }
        }
        self.fallback.import_slices("fb:", state)?;
        self.eigen_dispatches = state.counter("eigen_dispatches")?;
        Ok(())
    }
}

/// Memory overhead (in f32 elements) of one (m,n) matrix for each
/// strategy — Table 2 of the paper (Appendix H).
pub fn rotation_overhead_elems(
    m: usize,
    n: usize,
    source: Source,
    geometry: Geometry,
) -> usize {
    let rot = match geometry {
        Geometry::Bilateral => m * m + n * n,
        Geometry::Unilateral => m.min(n) * m.min(n),
    };
    let moments = match source {
        Source::Second => rot,
        Source::First => 0,
    };
    rot + moments
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{init_params, StagePartition};
    use crate::runtime::Runtime;

    /// Step a fresh S=1st BasisRotation `steps` times on a micro
    /// runtime and return eigen_dispatches after each step.
    fn eigen_dispatch_trace(freq: u32, steps: u64) -> Vec<u64> {
        let rt = Runtime::native("micro").unwrap();
        let cfg = TrainCfg { method: crate::config::Method::BasisRotation {
            source: Source::First,
            geometry: Geometry::Bilateral,
            freq,
            alloc: FreqAlloc::Uniform,
        }, ..Default::default() };
        let part = StagePartition::new(&rt.manifest, 1);
        let mut opt = BasisRotation::new(
            &rt, &cfg, Source::First, Geometry::Bilateral, freq,
            FreqAlloc::Uniform, false,
        );
        let mut params = init_params(&rt.manifest, 2);
        let grads: Vec<crate::tensor::Tensor> = params
            .iter()
            .map(|p| {
                crate::tensor::Tensor::new(
                    p.shape.clone(),
                    p.data.iter().map(|x| 0.1 * x + 0.01).collect(),
                )
            })
            .collect();
        let mut trace = Vec::new();
        for t in 1..=steps {
            let ctx = StepCtx {
                t,
                lr: cfg.lr_at(t as u32),
                cfg: &cfg,
                part: &part,
                stale: None,
                rt: &rt,
            };
            opt.step(&ctx, &mut params, &grads).unwrap();
            trace.push(opt.eigen_dispatches);
        }
        trace
    }

    #[test]
    fn basis_refresh_happens_on_first_step_then_every_freq() {
        // micro has 4 shape classes; S=1st dispatches eigen executables
        // only on refresh steps. freq=3 over 7 steps must refresh at
        // t = 1, 4, 7 — never t = 3 (the old `t % f == 0` off-by-one
        // left the first f-1 steps on the identity basis).
        let trace = eigen_dispatch_trace(3, 7);
        assert_eq!(trace, vec![4, 4, 4, 8, 8, 8, 12]);
        // freq=1 refreshes every step
        let every = eigen_dispatch_trace(1, 3);
        assert_eq!(every, vec![4, 8, 12]);
    }

    #[test]
    fn overhead_matches_table2_formulas() {
        // Llama-3-8B attention (4096x4096) and MLP (4096x14336), FP32 GB.
        let gb = |e: usize| e as f64 * 4.0 / 1e9;
        let attn = |s, g| gb(rotation_overhead_elems(4096, 4096, s, g));
        let mlp = |s, g| gb(rotation_overhead_elems(4096, 14336, s, g));
        use Geometry::*;
        use Source::*;
        assert!((attn(Second, Bilateral) - 0.268).abs() < 0.02);
        assert!((mlp(Second, Bilateral) - 1.78).abs() < 0.15);
        assert!((attn(Second, Unilateral) - 0.134).abs() < 0.01);
        assert!((mlp(First, Unilateral) - 0.067).abs() < 0.01);
        // orderings from the paper's Table 2
        assert!(attn(First, Bilateral) < attn(Second, Bilateral));
        assert!(mlp(Second, Unilateral) < mlp(Second, Bilateral));
    }
}
