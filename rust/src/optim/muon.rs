//! Muon (Jordan et al. 2024) and Scion (Pethick et al. 2025) — the
//! non-rotating preconditioned comparators of the paper's Table 3.
//!
//! Both orthogonalize a momentum buffer with Newton–Schulz via the
//! batched `muon_<class>` executables (native reference kernels, or
//! Pallas-bearing HLO under the `pjrt` feature) and apply
//! it with a spectral-scaled step; embeddings/gains/head fall back to
//! element-wise Adam (Muon's own convention) or sign-descent LMO
//! (Scion's ℓ∞ ball for non-matrix params).

use anyhow::{bail, Result};

use crate::model::{class_maps, set_slot_matrix, slot_matrix, ClassMap};
use crate::runtime::{tensor_to_value, Runtime};
use crate::tensor::{stack, unstack, Tensor};

use super::{ElementAdam, OptSlice, OptState, Optimizer, StepCtx};

const MUON_BETA: f32 = 0.95;
/// Keller Jordan's lr scale: 0.2·sqrt(max(m,n)) relative to the Adam lr.
const MUON_SCALE: f32 = 0.2;

struct MuonClass {
    map: ClassMap,
    mom: Tensor, // (NB, m, n)
}

pub struct Muon {
    classes: Vec<MuonClass>,
    fallback: ElementAdam,
    fallback_idx: Vec<usize>,
    /// Scion mode: norm-constrained LMO — spectral ball for matrices,
    /// ℓ∞ (sign) ball for the fallback params; no Adam state there.
    scion: bool,
}

impl Muon {
    pub fn new(rt: &Runtime, scion: bool) -> Self {
        let man = &rt.manifest;
        let maps = class_maps(man);
        let classes = maps
            .into_iter()
            .map(|map| {
                let (nb, m, n) = (map.class.count, map.class.m, map.class.n);
                MuonClass { mom: Tensor::zeros(&[nb, m, n]), map }
            })
            .collect();
        let fallback_idx = super::fallback_indices(man);
        let shapes: Vec<Vec<usize>> =
            fallback_idx.iter().map(|&i| man.params[i].shape.clone()).collect();
        Muon { classes, fallback: ElementAdam::new(&shapes), fallback_idx, scion }
    }
}

impl Optimizer for Muon {
    fn step(&mut self, ctx: &StepCtx, params: &mut [Tensor], grads: &[Tensor])
        -> Result<()> {
        // Fallback params.
        for (slot, &pi) in self.fallback_idx.clone().iter().enumerate() {
            if self.scion {
                // ℓ∞-ball LMO: sign descent on the momentum.
                let b1 = MUON_BETA;
                let m = &mut self.fallback.m[slot];
                for ((wi, &gi), mi) in params[pi]
                    .data
                    .iter_mut()
                    .zip(&grads[pi].data)
                    .zip(m.data.iter_mut())
                {
                    *mi = b1 * *mi + (1.0 - b1) * gi;
                    *wi -= ctx.lr_for(pi) * mi.signum();
                }
            } else {
                self.fallback.update(
                    slot,
                    &mut params[pi],
                    &grads[pi],
                    ctx.lr_for(pi),
                    ctx.cfg.beta1,
                    ctx.cfg.beta2,
                    ctx.cfg.eps,
                    ctx.cfg.weight_decay,
                    ctx.t,
                    false,
                );
            }
        }

        // Matrix classes: one batched NS-orthogonalization per class.
        for cs in self.classes.iter_mut() {
            let (m_dim, n_dim) = (cs.map.class.m, cs.map.class.n);
            let mats: Vec<Tensor> = cs
                .map
                .slots
                .iter()
                .map(|s| {
                    let mut g = slot_matrix(grads, s);
                    g.shape = vec![m_dim, n_dim];
                    g
                })
                .collect();
            let refs: Vec<&Tensor> = mats.iter().collect();
            let g_stack = stack(&refs);
            let nb = cs.map.class.count;
            let mut sc = Tensor::zeros(&[nb, 8]);
            for i in 0..nb {
                sc.data[i * 8 + 1] = MUON_BETA;
            }
            let name = format!("muon_{}", cs.map.class.name);
            let inputs = vec![
                tensor_to_value(&cs.mom)?,
                tensor_to_value(&g_stack)?,
                tensor_to_value(&sc)?,
            ];
            let outs = ctx.rt.exec_tensors(&name, &inputs)?;
            cs.mom = outs[0].clone();
            let orth = unstack(&outs[1]);
            // Spectral scale: Muon uses 0.2·sqrt(max(m,n)); Scion's
            // spectral-ball LMO radius is equivalent up to the constant.
            let scale = MUON_SCALE * (m_dim.max(n_dim) as f32).sqrt();
            for (s, o) in cs.map.slots.iter().zip(&orth) {
                let lr = ctx.lr_for(s.param) * scale;
                let mut w = slot_matrix(params, s);
                let wd = if self.scion { 0.0 } else { ctx.cfg.weight_decay };
                for (wi, &oi) in w.data.iter_mut().zip(&o.data) {
                    *wi -= lr * (oi + wd * *wi);
                }
                if params[s.param].rank() == 3 {
                    set_slot_matrix(params, s, &w);
                } else {
                    w.shape = params[s.param].shape.clone();
                    params[s.param] = w;
                }
            }
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        if self.scion { "scion" } else { "muon" }
    }

    fn state_elems(&self) -> usize {
        let mats: usize = self.classes.iter().map(|c| c.mom.len()).sum();
        mats + self.fallback.state_elems()
    }

    // Scion's fallback only uses the first-moment buffer (sign
    // descent); exporting/restoring the untouched v tensors as well is
    // harmless and keeps one code path for both modes.
    fn state_export(&self) -> Result<OptState> {
        let mut slices = Vec::new();
        for cs in &self.classes {
            slices
                .push(OptSlice::of(format!("cls:{}:mom", cs.map.class.name), &cs.mom));
        }
        self.fallback.export_slices("fb:", &mut slices);
        Ok(OptState {
            kind: self.name().to_string(),
            slices,
            counters: Vec::new(),
        })
    }

    fn state_import(&mut self, state: &OptState) -> Result<()> {
        if state.kind != self.name() {
            bail!(
                "optimizer state kind {:?} does not match live {:?}",
                state.kind, self.name()
            );
        }
        for cs in self.classes.iter_mut() {
            state
                .slice(&format!("cls:{}:mom", cs.map.class.name))?
                .restore(&mut cs.mom)?;
        }
        self.fallback.import_slices("fb:", state)
    }
}
