//! Per-stage optimizers: the paper's baselines (PipeDream Adam,
//! PipeDream-LR, Nesterov, Delay Compensation), the paper's contribution
//! (basis rotation, in `rotation`), and the preconditioned comparators
//! of Table 3 (SOAP in `rotation`, Muon/Scion in `muon`).
//!
//! Element-wise methods run natively in Rust; matrix-rotation methods
//! dispatch the batched HLO executables whose hot path is the L1 Pallas
//! kernels. `reference` holds independent Rust implementations of the
//! rotated update used by integration tests to cross-check the HLO path.

pub mod muon;
pub mod reference;
pub mod rotation;

use anyhow::{anyhow, bail, Result};

use crate::config::{pipedream_lr_scale, Method, TrainCfg};
use crate::model::StagePartition;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

/// Everything an optimizer may need for one step.
pub struct StepCtx<'a> {
    /// 1-based step count.
    pub t: u64,
    /// Scheduled base learning rate for this step.
    pub lr: f32,
    pub cfg: &'a TrainCfg,
    pub part: &'a StagePartition,
    /// The (stale) weights the gradients were computed at — needed by
    /// Delay Compensation's Taylor correction.
    pub stale: Option<&'a [Tensor]>,
    pub rt: &'a Runtime,
}

impl StepCtx<'_> {
    /// Per-parameter LR: PipeDream-LR rescales by the stage delay.
    pub fn lr_for(&self, param_idx: usize) -> f32 {
        match self.cfg.method {
            Method::PipeDreamLr => {
                self.lr * pipedream_lr_scale(self.part.delay_of[param_idx])
            }
            _ => self.lr,
        }
    }
}

/// One named tensor of optimizer state (shape + flattened f32 data).
#[derive(Clone, serde::Serialize, serde::Deserialize)]
pub struct OptSlice {
    pub key: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl OptSlice {
    pub fn of(key: impl Into<String>, t: &Tensor) -> OptSlice {
        OptSlice { key: key.into(), shape: t.shape.clone(), data: t.data.clone() }
    }

    /// Copy this slice's data into a live tensor of the same shape.
    pub fn restore(&self, t: &mut Tensor) -> Result<()> {
        if self.shape != t.shape {
            bail!(
                "state slice {:?}: snapshot shape {:?} does not match live {:?}",
                self.key, self.shape, t.shape
            );
        }
        t.data.clone_from(&self.data);
        Ok(())
    }
}

/// Portable snapshot of one optimizer's full internal state
/// ([`Optimizer::state_export`] / [`Optimizer::state_import`]).
///
/// Keys are flat strings namespaced by the owning optimizer (e.g.
/// `m:3` for ElementAdam moment of param 3, `cls:attn_qk:u` for a
/// rotation-class basis, `fb:v:0` for a matrix method's fallback Adam).
#[derive(Clone, serde::Serialize, serde::Deserialize)]
pub struct OptState {
    /// `Optimizer::name()` of the exporter; import validates it.
    pub kind: String,
    pub slices: Vec<OptSlice>,
    /// Scalar counters (e.g. `eigen_dispatches`) carried alongside.
    pub counters: Vec<(String, u64)>,
}

impl OptState {
    pub fn slice(&self, key: &str) -> Result<&OptSlice> {
        self.slices
            .iter()
            .find(|s| s.key == key)
            .ok_or_else(|| anyhow!("missing optimizer state slice {key:?}"))
    }

    pub fn counter(&self, key: &str) -> Result<u64> {
        self.counters
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| *v)
            .ok_or_else(|| anyhow!("missing optimizer state counter {key:?}"))
    }

    /// Total f32 elements captured (cross-check against `state_elems`).
    pub fn elems(&self) -> usize {
        self.slices.iter().map(|s| s.data.len()).sum()
    }
}

pub trait Optimizer {
    fn step(&mut self, ctx: &StepCtx, params: &mut [Tensor], grads: &[Tensor])
        -> Result<()>;
    fn name(&self) -> &'static str;
    /// Optimizer-state memory in f32 elements (Table 2 accounting).
    fn state_elems(&self) -> usize;

    /// Export the full internal state as a portable snapshot
    /// (checkpoint/resume). Defaults to a loud error so new optimizers
    /// cannot silently checkpoint nothing.
    fn state_export(&self) -> Result<OptState> {
        Err(anyhow!("{}: optimizer state export not implemented", self.name()))
    }

    /// Restore internal state from a snapshot made by `state_export`
    /// on an identically-configured optimizer.
    fn state_import(&mut self, _state: &OptState) -> Result<()> {
        Err(anyhow!("{}: optimizer state import not implemented", self.name()))
    }
}

/// Manifest indices of the parameters *not* covered by any rotated
/// shape class — the ones the matrix optimizers (BasisRotation, SOAP,
/// Muon, Scion) hand to their element-wise fallback.
pub fn fallback_indices(man: &crate::runtime::Manifest) -> Vec<usize> {
    let mut covered = vec![false; man.params.len()];
    for cm in &crate::model::class_maps(man) {
        for s in &cm.slots {
            covered[s.param] = true;
        }
    }
    (0..man.params.len()).filter(|&i| !covered[i]).collect()
}

/// Construct the optimizer for a method.
///
/// Works on a full-model runtime (the simulator) and on a stage-local
/// one (`Runtime::restricted`, the threaded engine): every optimizer
/// sizes its state from `rt.manifest`, so a restricted manifest yields
/// a stage-local optimizer over exactly the stage-resident parameters.
pub fn build(method: &Method, rt: &Runtime, cfg: &TrainCfg) -> Box<dyn Optimizer> {
    match method {
        Method::PipeDream | Method::PipeDreamLr => {
            Box::new(Adam::new(&rt.manifest, false))
        }
        Method::Nesterov => Box::new(Adam::new(&rt.manifest, true)),
        Method::DelayComp { lambda } => {
            Box::new(DelayComp::new(&rt.manifest, *lambda))
        }
        Method::BasisRotation { source, geometry, freq, alloc } => Box::new(
            rotation::BasisRotation::new(rt, cfg, *source, *geometry, *freq,
                                         *alloc, false),
        ),
        Method::Soap { freq } => Box::new(rotation::BasisRotation::new(
            rt,
            cfg,
            crate::config::Source::Second,
            crate::config::Geometry::Bilateral,
            *freq,
            crate::config::FreqAlloc::Uniform,
            true,
        )),
        Method::Muon => Box::new(muon::Muon::new(rt, false)),
        Method::Scion => Box::new(muon::Muon::new(rt, true)),
    }
}

// ---------------------------------------------------------------------------
// Element-wise Adam core (shared by several methods)
// ---------------------------------------------------------------------------

/// Fused element-wise Adam state/update for a set of parameters.
pub struct ElementAdam {
    pub m: Vec<Tensor>,
    pub v: Vec<Tensor>,
}

impl ElementAdam {
    pub fn new(shapes: &[Vec<usize>]) -> Self {
        ElementAdam {
            m: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
            v: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
        }
    }

    /// One Adam step on slot `i`. `nesterov` applies the momentum
    /// lookahead of Ajanthan et al. 2025 (NAdam-style numerator).
    #[allow(clippy::too_many_arguments)]
    pub fn update(
        &mut self,
        i: usize,
        w: &mut Tensor,
        g: &Tensor,
        lr: f32,
        b1: f32,
        b2: f32,
        eps: f32,
        wd: f32,
        t: u64,
        nesterov: bool,
    ) {
        let bc1 = 1.0 - b1.powi(t as i32);
        let bc2 = 1.0 - b2.powi(t as i32);
        let m = &mut self.m[i].data;
        let v = &mut self.v[i].data;
        for ((wi, &gi), (mi, vi)) in
            w.data.iter_mut().zip(&g.data).zip(m.iter_mut().zip(v.iter_mut()))
        {
            *mi = b1 * *mi + (1.0 - b1) * gi;
            *vi = b2 * *vi + (1.0 - b2) * gi * gi;
            let num = if nesterov {
                // Nesterov lookahead: β1·m_t + (1-β1)·g_t
                b1 * *mi + (1.0 - b1) * gi
            } else {
                *mi
            };
            let mhat = num / bc1;
            let vhat = *vi / bc2;
            *wi -= lr * (mhat / (vhat.sqrt() + eps) + wd * *wi);
        }
    }

    pub fn state_elems(&self) -> usize {
        self.m.iter().map(|t| t.len()).sum::<usize>() * 2
    }

    /// Append the moment tensors as `{prefix}m:{i}` / `{prefix}v:{i}`
    /// slices (the namespacing used by every method's state export).
    pub fn export_slices(&self, prefix: &str, out: &mut Vec<OptSlice>) {
        for (i, t) in self.m.iter().enumerate() {
            out.push(OptSlice::of(format!("{prefix}m:{i}"), t));
        }
        for (i, t) in self.v.iter().enumerate() {
            out.push(OptSlice::of(format!("{prefix}v:{i}"), t));
        }
    }

    /// Restore from slices written by [`Self::export_slices`] with the
    /// same prefix.
    pub fn import_slices(&mut self, prefix: &str, st: &OptState) -> Result<()> {
        for (i, t) in self.m.iter_mut().enumerate() {
            st.slice(&format!("{prefix}m:{i}"))?.restore(t)?;
        }
        for (i, t) in self.v.iter_mut().enumerate() {
            st.slice(&format!("{prefix}v:{i}"))?.restore(t)?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Adam (PipeDream / PipeDream-LR / Nesterov)
// ---------------------------------------------------------------------------

pub struct Adam {
    inner: ElementAdam,
    nesterov: bool,
}

impl Adam {
    pub fn new(man: &crate::runtime::Manifest, nesterov: bool) -> Self {
        let shapes: Vec<Vec<usize>> =
            man.params.iter().map(|p| p.shape.clone()).collect();
        Adam { inner: ElementAdam::new(&shapes), nesterov }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, ctx: &StepCtx, params: &mut [Tensor], grads: &[Tensor])
        -> Result<()> {
        let b1 = ctx.cfg.effective_beta1();
        for i in 0..params.len() {
            self.inner.update(
                i,
                &mut params[i],
                &grads[i],
                ctx.lr_for(i),
                b1,
                ctx.cfg.beta2,
                ctx.cfg.eps,
                ctx.cfg.weight_decay,
                ctx.t,
                self.nesterov,
            );
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        if self.nesterov { "nesterov" } else { "adam" }
    }

    fn state_elems(&self) -> usize {
        self.inner.state_elems()
    }

    fn state_export(&self) -> Result<OptState> {
        let mut slices = Vec::new();
        self.inner.export_slices("", &mut slices);
        Ok(OptState {
            kind: self.name().to_string(),
            slices,
            counters: Vec::new(),
        })
    }

    fn state_import(&mut self, state: &OptState) -> Result<()> {
        if state.kind != self.name() {
            bail!(
                "optimizer state kind {:?} does not match live {:?}",
                state.kind, self.name()
            );
        }
        self.inner.import_slices("", state)
    }
}

// ---------------------------------------------------------------------------
// Delay Compensation (Zheng et al. 2017, Fig. 19)
// ---------------------------------------------------------------------------

pub struct DelayComp {
    inner: ElementAdam,
    lambda: f32,
}

impl DelayComp {
    pub fn new(man: &crate::runtime::Manifest, lambda: f32) -> Self {
        let shapes: Vec<Vec<usize>> =
            man.params.iter().map(|p| p.shape.clone()).collect();
        DelayComp { inner: ElementAdam::new(&shapes), lambda }
    }
}

impl Optimizer for DelayComp {
    fn step(&mut self, ctx: &StepCtx, params: &mut [Tensor], grads: &[Tensor])
        -> Result<()> {
        let stale = ctx
            .stale
            .expect("DelayComp needs the stale weights the grads came from");
        for i in 0..params.len() {
            // g' = g + λ · g ⊙ g ⊙ (w_now − w_stale): first-order Taylor
            // correction with the diagonal empirical Fisher as Hessian.
            let g = &grads[i];
            let mut gc = g.clone();
            for ((gc_i, &g_i), (&w_i, &ws_i)) in gc
                .data
                .iter_mut()
                .zip(&g.data)
                .zip(params[i].data.iter().zip(&stale[i].data))
            {
                *gc_i = g_i + self.lambda * g_i * g_i * (w_i - ws_i);
            }
            self.inner.update(
                i,
                &mut params[i],
                &gc,
                ctx.lr_for(i),
                // same β1 convention as the Adam path (the paper's
                // per-method override), not the raw configured value
                ctx.cfg.effective_beta1(),
                ctx.cfg.beta2,
                ctx.cfg.eps,
                ctx.cfg.weight_decay,
                ctx.t,
                false,
            );
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "delay_comp"
    }

    fn state_elems(&self) -> usize {
        self.inner.state_elems()
    }

    // The Taylor reference (the stale weights the grads came from) is
    // not optimizer-owned state — it arrives per step via
    // `StepCtx::stale` from the stash ring, which checkpoints
    // separately — so DelayComp's exportable state is exactly its
    // inner Adam moments.
    fn state_export(&self) -> Result<OptState> {
        let mut slices = Vec::new();
        self.inner.export_slices("", &mut slices);
        Ok(OptState {
            kind: self.name().to_string(),
            slices,
            counters: Vec::new(),
        })
    }

    fn state_import(&mut self, state: &OptState) -> Result<()> {
        if state.kind != self.name() {
            bail!(
                "optimizer state kind {:?} does not match live {:?}",
                state.kind, self.name()
            );
        }
        self.inner.import_slices("", state)
    }
}

/// Global gradient-norm clipping (paper D.2: clip at 1.0). Returns the
/// pre-clip norm.
pub fn clip_global_norm(grads: &mut [Tensor], max_norm: f32) -> f32 {
    let total: f32 =
        grads.iter().map(|g| g.data.iter().map(|x| x * x).sum::<f32>()).sum();
    let norm = total.sqrt();
    if norm > max_norm && norm > 0.0 {
        let s = max_norm / norm;
        for g in grads.iter_mut() {
            for x in g.data.iter_mut() {
                *x *= s;
            }
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn element_adam_first_step_is_signed_lr() {
        // With zero init state, bias correction makes the first Adam step
        // ≈ lr·sign(g) (wd = 0).
        let shapes = vec![vec![4]];
        let mut p = Tensor::new(vec![4], vec![1.0, -2.0, 3.0, 0.5]);
        let before = p.clone();
        let mut ad = ElementAdam::new(&shapes);
        let g = Tensor::new(vec![4], vec![0.3, -0.7, 0.1, 0.0]);
        ad.update(0, &mut p, &g, 0.01, 0.9, 0.999, 1e-12, 0.0, 1, false);
        for i in 0..3 {
            let step = p.data[i] - before.data[i];
            assert!((step + 0.01 * g.data[i].signum()).abs() < 1e-4, "{step}");
        }
        assert_eq!(p.data[3], before.data[3]); // zero grad → no move
    }

    #[test]
    fn adam_minimizes_quadratic() {
        let shapes = vec![vec![2]];
        let mut p = Tensor::new(vec![2], vec![5.0, -3.0]);
        let mut ad = ElementAdam::new(&shapes);
        for t in 1..=800 {
            let g = Tensor::new(vec![2], vec![2.0 * p.data[0], 10.0 * p.data[1]]);
            ad.update(0, &mut p, &g, 0.05, 0.9, 0.999, 1e-8, 0.0, t, false);
        }
        assert!(p.max_abs() < 0.1, "{p:?}");
    }

    #[test]
    fn nesterov_differs_from_adam() {
        let shapes = vec![vec![2]];
        let g = Tensor::new(vec![2], vec![1.0, -1.0]);
        let mut p1 = Tensor::zeros(&[2]);
        let mut p2 = Tensor::zeros(&[2]);
        let mut a1 = ElementAdam::new(&shapes);
        let mut a2 = ElementAdam::new(&shapes);
        for t in 1..=3 {
            a1.update(0, &mut p1, &g, 0.1, 0.9, 0.999, 1e-8, 0.0, t, false);
            a2.update(0, &mut p2, &g, 0.1, 0.9, 0.999, 1e-8, 0.0, t, true);
        }
        assert_ne!(p1.data, p2.data);
    }

    #[test]
    fn clip_rescales_only_when_needed() {
        let mut gs = vec![Tensor::new(vec![2], vec![3.0, 4.0])];
        let n = clip_global_norm(&mut gs, 1.0);
        assert!((n - 5.0).abs() < 1e-6);
        assert!((gs[0].norm() - 1.0).abs() < 1e-6);
        let mut gs2 = vec![Tensor::new(vec![2], vec![0.3, 0.4])];
        let n2 = clip_global_norm(&mut gs2, 1.0);
        assert!((n2 - 0.5).abs() < 1e-6);
        assert_eq!(gs2[0].data, vec![0.3, 0.4]);
    }

    #[test]
    fn delay_comp_uses_effective_beta1_like_adam() {
        // Pins the observable contract behind the effective_beta1()
        // wiring (today effective_beta1() == beta1 for DelayComp, so
        // the fix is about staying in lockstep with the Adam path if
        // the per-method β1 convention ever changes): with zero delay
        // (stale == current) the Taylor correction vanishes and a
        // DelayComp step must equal an Adam step coordinate-for-
        // coordinate under the same config.
        let rt = Runtime::native("micro").unwrap();
        let part = StagePartition::new(&rt.manifest, 1);
        let mut cfg = TrainCfg::default();
        let init = crate::model::init_params(&rt.manifest, 4);
        let grads: Vec<Tensor> = init
            .iter()
            .map(|p| Tensor::new(p.shape.clone(), p.data.iter().map(|x| x * 0.1).collect()))
            .collect();

        cfg.method = Method::DelayComp { lambda: 0.3 };
        let mut dc = DelayComp::new(&rt.manifest, 0.3);
        let mut p_dc = init.clone();
        let stale = init.clone();
        let ctx = StepCtx {
            t: 1,
            lr: cfg.lr_at(1),
            cfg: &cfg,
            part: &part,
            stale: Some(&stale),
            rt: &rt,
        };
        dc.step(&ctx, &mut p_dc, &grads).unwrap();

        let mut cfg_adam = cfg.clone();
        cfg_adam.method = Method::PipeDream;
        let mut adam = Adam::new(&rt.manifest, false);
        let mut p_adam = init.clone();
        let ctx2 = StepCtx {
            t: 1,
            lr: cfg_adam.lr_at(1),
            cfg: &cfg_adam,
            part: &part,
            stale: None,
            rt: &rt,
        };
        adam.step(&ctx2, &mut p_adam, &grads).unwrap();

        for (a, b) in p_dc.iter().zip(&p_adam) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn weight_decay_shrinks_weights() {
        let shapes = vec![vec![1]];
        let mut p = Tensor::new(vec![1], vec![10.0]);
        let mut ad = ElementAdam::new(&shapes);
        let g = Tensor::zeros(&[1]);
        ad.update(0, &mut p, &g, 0.1, 0.9, 0.999, 1e-8, 0.1, 1, false);
        assert!(p.data[0] < 10.0);
    }
}
