//! # abrot — Asynchronous Basis-Rotation Pipeline Training
//!
//! Reproduction of "Mitigating Staleness in Asynchronous Pipeline
//! Parallelism via Basis Rotation" (Jung, Shin, Lee; ICML 2026) as a
//! three-layer stack with a **pluggable compute backend**:
//!
//! * **L3 (this crate)** — the pipeline-parallel training coordinator:
//!   1F1B asynchronous schedule, weight stashing, stage-dependent delay,
//!   hybrid data parallelism (`replicas = R` pipeline replicas with a
//!   per-step gradient all-reduce, [`pipeline::dp`]), per-stage
//!   optimizers (PipeDream / PipeDream-LR / Nesterov / DC / Muon /
//!   Scion / SOAP / **basis rotation**), metrics and benchmarks.
//! * **L2** — the model graphs (transformer fwd/bwd, batched optimizer
//!   updates), served by one of two interchangeable backends behind
//!   [`runtime::Backend`]:
//!   - [`runtime::native`] (default): pure-Rust reference kernels.
//!     `cargo build && cargo test` work on a clean machine with no
//!     Python, no XLA and no artifacts directory.
//!   - `runtime::pjrt` (cargo feature `pjrt`): HLO text artifacts
//!     lowered AOT by `python/compile/aot.py` from JAX, executed via
//!     the PJRT CPU client.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the rotated
//!   Adam update, tiled matmul and attention, lowered into the HLO the
//!   PJRT backend executes. The native backend mirrors them with the
//!   reference implementations in [`optim::reference`].
//!
//! Python never runs on the training path: it is an optional,
//! build-time artifact generator for the `pjrt` feature. See
//! `README.md` for the quickstart and `docs/ARCHITECTURE.md` for the
//! schedule/staleness model.

// Index-heavy reference kernels read better with explicit loops, and
// the exported graph signatures are long by design.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

pub mod analysis;
pub mod bench;
pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod jsonio;
pub mod landscape;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod pipeline;
pub mod rngs;
pub mod runtime;
pub mod tensor;
pub mod trace;
