//! # abrot — Asynchronous Basis-Rotation Pipeline Training
//!
//! Reproduction of "Mitigating Staleness in Asynchronous Pipeline
//! Parallelism via Basis Rotation" (Jung, Shin, Lee; ICML 2026) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the pipeline-parallel training coordinator:
//!   1F1B asynchronous schedule, weight stashing, stage-dependent delay,
//!   per-stage optimizers (PipeDream / PipeDream-LR / Nesterov / DC /
//!   Muon / Scion / SOAP / **basis rotation**), metrics and benchmarks.
//! * **L2 (python/compile)** — JAX transformer fwd/bwd lowered AOT to
//!   HLO text artifacts, executed here via the PJRT CPU client.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the rotated
//!   Adam update, tiled matmul and attention, lowered into the same HLO.
//!
//! Python never runs on the training path: `make artifacts` is the only
//! python invocation; afterwards the `abrot` binary is self-contained.

pub mod tensor;
pub mod rngs;
pub mod jsonio;
pub mod config;
pub mod data;
pub mod runtime;
pub mod model;
pub mod optim;
pub mod pipeline;
pub mod coordinator;
pub mod landscape;
pub mod analysis;
pub mod metrics;
pub mod bench;
