//! Synthetic corpus — the OpenWebText substitute (DESIGN.md §5).
//!
//! A deterministic order-2 n-gram language over the model's vocab: each
//! context (a, b) has a hash-determined "preferred" next token which is
//! emitted with probability `det_p`; otherwise the next token is drawn
//! from a Zipf(1.1) unigram. The deterministic component gives the model
//! learnable structure (loss curves fall well below the unigram
//! entropy), the Zipf tail mirrors natural-language token statistics.
//! Train and validation streams come from disjoint RNG streams of the
//! same language, so validation loss is meaningful (paper Fig. 18).

use crate::rngs::{Rng, Zipf};

/// Corpus stream label of the training split (replica 0). Validation
/// uses `pipeline::VAL_STREAM`; data-parallel replicas shard via
/// [`replica_stream`].
pub const TRAIN_STREAM: u64 = 1;

/// Deterministic data-parallel sharding: the stream label replica `r`
/// draws its batches from. Replica 0 keeps `base` unchanged (so R = 1
/// reproduces pre-DP trajectories bit-for-bit); other replicas are
/// offset far beyond the +0x1000 steps `BatchIter::refill` takes, so
/// shards never collide however long the run is.
pub fn replica_stream(base: u64, replica: usize) -> u64 {
    base.wrapping_add((replica as u64) << 32)
}

#[derive(Clone)]
pub struct Corpus {
    vocab: usize,
    /// probability of the order-1 (bigram) deterministic successor —
    /// quickly learnable even by small models.
    p1: f32,
    /// probability of the order-2 (trigram) successor — rewards context
    /// depth beyond bigrams.
    p2: f32,
    zipf: Zipf,
    lang_seed: u64,
}

impl Corpus {
    pub fn new(vocab: usize, seed: u64) -> Self {
        Corpus { vocab, p1: 0.55, p2: 0.25, zipf: Zipf::new(vocab, 1.1), lang_seed: seed }
    }

    fn hash(&self, x: u64) -> u64 {
        let mut h = self.lang_seed ^ x.wrapping_mul(0x9E3779B97F4A7C15);
        h ^= h >> 33;
        h = h.wrapping_mul(0xFF51AFD7ED558CCD);
        h ^= h >> 33;
        h
    }

    /// Order-1 rule: a fixed pseudorandom permutation-like map of b.
    pub fn preferred1(&self, b: u32) -> u32 {
        (self.hash(b as u64 | 1 << 40) % self.vocab as u64) as u32
    }

    /// Order-2 rule: successor of the pair (a, b).
    pub fn preferred2(&self, a: u32, b: u32) -> u32 {
        let x = (a as u64) << 20 | b as u64;
        (self.hash(x | 1 << 41) % self.vocab as u64) as u32
    }

    /// Stream `n` tokens with a per-stream RNG (train vs val use
    /// different `stream` labels).
    pub fn tokens(&self, n: usize, stream: u64) -> Vec<i32> {
        let mut rng = Rng::new(self.lang_seed).fold(stream);
        let mut out = Vec::with_capacity(n);
        let (mut a, mut b) = (
            self.zipf.sample(&mut rng) as u32,
            self.zipf.sample(&mut rng) as u32,
        );
        for _ in 0..n {
            let u = rng.uniform();
            let next = if u < self.p1 {
                self.preferred1(b)
            } else if u < self.p1 + self.p2 {
                self.preferred2(a, b)
            } else {
                self.zipf.sample(&mut rng) as u32
            };
            out.push(next as i32);
            a = b;
            b = next;
        }
        out
    }
}

/// Batches generated per refill chunk; each chunk draws its tokens
/// from one stream label, so the pair (chunk index, offset) is an
/// exact, resumable position in the stream.
const BATCHES_PER_CHUNK: u64 = 64;

/// Resumable position of a [`BatchIter`]: the base stream label plus
/// the number of batches drawn so far. [`BatchIter::seek`] restores an
/// identical iterator from it without replaying the consumed prefix.
#[derive(Clone, serde::Serialize, serde::Deserialize)]
pub struct DataCursor {
    pub stream0: u64,
    pub drawn: u64,
}

/// Batch iterator producing (tokens, targets) with targets shifted by 1.
pub struct BatchIter {
    corpus: Corpus,
    batch: usize,
    seq: usize,
    /// Base stream label this iterator was created with.
    stream0: u64,
    /// Stream label the *next* refill chunk will draw from.
    stream: u64,
    /// Batches drawn since creation (or the last `seek`).
    drawn: u64,
    cursor: usize,
    buf: Vec<i32>,
}

impl BatchIter {
    pub fn new(corpus: Corpus, batch: usize, seq: usize, stream: u64) -> Self {
        BatchIter {
            corpus,
            batch,
            seq,
            stream0: stream,
            stream,
            drawn: 0,
            cursor: 0,
            buf: Vec::new(),
        }
    }

    fn refill(&mut self) {
        let need = self.batch * (self.seq + 1) * BATCHES_PER_CHUNK as usize;
        self.buf = self.corpus.tokens(need, self.stream);
        self.stream = self.stream.wrapping_add(0x1000);
        self.cursor = 0;
    }

    /// Next (tokens, targets), each `batch*seq` row-major i32.
    pub fn next_batch(&mut self) -> (Vec<i32>, Vec<i32>) {
        let span = self.seq + 1;
        let need = self.batch * span;
        if self.cursor + need > self.buf.len() {
            self.refill();
        }
        let mut toks = Vec::with_capacity(self.batch * self.seq);
        let mut tgts = Vec::with_capacity(self.batch * self.seq);
        for r in 0..self.batch {
            let s = self.cursor + r * span;
            toks.extend_from_slice(&self.buf[s..s + self.seq]);
            tgts.extend_from_slice(&self.buf[s + 1..s + 1 + self.seq]);
        }
        self.cursor += need;
        self.drawn += 1;
        (toks, tgts)
    }

    /// Current resumable position.
    pub fn cursor(&self) -> DataCursor {
        DataCursor { stream0: self.stream0, drawn: self.drawn }
    }

    /// Jump to the position after `drawn` batches, regenerating only
    /// the refill chunk the position lands in — the iterator then
    /// yields exactly the batches an uninterrupted one would.
    pub fn seek(&mut self, drawn: u64) {
        let chunk = drawn / BATCHES_PER_CHUNK;
        let within = (drawn % BATCHES_PER_CHUNK) as usize;
        self.stream = self.stream0.wrapping_add(0x1000u64.wrapping_mul(chunk));
        if within == 0 {
            // chunk boundary: the next draw triggers the refill itself
            self.buf = Vec::new();
            self.cursor = 0;
        } else {
            self.refill();
            self.cursor = within * self.batch * (self.seq + 1);
        }
        self.drawn = drawn;
    }

    /// Restore from a saved cursor; errors if the cursor belongs to a
    /// different stream (shard relabeling across a resume is a bug).
    pub fn restore(&mut self, c: &DataCursor) -> anyhow::Result<()> {
        if c.stream0 != self.stream0 {
            anyhow::bail!(
                "data cursor stream {} does not match iterator stream {}",
                c.stream0, self.stream0
            );
        }
        self.seek(c.drawn);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab_and_deterministic() {
        let c = Corpus::new(64, 7);
        let t1 = c.tokens(1000, 0);
        let t2 = c.tokens(1000, 0);
        assert_eq!(t1, t2);
        assert!(t1.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn streams_differ() {
        let c = Corpus::new(64, 7);
        assert_ne!(c.tokens(200, 0), c.tokens(200, 1));
    }

    #[test]
    fn language_is_learnable() {
        // Oracles that know the transition rules predict the next token
        // far above the Zipf baseline — real structure to learn, with
        // the order-2 rule adding predictability beyond bigrams (depth
        // pays off, Fig. 6).
        let c = Corpus::new(256, 3);
        let toks = c.tokens(8000, 0);
        let (mut hit1, mut hit2) = (0usize, 0usize);
        for w in toks.windows(3) {
            if c.preferred1(w[1] as u32) == w[2] as u32 {
                hit1 += 1;
            }
            if c.preferred1(w[1] as u32) == w[2] as u32
                || c.preferred2(w[0] as u32, w[1] as u32) == w[2] as u32
            {
                hit2 += 1;
            }
        }
        let n = (toks.len() - 2) as f32;
        let acc1 = hit1 as f32 / n;
        let acc2 = hit2 as f32 / n;
        assert!(acc1 > 0.5, "order-1 oracle acc {acc1}");
        assert!(acc2 > acc1 + 0.15, "order-2 adds {acc1} -> {acc2}");
    }

    #[test]
    fn batches_shift_targets_by_one() {
        let c = Corpus::new(64, 9);
        let mut it = BatchIter::new(c, 2, 8, 0);
        let (toks, tgts) = it.next_batch();
        assert_eq!(toks.len(), 16);
        assert_eq!(tgts.len(), 16);
        // within each row, targets[i] == tokens[i+1]
        for r in 0..2 {
            for i in 0..7 {
                assert_eq!(tgts[r * 8 + i], toks[r * 8 + i + 1]);
            }
        }
    }

    #[test]
    fn batches_advance() {
        let c = Corpus::new(64, 9);
        let mut it = BatchIter::new(c, 2, 8, 0);
        let a = it.next_batch();
        let b = it.next_batch();
        assert_ne!(a.0, b.0);
    }

    #[test]
    fn replica_streams_are_disjoint_and_stable() {
        assert_eq!(replica_stream(TRAIN_STREAM, 0), TRAIN_STREAM);
        let c = Corpus::new(64, 9);
        let mut seen = Vec::new();
        for r in 0..4 {
            let mut it =
                BatchIter::new(c.clone(), 2, 8, replica_stream(TRAIN_STREAM, r));
            seen.push(it.next_batch().0);
        }
        for i in 0..seen.len() {
            for j in i + 1..seen.len() {
                assert_ne!(seen[i], seen[j], "shards {i} and {j} collide");
            }
        }
        // far apart even after many refills: 2^32 >> 0x1000 * refills
        assert!(replica_stream(TRAIN_STREAM, 1) - TRAIN_STREAM > 0x1000 * 1_000);
    }

    #[test]
    fn seek_matches_uninterrupted_iteration() {
        // across chunk boundaries (64 batches/chunk) and within them
        let c = Corpus::new(64, 9);
        for n in [0u64, 1, 5, 63, 64, 65, 130] {
            let mut full = BatchIter::new(c.clone(), 2, 8, 5);
            for _ in 0..n {
                full.next_batch();
            }
            let mut jumped = BatchIter::new(c.clone(), 2, 8, 5);
            jumped.seek(n);
            assert_eq!(jumped.cursor().drawn, n);
            for _ in 0..70 {
                assert_eq!(full.next_batch(), jumped.next_batch());
            }
        }
    }

    #[test]
    fn restore_rejects_foreign_stream() {
        let c = Corpus::new(64, 9);
        let mut it = BatchIter::new(c, 2, 8, 5);
        let bad = DataCursor { stream0: 6, drawn: 3 };
        assert!(it.restore(&bad).is_err());
        let good = DataCursor { stream0: 5, drawn: 3 };
        assert!(it.restore(&good).is_ok());
    }

    #[test]
    fn refill_is_seamless() {
        let c = Corpus::new(64, 9);
        let mut it = BatchIter::new(c, 4, 16, 5);
        for _ in 0..200 {
            let (t, g) = it.next_batch();
            assert_eq!(t.len(), 64);
            assert!(g.iter().all(|&x| (0..64).contains(&x)));
        }
    }
}
