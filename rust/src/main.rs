//! `abrot` — asynchronous basis-rotation pipeline training CLI.
//!
//! Subcommands:
//!   info       --config <name>                 show manifest summary
//!   train      --config <name> --method <m> --stages P --steps N [...]
//!   engine     --config <name> --stages P --steps N    threaded 1F1B run
//!   repro      --fig <id>|--table <id>|--all [--steps N] [--out DIR]
//!   landscape                                  Figs 3–4 toy experiments
//!   calc       stage/memory calculators (Tables 1–2)

use std::path::PathBuf;

use anyhow::{anyhow, bail, Result};

use abrot::config::{FreqAlloc, Geometry, Method, ScheduleKind, Source, StashMode, TrainCfg};
use abrot::coordinator::figures::{FigOpts, Harness};
use abrot::coordinator::{Coordinator, Experiment};
use abrot::metrics::write_losses;
use abrot::runtime::Runtime;

/// Minimal flag parser: --key value pairs after the subcommand.
struct Args {
    map: std::collections::HashMap<String, String>,
    flags: std::collections::HashSet<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut map = std::collections::HashMap::new();
        let mut flags = std::collections::HashSet::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    map.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string());
                    i += 1;
                }
            } else {
                i += 1;
            }
        }
        Args { map, flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn parse_num<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains(key) || self.map.contains_key(key)
    }
}

fn parse_method(name: &str) -> Result<Method> {
    Ok(match name {
        "pipedream" | "adam" => Method::PipeDream,
        "pipedream_lr" => Method::PipeDreamLr,
        "nesterov" => Method::Nesterov,
        "muon" => Method::Muon,
        "scion" => Method::Scion,
        "soap" => Method::Soap { freq: 10 },
        "br" | "basis_rotation" => Method::br_default(),
        s if s.starts_with("dc_") => Method::DelayComp {
            lambda: s[3..].parse().map_err(|_| anyhow!("bad dc lambda"))?,
        },
        s if s.starts_with("br_") => {
            // br_<1st|2nd>_<uni|bi>[_f<freq>][_sa|_isa]
            let parts: Vec<&str> = s.split('_').collect();
            let source = match parts.get(1) {
                Some(&"1st") => Source::First,
                Some(&"2nd") => Source::Second,
                _ => bail!("bad br source in {s}"),
            };
            let geometry = match parts.get(2) {
                Some(&"uni") => Geometry::Unilateral,
                Some(&"bi") => Geometry::Bilateral,
                _ => bail!("bad br geometry in {s}"),
            };
            let mut freq = 10;
            let mut alloc = FreqAlloc::Uniform;
            for p in &parts[3..] {
                if let Some(f) = p.strip_prefix('f') {
                    freq = f.parse().map_err(|_| anyhow!("bad freq in {s}"))?;
                } else if *p == "sa" {
                    alloc = FreqAlloc::StageAware;
                } else if *p == "isa" {
                    alloc = FreqAlloc::InverseStageAware;
                }
            }
            Method::BasisRotation { source, geometry, freq, alloc }
        }
        _ => bail!("unknown method {name:?}"),
    })
}

fn train_cfg_from(args: &Args) -> Result<TrainCfg> {
    let method = parse_method(&args.get_or("method", "pipedream"))?;
    let stash = match args.get_or("stash", "stash").as_str() {
        "stash" => StashMode::Stash,
        "nostash" => StashMode::NoStash,
        "predict" => StashMode::Predict,
        s => bail!("bad --stash {s}"),
    };
    let schedule = match args.get("schedule") {
        None => ScheduleKind::OneFOneB,
        Some(s) => ScheduleKind::parse(s).ok_or_else(|| {
            anyhow!("bad --schedule {s:?}: use gpipe | 1f1b | interleaved[:V] | amdp")
        })?,
    };
    Ok(TrainCfg {
        method,
        stages: args.parse_num("stages", 1usize),
        replicas: args.parse_num("replicas", 1usize).max(1),
        threads: args.parse_num("threads", 0usize),
        steps: args.parse_num("steps", 200u32),
        lr: args.parse_num("lr", 1e-3f32),
        seed: args.parse_num("seed", 1234u64),
        eval_every: args.parse_num("eval-every", 0u32),
        stash,
        schedule,
        microbatches: args.parse_num("microbatches", 0u32),
        checkpoint_every: args.parse_num("checkpoint-every", 0u32),
        checkpoint_dir: args.get("checkpoint-dir").map(|s| s.to_string()),
        resume: args.get("resume").map(|s| s.to_string()),
        trace: args.get("trace").map(|s| s.to_string()),
        metrics: args.get("metrics").map(|s| s.to_string()),
        dp_async: args.has("dp-async"),
        max_skew: args.parse_num("max-skew", 0u32),
        reduce_timeout_ms: args.parse_num("reduce-timeout-ms", 0u64),
        ..Default::default()
    })
}

/// Build a fault plan from the engine subcommand's `--kill`, `--join`
/// and `--delay` flags; each takes a comma-separated list of specs.
fn fault_plan_from(args: &Args) -> Result<abrot::checkpoint::FaultPlan> {
    let mut plan = abrot::checkpoint::FaultPlan::default();
    if let Some(specs) = args.get("kill") {
        for s in specs.split(',') {
            plan.kills.push(abrot::checkpoint::FaultPlan::parse_kill(s)?);
        }
    }
    if let Some(specs) = args.get("join") {
        for s in specs.split(',') {
            plan.joins.push(abrot::checkpoint::FaultPlan::parse_join(s)?);
        }
    }
    if let Some(specs) = args.get("delay") {
        for s in specs.split(',') {
            plan.delays.push(abrot::checkpoint::FaultPlan::parse_delay(s)?);
        }
    }
    Ok(plan)
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let args = Args::parse(&argv[1.min(argv.len())..]);
    let root = PathBuf::from(args.get_or("artifacts", "artifacts"));

    match cmd {
        "info" => {
            let cfg = args.get_or("config", "micro");
            let rt = Runtime::open(root.join(&cfg))?;
            let m = &rt.manifest;
            println!("backend: {}", rt.backend_kind());
            println!("config {} : vocab={} seq={} d_model={} heads={} blocks={} d_ff={} batch={}{}",
                     m.cfg.name, m.cfg.vocab, m.cfg.seq, m.cfg.d_model,
                     m.cfg.n_heads, m.cfg.n_blocks, m.cfg.d_ff, m.cfg.batch,
                     m.cfg.moe.as_ref().map_or(String::new(),
                         |x| format!(" moe={}x top{}", x.n_experts, x.top_k)));
            println!("params: {} tensors, {} total elements",
                     m.params.len(), m.total_params());
            println!("executables: {}", m.executables.len());
            let mut names: Vec<_> = m.executables.keys().collect();
            names.sort();
            for n in names {
                println!("  {n}");
            }
        }
        "train" => {
            let cfg_name = args.get_or("config", "micro");
            let tcfg = train_cfg_from(&args)?;
            abrot::runtime::pool::set_global_threads(
                abrot::runtime::pool::ThreadCfg::new(tcfg.threads),
            );
            let mut coord = Coordinator::new(&root);
            println!("training {cfg_name} with {} (P={}, R={}, {} steps)",
                     tcfg.method.name(), tcfg.stages, tcfg.dp_replicas(),
                     tcfg.steps);
            let res = coord.run(&Experiment { model: cfg_name, train: tcfg })?;
            for (i, l) in res.losses.iter().enumerate() {
                if (i + 1) % 10 == 0 || i == 0 {
                    println!("step {:>5}  loss {:.4}", i + 1, l);
                }
            }
            println!("final (smoothed) {:.4}  wall {:.1}s  dispatches {}",
                     res.final_loss(), res.wall_secs, res.dispatches);
            if let Some(out) = args.get("out") {
                write_losses(out, &[&res])?;
                println!("losses -> {out}");
            }
        }
        "engine" => {
            let cfg_name = args.get_or("config", "micro");
            let tcfg = train_cfg_from(&args)?;
            abrot::runtime::pool::set_global_threads(
                abrot::runtime::pool::ThreadCfg::new(tcfg.threads),
            );
            let plan = fault_plan_from(&args)?;
            let mut coord = Coordinator::new(&root);
            let res = coord
                .run_engine_elastic(&Experiment { model: cfg_name, train: tcfg }, &plan)?;
            println!(
                "engine: {} P={} R={} final {:.4}  tokens/s {:.0}  bubble {:.1}% \
                 (model {:.1}%, analytic {:.1}%)  wall {:.1}s",
                res.schedule, res.stages, res.replicas, res.final_loss(),
                res.tokens_per_sec, res.bubble_frac * 100.0,
                res.bubble_frac_model * 100.0, res.bubble_frac_analytic * 100.0,
                res.wall_secs
            );
        }
        "repro" => {
            let opts = FigOpts {
                out: PathBuf::from(args.get_or("out", "results")),
                steps: args.parse_num("steps", 200u32),
                stages: args
                    .get("stages")
                    .map(|s| s.split(',').map(|x| x.parse().unwrap()).collect())
                    .unwrap_or_else(|| vec![1, 4, 8, 16, 32]),
                seed: args.parse_num("seed", 1234u64),
                lr: args.parse_num("lr", 1e-3f32),
            };
            let model = args.get_or("model", "tiny32");
            let mut coord = Coordinator::new(&root);
            let mut h = Harness::new(&mut coord, opts);
            if args.has("all") {
                h.all(&model)?;
            } else if let Some(t) = args.get("table") {
                match t {
                    "table1" | "table2" => h.tables12()?,
                    "table3" => h.table3(&model)?,
                    _ => bail!("unknown table {t}"),
                }
            } else if let Some(fspec) = args.get("fig") {
                for f in fspec.split(',') {
                    match f {
                    "fig2a" | "fig2b" | "fig5" | "fig12" | "fig13" => h.fig5(&model)?,
                    "fig3" => h.fig3()?,
                    "fig4" => h.fig4()?,
                    "fig6" | "fig14" => h.fig6()?,
                    "fig7" | "fig20" => h.fig7()?,
                    "fig8" | "fig16" => h.fig8(&model)?,
                    "fig9a" | "fig9b" => h.fig9ab(&model)?,
                    "fig9c" | "fig17" => h.fig9c(&model)?,
                    "fig10" => h.fig10(&model)?,
                    "fig11" => h.fig11("tiny8")?,
                    "fig15" => h.fig15(&model)?,
                    "fig18" => h.fig18(&model)?,
                    "fig19" => h.fig19(&model)?,
                    "fig21" => h.fig21()?,
                    "engine" => {
                        let p = args.parse_num("stages-engine", 2usize);
                        h.engine(&args.get_or("engine-model", "micro"), p)?
                    }
                    "dp" => {
                        let p = args.parse_num("dp-stages", 4usize);
                        h.dp(&args.get_or("dp-model", "pico4"), p, &[1, 2, 4])?
                    }
                    "dp_async" => {
                        let p = args.parse_num("dp-stages", 4usize);
                        h.dp_async(&args.get_or("dp-model", "pico4"), p, &[0, 1, 2, 4])?
                    }
                    "schedule" => {
                        let p = args.parse_num("schedule-stages", 4usize);
                        h.schedule(&args.get_or("schedule-model", "pico8"), p)?
                    }
                    "timeline" => {
                        let p = args.parse_num("timeline-stages", 4usize);
                        h.timeline(&args.get_or("timeline-model", "pico8"), p)?
                    }
                    _ => bail!("unknown figure {f}"),
                    }
                }
            } else {
                bail!("repro needs --fig, --table or --all");
            }
        }
        "benchcmp" => {
            let baseline = args
                .get("baseline")
                .ok_or_else(|| anyhow!("benchcmp needs --baseline PATH"))?;
            let current = args
                .get("current")
                .ok_or_else(|| anyhow!("benchcmp needs --current PATH"))?;
            let tol = args.parse_num("tol", 1.5f64);
            let base = abrot::bench::load_snapshot(baseline)?;
            let cur = abrot::bench::load_snapshot(current)?;
            abrot::bench::validate_snapshot(&base).map_err(anyhow::Error::msg)?;
            abrot::bench::validate_snapshot(&cur).map_err(anyhow::Error::msg)?;
            let cmp = abrot::bench::compare_snapshots(&cur, &base, tol);
            cmp.print();
            let regs = cmp.regressions();
            if !regs.is_empty() {
                if args.has("strict") {
                    bail!("{} bench regression(s) above {tol}x", regs.len());
                }
                println!("{} regression(s) above {tol}x (non-strict; exit 0)", regs.len());
            }
        }
        "landscape" => {
            let mut coord = Coordinator::new(&root);
            let mut h = Harness::new(&mut coord, FigOpts::default());
            h.fig3()?;
            h.fig4()?;
        }
        "calc" => {
            let mut coord = Coordinator::new(&root);
            let mut h = Harness::new(&mut coord, FigOpts::default());
            h.tables12()?;
        }
        _ => {
            println!("abrot — asynchronous basis-rotation pipeline training");
            println!("usage: abrot <info|train|engine|repro|benchcmp|landscape|calc> [--flags]");
            println!("  e.g. abrot train --config tiny32 --method br --stages 32 --steps 300");
            println!("       abrot engine --config micro --stages 2 --replicas 2 --steps 40");
            println!("       abrot repro --fig fig5 --steps 200 --out results");
            println!("threading: --threads N sets the kernel pool budget (default:");
            println!("  auto = ABROT_THREADS env or available cores). The engine splits");
            println!("  the budget across its P x R stage workers; results are");
            println!("  bit-identical at any --threads value.");
            println!("observability: --trace out.json writes a Chrome trace_event span");
            println!("  timeline (engine: wall-clock per worker; train: virtual-clock");
            println!("  schedule model); --metrics out.jsonl writes per-step run metrics.");
            println!("  abrot benchcmp --baseline benchmarks/BENCH_engine.json \\");
            println!("      --current BENCH_engine.json [--tol 1.5] [--strict]");
            println!("checkpointing: --checkpoint-every K [--checkpoint-dir D] writes");
            println!("  atomic step snapshots; --resume PATH continues one bit-exactly");
            println!("  (sim) or drain-consistently (engine). engine fault injection:");
            println!("  --kill STEP:REPLICA[:WORKER] --join STEP[:COUNT]");
            println!("  --delay STEP:REPLICA:WORKER:MILLIS (comma-separated lists)");
            println!("data parallelism: --replicas R runs R sharded pipelines with a");
            println!("  synchronous gradient average per step. --dp-async --max-skew K");
            println!("  relaxes the barrier to bounded step skew: replicas fold peer");
            println!("  gradients up to K steps stale and stall only at the bound, so");
            println!("  a straggler (--delay) no longer stalls the group; K=0 is");
            println!("  bit-exact with the synchronous path. --reduce-timeout-ms M");
            println!("  bounds any all-reduce wait (default 120000); an unresponsive");
            println!("  peer is a loud error naming the replica, never a silent hang.");
            println!("backends: native reference kernels by default; with an");
            println!("  artifacts/<config>/ dir and a `pjrt`-feature build, the");
            println!("  HLO/PJRT path is used instead (see README).");
        }
    }
    Ok(())
}
