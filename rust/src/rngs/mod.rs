//! Deterministic RNG stack (no external crates): SplitMix64 seeding,
//! Xoshiro256** core, and the samplers the system needs — normal
//! (Box–Muller) for init, Cauchy for the Hessian (1,1)-norm trace
//! estimator (paper Fig. 11 / Xie et al. 2025), and Zipf for the
//! synthetic corpus.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    spare_normal: Option<f32>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm),
                 splitmix64(&mut sm)];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (stable across runs) for `label`.
    pub fn fold(&self, label: u64) -> Rng {
        let mut sm = self.s[0] ^ label.wrapping_mul(0xA24BAED4963EE407);
        let s = [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm),
                 splitmix64(&mut sm)];
        Rng { s, spare_normal: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (pairs cached).
    pub fn normal(&mut self) -> f32 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f32::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f32::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Standard Cauchy (heavy-tailed) — for (1,1)-norm trace estimation.
    pub fn cauchy(&mut self) -> f32 {
        let u = self.uniform();
        (std::f32::consts::PI * (u - 0.5)).tan()
    }

    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for x in out.iter_mut() {
            *x = self.normal() * std;
        }
    }
}

/// Zipf(α) sampler over {0..n-1} via precomputed CDF.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f32>,
}

impl Zipf {
    pub fn new(n: usize, alpha: f32) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(alpha as f64);
            cdf.push(acc as f32);
        }
        let total = *cdf.last().unwrap();
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.uniform();
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn fold_streams_independent() {
        let base = Rng::new(7);
        let mut s1 = base.fold(1);
        let mut s2 = base.fold(2);
        assert_ne!(s1.next_u64(), s2.next_u64());
        // fold is pure
        let mut s1b = base.fold(1);
        let mut s1c = base.fold(1);
        assert_eq!(s1b.next_u64(), s1c.next_u64());
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mean: f32 = (0..n).map(|_| r.uniform()).sum::<f32>() / n as f32;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn cauchy_median_zero_heavy_tails() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.cauchy()).collect();
        let below = xs.iter().filter(|&&x| x < 0.0).count() as f32 / n as f32;
        assert!((below - 0.5).abs() < 0.02);
        // heavy tails: |x| > 10 should appear with prob ≈ 2/(π·10) ≈ 0.063
        let tail = xs.iter().filter(|&&x| x.abs() > 10.0).count() as f32 / n as f32;
        assert!(tail > 0.03 && tail < 0.10, "tail {tail}");
    }

    #[test]
    fn zipf_is_monotone_decreasing() {
        let z = Zipf::new(64, 1.1);
        let mut r = Rng::new(11);
        let mut counts = vec![0usize; 64];
        for _ in 0..50_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[5]);
        assert!(counts[1] > counts[20]);
        assert!(counts[0] > counts[63] * 10);
    }
}
