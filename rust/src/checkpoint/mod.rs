//! Crash-consistent checkpoint/resume with deterministic fault
//! injection and elastic data-parallel replicas.
//!
//! A [`RunState`] snapshot captures everything a training loop needs to
//! continue as if it had never stopped: per-stage parameters, the full
//! optimizer state ([`crate::optim::OptState`] — Adam moments, rotation
//! basis matrices and refresh counters, Muon/Scion momentum), the
//! simulator's 1F1B stash rings, data-stream cursors per replica
//! ([`crate::data::DataCursor`]), recorded loss trajectories and the
//! step counter. Snapshots are JSON (the vendored serde subset, both
//! directions) written with the classic crash-consistency idiom: write
//! to `<path>.tmp`, then atomically `rename` into place, so a crash
//! mid-write never leaves a torn snapshot under the live name.
//!
//! Two flavors share the format:
//!
//! * `"sim"` — written inside [`crate::pipeline::train_sim_observed`]
//!   every `--checkpoint-every` steps. Resume is **bit-exact**: params,
//!   optimizer tensors and stash rings restore exactly (f32 → JSON →
//!   f32 round-trips through the shortest-f64 representation without
//!   loss), data cursors regenerate the very next batch an
//!   uninterrupted run would have drawn, and everything else the loop
//!   reads is a pure function of (cfg, t). The `checkpoint_` tests pin
//!   kill-at-step-k + resume against uninterrupted golden trajectories.
//! * `"engine"` — written by [`run_engine_elastic`], which drives the
//!   threaded engine in **segments** between checkpoint boundaries.
//!   Each segment re-fills the pipeline from the snapshot weights, so
//!   resumed trajectories of the asynchronous schedules are
//!   drain-consistent (the snapshot is a fully-drained pipeline), not
//!   bit-identical to an uninterrupted async run; the synchronous
//!   schedules (gpipe / interleaved) drain at every update and stay
//!   exact. AMDP is rejected: its two counter-flowing weight copies per
//!   part make a single exported part state ambiguous.
//!
//! Fault injection ([`FaultPlan`]) is deterministic: worker w of
//! replica r "dies" immediately after completing optimizer update k.
//! The death propagates exactly like a real crash — the replica's
//! peers wind down over their closed channels, and the other replicas
//! observe the dropped all-reduce handles ([`crate::pipeline::dp`]) —
//! after which the driver reloads the last checkpoint, drops the dead
//! replica from the roster, re-partitions the data shards over the
//! survivors (replica ids renumber, so `data::replica_stream` labels
//! re-shard automatically and `dp::group` rebuilds the reduce tree one
//! replica smaller) and re-runs the segment. Planned joins grow the
//! roster at a segment boundary the same way, seeding the newcomers
//! from the snapshot.

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};
use serde::Serialize;

use crate::config::{ScheduleKind, TrainCfg};
use crate::data::{replica_stream, DataCursor, TRAIN_STREAM};
use crate::metrics::RunResult;
use crate::optim::OptState;
use crate::pipeline::engine::{self, EngineCheckpoint, SegmentOpts};
use crate::pipeline::schedule;
use crate::tensor::Tensor;
use crate::trace;

/// Bump on any incompatible change to the [`RunState`] layout; `load`
/// rejects mismatches loudly instead of misreading old snapshots.
pub const RUN_STATE_VERSION: u32 = 1;

/// A shape-tagged tensor snapshot. f32 values survive the JSON round
/// trip bit-exactly (widened to f64, printed shortest, narrowed back).
#[derive(Clone, serde::Serialize, serde::Deserialize)]
pub struct TensorState {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl TensorState {
    pub fn of(t: &Tensor) -> Self {
        TensorState { shape: t.shape.clone(), data: t.data.clone() }
    }

    pub fn to_tensor(&self) -> Tensor {
        Tensor::new(self.shape.clone(), self.data.clone())
    }

    /// Copy into an existing tensor, validating the shape.
    pub fn restore_into(&self, t: &mut Tensor) -> Result<()> {
        if self.shape != t.shape {
            bail!(
                "checkpoint tensor shape {:?} does not match live {:?}",
                self.shape,
                t.shape
            );
        }
        t.data.clone_from(&self.data);
        Ok(())
    }
}

/// The simulator's per-parameter stash rings, oldest version first —
/// the in-flight weight versions of the modeled pipeline.
#[derive(Clone, serde::Serialize, serde::Deserialize)]
pub struct StashSnapshot {
    pub rings: Vec<Vec<TensorState>>,
}

/// One versioned, self-describing snapshot of a training run.
///
/// The identity fields (`model` .. `steps_total`) are validated on
/// resume ([`RunState::expect`]): silently resuming under a different
/// configuration would produce a plausible-looking but meaningless
/// trajectory. Caveats inherited from the JSON subset: integers ride
/// f64, so `seed`/`step` above 2^53 are rejected at load time rather
/// than rounded; that is far beyond any value this repo uses.
#[derive(Clone, serde::Serialize, serde::Deserialize)]
pub struct RunState {
    pub version: u32,
    /// `"sim"` (bit-exact resume) or `"engine"` (segment driver).
    pub flavor: String,
    pub model: String,
    pub method: String,
    pub schedule: String,
    pub stages: usize,
    /// Replica roster when the snapshot was taken (elastic runs shrink
    /// and grow this between segments).
    pub replicas: usize,
    pub seed: u64,
    pub steps_total: u32,
    /// Optimizer updates completed; the run continues at `step + 1`.
    pub step: u64,
    pub params: Vec<TensorState>,
    /// One entry for the sim (whole-model optimizer); one per model
    /// part for the engine.
    pub opts: Vec<OptState>,
    /// Sim only; the engine snapshot is a drained pipeline.
    pub stash: Option<StashSnapshot>,
    pub train_cursors: Vec<DataCursor>,
    pub val_cursor: Option<DataCursor>,
    pub losses: Vec<f32>,
    pub val_losses: Vec<(u32, f32)>,
    /// Sim per-replica dispatch counters (informational).
    pub dispatches: Vec<u64>,
    /// DP reduce-mode identity: `None` (or the historical absent key —
    /// `Option` revives as `None`) for synchronous DP, `"async:K"` for
    /// bounded-skew async DP. Validated on resume: the skew bound is
    /// part of the delay model, so crossing modes mid-run would
    /// silently change the trajectory.
    pub dp_mode: Option<String>,
    /// Engine snapshots under `--dp-async` at K > 0: every replica's
    /// drained `(params, per-part opts)` copy — the in-flight skew
    /// state, so a resumed segment restarts each replica from exactly
    /// where it drained. Absent when replicas are in lockstep.
    pub dp_replica_states: Option<Vec<DpReplicaState>>,
}

/// One replica's drained copy under bounded-skew async DP (see
/// [`RunState::dp_replica_states`]).
#[derive(Clone, serde::Serialize, serde::Deserialize)]
pub struct DpReplicaState {
    pub replica: usize,
    pub params: Vec<TensorState>,
    pub opts: Vec<OptState>,
}

impl RunState {
    /// Validate the identity fields against the resuming run's
    /// configuration. `replicas` is checked by the caller — the sim
    /// requires an exact match, the elastic engine driver does not.
    #[allow(clippy::too_many_arguments)]
    pub fn expect(
        &self,
        flavor: &str,
        model: &str,
        method: &str,
        schedule: &str,
        stages: usize,
        seed: u64,
        steps: u32,
    ) -> Result<()> {
        fn chk<T: PartialEq + std::fmt::Display>(
            what: &str,
            saved: T,
            run: T,
        ) -> Result<()> {
            if saved != run {
                bail!("checkpoint {what} mismatch: snapshot has {saved}, this run has {run}");
            }
            Ok(())
        }
        if self.step > steps as u64 {
            bail!(
                "checkpoint is at step {} but this run only has {steps} steps",
                self.step
            );
        }
        chk("flavor", self.flavor.as_str(), flavor)?;
        chk("model", self.model.as_str(), model)?;
        chk("method", self.method.as_str(), method)?;
        chk("schedule", self.schedule.as_str(), schedule)?;
        chk("stages", self.stages, stages)?;
        chk("seed", self.seed, seed)?;
        // lr_at's warmup/decay shape depends on the total step budget,
        // so resuming under a different budget silently changes the lr
        // schedule — reject it.
        chk("total steps", self.steps_total, steps)?;
        Ok(())
    }
}

/// Canonical snapshot filename for a step within a checkpoint dir.
pub fn step_path(dir: &Path, step: u64) -> PathBuf {
    dir.join(format!("step{step:06}.json"))
}

/// Newest `step*.json` snapshot in `dir` (by step number), if any —
/// what "resume from the latest checkpoint" means after a crash.
pub fn latest(dir: &Path) -> Result<Option<PathBuf>> {
    let mut best: Option<(u64, PathBuf)> = None;
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(None), // no dir yet: nothing to resume
    };
    for entry in entries {
        let path = entry?.path();
        let name = match path.file_name().and_then(|n| n.to_str()) {
            Some(n) => n,
            None => continue,
        };
        let step: u64 = match name
            .strip_prefix("step")
            .and_then(|r| r.strip_suffix(".json"))
            .and_then(|r| r.parse().ok())
        {
            Some(s) => s,
            None => continue,
        };
        let newer = match &best {
            Some((b, _)) => step > *b,
            None => true,
        };
        if newer {
            best = Some((step, path));
        }
    }
    Ok(best.map(|(_, p)| p))
}

/// Atomically write a snapshot: serialize, write `<path>.tmp`, fsync is
/// elided (the rename gives crash consistency of the *name*: readers
/// see the old snapshot or the new one, never a torn file).
pub fn save(path: &Path, st: &RunState) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    let tmp = path.with_extension("json.tmp");
    fs::write(&tmp, st.to_json())
        .with_context(|| format!("writing {}", tmp.display()))?;
    fs::rename(&tmp, path)
        .with_context(|| format!("renaming into {}", path.display()))?;
    Ok(())
}

/// Load and version-check a snapshot.
pub fn load(path: &Path) -> Result<RunState> {
    let text = fs::read_to_string(path)
        .with_context(|| format!("reading checkpoint {}", path.display()))?;
    let st: RunState = serde::from_str(&text)
        .map_err(|e| anyhow!("parsing checkpoint {}: {e}", path.display()))?;
    if st.version != RUN_STATE_VERSION {
        bail!(
            "checkpoint {} has version {}, this binary reads {}",
            path.display(),
            st.version,
            RUN_STATE_VERSION
        );
    }
    Ok(st)
}

/// Worker w of replica `replica` dies immediately after completing
/// optimizer update `at_update`. A kill landing exactly on a segment
/// boundary is a clean departure (the replica leaves the roster with no
/// work lost); one landing mid-segment crashes the run there and the
/// driver re-runs the segment from the last checkpoint without it.
#[derive(Clone, Copy, Debug)]
pub struct ReplicaKill {
    pub at_update: u64,
    pub replica: usize,
    pub worker: usize,
}

/// `count` replicas join the roster at the `at_update` segment
/// boundary, seeded from the snapshot (all replicas hold identical
/// params/optimizer state under synchronous DP).
#[derive(Clone, Copy, Debug)]
pub struct ReplicaJoin {
    pub at_update: u64,
    pub count: usize,
}

/// Worker w of replica r sleeps `millis` after completing update
/// `at_update` — a timing perturbation that must not change any
/// recorded value (the schedules are deterministic in message order,
/// not arrival time), which the fault-injection tests assert.
#[derive(Clone, Copy, Debug)]
pub struct WorkerDelay {
    pub at_update: u64,
    pub replica: usize,
    pub worker: usize,
    pub millis: u64,
}

/// A deterministic fault schedule for [`run_engine_elastic`].
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub kills: Vec<ReplicaKill>,
    pub joins: Vec<ReplicaJoin>,
    pub delays: Vec<WorkerDelay>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.kills.is_empty() && self.joins.is_empty() && self.delays.is_empty()
    }

    /// Parse a `--kill STEP:REPLICA[:WORKER]` CLI spec.
    pub fn parse_kill(spec: &str) -> Result<ReplicaKill> {
        let parts: Vec<&str> = spec.split(':').collect();
        let bad = || anyhow!("--kill wants STEP:REPLICA[:WORKER], got {spec:?}");
        if parts.len() < 2 || parts.len() > 3 {
            return Err(bad());
        }
        Ok(ReplicaKill {
            at_update: parts[0].parse().map_err(|_| bad())?,
            replica: parts[1].parse().map_err(|_| bad())?,
            worker: parts.get(2).map_or(Ok(0), |w| w.parse()).map_err(|_| bad())?,
        })
    }

    /// Parse a `--join STEP[:COUNT]` CLI spec.
    pub fn parse_join(spec: &str) -> Result<ReplicaJoin> {
        let parts: Vec<&str> = spec.split(':').collect();
        let bad = || anyhow!("--join wants STEP[:COUNT], got {spec:?}");
        if parts.is_empty() || parts.len() > 2 {
            return Err(bad());
        }
        Ok(ReplicaJoin {
            at_update: parts[0].parse().map_err(|_| bad())?,
            count: parts.get(1).map_or(Ok(1), |c| c.parse()).map_err(|_| bad())?,
        })
    }

    /// Parse a `--delay STEP:REPLICA:WORKER:MILLIS` CLI spec.
    pub fn parse_delay(spec: &str) -> Result<WorkerDelay> {
        let parts: Vec<&str> = spec.split(':').collect();
        let bad = || anyhow!("--delay wants STEP:REPLICA:WORKER:MILLIS, got {spec:?}");
        if parts.len() != 4 {
            return Err(bad());
        }
        Ok(WorkerDelay {
            at_update: parts[0].parse().map_err(|_| bad())?,
            replica: parts[1].parse().map_err(|_| bad())?,
            worker: parts[2].parse().map_err(|_| bad())?,
            millis: parts[3].parse().map_err(|_| bad())?,
        })
    }
}

/// Drive the threaded engine with checkpointing, resume, fault
/// injection and an elastic replica roster.
///
/// With no checkpointing, no resume and an empty plan this is exactly
/// [`engine::train_engine`]. Otherwise the run proceeds in segments
/// between boundaries (checkpoint multiples, planned joins, the final
/// step); each completed segment exports the drained weights and
/// per-part optimizer states, which seed the next segment and the
/// periodic [`RunState`] snapshots. A mid-segment replica death crashes
/// the segment; the driver drops the dead replica, re-partitions the
/// shards over the renumbered survivors and re-runs the segment from
/// the last snapshot.
pub fn run_engine_elastic(
    artifacts_dir: &Path,
    cfg: &TrainCfg,
    plan: &FaultPlan,
) -> Result<RunResult> {
    if cfg.checkpoint_every == 0 && cfg.resume.is_none() && plan.is_empty() {
        return engine::train_engine(artifacts_dir.to_path_buf(), cfg);
    }
    if cfg.schedule == ScheduleKind::Amdp {
        bail!(
            "engine checkpointing/fault injection does not support --schedule \
             amdp: its two counter-flowing weight copies per part make a \
             single exported part snapshot ambiguous"
        );
    }
    let model = crate::runtime::Manifest::resolve(artifacts_dir)?.cfg.name.clone();
    let sched = schedule::build(cfg.schedule);
    let mpu = sched
        .micro_per_update(cfg.stages, cfg.microbatches as usize)
        .max(1) as u64;
    let steps = cfg.steps as u64;
    let every = cfg.checkpoint_every as u64;
    let ckpt_dir: PathBuf = cfg
        .checkpoint_dir
        .clone()
        .unwrap_or_else(|| "checkpoints".into())
        .into();

    let mut roster = cfg.dp_replicas();
    let mut state: Option<EngineCheckpoint> = None;
    let mut losses: Vec<f32> = Vec::new();
    let mut val_losses: Vec<(u32, f32)> = Vec::new();
    let mut start: u64 = 0;
    if let Some(path) = &cfg.resume {
        let st = load(Path::new(path))?;
        st.expect(
            "engine",
            &model,
            &cfg.method.name(),
            &cfg.schedule.name(),
            cfg.stages,
            cfg.seed,
            cfg.steps,
        )?;
        if st.dp_mode != cfg.dp_mode() {
            bail!(
                "checkpoint DP mode mismatch: snapshot was taken under {}, \
                 this run uses {} (the skew bound is part of the delay model; \
                 resume with the original --dp-async/--max-skew flags)",
                st.dp_mode.as_deref().unwrap_or("sync"),
                cfg.dp_mode().as_deref().unwrap_or("sync")
            );
        }
        roster = st.replicas;
        losses = st.losses.clone();
        val_losses = st.val_losses.clone();
        start = st.step;
        // Per-replica skew state (async DP at K > 0) rides along so
        // each replica restarts from exactly where it drained.
        let replica_states = st
            .dp_replica_states
            .as_ref()
            .map(|rs| {
                rs.iter()
                    .map(|r| {
                        (
                            r.replica,
                            r.params.iter().map(|t| t.to_tensor()).collect(),
                            r.opts.clone(),
                        )
                    })
                    .collect()
            })
            .unwrap_or_default();
        state = Some(EngineCheckpoint {
            step: st.step,
            params: st.params.iter().map(|t| t.to_tensor()).collect(),
            opts: st.opts.clone(),
            replica_states,
        });
    }

    let mut kills: Vec<ReplicaKill> =
        plan.kills.iter().filter(|k| k.at_update > start).copied().collect();
    let joins: Vec<ReplicaJoin> =
        plan.joins.iter().filter(|j| j.at_update > start).copied().collect();

    let mut last: Option<RunResult> = None;
    let mut total_dispatches = 0u64;
    let mut wall = 0.0f64;
    let mut driver_spans: Vec<trace::Span> = Vec::new();
    let mut driver_clock_us = 0.0f64;
    while start < steps {
        let mut end = steps;
        if every > 0 {
            end = end.min((start / every + 1) * every);
        }
        if let Some(j) =
            joins.iter().map(|j| j.at_update).filter(|&u| u > start).min()
        {
            end = end.min(j);
        }
        let mut cfg_seg = cfg.clone();
        cfg_seg.replicas = roster;
        let opts = SegmentOpts {
            start_update: start,
            end_update: end,
            export_state: every > 0 || end < steps,
            kills: kills
                .iter()
                .filter(|k| k.at_update > start && k.at_update < end)
                .map(|k| (k.replica, k.worker, k.at_update))
                .collect(),
            delays: plan
                .delays
                .iter()
                .filter(|d| d.at_update > start && d.at_update <= end)
                .map(|d| (d.replica, d.worker, d.at_update, d.millis))
                .collect(),
        };
        let (res, export) =
            engine::train_engine_segment(artifacts_dir.to_path_buf(), &cfg_seg, &opts, state.as_ref())?;
        wall += res.wall_secs;
        total_dispatches += res.dispatches;
        if res.diverged {
            let mut out = res;
            losses.extend(out.losses.iter().copied());
            val_losses.extend(out.val_losses.iter().copied());
            out.losses = losses;
            out.val_losses = val_losses;
            out.dispatches = total_dispatches;
            out.wall_secs = wall;
            return Ok(out);
        }
        let done = res.losses.len() as u64 == end - start;
        if !done {
            // Mid-segment crash: only a planned kill explains it.
            let dead: Vec<usize> = kills
                .iter()
                .filter(|k| k.at_update > start && k.at_update < end)
                .map(|k| k.replica)
                .collect();
            if dead.is_empty() {
                bail!(
                    "engine segment [{start}, {end}) stopped after {} of {} \
                     updates with no planned fault",
                    res.losses.len(),
                    end - start
                );
            }
            kills.retain(|k| !(k.at_update > start && k.at_update < end));
            let mut gone = dead.clone();
            gone.sort_unstable();
            gone.dedup();
            if gone.len() >= roster {
                bail!("fault plan kills every replica of the roster at step {start}");
            }
            roster -= gone.len();
            collapse_skew_state(&mut state);
            trace::progress(format!(
                "  [elastic] replica death mid-segment; re-sharding onto \
                 R={roster} survivors and re-running from step {start}"
            ));
            continue;
        }
        losses.extend(res.losses.iter().copied());
        val_losses.extend(res.val_losses.iter().copied());
        if opts.export_state {
            state = Some(export.ok_or_else(|| {
                anyhow!("completed engine segment returned no state export")
            })?);
        }
        last = Some(res);
        start = end;
        // Boundary roster changes: clean departures and planned joins.
        let leaving: Vec<usize> = kills
            .iter()
            .filter(|k| k.at_update == end)
            .map(|k| k.replica)
            .collect();
        if !leaving.is_empty() {
            let mut gone = leaving;
            gone.sort_unstable();
            gone.dedup();
            if gone.len() >= roster {
                bail!("fault plan kills every replica of the roster at step {end}");
            }
            roster -= gone.len();
            kills.retain(|k| k.at_update != end);
            collapse_skew_state(&mut state);
            trace::progress(format!(
                "  [elastic] clean departure at step {end}; R={roster}"
            ));
        }
        let joining: usize =
            joins.iter().filter(|j| j.at_update == end).map(|j| j.count).sum();
        if joining > 0 {
            roster += joining;
            collapse_skew_state(&mut state);
            trace::progress(format!(
                "  [elastic] {joining} replica(s) join at step {end}; R={roster}"
            ));
        }
        if every > 0 && start % every == 0 && start < steps {
            let ck = state.as_ref().expect("export_state held a snapshot");
            let st = RunState {
                version: RUN_STATE_VERSION,
                flavor: "engine".to_string(),
                model: model.clone(),
                method: cfg.method.name(),
                schedule: cfg.schedule.name(),
                stages: cfg.stages,
                replicas: roster,
                seed: cfg.seed,
                steps_total: cfg.steps,
                step: start,
                params: ck.params.iter().map(TensorState::of).collect(),
                opts: ck.opts.clone(),
                stash: None,
                train_cursors: (0..roster)
                    .map(|r| DataCursor {
                        stream0: replica_stream(TRAIN_STREAM, r),
                        drawn: start * mpu,
                    })
                    .collect(),
                val_cursor: None,
                losses: losses.clone(),
                val_losses: val_losses.clone(),
                dispatches: Vec::new(),
                dp_mode: cfg.dp_mode(),
                dp_replica_states: if ck.replica_states.is_empty() {
                    None
                } else {
                    Some(
                        ck.replica_states
                            .iter()
                            .map(|(rep, ps, os)| DpReplicaState {
                                replica: *rep,
                                params: ps.iter().map(TensorState::of).collect(),
                                opts: os.clone(),
                            })
                            .collect(),
                    )
                },
            };
            let path = step_path(&ckpt_dir, start);
            let t_save = std::time::Instant::now();
            save(&path, &st)?;
            let save_us = t_save.elapsed().as_secs_f64() * 1e6;
            // The driver writes checkpoints between segments; give those
            // writes their own timeline row in the trace (the segment
            // just rewrote the file with its worker spans, so append).
            if let Some(tp) = &cfg.trace {
                driver_spans.push(trace::Span {
                    kind: trace::SpanKind::Checkpoint,
                    chunk: -1,
                    mb: -1,
                    step: start as i64,
                    ts_us: driver_clock_us,
                    dur_us: save_us,
                    n_disp: 0,
                });
                driver_clock_us += save_us;
                trace::append_events(tp, 0, 999, "driver/ckpt", &driver_spans)?;
            }
            if cfg.log_every > 0 {
                trace::progress(format!(
                    "  [ckpt] step {start} -> {} ({:.1} ms)",
                    path.display(),
                    save_us / 1e3
                ));
            }
        }
    }
    let mut out = last.ok_or_else(|| anyhow!("elastic run executed no segment"))?;
    out.losses = losses;
    out.val_losses = val_losses;
    out.replicas = roster;
    out.dispatches = total_dispatches;
    out.wall_secs = wall;
    Ok(out)
}

/// A roster change renumbers the survivors, so per-replica async-DP
/// skew state saved under the old numbering no longer applies: drop it
/// and re-seed every replica from the canonical replica-0 copy.
fn collapse_skew_state(state: &mut Option<EngineCheckpoint>) {
    if let Some(ck) = state.as_mut() {
        if !ck.replica_states.is_empty() {
            ck.replica_states.clear();
            trace::progress(
                "  [elastic] roster changed; collapsing async-DP skew state \
                 onto the replica-0 snapshot",
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use serde::Serialize;

    #[test]
    fn fault_specs_parse_and_reject_garbage() {
        let k = FaultPlan::parse_kill("10:1").unwrap();
        assert_eq!((k.at_update, k.replica, k.worker), (10, 1, 0));
        let k = FaultPlan::parse_kill("5:0:3").unwrap();
        assert_eq!((k.at_update, k.replica, k.worker), (5, 0, 3));
        assert!(FaultPlan::parse_kill("oops").is_err());
        assert!(FaultPlan::parse_kill("1:2:3:4").is_err());
        let j = FaultPlan::parse_join("10").unwrap();
        assert_eq!((j.at_update, j.count), (10, 1));
        let j = FaultPlan::parse_join("10:2").unwrap();
        assert_eq!((j.at_update, j.count), (10, 2));
        let d = FaultPlan::parse_delay("5:0:1:50").unwrap();
        assert_eq!((d.at_update, d.replica, d.worker, d.millis), (5, 0, 1, 50));
        assert!(FaultPlan::parse_delay("5:0:1").is_err());
    }

    fn tiny_state(step: u64) -> RunState {
        RunState {
            version: RUN_STATE_VERSION,
            flavor: "sim".to_string(),
            model: "pico4".to_string(),
            method: "pipedream".to_string(),
            schedule: "1f1b".to_string(),
            stages: 4,
            replicas: 1,
            seed: 2024,
            steps_total: 20,
            step,
            params: vec![TensorState { shape: vec![2], data: vec![0.5, -1.25] }],
            opts: Vec::new(),
            stash: Some(StashSnapshot {
                rings: vec![vec![TensorState { shape: vec![2], data: vec![0.0, 0.0] }]],
            }),
            train_cursors: vec![DataCursor { stream0: 1, drawn: step }],
            val_cursor: None,
            losses: vec![3.5, 3.25],
            val_losses: vec![(10, 3.125)],
            dispatches: vec![step],
            dp_mode: None,
            dp_replica_states: None,
        }
    }

    #[test]
    fn save_load_round_trips_atomically() {
        let dir = std::env::temp_dir().join("abrot_ckpt_test_roundtrip");
        let path = step_path(&dir, 10);
        save(&path, &tiny_state(10)).unwrap();
        // the tmp file must not survive the rename
        assert!(!path.with_extension("json.tmp").exists());
        let st = load(&path).unwrap();
        assert_eq!(st.step, 10);
        assert_eq!(st.params[0].data, vec![0.5, -1.25]);
        assert_eq!(st.losses, vec![3.5, 3.25]);
        assert_eq!(st.val_losses, vec![(10, 3.125)]);
        assert_eq!(st.train_cursors[0].drawn, 10);
        save(&step_path(&dir, 15), &tiny_state(15)).unwrap();
        let newest = latest(&dir).unwrap().unwrap();
        assert_eq!(newest, step_path(&dir, 15));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn expect_rejects_mismatched_identity() {
        let st = tiny_state(10);
        st.expect("sim", "pico4", "pipedream", "1f1b", 4, 2024, 20).unwrap();
        for (err_contains, res) in [
            ("flavor", st.expect("engine", "pico4", "pipedream", "1f1b", 4, 2024, 20)),
            ("model", st.expect("sim", "pico8", "pipedream", "1f1b", 4, 2024, 20)),
            ("method", st.expect("sim", "pico4", "nesterov", "1f1b", 4, 2024, 20)),
            ("schedule", st.expect("sim", "pico4", "pipedream", "gpipe", 4, 2024, 20)),
            ("stages", st.expect("sim", "pico4", "pipedream", "1f1b", 2, 2024, 20)),
            ("seed", st.expect("sim", "pico4", "pipedream", "1f1b", 4, 7, 20)),
            ("total steps", st.expect("sim", "pico4", "pipedream", "1f1b", 4, 2024, 40)),
            ("step", st.expect("sim", "pico4", "pipedream", "1f1b", 4, 2024, 5)),
        ] {
            let msg = res.unwrap_err().to_string();
            assert!(msg.contains(err_contains), "{err_contains}: {msg}");
        }
    }

    #[test]
    fn load_rejects_foreign_versions_and_torn_files() {
        let dir = std::env::temp_dir().join("abrot_ckpt_test_versions");
        std::fs::create_dir_all(&dir).unwrap();
        let mut st = tiny_state(10);
        st.version = RUN_STATE_VERSION + 1;
        let path = dir.join("vnext.json");
        std::fs::write(&path, st.to_json()).unwrap();
        let msg = load(&path).unwrap_err().to_string();
        assert!(msg.contains("version"), "{msg}");
        // a torn write (truncated JSON) must fail to parse, loudly
        let torn = dir.join("torn.json");
        let full = tiny_state(10).to_json();
        std::fs::write(&torn, &full[..full.len() / 2]).unwrap();
        assert!(load(&torn).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
