//! Analytic & measurement tooling for the paper's appendix results:
//!
//! * `stages` — Table 1: minimum pipeline stages for LLaMA models on
//!   commodity GPUs (Appendix A memory model).
//! * `memory` — Table 2: per-matrix memory overhead of the four
//!   basis-rotation strategies on Llama-3-8B (Appendix H).
//! * `hessian` — Fig. 11: Hessian (1,1)-norm estimation via HVPs with
//!   random Cauchy vectors (Xie et al. 2025), and update-oscillation
//!   tracking along the dominant Hessian eigenvector.

use anyhow::Result;

use crate::config::{Geometry, Source};
use crate::optim::rotation::rotation_overhead_elems;
use crate::rngs::Rng;
use crate::runtime::{tensor_to_value, tokens_to_value, Runtime, Value};
use crate::tensor::Tensor;

// ---------------------------------------------------------------------------
// Table 1 (Appendix A): stage-count calculator
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct LlamaModel {
    pub name: &'static str,
    pub h: u64,
    pub a: u64,
    /// parameters per transformer block
    pub w: u64,
    pub l: u64,
}

#[derive(Clone, Debug)]
pub struct Gpu {
    pub name: &'static str,
    pub mem_bytes: u64,
}

pub fn llama_models() -> Vec<LlamaModel> {
    vec![
        LlamaModel { name: "Llama 3.2 1B", h: 2048, a: 32, w: 67_000_000, l: 16 },
        LlamaModel { name: "Llama 3.2 3B", h: 3072, a: 24, w: 113_000_000, l: 28 },
        LlamaModel { name: "LLaMA 1-7B", h: 4096, a: 32, w: 202_000_000, l: 32 },
        LlamaModel { name: "LLaMA 1-13B", h: 5120, a: 40, w: 317_000_000, l: 40 },
        LlamaModel { name: "LLaMA 1-33B", h: 6656, a: 52, w: 535_000_000, l: 60 },
        LlamaModel { name: "LLaMA 1-65B", h: 8192, a: 64, w: 810_000_000, l: 80 },
        LlamaModel { name: "Llama 3.1 405B", h: 16384, a: 128, w: 3_190_000_000, l: 126 },
    ]
}

pub fn gpus() -> Vec<Gpu> {
    let gib = 1u64 << 30;
    vec![
        Gpu { name: "RTX3070 (8GB)", mem_bytes: 8 * gib },
        Gpu { name: "RTX3080 (16GB)", mem_bytes: 16 * gib },
        Gpu { name: "RTX3090 (24GB)", mem_bytes: 24 * gib },
        Gpu { name: "A6000 (48GB)", mem_bytes: 48 * gib },
        Gpu { name: "A100 (80GB)", mem_bytes: 80 * gib },
    ]
}

/// Appendix A Eq. (7): bytes for one block with mixed-precision AdamW
/// training and checkpointed activations.
pub fn block_bytes(w: u64, s: u64, b: u64, h: u64, a: u64) -> u64 {
    16 * w + 34 * s * b * h + 5 * b * a * s * s
}

/// Required stages for a model on a device (Appendix A). Returns
/// (stages, lower_bound_only): when even one block does not fit,
/// the paper reports "≥ 2L".
pub fn required_stages(m: &LlamaModel, gpu: &Gpu, s: u64, b: u64) -> (u64, bool) {
    let mb = block_bytes(m.w, s, b, m.h, m.a);
    let n_max = gpu.mem_bytes / mb;
    if n_max == 0 {
        (2 * m.l, true)
    } else {
        (m.l.div_ceil(n_max), false)
    }
}

/// Render Table 1 rows (s=4096, b=1, like the paper).
pub fn table1_rows() -> Vec<(String, Vec<String>)> {
    llama_models()
        .iter()
        .map(|m| {
            let cells = gpus()
                .iter()
                .map(|g| {
                    let (p, lb) = required_stages(m, g, 4096, 1);
                    if lb { format!(">={p}*") } else { format!("{p}") }
                })
                .collect();
            (m.name.to_string(), cells)
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Table 2 (Appendix H): memory overhead calculator
// ---------------------------------------------------------------------------

pub struct Table2Row {
    pub source: Source,
    pub geometry: Geometry,
    pub attn_gb: f64,
    pub mlp_gb: f64,
}

pub fn table2_rows() -> Vec<Table2Row> {
    let gb = |e: usize| e as f64 * 4.0 / 1e9;
    let mut rows = Vec::new();
    for source in [Source::Second, Source::First] {
        for geometry in [Geometry::Bilateral, Geometry::Unilateral] {
            rows.push(Table2Row {
                source,
                geometry,
                attn_gb: gb(rotation_overhead_elems(4096, 4096, source, geometry)),
                mlp_gb: gb(rotation_overhead_elems(4096, 14336, source, geometry)),
            });
        }
    }
    rows
}

// ---------------------------------------------------------------------------
// Fig. 11: Hessian (1,1)-norm via Cauchy HVPs + oscillation tracking
// ---------------------------------------------------------------------------

fn flat_len(params: &[Tensor]) -> usize {
    params.iter().map(|p| p.len()).sum()
}

fn hvp(
    rt: &Runtime,
    params: &[Tensor],
    vec: &[Tensor],
    toks: &[i32],
    tgts: &[i32],
) -> Result<Vec<Tensor>> {
    let cfg = rt.cfg();
    let mut ins: Vec<Value> = Vec::with_capacity(2 * params.len() + 2);
    for p in params {
        ins.push(tensor_to_value(p)?);
    }
    for v in vec {
        ins.push(tensor_to_value(v)?);
    }
    ins.push(tokens_to_value(toks, cfg.batch, cfg.seq)?);
    ins.push(tokens_to_value(tgts, cfg.batch, cfg.seq)?);
    rt.exec_tensors("hvp", &ins)
}

/// Estimate the normalized Hessian (1,1)-norm ‖H‖₁,₁/d via the Cauchy
/// trace estimator of Xie et al. 2025: for s ~ Cauchy(0,1)ᵈ,
/// median-of-means of sᵀ' H s with the sign trick reduces to estimating
/// E[|Σ_j H_ij s_j|] = (2/π)·Σ_j |H_ij| per row; averaging |vᵀ (Hs)|
/// over Cauchy probes estimates (2/π)·‖H‖₁,₁ when v = sign pattern.
/// We use the practical estimator: E_s[ ‖H s‖₁ / scale ] with Cauchy s,
/// whose median over probes is proportional to ‖H‖₁,₁ row-sums; the
/// constant cancels in the *ratio* reported by the paper (before vs
/// after rotation), which is what we reproduce.
pub fn hessian_11_norm(
    rt: &Runtime,
    params: &[Tensor],
    n_probes: usize,
    seed: u64,
) -> Result<f64> {
    let cfg = rt.cfg().clone();
    let corpus = crate::data::Corpus::new(cfg.vocab, seed ^ 0xDA7A);
    let mut it = crate::data::BatchIter::new(corpus, cfg.batch, cfg.seq, 77);
    let mut rng = Rng::new(seed);
    let d = flat_len(params) as f64;
    let mut estimates = Vec::with_capacity(n_probes);
    for _ in 0..n_probes {
        let probe: Vec<Tensor> = params
            .iter()
            .map(|p| {
                let mut t = Tensor::zeros(&p.shape);
                for x in t.data.iter_mut() {
                    *x = rng.cauchy();
                }
                t
            })
            .collect();
        let (toks, tgts) = it.next_batch();
        let hv = hvp(rt, params, &probe, &toks, &tgts)?;
        let l1: f64 = hv.iter().map(|t| t.abs_sum() as f64).sum();
        estimates.push(l1 / d);
    }
    // median for heavy-tailed robustness
    estimates.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(estimates[estimates.len() / 2])
}

/// Dominant Hessian eigenvector via power iteration on HVPs.
pub fn dominant_eigvec(
    rt: &Runtime,
    params: &[Tensor],
    iters: usize,
    seed: u64,
) -> Result<Vec<Tensor>> {
    let cfg = rt.cfg().clone();
    let corpus = crate::data::Corpus::new(cfg.vocab, seed ^ 0xDA7A);
    let mut it = crate::data::BatchIter::new(corpus, cfg.batch, cfg.seq, 78);
    let mut rng = Rng::new(seed ^ 0xE16);
    let mut v: Vec<Tensor> = params
        .iter()
        .map(|p| {
            let mut t = Tensor::zeros(&p.shape);
            rng.fill_normal(&mut t.data, 1.0);
            t
        })
        .collect();
    for _ in 0..iters {
        let (toks, tgts) = it.next_batch();
        let hv = hvp(rt, params, &v, &toks, &tgts)?;
        let norm: f32 = hv.iter().map(|t| t.norm().powi(2)).sum::<f32>().sqrt();
        v = hv.into_iter().map(|t| t.scale(1.0 / norm.max(1e-20))).collect();
    }
    Ok(v)
}

/// Projection of a parameter delta onto a (flattened) direction.
pub fn project(delta: &[Tensor], dir: &[Tensor]) -> f32 {
    delta.iter().zip(dir).map(|(d, v)| d.dot(v)).sum()
}

/// Orthogonalize `v` against `against` and normalize (non-dominant
/// direction construction, paper D.3).
pub fn orthogonalize(v: &mut [Tensor], against: &[Tensor]) {
    let dot: f32 = v.iter().zip(against).map(|(a, b)| a.dot(b)).sum();
    for (vi, ai) in v.iter_mut().zip(against) {
        vi.axpy(-dot, ai);
    }
    let norm: f32 = v.iter().map(|t| t.norm().powi(2)).sum::<f32>().sqrt();
    for vi in v.iter_mut() {
        *vi = vi.scale(1.0 / norm.max(1e-20));
    }
}

/// Fig. 11 end-to-end report for one method: train, estimate the
/// Hessian (1,1)-norm and the update-oscillation scores along the
/// dominant / a non-dominant eigenvector.
pub struct AlignmentReport {
    pub h11: f64,
    pub osc_dom: f32,
    pub osc_nondom: f32,
}

pub fn alignment_report(
    rt: &Runtime,
    cfg: &crate::config::TrainCfg,
    probes: usize,
) -> Result<AlignmentReport> {
    // Phase 1: train to the midpoint, keep the params.
    let (_, params) =
        crate::pipeline::train_sim_observed(rt, cfg, &mut |_t, _p| {})?;
    let h11 = hessian_11_norm(rt, &params, probes, cfg.seed ^ 0x1111)?;
    let dom = dominant_eigvec(rt, &params, 10, cfg.seed ^ 0x2222)?;
    let mut nondom: Vec<Tensor> = {
        let mut rng = Rng::new(cfg.seed ^ 0x3333);
        params
            .iter()
            .map(|p| {
                let mut t = Tensor::zeros(&p.shape);
                rng.fill_normal(&mut t.data, 1.0);
                t
            })
            .collect()
    };
    orthogonalize(&mut nondom, &dom);

    // Phase 2: rerun deterministically for `tail` extra steps and track
    // update projections along the two directions (paper D.3: 100 its).
    let tail = 60u32;
    let mut cfg2 = cfg.clone();
    cfg2.steps = cfg.steps + tail;
    let mut prev: Option<Vec<Tensor>> = None;
    let mut proj_dom = Vec::new();
    let mut proj_non = Vec::new();
    let from = cfg.steps as u64;
    crate::pipeline::train_sim_observed(rt, &cfg2, &mut |t, p| {
        if t >= from {
            if let Some(prev) = &prev {
                let delta: Vec<Tensor> =
                    p.iter().zip(prev).map(|(a, b)| a.sub(b)).collect();
                proj_dom.push(project(&delta, &dom));
                proj_non.push(project(&delta, &nondom));
            }
            prev = Some(p.to_vec());
        }
    })?;
    Ok(AlignmentReport {
        h11,
        osc_dom: oscillation_score(&proj_dom),
        osc_nondom: oscillation_score(&proj_non),
    })
}

/// Oscillation score of a projection series: mean |sign flip| weighted
/// by magnitude — the quantity Fig. 11 plots qualitatively.
pub fn oscillation_score(projections: &[f32]) -> f32 {
    if projections.len() < 2 {
        return 0.0;
    }
    let mut flips = 0.0f32;
    for w in projections.windows(2) {
        if w[0].signum() != w[1].signum() {
            flips += (w[0] - w[1]).abs();
        }
    }
    flips / (projections.len() - 1) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper_anchor_cells() {
        // Paper Table 1 anchors (s=4096, b=1).
        let models = llama_models();
        let gs = gpus();
        let find = |m: &str| models.iter().find(|x| x.name == m).unwrap().clone();
        let g = |n: &str| gs.iter().find(|x| x.name.starts_with(n)).unwrap().clone();
        // Anchors our Eq.-(7) memory model reproduces exactly from the
        // paper's Table 1 (the 1B row needs extra unstated terms — see
        // EXPERIMENTS.md; orderings still hold there).
        assert_eq!(required_stages(&find("Llama 3.2 1B"), &g("A100"), 4096, 1).0, 1);
        assert_eq!(required_stages(&find("LLaMA 1-7B"), &g("RTX3090"), 4096, 1).0, 11);
        assert_eq!(required_stages(&find("LLaMA 1-65B"), &g("A100"), 4096, 1).0, 20);
        let (p, lb) = required_stages(&find("LLaMA 1-13B"), &g("RTX3070"), 4096, 1);
        assert!(lb);
        assert_eq!(p, 80);
        let (p405, lb405) =
            required_stages(&find("Llama 3.1 405B"), &g("A100"), 4096, 1);
        assert!(!lb405);
        assert_eq!(p405, 126);
        // monotonicity: stages never increase with GPU memory
        for m in &models {
            let mut prev = u64::MAX;
            for gpu in &gs {
                let (p, _) = required_stages(m, gpu, 4096, 1);
                assert!(p <= prev, "{} on {}", m.name, gpu.name);
                prev = p;
            }
        }
    }

    #[test]
    fn table2_orderings() {
        let rows = table2_rows();
        let get = |s: Source, g: Geometry| {
            rows.iter()
                .find(|r| r.source == s && r.geometry == g)
                .unwrap()
        };
        use Geometry::*;
        use Source::*;
        // paper Table 2 values (GB): 2nd/Bi 0.25/1.66; 1st/Uni 0.06/0.06
        let r = get(Second, Bilateral);
        assert!((r.attn_gb - 0.268).abs() < 0.03 && (r.mlp_gb - 1.78).abs() < 0.2);
        let r = get(First, Unilateral);
        assert!(r.attn_gb < 0.08 && r.mlp_gb < 0.08);
        // monotone orderings
        assert!(get(First, Bilateral).mlp_gb < get(Second, Bilateral).mlp_gb);
        assert!(get(Second, Unilateral).mlp_gb < get(Second, Bilateral).mlp_gb);
    }

    #[test]
    fn oscillation_score_detects_flipping() {
        let osc = [1.0f32, -1.0, 1.0, -1.0, 1.0];
        let smooth = [1.0f32, 0.9, 0.8, 0.7, 0.6];
        assert!(oscillation_score(&osc) > 10.0 * oscillation_score(&smooth).max(1e-9));
    }

    #[test]
    fn orthogonalize_makes_perpendicular() {
        let a = vec![Tensor::new(vec![2], vec![1.0, 0.0])];
        let mut b = vec![Tensor::new(vec![2], vec![0.7, 0.7])];
        orthogonalize(&mut b, &a);
        assert!(project(&b, &a).abs() < 1e-6);
        assert!((b[0].norm() - 1.0).abs() < 1e-6);
    }
}
