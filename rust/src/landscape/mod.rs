//! 2-D optimization lab — reproduces the paper's diagnostic figures:
//!
//! * Fig. 3: AdaSGD vs Adam on an ill-conditioned quadratic, Hessian
//!   aligned vs 45°-rotated, with and without delay (τ = 2).
//! * Fig. 4: Adam on the spiral loss
//!   `f(r,θ) = r² + (20·sin(4r−θ)+1)²`, and the slowdown ratio
//!   `T_delay / T_no-delay` along the trajectory.
//!
//! These run in microseconds and carry the paper's core mechanism in a
//! form unit tests can assert on.

use crate::rngs::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Opt2d {
    /// Adam with coordinate-wise second moments.
    Adam,
    /// AdaSGD (Wang & Wiens 2020): one global adaptive scale — the EMA
    /// of the *mean* second moment across coordinates.
    AdaSgd,
}

#[derive(Clone, Debug)]
pub struct Trajectory {
    pub points: Vec<[f64; 2]>,
    pub losses: Vec<f64>,
}

/// Generic delayed optimizer driver on an arbitrary 2-D loss.
/// `rotate`: optional orthogonal basis (columns) in which the adaptive
/// scaling is applied (basis rotation).
#[allow(clippy::too_many_arguments)]
pub fn run_2d(
    grad: &dyn Fn([f64; 2]) -> [f64; 2],
    loss: &dyn Fn([f64; 2]) -> f64,
    x0: [f64; 2],
    opt: Opt2d,
    lr: f64,
    beta1: f64,
    beta2: f64,
    delay: usize,
    steps: usize,
    rotate: Option<[[f64; 2]; 2]>,
) -> Trajectory {
    let mut x = x0;
    let mut m = [0.0f64; 2];
    let mut v = [0.0f64; 2];
    let mut v_scalar = 0.0f64;
    let eps = 1e-8;
    let mut ring: Vec<[f64; 2]> = vec![x0; delay + 1];
    let mut points = vec![x0];
    let mut losses = vec![loss(x0)];
    let rot = |g: [f64; 2], q: &[[f64; 2]; 2]| {
        // q^T g (columns of q are the basis)
        [q[0][0] * g[0] + q[1][0] * g[1], q[0][1] * g[0] + q[1][1] * g[1]]
    };
    let unrot = |g: [f64; 2], q: &[[f64; 2]; 2]| {
        [q[0][0] * g[0] + q[0][1] * g[1], q[1][0] * g[0] + q[1][1] * g[1]]
    };
    for _t in 1..=steps {
        let stale = ring[0];
        let mut g = grad(stale);
        if let Some(q) = &rotate {
            g = rot(g, q);
        }
        for i in 0..2 {
            m[i] = beta1 * m[i] + (1.0 - beta1) * g[i];
        }
        let mut step = [0.0f64; 2];
        match opt {
            Opt2d::Adam => {
                for i in 0..2 {
                    v[i] = beta2 * v[i] + (1.0 - beta2) * g[i] * g[i];
                    step[i] = m[i] / (v[i].sqrt() + eps);
                }
            }
            Opt2d::AdaSgd => {
                let mean_sq = (g[0] * g[0] + g[1] * g[1]) / 2.0;
                v_scalar = beta2 * v_scalar + (1.0 - beta2) * mean_sq;
                for i in 0..2 {
                    step[i] = m[i] / (v_scalar.sqrt() + eps);
                }
            }
        }
        if let Some(q) = &rotate {
            step = unrot(step, q);
        }
        for i in 0..2 {
            x[i] -= lr * step[i];
        }
        ring.remove(0);
        ring.push(x);
        points.push(x);
        losses.push(loss(x));
    }
    Trajectory { points, losses }
}

// ---------------------------------------------------------------------------
// Fig. 3: quadratic landscape
// ---------------------------------------------------------------------------

/// ½ xᵀHx with H = Q Λ Qᵀ, Λ = diag(λ1, λ2); `angle` rotates the basis.
pub fn quadratic(lam: [f64; 2], angle_deg: f64) -> (impl Fn([f64; 2]) -> [f64; 2], impl Fn([f64; 2]) -> f64, [[f64; 2]; 2]) {
    let th = angle_deg.to_radians();
    let q = [[th.cos(), -th.sin()], [th.sin(), th.cos()]];
    let h = {
        let mut h = [[0.0f64; 2]; 2];
        for i in 0..2 {
            for j in 0..2 {
                for k in 0..2 {
                    h[i][j] += q[i][k] * lam[k] * q[j][k];
                }
            }
        }
        h
    };
    let grad = move |x: [f64; 2]| {
        [h[0][0] * x[0] + h[0][1] * x[1], h[1][0] * x[0] + h[1][1] * x[1]]
    };
    let loss = move |x: [f64; 2]| {
        0.5 * (x[0] * (h[0][0] * x[0] + h[0][1] * x[1])
            + x[1] * (h[1][0] * x[0] + h[1][1] * x[1]))
    };
    (grad, loss, q)
}

/// One row of the Fig.-3 grid: tail loss for {AdaSGD, Adam} × {aligned,
/// misaligned} × {delay 0, delay τ} (+ Adam rotated under delay).
pub struct Fig3Row {
    pub opt: &'static str,
    pub aligned: bool,
    pub delay: usize,
    pub tail_loss: f64,
}

pub fn fig3_grid(delay: usize) -> Vec<Fig3Row> {
    let lam = [100.0, 1.0];
    let x0 = [3.0, 0.5];
    let mut rows = Vec::new();
    for (opt, name) in [(Opt2d::AdaSgd, "adasgd"), (Opt2d::Adam, "adam")] {
        for aligned in [true, false] {
            for d in [0usize, delay] {
                let (g, l, _q) = quadratic(lam, if aligned { 0.0 } else { 45.0 });
                let tr = run_2d(&g, &l, x0, opt, 0.05, 0.0, 0.5, d, 400, None);
                let tail = tail_mean(&tr.losses, 20);
                rows.push(Fig3Row { opt: name, aligned, delay: d, tail_loss: tail });
            }
        }
    }
    // Adam + basis rotation on the misaligned quadratic under delay
    let (g, l, q) = quadratic(lam, 45.0);
    let tr = run_2d(&g, &l, x0, Opt2d::Adam, 0.05, 0.0, 0.5, delay, 400, Some(q));
    rows.push(Fig3Row {
        opt: "adam+rot",
        aligned: false,
        delay,
        tail_loss: tail_mean(&tr.losses, 20),
    });
    rows
}

pub fn tail_mean(xs: &[f64], k: usize) -> f64 {
    let n = xs.len().min(k);
    xs[xs.len() - n..].iter().sum::<f64>() / n as f64
}

// ---------------------------------------------------------------------------
// Fig. 4: spiral loss
// ---------------------------------------------------------------------------

/// f(r,θ) = r² + (20·sin(4r − θ) + 1)² in Cartesian coordinates.
pub fn spiral_loss(x: [f64; 2]) -> f64 {
    let r = (x[0] * x[0] + x[1] * x[1]).sqrt();
    let th = x[1].atan2(x[0]);
    let s = 20.0 * (4.0 * r - th).sin() + 1.0;
    r * r + s * s
}

pub fn spiral_grad(x: [f64; 2]) -> [f64; 2] {
    // numerical gradient: the paper's landscape is diagnostic, not a
    // performance path; central differences are exact enough.
    let h = 1e-6;
    let mut g = [0.0f64; 2];
    for i in 0..2 {
        let mut xp = x;
        let mut xm = x;
        xp[i] += h;
        xm[i] -= h;
        g[i] = (spiral_loss(xp) - spiral_loss(xm)) / (2.0 * h);
    }
    g
}

/// Fig. 4b: slowdown ratio T_delay/T_no-delay to advance a fixed angular
/// interval, sampled at points along the no-delay trajectory.
pub struct SpiralSample {
    pub angle_deg: f64,
    pub slowdown: f64,
}

pub fn spiral_slowdowns(n_samples: usize, seed: u64) -> Vec<SpiralSample> {
    // lr = 0.01 tracks the spiral valley (width ~1/80); larger steps
    // hop across it and never advance.
    let base = run_2d(&spiral_grad, &spiral_loss, [4.0, 0.0], Opt2d::Adam, 0.01,
                      0.0, 0.9, 0, 8000, None);
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for _ in 0..n_samples {
        let idx = 200 + rng.below(base.points.len().saturating_sub(400));
        let start = base.points[idx];
        let ang0 = start[1].atan2(start[0]);
        let advance = 3.0f64.to_radians();
        let count_iters = |delay: usize| -> Option<f64> {
            let tr = run_2d(&spiral_grad, &spiral_loss, start, Opt2d::Adam, 0.01,
                            0.0, 0.9, delay, 6000, None);
            for (i, p) in tr.points.iter().enumerate().skip(1) {
                let a = p[1].atan2(p[0]);
                // unwrap relative to ang0 (the trajectory spirals inward,
                // angle increases)
                let mut da = a - ang0;
                while da < -std::f64::consts::PI {
                    da += 2.0 * std::f64::consts::PI;
                }
                while da > std::f64::consts::PI {
                    da -= 2.0 * std::f64::consts::PI;
                }
                if da.abs() >= advance {
                    return Some(i as f64);
                }
            }
            None
        };
        if let (Some(t1), Some(t0)) = (count_iters(1), count_iters(0)) {
            out.push(SpiralSample {
                angle_deg: ang0.to_degrees(),
                slowdown: t1 / t0,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_beats_adasgd_on_aligned_quadratic() {
        // Fig. 3a: coordinate-wise adaptivity suppresses the oscillation
        // AdaSGD shows along the dominant direction.
        let (g, l, _) = quadratic([100.0, 1.0], 0.0);
        let adam = run_2d(&g, &l, [3.0, 0.5], Opt2d::Adam, 0.05, 0.0, 0.5, 0, 400, None);
        let ada = run_2d(&g, &l, [3.0, 0.5], Opt2d::AdaSgd, 0.05, 0.0, 0.5, 0, 400, None);
        assert!(tail_mean(&adam.losses, 20) < tail_mean(&ada.losses, 20));
    }

    #[test]
    fn fig3_misalignment_amplifies_delay_and_rotation_fixes_it() {
        let rows = fig3_grid(3);
        let get = |opt: &str, aligned: bool, delay: usize| {
            rows.iter()
                .find(|r| r.opt == opt && r.aligned == aligned && r.delay == delay)
                .unwrap()
                .tail_loss
        };
        // delay hurts the misaligned case much more than the aligned one
        assert!(get("adam", false, 3) > 2.0 * get("adam", true, 3));
        // basis rotation under delay recovers ~the aligned behaviour
        let rot = rows.iter().find(|r| r.opt == "adam+rot").unwrap().tail_loss;
        assert!(rot < 0.6 * get("adam", false, 3));
    }

    #[test]
    fn adam_equivariance_under_rotation_no_delay() {
        // Rotated Adam on H = QΛQᵀ started at Q·x0 must trace exactly the
        // loss curve of plain Adam on Λ started at x0 (Appendix C).
        let (ga, la, _) = quadratic([100.0, 1.0], 0.0);
        let (gm, lm, q) = quadratic([100.0, 1.0], 45.0);
        let x0 = [3.0, 0.5];
        let x0_rot = [
            q[0][0] * x0[0] + q[0][1] * x0[1],
            q[1][0] * x0[0] + q[1][1] * x0[1],
        ];
        let a = run_2d(&ga, &la, x0, Opt2d::Adam, 0.05, 0.0, 0.5, 0, 200, None);
        let r = run_2d(&gm, &lm, x0_rot, Opt2d::Adam, 0.05, 0.0, 0.5, 0, 200, Some(q));
        for (x, y) in a.losses.iter().zip(&r.losses) {
            assert!((x - y).abs() < 1e-6 * x.abs().max(1.0), "{x} vs {y}");
        }
    }

    #[test]
    fn spiral_loss_shape() {
        // global structure: radial growth plus ridge oscillation
        assert!(spiral_loss([8.0, 0.0]) > spiral_loss([0.05, 0.0]));
        let g = spiral_grad([2.0, 1.0]);
        assert!(g[0].is_finite() && g[1].is_finite());
    }

    #[test]
    fn spiral_slowdown_exceeds_one_on_average() {
        let samples = spiral_slowdowns(12, 3);
        assert!(samples.len() >= 6, "only {} samples converged", samples.len());
        let mean: f64 =
            samples.iter().map(|s| s.slowdown).sum::<f64>() / samples.len() as f64;
        assert!(mean > 1.0, "mean slowdown {mean}");
    }
}
