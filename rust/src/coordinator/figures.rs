//! Figure/table harness: regenerates every quantitative result of the
//! paper at CPU scale (`abrot repro --fig fig5 --out results`).
//!
//! Absolute numbers differ from the paper (single-core CPU testbed,
//! small models, synthetic corpus — DESIGN.md §5); the *shape* of each
//! result — who wins, how the gap scales with P, where the orderings
//! fall — is the reproduction target recorded in EXPERIMENTS.md.
//!
//! Runs are cached within the process so overlapping figures (e.g.
//! Fig. 2a ⊂ Fig. 5, Fig. 9a reuses Fig. 5's wall-clocks) share work.

use anyhow::Result;
use std::collections::HashMap;
use std::path::PathBuf;

use crate::config::{FreqAlloc, Geometry, Method, ScheduleKind, Source, StashMode, TrainCfg};
use crate::landscape;
use crate::metrics::{
    iter_reduction_vs, iters_to_target, slowdown, write_losses, Csv, RunResult,
};

use super::{Coordinator, Experiment};

/// Harness options (CLI-settable).
#[derive(Clone, Debug)]
pub struct FigOpts {
    pub out: PathBuf,
    /// steps per training run (default small: single-core CPU)
    pub steps: u32,
    /// stage sweep for the P figures
    pub stages: Vec<usize>,
    pub seed: u64,
    pub lr: f32,
}

impl Default for FigOpts {
    fn default() -> Self {
        FigOpts {
            out: PathBuf::from("results"),
            steps: 200,
            stages: vec![1, 4, 8, 16, 32],
            seed: 1234,
            lr: 1e-3,
        }
    }
}

/// (model, method, stages, replicas, steps, stash/eval tag, DP tag)
/// — DP tag is 0 for synchronous DP, 1+K for `dp_async` at skew K, so
/// async runs never collide with sync ones in the cache.
type RunKey = (String, String, usize, usize, u32, u8, u32);

pub struct Harness<'a> {
    pub coord: &'a mut Coordinator,
    pub opts: FigOpts,
    cache: HashMap<RunKey, RunResult>,
}

fn stash_tag(s: StashMode) -> u8 {
    match s {
        StashMode::Stash => 0,
        StashMode::NoStash => 1,
        StashMode::Predict => 2,
    }
}

impl<'a> Harness<'a> {
    pub fn new(coord: &'a mut Coordinator, opts: FigOpts) -> Self {
        Harness { coord, opts, cache: HashMap::new() }
    }

    fn cfg(&self, method: Method, stages: usize) -> TrainCfg {
        TrainCfg {
            method,
            stages,
            steps: self.opts.steps,
            lr: self.opts.lr,
            seed: self.opts.seed,
            log_every: 0,
            ..Default::default()
        }
    }

    pub fn run(&mut self, model: &str, mut cfg: TrainCfg) -> Result<RunResult> {
        let key = (
            model.to_string(),
            cfg.method.name(),
            cfg.stages,
            cfg.dp_replicas(),
            cfg.steps,
            stash_tag(cfg.stash) + 10 * (cfg.eval_every > 0) as u8,
            if cfg.dp_async { 1 + cfg.max_skew } else { 0 },
        );
        if let Some(r) = self.cache.get(&key) {
            return Ok(r.clone());
        }
        cfg.seed = self.opts.seed;
        eprintln!(
            "  running {model} {} P={} R={} steps={} ...",
            cfg.method.name(),
            cfg.stages,
            cfg.dp_replicas(),
            cfg.steps
        );
        let t0 = std::time::Instant::now();
        let res = self
            .coord
            .run(&Experiment { model: model.into(), train: cfg })?;
        eprintln!(
            "    -> final {:.4}  ({:.1}s)",
            res.final_loss(),
            t0.elapsed().as_secs_f64()
        );
        self.cache.insert(key, res.clone());
        Ok(res)
    }

    fn out(&self, name: &str) -> PathBuf {
        self.opts.out.join(name)
    }

    /// The four headline methods of Figs. 2/5/6.
    fn main_methods(&self) -> Vec<Method> {
        vec![
            Method::PipeDream,
            Method::PipeDreamLr,
            Method::Nesterov,
            Method::br_default(),
        ]
    }

    /// Loss target for slowdown metrics: the P=1 PipeDream run's final
    /// smoothed loss plus a margin (reachable by all methods).
    fn target_loss(&mut self, model: &str) -> Result<f32> {
        let base = self.run(model, self.cfg(Method::PipeDream, 1))?;
        Ok(base.final_loss() + 0.15)
    }

    // -----------------------------------------------------------------
    // Figures
    // -----------------------------------------------------------------

    /// Fig. 2a + Fig. 5 + Fig. 12/13 + Fig. 9a: method × P sweep on the
    /// 32-block model.
    pub fn fig5(&mut self, model: &str) -> Result<()> {
        let stages = self.opts.stages.clone();
        let methods = self.main_methods();
        let target = self.target_loss(model)?;
        let mut rows =
            Csv::create(self.out("fig5_summary.csv"),
                        "method,stages,final_loss,iters_to_target,slowdown_vs_p1,wall_secs")?;
        let mut all_runs: Vec<RunResult> = Vec::new();
        println!("\n== Fig 2a/5/12/13: method x P sweep on {model} (target loss {target:.3}) ==");
        println!("{:<16} {:>4} {:>12} {:>10} {:>10} {:>9}",
                 "method", "P", "final_loss", "iters@tgt", "slowdown", "wall_s");
        for m in &methods {
            let base = self.run(model, self.cfg(*m, 1))?;
            for &p in &stages {
                let r = self.run(model, self.cfg(*m, p))?;
                let it = iters_to_target(&r.losses, target);
                let sd = slowdown(&r.losses, &base.losses, target);
                println!(
                    "{:<16} {:>4} {:>12.4} {:>10} {:>10} {:>9.1}",
                    r.method,
                    p,
                    r.final_loss(),
                    it.map_or("-".into(), |x| x.to_string()),
                    sd.map_or("-".into(), |x| format!("{x:.2}x")),
                    r.wall_secs
                );
                rows.row(&[
                    r.method.clone(),
                    p.to_string(),
                    format!("{:.4}", r.final_loss()),
                    it.map_or("-".into(), |x| x.to_string()),
                    sd.map_or("-".into(), |x| format!("{x:.3}")),
                    format!("{:.2}", r.wall_secs),
                ])?;
                all_runs.push(r);
            }
        }
        let refs: Vec<&RunResult> = all_runs.iter().collect();
        write_losses(self.out("fig5_losses.csv"), &refs)?;
        // Fig. 2b headline: iteration reduction of BR vs best baseline at max P
        let pmax = *stages.last().unwrap();
        let br = self.run(model, self.cfg(Method::br_default(), pmax))?;
        let mut best_base: Option<RunResult> = None;
        for m in &methods[..3] {
            let r = self.run(model, self.cfg(*m, pmax))?;
            if best_base.as_ref().map_or(true, |b| r.final_loss() < b.final_loss()) {
                best_base = Some(r);
            }
        }
        let bb = best_base.unwrap();
        if let Some(red) = iter_reduction_vs(&br, &bb) {
            println!(
                "Fig 2b headline: basis rotation reaches {}'s final loss with {:.1}% fewer iterations (paper: 71.6-81.7%)",
                bb.method, red * 100.0
            );
        }
        Ok(())
    }

    /// Fig. 6 / Fig. 14: depth scaling with P = L.
    pub fn fig6(&mut self) -> Result<()> {
        let family = [("tiny4", 4usize), ("tiny8", 8), ("tiny16", 16), ("tiny32", 32)];
        let methods = self.main_methods();
        let mut rows = Csv::create(self.out("fig6_summary.csv"),
                                   "method,blocks,stages,final_loss")?;
        println!("\n== Fig 6/14: depth scaling (P = n_blocks) ==");
        println!("{:<16} {:>7} {:>12}", "method", "blocks", "final_loss");
        for m in &methods {
            let mut prev = f32::INFINITY;
            let mut monotone_break = false;
            for (model, p) in family {
                let r = self.run(model, self.cfg(*m, p))?;
                println!("{:<16} {:>7} {:>12.4}", r.method, p, r.final_loss());
                rows.row(&[
                    r.method.clone(),
                    p.to_string(),
                    p.to_string(),
                    format!("{:.4}", r.final_loss()),
                ])?;
                if r.final_loss() > prev + 0.02 {
                    monotone_break = true;
                }
                prev = r.final_loss();
            }
            println!("   -> {} scaling {}", m.name(),
                     if monotone_break { "BROKEN (loss rises with depth)" }
                     else { "holds (loss falls with depth)" });
        }
        Ok(())
    }

    /// Fig. 7 / Fig. 20: width scaling at fixed P.
    pub fn fig7(&mut self) -> Result<()> {
        let p = 8;
        let methods =
            [Method::PipeDream, Method::PipeDreamLr, Method::br_default()];
        let mut rows = Csv::create(self.out("fig7_summary.csv"),
                                   "method,model,final_loss,iter_reduction_vs_best_baseline")?;
        println!("\n== Fig 7/20: width scaling at P={p} ==");
        for model in ["small", "wide"] {
            let mut runs = Vec::new();
            for m in &methods {
                runs.push(self.run(model, self.cfg(*m, p))?);
            }
            let br = runs.pop().unwrap();
            let best = runs
                .iter()
                .min_by(|a, b| a.final_loss().partial_cmp(&b.final_loss()).unwrap())
                .unwrap()
                .clone();
            let red = iter_reduction_vs(&br, &best);
            println!(
                "{model:>6}: BR final {:.4} vs best baseline ({}) {:.4}; iter reduction {}",
                br.final_loss(),
                best.method,
                best.final_loss(),
                red.map_or("-".into(), |x| format!("{:.1}%", x * 100.0))
            );
            for r in runs.iter().chain(std::iter::once(&br)) {
                rows.row(&[
                    r.method.clone(),
                    model.to_string(),
                    format!("{:.4}", r.final_loss()),
                    red.map_or("-".into(), |x| format!("{:.3}", x)),
                ])?;
            }
        }
        Ok(())
    }

    /// Fig. 8 / Table + Fig. 16: eigenbasis-estimation strategy matrix.
    pub fn fig8(&mut self, model: &str) -> Result<()> {
        let pmax = *self.opts.stages.last().unwrap();
        let target = self.target_loss(model)?;
        let mut variants = Vec::new();
        for source in [Source::First, Source::Second] {
            for geometry in [Geometry::Unilateral, Geometry::Bilateral] {
                variants.push(Method::BasisRotation {
                    source,
                    geometry,
                    freq: 10,
                    alloc: FreqAlloc::Uniform,
                });
            }
        }
        let mut rows = Csv::create(self.out("fig8_summary.csv"),
                                   "method,slowdown,final_loss_pmax")?;
        println!("\n== Fig 8: eigenbasis estimation strategies (P={pmax} vs P=1) ==");
        println!("{:<16} {:>10} {:>12}", "method", "slowdown", "final@Pmax");
        let lr_base = self.run(model, self.cfg(Method::PipeDreamLr, 1))?;
        let lr_pmax = self.run(model, self.cfg(Method::PipeDreamLr, pmax))?;
        let base_sd = slowdown(&lr_pmax.losses, &lr_base.losses, target);
        println!("{:<16} {:>10} {:>12.4}", "pipedream_lr",
                 base_sd.map_or("-".into(), |x| format!("{x:.2}x")),
                 lr_pmax.final_loss());
        rows.row(&[
            "pipedream_lr".into(),
            base_sd.map_or("-".into(), |x| format!("{x:.3}")),
            format!("{:.4}", lr_pmax.final_loss()),
        ])?;
        let mut sds: Vec<(String, Option<f32>)> = Vec::new();
        for m in variants {
            let r1 = self.run(model, self.cfg(m, 1))?;
            let rp = self.run(model, self.cfg(m, pmax))?;
            let sd = slowdown(&rp.losses, &r1.losses, target);
            println!("{:<16} {:>10} {:>12.4}", m.name(),
                     sd.map_or("-".into(), |x| format!("{x:.2}x")),
                     rp.final_loss());
            rows.row(&[
                m.name(),
                sd.map_or("-".into(), |x| format!("{x:.3}")),
                format!("{:.4}", rp.final_loss()),
            ])?;
            sds.push((m.name(), sd));
        }
        Ok(())
    }

    /// Fig. 9a/9b: wall-clock efficiency + basis update frequency sweep.
    pub fn fig9ab(&mut self, model: &str) -> Result<()> {
        let pmax = *self.opts.stages.last().unwrap();
        println!("\n== Fig 9a: wall-clock to loss at P={pmax} ==");
        let mut rows = Csv::create(self.out("fig9_summary.csv"),
                                   "method,final_loss,wall_secs,secs_per_step")?;
        let methods = [
            Method::PipeDream,
            Method::PipeDreamLr,
            Method::Nesterov,
            Method::br_default(),
        ];
        for m in methods {
            let r = self.run(model, self.cfg(m, pmax))?;
            println!("{:<16} final {:.4} in {:>7.1}s ({:.3}s/step)",
                     r.method, r.final_loss(), r.wall_secs,
                     r.wall_secs / r.losses.len().max(1) as f64);
            rows.row(&[
                r.method.clone(),
                format!("{:.4}", r.final_loss()),
                format!("{:.2}", r.wall_secs),
                format!("{:.4}", r.wall_secs / r.losses.len().max(1) as f64),
            ])?;
        }
        println!("\n== Fig 9b: basis update frequency ==");
        for freq in [10u32, 33, 100] {
            let m = Method::BasisRotation {
                source: Source::Second,
                geometry: Geometry::Bilateral,
                freq,
                alloc: FreqAlloc::Uniform,
            };
            let r = self.run(model, self.cfg(m, pmax))?;
            println!("freq={freq:<4} final {:.4} in {:>7.1}s", r.final_loss(),
                     r.wall_secs);
            rows.row(&[
                r.method.clone(),
                format!("{:.4}", r.final_loss()),
                format!("{:.2}", r.wall_secs),
                format!("{:.4}", r.wall_secs / r.losses.len().max(1) as f64),
            ])?;
        }
        Ok(())
    }

    /// Fig. 9c + Fig. 17: stage-aware / inverse-stage-aware allocation.
    pub fn fig9c(&mut self, model: &str) -> Result<()> {
        let pmax = *self.opts.stages.last().unwrap();
        let target = self.target_loss(model)?;
        println!("\n== Fig 9c/17: stage-aware rotation budget at P={pmax} ==");
        let mut rows = Csv::create(self.out("fig9c_summary.csv"),
                                   "alloc,final_loss,iters_to_target")?;
        let mut uniform_it = None;
        for (alloc, label) in [
            (FreqAlloc::Uniform, "uniform"),
            (FreqAlloc::StageAware, "stage_aware"),
            (FreqAlloc::InverseStageAware, "inverse"),
        ] {
            let m = Method::BasisRotation {
                source: Source::Second,
                geometry: Geometry::Bilateral,
                freq: 10,
                alloc,
            };
            let r = self.run(model, self.cfg(m, pmax))?;
            let it = iters_to_target(&r.losses, target);
            if alloc == FreqAlloc::Uniform {
                uniform_it = it;
            }
            let speedup = match (it, uniform_it) {
                (Some(a), Some(u)) => format!("{:+.1}%", (1.0 - a as f32 / u as f32) * 100.0),
                _ => "-".into(),
            };
            println!("{label:<12} final {:.4}  iters@tgt {:>6}  vs uniform {speedup}",
                     r.final_loss(),
                     it.map_or("-".into(), |x| x.to_string()));
            rows.row(&[
                label.into(),
                format!("{:.4}", r.final_loss()),
                it.map_or("-".into(), |x| x.to_string()),
            ])?;
        }
        Ok(())
    }

    /// Fig. 10: robustness without weight stashing.
    pub fn fig10(&mut self, model: &str) -> Result<()> {
        let pmax = *self.opts.stages.last().unwrap();
        println!("\n== Fig 10: no weight stashing at P={pmax} ==");
        let mut rows = Csv::create(self.out("fig10_summary.csv"),
                                   "method,stash,final_loss")?;
        let mut all = Vec::new();
        for m in [Method::PipeDream, Method::PipeDreamLr, Method::br_default()] {
            for stash in [StashMode::Stash, StashMode::NoStash] {
                let mut cfg = self.cfg(m, pmax);
                cfg.stash = stash;
                let r = self.run(model, cfg)?;
                let tag = if stash == StashMode::Stash { "stash" } else { "nostash" };
                println!("{:<16} {:<8} final {:.4}{}", r.method, tag, r.final_loss(),
                         if r.diverged { "  [diverged]" } else { "" });
                rows.row(&[r.method.clone(), tag.into(), format!("{:.4}", r.final_loss())])?;
                all.push(r);
            }
        }
        let refs: Vec<&RunResult> = all.iter().collect();
        write_losses(self.out("fig10_losses.csv"), &refs)?;
        Ok(())
    }

    /// Fig. 15: PipeMare-style weight prediction.
    pub fn fig15(&mut self, model: &str) -> Result<()> {
        let pmax = *self.opts.stages.last().unwrap();
        println!("\n== Fig 15: weight prediction at P={pmax} ==");
        let mut rows = Csv::create(self.out("fig15_summary.csv"),
                                   "method,final_loss")?;
        for m in [Method::PipeDream, Method::PipeDreamLr, Method::br_default()] {
            let mut cfg = self.cfg(m, pmax);
            cfg.stash = StashMode::Predict;
            let r = self.run(model, cfg)?;
            println!("{:<16} final {:.4}", r.method, r.final_loss());
            rows.row(&[r.method.clone(), format!("{:.4}", r.final_loss())])?;
        }
        Ok(())
    }

    /// Fig. 18: validation loss tracking.
    pub fn fig18(&mut self, model: &str) -> Result<()> {
        let pmax = *self.opts.stages.last().unwrap();
        println!("\n== Fig 18: train vs validation loss at P={pmax} ==");
        let mut rows = Csv::create(self.out("fig18_val.csv"),
                                   "method,step,val_loss")?;
        for m in [Method::PipeDreamLr, Method::br_default()] {
            let mut cfg = self.cfg(m, pmax);
            cfg.eval_every = (self.opts.steps / 8).max(1);
            let r = self.run(model, cfg)?;
            for (step, vl) in &r.val_losses {
                rows.row(&[r.method.clone(), step.to_string(), format!("{vl:.4}")])?;
            }
            let last_val = r.val_losses.last().map(|x| x.1).unwrap_or(f32::NAN);
            println!("{:<16} final train {:.4}  final val {:.4}", r.method,
                     r.final_loss(), last_val);
        }
        Ok(())
    }

    /// Fig. 19: Delay Compensation λ sweep.
    pub fn fig19(&mut self, model: &str) -> Result<()> {
        let pmax = *self.opts.stages.last().unwrap();
        println!("\n== Fig 19: delay compensation at P={pmax} ==");
        let mut rows = Csv::create(self.out("fig19_summary.csv"),
                                   "method,final_loss")?;
        let pd = self.run(model, self.cfg(Method::PipeDream, pmax))?;
        println!("{:<16} final {:.4}", pd.method, pd.final_loss());
        rows.row(&[pd.method.clone(), format!("{:.4}", pd.final_loss())])?;
        for lambda in [0.04f32, 0.1, 0.5, 1.0] {
            let r = self.run(model, self.cfg(Method::DelayComp { lambda }, pmax))?;
            println!("{:<16} final {:.4}", r.method, r.final_loss());
            rows.row(&[r.method.clone(), format!("{:.4}", r.final_loss())])?;
        }
        let br = self.run(model, self.cfg(Method::br_default(), pmax))?;
        println!("{:<16} final {:.4}", br.method, br.final_loss());
        rows.row(&[br.method.clone(), format!("{:.4}", br.final_loss())])?;
        Ok(())
    }

    /// Fig. 21: MoE generalization.
    pub fn fig21(&mut self) -> Result<()> {
        let model = "moe_tiny";
        let p = 8;
        println!("\n== Fig 21: MoE (8 experts, top-2) at P={p} ==");
        let mut rows = Csv::create(self.out("fig21_summary.csv"),
                                   "method,final_loss,iter_reduction_vs_best_baseline")?;
        let mut runs = Vec::new();
        for m in [Method::PipeDream, Method::PipeDreamLr, Method::Nesterov] {
            runs.push(self.run(model, self.cfg(m, p))?);
        }
        let br = self.run(model, self.cfg(Method::br_default(), p))?;
        let best = runs
            .iter()
            .min_by(|a, b| a.final_loss().partial_cmp(&b.final_loss()).unwrap())
            .unwrap()
            .clone();
        let red = iter_reduction_vs(&br, &best);
        for r in runs.iter() {
            println!("{:<16} final {:.4}", r.method, r.final_loss());
            rows.row(&[r.method.clone(), format!("{:.4}", r.final_loss()), "-".into()])?;
        }
        println!("{:<16} final {:.4}  iter reduction vs {}: {} (paper: 46.8%)",
                 br.method, br.final_loss(), best.method,
                 red.map_or("-".into(), |x| format!("{:.1}%", x * 100.0)));
        rows.row(&[
            br.method.clone(),
            format!("{:.4}", br.final_loss()),
            red.map_or("-".into(), |x| format!("{:.3}", x)),
        ])?;
        Ok(())
    }

    /// Table 3: preconditioned optimizers.
    pub fn table3(&mut self, model: &str) -> Result<()> {
        let pmax = *self.opts.stages.last().unwrap();
        let target = self.target_loss(model)?;
        println!("\n== Table 3: preconditioned methods, slowdown P={pmax} vs P=1 ==");
        let mut rows = Csv::create(self.out("table3.csv"), "method,slowdown,final_loss")?;
        let methods = [
            Method::PipeDreamLr,
            Method::Nesterov,
            Method::Muon,
            Method::Scion,
            Method::Soap { freq: 10 },
            Method::br_default(),
        ];
        println!("{:<16} {:>10} {:>12}", "method", "slowdown", "final@Pmax");
        for m in methods {
            let r1 = self.run(model, self.cfg(m, 1))?;
            let rp = self.run(model, self.cfg(m, pmax))?;
            let sd = slowdown(&rp.losses, &r1.losses, target);
            println!("{:<16} {:>10} {:>12.4}", m.name(),
                     sd.map_or("-".into(), |x| format!("{x:.2}x")),
                     rp.final_loss());
            rows.row(&[
                m.name(),
                sd.map_or("-".into(), |x| format!("{x:.3}")),
                format!("{:.4}", rp.final_loss()),
            ])?;
        }
        Ok(())
    }

    /// Fig. 3: quadratic-landscape grid.
    pub fn fig3(&mut self) -> Result<()> {
        println!("\n== Fig 3: AdaSGD/Adam on aligned vs misaligned quadratic ==");
        let rows = landscape::fig3_grid(2);
        let mut csv = Csv::create(self.out("fig3.csv"), "opt,aligned,delay,tail_loss")?;
        for r in &rows {
            println!("{:<10} aligned={:<5} delay={} tail_loss={:.4}", r.opt,
                     r.aligned, r.delay, r.tail_loss);
            csv.row(&[
                r.opt.into(),
                r.aligned.to_string(),
                r.delay.to_string(),
                format!("{:.6}", r.tail_loss),
            ])?;
        }
        Ok(())
    }

    /// Fig. 4: spiral-loss slowdown samples.
    pub fn fig4(&mut self) -> Result<()> {
        println!("\n== Fig 4: spiral-loss slowdown T_delay/T_no-delay ==");
        let samples = landscape::spiral_slowdowns(40, self.opts.seed);
        let mut csv = Csv::create(self.out("fig4.csv"), "angle_deg,slowdown")?;
        let mut mean = 0.0;
        for s in &samples {
            csv.row(&[format!("{:.2}", s.angle_deg), format!("{:.3}", s.slowdown)])?;
            mean += s.slowdown;
        }
        mean /= samples.len().max(1) as f64;
        let max = samples.iter().map(|s| s.slowdown).fold(0.0, f64::max);
        println!("{} samples; mean slowdown {:.2}x, max {:.2}x (delay amplifies in misaligned regions)",
                 samples.len(), mean, max);
        Ok(())
    }

    /// Fig. 11: Hessian (1,1)-norm + oscillation before/after rotation.
    pub fn fig11(&mut self, model: &str) -> Result<()> {
        println!("\n== Fig 11: basis-alignment validation on {model} ==");
        // Train briefly with each method, then measure.
        let steps = self.opts.steps.min(120);
        let p = 4usize;
        let mut out = Csv::create(self.out("fig11.csv"),
                                  "method,h11_norm,osc_dominant,osc_nondominant")?;
        for m in [Method::PipeDream, Method::br_default()] {
            let mut cfg = self.cfg(m, p);
            cfg.steps = steps;
            let rt = self.coord.runtime(model)?;
            let measured = crate::analysis::alignment_report(rt, &cfg, 40)?;
            println!(
                "{:<16} H(1,1)/d={:.4}  osc(dominant)={:.4}  osc(non-dom)={:.4}",
                m.name(), measured.h11, measured.osc_dom, measured.osc_nondom
            );
            out.row(&[
                m.name(),
                format!("{:.5}", measured.h11),
                format!("{:.5}", measured.osc_dom),
                format!("{:.5}", measured.osc_nondom),
            ])?;
        }
        Ok(())
    }

    /// Table 1 + Table 2 (analytic).
    pub fn tables12(&mut self) -> Result<()> {
        println!("\n== Table 1: required pipeline stages (s=4096, b=1) ==");
        let gpus = crate::analysis::gpus();
        print!("{:<16}", "model");
        for g in &gpus {
            print!(" {:>14}", g.name.split(' ').next().unwrap());
        }
        println!();
        let mut csv = Csv::create(self.out("table1.csv"), "model,gpu,stages")?;
        for (model, cells) in crate::analysis::table1_rows() {
            print!("{model:<16}");
            for (c, g) in cells.iter().zip(&gpus) {
                print!(" {c:>14}");
                csv.row(&[model.clone(), g.name.into(), c.clone()])?;
            }
            println!();
        }
        println!("\n== Table 2: rotation memory overhead on Llama-3-8B (GB/matrix) ==");
        let mut csv2 = Csv::create(self.out("table2.csv"),
                                   "source,geometry,attn_gb,mlp_gb")?;
        for r in crate::analysis::table2_rows() {
            let s = match r.source { Source::Second => "2nd", Source::First => "1st" };
            let g = match r.geometry { Geometry::Bilateral => "Bi", Geometry::Unilateral => "Uni" };
            println!("{s:<4} {g:<4} attn {:.2} GB   mlp {:.2} GB", r.attn_gb, r.mlp_gb);
            csv2.row(&[s.into(), g.into(), format!("{:.3}", r.attn_gb),
                       format!("{:.3}", r.mlp_gb)])?;
        }
        Ok(())
    }

    /// DP x PP scenario matrix: methods x replica counts at fixed P,
    /// through the simulator — the `replicas` axis added to the
    /// {method x P x stash x MoE} grid.
    pub fn dp(&mut self, model: &str, stages: usize, replicas: &[usize]) -> Result<()> {
        println!("\n== DP x PP: method x R sweep on {model} at P={stages} ==");
        println!("{:<16} {:>4} {:>4} {:>12} {:>9}",
                 "method", "P", "R", "final_loss", "wall_s");
        let mut rows = Csv::create(self.out("dp_summary.csv"),
                                   "method,stages,replicas,final_loss,wall_secs")?;
        for m in [Method::PipeDream, Method::Nesterov, Method::br_default()] {
            for &r_count in replicas {
                let mut cfg = self.cfg(m, stages);
                cfg.replicas = r_count;
                let r = self.run(model, cfg)?;
                println!("{:<16} {:>4} {:>4} {:>12.4} {:>9.1}",
                         r.method, stages, r_count, r.final_loss(), r.wall_secs);
                rows.row(&[
                    r.method.clone(),
                    stages.to_string(),
                    r_count.to_string(),
                    format!("{:.4}", r.final_loss()),
                    format!("{:.2}", r.wall_secs),
                ])?;
            }
        }
        Ok(())
    }

    /// Skew-vs-convergence matrix for bounded-skew async DP: methods x
    /// skew bound K at fixed P and R=2, through the simulator's
    /// composed delay model (PP delay + K). K=0 is the synchronous DP
    /// trajectory; the K axis shows what the relaxed barrier costs in
    /// convergence — the throughput side lives in BENCH_dp_async.json.
    pub fn dp_async(
        &mut self,
        model: &str,
        stages: usize,
        skews: &[u32],
    ) -> Result<()> {
        println!("\n== Async DP: method x max-skew sweep on {model} at P={stages}, R=2 ==");
        println!("{:<16} {:>4} {:>5} {:>12} {:>9}",
                 "method", "P", "K", "final_loss", "wall_s");
        let mut rows = Csv::create(
            self.out("dp_async.csv"),
            "method,stages,replicas,max_skew,final_loss,wall_secs",
        )?;
        for m in [Method::PipeDream, Method::Nesterov, Method::br_default()] {
            for &k in skews {
                let mut cfg = self.cfg(m, stages);
                cfg.replicas = 2;
                cfg.dp_async = true;
                cfg.max_skew = k;
                let r = self.run(model, cfg)?;
                println!("{:<16} {:>4} {:>5} {:>12.4} {:>9.1}",
                         r.method, stages, k, r.final_loss(), r.wall_secs);
                rows.row(&[
                    r.method.clone(),
                    stages.to_string(),
                    "2".to_string(),
                    k.to_string(),
                    format!("{:.4}", r.final_loss()),
                    format!("{:.2}", r.wall_secs),
                ])?;
            }
        }
        Ok(())
    }

    /// Engine demo: threaded 1F1B throughput/bubble + loss sanity.
    pub fn engine(&mut self, model: &str, stages: usize) -> Result<()> {
        println!("\n== Engine: threaded 1F1B pipeline on {model}, P={stages} ==");
        let cfg = TrainCfg {
            method: Method::PipeDream,
            stages,
            steps: self.opts.steps.min(60),
            lr: self.opts.lr,
            seed: self.opts.seed,
            ..Default::default()
        };
        let r = self.coord.run_engine(&Experiment { model: model.into(), train: cfg })?;
        println!(
            "microbatches={} final_loss={:.4} tokens/s={:.0} bubble={:.1}% wall={:.1}s",
            r.losses.len(), r.final_loss(), r.tokens_per_sec,
            r.bubble_frac * 100.0, r.wall_secs
        );
        let mut csv = Csv::create(self.out("engine.csv"),
                                  "stages,final_loss,tokens_per_sec,bubble_frac,wall_secs")?;
        csv.row(&[
            stages.to_string(),
            format!("{:.4}", r.final_loss()),
            format!("{:.1}", r.tokens_per_sec),
            format!("{:.4}", r.bubble_frac),
            format!("{:.2}", r.wall_secs),
        ])?;
        // analytic sync-vs-async bubble comparison (Fig. 1 premise)
        println!("analytic bubble (sync GPipe, M=P): {:.1}% vs async steady-state 0%",
                 crate::pipeline::engine::sync_bubble_fraction(stages, stages) * 100.0);
        Ok(())
    }

    /// Schedule comparison: the threaded engine under every pipeline
    /// schedule at fixed P — wall-clock bubble vs the deterministic
    /// schedule-model bubble vs the analytic formula, plus the loss
    /// the staleness profile buys. The model needs P·V blocks for
    /// interleaved:V (default caller: pico8 at P=4).
    pub fn schedule(&mut self, model: &str, stages: usize) -> Result<()> {
        println!("\n== Schedules: engine on {model} at P={stages} ==");
        println!("{:<14} {:>12} {:>9} {:>9} {:>9} {:>8}",
                 "schedule", "final_loss", "bubble%", "model%", "analytic%", "wall_s");
        let mut csv = Csv::create(
            self.out("schedule.csv"),
            "schedule,stages,final_loss,bubble_frac,bubble_frac_model,bubble_frac_analytic,wall_secs",
        )?;
        let kinds = [
            ScheduleKind::Gpipe,
            ScheduleKind::OneFOneB,
            ScheduleKind::Interleaved { v: 2 },
            ScheduleKind::Amdp,
        ];
        for kind in kinds {
            if kind == ScheduleKind::Amdp && stages % 2 != 0 {
                println!("{:<14} skipped (amdp needs an even stage count)", kind.name());
                continue;
            }
            let cfg = TrainCfg {
                method: Method::PipeDream,
                schedule: kind,
                stages,
                steps: self.opts.steps.min(40),
                lr: self.opts.lr,
                seed: self.opts.seed,
                ..Default::default()
            };
            let r = self
                .coord
                .run_engine(&Experiment { model: model.into(), train: cfg })?;
            println!("{:<14} {:>12.4} {:>9.1} {:>9.1} {:>9.1} {:>8.1}",
                     r.schedule, r.final_loss(), r.bubble_frac * 100.0,
                     r.bubble_frac_model * 100.0, r.bubble_frac_analytic * 100.0,
                     r.wall_secs);
            csv.row(&[
                r.schedule.clone(),
                stages.to_string(),
                format!("{:.4}", r.final_loss()),
                format!("{:.4}", r.bubble_frac),
                format!("{:.4}", r.bubble_frac_model),
                format!("{:.4}", r.bubble_frac_analytic),
                format!("{:.2}", r.wall_secs),
            ])?;
        }
        Ok(())
    }

    /// Per-stage utilization timeline: run the engine with span tracing
    /// on, write the Chrome trace next to a per-worker busy/idle CSV
    /// derived from `RunResult.stage_spans`, and cross-check the
    /// span-derived split against the wall-clock bubble fraction.
    pub fn timeline(&mut self, model: &str, stages: usize) -> Result<()> {
        println!("\n== Timeline: engine span trace on {model} at P={stages} ==");
        let trace_path = self.out("timeline_trace.json");
        let cfg = TrainCfg {
            method: Method::PipeDream,
            stages,
            steps: self.opts.steps.min(24),
            lr: self.opts.lr,
            seed: self.opts.seed,
            trace: Some(trace_path.to_string_lossy().into_owned()),
            metrics: Some(
                self.out("timeline_metrics.jsonl").to_string_lossy().into_owned(),
            ),
            ..Default::default()
        };
        let r = self
            .coord
            .run_engine(&Experiment { model: model.into(), train: cfg })?;
        println!("{:<8} {:>8} {:>9} {:>9} {:>10} {:>7}",
                 "worker", "spans", "busy_s", "idle_s", "idle_frac", "");
        let mut csv = Csv::create(
            self.out("timeline.csv"),
            "replica,worker,spans,busy_s,idle_s,idle_frac",
        )?;
        for sp in &r.stage_spans {
            let tot = sp.busy_s + sp.idle_s;
            let frac = if tot > 0.0 { sp.idle_s / tot } else { 0.0 };
            println!("r{}/w{:<4} {:>8} {:>9.3} {:>9.3} {:>10.3}",
                     sp.replica, sp.worker, sp.spans, sp.busy_s, sp.idle_s, frac);
            csv.row(&[
                sp.replica.to_string(),
                sp.worker.to_string(),
                sp.spans.to_string(),
                format!("{:.4}", sp.busy_s),
                format!("{:.4}", sp.idle_s),
                format!("{:.4}", frac),
            ])?;
        }
        let busy: f64 = r.stage_spans.iter().map(|s| s.busy_s).sum();
        let idle: f64 = r.stage_spans.iter().map(|s| s.idle_s).sum();
        let span_bubble = if busy + idle > 0.0 { idle / (busy + idle) } else { 0.0 };
        println!(
            "span bubble {:.1}% vs wall-clock bubble {:.1}%  (trace -> {})",
            span_bubble * 100.0,
            r.bubble_frac * 100.0,
            trace_path.display()
        );
        Ok(())
    }

    /// Run everything.
    pub fn all(&mut self, model: &str) -> Result<()> {
        self.fig3()?;
        self.fig4()?;
        self.tables12()?;
        self.fig5(model)?;
        self.fig6()?;
        self.fig7()?;
        self.fig8(model)?;
        self.fig9ab(model)?;
        self.fig9c(model)?;
        self.fig10(model)?;
        self.fig15(model)?;
        self.fig18(model)?;
        self.fig19(model)?;
        self.fig21()?;
        self.table3(model)?;
        self.fig11("tiny8")?;
        self.engine("micro", 2)?;
        self.dp("pico4", 4, &[1, 2])?;
        self.dp_async("pico4", 4, &[0, 1, 2])?;
        self.schedule("pico8", 4)?;
        Ok(())
    }
}

