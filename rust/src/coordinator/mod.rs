//! Experiment coordinator: wires runtime + data + pipeline + optimizer
//! into named runs, and regenerates every table and figure of the paper
//! (`figures` submodule → `abrot repro --fig ...`).

pub mod figures;

use anyhow::Result;
use std::path::{Path, PathBuf};

use crate::config::TrainCfg;
use crate::metrics::RunResult;
use crate::pipeline::train_sim;
use crate::runtime::Runtime;

/// One fully-specified experiment: model config + training config.
#[derive(Clone, Debug)]
pub struct Experiment {
    pub model: String,
    pub train: TrainCfg,
}

pub struct Coordinator {
    pub artifacts_root: PathBuf,
    /// cached runtimes per model config (compile once per process).
    runtimes: std::collections::HashMap<String, Runtime>,
}

impl Coordinator {
    pub fn new(artifacts_root: impl AsRef<Path>) -> Self {
        Coordinator {
            artifacts_root: artifacts_root.as_ref().to_path_buf(),
            runtimes: Default::default(),
        }
    }

    pub fn runtime(&mut self, model: &str) -> Result<&Runtime> {
        if !self.runtimes.contains_key(model) {
            let rt = Runtime::open(self.artifacts_root.join(model))?;
            self.runtimes.insert(model.to_string(), rt);
        }
        Ok(&self.runtimes[model])
    }

    /// Run one experiment through the delay-accurate simulator.
    pub fn run(&mut self, exp: &Experiment) -> Result<RunResult> {
        let rt = self.runtime(&exp.model)?;
        let mut res = train_sim(rt, &exp.train)?;
        res.method = exp.train.method.name();
        Ok(res)
    }

    /// Run the real threaded pipeline engine.
    pub fn run_engine(&mut self, exp: &Experiment) -> Result<RunResult> {
        crate::pipeline::engine::train_engine(
            self.artifacts_root.join(&exp.model),
            &exp.train,
        )
    }

    /// Run the engine under the elastic checkpoint driver: periodic
    /// snapshots, resume, deterministic fault injection and replica
    /// roster changes ([`crate::checkpoint::run_engine_elastic`]).
    /// With checkpointing off, no resume and an empty plan this is
    /// exactly [`run_engine`](Self::run_engine).
    pub fn run_engine_elastic(
        &mut self,
        exp: &Experiment,
        plan: &crate::checkpoint::FaultPlan,
    ) -> Result<RunResult> {
        crate::checkpoint::run_engine_elastic(
            &self.artifacts_root.join(&exp.model),
            &exp.train,
            plan,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;

    fn root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn micro_pipedream_trains() {
        let mut c = Coordinator::new(root());
        let exp = Experiment {
            model: "micro".into(),
            train: TrainCfg {
                method: Method::PipeDream,
                stages: 2,
                steps: 80,
                lr: 1e-2,
                eval_every: 40,
                ..Default::default()
            },
        };
        let res = c.run(&exp).unwrap();
        assert_eq!(res.losses.len(), 80);
        assert!(!res.diverged);
        // the synthetic language is learnable: loss must fall
        let first = res.losses[0];
        let last = res.final_loss();
        assert!(last < first - 0.4, "loss {first} -> {last}");
        assert_eq!(res.val_losses.len(), 2);
    }

    #[test]
    fn micro_basis_rotation_trains() {
        let mut c = Coordinator::new(root());
        let exp = Experiment {
            model: "micro".into(),
            train: TrainCfg {
                method: Method::br_default(),
                stages: 2,
                steps: 50,
                lr: 1e-2,
                ..Default::default()
            },
        };
        let res = c.run(&exp).unwrap();
        assert!(!res.diverged);
        assert!(res.final_loss() < res.losses[0] - 0.2);
    }

    #[test]
    fn dp_sim_run_reports_replica_counters() {
        let mut c = Coordinator::new(root());
        let exp = Experiment {
            model: "micro".into(),
            train: TrainCfg {
                method: Method::PipeDream,
                stages: 2,
                replicas: 2,
                steps: 12,
                lr: 5e-3,
                ..Default::default()
            },
        };
        let res = c.run(&exp).unwrap();
        assert_eq!(res.replicas, 2);
        assert_eq!(res.losses.len(), 12);
        assert!(!res.diverged);
        // one counter row per replica, each with one dispatch per step
        assert_eq!(res.stage_counters.len(), 2);
        for (r, sc) in res.stage_counters.iter().enumerate() {
            assert_eq!(sc.replica, r);
            assert_eq!(sc.dispatches, 12);
            assert_eq!(sc.updates, 12);
            assert!(sc.optimizer_state_elems > 0);
        }
    }

    #[test]
    fn runtime_cache_reused() {
        let mut c = Coordinator::new(root());
        c.runtime("micro").unwrap();
        let n = c.runtimes.len();
        c.runtime("micro").unwrap();
        assert_eq!(c.runtimes.len(), n);
    }
}
