//! Dense f32 tensor — the coordinator's host-side value type.
//!
//! Deliberately dependency-free: the hot path only needs elementwise
//! ops, small matmuls (the reference kernels the native backend runs
//! on, doubling as the cross-check for the HLO/Pallas path) and
//! conversion into [`crate::runtime::Value`]s at the backend boundary.

use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} el]", self.shape, self.data.len())
    }
}

impl Tensor {
    /// Build from shape + row-major data (panics on length mismatch).
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} vs data len {}",
            shape,
            data.len()
        );
        Tensor { shape, data }
    }

    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    /// All-ones tensor.
    pub fn ones(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![1.0; shape.iter().product()] }
    }

    /// Constant-filled tensor.
    pub fn full(shape: &[usize], v: f32) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    /// Identity matrix (n, n).
    pub fn eye(n: usize) -> Self {
        let mut t = Self::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Number of axes.
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// (rows, cols) of a 2-D tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.shape.len(), 2, "not a matrix: {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    // ---- elementwise ----

    /// Elementwise map.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }

    /// Elementwise combine of two same-shaped tensors.
    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    /// Elementwise sum.
    pub fn add(&self, o: &Tensor) -> Tensor {
        self.zip(o, |a, b| a + b)
    }

    /// Elementwise difference.
    pub fn sub(&self, o: &Tensor) -> Tensor {
        self.zip(o, |a, b| a - b)
    }

    /// Elementwise (Hadamard) product.
    pub fn mul(&self, o: &Tensor) -> Tensor {
        self.zip(o, |a, b| a * b)
    }

    /// Scalar multiple.
    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    /// self += alpha * x (BLAS axpy), in place.
    ///
    /// ```
    /// use abrot::tensor::Tensor;
    /// let mut y = Tensor::zeros(&[3]);
    /// let x = Tensor::new(vec![3], vec![1., 2., 3.]);
    /// y.axpy(2.0, &x);
    /// assert_eq!(y.data, vec![2., 4., 6.]);
    /// ```
    pub fn axpy(&mut self, alpha: f32, x: &Tensor) {
        assert_eq!(self.shape, x.shape);
        for (a, b) in self.data.iter_mut().zip(&x.data) {
            *a += alpha * b;
        }
    }

    // ---- reductions ----

    /// Flattened dot product.
    pub fn dot(&self, o: &Tensor) -> f32 {
        assert_eq!(self.shape, o.shape);
        self.data.iter().zip(&o.data).map(|(a, b)| a * b).sum()
    }

    /// Frobenius / L2 norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Sum of absolute values (L1 norm).
    pub fn abs_sum(&self) -> f32 {
        self.data.iter().map(|x| x.abs()).sum()
    }

    /// Largest absolute entry (L-infinity norm).
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Mean element (0 for empty tensors).
    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            0.0
        } else {
            self.data.iter().sum::<f32>() / self.data.len() as f32
        }
    }

    /// True when no element is NaN or infinite.
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    // ---- linear algebra (reference-grade, blocked for cache locality) ----

    /// C = A @ B for 2-D tensors.
    ///
    /// ```
    /// use abrot::tensor::Tensor;
    /// let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
    /// let b = Tensor::new(vec![3, 2], vec![7., 8., 9., 10., 11., 12.]);
    /// let c = a.matmul(&b);
    /// assert_eq!(c.shape, vec![2, 2]);
    /// assert_eq!(c.data, vec![58., 64., 139., 154.]);
    /// assert_eq!(a.matmul(&Tensor::eye(3)), a);
    /// ```
    pub fn matmul(&self, b: &Tensor) -> Tensor {
        let (m, k) = self.dims2();
        let (k2, n) = b.dims2();
        assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
        let mut out = vec![0.0f32; m * n];
        // i-k-j loop order: streams B rows, accumulates into C rows.
        for i in 0..m {
            let arow = &self.data[i * k..(i + 1) * k];
            let crow = &mut out[i * n..(i + 1) * n];
            for (kk, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let brow = &b.data[kk * n..(kk + 1) * n];
                for (c, &bv) in crow.iter_mut().zip(brow) {
                    *c += a * bv;
                }
            }
        }
        Tensor::new(vec![m, n], out)
    }

    /// Matrix transpose of a 2-D tensor.
    pub fn transpose(&self) -> Tensor {
        let (m, n) = self.dims2();
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::new(vec![n, m], out)
    }

    /// Slice out sub-tensor `idx` along axis 0 (e.g. one expert of (E,D,F)).
    pub fn index_axis0(&self, idx: usize) -> Tensor {
        assert!(self.rank() >= 2 && idx < self.shape[0]);
        let sub: usize = self.shape[1..].iter().product();
        Tensor::new(self.shape[1..].to_vec(), self.data[idx * sub..(idx + 1) * sub].to_vec())
    }

    /// Overwrite sub-tensor `idx` along axis 0.
    pub fn set_axis0(&mut self, idx: usize, t: &Tensor) {
        let sub: usize = self.shape[1..].iter().product();
        assert_eq!(t.data.len(), sub);
        self.data[idx * sub..(idx + 1) * sub].copy_from_slice(&t.data);
    }
}

/// Stack equally-shaped tensors along a new leading axis.
pub fn stack(ts: &[&Tensor]) -> Tensor {
    assert!(!ts.is_empty());
    let shape = &ts[0].shape;
    let mut data = Vec::with_capacity(ts.len() * ts[0].len());
    for t in ts {
        assert_eq!(&t.shape, shape);
        data.extend_from_slice(&t.data);
    }
    let mut s = vec![ts.len()];
    s.extend_from_slice(shape);
    Tensor::new(s, data)
}

/// Split a stacked tensor back along axis 0.
pub fn unstack(t: &Tensor) -> Vec<Tensor> {
    (0..t.shape[0]).map(|i| t.index_axis0(i)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_matches_manual() {
        let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::new(vec![3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.shape, vec![2, 2]);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_identity() {
        let a = Tensor::new(vec![3, 3], (0..9).map(|x| x as f32).collect());
        let i = Tensor::eye(3);
        assert_eq!(a.matmul(&i).data, a.data);
        assert_eq!(i.matmul(&a).data, a.data);
    }

    #[test]
    fn transpose_involution() {
        let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().shape, vec![3, 2]);
        assert_eq!(a.transpose().data, vec![1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn elementwise_and_reductions() {
        let a = Tensor::new(vec![4], vec![1., -2., 3., -4.]);
        let b = Tensor::ones(&[4]);
        assert_eq!(a.add(&b).data, vec![2., -1., 4., -3.]);
        assert_eq!(a.sub(&b).data, vec![0., -3., 2., -5.]);
        assert_eq!(a.mul(&a).data, vec![1., 4., 9., 16.]);
        assert_eq!(a.abs_sum(), 10.0);
        assert_eq!(a.max_abs(), 4.0);
        assert!((a.norm() - 30.0f32.sqrt()).abs() < 1e-6);
        assert_eq!(a.dot(&b), -2.0);
    }

    #[test]
    fn axpy_accumulates() {
        let mut a = Tensor::zeros(&[3]);
        let x = Tensor::new(vec![3], vec![1., 2., 3.]);
        a.axpy(2.0, &x);
        a.axpy(-1.0, &x);
        assert_eq!(a.data, vec![1., 2., 3.]);
    }

    #[test]
    fn stack_unstack_roundtrip() {
        let a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(vec![2, 2], vec![5., 6., 7., 8.]);
        let s = stack(&[&a, &b]);
        assert_eq!(s.shape, vec![2, 2, 2]);
        let us = unstack(&s);
        assert_eq!(us[0], a);
        assert_eq!(us[1], b);
    }

    #[test]
    fn index_set_axis0() {
        let mut s = Tensor::zeros(&[3, 2, 2]);
        let t = Tensor::ones(&[2, 2]);
        s.set_axis0(1, &t);
        assert_eq!(s.index_axis0(1), t);
        assert_eq!(s.index_axis0(0), Tensor::zeros(&[2, 2]));
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Tensor::zeros(&[2]);
        let b = Tensor::zeros(&[3]);
        let _ = a.add(&b);
    }
}
