//! Dense f32 tensor — the coordinator's host-side value type.
//!
//! Deliberately dependency-free: the hot path only needs elementwise
//! ops, small matmuls (the reference kernels the native backend runs
//! on, doubling as the cross-check for the HLO/Pallas path) and
//! conversion into [`crate::runtime::Value`]s at the backend boundary.

use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} el]", self.shape, self.data.len())
    }
}

impl Tensor {
    /// Build from shape + row-major data (panics on length mismatch).
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} vs data len {}",
            shape,
            data.len()
        );
        Tensor { shape, data }
    }

    /// All-zeros tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    /// All-ones tensor.
    pub fn ones(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![1.0; shape.iter().product()] }
    }

    /// Constant-filled tensor.
    pub fn full(shape: &[usize], v: f32) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    /// Identity matrix (n, n).
    pub fn eye(n: usize) -> Self {
        let mut t = Self::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Number of axes.
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// (rows, cols) of a 2-D tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.shape.len(), 2, "not a matrix: {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    // ---- elementwise ----

    /// Elementwise map.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }

    /// Elementwise combine of two same-shaped tensors.
    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    /// Elementwise sum.
    pub fn add(&self, o: &Tensor) -> Tensor {
        self.zip(o, |a, b| a + b)
    }

    /// Elementwise difference.
    pub fn sub(&self, o: &Tensor) -> Tensor {
        self.zip(o, |a, b| a - b)
    }

    /// Elementwise (Hadamard) product.
    pub fn mul(&self, o: &Tensor) -> Tensor {
        self.zip(o, |a, b| a * b)
    }

    /// Scalar multiple.
    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    /// self += alpha * x (BLAS axpy), in place.
    ///
    /// ```
    /// use abrot::tensor::Tensor;
    /// let mut y = Tensor::zeros(&[3]);
    /// let x = Tensor::new(vec![3], vec![1., 2., 3.]);
    /// y.axpy(2.0, &x);
    /// assert_eq!(y.data, vec![2., 4., 6.]);
    /// ```
    pub fn axpy(&mut self, alpha: f32, x: &Tensor) {
        assert_eq!(self.shape, x.shape);
        for (a, b) in self.data.iter_mut().zip(&x.data) {
            *a += alpha * b;
        }
    }

    // ---- reductions ----

    /// Flattened dot product.
    pub fn dot(&self, o: &Tensor) -> f32 {
        assert_eq!(self.shape, o.shape);
        self.data.iter().zip(&o.data).map(|(a, b)| a * b).sum()
    }

    /// Frobenius / L2 norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Sum of absolute values (L1 norm).
    pub fn abs_sum(&self) -> f32 {
        self.data.iter().map(|x| x.abs()).sum()
    }

    /// Largest absolute entry (L-infinity norm).
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Mean element (0 for empty tensors).
    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            0.0
        } else {
            self.data.iter().sum::<f32>() / self.data.len() as f32
        }
    }

    /// True when no element is NaN or infinite.
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    // ---- linear algebra (cache-tiled, row-parallel; see kernels below) ----

    /// C = A @ B for 2-D tensors.
    ///
    /// Runs the cache-tiled, row-parallel kernel [`mm_into`]; bit-exact
    /// against the reference loop [`Self::matmul_ref`] at every thread
    /// count (each output element sums k in ascending order either
    /// way).
    ///
    /// ```
    /// use abrot::tensor::Tensor;
    /// let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
    /// let b = Tensor::new(vec![3, 2], vec![7., 8., 9., 10., 11., 12.]);
    /// let c = a.matmul(&b);
    /// assert_eq!(c.shape, vec![2, 2]);
    /// assert_eq!(c.data, vec![58., 64., 139., 154.]);
    /// assert_eq!(a.matmul(&Tensor::eye(3)), a);
    /// ```
    pub fn matmul(&self, b: &Tensor) -> Tensor {
        let (m, k) = self.dims2();
        let (k2, n) = b.dims2();
        assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
        let mut out = vec![0.0f32; m * n];
        mm_into(&self.data, &b.data, &mut out, m, k, n);
        Tensor::new(vec![m, n], out)
    }

    /// Reference i-k-j matmul: the pristine single-threaded loop the
    /// tiled/parallel kernel behind [`Self::matmul`] is tested
    /// bit-exact against.
    pub fn matmul_ref(&self, b: &Tensor) -> Tensor {
        let (m, k) = self.dims2();
        let (k2, n) = b.dims2();
        assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
        let mut out = vec![0.0f32; m * n];
        mm_ref_into(&self.data, &b.data, &mut out, m, k, n);
        Tensor::new(vec![m, n], out)
    }

    /// Matrix transpose of a 2-D tensor (blocked kernel
    /// [`transpose_into`]; the naive column-stride loop is kept as
    /// [`Self::transpose_ref`]).
    pub fn transpose(&self) -> Tensor {
        let (m, n) = self.dims2();
        let mut out = vec![0.0f32; m * n];
        transpose_into(&self.data, &mut out, m, n);
        Tensor::new(vec![n, m], out)
    }

    /// Reference transpose: the naive column-stride loop (thrashes on
    /// large matrices; kept as the equivalence oracle for
    /// [`Self::transpose`]).
    pub fn transpose_ref(&self) -> Tensor {
        let (m, n) = self.dims2();
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::new(vec![n, m], out)
    }

    /// Slice out sub-tensor `idx` along axis 0 (e.g. one expert of (E,D,F)).
    pub fn index_axis0(&self, idx: usize) -> Tensor {
        assert!(self.rank() >= 2 && idx < self.shape[0]);
        let sub: usize = self.shape[1..].iter().product();
        Tensor::new(self.shape[1..].to_vec(), self.data[idx * sub..(idx + 1) * sub].to_vec())
    }

    /// Overwrite sub-tensor `idx` along axis 0.
    pub fn set_axis0(&mut self, idx: usize, t: &Tensor) {
        let sub: usize = self.shape[1..].iter().product();
        assert_eq!(t.data.len(), sub);
        self.data[idx * sub..(idx + 1) * sub].copy_from_slice(&t.data);
    }
}

// ---------------------------------------------------------------------------
// Row-major matmul/transpose kernels (shared with runtime::native::dense)
// ---------------------------------------------------------------------------
//
// Bit-exactness contract: every kernel accumulates each output element
// in a single f32 accumulator visiting k in ascending order — exactly
// like its `*_ref` loop — so cache tiling and row-parallelism only
// change *which thread* computes an element, never its bits. The
// `*_ref` loops deliberately have no `a == 0.0` fast path: skipping
// zero terms swallows `0.0 * NaN` / `0.0 * inf` (masking divergence the
// engine's non-finite-loss detector must see), and for finite operands
// adding the `±0.0` product to an accumulator that starts at `+0.0`
// cannot change its bits (IEEE 754: a sum is `-0.0` only when both
// addends are `-0.0`), so dropping the skip is itself bit-neutral.

/// Multiply-add count below which a kernel stays on the calling thread
/// (spawning scoped workers costs more than the loop at test-scale
/// shapes).
const PAR_MIN_WORK: usize = 32 * 1024;
/// k-tile depth: one K_TILE-row block of B is streamed over all of a
/// task's C rows before moving to the next block.
const K_TILE: usize = 256;
/// Transpose tile edge (T_TILE² f32 = 16 KiB, comfortably L1).
const T_TILE: usize = 64;

fn par_threads(work: usize) -> usize {
    if work >= PAR_MIN_WORK {
        crate::runtime::pool::kernel_threads()
    } else {
        1
    }
}

/// out(m,n) += A(m,k) @ B(k,n) — cache-tiled, parallel over C rows.
/// Callers pass a zeroed `out`.
pub fn mm_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    crate::runtime::pool::par_rows(par_threads(m * k * n), out, n, |i0, crows| {
        let rows = crows.len() / n;
        for kb in (0..k).step_by(K_TILE) {
            let kend = (kb + K_TILE).min(k);
            for r in 0..rows {
                let arow = &a[(i0 + r) * k + kb..(i0 + r) * k + kend];
                let crow = &mut crows[r * n..(r + 1) * n];
                for (kk, &av) in arow.iter().enumerate() {
                    let brow = &b[(kb + kk) * n..(kb + kk + 1) * n];
                    for (c, &bv) in crow.iter_mut().zip(brow) {
                        *c += av * bv;
                    }
                }
            }
        }
    });
}

/// Reference i-k-j loop for [`mm_into`] (single-threaded, untiled).
pub fn mm_ref_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            let brow = &b[kk * n..(kk + 1) * n];
            for (c, &bv) in crow.iter_mut().zip(brow) {
                *c += av * bv;
            }
        }
    }
}

/// out(m,n) = A(m,k) @ B(n,k)^T — parallel over C rows, 4-wide
/// j-blocking (four independent per-element accumulators reuse the A
/// row and break the FP-add latency chain; each element still sums k
/// ascending, so the bits match [`mm_bt_ref_into`]).
pub fn mm_bt_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    crate::runtime::pool::par_rows(par_threads(m * k * n), out, n, |i0, crows| {
        let rows = crows.len() / n;
        let jend = n - n % 4;
        for r in 0..rows {
            let arow = &a[(i0 + r) * k..(i0 + r + 1) * k];
            let crow = &mut crows[r * n..(r + 1) * n];
            for j in (0..jend).step_by(4) {
                let b0 = &b[j * k..(j + 1) * k];
                let b1 = &b[(j + 1) * k..(j + 2) * k];
                let b2 = &b[(j + 2) * k..(j + 3) * k];
                let b3 = &b[(j + 3) * k..(j + 4) * k];
                let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
                for (kk, &x) in arow.iter().enumerate() {
                    s0 += x * b0[kk];
                    s1 += x * b1[kk];
                    s2 += x * b2[kk];
                    s3 += x * b3[kk];
                }
                crow[j] = s0;
                crow[j + 1] = s1;
                crow[j + 2] = s2;
                crow[j + 3] = s3;
            }
            for j in jend..n {
                let brow = &b[j * k..(j + 1) * k];
                let mut s = 0.0f32;
                for (&x, &y) in arow.iter().zip(brow) {
                    s += x * y;
                }
                crow[j] = s;
            }
        }
    });
}

/// Reference per-(i,j) dot-product loop for [`mm_bt_into`].
pub fn mm_bt_ref_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut s = 0.0f32;
            for (&x, &y) in arow.iter().zip(brow) {
                s += x * y;
            }
            out[i * n + j] = s;
        }
    }
}

/// out(m,n) = A(k,m)^T @ B(k,n) — parallel over C rows (columns of A),
/// k-tiled so each task re-streams one B block across its rows.
pub fn mm_at_into(a: &[f32], b: &[f32], out: &mut [f32], k: usize, m: usize, n: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    crate::runtime::pool::par_rows(par_threads(m * k * n), out, n, |i0, crows| {
        let rows = crows.len() / n;
        for kb in (0..k).step_by(K_TILE) {
            let kend = (kb + K_TILE).min(k);
            for r in 0..rows {
                let crow = &mut crows[r * n..(r + 1) * n];
                for kk in kb..kend {
                    let av = a[kk * m + i0 + r];
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (c, &bv) in crow.iter_mut().zip(brow) {
                        *c += av * bv;
                    }
                }
            }
        }
    });
}

/// Reference k-outer loop for [`mm_at_into`].
pub fn mm_at_ref_into(a: &[f32], b: &[f32], out: &mut [f32], k: usize, m: usize, n: usize) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for kk in 0..k {
        let arow = &a[kk * m..(kk + 1) * m];
        let brow = &b[kk * n..(kk + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            let crow = &mut out[i * n..(i + 1) * n];
            for (c, &bv) in crow.iter_mut().zip(brow) {
                *c += av * bv;
            }
        }
    }
}

/// out(n,m) = x(m,n)^T — T_TILE² blocked (both the read and the write
/// side of a tile stay cache-resident), parallel over output rows.
/// A pure permutation: trivially bit-exact at any tiling/thread count.
pub fn transpose_into(x: &[f32], out: &mut [f32], m: usize, n: usize) {
    debug_assert_eq!(x.len(), m * n);
    debug_assert_eq!(out.len(), m * n);
    crate::runtime::pool::par_rows(par_threads(m * n), out, m, |j0, orows| {
        let jrows = orows.len() / m;
        for ib in (0..m).step_by(T_TILE) {
            let iend = (ib + T_TILE).min(m);
            for jb in (0..jrows).step_by(T_TILE) {
                let jend = (jb + T_TILE).min(jrows);
                for jr in jb..jend {
                    let j = j0 + jr;
                    let orow = &mut orows[jr * m..(jr + 1) * m];
                    for i in ib..iend {
                        orow[i] = x[i * n + j];
                    }
                }
            }
        }
    });
}

/// Stack equally-shaped tensors along a new leading axis.
pub fn stack(ts: &[&Tensor]) -> Tensor {
    assert!(!ts.is_empty());
    let shape = &ts[0].shape;
    let mut data = Vec::with_capacity(ts.len() * ts[0].len());
    for t in ts {
        assert_eq!(&t.shape, shape);
        data.extend_from_slice(&t.data);
    }
    let mut s = vec![ts.len()];
    s.extend_from_slice(shape);
    Tensor::new(s, data)
}

/// Split a stacked tensor back along axis 0.
pub fn unstack(t: &Tensor) -> Vec<Tensor> {
    (0..t.shape[0]).map(|i| t.index_axis0(i)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_matches_manual() {
        let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::new(vec![3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.shape, vec![2, 2]);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_identity() {
        let a = Tensor::new(vec![3, 3], (0..9).map(|x| x as f32).collect());
        let i = Tensor::eye(3);
        assert_eq!(a.matmul(&i).data, a.data);
        assert_eq!(i.matmul(&a).data, a.data);
    }

    #[test]
    fn transpose_involution() {
        let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().shape, vec![3, 2]);
        assert_eq!(a.transpose().data, vec![1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn transpose_involution_odd_and_rectangular_shapes() {
        // shapes straddling the T_TILE edge and the parallel threshold
        for (m, n) in [
            (1, 1),
            (3, 7),
            (7, 3),
            (63, 65),
            (64, 64),
            (65, 129),
            (1, 300),
            (300, 1),
            (257, 131),
        ] {
            let mut t = Tensor::zeros(&[m, n]);
            for (i, x) in t.data.iter_mut().enumerate() {
                *x = i as f32 * 0.5 - 3.0;
            }
            assert_eq!(t.transpose().transpose(), t, "{m}x{n}");
            assert_eq!(t.transpose(), t.transpose_ref(), "{m}x{n}");
        }
    }

    #[test]
    fn matmul_propagates_nan_and_inf_through_zero_terms() {
        // 0.0 * NaN = NaN and 0.0 * inf = NaN must reach the output —
        // the removed `a == 0.0` fast path swallowed them, masking
        // divergence the engine's non-finite-loss detector watches for.
        let a = Tensor::new(vec![1, 2], vec![0.0, 1.0]);
        let b = Tensor::new(vec![2, 2], vec![f32::NAN, f32::INFINITY, 1.0, 2.0]);
        let c = a.matmul(&b);
        assert!(c.data[0].is_nan(), "0*NaN + 1*1 must be NaN, got {}", c.data[0]);
        assert!(c.data[1].is_nan(), "0*inf + 1*2 must be NaN, got {}", c.data[1]);
        let r = a.matmul_ref(&b);
        assert!(r.data[0].is_nan() && r.data[1].is_nan());
    }

    #[test]
    fn parallel_matmul_is_bit_exact_vs_ref() {
        let mut rng = crate::rngs::Rng::new(11);
        for (m, k, n) in [(1, 1, 1), (5, 3, 4), (33, 129, 65), (130, 70, 96)] {
            let mut a = Tensor::zeros(&[m, k]);
            rng.fill_normal(&mut a.data, 1.0);
            let mut b = Tensor::zeros(&[k, n]);
            rng.fill_normal(&mut b.data, 1.0);
            let want = a.matmul_ref(&b);
            for threads in [1usize, 2, 7] {
                let _g = crate::runtime::pool::install_budget(threads);
                assert_eq!(a.matmul(&b).data, want.data, "{m}x{k}x{n} threads={threads}");
                assert_eq!(a.transpose().data, a.transpose_ref().data);
            }
        }
    }

    #[test]
    fn elementwise_and_reductions() {
        let a = Tensor::new(vec![4], vec![1., -2., 3., -4.]);
        let b = Tensor::ones(&[4]);
        assert_eq!(a.add(&b).data, vec![2., -1., 4., -3.]);
        assert_eq!(a.sub(&b).data, vec![0., -3., 2., -5.]);
        assert_eq!(a.mul(&a).data, vec![1., 4., 9., 16.]);
        assert_eq!(a.abs_sum(), 10.0);
        assert_eq!(a.max_abs(), 4.0);
        assert!((a.norm() - 30.0f32.sqrt()).abs() < 1e-6);
        assert_eq!(a.dot(&b), -2.0);
    }

    #[test]
    fn axpy_accumulates() {
        let mut a = Tensor::zeros(&[3]);
        let x = Tensor::new(vec![3], vec![1., 2., 3.]);
        a.axpy(2.0, &x);
        a.axpy(-1.0, &x);
        assert_eq!(a.data, vec![1., 2., 3.]);
    }

    #[test]
    fn stack_unstack_roundtrip() {
        let a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(vec![2, 2], vec![5., 6., 7., 8.]);
        let s = stack(&[&a, &b]);
        assert_eq!(s.shape, vec![2, 2, 2]);
        let us = unstack(&s);
        assert_eq!(us[0], a);
        assert_eq!(us[1], b);
    }

    #[test]
    fn index_set_axis0() {
        let mut s = Tensor::zeros(&[3, 2, 2]);
        let t = Tensor::ones(&[2, 2]);
        s.set_axis0(1, &t);
        assert_eq!(s.index_axis0(1), t);
        assert_eq!(s.index_axis0(0), Tensor::zeros(&[2, 2]));
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let a = Tensor::zeros(&[2]);
        let b = Tensor::zeros(&[3]);
        let _ = a.add(&b);
    }
}
