//! Model-side metadata on the Rust side: parameter initialization,
//! pipeline-stage partitioning and the param↔shape-class mapping used by
//! the batched optimizer executables.
//!
//! The schema itself comes from the manifest (built-in registry in
//! `runtime::presets`, mirrored by `python/compile/configs.py` for the
//! PJRT artifact path); this module only *derives* from it.

use crate::runtime::{Manifest, ParamSpec};
use crate::rngs::Rng;
use crate::tensor::Tensor;

/// Initialize parameters exactly like `model.init_params` on the python
/// side: gains = 1, everything else N(0, 0.02), residual projections
/// (wo / w2 / w2e) scaled by 1/sqrt(2L).
pub fn init_params(man: &Manifest, seed: u64) -> Vec<Tensor> {
    let rng = Rng::new(seed);
    let resid_scale = 1.0 / (2.0 * man.cfg.n_blocks as f32).sqrt();
    man.params
        .iter()
        .enumerate()
        .map(|(i, p)| {
            if p.kind == "gain" {
                Tensor::ones(&p.shape)
            } else {
                let mut std = 0.02;
                if p.name.ends_with(".wo")
                    || p.name.ends_with(".w2")
                    || p.name.ends_with(".w2e")
                {
                    std *= resid_scale;
                }
                let mut t = Tensor::zeros(&p.shape);
                rng.fold(i as u64).fill_normal(&mut t.data, std);
                t
            }
        })
        .collect()
}

/// Pipeline partition: block b → stage floor(b·P/L); embeddings live on
/// stage 0, final norm + head on the last stage (paper D.2).
#[derive(Clone, Debug)]
pub struct StagePartition {
    pub stages: usize,
    /// stage id per parameter (manifest order).
    pub stage_of: Vec<usize>,
    /// gradient delay per parameter: τ = P-1-stage (paper: τ_i = K-k).
    pub delay_of: Vec<u32>,
    /// blocks assigned to each stage (contiguous ranges).
    pub blocks_of_stage: Vec<Vec<usize>>,
}

impl StagePartition {
    pub fn new(man: &Manifest, stages: usize) -> StagePartition {
        let l = man.cfg.n_blocks;
        assert!(stages >= 1 && stages <= l, "need 1 <= P <= L (= {l}), got {stages}");
        let stage_of_block =
            |b: usize| -> usize { (b * stages / l).min(stages - 1) };
        let stage_of: Vec<usize> = man
            .params
            .iter()
            .map(|p: &ParamSpec| {
                if p.block >= 0 {
                    stage_of_block(p.block as usize)
                } else if p.name == "tok_emb" || p.name == "pos_emb" {
                    0
                } else {
                    stages - 1 // gf, head
                }
            })
            .collect();
        let delay_of =
            stage_of.iter().map(|&s| (stages - 1 - s) as u32).collect();
        let mut blocks_of_stage = vec![Vec::new(); stages];
        for b in 0..l {
            blocks_of_stage[stage_of_block(b)].push(b);
        }
        StagePartition { stages, stage_of, delay_of, blocks_of_stage }
    }

    pub fn max_delay(&self) -> u32 {
        (self.stages - 1) as u32
    }

    /// Manifest indices of the parameters stage `k` owns (the engine's
    /// per-stage parameter view; order follows the manifest).
    pub fn params_of_stage(&self, k: usize) -> Vec<usize> {
        (0..self.stage_of.len()).filter(|&i| self.stage_of[i] == k).collect()
    }

    /// Effective stage-aware delay τ' of Eq. (3), with uniform per-
    /// coordinate smoothness weights (C_i identical): the RMS of the
    /// per-parameter delays weighted by parameter count.
    pub fn effective_delay_uniform(&self, man: &Manifest) -> f32 {
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (p, &tau) in man.params.iter().zip(&self.delay_of) {
            let d = p.shape.iter().product::<usize>() as f64;
            num += d * (tau as f64) * (tau as f64);
            den += d;
        }
        (num / den).sqrt() as f32
    }
}

/// Mapping of rotated parameters into the batched shape-class
/// executables: class `c` packs `count` matrices (one per block, or one
/// per block×expert for MoE) in block order.
#[derive(Clone, Debug)]
pub struct ClassSlot {
    /// index into the manifest param list
    pub param: usize,
    /// sub-matrix along axis 0 for expert tensors; 0 for plain matrices
    pub slot: usize,
}

#[derive(Clone, Debug)]
pub struct ClassMap {
    pub class: crate::runtime::ShapeClass,
    pub slots: Vec<ClassSlot>,
}

/// Build the per-class slot lists from the manifest schema (slot
/// convention: `ParamSpec::slots_in_class`).
pub fn class_maps(man: &Manifest) -> Vec<ClassMap> {
    man.shape_classes
        .iter()
        .map(|sc| {
            let mut slots = Vec::new();
            for (i, p) in man.params.iter().enumerate() {
                for e in 0..p.slots_in_class(&sc.name) {
                    slots.push(ClassSlot { param: i, slot: e });
                }
            }
            assert_eq!(
                slots.len(),
                sc.count,
                "class {} slot mismatch",
                sc.name
            );
            ClassMap { class: sc.clone(), slots }
        })
        .collect()
}

/// Extract the (m,n) matrix for a slot (copies; experts are sliced).
pub fn slot_matrix(params: &[Tensor], s: &ClassSlot) -> Tensor {
    let p = &params[s.param];
    if p.rank() == 3 {
        p.index_axis0(s.slot)
    } else {
        p.clone()
    }
}

/// Write a slot matrix back.
pub fn set_slot_matrix(params: &mut [Tensor], s: &ClassSlot, t: &Tensor) {
    if params[s.param].rank() == 3 {
        params[s.param].set_axis0(s.slot, t);
    } else {
        params[s.param] = t.clone();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn man(name: &str) -> Manifest {
        Manifest::builtin(name).unwrap()
    }

    #[test]
    fn init_matches_schema_and_seed_determinism() {
        let m = man("micro");
        let a = init_params(&m, 7);
        let b = init_params(&m, 7);
        let c = init_params(&m, 8);
        assert_eq!(a.len(), m.params.len());
        for ((x, y), p) in a.iter().zip(&b).zip(&m.params) {
            assert_eq!(x.shape, p.shape);
            assert_eq!(x.data, y.data);
            if p.kind == "gain" {
                assert!(x.data.iter().all(|&v| v == 1.0));
            }
        }
        assert_ne!(a[0].data, c[0].data);
    }

    #[test]
    fn residual_projections_scaled_down() {
        let m = man("micro");
        let p = init_params(&m, 3);
        let iw1 = m.param_index("b0.w1").unwrap();
        let iw2 = m.param_index("b0.w2").unwrap();
        let s1 = p[iw1].norm() / (p[iw1].len() as f32).sqrt();
        let s2 = p[iw2].norm() / (p[iw2].len() as f32).sqrt();
        assert!(s2 < s1 * 0.7, "w2 std {s2} vs w1 std {s1}");
    }

    #[test]
    fn partition_p1_no_delay() {
        let m = man("micro");
        let part = StagePartition::new(&m, 1);
        assert!(part.delay_of.iter().all(|&d| d == 0));
        assert_eq!(part.effective_delay_uniform(&m), 0.0);
    }

    #[test]
    fn partition_p_equals_l() {
        let m = man("micro"); // L = 2
        let part = StagePartition::new(&m, 2);
        // embeds stage 0, block0 stage 0, block1 stage 1, head stage 1
        let i_b0 = m.param_index("b0.wqkv").unwrap();
        let i_b1 = m.param_index("b1.wqkv").unwrap();
        assert_eq!(part.stage_of[i_b0], 0);
        assert_eq!(part.stage_of[i_b1], 1);
        assert_eq!(part.stage_of[m.param_index("tok_emb").unwrap()], 0);
        assert_eq!(part.stage_of[m.param_index("head").unwrap()], 1);
        assert_eq!(part.delay_of[i_b0], 1);
        assert_eq!(part.delay_of[i_b1], 0);
        assert!(part.effective_delay_uniform(&m) > 0.0);
        assert!(part.effective_delay_uniform(&m) <= part.max_delay() as f32);
    }

    #[test]
    fn params_of_stage_covers_everything_once() {
        let m = man("micro");
        let part = StagePartition::new(&m, 2);
        let s0 = part.params_of_stage(0);
        let s1 = part.params_of_stage(1);
        assert_eq!(s0.len() + s1.len(), m.params.len());
        assert!(s0.iter().all(|i| !s1.contains(i)));
        // a restricted manifest re-partitions to the same stages/delays
        let sub = m.restrict(&s1);
        let part_local = StagePartition::new(&sub, 2);
        for (local, &global) in s1.iter().enumerate() {
            assert_eq!(part_local.stage_of[local], part.stage_of[global]);
            assert_eq!(part_local.delay_of[local], part.delay_of[global]);
        }
    }

    #[test]
    #[should_panic]
    fn partition_more_stages_than_blocks_panics() {
        let m = man("micro");
        let _ = StagePartition::new(&m, 5);
    }

    #[test]
    fn class_maps_cover_all_rotated_params() {
        let m = man("micro");
        let maps = class_maps(&m);
        assert_eq!(maps.len(), 4);
        let total: usize = maps.iter().map(|c| c.slots.len()).sum();
        let rotated = m.params.iter().filter(|p| p.rotated).count();
        assert_eq!(total, rotated); // dense: 1 slot per rotated matrix
        for cm in &maps {
            for s in &cm.slots {
                let p = &m.params[s.param];
                assert!(p.rotated);
                let (mm, nn) = (p.shape[p.shape.len() - 2], p.shape[p.shape.len() - 1]);
                assert_eq!((mm, nn), (cm.class.m, cm.class.n));
            }
        }
    }

    #[test]
    fn moe_class_maps_fold_experts() {
        let m = man("moe_micro");
        let maps = class_maps(&m);
        let w1e = maps.iter().find(|c| c.class.name == "w1e").unwrap();
        assert_eq!(w1e.slots.len(), m.cfg.n_blocks * m.cfg.moe.as_ref().unwrap().n_experts);
    }

    #[test]
    fn slot_roundtrip() {
        let m = man("moe_micro");
        let mut params = init_params(&m, 1);
        let maps = class_maps(&m);
        let cm = maps.iter().find(|c| c.class.name == "w1e").unwrap();
        let s = &cm.slots[3];
        let t = slot_matrix(&params, s);
        let t2 = t.scale(2.0);
        set_slot_matrix(&mut params, s, &t2);
        assert_eq!(slot_matrix(&params, s), t2);
    }
}
