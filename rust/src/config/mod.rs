//! Run configuration: optimization method, schedule, pipeline shape.
//!
//! The *model* configuration is owned by the artifact manifest
//! (`runtime::Manifest`) — single source of truth emitted by
//! `python/compile/aot.py`. This module configures everything the
//! coordinator decides at run time.

use std::fmt;

/// Eigenbasis-estimation strategy axes (paper §3.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Source {
    First,  // S = 1st: momentum outer products
    Second, // S = 2nd: Kronecker-factored empirical Fisher EMA
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Geometry {
    Unilateral,
    Bilateral,
}

/// How the per-stage rotation budget is allocated (paper Fig. 9c / 17).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FreqAlloc {
    /// Same update frequency everywhere.
    Uniform,
    /// More frequent basis refresh at earlier (more delayed) stages.
    StageAware,
    /// Ablation: the reverse allocation (paper Fig. 17).
    InverseStageAware,
}

/// Training method — the paper's baselines + basis rotation variants +
/// the preconditioned comparators of Table 3.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Method {
    /// Vanilla asynchronous Adam (PipeDream).
    PipeDream,
    /// Stage-wise learning-rate rescaling (PipeDream-LR / Yang et al.).
    PipeDreamLr,
    /// Nesterov-momentum correction (Ajanthan et al. 2025).
    Nesterov,
    /// Delay compensation via Taylor expansion (Zheng et al. 2017).
    DelayComp { lambda: f32 },
    /// The paper's contribution.
    BasisRotation { source: Source, geometry: Geometry, freq: u32,
                    alloc: FreqAlloc },
    /// SOAP (Vyas et al. 2025): rotated-space momentum accumulation.
    Soap { freq: u32 },
    /// Muon (Jordan et al. 2024): NS-orthogonalized momentum.
    Muon,
    /// Scion (Pethick et al. 2025): norm-constrained LMO steps.
    Scion,
}

impl Method {
    pub fn name(&self) -> String {
        match self {
            Method::PipeDream => "pipedream".into(),
            Method::PipeDreamLr => "pipedream_lr".into(),
            Method::Nesterov => "nesterov".into(),
            Method::DelayComp { lambda } => format!("dc_{lambda}"),
            Method::BasisRotation { source, geometry, freq, alloc } => {
                let s = match source { Source::First => "1st", Source::Second => "2nd" };
                let g = match geometry { Geometry::Unilateral => "uni", Geometry::Bilateral => "bi" };
                let a = match alloc {
                    FreqAlloc::Uniform => "",
                    FreqAlloc::StageAware => "_sa",
                    FreqAlloc::InverseStageAware => "_isa",
                };
                format!("br_{s}_{g}_f{freq}{a}")
            }
            Method::Soap { freq } => format!("soap_f{freq}"),
            Method::Muon => "muon".into(),
            Method::Scion => "scion".into(),
        }
    }

    /// Default basis rotation per the paper: S=2nd, bilateral, freq 10.
    pub fn br_default() -> Method {
        Method::BasisRotation {
            source: Source::Second,
            geometry: Geometry::Bilateral,
            freq: 10,
            alloc: FreqAlloc::Uniform,
        }
    }
}

impl fmt::Display for Method {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Which pipeline schedule drives the per-stage action streams
/// (`pipeline::schedule`). The schedule decides warmup counts,
/// fwd/bwd interleaving, how many microbatches feed one optimizer
/// update, and the per-stage gradient-delay profile the staleness
/// model (and the delay-aware optimizers) see.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleKind {
    /// Synchronous GPipe: M forwards, M backwards, one update. Delay 0
    /// everywhere; bubble (P-1)/(M+P-1).
    Gpipe,
    /// Asynchronous 1F1B (PipeDream) — the repo's original hard-coded
    /// schedule: stage k runs P-1-k warmup forwards then alternates
    /// fwd/bwd with an update per microbatch. Delay P-1-k at stage k.
    OneFOneB,
    /// Synchronous interleaved 1F1B (Megatron): V virtual chunks per
    /// worker shrink the fill bubble to (P-1)/(M·V+P-1). Delay 0.
    Interleaved { v: usize },
    /// Asynchronous bidirectional schedule (AMDP/Chimera-style): two
    /// counter-flowing 1F1B streams over two full weight copies; each
    /// update averages one microbatch per direction. Delay P-1-k,
    /// requires even P.
    Amdp,
}

impl ScheduleKind {
    /// CLI name. `Interleaved` encodes V: `interleaved:2`.
    pub fn name(&self) -> String {
        match self {
            ScheduleKind::Gpipe => "gpipe".into(),
            ScheduleKind::OneFOneB => "1f1b".into(),
            ScheduleKind::Interleaved { v } => format!("interleaved:{v}"),
            ScheduleKind::Amdp => "amdp".into(),
        }
    }

    /// Parse a `--schedule` value: `gpipe | 1f1b | interleaved[:V] | amdp`.
    pub fn parse(s: &str) -> Option<ScheduleKind> {
        match s {
            "gpipe" => Some(ScheduleKind::Gpipe),
            "1f1b" | "pipedream" => Some(ScheduleKind::OneFOneB),
            "amdp" => Some(ScheduleKind::Amdp),
            _ => {
                let rest = s.strip_prefix("interleaved")?;
                if rest.is_empty() {
                    return Some(ScheduleKind::Interleaved { v: 2 });
                }
                let v: usize = rest.strip_prefix(':')?.parse().ok()?;
                (v >= 1).then_some(ScheduleKind::Interleaved { v })
            }
        }
    }
}

impl fmt::Display for ScheduleKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// How stale weights are handled at the forward pass (paper §4.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StashMode {
    /// Weight stashing (PipeDream): forward & backward per stage use the
    /// stashed version — correct per-stage gradients.
    Stash,
    /// No stashing: backward uses current weights against activations
    /// from stale weights — incorrect gradients (Fig. 10).
    NoStash,
    /// PipeMare-style weight prediction at the forward pass (Fig. 15).
    Predict,
}

#[derive(Clone, Debug)]
pub struct TrainCfg {
    pub method: Method,
    /// Number of pipeline stages P (delay at stage k is P-1-k).
    pub stages: usize,
    /// Data-parallel pipeline replicas R (DP x PP). Each replica runs
    /// the full P-stage pipeline on a disjoint data shard; gradients
    /// are averaged across replicas at every optimizer step
    /// (`pipeline::dp`), so `steps` counts optimizer steps and each
    /// step consumes R microbatches. 0 is treated as 1.
    pub replicas: usize,
    pub steps: u32,
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    pub grad_clip: f32,
    /// Linear warmup fraction followed by cosine decay (paper D.2).
    pub warmup_frac: f32,
    pub stash: StashMode,
    /// Pipeline schedule (see [`ScheduleKind`]). `OneFOneB` is the
    /// original behavior and keeps every pre-schedule config bit-exact.
    pub schedule: ScheduleKind,
    /// In-flight microbatches M for the synchronous schedules (GPipe /
    /// interleaved): how many microbatches one optimizer update
    /// averages over. 0 = auto (M = P). Ignored by `1f1b` (1 per
    /// update) and `amdp` (2 per update, one per direction).
    pub microbatches: u32,
    pub seed: u64,
    pub eval_every: u32,
    pub log_every: u32,
    /// Write a crash-consistent `checkpoint::RunState` snapshot every K
    /// optimizer steps (0 = off). Snapshots land in `checkpoint_dir`.
    pub checkpoint_every: u32,
    /// Directory for periodic snapshots (default `checkpoints/`).
    pub checkpoint_dir: Option<String>,
    /// Resume from a snapshot file written by a previous run. The run
    /// must be configured identically (model, method, schedule, P, R,
    /// seed, total steps) — resume validates and then continues
    /// bit-exactly where the snapshot left off.
    pub resume: Option<String>,
    /// Write a Chrome `trace_event` JSON span timeline of the engine
    /// run (one merged timeline per worker thread; open in
    /// `chrome://tracing` or Perfetto). Segmented/elastic runs rewrite
    /// the file per segment, so it holds the final segment's spans.
    pub trace: Option<String>,
    /// Write step-granularity run metrics as JSONL (one object per
    /// optimizer step: loss, lr, staleness, queue depth; see
    /// `metrics::Registry`).
    pub metrics: Option<String>,
    /// Kernel thread budget for the pooled compute layer (`--threads`).
    /// 0 = auto (`ABROT_THREADS` env override, else
    /// `available_parallelism`). The engine divides this budget across
    /// its P x R stage workers so workers x kernel threads never
    /// oversubscribes the host; results are bit-identical at any
    /// setting (see `runtime::pool`).
    pub threads: usize,
    /// Bounded-staleness asynchronous DP (`--dp-async`): replicas stop
    /// barriering at every optimizer step and instead fold whatever
    /// peer gradients have arrived within `max_skew` steps
    /// (`pipeline::dp_async`). A replica stalls only when it would run
    /// more than `max_skew` steps ahead of the slowest peer. With
    /// `max_skew = 0` this reduces bit-exactly to the synchronous path.
    pub dp_async: bool,
    /// Skew bound K for `dp_async`: the maximum number of optimizer
    /// steps any replica may run ahead of the slowest peer.
    pub max_skew: u32,
    /// Reduce timeout in milliseconds: how long a replica waits on a
    /// peer inside an all-reduce (sync or async) before erroring loudly
    /// naming the unresponsive peer. 0 = the 120 s default.
    pub reduce_timeout_ms: u64,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            method: Method::PipeDream,
            stages: 1,
            replicas: 1,
            steps: 200,
            lr: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.01,
            grad_clip: 1.0,
            warmup_frac: 0.012,
            stash: StashMode::Stash,
            schedule: ScheduleKind::OneFOneB,
            microbatches: 0,
            seed: 1234,
            eval_every: 0,
            log_every: 10,
            checkpoint_every: 0,
            checkpoint_dir: None,
            resume: None,
            trace: None,
            metrics: None,
            threads: 0,
            dp_async: false,
            max_skew: 0,
            reduce_timeout_ms: 0,
        }
    }
}

impl TrainCfg {
    /// Scheduled learning rate at step t (1-based): linear warmup then
    /// cosine decay to 10% (paper Appendix D.2).
    pub fn lr_at(&self, t: u32) -> f32 {
        let warm = ((self.steps as f32 * self.warmup_frac).ceil() as u32).max(1);
        if t <= warm {
            return self.lr * t as f32 / warm as f32;
        }
        let prog = (t - warm) as f32 / (self.steps - warm).max(1) as f32;
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * prog.min(1.0)).cos());
        self.lr * (0.1 + 0.9 * cos)
    }

    /// Effective data-parallel width: `replicas` with 0 treated as 1,
    /// so configs predating the DP axis keep their meaning.
    pub fn dp_replicas(&self) -> usize {
        self.replicas.max(1)
    }

    /// Resolved reduce timeout: `reduce_timeout_ms` with 0 meaning the
    /// 120 s default — long enough that only a genuinely wedged peer
    /// (not an injected straggler sleep) ever trips it.
    pub fn reduce_timeout(&self) -> std::time::Duration {
        let ms = if self.reduce_timeout_ms == 0 { 120_000 } else { self.reduce_timeout_ms };
        std::time::Duration::from_millis(ms)
    }

    /// DP reduce-mode identity for checkpoints: `None` for the
    /// synchronous barrier, `"async:K"` under `--dp-async`. Snapshots
    /// record it and resume validates it — the skew bound is part of
    /// the delay model, so crossing modes mid-run would silently change
    /// the trajectory.
    pub fn dp_mode(&self) -> Option<String> {
        if self.dp_async {
            Some(format!("async:{}", self.max_skew))
        } else {
            None
        }
    }

    /// The paper's β1 convention: 0.99 for Nesterov, 0.9 otherwise.
    pub fn effective_beta1(&self) -> f32 {
        match self.method {
            Method::Nesterov => 0.99,
            _ => self.beta1,
        }
    }
}

/// Stage-wise LR multiplier for PipeDream-LR (Yang et al. 2021): scale
/// down proportionally to sqrt(1 + delay).
pub fn pipedream_lr_scale(delay: u32) -> f32 {
    1.0 / (1.0 + delay as f32).sqrt()
}

/// Stage-aware rotation frequency (paper Appendix I scheduling rule):
/// stages with larger delay refresh their basis more often, under the
/// same total budget as uniform `f0`.
pub fn stage_aware_freq(f0: u32, delay: u32, stages: usize) -> u32 {
    if stages <= 1 {
        return f0;
    }
    let mid = (stages / 2).max(1) as f32;
    let tau = delay as f32;
    let n = if tau > mid - 1.0 { mid - 1.0 - tau } else { mid - tau };
    let denom = 1.0 - n / mid; // in (0, 2)
    ((f0 as f32 / denom.max(0.25)).floor() as u32).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_warms_up_then_decays() {
        let cfg = TrainCfg { steps: 1000, lr: 1e-3, ..Default::default() };
        assert!(cfg.lr_at(1) < cfg.lr_at(10));
        let warm = (1000.0f32 * cfg.warmup_frac).ceil() as u32;
        assert!((cfg.lr_at(warm) - 1e-3).abs() < 1e-9);
        assert!(cfg.lr_at(500) < 1e-3);
        assert!(cfg.lr_at(1000) < cfg.lr_at(500));
        // floor at 10%
        assert!(cfg.lr_at(1000) >= 0.1 * 1e-3 - 1e-9);
    }

    #[test]
    fn lr_schedule_monotone_after_warmup() {
        let cfg = TrainCfg { steps: 400, ..Default::default() };
        let warm = (400.0f32 * cfg.warmup_frac).ceil() as u32;
        let mut prev = f32::INFINITY;
        for t in warm..=400 {
            let l = cfg.lr_at(t);
            assert!(l <= prev + 1e-9);
            prev = l;
        }
    }

    #[test]
    fn pipedream_lr_scale_decreases_with_delay() {
        assert_eq!(pipedream_lr_scale(0), 1.0);
        assert!(pipedream_lr_scale(3) < pipedream_lr_scale(1));
        assert!((pipedream_lr_scale(3) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn stage_aware_freq_monotone_in_delay() {
        // larger delay ⇒ more frequent (smaller freq value)
        let stages = 8;
        let f: Vec<u32> =
            (0..stages as u32).map(|d| stage_aware_freq(10, d, stages)).collect();
        assert!(f[7] <= f[0], "{f:?}");
        assert!(f.iter().all(|&x| x >= 1));
    }

    #[test]
    fn method_names_unique() {
        let ms = [
            Method::PipeDream,
            Method::PipeDreamLr,
            Method::Nesterov,
            Method::DelayComp { lambda: 0.1 },
            Method::br_default(),
            Method::Soap { freq: 10 },
            Method::Muon,
            Method::Scion,
        ];
        let names: std::collections::HashSet<_> =
            ms.iter().map(|m| m.name()).collect();
        assert_eq!(names.len(), ms.len());
    }

    #[test]
    fn dp_replicas_defaults_to_one() {
        let cfg = TrainCfg::default();
        assert_eq!(cfg.replicas, 1);
        assert_eq!(cfg.dp_replicas(), 1);
        let zero = TrainCfg { replicas: 0, ..Default::default() };
        assert_eq!(zero.dp_replicas(), 1);
        let four = TrainCfg { replicas: 4, ..Default::default() };
        assert_eq!(four.dp_replicas(), 4);
    }

    #[test]
    fn schedule_kind_parse_round_trips() {
        let kinds = [
            ScheduleKind::Gpipe,
            ScheduleKind::OneFOneB,
            ScheduleKind::Interleaved { v: 2 },
            ScheduleKind::Interleaved { v: 4 },
            ScheduleKind::Amdp,
        ];
        for k in kinds {
            assert_eq!(ScheduleKind::parse(&k.name()), Some(k), "{k}");
        }
        // bare `interleaved` defaults to V=2; aliases and junk
        assert_eq!(
            ScheduleKind::parse("interleaved"),
            Some(ScheduleKind::Interleaved { v: 2 })
        );
        assert_eq!(ScheduleKind::parse("pipedream"), Some(ScheduleKind::OneFOneB));
        assert_eq!(ScheduleKind::parse("interleaved:0"), None);
        assert_eq!(ScheduleKind::parse("gpipe2"), None);
        // default config keeps the original schedule
        assert_eq!(TrainCfg::default().schedule, ScheduleKind::OneFOneB);
        assert_eq!(TrainCfg::default().microbatches, 0);
    }

    #[test]
    fn nesterov_beta1_override() {
        let mut cfg = TrainCfg::default();
        assert_eq!(cfg.effective_beta1(), 0.9);
        cfg.method = Method::Nesterov;
        assert_eq!(cfg.effective_beta1(), 0.99);
    }
}
