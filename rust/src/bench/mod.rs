//! Tiny benchmarking framework for the `harness = false` cargo benches
//! (criterion is unavailable in this offline environment): warmup,
//! fixed-iteration timing, median/p10/p90 reporting.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_us: f64,
    pub p10_us: f64,
    pub p90_us: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "bench {:<40} {:>10.1} us/iter  (p10 {:>9.1}, p90 {:>9.1}, n={})",
            self.name, self.median_us, self.p10_us, self.p90_us, self.iters
        );
    }
}

/// Run `f` `iters` times after `warmup` calls; per-iteration timing.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    let r = BenchResult {
        name: name.to_string(),
        iters,
        median_us: q(0.5),
        p10_us: q(0.1),
        p90_us: q(0.9),
    };
    r.print();
    r
}

/// Time a single long-running closure.
pub fn time_once<T>(name: &str, f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    let secs = t0.elapsed().as_secs_f64();
    println!("time  {name:<40} {secs:>10.3} s");
    (out, secs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_ordered_quantiles() {
        let r = bench("noop", 2, 50, || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.p10_us <= r.median_us && r.median_us <= r.p90_us);
    }
}
