//! Tiny benchmarking framework for the `harness = false` cargo benches
//! (criterion is unavailable in this offline environment): warmup,
//! fixed-iteration timing, median/p10/p90 reporting, and JSON
//! snapshots (`BENCH_*.json`) so the perf trajectory is recorded
//! in-repo and regressions are visible across PRs.

use std::path::Path;
use std::time::Instant;

#[derive(Clone, Debug, serde::Serialize, serde::Deserialize)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_us: f64,
    pub p10_us: f64,
    pub p90_us: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "bench {:<40} {:>10.1} us/iter  (p10 {:>9.1}, p90 {:>9.1}, n={})",
            self.name, self.median_us, self.p10_us, self.p90_us, self.iters
        );
    }
}

/// Nearest-rank percentile of an ascending-sorted sample vector:
/// the smallest value with at least `p * n` samples at or below it
/// (`ceil(p * n)`-th order statistic). Unlike truncating `(n-1) * p`
/// indexing, this never biases p50/p90 low on small n.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let n = sorted.len();
    let rank = (p * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

/// Run `f` `iters` times after `warmup` calls; per-iteration timing.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let r = BenchResult {
        name: name.to_string(),
        iters,
        median_us: percentile(&samples, 0.5),
        p10_us: percentile(&samples, 0.1),
        p90_us: percentile(&samples, 0.9),
    };
    r.print();
    r
}

/// Time a single long-running closure.
pub fn time_once<T>(name: &str, f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    let secs = t0.elapsed().as_secs_f64();
    println!("time  {name:<40} {secs:>10.3} s");
    (out, secs)
}

// ---------------------------------------------------------------------------
// Snapshots (BENCH_<area>.json) + regression comparison
// ---------------------------------------------------------------------------

/// Where the snapshot was recorded; medians are only comparable on
/// similar hosts, so the comparison helper reports fingerprint
/// mismatches instead of flagging timing deltas across machines.
#[derive(Clone, Debug, serde::Serialize, serde::Deserialize)]
pub struct HostInfo {
    pub os: String,
    pub arch: String,
    pub cpus: usize,
}

pub fn host_fingerprint() -> HostInfo {
    HostInfo {
        os: std::env::consts::OS.to_string(),
        arch: std::env::consts::ARCH.to_string(),
        cpus: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    }
}

/// A recorded bench run: host fingerprint + per-bench quantiles.
/// `area` names the snapshot family ("engine", "kernels").
#[derive(Clone, Debug, serde::Serialize, serde::Deserialize)]
pub struct BenchSnapshot {
    pub schema_version: u32,
    pub area: String,
    pub host: HostInfo,
    /// Kernel thread budget the run was recorded at (`runtime::pool`
    /// resolved value). `None` on snapshots predating the pooled kernel
    /// layer; medians at different thread budgets are not comparable,
    /// so the comparison helper treats a mismatch like a host-
    /// fingerprint mismatch. (The vendored serde derive revives a
    /// missing key as `None`, keeping pre-pool snapshots loadable.)
    pub threads: Option<usize>,
    pub results: Vec<BenchResult>,
}

pub const SNAPSHOT_SCHEMA_VERSION: u32 = 1;

impl BenchSnapshot {
    pub fn new(area: &str, results: Vec<BenchResult>) -> BenchSnapshot {
        BenchSnapshot {
            schema_version: SNAPSHOT_SCHEMA_VERSION,
            area: area.to_string(),
            host: host_fingerprint(),
            threads: Some(crate::runtime::pool::kernel_threads()),
            results,
        }
    }
}

pub fn write_snapshot(path: impl AsRef<Path>, snap: &BenchSnapshot) -> anyhow::Result<()> {
    use serde::Serialize;
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, snap.to_json())?;
    Ok(())
}

pub fn load_snapshot(path: impl AsRef<Path>) -> anyhow::Result<BenchSnapshot> {
    let text = std::fs::read_to_string(path.as_ref())?;
    serde::from_str(&text).map_err(anyhow::Error::msg)
}

/// Basic shape validation for a snapshot (used by the CI bench-smoke
/// job): known schema version, non-empty results, finite ordered
/// quantiles.
pub fn validate_snapshot(snap: &BenchSnapshot) -> Result<(), String> {
    if snap.schema_version != SNAPSHOT_SCHEMA_VERSION {
        return Err(format!("unknown schema_version {}", snap.schema_version));
    }
    if snap.results.is_empty() {
        return Err("snapshot has no results".to_string());
    }
    for r in &snap.results {
        if r.name.is_empty() || r.iters == 0 {
            return Err(format!("malformed result {:?}", r.name));
        }
        for v in [r.median_us, r.p10_us, r.p90_us] {
            if !v.is_finite() || v < 0.0 {
                return Err(format!("non-finite quantile in {:?}", r.name));
            }
        }
        if !(r.p10_us <= r.median_us && r.median_us <= r.p90_us) {
            return Err(format!("unordered quantiles in {:?}", r.name));
        }
    }
    Ok(())
}

/// One comparison row between a current run and the committed baseline.
#[derive(Clone, Debug)]
pub struct BenchDelta {
    pub name: String,
    pub baseline_us: f64,
    pub current_us: f64,
    /// current / baseline median; > 1 is slower.
    pub ratio: f64,
    pub regressed: bool,
}

/// Comparison output: per-bench deltas plus benches present on only
/// one side and whether the host fingerprints matched (timing deltas
/// across differing hosts are informational, not regressions).
#[derive(Debug, Default)]
pub struct BenchComparison {
    pub deltas: Vec<BenchDelta>,
    pub only_baseline: Vec<String>,
    pub only_current: Vec<String>,
    pub host_match: bool,
}

impl BenchComparison {
    pub fn regressions(&self) -> Vec<&BenchDelta> {
        self.deltas.iter().filter(|d| d.regressed).collect()
    }

    pub fn print(&self) {
        for d in &self.deltas {
            println!(
                "cmp   {:<40} {:>10.1} -> {:>10.1} us  ({:>5.2}x){}",
                d.name,
                d.baseline_us,
                d.current_us,
                d.ratio,
                if d.regressed { "  REGRESSION" } else { "" }
            );
        }
        for n in &self.only_baseline {
            println!("cmp   {n:<40} missing from current run");
        }
        for n in &self.only_current {
            println!("cmp   {n:<40} new (no baseline)");
        }
        if !self.host_match {
            println!("cmp   (host fingerprint or thread budget differs from baseline; ratios are informational)");
        }
    }
}

/// Flag current medians more than `tol` times the baseline median
/// (e.g. `tol = 1.5` -> 50% slower). Regressions are only flagged when
/// the host fingerprint matches the baseline's, including the kernel
/// thread budget when both snapshots record one (a snapshot at
/// `--threads 1` is not a regression oracle for a `--threads 4` run).
pub fn compare_snapshots(current: &BenchSnapshot, baseline: &BenchSnapshot, tol: f64) -> BenchComparison {
    let threads_match = match (current.threads, baseline.threads) {
        (Some(a), Some(b)) => a == b,
        _ => true, // pre-pool snapshot: no budget recorded, can't gate on it
    };
    let host_match = current.host.os == baseline.host.os
        && current.host.arch == baseline.host.arch
        && current.host.cpus == baseline.host.cpus
        && threads_match;
    let mut cmp = BenchComparison { host_match, ..Default::default() };
    for b in &baseline.results {
        match current.results.iter().find(|c| c.name == b.name) {
            Some(c) => {
                let ratio = if b.median_us > 0.0 { c.median_us / b.median_us } else { 1.0 };
                cmp.deltas.push(BenchDelta {
                    name: b.name.clone(),
                    baseline_us: b.median_us,
                    current_us: c.median_us,
                    ratio,
                    regressed: host_match && ratio > tol,
                });
            }
            None => cmp.only_baseline.push(b.name.clone()),
        }
    }
    for c in &current.results {
        if !baseline.results.iter().any(|b| b.name == c.name) {
            cmp.only_current.push(c.name.clone());
        }
    }
    cmp
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_ordered_quantiles() {
        let r = bench("noop", 2, 50, || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.p10_us <= r.median_us && r.median_us <= r.p90_us);
    }

    #[test]
    fn bench_percentiles_nearest_rank_on_10_samples() {
        let samples: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        // nearest-rank on n=10: p10 -> 1st, p50 -> 5th, p90 -> 9th
        assert_eq!(percentile(&samples, 0.10), 1.0);
        assert_eq!(percentile(&samples, 0.50), 5.0);
        assert_eq!(percentile(&samples, 0.90), 9.0);
        assert_eq!(percentile(&samples, 1.0), 10.0);
        // the old truncating (n-1)*p indexing gave p90 -> samples[8]=9
        // but p50 -> samples[4]=5 only by luck of odd offsets; pin the
        // small-n case that exposed the bias:
        let three = vec![1.0, 2.0, 3.0];
        assert_eq!(percentile(&three, 0.5), 2.0);
        assert_eq!(percentile(&three, 0.9), 3.0); // old code: index 1 -> 2.0
    }

    #[test]
    fn bench_snapshot_roundtrip_and_compare() {
        let mk = |name: &str, med: f64| BenchResult {
            name: name.to_string(),
            iters: 10,
            median_us: med,
            p10_us: med * 0.9,
            p90_us: med * 1.2,
        };
        let base = BenchSnapshot::new("engine", vec![mk("a", 100.0), mk("b", 50.0), mk("gone", 1.0)]);
        validate_snapshot(&base).unwrap();

        let dir = std::env::temp_dir().join("abrot_bench_snap");
        let p = dir.join("BENCH_test.json");
        write_snapshot(&p, &base).unwrap();
        let loaded = load_snapshot(&p).unwrap();
        assert_eq!(loaded.area, "engine");
        assert_eq!(loaded.results.len(), 3);
        assert_eq!(loaded.results[0].name, "a");
        assert!((loaded.results[1].median_us - 50.0).abs() < 1e-9);

        let cur = BenchSnapshot::new("engine", vec![mk("a", 200.0), mk("b", 51.0), mk("new", 9.0)]);
        let cmp = compare_snapshots(&cur, &loaded, 1.5);
        let regs = cmp.regressions();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].name, "a");
        assert!(regs[0].ratio > 1.9);
        assert_eq!(cmp.only_baseline, vec!["gone".to_string()]);
        assert_eq!(cmp.only_current, vec!["new".to_string()]);

        // identical snapshots never regress
        let same = compare_snapshots(&loaded, &loaded, 1.5);
        assert!(same.regressions().is_empty());
    }

    #[test]
    fn bench_thread_budget_mismatch_suppresses_regressions() {
        let mk = |name: &str, med: f64| BenchResult {
            name: name.to_string(),
            iters: 10,
            median_us: med,
            p10_us: med * 0.9,
            p90_us: med * 1.2,
        };
        let mut base = BenchSnapshot::new("engine", vec![mk("a", 100.0)]);
        base.threads = Some(1);
        let mut cur = BenchSnapshot::new("engine", vec![mk("a", 500.0)]);
        cur.threads = Some(4);
        // different recorded budgets: informational only, never a regression
        let cmp = compare_snapshots(&cur, &base, 1.5);
        assert!(!cmp.host_match);
        assert!(cmp.regressions().is_empty());
        // a pre-pool baseline records no budget, so the host gate alone decides
        base.threads = None;
        let cmp2 = compare_snapshots(&cur, &base, 1.5);
        assert_eq!(cmp2.regressions().len(), 1);
    }

    #[test]
    fn bench_validate_rejects_malformed() {
        let mut s = BenchSnapshot::new("x", vec![]);
        assert!(validate_snapshot(&s).is_err());
        s.results.push(BenchResult {
            name: "a".into(),
            iters: 5,
            median_us: 1.0,
            p10_us: 2.0, // unordered
            p90_us: 3.0,
        });
        assert!(validate_snapshot(&s).is_err());
    }
}
