//! Bounded-staleness asynchronous data-parallel gradient reduction
//! (`--dp-async --max-skew K`).
//!
//! The synchronous reducer ([`super::dp`]) barriers every replica at
//! every optimizer step: the group advances at the pace of its slowest
//! member. This module replaces the barrier with a **bounded step
//! skew**: each replica broadcasts its gradient tagged `(replica,
//! step)` to every peer, folds whatever peer contributions have
//! arrived, and blocks only when proceeding would put it more than `K`
//! optimizer steps ahead of the slowest live peer. A straggler
//! therefore delays its peers by at most the work of `K` steps instead
//! of stalling the group at every reduce.
//!
//! Semantics the engine and the tests rely on:
//!
//! - **Fold determinism.** For its step `s`, a replica selects per
//!   peer the newest contribution with step `≤ s` and folds the
//!   selected sets in replica-id order through [`dp::average`] — the
//!   same deterministic left fold as the synchronous path. *Which*
//!   step gets selected depends on arrival timing when `K > 0` (that
//!   is the staleness being modeled); the fold order never does.
//! - **Skew bound.** The stall rule guarantees every selected
//!   contribution satisfies `s - step ≤ K`: a replica only reaches
//!   step `s` once every live peer has reached `s - K`, and boards
//!   keep contributions contiguously from the last selection upward.
//!   Realized per-peer skews are recorded in [`AsyncReducer::
//!   skew_hist`] so the bound is test-pinnable.
//! - **`K = 0` ≡ synchronous.** The stall rule degenerates to "wait
//!   until every peer has reached my step", the selection to "my
//!   step's contribution from every replica", and the fold to exactly
//!   [`dp::average`] over the step-`s` gradients — bit-identical to
//!   [`dp::Reducer::all_reduce`].
//! - **Retirement.** A replica whose final contribution
//!   (`step == final_step`) has been absorbed is *retired*: it is
//!   excluded from the stall bound (it will never advance again) and
//!   its closed channel is not an error. Its final-window
//!   contributions still participate in the fold.
//! - **Failures are loud.** A peer that hangs up before retiring
//!   (crash, kill fault) or stays silent past the reduce timeout
//!   surfaces as an `Err` naming the peer, exactly like the
//!   synchronous reducer's wind-down signal.
//!
//! During the first `K` steps a slow starter may have contributed
//! nothing yet; it is simply absent from the fold (the average runs
//! over the replicas that have arrived), mirroring how the bound
//! admits partial views within the skew window. With `K = 0` this
//! never happens.

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::dp;
use crate::tensor::Tensor;

/// One stale-tolerant gradient message: the sending replica, the
/// optimizer step it was computed at (1-based within the run; offset
/// by the segment's `start_update` under checkpointing), and the
/// gradient set itself.
struct Contribution {
    from: usize,
    step: u64,
    grads: Vec<Tensor>,
}

/// One replica's handle into a bounded-skew all-to-all reduce group.
/// Unlike the synchronous tree, every participant folds locally (the
/// selection is per-replica state), so the topology is a full mesh of
/// mpsc channels: R·(R-1) senders overall, one receiver per replica.
pub struct AsyncReducer {
    /// Replica id of this handle (0-based).
    pub id: usize,
    /// Group size R.
    pub replicas: usize,
    /// Skew bound K in optimizer steps.
    pub max_skew: u32,
    /// Step counter value before the group's first reduce (0 for a
    /// fresh run, `start_update` for a resumed segment).
    first_step: u64,
    /// Last step of the run/segment; a peer observed at this step is
    /// retired from the stall bound.
    final_step: u64,
    timeout: Duration,
    /// Senders to every peer, indexed by replica id (`None` at own id).
    txs: Vec<Option<Sender<Contribution>>>,
    rx: Receiver<Contribution>,
    /// Per-replica board: absorbed contributions by step, pruned below
    /// the last selection so at most ~K+1 entries live per peer.
    boards: Vec<BTreeMap<u64, Vec<Tensor>>>,
    /// Highest absorbed step per replica (`first_step` = none yet).
    high: Vec<u64>,
    /// `skew_hist[d]` = folded contributions whose realized skew was
    /// exactly `d` steps.
    skew_hist: Vec<u64>,
    max_seen: u32,
    stalls: u64,
}

/// Build the handles of one bounded-skew reduce group (index = replica
/// id). `first_step`/`final_step` bound the step tags the group will
/// see: a fresh engine run passes `(0, steps)`, a resumed segment
/// `(start_update, end_update)`.
pub fn group(
    replicas: usize,
    max_skew: u32,
    first_step: u64,
    final_step: u64,
    timeout: Duration,
) -> Vec<AsyncReducer> {
    assert!(replicas >= 1, "dp_async::group needs at least one replica");
    assert!(final_step > first_step, "dp_async::group needs a non-empty step range");
    let mut txs_all = Vec::with_capacity(replicas);
    let mut rxs = Vec::with_capacity(replicas);
    for _ in 0..replicas {
        let (tx, rx) = channel::<Contribution>();
        txs_all.push(tx);
        rxs.push(rx);
    }
    rxs.into_iter()
        .enumerate()
        .map(|(id, rx)| AsyncReducer {
            id,
            replicas,
            max_skew,
            first_step,
            final_step,
            timeout,
            txs: txs_all
                .iter()
                .enumerate()
                .map(|(j, t)| if j == id { None } else { Some(t.clone()) })
                .collect(),
            rx,
            boards: (0..replicas).map(|_| BTreeMap::new()).collect(),
            high: vec![first_step; replicas],
            skew_hist: Vec::new(),
            max_seen: 0,
            stalls: 0,
        })
        .collect()
    // the original senders in `txs_all` drop here, so a receiver only
    // disconnects once every *peer handle* is gone
}

impl AsyncReducer {
    fn absorb(&mut self, c: Contribution) {
        debug_assert!(c.from < self.replicas && c.from != self.id);
        self.high[c.from] = self.high[c.from].max(c.step);
        self.boards[c.from].insert(c.step, c.grads);
    }

    /// Drain every contribution already delivered, without blocking.
    fn drain(&mut self) {
        while let Ok(c) = self.rx.try_recv() {
            self.absorb(c);
        }
    }

    /// Slowest peer still expected to advance: `(id, high)` minimizing
    /// high (ties to the smallest id), excluding retired peers. `None`
    /// when every peer has retired.
    fn slowest_active(&self) -> Option<(usize, u64)> {
        let mut out: Option<(usize, u64)> = None;
        for p in 0..self.replicas {
            if p == self.id || self.high[p] >= self.final_step {
                continue;
            }
            if out.map_or(true, |(_, h)| self.high[p] < h) {
                out = Some((p, self.high[p]));
            }
        }
        out
    }

    fn note_skew(&mut self, skew: u32) {
        let d = skew as usize;
        if self.skew_hist.len() <= d {
            self.skew_hist.resize(d + 1, 0);
        }
        self.skew_hist[d] += 1;
        self.max_seen = self.max_seen.max(skew);
    }

    /// Contribute this replica's step-`step` gradients and return the
    /// bounded-stale group average. Blocks only while the skew bound
    /// requires it. An `Err` means a live peer hung up or stayed
    /// silent past the reduce timeout; the message names the peer.
    pub fn all_reduce(&mut self, step: u64, grads: Vec<Tensor>) -> Result<Vec<Tensor>> {
        debug_assert!(step > self.first_step && step <= self.final_step);
        if self.replicas == 1 {
            self.note_skew(0);
            return Ok(grads);
        }
        // Broadcast before anything else so peers stalled on *us* can
        // make progress. A failed send to a retired peer is normal
        // teardown; to a live peer it is a crash.
        let mut failed = Vec::new();
        for (peer, tx) in self.txs.iter().enumerate() {
            if let Some(tx) = tx {
                let c = Contribution { from: self.id, step, grads: grads.clone() };
                if tx.send(c).is_err() {
                    failed.push(peer);
                }
            }
        }
        self.high[self.id] = step;
        self.boards[self.id].insert(step, grads);
        self.drain();
        for peer in failed {
            if self.high[peer] < self.final_step {
                return Err(anyhow!(
                    "dp_async: replica {peer} hung up during all-reduce \
                     (replica {} at step {step})",
                    self.id
                ));
            }
        }
        // Skew bound: block until no live peer is more than K steps
        // behind this step.
        while let Some((slow, low)) = self.slowest_active() {
            if step <= low + self.max_skew as u64 {
                break;
            }
            self.stalls += 1;
            match self.rx.recv_timeout(self.timeout) {
                Ok(c) => self.absorb(c),
                Err(RecvTimeoutError::Timeout) => {
                    return Err(anyhow!(
                        "dp_async: replica {slow} unresponsive for {:.1}s at \
                         step {low} while replica {} waits at step {step} \
                         (skew bound {}; raise --reduce-timeout-ms if this \
                         was a legitimate stall)",
                        self.timeout.as_secs_f64(),
                        self.id,
                        self.max_skew
                    ));
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(anyhow!(
                        "dp_async: replica {slow} hung up during all-reduce \
                         (replica {} at step {step})",
                        self.id
                    ));
                }
            }
        }
        // Select per replica the newest contribution with step ≤ s;
        // replicas with nothing in range yet (possible only inside the
        // first K steps) are absent from the fold.
        let chosen: Vec<Option<u64>> = (0..self.replicas)
            .map(|r| self.boards[r].range(..=step).next_back().map(|(&s, _)| s))
            .collect();
        let mut sets: Vec<Vec<Tensor>> = Vec::with_capacity(self.replicas);
        for r in 0..self.replicas {
            if let Some(s) = chosen[r] {
                self.note_skew((step - s) as u32);
                sets.push(
                    self.boards[r]
                        .get(&s)
                        .expect("selected step is on the board")
                        .clone(),
                );
            }
        }
        // Prune below the selection; the selected entry stays so a
        // stalled peer's newest view can be re-folded next step.
        for r in 0..self.replicas {
            if let Some(s) = chosen[r] {
                self.boards[r] = self.boards[r].split_off(&s);
            }
        }
        dp::average(&sets)
    }

    /// Realized per-contribution skew histogram (`hist[d]` = folded
    /// contributions at exactly `d` steps of skew).
    pub fn skew_hist(&self) -> &[u64] {
        &self.skew_hist
    }

    /// Largest realized skew so far — never exceeds `max_skew`.
    pub fn max_skew_seen(&self) -> u32 {
        self.max_seen
    }

    /// Blocking waits the skew bound forced on this replica.
    pub fn stalls(&self) -> u64 {
        self.stalls
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: &[f32]) -> Tensor {
        Tensor::new(vec![v.len()], v.to_vec())
    }

    #[test]
    fn dp_async_skew0_equals_sync_average_for_many_r() {
        // property-style: at K=0, every replica's fold at every step is
        // bit-identical to dp::average over that step's gradient sets —
        // the deterministic replica-order fold.
        for r in [1usize, 2, 3, 5, 8] {
            let steps = 4u64;
            let per_step_sets: Vec<Vec<Vec<Tensor>>> = (1..=steps)
                .map(|s| {
                    (0..r)
                        .map(|i| {
                            vec![
                                t(&[i as f32 + 0.5 * s as f32, -(i as f32)]),
                                t(&[0.1 * i as f32, s as f32]),
                            ]
                        })
                        .collect()
                })
                .collect();
            let want: Vec<Vec<Tensor>> = per_step_sets
                .iter()
                .map(|sets| dp::average(sets).unwrap())
                .collect();
            let handles = group(r, 0, 0, steps, Duration::from_secs(10));
            let mut threads = Vec::new();
            for (i, mut h) in handles.into_iter().enumerate() {
                let mine: Vec<Vec<Tensor>> =
                    per_step_sets.iter().map(|sets| sets[i].clone()).collect();
                threads.push(std::thread::spawn(move || {
                    let mut out = Vec::new();
                    for (s, g) in mine.into_iter().enumerate() {
                        out.push(h.all_reduce(s as u64 + 1, g).unwrap());
                    }
                    assert_eq!(h.max_skew_seen(), 0);
                    out
                }));
            }
            for th in threads {
                let got = th.join().unwrap();
                for (gs, ws) in got.iter().zip(&want) {
                    for (a, b) in gs.iter().zip(ws) {
                        assert_eq!(a.data, b.data, "R={r}");
                    }
                }
            }
        }
    }

    #[test]
    fn dp_async_skew_stays_within_bound_under_straggler() {
        let k = 2u32;
        let steps = 8u64;
        let handles = group(3, k, 0, steps, Duration::from_secs(10));
        let mut threads = Vec::new();
        for (i, mut h) in handles.into_iter().enumerate() {
            threads.push(std::thread::spawn(move || {
                for s in 1..=steps {
                    if i == 2 {
                        // replica 2 is the jittery straggler
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    h.all_reduce(s, vec![t(&[i as f32, s as f32])]).unwrap();
                }
                (h.max_skew_seen(), h.skew_hist().to_vec(), h.stalls())
            }));
        }
        for th in threads {
            let (max_seen, hist, _stalls) = th.join().unwrap();
            assert!(max_seen <= k, "max skew {max_seen} exceeds bound {k}");
            assert!(hist.len() <= k as usize + 1, "{hist:?}");
            assert!(hist.iter().sum::<u64>() > 0);
        }
    }

    #[test]
    fn dp_async_retired_peer_is_not_an_error() {
        // replica 1 finishes all its steps and drops its handle while
        // replica 0 is still mid-run: the closed channel must read as
        // retirement, not a crash.
        let steps = 6u64;
        let mut handles = group(2, 2, 0, steps, Duration::from_secs(10));
        let mut h1 = handles.pop().unwrap();
        let mut h0 = handles.pop().unwrap();
        let t1 = std::thread::spawn(move || {
            for s in 1..=steps {
                h1.all_reduce(s, vec![t(&[1.0, s as f32])]).unwrap();
            }
            // handle drops here — retired
        });
        let t0 = std::thread::spawn(move || {
            for s in 1..=steps {
                std::thread::sleep(Duration::from_millis(3));
                h0.all_reduce(s, vec![t(&[0.0, s as f32])]).unwrap();
            }
            h0.max_skew_seen()
        });
        t1.join().unwrap();
        let max_seen = t0.join().unwrap();
        assert!(max_seen <= 2);
    }

    #[test]
    fn dp_async_dead_peer_surfaces_as_error_naming_it() {
        let mut handles = group(2, 0, 0, 4, Duration::from_secs(10));
        let h1 = handles.pop().unwrap();
        let mut h0 = handles.pop().unwrap();
        drop(h1); // replica 1 dies before contributing anything
        let err = h0.all_reduce(1, vec![t(&[1.0])]).unwrap_err().to_string();
        assert!(err.contains("replica 1"), "{err}");
    }

    #[test]
    fn dp_async_silent_peer_times_out_loudly() {
        // replica 1 holds its handle open but never reduces — the shape
        // of a stalled worker. Replica 0 must error within the timeout
        // naming replica 1 instead of blocking forever.
        let mut handles = group(2, 0, 0, 4, Duration::from_millis(80));
        let h1 = handles.pop().unwrap();
        let mut h0 = handles.pop().unwrap();
        let th = std::thread::spawn(move || {
            h0.all_reduce(1, vec![t(&[1.0])]).map(|_| ())
        });
        let err = th.join().unwrap().unwrap_err().to_string();
        assert!(err.contains("replica 1"), "{err}");
        assert!(err.contains("unresponsive"), "{err}");
        drop(h1);
    }

    #[test]
    fn dp_async_partial_fold_in_first_k_steps() {
        // With K=1, replica 0 may fold its first step alone while
        // replica 1 has not arrived: the average is over the replicas
        // present. Sequenced deterministically via a side channel.
        let (go_tx, go_rx) = channel::<()>();
        let mut handles = group(2, 1, 0, 2, Duration::from_secs(10));
        let mut h1 = handles.pop().unwrap();
        let mut h0 = handles.pop().unwrap();
        let t1 = std::thread::spawn(move || {
            go_rx.recv().unwrap(); // wait until replica 0 folded step 1
            for s in 1..=2u64 {
                h1.all_reduce(s, vec![t(&[10.0])]).unwrap();
            }
        });
        let out = h0.all_reduce(1, vec![t(&[2.0])]).unwrap();
        // nothing from replica 1 yet: the fold is replica 0 alone
        assert_eq!(out[0].data, vec![2.0]);
        go_tx.send(()).unwrap();
        let out2 = h0.all_reduce(2, vec![t(&[4.0])]).unwrap();
        // step 2 stalls until replica 1 reaches step >= 1; its newest
        // in-range contribution joins the fold
        assert!(out2[0].data[0] > 2.0, "{:?}", out2[0].data);
        drop(h0);
        t1.join().unwrap();
    }
}
