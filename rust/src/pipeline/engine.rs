//! The real asynchronous pipeline engine: one OS thread per stage,
//! mpsc channels carrying activations, deterministic 1F1B schedule with
//! per-microbatch weight stashing and immediate updates on backward —
//! PipeDream's execution model, end to end, on per-block executables
//! (`embed_fwd` / `block_fwd` / `block_bwd` / `head_fwdbwd`).
//!
//! Each stage thread opens its own [`Runtime`] and thereby owns its own
//! boxed [`crate::runtime::Backend`] (the PJRT client is not `Send`;
//! the native backend is stateless either way), executes only the
//! graphs it needs, and owns its blocks' parameters and optimizer
//! state. Activations cross threads as plain `Vec<f32>`.
//!
//! Schedule: stage k (0-indexed of P) performs `P-1-k` warmup forwards,
//! then strictly alternates backward/forward. In steady state the
//! forward of microbatch m therefore uses stage-k weights of version
//! `m-(P-1-k)` — exactly the simulator's staleness model, which the
//! `engine_matches_sim` integration test pins down.
//!
//! Differences from the simulator (documented, not bugs): gradient-norm
//! clipping is per-stage (a real distributed pipeline has no global
//! norm without an extra collective), so equivalence tests disable
//! clipping.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::{Method, TrainCfg};
use crate::data::{BatchIter, Corpus};
use crate::metrics::RunResult;
use crate::model::{init_params, StagePartition};
use crate::optim::ElementAdam;
use crate::runtime::{
    tensor_to_value, tokens_to_value, value_scalar_f32, value_to_tensor, Runtime,
    Value,
};
use crate::tensor::Tensor;

struct FwdMsg {
    mb: u64,
    x: Vec<f32>,
}

struct BwdMsg {
    mb: u64,
    dx: Vec<f32>,
}

/// Loss + perf sample emitted by the last stage / each stage.
pub struct StageReport {
    pub stage: usize,
    pub losses: Vec<f32>,
    pub compute_s: f64,
    pub idle_s: f64,
    pub updates: u64,
}

struct Worker {
    k: usize,
    stages: usize,
    rt: Runtime,
    /// manifest indices of this stage's params.
    param_idx: Vec<usize>,
    blocks: Vec<usize>,
    params: Vec<Tensor>,
    opt: ElementAdam,
    cfg: TrainCfg,
    delays: Vec<u32>,
    /// (mb, weight snapshot, per-block input activations)
    stash: std::collections::VecDeque<(u64, Vec<Tensor>, Vec<Tensor>)>,
    pending_tokens: std::collections::HashMap<u64, Vec<i32>>,
    pending_targets: std::collections::HashMap<u64, Vec<i32>>,
    use_stash: bool,
    updates: u64,
    compute_s: f64,
    idle_s: f64,
    losses: Vec<f32>,
}

impl Worker {
    fn first(&self) -> bool {
        self.k == 0
    }

    fn last(&self) -> bool {
        self.k == self.stages - 1
    }

    fn local_index(&self, name: &str) -> usize {
        self.param_idx
            .iter()
            .position(|&pi| self.rt.manifest.params[pi].name == name)
            .unwrap_or_else(|| panic!("stage {} missing {name}", self.k))
    }

    fn block_params(&self, b: usize, snapshot: &[Tensor]) -> Vec<Tensor> {
        let prefix = format!("b{b}.");
        self.param_idx
            .iter()
            .enumerate()
            .filter(|(_, &pi)| self.rt.manifest.params[pi].name.starts_with(&prefix))
            .map(|(local, _)| snapshot[local].clone())
            .collect()
    }

    /// Forward one microbatch through this stage; returns the output
    /// activation (to send or, on the last stage, to feed the head).
    fn forward(
        &mut self,
        mb: u64,
        data: &mut BatchIter,
        rx_fwd: Option<&Receiver<FwdMsg>>,
    ) -> Result<Tensor> {
        let mcfg = self.rt.cfg().clone();
        let (b, s, d) = (mcfg.batch, mcfg.seq, mcfg.d_model);
        let x0: Vec<f32> = if self.first() {
            let (toks, tgts) = data.next_batch();
            if self.last() {
                self.pending_targets.insert(mb, tgts);
            }
            let t0 = Instant::now();
            let te = &self.params[self.local_index("tok_emb")];
            let pe = &self.params[self.local_index("pos_emb")];
            let outs = self.rt.exec(
                "embed_fwd",
                &[
                    tensor_to_value(te)?,
                    tensor_to_value(pe)?,
                    tokens_to_value(&toks, b, s)?,
                ],
            )?;
            self.compute_s += t0.elapsed().as_secs_f64();
            self.pending_tokens.insert(mb, toks);
            outs[0].to_f32()?
        } else {
            if self.last() {
                // last stage needs this microbatch's targets; re-derive
                // the deterministic batch stream locally.
                let (_toks, tgts) = data.next_batch();
                self.pending_targets.insert(mb, tgts);
            }
            let t0 = Instant::now();
            let msg =
                rx_fwd.unwrap().recv().map_err(|_| anyhow!("fwd channel closed"))?;
            self.idle_s += t0.elapsed().as_secs_f64();
            assert_eq!(msg.mb, mb, "stage {}: out-of-order microbatch", self.k);
            msg.x
        };

        let t0 = Instant::now();
        let snapshot = self.params.clone();
        let mut x = Tensor::new(vec![b, s, d], x0);
        let mut block_inputs = Vec::with_capacity(self.blocks.len());
        for &blk in &self.blocks.clone() {
            block_inputs.push(x.clone());
            let bp = self.block_params(blk, &snapshot);
            let mut ins: Vec<Value> =
                bp.iter().map(tensor_to_value).collect::<Result<_>>()?;
            ins.push(tensor_to_value(&x)?);
            let outs = self.rt.exec("block_fwd", &ins)?;
            x = value_to_tensor(&outs[0], &[b, s, d])?;
        }
        self.compute_s += t0.elapsed().as_secs_f64();
        let stashed = if self.use_stash { snapshot } else { Vec::new() };
        self.stash.push_back((mb, stashed, block_inputs));
        Ok(x)
    }

    /// Backward for microbatch mb. On the last stage, `x_out` is the
    /// forward output and the head provides loss + dx; otherwise dx
    /// comes from `rx_bwd`.
    fn backward(
        &mut self,
        mb: u64,
        x_out: Option<Tensor>,
        rx_bwd: Option<&Receiver<BwdMsg>>,
        tx_bwd: Option<&Sender<BwdMsg>>,
    ) -> Result<()> {
        let mcfg = self.rt.cfg().clone();
        let (b, s, d) = (mcfg.batch, mcfg.seq, mcfg.d_model);
        let pos = self
            .stash
            .iter()
            .position(|(m, _, _)| *m == mb)
            .ok_or_else(|| anyhow!("stage {}: no stash for mb {mb}", self.k))?;
        let (_, snapshot, block_inputs) = self.stash.remove(pos).unwrap();
        let weights = if self.use_stash { snapshot } else { self.params.clone() };

        let mut grads: Vec<Tensor> =
            self.params.iter().map(|p| Tensor::zeros(&p.shape)).collect();

        // ---- obtain dx at the stage output ----
        let mut dx = if self.last() {
            let tgts = self.pending_targets.remove(&mb).expect("targets");
            let x = x_out.expect("last stage forwards its own x");
            let t0 = Instant::now();
            let gf = if self.use_stash {
                weights[self.local_index("gf")].clone()
            } else {
                self.params[self.local_index("gf")].clone()
            };
            let head = if self.use_stash {
                weights[self.local_index("head")].clone()
            } else {
                self.params[self.local_index("head")].clone()
            };
            let outs = self.rt.exec(
                "head_fwdbwd",
                &[
                    tensor_to_value(&gf)?,
                    tensor_to_value(&head)?,
                    tensor_to_value(&x)?,
                    tokens_to_value(&tgts, b, s)?,
                ],
            )?;
            self.compute_s += t0.elapsed().as_secs_f64();
            let loss = value_scalar_f32(&outs[0])?;
            self.losses.push(loss);
            let i_gf = self.local_index("gf");
            let i_head = self.local_index("head");
            let gf_shape = self.params[i_gf].shape.clone();
            let head_shape = self.params[i_head].shape.clone();
            grads[i_gf] = value_to_tensor(&outs[2], &gf_shape)?;
            grads[i_head] = value_to_tensor(&outs[3], &head_shape)?;
            value_to_tensor(&outs[1], &[b, s, d])?
        } else {
            let t0 = Instant::now();
            let msg =
                rx_bwd.unwrap().recv().map_err(|_| anyhow!("bwd channel closed"))?;
            self.idle_s += t0.elapsed().as_secs_f64();
            assert_eq!(msg.mb, mb, "stage {}: out-of-order backward", self.k);
            Tensor::new(vec![b, s, d], msg.dx)
        };

        // ---- backward through this stage's blocks ----
        let t0 = Instant::now();
        for (bi, &blk) in self.blocks.clone().iter().enumerate().rev() {
            let bp = self.block_params(blk, &weights);
            let mut ins: Vec<Value> =
                bp.iter().map(tensor_to_value).collect::<Result<_>>()?;
            ins.push(tensor_to_value(&block_inputs[bi])?);
            ins.push(tensor_to_value(&dx)?);
            let outs = self.rt.exec("block_bwd", &ins)?;
            dx = value_to_tensor(&outs[0], &[b, s, d])?;
            let prefix = format!("b{blk}.");
            let mut gi = 1;
            for (local, &pi) in self.param_idx.clone().iter().enumerate() {
                if self.rt.manifest.params[pi].name.starts_with(&prefix) {
                    let shape = self.params[local].shape.clone();
                    grads[local] = value_to_tensor(&outs[gi], &shape)?;
                    gi += 1;
                }
            }
        }
        self.compute_s += t0.elapsed().as_secs_f64();

        if let Some(tx) = tx_bwd {
            tx.send(BwdMsg { mb, dx: dx.data.clone() })
                .map_err(|_| anyhow!("bwd send"))?;
        }

        // ---- embedding backward on stage 0 ----
        if self.first() {
            let toks = self.pending_tokens.remove(&mb).expect("tokens");
            let t0e = Instant::now();
            let outs = self.rt.exec(
                "embed_bwd",
                &[tokens_to_value(&toks, b, s)?, tensor_to_value(&dx)?],
            )?;
            self.compute_s += t0e.elapsed().as_secs_f64();
            let i_te = self.local_index("tok_emb");
            let i_pe = self.local_index("pos_emb");
            let te_shape = self.params[i_te].shape.clone();
            let pe_shape = self.params[i_pe].shape.clone();
            grads[i_te] = value_to_tensor(&outs[0], &te_shape)?;
            grads[i_pe] = value_to_tensor(&outs[1], &pe_shape)?;
        }

        // ---- per-stage clip + immediate update (async semantics) ----
        crate::optim::clip_global_norm(&mut grads, self.cfg.grad_clip);
        self.updates += 1;
        let t = self.updates;
        let lr = self.cfg.lr_at(t as u32);
        let b1 = self.cfg.effective_beta1();
        let nesterov = matches!(self.cfg.method, Method::Nesterov);
        for local in 0..self.params.len() {
            let pi = self.param_idx[local];
            let scale = match self.cfg.method {
                Method::PipeDreamLr => {
                    crate::config::pipedream_lr_scale(self.delays[pi])
                }
                _ => 1.0,
            };
            self.opt.update(
                local,
                &mut self.params[local],
                &grads[local],
                lr * scale,
                b1,
                self.cfg.beta2,
                self.cfg.eps,
                self.cfg.weight_decay,
                t,
                nesterov,
            );
        }
        Ok(())
    }

    fn report(self) -> StageReport {
        StageReport {
            stage: self.k,
            losses: self.losses,
            compute_s: self.compute_s,
            idle_s: self.idle_s,
            updates: self.updates,
        }
    }
}

fn run_stage(
    mut w: Worker,
    mut data: BatchIter,
    rx_fwd: Option<Receiver<FwdMsg>>,
    tx_fwd: Option<Sender<FwdMsg>>,
    rx_bwd: Option<Receiver<BwdMsg>>,
    tx_bwd: Option<Sender<BwdMsg>>,
    n_micro: u64,
) -> Result<StageReport> {
    let warmup = (w.stages - 1 - w.k) as u64;
    if w.last() {
        // fused fwd+bwd per microbatch (no warmup, delay 0)
        for mb in 0..n_micro {
            let x = w.forward(mb, &mut data, rx_fwd.as_ref())?;
            w.backward(mb, Some(x), None, tx_bwd.as_ref())?;
        }
        return Ok(w.report());
    }
    let mut next_fwd = 0u64;
    while next_fwd < warmup.min(n_micro) {
        let x = w.forward(next_fwd, &mut data, rx_fwd.as_ref())?;
        tx_fwd
            .as_ref()
            .unwrap()
            .send(FwdMsg { mb: next_fwd, x: x.data })
            .map_err(|_| anyhow!("fwd send"))?;
        next_fwd += 1;
    }
    for mb_b in 0..n_micro {
        if next_fwd < n_micro {
            let x = w.forward(next_fwd, &mut data, rx_fwd.as_ref())?;
            tx_fwd
                .as_ref()
                .unwrap()
                .send(FwdMsg { mb: next_fwd, x: x.data })
                .map_err(|_| anyhow!("fwd send"))?;
            next_fwd += 1;
        }
        w.backward(mb_b, None, rx_bwd.as_ref(), tx_bwd.as_ref())?;
    }
    Ok(w.report())
}

/// Train with the real threaded pipeline. `cfg.steps` = microbatches.
pub fn train_engine(artifacts_dir: PathBuf, cfg: &TrainCfg) -> Result<RunResult> {
    let man0 = crate::runtime::Manifest::resolve(&artifacts_dir)?;
    if man0.cfg.moe.is_some() {
        anyhow::bail!("engine supports dense configs only");
    }
    let part = StagePartition::new(&man0, cfg.stages);
    let init = init_params(&man0, cfg.seed);
    let p = cfg.stages;
    let n_micro = cfg.steps as u64;
    let mcfg = man0.cfg.clone();

    // channels between consecutive stages
    let mut fwd_txs = Vec::new();
    let mut fwd_rxs = vec![None];
    let mut bwd_txs = vec![None];
    let mut bwd_rxs = Vec::new();
    for _ in 0..p.saturating_sub(1) {
        let (ftx, frx) = channel::<FwdMsg>();
        fwd_txs.push(Some(ftx));
        fwd_rxs.push(Some(frx));
        let (btx, brx) = channel::<BwdMsg>();
        bwd_txs.push(Some(btx));
        bwd_rxs.push(Some(brx));
    }
    fwd_txs.push(None);
    bwd_rxs.push(None);

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for k in (0..p).rev() {
        let dir = artifacts_dir.clone();
        let cfg_k = cfg.clone();
        let part_k = part.clone();
        let init_k: Vec<Tensor> = (0..man0.params.len())
            .filter(|&i| part.stage_of[i] == k)
            .map(|i| init[i].clone())
            .collect();
        let rx_fwd = fwd_rxs[k].take();
        let tx_fwd = fwd_txs[k].take();
        let rx_bwd = bwd_rxs[k].take();
        let tx_bwd = bwd_txs[k].take();
        let use_stash = cfg.stash != crate::config::StashMode::NoStash;
        let corpus = Corpus::new(mcfg.vocab, cfg.seed ^ 0xDA7A);
        let data = BatchIter::new(corpus, mcfg.batch, mcfg.seq, 1);
        handles.push((
            k,
            std::thread::spawn(move || -> Result<StageReport> {
                let rt = Runtime::open(&dir)?;
                let param_idx: Vec<usize> = (0..rt.manifest.params.len())
                    .filter(|&i| part_k.stage_of[i] == k)
                    .collect();
                let shapes: Vec<Vec<usize>> =
                    init_k.iter().map(|t| t.shape.clone()).collect();
                let worker = Worker {
                    k,
                    stages: part_k.stages,
                    blocks: part_k.blocks_of_stage[k].clone(),
                    param_idx,
                    params: init_k,
                    opt: ElementAdam::new(&shapes),
                    cfg: cfg_k,
                    delays: part_k.delay_of.clone(),
                    stash: Default::default(),
                    pending_tokens: Default::default(),
                    pending_targets: Default::default(),
                    use_stash,
                    updates: 0,
                    compute_s: 0.0,
                    idle_s: 0.0,
                    losses: Vec::new(),
                    rt,
                };
                run_stage(worker, data, rx_fwd, tx_fwd, rx_bwd, tx_bwd, n_micro)
            }),
        ));
    }

    let mut result = RunResult::new(&cfg.method.name(), p);
    result.param_count = man0.total_params();
    let mut total_compute = 0.0;
    let mut total_idle = 0.0;
    for (k, h) in handles {
        let rep = h.join().map_err(|_| anyhow!("stage {k} panicked"))??;
        total_compute += rep.compute_s;
        total_idle += rep.idle_s;
        if rep.stage == p - 1 {
            result.losses = rep.losses;
        }
    }
    result.wall_secs = t0.elapsed().as_secs_f64();
    result.bubble_frac = if total_compute + total_idle > 0.0 {
        total_idle / (total_compute + total_idle)
    } else {
        0.0
    };
    result.tokens_per_sec =
        (n_micro as f64 * mcfg.batch as f64 * mcfg.seq as f64) / result.wall_secs;
    Ok(result)
}

/// Analytic schedule model (Fig. 1): bubble fraction of synchronous
/// GPipe vs asynchronous PipeDream for P stages and M in-flight
/// microbatches per step, with unit per-stage fwd+bwd cost.
pub fn sync_bubble_fraction(p: usize, m: usize) -> f64 {
    (p as f64 - 1.0) / (m as f64 + p as f64 - 1.0)
}

pub fn async_bubble_fraction_steady() -> f64 {
    0.0 // PipeDream's steady state keeps every stage busy
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_bubbles_shrink_with_microbatches() {
        assert!(sync_bubble_fraction(4, 1) > sync_bubble_fraction(4, 16));
        assert!((sync_bubble_fraction(4, 4) - 3.0 / 7.0).abs() < 1e-12);
        assert!(sync_bubble_fraction(1, 8) == 0.0);
        assert_eq!(async_bubble_fraction_steady(), 0.0);
    }

    #[test]
    fn sync_bubbles_grow_with_depth() {
        assert!(sync_bubble_fraction(32, 8) > sync_bubble_fraction(4, 8));
    }
}
