//! The real asynchronous pipeline engine: one OS thread per (replica,
//! worker), mpsc channels carrying activations, executing the action
//! stream of a pluggable [`Schedule`](super::schedule::Schedule) —
//! GPipe, 1F1B (PipeDream, the original hard-coded schedule),
//! interleaved 1F1B with V virtual chunk-stages per worker, or the
//! bidirectional AMDP schedule — on per-block executables
//! (`embed_fwd` / `block_fwd` / `block_bwd` / `head_fwdbwd`), for both
//! dense and MoE block flavours.
//!
//! Each worker hosts one or more **chunks** (parameter partitions; one
//! per worker for the linear schedules, V for interleaved, two — one
//! per direction — for AMDP). Every chunk opens its own [`Runtime`]
//! restricted to a chunk-local manifest ([`crate::runtime::Manifest::
//! restrict`]) and owns its method's *real* optimizer from
//! [`optim::build`], its own 1F1B weight/activation stash, gradient
//! accumulator, batch feed and all-reduce handle.
//!
//! The worker thread executes exactly the per-worker action stream the
//! schedule emits (`Fwd`/`Bwd`/`Update` per chunk); the virtual-clock
//! executor ([`super::schedule::simulate`]) validates that stream
//! before any thread spawns, which both rejects malformed schedules
//! and guarantees the blocking execution below is deadlock-free (the
//! stream is feasible in virtual time, and actions are totally ordered
//! by their virtual slots). Messages are tagged with their destination
//! chunk; out-of-order arrivals are buffered, so the single inbox per
//! worker serves any schedule topology (including AMDP's two
//! counter-flowing streams and interleaved self-sends at P=1).
//!
//! For the 1F1B schedule this reduces to the original engine bit for
//! bit: stage k performs `P-1-k` warmup forwards then alternates
//! fwd/bwd with an update per microbatch, so the forward of microbatch
//! m uses stage-k weights of version `m-(P-1-k)` — exactly the
//! simulator's staleness model, pinned by the
//! `engine_matches_simulator_trajectory` integration tests.
//!
//! Gradient accumulation (`micro_per_update > 1`, GPipe/interleaved):
//! backwards accumulate into the chunk's gradient in microbatch order
//! and the update scales by `1/M` — the same fold order as
//! [`dp::average`], so engine and simulator trajectories stay
//! bit-comparable. The single-microbatch path moves the gradient with
//! zero float ops, preserving the original 1F1B arithmetic exactly.
//!
//! Divergence: the chunk hosting the loss head checks every training
//! loss; a non-finite loss sets `diverged`, skips the update and stops
//! the run — the worker broadcasts a `Stop` to its replica's peers
//! (channel teardown alone cannot wind down the all-to-all topology)
//! and the dropped all-reduce handles wind down the other replicas,
//! mirroring `train_sim`. Validation: when `cfg.eval_every > 0`,
//! replica 0's stream-0 source chunk emits an eval-tagged forward
//! after every `eval_every`-th update; it rides the stream-0 chunk
//! sequence at current weights and the head chunk scores it against
//! the shared validation stream. Workers process eval messages only at
//! forward-wait points (buffering them during backward waits), which
//! keeps the legacy engine's deterministic evaluation timing for the
//! single-stream schedules; AMDP's merged streams make eval *values*
//! timing-dependent, so equivalence tests run AMDP with
//! `eval_every = 0`.
//!
//! Data parallelism (`cfg.replicas = R`): R full pipelines on disjoint
//! shards; the copies of each *part* share a channel all-reduce group
//! ([`super::dp`]) averaging gradients right before every optimizer
//! step. With `--dp-async` the group is the bounded-skew mesh
//! ([`super::dp_async`]) instead: replicas fold whatever peer gradients
//! arrived within `--max-skew` optimizer steps and block only at the
//! bound, so a straggler no longer stalls the group at every reduce;
//! `--max-skew 0` reduces bit-exactly to the synchronous tree. AMDP's two copies of part s join the same group (fold order:
//! down before up within each replica — the simulator's draw order),
//! which doubles as the cross-copy synchronization of the
//! bidirectional schedule.
//!
//! Differences from the simulator (documented, not bugs): gradient
//! clipping is per-chunk (no global norm without an extra collective),
//! so equivalence tests disable clipping; AMDP at R > 1 folds all 2R
//! copies flat while the simulator nests mean-of-means, so AMDP
//! equivalence tests run at R = 1. `StashMode::Predict` is
//! simulator-only and rejected loudly for every `--schedule`.

use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::{dp, dp_async};
use super::schedule::{self, Action, ChunkSpec, Schedule};
use crate::config::{Method, ScheduleKind, StashMode, TrainCfg};
use crate::data::{replica_stream, BatchIter, Corpus, TRAIN_STREAM};
use crate::metrics::{RunResult, StageCounter, StageSpan};
use crate::model::{init_params, StagePartition};
use crate::trace::{self, SpanKind};
use crate::optim::{self, OptState, Optimizer, StepCtx};
use crate::runtime::{
    tensor_to_value, tokens_to_value, value_scalar_f32, value_to_tensor, Runtime,
    Value,
};
use crate::tensor::Tensor;

/// Inter-worker message, tagged with its destination chunk.
enum Msg {
    /// Training activation entering `chunk` for microbatch `mb`.
    Fwd { chunk: usize, mb: u64, x: Vec<f32> },
    /// Output-side gradient entering `chunk` for microbatch `mb`.
    Bwd { chunk: usize, mb: u64, dx: Vec<f32> },
    /// Validation activation entering `chunk`, recorded under `label`
    /// (the sourcing update index) at the head chunk.
    Eval { chunk: usize, label: u32, x: Vec<f32> },
    /// Early-stop broadcast (divergence or peer teardown).
    Stop,
}

/// Per-chunk slice of a worker's report.
#[derive(Clone, Debug, serde::Serialize)]
pub struct ChunkReport {
    pub chunk: usize,
    pub part: usize,
    pub stream: usize,
    /// Training losses recorded at the head chunk, per microbatch.
    pub losses: Vec<(u64, f32)>,
    pub val_losses: Vec<(u32, f32)>,
    pub updates: u64,
    pub diverged: bool,
    pub dispatches: u64,
    pub state_elems: usize,
    /// Realized-delay instrumentation: microbatches observed and the
    /// max realized gradient delay (in updates) across them.
    pub realized_mbs: u64,
    pub realized_max_delay: u32,
    pub is_head: bool,
    /// Staleness histogram: `delay_hist[d]` = microbatches whose
    /// gradient was applied exactly `d` optimizer updates after their
    /// forward.
    pub delay_hist: Vec<u64>,
    /// Per-microbatch staleness samples `(global update index, delay)`,
    /// in drain order — the step-granularity series behind the
    /// `--metrics` JSONL staleness columns.
    pub delay_samples: Vec<(u64, u32)>,
    /// Realized DP-skew histogram from the chunk's reduce handle
    /// (`hist[d]` = folded peer contributions exactly `d` optimizer
    /// steps stale); empty under synchronous DP.
    pub dp_skew_hist: Vec<u64>,
    /// Largest realized DP skew — never exceeds `--max-skew`.
    pub dp_max_skew: u32,
    /// Blocking waits the skew bound forced on this chunk's reduces.
    pub dp_stalls: u64,
}

/// One worker thread's report: per-chunk counters + wall-clock split.
#[derive(Clone, Debug, serde::Serialize)]
pub struct WorkerReport {
    pub replica: usize,
    pub worker: usize,
    pub compute_s: f64,
    pub idle_s: f64,
    pub chunks: Vec<ChunkReport>,
    /// This worker thread's span timeline (all threads share the run
    /// epoch, so timelines merge into one Chrome trace).
    pub spans: Vec<trace::Span>,
    /// `(global update index, pending fwd+bwd buffer depth)` sampled
    /// at every Update action.
    pub queue_samples: Vec<(u64, u32)>,
}

/// Drained weights and per-part optimizer states exported at the end
/// of a completed engine segment. Under synchronous DP all replicas
/// are in parameter lockstep, so replica 0's copy (`params`/`opts`)
/// suffices. Under `--dp-async` at `max_skew > 0` the replicas drain
/// with divergent weights (each folded different stale peer views);
/// `replica_states` then carries every replica's copy so a resumed
/// segment restores the in-flight skew state drain-consistently.
pub struct EngineCheckpoint {
    /// Global optimizer updates completed when the export was taken.
    pub step: u64,
    /// Full-manifest-order parameters, merged from the per-part chunks
    /// (replica 0's copy — the canonical state).
    pub params: Vec<Tensor>,
    /// One optimizer state per model part (replica 0's copy).
    pub opts: Vec<OptState>,
    /// Per-replica `(replica, params, per-part opts)` under async-DP
    /// skew; empty when the replicas are in lockstep (sync DP,
    /// `max_skew = 0`, or a roster change collapsed the skew state).
    pub replica_states: Vec<(usize, Vec<Tensor>, Vec<OptState>)>,
}

/// One segment of a checkpointed/elastic engine run, driven by
/// [`crate::checkpoint::run_engine_elastic`]. The segment performs the
/// global optimizer updates `start_update+1 ..= end_update` with feeds,
/// learning rate, eval cadence and update counters all offset to the
/// global position, so consecutive segments chain into one run.
#[derive(Clone, Debug, Default)]
pub struct SegmentOpts {
    /// Optimizer updates already completed before this segment.
    pub start_update: u64,
    /// Global update index the segment runs to; 0 means `cfg.steps`.
    pub end_update: u64,
    /// Export an [`EngineCheckpoint`] when the segment completes.
    pub export_state: bool,
    /// Planned faults `(replica, worker, at_update)`: the worker dies
    /// immediately after completing that global update.
    pub kills: Vec<(usize, usize, u64)>,
    /// Timing perturbations `(replica, worker, at_update, millis)`:
    /// the worker sleeps after completing that global update.
    pub delays: Vec<(usize, usize, u64, u64)>,
}

/// A chunk's all-reduce handle: the synchronous tree barrier or the
/// bounded-skew asynchronous mesh (`--dp-async`).
enum DpReduce {
    Sync(dp::Reducer),
    Async(dp_async::AsyncReducer),
}

impl DpReduce {
    /// Reduce this chunk's step-`step` gradients. The synchronous path
    /// ignores the step tag (it is in step lockstep by construction);
    /// the asynchronous path folds the peer contributions within the
    /// skew bound of `step`.
    fn all_reduce(&mut self, step: u64, grads: Vec<Tensor>) -> Result<Vec<Tensor>> {
        match self {
            DpReduce::Sync(r) => r.all_reduce(grads),
            DpReduce::Async(r) => r.all_reduce(step, grads),
        }
    }

    fn skew_hist(&self) -> Vec<u64> {
        match self {
            DpReduce::Sync(_) => Vec::new(),
            DpReduce::Async(r) => r.skew_hist().to_vec(),
        }
    }

    fn max_skew_seen(&self) -> u32 {
        match self {
            DpReduce::Sync(_) => 0,
            DpReduce::Async(r) => r.max_skew_seen(),
        }
    }

    fn stalls(&self) -> u64 {
        match self {
            DpReduce::Sync(_) => 0,
            DpReduce::Async(r) => r.stalls(),
        }
    }
}

/// Split the kernel thread budget across the P·R stage workers:
/// everyone gets `total / workers`, the first `total % workers` get
/// one extra, and nobody drops below 1 — so leftover cores are no
/// longer stranded by floor division (6 threads at P=4 is
/// `[2, 2, 1, 1]`, not `[1, 1, 1, 1]`). Results stay bit-identical at
/// any budget; only wall-clock changes.
pub fn split_thread_budget(total: usize, workers: usize) -> Vec<usize> {
    let base = total / workers;
    let extra = total % workers;
    (0..workers)
        .map(|i| (base + usize::from(i < extra)).max(1))
        .collect()
}

/// Rebuild a metrics [`Hist`](crate::metrics::Hist) from raw bucket
/// counts. Exact for staleness data: the observed values are the
/// bucket indices themselves, so mean/mode/max all round-trip.
fn hist_of_counts(counts: &[u64]) -> crate::metrics::Hist {
    crate::metrics::Hist {
        counts: counts.to_vec(),
        overflow: 0,
        n: counts.iter().sum(),
        sum: counts
            .iter()
            .enumerate()
            .map(|(d, &c)| d as f64 * c as f64)
            .sum(),
        max: counts.iter().rposition(|&c| c > 0).unwrap_or(0) as f64,
    }
}

/// Everything one chunk owns: restricted runtime, parameters, real
/// optimizer, stash, gradient accumulator, data feed, all-reduce
/// handle and instrumentation counters.
struct ChunkState {
    spec: ChunkSpec,
    rt: Runtime,
    /// Chunk-local partition for StepCtx; `delay_of` overridden to the
    /// chunk's declared delay (identical to the legacy `P-1-k` values
    /// for 1F1B).
    part: StagePartition,
    blocks: Vec<usize>,
    params: Vec<Tensor>,
    opt: Box<dyn Optimizer>,
    dp: DpReduce,
    cfg: TrainCfg,
    /// Deterministic per-chunk batch feed; advanced to each global
    /// microbatch id (skipping the other stream's draws under AMDP).
    feed: BatchIter,
    feed_next: u64,
    /// (mb, weight snapshot, per-block input activations)
    stash: VecDeque<(u64, Vec<Tensor>, Vec<Tensor>)>,
    /// Head-chunk forward outputs awaiting their backward.
    head_x: HashMap<u64, Tensor>,
    pending_tokens: HashMap<u64, Vec<i32>>,
    pending_targets: HashMap<u64, Vec<i32>>,
    /// Gradient accumulator: first backward moves its gradient in
    /// (zero float ops at micro_per_update = 1), later backwards add
    /// elementwise in microbatch order.
    acc: Option<Vec<Tensor>>,
    acc_n: usize,
    /// Stale weight reference for DelayComp (last drained microbatch's
    /// stashed snapshot — the view its gradient was computed at).
    last_snapshot: Vec<Tensor>,
    /// Backward runs at the stashed snapshot (PipeDream stashing).
    use_stash: bool,
    /// Snapshot weights at forward even in no-stash mode (DelayComp).
    stash_weights: bool,
    updates: u64,
    compute_s: f64,
    losses: Vec<(u64, f32)>,
    val_losses: Vec<(u32, f32)>,
    val_iter: Option<BatchIter>,
    evals_handled: u64,
    evals_expected: u64,
    /// Update counter at each in-flight microbatch's forward.
    u_at_fwd: HashMap<u64, u64>,
    /// Microbatches backwarded since the last update.
    pending_mbs: Vec<u64>,
    realized_mbs: u64,
    realized_max: u32,
    delay_hist: Vec<u64>,
    delay_samples: Vec<(u64, u32)>,
    diverged: bool,
}

impl ChunkState {
    fn local_index(&self, name: &str) -> usize {
        self.rt
            .manifest
            .param_index(name)
            .unwrap_or_else(|| panic!("chunk {} missing {name}", self.spec.id))
    }

    fn block_params(&self, b: usize, snapshot: &[Tensor]) -> Vec<Tensor> {
        let prefix = format!("b{b}.");
        self.rt
            .manifest
            .params
            .iter()
            .enumerate()
            .filter(|(_, p)| p.name.starts_with(&prefix))
            .map(|(local, _)| snapshot[local].clone())
            .collect()
    }

    /// Advance this chunk's feed to global microbatch `mb` and draw
    /// its batch (intermediate draws belong to other chunks' streams
    /// and are discarded — every chunk derives the same deterministic
    /// mb → batch mapping from its own iterator).
    fn batch_for(&mut self, mb: u64) -> (Vec<i32>, Vec<i32>) {
        debug_assert!(mb >= self.feed_next, "chunk feed must advance monotonically");
        while self.feed_next < mb {
            self.feed.next_batch();
            self.feed_next += 1;
        }
        self.feed_next = mb + 1;
        self.feed.next_batch()
    }

    /// Embed a token batch (source chunks only).
    fn embed_fwd(&mut self, toks: &[i32]) -> Result<Vec<f32>> {
        let mcfg = self.rt.cfg().clone();
        let (b, s) = (mcfg.batch, mcfg.seq);
        let t0 = Instant::now();
        let te = &self.params[self.local_index("tok_emb")];
        let pe = &self.params[self.local_index("pos_emb")];
        let outs = self.rt.exec(
            "embed_fwd",
            &[
                tensor_to_value(te)?,
                tensor_to_value(pe)?,
                tokens_to_value(toks, b, s)?,
            ],
        )?;
        self.compute_s += t0.elapsed().as_secs_f64();
        outs[0].to_f32()
    }

    /// Training forward through this chunk's blocks: snapshot weights,
    /// record block inputs in the stash, note the update counter for
    /// realized-delay instrumentation.
    fn forward_blocks(&mut self, mb: u64, x0: Vec<f32>) -> Result<Tensor> {
        let mcfg = self.rt.cfg().clone();
        let (b, s, d) = (mcfg.batch, mcfg.seq, mcfg.d_model);
        let t0 = Instant::now();
        let snapshot = self.params.clone();
        let mut x = Tensor::new(vec![b, s, d], x0);
        let mut block_inputs = Vec::with_capacity(self.blocks.len());
        for &blk in &self.blocks.clone() {
            block_inputs.push(x.clone());
            let bp = self.block_params(blk, &snapshot);
            let mut ins: Vec<Value> =
                bp.iter().map(tensor_to_value).collect::<Result<_>>()?;
            ins.push(tensor_to_value(&x)?);
            let outs = self.rt.exec("block_fwd", &ins)?;
            x = value_to_tensor(&outs[0], &[b, s, d])?;
        }
        self.compute_s += t0.elapsed().as_secs_f64();
        let stashed = if self.stash_weights { snapshot } else { Vec::new() };
        self.stash.push_back((mb, stashed, block_inputs));
        self.u_at_fwd.insert(mb, self.updates);
        Ok(x)
    }

    /// Validation forward through this chunk's blocks at *current*
    /// weights (no stash, no cache).
    fn eval_blocks(&mut self, x0: Vec<f32>) -> Result<Tensor> {
        let mcfg = self.rt.cfg().clone();
        let (b, s, d) = (mcfg.batch, mcfg.seq, mcfg.d_model);
        let t0 = Instant::now();
        let mut x = Tensor::new(vec![b, s, d], x0);
        for &blk in &self.blocks.clone() {
            let bp = self.block_params(blk, &self.params);
            let mut ins: Vec<Value> =
                bp.iter().map(tensor_to_value).collect::<Result<_>>()?;
            ins.push(tensor_to_value(&x)?);
            let outs = self.rt.exec("block_fwd", &ins)?;
            x = value_to_tensor(&outs[0], &[b, s, d])?;
        }
        self.compute_s += t0.elapsed().as_secs_f64();
        Ok(x)
    }

    /// Score a validation activation on the loss-only head executable
    /// and record it under the sourcing update's `label`. Falls back
    /// to `head_fwdbwd`'s loss output on manifests that predate
    /// `head_loss`.
    fn record_val(&mut self, label: u32, x: &Tensor, vg: &[i32]) -> Result<()> {
        let mcfg = self.rt.cfg().clone();
        let (b, s) = (mcfg.batch, mcfg.seq);
        let t0 = Instant::now();
        let gf = &self.params[self.local_index("gf")];
        let head = &self.params[self.local_index("head")];
        let ins = [
            tensor_to_value(gf)?,
            tensor_to_value(head)?,
            tensor_to_value(x)?,
            tokens_to_value(vg, b, s)?,
        ];
        let exec_name = if self.rt.has_executable("head_loss") {
            "head_loss"
        } else {
            "head_fwdbwd"
        };
        let outs = self.rt.exec(exec_name, &ins)?;
        self.compute_s += t0.elapsed().as_secs_f64();
        self.val_losses.push((label, value_scalar_f32(&outs[0])?));
        Ok(())
    }

    /// Backward for microbatch `mb` through this chunk. `dx_in` is the
    /// downstream gradient; `None` on the head chunk, which runs
    /// `head_fwdbwd` on its stored forward output (recording the loss,
    /// or detecting divergence). Returns the per-parameter gradients
    /// and the input-side dx, or `None` on divergence.
    fn backward_core(
        &mut self,
        mb: u64,
        dx_in: Option<Vec<f32>>,
    ) -> Result<Option<(Vec<Tensor>, Tensor)>> {
        let mcfg = self.rt.cfg().clone();
        let (b, s, d) = (mcfg.batch, mcfg.seq, mcfg.d_model);
        let pos = self
            .stash
            .iter()
            .position(|(m, _, _)| *m == mb)
            .ok_or_else(|| anyhow!("chunk {}: no stash for mb {mb}", self.spec.id))?;
        let (_, snapshot, block_inputs) = self.stash.remove(pos).unwrap();
        let current_weights;
        let weights: &[Tensor] = if self.use_stash {
            &snapshot
        } else {
            current_weights = self.params.clone();
            &current_weights
        };

        let mut grads: Vec<Tensor> =
            self.params.iter().map(|p| Tensor::zeros(&p.shape)).collect();

        let mut dx = match dx_in {
            Some(dx) => Tensor::new(vec![b, s, d], dx),
            None => {
                // head chunk: fused loss + output gradient
                let tgts = self.pending_targets.remove(&mb).expect("targets");
                let x = self
                    .head_x
                    .remove(&mb)
                    .expect("head chunk stores its forward output");
                let t0 = Instant::now();
                let gf = &weights[self.local_index("gf")];
                let head = &weights[self.local_index("head")];
                let outs = self.rt.exec(
                    "head_fwdbwd",
                    &[
                        tensor_to_value(gf)?,
                        tensor_to_value(head)?,
                        tensor_to_value(&x)?,
                        tokens_to_value(&tgts, b, s)?,
                    ],
                )?;
                self.compute_s += t0.elapsed().as_secs_f64();
                let loss = value_scalar_f32(&outs[0])?;
                if !loss.is_finite() {
                    // mirror train_sim: don't record the loss, skip the
                    // update, stop the run
                    self.diverged = true;
                    return Ok(None);
                }
                self.losses.push((mb, loss));
                let i_gf = self.local_index("gf");
                let i_head = self.local_index("head");
                let gf_shape = self.params[i_gf].shape.clone();
                let head_shape = self.params[i_head].shape.clone();
                grads[i_gf] = value_to_tensor(&outs[2], &gf_shape)?;
                grads[i_head] = value_to_tensor(&outs[3], &head_shape)?;
                value_to_tensor(&outs[1], &[b, s, d])?
            }
        };

        let t0 = Instant::now();
        for (bi, &blk) in self.blocks.clone().iter().enumerate().rev() {
            let bp = self.block_params(blk, weights);
            let mut ins: Vec<Value> =
                bp.iter().map(tensor_to_value).collect::<Result<_>>()?;
            ins.push(tensor_to_value(&block_inputs[bi])?);
            ins.push(tensor_to_value(&dx)?);
            let outs = self.rt.exec("block_bwd", &ins)?;
            dx = value_to_tensor(&outs[0], &[b, s, d])?;
            let prefix = format!("b{blk}.");
            let mut gi = 1;
            for local in 0..self.params.len() {
                if self.rt.manifest.params[local].name.starts_with(&prefix) {
                    let shape = self.params[local].shape.clone();
                    grads[local] = value_to_tensor(&outs[gi], &shape)?;
                    gi += 1;
                }
            }
        }
        self.compute_s += t0.elapsed().as_secs_f64();
        if self.stash_weights {
            self.last_snapshot = snapshot;
        }
        Ok(Some((grads, dx)))
    }

    /// Fold one backward's gradients into the accumulator (source
    /// chunks first run the embedding backward with the final dx).
    fn accumulate(
        &mut self,
        mb: u64,
        mut grads: Vec<Tensor>,
        embed_dx: Option<&Tensor>,
    ) -> Result<()> {
        if let Some(dx) = embed_dx {
            let mcfg = self.rt.cfg().clone();
            let (b, s) = (mcfg.batch, mcfg.seq);
            let toks = self.pending_tokens.remove(&mb).expect("tokens");
            let t0 = Instant::now();
            let outs = self.rt.exec(
                "embed_bwd",
                &[tokens_to_value(&toks, b, s)?, tensor_to_value(dx)?],
            )?;
            self.compute_s += t0.elapsed().as_secs_f64();
            let i_te = self.local_index("tok_emb");
            let i_pe = self.local_index("pos_emb");
            let te_shape = self.params[i_te].shape.clone();
            let pe_shape = self.params[i_pe].shape.clone();
            grads[i_te] = value_to_tensor(&outs[0], &te_shape)?;
            grads[i_pe] = value_to_tensor(&outs[1], &pe_shape)?;
        }
        self.pending_mbs.push(mb);
        match &mut self.acc {
            None => {
                self.acc = Some(grads);
                self.acc_n = 1;
            }
            Some(acc) => {
                for (a, g) in acc.iter_mut().zip(&grads) {
                    for (ai, &gi) in a.data.iter_mut().zip(&g.data) {
                        *ai += gi;
                    }
                }
                self.acc_n += 1;
            }
        }
        Ok(())
    }

    /// All-reduce the accumulated gradient, clip, and apply this
    /// chunk's optimizer step (the legacy reduce → clip → step order).
    /// Returns `(applied, idle_seconds)`; `applied = false` means a
    /// peer hung up mid-reduce (wind-down). Records a `Reduce` span
    /// over the all-reduce wait and an `Update` span over the
    /// clip + optimizer step into the worker's recorder.
    fn apply_update(&mut self, rec: &mut trace::Recorder) -> Result<(bool, f64)> {
        let mut grads = self.acc.take().ok_or_else(|| {
            anyhow!("chunk {}: update with no accumulated gradient", self.spec.id)
        })?;
        let n = self.acc_n;
        self.acc_n = 0;
        if n > 1 {
            // mean over the accumulated microbatches — same op order
            // as dp::average (sum in order, then scale)
            let inv = 1.0 / n as f32;
            for t in grads.iter_mut() {
                for a in t.data.iter_mut() {
                    *a *= inv;
                }
            }
        }
        let t_red = Instant::now();
        let reduced = self.dp.all_reduce(self.updates + 1, grads);
        let idle = t_red.elapsed().as_secs_f64();
        rec.push(
            SpanKind::Reduce,
            self.spec.id as i64,
            -1,
            (self.updates + 1) as i64,
            t_red,
            0,
        );
        let mut grads = match reduced {
            Ok(g) => g,
            Err(_) => return Ok((false, idle)),
        };
        let t_upd = Instant::now();
        let d0 = self.rt.total_dispatches();
        optim::clip_global_norm(&mut grads, self.cfg.grad_clip);
        // realized-delay instrumentation: updates seen between each
        // microbatch's forward and this update (before the increment)
        for mb in self.pending_mbs.drain(..) {
            let seen = self.u_at_fwd.remove(&mb).unwrap_or(self.updates);
            let delay = (self.updates - seen) as u32;
            self.realized_mbs += 1;
            self.realized_max = self.realized_max.max(delay);
            let d = delay as usize;
            if self.delay_hist.len() <= d {
                self.delay_hist.resize(d + 1, 0);
            }
            self.delay_hist[d] += 1;
            self.delay_samples.push((self.updates + 1, delay));
        }
        self.updates += 1;
        let needs_stale = matches!(self.cfg.method, Method::DelayComp { .. });
        let ctx = StepCtx {
            t: self.updates,
            lr: self.cfg.lr_at(self.updates as u32),
            cfg: &self.cfg,
            part: &self.part,
            // the stash is exactly the weight view the gradient was
            // computed at — DelayComp's Taylor reference
            stale: if needs_stale { Some(&self.last_snapshot) } else { None },
            rt: &self.rt,
        };
        self.opt.step(&ctx, &mut self.params, &grads)?;
        rec.push(
            SpanKind::Update,
            self.spec.id as i64,
            -1,
            self.updates as i64,
            t_upd,
            self.rt.total_dispatches() - d0,
        );
        Ok((true, idle))
    }

    fn report(&self, is_head: bool) -> ChunkReport {
        ChunkReport {
            chunk: self.spec.id,
            part: self.spec.part,
            stream: self.spec.stream,
            losses: self.losses.clone(),
            val_losses: self.val_losses.clone(),
            updates: self.updates,
            diverged: self.diverged,
            dispatches: self.rt.total_dispatches(),
            state_elems: self.opt.state_elems(),
            realized_mbs: self.realized_mbs,
            realized_max_delay: self.realized_max,
            is_head,
            delay_hist: self.delay_hist.clone(),
            delay_samples: self.delay_samples.clone(),
            dp_skew_hist: self.dp.skew_hist(),
            dp_max_skew: self.dp.max_skew_seen(),
            dp_stalls: self.dp.stalls(),
        }
    }
}

/// One worker thread: executes its action stream over its chunks.
struct Worker {
    w: usize,
    replica: usize,
    cfg: TrainCfg,
    chunks: Vec<ChunkState>,
    /// chunk id → local index in `chunks`.
    index: HashMap<usize, usize>,
    /// Global layout tables (shared by every worker of the replica).
    specs_by_id: HashMap<usize, ChunkSpec>,
    by_pos: HashMap<(usize, usize), usize>,
    depth: HashMap<usize, usize>,
    inbox: Receiver<Msg>,
    peers: Vec<Sender<Msg>>,
    pending_fwd: HashMap<(usize, u64), Vec<f32>>,
    pending_bwd: HashMap<(usize, u64), Vec<f32>>,
    /// Evals dequeued during backward waits, replayed at the next
    /// forward-wait point (legacy determinism).
    pending_evals: VecDeque<(usize, u32, Vec<f32>)>,
    sent_stop: bool,
    idle_s: f64,
    /// Per-thread span buffer (lock-free: owned by this thread only,
    /// handed back through the [`WorkerReport`] at join).
    rec: trace::Recorder,
    /// `(global update, pending-buffer depth)` sampled at each Update.
    queue_samples: Vec<(u64, u32)>,
    /// Planned fault: die right after completing this global update.
    kill_at: Option<u64>,
    /// Planned perturbations: (global update, sleep millis).
    inject_delays: Vec<(u64, u64)>,
    /// Export chunk params + optimizer state after a completed stream.
    export: bool,
}

/// One chunk's exported state: (part id, params, optimizer state).
type ChunkExport = (usize, Vec<Tensor>, OptState);

impl Worker {
    fn is_head(&self, spec: &ChunkSpec) -> bool {
        spec.seq + 1 == self.depth[&spec.stream]
    }

    /// Broadcast `Stop` to this replica's other workers (idempotent).
    fn stop_all(&mut self) {
        if self.sent_stop {
            return;
        }
        self.sent_stop = true;
        for (i, tx) in self.peers.iter().enumerate() {
            if i != self.w {
                tx.send(Msg::Stop).ok();
            }
        }
    }

    /// Handle a validation activation for a local chunk: forward at
    /// current weights, then record (head) or relay downstream.
    fn handle_eval(&mut self, chunk: usize, label: u32, x: Vec<f32>) -> Result<()> {
        let li = *self
            .index
            .get(&chunk)
            .ok_or_else(|| anyhow!("worker {}: eval for foreign chunk {chunk}", self.w))?;
        let spec = self.chunks[li].spec;
        let xt = self.chunks[li].eval_blocks(x)?;
        if self.is_head(&spec) {
            let vg = {
                let c = &mut self.chunks[li];
                let (_vt, vg) = c
                    .val_iter
                    .as_mut()
                    .expect("head chunk has a val iter")
                    .next_batch();
                vg
            };
            self.chunks[li].record_val(label, &xt, &vg)?;
        } else {
            let next = self.by_pos[&(spec.stream, spec.seq + 1)];
            let nw = self.specs_by_id[&next].worker;
            // a dropped receiver means downstream already stopped; the
            // training path notices on its own send/recv
            self.peers[nw].send(Msg::Eval { chunk: next, label, x: xt.data }).ok();
        }
        self.chunks[li].evals_handled += 1;
        Ok(())
    }

    /// Replica 0's stream-0 source chunk: emit one validation forward
    /// after an eval-triggering update.
    fn source_eval(&mut self, li: usize) -> Result<()> {
        let spec = self.chunks[li].spec;
        let label = self.chunks[li].updates as u32;
        let (vt, vg) = {
            let c = &mut self.chunks[li];
            c.val_iter
                .as_mut()
                .expect("source chunk has a val iter")
                .next_batch()
        };
        let x0 = self.chunks[li].embed_fwd(&vt)?;
        let x = self.chunks[li].eval_blocks(x0)?;
        if self.is_head(&spec) {
            // P = 1: post-update weights + shared val stream — exactly
            // the simulator's evaluation
            self.chunks[li].record_val(label, &x, &vg)?;
        } else {
            let next = self.by_pos[&(spec.stream, spec.seq + 1)];
            let nw = self.specs_by_id[&next].worker;
            self.peers[nw].send(Msg::Eval { chunk: next, label, x: x.data }).ok();
        }
        Ok(())
    }

    /// Receive the training activation for (chunk, mb). This is a
    /// forward-wait point: buffered and incoming evals are processed
    /// here. `None` means wind-down (Stop or closed inbox).
    fn recv_fwd(&mut self, chunk: usize, mb: u64) -> Result<Option<Vec<f32>>> {
        loop {
            while let Some((c, label, x)) = self.pending_evals.pop_front() {
                self.handle_eval(c, label, x)?;
            }
            if let Some(x) = self.pending_fwd.remove(&(chunk, mb)) {
                return Ok(Some(x));
            }
            let t0 = Instant::now();
            let msg = match self.inbox.recv() {
                Ok(m) => m,
                Err(_) => return Ok(None),
            };
            self.idle_s += t0.elapsed().as_secs_f64();
            self.rec.push(SpanKind::Idle, chunk as i64, mb as i64, -1, t0, 0);
            match msg {
                Msg::Fwd { chunk: c, mb: m, x } => {
                    self.pending_fwd.insert((c, m), x);
                }
                Msg::Bwd { chunk: c, mb: m, dx } => {
                    self.pending_bwd.insert((c, m), dx);
                }
                Msg::Eval { chunk: c, label, x } => self.handle_eval(c, label, x)?,
                Msg::Stop => return Ok(None),
            }
        }
    }

    /// Receive the output-side gradient for (chunk, mb). Evals
    /// arriving here are buffered, not processed (legacy determinism:
    /// evaluation happens at forward-wait points only).
    fn recv_bwd(&mut self, chunk: usize, mb: u64) -> Result<Option<Vec<f32>>> {
        loop {
            if let Some(dx) = self.pending_bwd.remove(&(chunk, mb)) {
                return Ok(Some(dx));
            }
            let t0 = Instant::now();
            let msg = match self.inbox.recv() {
                Ok(m) => m,
                Err(_) => return Ok(None),
            };
            self.idle_s += t0.elapsed().as_secs_f64();
            self.rec.push(SpanKind::Idle, chunk as i64, mb as i64, -1, t0, 0);
            match msg {
                Msg::Fwd { chunk: c, mb: m, x } => {
                    self.pending_fwd.insert((c, m), x);
                }
                Msg::Bwd { chunk: c, mb: m, dx } => {
                    self.pending_bwd.insert((c, m), dx);
                }
                Msg::Eval { chunk: c, label, x } => {
                    self.pending_evals.push_back((c, label, x));
                }
                Msg::Stop => return Ok(None),
            }
        }
    }

    /// Execute one Fwd action. `false` = wind down.
    fn do_fwd(&mut self, chunk: usize, mb: u64) -> Result<bool> {
        let li = self.index[&chunk];
        let spec = self.chunks[li].spec;
        let is_head = self.is_head(&spec);
        let step = self.chunks[li].updates as i64;
        // Fwd span: embed (source chunks) + block forwards. For
        // non-source chunks the clock starts after the recv returns,
        // so the recv wait stays in its own Idle spans and the
        // timeline never overlaps.
        let mut t_fwd = Instant::now();
        let mut d0 = self.chunks[li].rt.total_dispatches();
        let x0: Vec<f32> = if spec.seq == 0 {
            let (toks, tgts) = self.chunks[li].batch_for(mb);
            if is_head {
                self.chunks[li].pending_targets.insert(mb, tgts);
            }
            let x = self.chunks[li].embed_fwd(&toks)?;
            self.chunks[li].pending_tokens.insert(mb, toks);
            x
        } else {
            if is_head {
                // the head chunk needs this microbatch's targets;
                // re-derive the deterministic batch stream locally
                let (_toks, tgts) = self.chunks[li].batch_for(mb);
                self.chunks[li].pending_targets.insert(mb, tgts);
            }
            let x = match self.recv_fwd(chunk, mb)? {
                Some(x) => x,
                None => return Ok(false),
            };
            t_fwd = Instant::now();
            d0 = self.chunks[li].rt.total_dispatches();
            x
        };
        let x = self.chunks[li].forward_blocks(mb, x0)?;
        let n_disp = self.chunks[li].rt.total_dispatches() - d0;
        self.rec.push(SpanKind::Fwd, chunk as i64, mb as i64, step, t_fwd, n_disp);
        if is_head {
            self.chunks[li].head_x.insert(mb, x);
        } else {
            let next = self.by_pos[&(spec.stream, spec.seq + 1)];
            let nw = self.specs_by_id[&next].worker;
            if self.peers[nw]
                .send(Msg::Fwd { chunk: next, mb, x: x.data })
                .is_err()
            {
                return Ok(false);
            }
        }
        Ok(true)
    }

    /// Execute one Bwd action. `false` = wind down (including
    /// divergence, which sets the chunk's flag first).
    fn do_bwd(&mut self, chunk: usize, mb: u64) -> Result<bool> {
        let li = self.index[&chunk];
        let spec = self.chunks[li].spec;
        let dx_in = if self.is_head(&spec) {
            None
        } else {
            match self.recv_bwd(chunk, mb)? {
                Some(dx) => Some(dx),
                None => return Ok(false),
            }
        };
        // Bwd span: head loss + block backwards + embedding backward
        // (the recv wait above already landed in Idle spans).
        let step = self.chunks[li].updates as i64;
        let t_bwd = Instant::now();
        let d0 = self.chunks[li].rt.total_dispatches();
        let (grads, dx) = match self.chunks[li].backward_core(mb, dx_in)? {
            Some(out) => out,
            None => return Ok(false), // diverged
        };
        if spec.seq > 0 {
            let prev = self.by_pos[&(spec.stream, spec.seq - 1)];
            let pw = self.specs_by_id[&prev].worker;
            if self.peers[pw]
                .send(Msg::Bwd { chunk: prev, mb, dx: dx.data.clone() })
                .is_err()
            {
                return Ok(false);
            }
            self.chunks[li].accumulate(mb, grads, None)?;
        } else {
            self.chunks[li].accumulate(mb, grads, Some(&dx))?;
        }
        let n_disp = self.chunks[li].rt.total_dispatches() - d0;
        self.rec.push(SpanKind::Bwd, chunk as i64, mb as i64, step, t_bwd, n_disp);
        Ok(true)
    }

    /// Execute one Update action. `false` = wind down (peer hung up).
    fn do_update(&mut self, chunk: usize) -> Result<bool> {
        let li = self.index[&chunk];
        let depth = (self.pending_fwd.len() + self.pending_bwd.len()) as u32;
        self.queue_samples.push((self.chunks[li].updates + 1, depth));
        let (applied, idle) = {
            let c = &mut self.chunks[li];
            c.apply_update(&mut self.rec)?
        };
        self.idle_s += idle;
        if !applied {
            return Ok(false);
        }
        let c = &self.chunks[li];
        // Replicas stay in parameter lockstep under synchronous DP
        // (identical all-reduced gradients), so one validation pass —
        // replica 0's stream-0 pipeline — covers all R. Under async DP
        // at K > 0 replicas drift within the skew bound; replica 0's
        // curve stands in for the group (documented approximation).
        if c.spec.stream == 0
            && c.spec.seq == 0
            && self.replica == 0
            && self.cfg.eval_every > 0
            && c.updates % self.cfg.eval_every as u64 == 0
        {
            self.source_eval(li)?;
        }
        // Deterministic fault injection, keyed on the global update
        // counter. A delay is a pure timing perturbation (the schedules
        // are deterministic in message order, not arrival time); a kill
        // makes this worker wind down exactly like a crashed thread —
        // its replica's peers stop over the closed channels and the
        // other replicas observe the dropped all-reduce handle.
        let u = self.chunks[li].updates;
        for &(at, ms) in &self.inject_delays {
            if at == u {
                std::thread::sleep(std::time::Duration::from_millis(ms));
            }
        }
        if self.kill_at == Some(u) {
            return Ok(false);
        }
        Ok(true)
    }

    /// After the action stream: keep relaying/recording evals until
    /// every local chunk has handled the evals the run owes it
    /// (covers evals still in flight when the stream ends).
    fn drain_evals(&mut self) -> Result<()> {
        while self
            .chunks
            .iter()
            .any(|c| c.evals_handled < c.evals_expected)
        {
            if let Some((c, label, x)) = self.pending_evals.pop_front() {
                self.handle_eval(c, label, x)?;
                continue;
            }
            let msg = match self.inbox.recv() {
                Ok(m) => m,
                Err(_) => break,
            };
            match msg {
                Msg::Eval { chunk, label, x } => self.handle_eval(chunk, label, x)?,
                Msg::Stop => break,
                // stray late training messages: the stream is done
                Msg::Fwd { .. } | Msg::Bwd { .. } => {}
            }
        }
        Ok(())
    }

    fn run_inner(&mut self, actions: &[Action]) -> Result<bool> {
        for a in actions {
            let cont = match *a {
                Action::Fwd { mb, chunk } => self.do_fwd(chunk, mb)?,
                Action::Bwd { mb, chunk } => self.do_bwd(chunk, mb)?,
                Action::Update { chunk } => self.do_update(chunk)?,
            };
            if !cont {
                return Ok(false);
            }
        }
        Ok(true)
    }

    fn run(mut self, actions: Vec<Action>) -> Result<(WorkerReport, Vec<ChunkExport>)> {
        let ran = self.run_inner(&actions);
        let mut exports: Vec<ChunkExport> = Vec::new();
        match ran {
            Ok(true) => {
                if let Err(e) = self.drain_evals() {
                    self.stop_all();
                    return Err(e);
                }
                // Export only after a fully-completed stream: a
                // wound-down segment is recovered from the previous
                // checkpoint, never from partial state.
                if self.export {
                    for c in &self.chunks {
                        exports.push((
                            c.spec.part,
                            c.params.clone(),
                            c.opt.state_export()?,
                        ));
                    }
                }
            }
            Ok(false) => self.stop_all(),
            Err(e) => {
                self.stop_all();
                return Err(e);
            }
        }
        let mut chunks: Vec<ChunkReport> = Vec::with_capacity(self.chunks.len());
        for c in &self.chunks {
            chunks.push(c.report(self.is_head(&c.spec)));
        }
        let spans = self.rec.take_spans();
        let queue_samples = std::mem::take(&mut self.queue_samples);
        Ok((
            WorkerReport {
                replica: self.replica,
                worker: self.w,
                compute_s: self.chunks.iter().map(|c| c.compute_s).sum(),
                idle_s: self.idle_s,
                chunks,
                spans,
                queue_samples,
            },
            exports,
        ))
    }
}

/// Train with the real threaded pipeline under `cfg.schedule`.
/// `cfg.steps` = optimizer steps per replica; each step consumes the
/// schedule's `micro_per_update` microbatches.
///
/// Supports every [`Method`] (each chunk builds its own optimizer via
/// [`optim::build`] over a chunk-local manifest) on dense *and* MoE
/// configs, data parallelism (`cfg.replicas = R`), and the four
/// schedules (gpipe / 1f1b / interleaved:V / amdp). The schedule's
/// action streams are validated on the virtual-clock executor before
/// any thread spawns; its deterministic bubble lands in
/// `bubble_frac_model` next to the wall-clock `bubble_frac`, and the
/// per-chunk realized gradient delays land in `realized_delays`.
/// `StashMode::Predict` is simulator-only and errors loudly.
pub fn train_engine(artifacts_dir: PathBuf, cfg: &TrainCfg) -> Result<RunResult> {
    train_engine_segment(artifacts_dir, cfg, &SegmentOpts::default(), None)
        .map(|(r, _)| r)
}

/// One segment of a (possibly checkpointed/elastic) engine run. With
/// default [`SegmentOpts`] and no seed this is the whole run and
/// behaves exactly like the historical `train_engine`. A non-trivial
/// segment starts from the `seed` checkpoint's weights and optimizer
/// states, offsets every global counter (feeds, lr, eval cadence,
/// update indices) to `start_update`, injects the planned faults, and
/// on completion exports the drained state for the next segment.
pub fn train_engine_segment(
    artifacts_dir: PathBuf,
    cfg: &TrainCfg,
    seg: &SegmentOpts,
    seed: Option<&EngineCheckpoint>,
) -> Result<(RunResult, Option<EngineCheckpoint>)> {
    let man0 = crate::runtime::Manifest::resolve(&artifacts_dir)?;
    if cfg.stash == StashMode::Predict {
        bail!(
            "engine does not implement StashMode::Predict (PipeMare weight \
             prediction is simulator-only): no engine schedule supports it — \
             --schedule gpipe, 1f1b, interleaved:V and amdp all reject it; \
             use train_sim, or StashMode::Stash/NoStash on the engine"
        );
    }
    let sched: Box<dyn Schedule> = schedule::build(cfg.schedule);
    let p = cfg.stages;
    let n_parts = sched.n_parts(p);
    if cfg.schedule == ScheduleKind::Amdp && p % 2 != 0 {
        bail!(
            "--schedule amdp pairs worker k with worker P-1-k across its two \
             streams and needs an even stage count; got P={p} (use an even \
             --stages or another --schedule)"
        );
    }
    if cfg.dp_async && cfg.schedule == ScheduleKind::Amdp {
        bail!(
            "--dp-async does not support --schedule amdp: its two weight \
             copies per part share one reduce group, which has no per-replica \
             step-skew semantics; use a linear --schedule"
        );
    }
    if n_parts > man0.cfg.n_blocks {
        bail!(
            "--schedule {} needs {n_parts} model chunks but the model has \
             only {} blocks; lower --stages or the interleave factor V",
            cfg.schedule.name(),
            man0.cfg.n_blocks
        );
    }
    let r_count = cfg.dp_replicas();
    let start_u = seg.start_update;
    let end_u = if seg.end_update == 0 { cfg.steps as u64 } else { seg.end_update };
    if end_u > cfg.steps as u64 || start_u >= end_u {
        bail!(
            "engine segment [{start_u}, {end_u}) does not fit a {}-step run",
            cfg.steps
        );
    }
    let segmented = seg.export_state || seed.is_some() || start_u > 0;
    if segmented && cfg.schedule == ScheduleKind::Amdp {
        bail!(
            "engine checkpointing does not support --schedule amdp: its two \
             counter-flowing weight copies per part make a single exported \
             part snapshot ambiguous"
        );
    }
    let n_updates = end_u - start_u;
    let m_eff = sched.effective_m(p, cfg.microbatches as usize);
    let mpu = sched.micro_per_update(p, cfg.microbatches as usize).max(1) as u64;
    let mcfg = man0.cfg.clone();
    let chunks = sched.chunks(p);
    let specs_by_id: HashMap<usize, ChunkSpec> =
        chunks.iter().map(|c| (c.id, *c)).collect();
    let by_pos: HashMap<(usize, usize), usize> =
        chunks.iter().map(|c| ((c.stream, c.seq), c.id)).collect();
    let mut depth: HashMap<usize, usize> = HashMap::new();
    for c in &chunks {
        let e = depth.entry(c.stream).or_insert(0);
        *e = (*e).max(c.seq + 1);
    }

    // Validate the action streams on the virtual clock before spawning
    // anything: a malformed or cyclic stream is an error here, not a
    // deadlocked thread — and the feasible virtual-time order is what
    // makes the blocking execution below deadlock-free. The measured
    // bubble doubles as the run's deterministic schedule model.
    let model_stats =
        schedule::simulate(sched.as_ref(), p, cfg.microbatches as usize, n_updates)?;
    let actions_by_worker: Vec<Vec<Action>> = (0..p)
        .map(|w| sched.worker_actions(p, m_eff, n_updates, w))
        .collect();

    let part0 = StagePartition::new(&man0, n_parts);
    let init = init_params(&man0, cfg.seed);
    if let Some(ck) = seed {
        if ck.step != start_u {
            bail!(
                "seed checkpoint is at step {} but the segment starts at {start_u}",
                ck.step
            );
        }
        if ck.params.len() != init.len() {
            bail!(
                "seed checkpoint holds {} params, model has {}",
                ck.params.len(),
                init.len()
            );
        }
        if ck.opts.len() != n_parts {
            bail!(
                "seed checkpoint holds {} optimizer states for {n_parts} parts",
                ck.opts.len()
            );
        }
        for (rep, ps, os) in &ck.replica_states {
            if ps.len() != init.len() || os.len() != n_parts {
                bail!(
                    "seed checkpoint replica {rep} state holds {} params / {} \
                     optimizer states; the model has {} params and {n_parts} \
                     parts",
                    ps.len(),
                    os.len(),
                    init.len()
                );
            }
        }
    }

    // one all-reduce group per part over R × copies handles; copies
    // sorted by stream so the fold order is down-before-up per replica
    // (the simulator's draw order)
    let mut copies_of_part: Vec<Vec<usize>> = vec![Vec::new(); n_parts];
    for c in &chunks {
        copies_of_part[c.part].push(c.id);
    }
    for v in copies_of_part.iter_mut() {
        v.sort_by_key(|id| specs_by_id[id].stream);
    }
    let mut dp_handles: Vec<Vec<Option<DpReduce>>> = copies_of_part
        .iter()
        .map(|v| {
            let n = r_count * v.len();
            if cfg.dp_async {
                dp_async::group(n, cfg.max_skew, start_u, end_u, cfg.reduce_timeout())
                    .into_iter()
                    .map(|h| Some(DpReduce::Async(h)))
                    .collect()
            } else {
                dp::group_with(n, cfg.reduce_timeout())
                    .into_iter()
                    .map(|h| Some(DpReduce::Sync(h)))
                    .collect()
            }
        })
        .collect();

    let t0 = Instant::now();
    // Shared span epoch: every worker thread stamps its spans against
    // the same origin, so per-thread timelines merge into one trace.
    let epoch = t0;
    // Divide the kernel thread budget across the P x R stage workers so
    // stage workers x kernel threads never oversubscribes the host; each
    // worker installs its share as a thread-local budget (runtime::pool)
    // before touching any kernel. The remainder goes to the first
    // `total % (P*R)` workers instead of being stranded. Results are
    // bit-identical regardless.
    let total_threads = crate::runtime::pool::ThreadCfg::new(cfg.threads).resolve();
    let worker_budgets = split_thread_budget(total_threads, p * r_count);
    let mut handles = Vec::new();
    for rep in 0..r_count {
        let mut txs: Vec<Sender<Msg>> = Vec::new();
        let mut rxs = Vec::new();
        for _ in 0..p {
            let (tx, rx) = channel::<Msg>();
            txs.push(tx);
            rxs.push(rx);
        }
        for (w, inbox) in rxs.into_iter().enumerate() {
            let my_specs: Vec<ChunkSpec> =
                chunks.iter().filter(|c| c.worker == w).copied().collect();
            // per-chunk setup data prepared on the main thread
            let mut setup = Vec::with_capacity(my_specs.len());
            for spec in &my_specs {
                let keep = part0.params_of_stage(spec.part);
                // Seeded segments start from the checkpoint weights and
                // optimizer state; a fresh run from the seeded init.
                // Under async DP at K > 0 a checkpoint carries each
                // replica's divergent copy — seed from it when present.
                // Everything else (fresh run, sync checkpoint, roster
                // change that collapsed the skew state) seeds from the
                // canonical replica-0 state.
                let rep_state = seed.and_then(|ck| {
                    ck.replica_states.iter().find(|(r, _, _)| *r == rep)
                });
                let init_c: Vec<Tensor> = match (rep_state, seed) {
                    (Some((_, ps, _)), _) => {
                        keep.iter().map(|&i| ps[i].clone()).collect()
                    }
                    (None, Some(ck)) => {
                        keep.iter().map(|&i| ck.params[i].clone()).collect()
                    }
                    (None, None) => keep.iter().map(|&i| init[i].clone()).collect(),
                };
                let opt_state: Option<OptState> = match (rep_state, seed) {
                    (Some((_, _, os)), _) => Some(os[spec.part].clone()),
                    (None, Some(ck)) => Some(ck.opts[spec.part].clone()),
                    (None, None) => None,
                };
                let copy_idx = copies_of_part[spec.part]
                    .iter()
                    .position(|&id| id == spec.id)
                    .unwrap();
                let copies = copies_of_part[spec.part].len();
                let dp_h =
                    dp_handles[spec.part][rep * copies + copy_idx].take().unwrap();
                let corpus = Corpus::new(mcfg.vocab, cfg.seed ^ 0xDA7A);
                let mut feed = BatchIter::new(
                    corpus.clone(),
                    mcfg.batch,
                    mcfg.seq,
                    replica_stream(TRAIN_STREAM, rep),
                );
                if start_u > 0 {
                    // global microbatches this replica consumed before
                    // the segment; local mb m maps to global offset + m
                    feed.seek(start_u * mpu);
                }
                let needs_val = cfg.eval_every > 0
                    && rep == 0
                    && spec.stream == 0
                    && (spec.seq == 0 || spec.seq + 1 == depth[&spec.stream]);
                let val_iter = if needs_val {
                    let mut it = BatchIter::new(
                        corpus,
                        mcfg.batch,
                        mcfg.seq,
                        super::VAL_STREAM,
                    );
                    if start_u > 0 {
                        // one validation batch per eval already sourced
                        it.seek(start_u / cfg.eval_every as u64);
                    }
                    Some(it)
                } else {
                    None
                };
                // chunks downstream of the eval source each receive
                // (and relay or record) every sourced eval
                let evals_expected = if cfg.eval_every > 0
                    && rep == 0
                    && spec.stream == 0
                    && spec.seq > 0
                {
                    end_u / cfg.eval_every as u64 - start_u / cfg.eval_every as u64
                } else {
                    0
                };
                setup.push((
                    *spec,
                    keep,
                    init_c,
                    opt_state,
                    dp_h,
                    feed,
                    val_iter,
                    evals_expected,
                ));
            }
            let dir = artifacts_dir.clone();
            let cfg_w = cfg.clone();
            let actions = actions_by_worker[w].clone();
            let peers = txs.clone();
            let specs_by_id = specs_by_id.clone();
            let by_pos = by_pos.clone();
            let depth = depth.clone();
            let kill_at = seg
                .kills
                .iter()
                .find(|k| k.0 == rep && k.1 == w)
                .map(|k| k.2);
            let inject_delays: Vec<(u64, u64)> = seg
                .delays
                .iter()
                .filter(|d| d.0 == rep && d.1 == w)
                .map(|d| (d.2, d.3))
                .collect();
            // Sync DP: replica 0's drained copy represents the group.
            // Async DP at K > 0: every replica exports its own copy so
            // resume can restore the in-flight skew state.
            let export = seg.export_state
                && (rep == 0 || (cfg.dp_async && cfg.max_skew > 0 && r_count > 1));
            let worker_budget = worker_budgets[rep * p + w];
            handles.push((
                rep,
                w,
                std::thread::spawn(move || -> Result<(WorkerReport, Vec<ChunkExport>)> {
                    let _budget = crate::runtime::pool::install_budget(worker_budget);
                    let mut states = Vec::with_capacity(setup.len());
                    let mut index = HashMap::new();
                    for (
                        spec,
                        keep,
                        init_c,
                        opt_state,
                        dp_h,
                        feed,
                        val_iter,
                        evals_expected,
                    ) in setup
                    {
                        let rt = Runtime::open_restricted(&dir, &keep)?;
                        let mut part_c = StagePartition::new(&rt.manifest, n_parts);
                        // uniform chunk delay — the schedule's declared
                        // staleness (identical to the derived P-1-k
                        // values for the 1F1B layout)
                        for d in part_c.delay_of.iter_mut() {
                            *d = spec.delay;
                        }
                        let mut opt = optim::build(&cfg_w.method, &rt, &cfg_w);
                        if let Some(st) = &opt_state {
                            opt.state_import(st)?;
                        }
                        let use_stash = cfg_w.stash != StashMode::NoStash;
                        let stash_weights = use_stash
                            || matches!(cfg_w.method, Method::DelayComp { .. });
                        index.insert(spec.id, states.len());
                        states.push(ChunkState {
                            spec,
                            blocks: part_c.blocks_of_stage[spec.part].clone(),
                            part: part_c,
                            params: init_c,
                            opt,
                            dp: dp_h,
                            cfg: cfg_w.clone(),
                            feed,
                            feed_next: 0,
                            stash: Default::default(),
                            head_x: Default::default(),
                            pending_tokens: Default::default(),
                            pending_targets: Default::default(),
                            acc: None,
                            acc_n: 0,
                            last_snapshot: Vec::new(),
                            use_stash,
                            stash_weights,
                            updates: start_u,
                            compute_s: 0.0,
                            losses: Vec::new(),
                            val_losses: Vec::new(),
                            val_iter,
                            evals_handled: 0,
                            evals_expected,
                            u_at_fwd: Default::default(),
                            pending_mbs: Vec::new(),
                            realized_mbs: 0,
                            realized_max: 0,
                            delay_hist: Vec::new(),
                            delay_samples: Vec::new(),
                            diverged: false,
                            rt,
                        });
                    }
                    let worker = Worker {
                        w,
                        replica: rep,
                        cfg: cfg_w,
                        chunks: states,
                        index,
                        specs_by_id,
                        by_pos,
                        depth,
                        inbox,
                        peers,
                        pending_fwd: Default::default(),
                        pending_bwd: Default::default(),
                        pending_evals: Default::default(),
                        sent_stop: false,
                        idle_s: 0.0,
                        rec: trace::Recorder::new(epoch),
                        queue_samples: Vec::new(),
                        kill_at,
                        inject_delays,
                        export,
                    };
                    worker.run(actions)
                }),
            ));
        }
    }

    let mut result = RunResult::new(&cfg.method.name(), p);
    result.replicas = r_count;
    result.threads = total_threads;
    result.dp_async = cfg.dp_async;
    result.max_skew = cfg.max_skew;
    result.param_count = man0.total_params();
    result.schedule = cfg.schedule.name();
    let mut total_compute = 0.0;
    let mut total_idle = 0.0;
    let mut rep_records: Vec<Vec<(u64, f32)>> = vec![Vec::new(); r_count];
    let mut delay_rows: Vec<(usize, u64, u32)> = Vec::new();
    let mut chunk_exports: Vec<(usize, ChunkExport)> = Vec::new();
    let mut stale_rep_rows: Vec<(usize, usize, Vec<u64>)> = Vec::new();
    let mut stale_samples: Vec<(u64, u32)> = Vec::new();
    let mut queue_all: Vec<(u64, u32)> = Vec::new();
    let mut rep_updates: Vec<u64> = vec![0; r_count];
    let mut rep_wall: Vec<f64> = vec![0.0; r_count];
    let mut rep_skew_hist: Vec<Vec<u64>> = vec![Vec::new(); r_count];
    let mut rep_skew_max: Vec<u32> = vec![0; r_count];
    let mut rep_stalls: Vec<u64> = vec![0; r_count];
    let mut run_trace = trace::Trace::default();
    for (rep, w, h) in handles {
        let (wr, ex) = h
            .join()
            .map_err(|_| anyhow!("replica {rep} worker {w} panicked"))??;
        chunk_exports.extend(ex.into_iter().map(|e| (rep, e)));
        total_compute += wr.compute_s;
        total_idle += wr.idle_s;
        rep_wall[rep] = rep_wall[rep].max(wr.compute_s + wr.idle_s);
        let mut busy_s = 0.0;
        let mut widle_s = 0.0;
        for s in &wr.spans {
            if s.kind.is_busy() {
                busy_s += s.dur_us / 1e6;
            } else {
                widle_s += s.dur_us / 1e6;
            }
        }
        result.stage_spans.push(StageSpan {
            replica: rep,
            worker: w,
            busy_s,
            idle_s: widle_s,
            spans: wr.spans.len() as u64,
        });
        queue_all.extend(wr.queue_samples.iter().copied());
        for cr in &wr.chunks {
            result.dispatches += cr.dispatches;
            result.optimizer_state_elems += cr.state_elems;
            result.diverged |= cr.diverged;
            result.stage_counters.push(StageCounter {
                replica: rep,
                stage: cr.chunk,
                dispatches: cr.dispatches,
                optimizer_state_elems: cr.state_elems,
                updates: cr.updates,
            });
            if cr.is_head {
                rep_records[rep].extend(cr.losses.iter().copied());
                if rep == 0 && cr.stream == 0 {
                    result.val_losses = cr.val_losses.clone();
                }
            }
            if rep == 0 {
                delay_rows.push((cr.chunk, cr.realized_mbs, cr.realized_max_delay));
                stale_samples.extend(cr.delay_samples.iter().copied());
            }
            stale_rep_rows.push((rep, cr.chunk, cr.delay_hist.clone()));
            rep_updates[rep] = rep_updates[rep].max(cr.updates);
            if rep_skew_hist[rep].len() < cr.dp_skew_hist.len() {
                rep_skew_hist[rep].resize(cr.dp_skew_hist.len(), 0);
            }
            for (d, &c) in cr.dp_skew_hist.iter().enumerate() {
                rep_skew_hist[rep][d] += c;
            }
            rep_skew_max[rep] = rep_skew_max[rep].max(cr.dp_max_skew);
            rep_stalls[rep] += cr.dp_stalls;
        }
        run_trace.push_thread(rep as u64, w as u64, format!("r{rep}/w{w}"), wr.spans);
    }
    result.stage_counters.sort_by_key(|c| (c.replica, c.stage));
    result.stage_spans.sort_by_key(|s| (s.replica, s.worker));
    delay_rows.sort_by_key(|&(c, _, _)| c);
    result.realized_delays = delay_rows;
    stale_rep_rows.sort_by_key(|r| (r.0, r.1));
    // Merged per-chunk view over all replicas (Hist::merge), so the
    // steady-state mode stays pinned to the declared schedule delay
    // while per-replica drift (elastic faults, DP skew) stays visible
    // in the by-replica rows.
    let mut merged: std::collections::BTreeMap<usize, crate::metrics::Hist> =
        std::collections::BTreeMap::new();
    for (_, chunk, counts) in &stale_rep_rows {
        merged.entry(*chunk).or_default().merge(&hist_of_counts(counts));
    }
    result.staleness_histogram =
        merged.into_iter().map(|(c, h)| (c, h.counts)).collect();
    result.staleness_by_replica = stale_rep_rows;
    result.worker_budgets = worker_budgets;
    for rep in 0..r_count {
        let wall = rep_wall[rep];
        let updates = rep_updates[rep];
        result.replica_counters.push(crate::metrics::ReplicaCounter {
            replica: rep,
            updates,
            wall_s: wall,
            steps_per_sec: if wall > 0.0 { updates as f64 / wall } else { 0.0 },
            dp_skew_hist: std::mem::take(&mut rep_skew_hist[rep]),
            dp_max_skew: rep_skew_max[rep],
            dp_stalls: rep_stalls[rep],
        });
    }

    // Per-step losses: group each replica's head-chunk records by
    // optimizer step (mb / mpu), keep complete groups only (early
    // stop truncates), mean within the group in microbatch order and
    // across replicas in replica order — the simulator's fold exactly.
    let mut rep_losses: Vec<Vec<f32>> = Vec::with_capacity(r_count);
    for records in rep_records.iter_mut() {
        records.sort_by_key(|&(mb, _)| mb);
        let mut per_step = Vec::new();
        let mut i = 0usize;
        let mut step = 0u64;
        while i + (mpu as usize) <= records.len() {
            let hi = (step + 1) * mpu;
            let group: Vec<f32> = records[i..i + mpu as usize]
                .iter()
                .take_while(|&&(mb, _)| mb < hi)
                .map(|&(_, l)| l)
                .collect();
            if group.len() != mpu as usize {
                break;
            }
            per_step.push(if mpu == 1 { group[0] } else { dp::mean_loss(&group)? });
            i += mpu as usize;
            step += 1;
        }
        rep_losses.push(per_step);
    }
    let n_steps = rep_losses.iter().map(|l| l.len()).min().unwrap_or(0);
    let mut step_losses = Vec::with_capacity(n_steps);
    for i in 0..n_steps {
        step_losses.push(if r_count == 1 {
            rep_losses[0][i]
        } else {
            let at_step: Vec<f32> = rep_losses.iter().map(|l| l[i]).collect();
            dp::mean_loss(&at_step)?
        });
    }
    result.losses = step_losses;
    result.wall_secs = t0.elapsed().as_secs_f64();
    result.bubble_frac = if total_compute + total_idle > 0.0 {
        total_idle / (total_compute + total_idle)
    } else {
        0.0
    };
    result.bubble_frac_model = model_stats.bubble;
    // Analytic bubble: per-update M for the synchronous schedules, the
    // whole finite run's microbatch count for the asynchronous ones.
    let m_run = match cfg.schedule {
        ScheduleKind::OneFOneB | ScheduleKind::Amdp => {
            cfg.steps as usize * mpu as usize
        }
        _ => m_eff,
    };
    result.bubble_frac_analytic = sched.bubble_frac(p, m_run);
    result.tokens_per_sec = (result.losses.len() as f64
        * mpu as f64
        * r_count as f64
        * mcfg.batch as f64
        * mcfg.seq as f64)
        / result.wall_secs;

    if let Some(path) = &cfg.trace {
        run_trace.write_chrome(path)?;
    }
    if let Some(path) = &cfg.metrics {
        let mut reg = crate::metrics::Registry::new();
        reg.inc("dispatches", result.dispatches);
        reg.gauge("tokens_per_sec", result.tokens_per_sec);
        reg.gauge("bubble_frac", result.bubble_frac);
        for &(_, d) in &stale_samples {
            reg.observe("staleness", d as f64);
        }
        if cfg.dp_async {
            // DP component of the staleness: realized gradient skew of
            // every folded peer contribution, over all replicas.
            for rc in &result.replica_counters {
                for (d, &c) in rc.dp_skew_hist.iter().enumerate() {
                    for _ in 0..c {
                        reg.observe("staleness_dp", d as f64);
                    }
                }
            }
            reg.gauge(
                "dp_max_skew",
                result
                    .replica_counters
                    .iter()
                    .map(|rc| rc.dp_max_skew)
                    .max()
                    .unwrap_or(0) as f64,
            );
        }
        for sp in &result.stage_spans {
            let tot = sp.busy_s + sp.idle_s;
            if tot > 0.0 {
                reg.gauge(&format!("idle_frac/r{}w{}", sp.replica, sp.worker), sp.idle_s / tot);
            }
        }
        let mut stale_by_step: HashMap<u64, Vec<u32>> = HashMap::new();
        for &(u, d) in &stale_samples {
            stale_by_step.entry(u).or_default().push(d);
        }
        let mut queue_by_step: HashMap<u64, u32> = HashMap::new();
        for &(u, q) in &queue_all {
            let e = queue_by_step.entry(u).or_insert(0);
            *e = (*e).max(q);
        }
        for (i, &loss) in result.losses.iter().enumerate() {
            let u = start_u + i as u64 + 1;
            let mut fields: Vec<(&str, f64)> =
                vec![("loss", loss as f64), ("lr", cfg.lr_at(u as u32) as f64)];
            if let Some(ds) = stale_by_step.get(&u) {
                let mean = ds.iter().map(|&d| d as f64).sum::<f64>() / ds.len() as f64;
                fields.push(("staleness_mean", mean));
                fields.push(("staleness_max", ds.iter().copied().max().unwrap_or(0) as f64));
            }
            if let Some(&q) = queue_by_step.get(&u) {
                fields.push(("queue_depth_max", q as f64));
            }
            reg.sample_step(u, &fields);
        }
        reg.write_jsonl(path)?;
    }

    // Assemble the segment export: a replica's chunks cover every part
    // exactly once (AMDP, the only multi-copy schedule, was rejected
    // above), so the merged params are the full drained model. Replica
    // 0 is the canonical copy; under async DP at K > 0 every replica
    // exported, and the per-replica copies ride along so a resumed
    // segment restores the in-flight skew state.
    let completed = result.losses.len() as u64 == n_updates && !result.diverged;
    let export = if seg.export_state && completed {
        let assemble =
            |exports: Vec<ChunkExport>| -> Result<(Vec<Tensor>, Vec<OptState>)> {
                let mut opts_by_part: Vec<Option<OptState>> =
                    (0..n_parts).map(|_| None).collect();
                let mut parts: Vec<(Vec<usize>, Vec<Tensor>)> = Vec::new();
                for (part, params, ost) in exports {
                    parts.push((part0.params_of_stage(part), params));
                    opts_by_part[part] = Some(ost);
                }
                let params = dp::merge_restricted(init.len(), &parts)?;
                let opts = opts_by_part
                    .into_iter()
                    .enumerate()
                    .map(|(i, o)| {
                        o.ok_or_else(|| {
                            anyhow!("no optimizer state exported for part {i}")
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                Ok((params, opts))
            };
        let mut by_rep: std::collections::BTreeMap<usize, Vec<ChunkExport>> =
            std::collections::BTreeMap::new();
        for (rep, e) in chunk_exports {
            by_rep.entry(rep).or_default().push(e);
        }
        let (params, opts) = assemble(
            by_rep
                .remove(&0)
                .ok_or_else(|| anyhow!("replica 0 exported no chunk state"))?,
        )?;
        let mut replica_states = Vec::new();
        if !by_rep.is_empty() {
            replica_states.push((0, params.clone(), opts.clone()));
            for (rep, exports) in by_rep {
                let (p_r, o_r) = assemble(exports)?;
                replica_states.push((rep, p_r, o_r));
            }
        }
        Some(EngineCheckpoint { step: end_u, params, opts, replica_states })
    } else {
        None
    };
    Ok((result, export))
}

/// Analytic schedule model (Fig. 1): bubble fraction of a synchronous
/// fill/drain schedule for P stages and M in-flight microbatches, unit
/// per-stage fwd+bwd cost — `(P-1)/(M+P-1)`. Kept as the historical
/// name; delegates to [`schedule::gpipe_bubble_fraction`], which the
/// pluggable schedules and conformance tests use directly.
pub fn sync_bubble_fraction(p: usize, m: usize) -> f64 {
    schedule::gpipe_bubble_fraction(p, m)
}

pub fn async_bubble_fraction_steady() -> f64 {
    0.0 // PipeDream's steady state keeps every stage busy
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_bubbles_shrink_with_microbatches() {
        assert!(sync_bubble_fraction(4, 1) > sync_bubble_fraction(4, 16));
        assert!((sync_bubble_fraction(4, 4) - 3.0 / 7.0).abs() < 1e-12);
        assert!(sync_bubble_fraction(1, 8) == 0.0);
        assert_eq!(async_bubble_fraction_steady(), 0.0);
    }

    #[test]
    fn sync_bubbles_grow_with_depth() {
        assert!(sync_bubble_fraction(32, 8) > sync_bubble_fraction(4, 8));
    }

    #[test]
    fn snippets_bubble_formulas_pinned() {
        // SNIPPETS.md snippet 1: GPipe bubble over *total* slots is
        // (P-1)/(M+P-1); `sync_bubble_fraction` has always used this
        // total-slot convention, so it keeps its name and now
        // delegates to the schedule module's formula.
        assert!((sync_bubble_fraction(4, 8) - 3.0 / 11.0).abs() < 1e-12);
        assert_eq!(
            sync_bubble_fraction(4, 8),
            schedule::gpipe_bubble_fraction(4, 8)
        );
        // 1F1B warmup-drain variant quoted over *ideal* time: (P-1)/M
        assert!(
            (schedule::one_f_one_b_bubble_fraction_ideal(4, 8) - 3.0 / 8.0).abs()
                < 1e-12
        );
        // interleaved: (P-1)/(M·V) over ideal time
        assert!(
            (schedule::interleaved_bubble_fraction_ideal(4, 8, 2) - 3.0 / 16.0)
                .abs()
                < 1e-12
        );
        // the two conventions agree via total = ideal/(1+ideal)
        let x = schedule::one_f_one_b_bubble_fraction_ideal(4, 8);
        assert!((sync_bubble_fraction(4, 8) - x / (1.0 + x)).abs() < 1e-12);
    }

    #[test]
    fn engine_rejects_predict_stash_mode() {
        // silent fallback would corrupt experiments — reject loudly,
        // and say which schedules are affected (all of them)
        let cfg = TrainCfg {
            stash: StashMode::Predict,
            stages: 2,
            steps: 4,
            ..Default::default()
        };
        let err = train_engine(PathBuf::from("artifacts/micro"), &cfg)
            .unwrap_err()
            .to_string();
        assert!(err.contains("Predict"), "{err}");
        assert!(err.contains("--schedule"), "{err}");
    }

    #[test]
    fn engine_rejects_odd_stage_amdp() {
        let cfg = TrainCfg {
            schedule: ScheduleKind::Amdp,
            stages: 1,
            steps: 4,
            ..Default::default()
        };
        let err = train_engine(PathBuf::from("artifacts/micro"), &cfg)
            .unwrap_err()
            .to_string();
        assert!(err.contains("even"), "{err}");
        assert!(err.contains("--schedule"), "{err}");
    }

    #[test]
    fn worker_budget_split_strands_no_cores() {
        // the old floor division gave [1, 1, 1, 1] for 6 threads at
        // P=4, leaving 2 cores idle
        assert_eq!(split_thread_budget(6, 4), vec![2, 2, 1, 1]);
        assert_eq!(split_thread_budget(8, 4), vec![2, 2, 2, 2]);
        assert_eq!(split_thread_budget(7, 3), vec![3, 2, 2]);
        // oversubscribed hosts keep the floor of 1 per worker
        assert_eq!(split_thread_budget(3, 8), vec![1; 8]);
        // nothing stranded whenever total >= workers
        assert_eq!(split_thread_budget(6, 4).iter().sum::<usize>(), 6);
    }

    #[test]
    fn engine_rejects_dp_async_amdp() {
        let cfg = TrainCfg {
            schedule: ScheduleKind::Amdp,
            dp_async: true,
            stages: 2,
            steps: 4,
            ..Default::default()
        };
        let err = train_engine(PathBuf::from("artifacts/micro"), &cfg)
            .unwrap_err()
            .to_string();
        assert!(err.contains("--dp-async"), "{err}");
    }

    #[test]
    fn engine_rejects_oversubscribed_interleaving() {
        // micro has 2 blocks; P=2 × V=2 needs 4 chunks
        let cfg = TrainCfg {
            schedule: ScheduleKind::Interleaved { v: 2 },
            stages: 2,
            steps: 4,
            ..Default::default()
        };
        let err = train_engine(PathBuf::from("artifacts/micro"), &cfg)
            .unwrap_err()
            .to_string();
        assert!(err.contains("blocks"), "{err}");
    }
}
