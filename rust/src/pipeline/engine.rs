//! The real asynchronous pipeline engine: one OS thread per stage,
//! mpsc channels carrying activations, deterministic 1F1B schedule with
//! per-microbatch weight stashing and immediate updates on backward —
//! PipeDream's execution model, end to end, on per-block executables
//! (`embed_fwd` / `block_fwd` / `block_bwd` / `head_fwdbwd`), for both
//! dense and MoE block flavours.
//!
//! Each stage thread opens its own [`Runtime`] (the PJRT client is not
//! `Send`; the native backend is stateless either way), restricted to a
//! **stage-local manifest** ([`crate::runtime::Manifest::restrict`]):
//! only the stage's parameters, with the rotated shape classes and
//! batched optimizer executables re-derived for the stage-resident
//! matrices. On top of that view every stage owns its method's *real*
//! optimizer — a `Box<dyn Optimizer>` from [`optim::build`] — so
//! BasisRotation/SOAP batch only stage-resident matrices, Muon/Scion
//! orthogonalize only local momentum, and DelayComp receives the
//! stashed weight snapshot its gradient was computed at (the 1F1B stash
//! doubles as the Taylor-correction reference even in no-stash mode).
//!
//! Schedule: stage k (0-indexed of P) performs `P-1-k` warmup forwards,
//! then strictly alternates backward/forward. In steady state the
//! forward of microbatch m therefore uses stage-k weights of version
//! `m-(P-1-k)` — exactly the simulator's staleness model, which the
//! `engine_matches_simulator_trajectory` integration tests pin down for
//! PipeDream, Nesterov and basis rotation.
//!
//! Divergence: the last stage checks every training loss; a non-finite
//! loss sets the `diverged` flag, skips the update and stops the run
//! (channel teardown winds down the other stages), mirroring
//! `train_sim`. Validation: when `cfg.eval_every > 0`, stage 0 sources
//! an extra eval-tagged forward through the pipeline after every
//! `eval_every`-th update; the last stage scores it against the shared
//! validation stream and reports `val_losses` like the simulator.
//!
//! Data parallelism (`cfg.replicas = R`): R full pipeline chains run
//! side by side, each on a disjoint data shard; the replicas of each
//! stage share a channel-based all-reduce group ([`super::dp`]) that
//! averages gradients right before every optimizer step. The 1F1B
//! stash stays replica-local (each replica stashes its own in-flight
//! weight snapshots), while the averaged gradient feeds each replica's
//! optimizer identically — so all replicas hold bit-identical
//! parameters at every step, and only replica 0 runs validation.
//!
//! Differences from the simulator (documented, not bugs): gradient-norm
//! clipping is per-stage (a real distributed pipeline has no global
//! norm without an extra collective), so equivalence tests disable
//! clipping. `StashMode::Predict` is simulator-only and rejected
//! loudly.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::dp;
use crate::config::{Method, StashMode, TrainCfg};
use crate::data::{replica_stream, BatchIter, Corpus, TRAIN_STREAM};
use crate::metrics::{RunResult, StageCounter};
use crate::model::{init_params, StagePartition};
use crate::optim::{self, Optimizer, StepCtx};
use crate::runtime::{
    tensor_to_value, tokens_to_value, value_scalar_f32, value_to_tensor, Runtime,
    Value,
};
use crate::tensor::Tensor;

struct FwdMsg {
    mb: u64,
    x: Vec<f32>,
    /// Validation forward: pass through the blocks at current weights,
    /// no stash, no backward; the last stage records the loss.
    eval: bool,
}

struct BwdMsg {
    mb: u64,
    dx: Vec<f32>,
}

/// Loss + perf sample emitted by the last stage / each stage.
#[derive(Clone, Debug, serde::Serialize)]
pub struct StageReport {
    pub replica: usize,
    pub stage: usize,
    pub losses: Vec<f32>,
    pub val_losses: Vec<(u32, f32)>,
    pub compute_s: f64,
    pub idle_s: f64,
    pub updates: u64,
    pub diverged: bool,
    pub dispatches: u64,
    pub state_elems: usize,
}

struct Worker {
    k: usize,
    stages: usize,
    /// Data-parallel replica id this stage thread belongs to.
    replica: usize,
    /// All-reduce handle shared with stage `k` of the other replicas.
    dp: dp::Reducer,
    /// Stage-local runtime: manifest restricted to this stage's params.
    rt: Runtime,
    /// Stage-local partition (delays per local param index).
    part: StagePartition,
    blocks: Vec<usize>,
    /// This stage's parameters, in stage-local manifest order.
    params: Vec<Tensor>,
    /// The method's real optimizer over the stage-local parameter view.
    opt: Box<dyn Optimizer>,
    cfg: TrainCfg,
    /// (mb, weight snapshot, per-block input activations)
    stash: std::collections::VecDeque<(u64, Vec<Tensor>, Vec<Tensor>)>,
    pending_tokens: std::collections::HashMap<u64, Vec<i32>>,
    pending_targets: std::collections::HashMap<u64, Vec<i32>>,
    /// Backward runs at the stashed weight snapshot (PipeDream stashing).
    use_stash: bool,
    /// Snapshot weights at forward even in no-stash mode (DelayComp
    /// needs the stale view its gradient was computed at).
    stash_weights: bool,
    updates: u64,
    compute_s: f64,
    idle_s: f64,
    losses: Vec<f32>,
    val_losses: Vec<(u32, f32)>,
    /// Validation batches (stage 0 sources tokens, the last stage
    /// re-derives targets from the same deterministic stream).
    val_iter: Option<BatchIter>,
    diverged: bool,
}

impl Worker {
    fn first(&self) -> bool {
        self.k == 0
    }

    fn last(&self) -> bool {
        self.k == self.stages - 1
    }

    fn local_index(&self, name: &str) -> usize {
        self.rt
            .manifest
            .param_index(name)
            .unwrap_or_else(|| panic!("stage {} missing {name}", self.k))
    }

    fn block_params(&self, b: usize, snapshot: &[Tensor]) -> Vec<Tensor> {
        let prefix = format!("b{b}.");
        self.rt
            .manifest
            .params
            .iter()
            .enumerate()
            .filter(|(_, p)| p.name.starts_with(&prefix))
            .map(|(local, _)| snapshot[local].clone())
            .collect()
    }

    fn eval_trigger(&self, mb: u64) -> bool {
        // Replicas stay in parameter lockstep (all-reduced gradients),
        // so one validation pass — replica 0's pipeline — covers all R.
        self.replica == 0
            && self.cfg.eval_every > 0
            && (mb + 1) % self.cfg.eval_every as u64 == 0
    }

    /// Receive the training activation for microbatch `mb`,
    /// transparently relaying any eval forwards that arrive in between.
    /// `None` means the neighbouring stage hung up (early stop).
    fn recv_train(
        &mut self,
        mb: u64,
        rx_fwd: &Receiver<FwdMsg>,
        tx_fwd: Option<&Sender<FwdMsg>>,
    ) -> Result<Option<Vec<f32>>> {
        loop {
            let t0 = Instant::now();
            let msg = match rx_fwd.recv() {
                Ok(m) => m,
                Err(_) => return Ok(None),
            };
            self.idle_s += t0.elapsed().as_secs_f64();
            if msg.eval {
                self.eval_forward(msg.mb, msg.x, tx_fwd)?;
                continue;
            }
            assert_eq!(msg.mb, mb, "stage {}: out-of-order microbatch", self.k);
            return Ok(Some(msg.x));
        }
    }

    /// Forward an activation through this stage's blocks at the
    /// *current* weights (validation path: no stash, no cache).
    fn eval_blocks(&mut self, x0: Vec<f32>) -> Result<Tensor> {
        let mcfg = self.rt.cfg().clone();
        let (b, s, d) = (mcfg.batch, mcfg.seq, mcfg.d_model);
        let t0 = Instant::now();
        let mut x = Tensor::new(vec![b, s, d], x0);
        for &blk in &self.blocks.clone() {
            let bp = self.block_params(blk, &self.params);
            let mut ins: Vec<Value> =
                bp.iter().map(tensor_to_value).collect::<Result<_>>()?;
            ins.push(tensor_to_value(&x)?);
            let outs = self.rt.exec("block_fwd", &ins)?;
            x = value_to_tensor(&outs[0], &[b, s, d])?;
        }
        self.compute_s += t0.elapsed().as_secs_f64();
        Ok(x)
    }

    /// Score a validation activation on the loss-only head executable
    /// (no backward) and record it under step label `mb + 1`. Falls
    /// back to `head_fwdbwd`'s loss output on manifests that predate
    /// `head_loss` (e.g. older PJRT artifact exports).
    fn record_val(&mut self, mb: u64, x: &Tensor, vg: &[i32]) -> Result<()> {
        let mcfg = self.rt.cfg().clone();
        let (b, s) = (mcfg.batch, mcfg.seq);
        let t0 = Instant::now();
        let gf = &self.params[self.local_index("gf")];
        let head = &self.params[self.local_index("head")];
        let ins = [
            tensor_to_value(gf)?,
            tensor_to_value(head)?,
            tensor_to_value(x)?,
            tokens_to_value(vg, b, s)?,
        ];
        let exec_name = if self.rt.has_executable("head_loss") {
            "head_loss"
        } else {
            "head_fwdbwd"
        };
        let outs = self.rt.exec(exec_name, &ins)?;
        self.compute_s += t0.elapsed().as_secs_f64();
        self.val_losses.push((mb as u32 + 1, value_scalar_f32(&outs[0])?));
        Ok(())
    }

    /// Handle an eval activation arriving from upstream: forward through
    /// the blocks, then record the loss (last stage) or pass it on.
    fn eval_forward(
        &mut self,
        mb: u64,
        x0: Vec<f32>,
        tx_fwd: Option<&Sender<FwdMsg>>,
    ) -> Result<()> {
        let x = self.eval_blocks(x0)?;
        if self.last() {
            let (_vt, vg) =
                self.val_iter.as_mut().expect("last stage has a val iter").next_batch();
            self.record_val(mb, &x, &vg)?;
        } else if let Some(tx) = tx_fwd {
            // a dropped receiver means downstream already stopped; the
            // training path notices on its own send/recv
            tx.send(FwdMsg { mb, x: x.data, eval: true }).ok();
        }
        Ok(())
    }

    /// Stage 0 (or the single stage of P=1): source one validation
    /// forward after the update of microbatch `mb`.
    fn source_eval(&mut self, mb: u64, tx_fwd: Option<&Sender<FwdMsg>>) -> Result<()> {
        debug_assert!(self.first());
        let (vt, vg) =
            self.val_iter.as_mut().expect("first stage has a val iter").next_batch();
        let mcfg = self.rt.cfg().clone();
        let (b, s) = (mcfg.batch, mcfg.seq);
        let t0 = Instant::now();
        let te = &self.params[self.local_index("tok_emb")];
        let pe = &self.params[self.local_index("pos_emb")];
        let outs = self.rt.exec(
            "embed_fwd",
            &[
                tensor_to_value(te)?,
                tensor_to_value(pe)?,
                tokens_to_value(&vt, b, s)?,
            ],
        )?;
        self.compute_s += t0.elapsed().as_secs_f64();
        let x = self.eval_blocks(outs[0].to_f32()?)?;
        if self.last() {
            // P = 1: post-update weights + shared val stream — exactly
            // the simulator's evaluation
            self.record_val(mb, &x, &vg)?;
        } else if let Some(tx) = tx_fwd {
            tx.send(FwdMsg { mb, x: x.data, eval: true }).ok();
        }
        Ok(())
    }

    /// After the training loop: keep relaying/recording eval forwards
    /// until upstream hangs up (covers an eval triggered by the final
    /// microbatch, still in flight when the loop ends).
    fn drain_evals(
        &mut self,
        rx_fwd: Option<&Receiver<FwdMsg>>,
        tx_fwd: Option<&Sender<FwdMsg>>,
    ) -> Result<()> {
        if self.cfg.eval_every == 0 {
            return Ok(());
        }
        if let Some(rx) = rx_fwd {
            while let Ok(msg) = rx.recv() {
                if msg.eval {
                    self.eval_forward(msg.mb, msg.x, tx_fwd)?;
                }
            }
        }
        Ok(())
    }

    /// Forward one microbatch through this stage; returns the output
    /// activation (to send or, on the last stage, to feed the head), or
    /// `None` when a neighbouring stage already stopped.
    fn forward(
        &mut self,
        mb: u64,
        data: &mut BatchIter,
        rx_fwd: Option<&Receiver<FwdMsg>>,
        tx_fwd: Option<&Sender<FwdMsg>>,
    ) -> Result<Option<Tensor>> {
        let mcfg = self.rt.cfg().clone();
        let (b, s, d) = (mcfg.batch, mcfg.seq, mcfg.d_model);
        let x0: Vec<f32> = if self.first() {
            let (toks, tgts) = data.next_batch();
            if self.last() {
                self.pending_targets.insert(mb, tgts);
            }
            let t0 = Instant::now();
            let te = &self.params[self.local_index("tok_emb")];
            let pe = &self.params[self.local_index("pos_emb")];
            let outs = self.rt.exec(
                "embed_fwd",
                &[
                    tensor_to_value(te)?,
                    tensor_to_value(pe)?,
                    tokens_to_value(&toks, b, s)?,
                ],
            )?;
            self.compute_s += t0.elapsed().as_secs_f64();
            self.pending_tokens.insert(mb, toks);
            outs[0].to_f32()?
        } else {
            if self.last() {
                // last stage needs this microbatch's targets; re-derive
                // the deterministic batch stream locally.
                let (_toks, tgts) = data.next_batch();
                self.pending_targets.insert(mb, tgts);
            }
            match self.recv_train(
                mb,
                rx_fwd.expect("non-first stage has rx_fwd"),
                tx_fwd,
            )? {
                Some(x) => x,
                None => return Ok(None),
            }
        };

        let t0 = Instant::now();
        let snapshot = self.params.clone();
        let mut x = Tensor::new(vec![b, s, d], x0);
        let mut block_inputs = Vec::with_capacity(self.blocks.len());
        for &blk in &self.blocks.clone() {
            block_inputs.push(x.clone());
            let bp = self.block_params(blk, &snapshot);
            let mut ins: Vec<Value> =
                bp.iter().map(tensor_to_value).collect::<Result<_>>()?;
            ins.push(tensor_to_value(&x)?);
            let outs = self.rt.exec("block_fwd", &ins)?;
            x = value_to_tensor(&outs[0], &[b, s, d])?;
        }
        self.compute_s += t0.elapsed().as_secs_f64();
        let stashed = if self.stash_weights { snapshot } else { Vec::new() };
        self.stash.push_back((mb, stashed, block_inputs));
        Ok(Some(x))
    }

    /// Backward for microbatch mb. On the last stage, `x_out` is the
    /// forward output and the head provides loss + dx; otherwise dx
    /// comes from `rx_bwd`. Returns `false` when the run should stop
    /// (divergence detected, or a neighbouring stage hung up).
    fn backward(
        &mut self,
        mb: u64,
        x_out: Option<Tensor>,
        rx_bwd: Option<&Receiver<BwdMsg>>,
        tx_bwd: Option<&Sender<BwdMsg>>,
    ) -> Result<bool> {
        let mcfg = self.rt.cfg().clone();
        let (b, s, d) = (mcfg.batch, mcfg.seq, mcfg.d_model);
        let pos = self
            .stash
            .iter()
            .position(|(m, _, _)| *m == mb)
            .ok_or_else(|| anyhow!("stage {}: no stash for mb {mb}", self.k))?;
        let (_, snapshot, block_inputs) = self.stash.remove(pos).unwrap();
        let current_weights;
        let weights: &[Tensor] = if self.use_stash {
            &snapshot
        } else {
            current_weights = self.params.clone();
            &current_weights
        };

        let mut grads: Vec<Tensor> =
            self.params.iter().map(|p| Tensor::zeros(&p.shape)).collect();

        // ---- obtain dx at the stage output ----
        let mut dx = if self.last() {
            let tgts = self.pending_targets.remove(&mb).expect("targets");
            let x = x_out.expect("last stage forwards its own x");
            let t0 = Instant::now();
            let gf = &weights[self.local_index("gf")];
            let head = &weights[self.local_index("head")];
            let outs = self.rt.exec(
                "head_fwdbwd",
                &[
                    tensor_to_value(gf)?,
                    tensor_to_value(head)?,
                    tensor_to_value(&x)?,
                    tokens_to_value(&tgts, b, s)?,
                ],
            )?;
            self.compute_s += t0.elapsed().as_secs_f64();
            let loss = value_scalar_f32(&outs[0])?;
            if !loss.is_finite() {
                // mirror train_sim: don't record the loss, skip the
                // update, stop the run
                self.diverged = true;
                return Ok(false);
            }
            self.losses.push(loss);
            let i_gf = self.local_index("gf");
            let i_head = self.local_index("head");
            let gf_shape = self.params[i_gf].shape.clone();
            let head_shape = self.params[i_head].shape.clone();
            grads[i_gf] = value_to_tensor(&outs[2], &gf_shape)?;
            grads[i_head] = value_to_tensor(&outs[3], &head_shape)?;
            value_to_tensor(&outs[1], &[b, s, d])?
        } else {
            let t0 = Instant::now();
            let msg = match rx_bwd.expect("non-last stage has rx_bwd").recv() {
                Ok(m) => m,
                Err(_) => return Ok(false),
            };
            self.idle_s += t0.elapsed().as_secs_f64();
            assert_eq!(msg.mb, mb, "stage {}: out-of-order backward", self.k);
            Tensor::new(vec![b, s, d], msg.dx)
        };

        // ---- backward through this stage's blocks ----
        let t0 = Instant::now();
        for (bi, &blk) in self.blocks.clone().iter().enumerate().rev() {
            let bp = self.block_params(blk, weights);
            let mut ins: Vec<Value> =
                bp.iter().map(tensor_to_value).collect::<Result<_>>()?;
            ins.push(tensor_to_value(&block_inputs[bi])?);
            ins.push(tensor_to_value(&dx)?);
            let outs = self.rt.exec("block_bwd", &ins)?;
            dx = value_to_tensor(&outs[0], &[b, s, d])?;
            let prefix = format!("b{blk}.");
            let mut gi = 1;
            for local in 0..self.params.len() {
                if self.rt.manifest.params[local].name.starts_with(&prefix) {
                    let shape = self.params[local].shape.clone();
                    grads[local] = value_to_tensor(&outs[gi], &shape)?;
                    gi += 1;
                }
            }
        }
        self.compute_s += t0.elapsed().as_secs_f64();

        if let Some(tx) = tx_bwd {
            if tx.send(BwdMsg { mb, dx: dx.data.clone() }).is_err() {
                return Ok(false);
            }
        }

        // ---- embedding backward on stage 0 ----
        if self.first() {
            let toks = self.pending_tokens.remove(&mb).expect("tokens");
            let t0e = Instant::now();
            let outs = self.rt.exec(
                "embed_bwd",
                &[tokens_to_value(&toks, b, s)?, tensor_to_value(&dx)?],
            )?;
            self.compute_s += t0e.elapsed().as_secs_f64();
            let i_te = self.local_index("tok_emb");
            let i_pe = self.local_index("pos_emb");
            let te_shape = self.params[i_te].shape.clone();
            let pe_shape = self.params[i_pe].shape.clone();
            grads[i_te] = value_to_tensor(&outs[0], &te_shape)?;
            grads[i_pe] = value_to_tensor(&outs[1], &pe_shape)?;
        }

        // ---- data-parallel all-reduce (averaging) barrier across the
        //      replicas of this stage, then per-stage clip + the
        //      method's real update (async semantics: immediately after
        //      this stage's backward). R = 1 is a passthrough; a peer
        //      replica hanging up (early stop / divergence) winds this
        //      replica down like a closed activation channel. Time
        //      spent blocked here is a synchronization stall and counts
        //      as idle, keeping bubble_frac honest for DP runs. ----
        let t_red = Instant::now();
        let reduced = self.dp.all_reduce(grads);
        self.idle_s += t_red.elapsed().as_secs_f64();
        let mut grads = match reduced {
            Ok(g) => g,
            Err(_) => return Ok(false),
        };
        crate::optim::clip_global_norm(&mut grads, self.cfg.grad_clip);
        self.updates += 1;
        let needs_stale = matches!(self.cfg.method, Method::DelayComp { .. });
        let ctx = StepCtx {
            t: self.updates,
            lr: self.cfg.lr_at(self.updates as u32),
            cfg: &self.cfg,
            part: &self.part,
            // the 1F1B stash is exactly the weight view the gradient
            // was computed at — DelayComp's Taylor reference
            stale: if needs_stale { Some(&snapshot) } else { None },
            rt: &self.rt,
        };
        self.opt.step(&ctx, &mut self.params, &grads)?;
        Ok(true)
    }

    fn report(self) -> StageReport {
        StageReport {
            replica: self.replica,
            stage: self.k,
            losses: self.losses,
            val_losses: self.val_losses,
            compute_s: self.compute_s,
            idle_s: self.idle_s,
            updates: self.updates,
            diverged: self.diverged,
            dispatches: self.rt.total_dispatches(),
            state_elems: self.opt.state_elems(),
        }
    }
}

fn run_stage(
    mut w: Worker,
    mut data: BatchIter,
    rx_fwd: Option<Receiver<FwdMsg>>,
    tx_fwd: Option<Sender<FwdMsg>>,
    rx_bwd: Option<Receiver<BwdMsg>>,
    tx_bwd: Option<Sender<BwdMsg>>,
    n_micro: u64,
) -> Result<StageReport> {
    let warmup = (w.stages - 1 - w.k) as u64;
    if w.last() {
        // fused fwd+bwd per microbatch (no warmup, delay 0)
        for mb in 0..n_micro {
            let x = match w.forward(mb, &mut data, rx_fwd.as_ref(), tx_fwd.as_ref())? {
                Some(x) => x,
                None => return Ok(w.report()),
            };
            if !w.backward(mb, Some(x), None, tx_bwd.as_ref())? {
                return Ok(w.report());
            }
            if w.first() && w.eval_trigger(mb) {
                w.source_eval(mb, tx_fwd.as_ref())?; // P = 1: local eval
            }
        }
        w.drain_evals(rx_fwd.as_ref(), tx_fwd.as_ref())?;
        return Ok(w.report());
    }
    let mut next_fwd = 0u64;
    while next_fwd < warmup.min(n_micro) {
        let x = match w.forward(next_fwd, &mut data, rx_fwd.as_ref(), tx_fwd.as_ref())?
        {
            Some(x) => x,
            None => return Ok(w.report()),
        };
        let sent = tx_fwd
            .as_ref()
            .unwrap()
            .send(FwdMsg { mb: next_fwd, x: x.data, eval: false });
        if sent.is_err() {
            return Ok(w.report());
        }
        next_fwd += 1;
    }
    for mb_b in 0..n_micro {
        if next_fwd < n_micro {
            let x = match w.forward(
                next_fwd,
                &mut data,
                rx_fwd.as_ref(),
                tx_fwd.as_ref(),
            )? {
                Some(x) => x,
                None => return Ok(w.report()),
            };
            let sent = tx_fwd
                .as_ref()
                .unwrap()
                .send(FwdMsg { mb: next_fwd, x: x.data, eval: false });
            if sent.is_err() {
                return Ok(w.report());
            }
            next_fwd += 1;
        }
        if !w.backward(mb_b, None, rx_bwd.as_ref(), tx_bwd.as_ref())? {
            return Ok(w.report());
        }
        if w.first() && w.eval_trigger(mb_b) {
            w.source_eval(mb_b, tx_fwd.as_ref())?;
        }
    }
    w.drain_evals(rx_fwd.as_ref(), tx_fwd.as_ref())?;
    Ok(w.report())
}

/// Train with the real threaded pipeline. `cfg.steps` = microbatches
/// per replica (= optimizer steps).
///
/// Supports every [`Method`] (each stage builds its own optimizer via
/// [`optim::build`] over a stage-local manifest) on dense *and* MoE
/// configs, and data parallelism (`cfg.replicas = R`): R x P stage
/// threads, one full pipeline per replica over a disjoint data shard
/// (`data::replica_stream`), with a channel-based all-reduce across
/// the replicas of each stage at every optimizer step (`pipeline::dp`).
/// Per-replica 1F1B stashes stay replica-local; the averaged gradient
/// feeds every replica's optimizer identically, so replicas remain in
/// parameter lockstep. `StashMode::Predict` is simulator-only and
/// errors loudly.
pub fn train_engine(artifacts_dir: PathBuf, cfg: &TrainCfg) -> Result<RunResult> {
    let man0 = crate::runtime::Manifest::resolve(&artifacts_dir)?;
    if cfg.stash == StashMode::Predict {
        anyhow::bail!(
            "engine does not implement StashMode::Predict (PipeMare weight \
             prediction is simulator-only); use train_sim or StashMode::Stash/NoStash"
        );
    }
    let part = StagePartition::new(&man0, cfg.stages);
    let init = init_params(&man0, cfg.seed);
    let p = cfg.stages;
    let r_count = cfg.dp_replicas();
    let n_micro = cfg.steps as u64;
    let mcfg = man0.cfg.clone();

    // one all-reduce group per stage, one handle per replica
    let mut dp_groups: Vec<Vec<Option<dp::Reducer>>> = (0..p)
        .map(|_| dp::group(r_count).into_iter().map(Some).collect())
        .collect();

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for rep in 0..r_count {
        // channels between consecutive stages of this replica's chain
        let mut fwd_txs = Vec::new();
        let mut fwd_rxs = vec![None];
        let mut bwd_txs = vec![None];
        let mut bwd_rxs = Vec::new();
        for _ in 0..p.saturating_sub(1) {
            let (ftx, frx) = channel::<FwdMsg>();
            fwd_txs.push(Some(ftx));
            fwd_rxs.push(Some(frx));
            let (btx, brx) = channel::<BwdMsg>();
            bwd_txs.push(Some(btx));
            bwd_rxs.push(Some(brx));
        }
        fwd_txs.push(None);
        bwd_rxs.push(None);

        for k in (0..p).rev() {
            let dir = artifacts_dir.clone();
            let cfg_k = cfg.clone();
            let keep = part.params_of_stage(k);
            let init_k: Vec<Tensor> = keep.iter().map(|&i| init[i].clone()).collect();
            let rx_fwd = fwd_rxs[k].take();
            let tx_fwd = fwd_txs[k].take();
            let rx_bwd = bwd_rxs[k].take();
            let tx_bwd = bwd_txs[k].take();
            let dp_handle = dp_groups[k][rep].take().unwrap();
            let corpus = Corpus::new(mcfg.vocab, cfg.seed ^ 0xDA7A);
            let data = BatchIter::new(
                corpus.clone(),
                mcfg.batch,
                mcfg.seq,
                replica_stream(TRAIN_STREAM, rep),
            );
            // replica 0's stage 0 sources validation tokens, its last
            // stage re-derives the targets from the same stream (P = 1:
            // one iterator, both roles); other replicas skip validation
            let val_iter =
                if cfg.eval_every > 0 && rep == 0 && (k == 0 || k == p - 1) {
                    Some(BatchIter::new(
                        corpus,
                        mcfg.batch,
                        mcfg.seq,
                        super::VAL_STREAM,
                    ))
                } else {
                    None
                };
            handles.push((
                rep,
                k,
                std::thread::spawn(move || -> Result<StageReport> {
                    let rt = Runtime::open_restricted(&dir, &keep)?;
                    let part_k = StagePartition::new(&rt.manifest, cfg_k.stages);
                    let opt = optim::build(&cfg_k.method, &rt, &cfg_k);
                    let use_stash = cfg_k.stash != StashMode::NoStash;
                    let stash_weights =
                        use_stash || matches!(cfg_k.method, Method::DelayComp { .. });
                    let worker = Worker {
                        k,
                        stages: cfg_k.stages,
                        replica: rep,
                        dp: dp_handle,
                        blocks: part_k.blocks_of_stage[k].clone(),
                        params: init_k,
                        opt,
                        part: part_k,
                        cfg: cfg_k,
                        stash: Default::default(),
                        pending_tokens: Default::default(),
                        pending_targets: Default::default(),
                        use_stash,
                        stash_weights,
                        updates: 0,
                        compute_s: 0.0,
                        idle_s: 0.0,
                        losses: Vec::new(),
                        val_losses: Vec::new(),
                        val_iter,
                        diverged: false,
                        rt,
                    };
                    run_stage(worker, data, rx_fwd, tx_fwd, rx_bwd, tx_bwd, n_micro)
                }),
            ));
        }
    }

    let mut result = RunResult::new(&cfg.method.name(), p);
    result.replicas = r_count;
    result.param_count = man0.total_params();
    let mut total_compute = 0.0;
    let mut total_idle = 0.0;
    let mut rep_losses: Vec<Vec<f32>> = vec![Vec::new(); r_count];
    for (rep, k, h) in handles {
        let sr = h
            .join()
            .map_err(|_| anyhow!("replica {rep} stage {k} panicked"))??;
        total_compute += sr.compute_s;
        total_idle += sr.idle_s;
        result.dispatches += sr.dispatches;
        result.optimizer_state_elems += sr.state_elems;
        result.diverged |= sr.diverged;
        result.stage_counters.push(StageCounter {
            replica: rep,
            stage: k,
            dispatches: sr.dispatches,
            optimizer_state_elems: sr.state_elems,
            updates: sr.updates,
        });
        if sr.stage == p - 1 {
            if rep == 0 {
                result.val_losses = sr.val_losses;
            }
            rep_losses[rep] = sr.losses;
        }
    }
    result.stage_counters.sort_by_key(|c| (c.replica, c.stage));
    // Per-step replica mean, like the simulator (truncated to the
    // shortest replica on early stop). R = 1 passes losses through.
    let n_steps = rep_losses.iter().map(|l| l.len()).min().unwrap_or(0);
    result.losses = (0..n_steps)
        .map(|i| {
            let at_step: Vec<f32> = rep_losses.iter().map(|l| l[i]).collect();
            dp::mean_loss(&at_step)
        })
        .collect();
    result.wall_secs = t0.elapsed().as_secs_f64();
    result.bubble_frac = if total_compute + total_idle > 0.0 {
        total_idle / (total_compute + total_idle)
    } else {
        0.0
    };
    result.tokens_per_sec = (result.losses.len() as f64
        * r_count as f64
        * mcfg.batch as f64
        * mcfg.seq as f64)
        / result.wall_secs;
    Ok(result)
}

/// Analytic schedule model (Fig. 1): bubble fraction of synchronous
/// GPipe vs asynchronous PipeDream for P stages and M in-flight
/// microbatches per step, with unit per-stage fwd+bwd cost.
pub fn sync_bubble_fraction(p: usize, m: usize) -> f64 {
    (p as f64 - 1.0) / (m as f64 + p as f64 - 1.0)
}

pub fn async_bubble_fraction_steady() -> f64 {
    0.0 // PipeDream's steady state keeps every stage busy
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_bubbles_shrink_with_microbatches() {
        assert!(sync_bubble_fraction(4, 1) > sync_bubble_fraction(4, 16));
        assert!((sync_bubble_fraction(4, 4) - 3.0 / 7.0).abs() < 1e-12);
        assert!(sync_bubble_fraction(1, 8) == 0.0);
        assert_eq!(async_bubble_fraction_steady(), 0.0);
    }

    #[test]
    fn sync_bubbles_grow_with_depth() {
        assert!(sync_bubble_fraction(32, 8) > sync_bubble_fraction(4, 8));
    }

    #[test]
    fn engine_rejects_predict_stash_mode() {
        // silent fallback would corrupt experiments — reject loudly
        let cfg = TrainCfg {
            stash: StashMode::Predict,
            stages: 2,
            steps: 4,
            ..Default::default()
        };
        let err = train_engine(PathBuf::from("artifacts/micro"), &cfg)
            .unwrap_err()
            .to_string();
        assert!(err.contains("Predict"), "{err}");
    }
}
