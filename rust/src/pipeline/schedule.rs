//! Pluggable pipeline schedules.
//!
//! A [`Schedule`] owns everything the execution layers previously
//! hard-coded for 1F1B: how the model is cut into *chunks* (parameter
//! partitions placed on workers), the per-worker **action stream**
//! (warmup counts, fwd/bwd interleaving, update placement), how many
//! microbatches feed one optimizer update, the per-stage gradient
//! **delay profile** the staleness model sees, and the analytic
//! **bubble fraction** the conformance tests pin the measured schedule
//! against.
//!
//! Four schedules (paper Fig. 1 premise + PAPERS.md related work):
//!
//! * [`Gpipe`] — synchronous fill/drain: M forwards, M backwards, one
//!   update. Delay 0 everywhere, bubble `(P-1)/(M+P-1)`.
//! * [`OneFOneB`] — asynchronous PipeDream 1F1B, the repo's original
//!   schedule: stage k warms up with `P-1-k` forwards then alternates
//!   fwd/bwd with an update per microbatch. Delay `P-1-k`, and the
//!   same fill/drain bubble `(P-1)/(M+P-1)` over a finite run of M
//!   microbatches (steady state itself is bubble-free).
//! * [`Interleaved`] — synchronous interleaved 1F1B (Megatron): each
//!   worker hosts V *virtual* chunk-stages (chunk c on worker c mod P,
//!   parameters re-restricted per chunk), so the fill shrinks to
//!   `(P-1)/(M·V+P-1)`. Delay 0.
//! * [`Amdp`] — asynchronous bidirectional schedule (AMDP / Chimera
//!   family): two counter-flowing 1F1B streams over two full weight
//!   copies; worker k hosts stage k of the "down" stream and stage
//!   P-1-k of the "up" stream, and each update averages one microbatch
//!   per direction across the paired copies. Delay `P-1-k` (in update
//!   units), requires even P so no worker pairs with itself inside a
//!   blocking all-reduce.
//!
//! The module also ships a deterministic **virtual-clock executor**
//! ([`simulate`]): unit-cost fwd/bwd with real dependency tracking.
//! It validates well-formedness (every microbatch exactly one fwd+bwd
//! per chunk, bwd never before its fwd, stash bounded), measures the
//! realized bubble fraction and per-chunk gradient delays, and is what
//! the schedule-conformance tests (and the engine's deterministic
//! `bubble_frac_model`) run against — wall-clock bubble measurements
//! stay as a separate, noisier metric.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

pub use crate::config::ScheduleKind;

/// One chunk: a parameter partition placed on a worker at a position
/// in a stream's forward order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkSpec {
    /// Chunk id (index into [`Schedule::chunks`]).
    pub id: usize,
    /// Worker (OS thread) hosting this chunk.
    pub worker: usize,
    /// Parameter partition index (stage-local manifest). Distinct
    /// chunks may share a `part` (AMDP's two copies of each stage).
    pub part: usize,
    /// Stream this chunk serves (0 = down; AMDP adds 1 = up).
    pub stream: usize,
    /// Position in the stream's forward order (0 = embeddings side).
    pub seq: usize,
    /// Declared steady-state gradient delay, in optimizer updates.
    pub delay: u32,
}

/// One entry of a worker's action stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    /// Forward microbatch `mb` through chunk `chunk`.
    Fwd { mb: u64, chunk: usize },
    /// Backward microbatch `mb` through chunk `chunk`.
    Bwd { mb: u64, chunk: usize },
    /// Apply chunk `chunk`'s accumulated gradient (mean over the
    /// microbatches accumulated since its previous update).
    Update { chunk: usize },
}

/// A pipeline schedule: chunk layout + per-worker action streams +
/// the analytic delay/bubble model they realize.
pub trait Schedule: Send + Sync {
    fn kind(&self) -> ScheduleKind;

    fn name(&self) -> String {
        self.kind().name()
    }

    /// Number of parameter partitions (`P`, or `P·V` interleaved).
    fn n_parts(&self, p: usize) -> usize {
        p
    }

    /// Number of counter-flowing streams (1, or 2 for AMDP). Global
    /// microbatch `mb` belongs to stream `mb % n_streams()`.
    fn n_streams(&self) -> usize {
        1
    }

    /// Chunk layout for P workers.
    fn chunks(&self, p: usize) -> Vec<ChunkSpec>;

    /// Effective in-flight microbatch count M from the config knob
    /// (0 = auto). Schedules with a fixed per-update arity ignore it.
    fn effective_m(&self, p: usize, cfg_m: usize) -> usize;

    /// Microbatches consumed by one optimizer update.
    fn micro_per_update(&self, p: usize, cfg_m: usize) -> usize;

    /// The action stream worker `w` executes for `n_updates` optimizer
    /// updates with `m = effective_m(...)` microbatches in flight.
    fn worker_actions(&self, p: usize, m: usize, n_updates: u64, w: usize)
        -> Vec<Action>;

    /// Declared gradient delay per model stage under the P-way
    /// partition (len P), in optimizer updates — what the simulator's
    /// stash rings and the delay-aware optimizers consume.
    fn delay_profile(&self, p: usize) -> Vec<u32>;

    /// Analytic bubble fraction, idle/(idle+busy) over all workers,
    /// for M in-flight microbatches (for `1f1b`/`amdp`, M = the total
    /// microbatch count of the finite run).
    fn bubble_frac(&self, p: usize, m: usize) -> f64;

    /// Declared maximum in-flight forward stash depth per chunk.
    fn max_stash(&self, p: usize, m: usize) -> usize;
}

/// Build the schedule implementation for a config kind.
pub fn build(kind: ScheduleKind) -> Box<dyn Schedule> {
    match kind {
        ScheduleKind::Gpipe => Box::new(Gpipe),
        ScheduleKind::OneFOneB => Box::new(OneFOneB),
        ScheduleKind::Interleaved { v } => Box::new(Interleaved { v }),
        ScheduleKind::Amdp => Box::new(Amdp),
    }
}

/// Linear single-stream chunk layout: chunk k = stage k on worker k.
fn linear_chunks(p: usize, delay_of: impl Fn(usize) -> u32) -> Vec<ChunkSpec> {
    (0..p)
        .map(|k| ChunkSpec {
            id: k,
            worker: k,
            part: k,
            stream: 0,
            seq: k,
            delay: delay_of(k),
        })
        .collect()
}

/// The per-chunk 1F1B pattern at stream depth `d`, seq position `q`,
/// over `n` stream-local microbatches: `d-1-q` warmup forwards, then
/// strict fwd-before-bwd alternation with an update per backward —
/// exactly the stream the engine's original hard-coded loop executed.
/// `mb_of` maps a stream-local index to its global microbatch id.
fn one_f_one_b_chunk_stream(
    d: usize,
    q: usize,
    n: u64,
    chunk: usize,
    mb_of: impl Fn(u64) -> u64,
) -> Vec<Action> {
    let warmup = ((d - 1 - q) as u64).min(n);
    let mut out = Vec::with_capacity((2 * n + n) as usize);
    for i in 0..warmup {
        out.push(Action::Fwd { mb: mb_of(i), chunk });
    }
    for i in 0..n {
        if warmup + i < n {
            out.push(Action::Fwd { mb: mb_of(warmup + i), chunk });
        }
        out.push(Action::Bwd { mb: mb_of(i), chunk });
        out.push(Action::Update { chunk });
    }
    out
}

// ---------------------------------------------------------------------------
// GPipe
// ---------------------------------------------------------------------------

pub struct Gpipe;

impl Schedule for Gpipe {
    fn kind(&self) -> ScheduleKind {
        ScheduleKind::Gpipe
    }

    fn chunks(&self, p: usize) -> Vec<ChunkSpec> {
        linear_chunks(p, |_| 0)
    }

    fn effective_m(&self, p: usize, cfg_m: usize) -> usize {
        if cfg_m == 0 { p } else { cfg_m }
    }

    fn micro_per_update(&self, p: usize, cfg_m: usize) -> usize {
        self.effective_m(p, cfg_m)
    }

    fn worker_actions(&self, p: usize, m: usize, n_updates: u64, w: usize)
        -> Vec<Action> {
        let m = self.effective_m(p, m) as u64;
        let mut out = Vec::new();
        for u in 0..n_updates {
            let base = u * m;
            for j in 0..m {
                out.push(Action::Fwd { mb: base + j, chunk: w });
            }
            for j in 0..m {
                out.push(Action::Bwd { mb: base + j, chunk: w });
            }
            out.push(Action::Update { chunk: w });
        }
        out
    }

    fn delay_profile(&self, p: usize) -> Vec<u32> {
        vec![0; p]
    }

    fn bubble_frac(&self, p: usize, m: usize) -> f64 {
        gpipe_bubble_fraction(p, self.effective_m(p, m))
    }

    fn max_stash(&self, p: usize, m: usize) -> usize {
        self.effective_m(p, m)
    }
}

// ---------------------------------------------------------------------------
// 1F1B (PipeDream) — the original hard-coded schedule
// ---------------------------------------------------------------------------

pub struct OneFOneB;

impl Schedule for OneFOneB {
    fn kind(&self) -> ScheduleKind {
        ScheduleKind::OneFOneB
    }

    fn chunks(&self, p: usize) -> Vec<ChunkSpec> {
        linear_chunks(p, |k| (p - 1 - k) as u32)
    }

    fn effective_m(&self, _p: usize, _cfg_m: usize) -> usize {
        1
    }

    fn micro_per_update(&self, _p: usize, _cfg_m: usize) -> usize {
        1
    }

    fn worker_actions(&self, p: usize, _m: usize, n_updates: u64, w: usize)
        -> Vec<Action> {
        one_f_one_b_chunk_stream(p, w, n_updates, w, |i| i)
    }

    fn delay_profile(&self, p: usize) -> Vec<u32> {
        (0..p).map(|k| (p - 1 - k) as u32).collect()
    }

    fn bubble_frac(&self, p: usize, m: usize) -> f64 {
        // Finite-run fill/drain bubble; the steady state itself is
        // bubble-free (`async_bubble_fraction_steady`).
        gpipe_bubble_fraction(p, m)
    }

    fn max_stash(&self, p: usize, _m: usize) -> usize {
        p // stage k holds at most P-k in-flight forwards
    }
}

// ---------------------------------------------------------------------------
// Interleaved 1F1B (Megatron virtual stages), synchronous variant
// ---------------------------------------------------------------------------

pub struct Interleaved {
    pub v: usize,
}

impl Schedule for Interleaved {
    fn kind(&self) -> ScheduleKind {
        ScheduleKind::Interleaved { v: self.v }
    }

    fn n_parts(&self, p: usize) -> usize {
        p * self.v
    }

    fn chunks(&self, p: usize) -> Vec<ChunkSpec> {
        (0..p * self.v)
            .map(|c| ChunkSpec {
                id: c,
                worker: c % p,
                part: c,
                stream: 0,
                seq: c,
                delay: 0,
            })
            .collect()
    }

    fn effective_m(&self, p: usize, cfg_m: usize) -> usize {
        if cfg_m == 0 { p } else { cfg_m }
    }

    fn micro_per_update(&self, p: usize, cfg_m: usize) -> usize {
        self.effective_m(p, cfg_m)
    }

    fn worker_actions(&self, p: usize, m: usize, n_updates: u64, w: usize)
        -> Vec<Action> {
        let m = self.effective_m(p, m) as u64;
        let mut out = Vec::new();
        for u in 0..n_updates {
            let base = u * m;
            // forward all M microbatches through each chunk level in
            // turn (level lv = chunk w + lv·P), then backward in
            // reverse level order — a dense interleaved wave whose
            // fill is P-1 chunk-slots instead of P-1 microbatch-slots
            for lv in 0..self.v {
                for j in 0..m {
                    out.push(Action::Fwd { mb: base + j, chunk: w + lv * p });
                }
            }
            for lv in (0..self.v).rev() {
                for j in 0..m {
                    out.push(Action::Bwd { mb: base + j, chunk: w + lv * p });
                }
            }
            for lv in 0..self.v {
                out.push(Action::Update { chunk: w + lv * p });
            }
        }
        out
    }

    fn delay_profile(&self, p: usize) -> Vec<u32> {
        vec![0; p]
    }

    fn bubble_frac(&self, p: usize, m: usize) -> f64 {
        interleaved_bubble_fraction_exact(p, self.effective_m(p, m), self.v)
    }

    fn max_stash(&self, p: usize, m: usize) -> usize {
        self.effective_m(p, m)
    }
}

// ---------------------------------------------------------------------------
// AMDP — asynchronous bidirectional (two counter-flowing 1F1B streams)
// ---------------------------------------------------------------------------

pub struct Amdp;

impl Amdp {
    /// The greedy worker merge below is deterministic; both copies of
    /// stage s sit at the same stream depth, so their paired updates
    /// align and the blocking cross-copy all-reduce cannot cycle.
    fn merged_actions(&self, p: usize, n_updates: u64) -> Vec<Vec<Action>> {
        let chunks = self.chunks(p);
        let streams: Vec<Vec<Action>> = chunks
            .iter()
            .map(|c| {
                let stream = c.stream as u64;
                one_f_one_b_chunk_stream(p, c.seq, n_updates, c.id, move |i| {
                    2 * i + stream
                })
            })
            .collect();
        merge_chunk_streams(p, &chunks, &streams)
            .expect("amdp merge is deadlock-free for even P")
    }
}

impl Schedule for Amdp {
    fn kind(&self) -> ScheduleKind {
        ScheduleKind::Amdp
    }

    fn n_streams(&self) -> usize {
        2
    }

    fn chunks(&self, p: usize) -> Vec<ChunkSpec> {
        // down stream: stage s on worker s; up stream: stage s on
        // worker p-1-s (both copies of stage s share part s and sit at
        // seq s of their stream)
        let mut out = linear_chunks(p, |k| (p - 1 - k) as u32);
        for s in 0..p {
            out.push(ChunkSpec {
                id: p + s,
                worker: p - 1 - s,
                part: s,
                stream: 1,
                seq: s,
                delay: (p - 1 - s) as u32,
            });
        }
        out
    }

    fn effective_m(&self, _p: usize, _cfg_m: usize) -> usize {
        2
    }

    fn micro_per_update(&self, _p: usize, _cfg_m: usize) -> usize {
        2
    }

    fn worker_actions(&self, p: usize, _m: usize, n_updates: u64, w: usize)
        -> Vec<Action> {
        self.merged_actions(p, n_updates)[w].clone()
    }

    fn delay_profile(&self, p: usize) -> Vec<u32> {
        (0..p).map(|k| (p - 1 - k) as u32).collect()
    }

    fn bubble_frac(&self, p: usize, m: usize) -> f64 {
        // The merged bidirectional stream has no simple closed form;
        // the declared analytic value is the exact unit-cost
        // virtual-clock bubble of the schedule's own action streams
        // (deterministic, data-independent). [`amdp_bubble_fraction`]
        // stays as the closed-form estimate / odd-P fallback.
        if p >= 2 && p % 2 == 0 && m >= 2 {
            if let Ok(stats) = simulate(self, p, 0, (m as u64) / 2) {
                return stats.bubble;
            }
        }
        amdp_bubble_fraction(p, m)
    }

    fn max_stash(&self, p: usize, _m: usize) -> usize {
        p // per chunk; a worker's two chunks stash ≤ P+1 together
    }
}

/// Greedy deterministic list-scheduling merge of per-chunk logical
/// streams into per-worker action sequences, under unit fwd/bwd costs
/// and the real dependency rules (including cross-copy update
/// pairing). Used by AMDP, whose two streams per worker have no
/// closed-form interleaving; the produced order is feasible in virtual
/// time, which makes the engine's blocking execution of it
/// deadlock-free.
/// Per chunk, per update index u: how many of the chunk's backwards
/// precede update u in its logical stream (the last of them is the
/// backward "feeding" that update).
fn bwds_before_updates(stream: &[Action]) -> Vec<usize> {
    let mut out = Vec::new();
    let mut bwds = 0usize;
    for a in stream {
        match a {
            Action::Bwd { .. } => bwds += 1,
            Action::Update { .. } => out.push(bwds),
            Action::Fwd { .. } => {}
        }
    }
    out
}

fn merge_chunk_streams(
    p: usize,
    chunks: &[ChunkSpec],
    streams: &[Vec<Action>],
) -> Result<Vec<Vec<Action>>> {
    let total: usize = streams.iter().map(|s| s.len()).sum();
    let feeds: Vec<Vec<usize>> =
        streams.iter().map(|s| bwds_before_updates(s)).collect();
    let by_pos: HashMap<(usize, usize), usize> =
        chunks.iter().map(|c| ((c.stream, c.seq), c.id)).collect();
    let depth: HashMap<usize, usize> = {
        let mut d = HashMap::new();
        for c in chunks {
            let e = d.entry(c.stream).or_insert(0usize);
            *e = (*e).max(c.seq + 1);
        }
        d
    };
    let mut cursors = vec![0usize; chunks.len()];
    let mut fwd_end: HashMap<(usize, u64), u64> = HashMap::new();
    let mut bwd_end: HashMap<(usize, u64), u64> = HashMap::new();
    // per chunk: end times of its executed backwards, in stream order
    let mut bwd_ends: Vec<Vec<u64>> = vec![Vec::new(); chunks.len()];
    let mut upd_done = vec![0usize; chunks.len()];
    let mut worker_free = vec![0u64; p];
    let mut out: Vec<Vec<Action>> = vec![Vec::new(); p];
    let mut done = 0usize;
    let mut t = 0u64;
    let deadline = 4 * total as u64 + 64;

    // update u of chunk c is admissible at slot t once *every* copy of
    // its part has finished the backward feeding that copy's update u
    let upd_ready = |c: &ChunkSpec,
                     u: usize,
                     t: u64,
                     bwd_ends: &[Vec<u64>]|
     -> bool {
        chunks.iter().filter(|o| o.part == c.part).all(|o| {
            let need = feeds[o.id][u];
            need == 0
                || bwd_ends[o.id].get(need - 1).map_or(false, |&e| e <= t)
        })
    };

    while done < total {
        if t > deadline {
            bail!("schedule merge: no progress (deadlock) at t={t}, {done}/{total}");
        }
        let mut progressed = false;
        for w in 0..p {
            if worker_free[w] > t {
                continue;
            }
            // this worker's chunks in (part, stream) priority order
            let mut mine: Vec<&ChunkSpec> =
                chunks.iter().filter(|c| c.worker == w).collect();
            mine.sort_by_key(|c| (c.part, c.stream));
            // any number of zero-cost updates, at most one unit action
            loop {
                let mut acted = None;
                for &c in &mine {
                    let cur = cursors[c.id];
                    if cur >= streams[c.id].len() {
                        continue;
                    }
                    let a = streams[c.id][cur];
                    let ready = match a {
                        Action::Fwd { mb, .. } => {
                            c.seq == 0
                                || fwd_end
                                    .get(&(by_pos[&(c.stream, c.seq - 1)], mb))
                                    .map_or(false, |&e| e <= t)
                        }
                        Action::Bwd { mb, .. } => {
                            fwd_end.get(&(c.id, mb)).map_or(false, |&e| e <= t)
                                && (c.seq + 1 >= depth[&c.stream]
                                    || bwd_end
                                        .get(&(by_pos[&(c.stream, c.seq + 1)], mb))
                                        .map_or(false, |&e| e <= t))
                        }
                        Action::Update { .. } => {
                            upd_ready(c, upd_done[c.id], t, &bwd_ends)
                        }
                    };
                    if ready {
                        acted = Some((c.id, a));
                        break;
                    }
                }
                let (cid, a) = match acted {
                    Some(x) => x,
                    None => break,
                };
                cursors[cid] += 1;
                out[w].push(a);
                done += 1;
                progressed = true;
                match a {
                    Action::Fwd { mb, .. } => {
                        fwd_end.insert((cid, mb), t + 1);
                        worker_free[w] = t + 1;
                        break;
                    }
                    Action::Bwd { mb, .. } => {
                        bwd_end.insert((cid, mb), t + 1);
                        bwd_ends[cid].push(t + 1);
                        worker_free[w] = t + 1;
                        break;
                    }
                    Action::Update { .. } => {
                        upd_done[cid] += 1; // zero cost: keep scanning
                    }
                }
            }
        }
        if !progressed {
            t += 1;
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Virtual-clock executor
// ---------------------------------------------------------------------------

/// Deterministic measurements of a schedule's emitted action streams.
#[derive(Clone, Debug, Default)]
pub struct ExecStats {
    /// Virtual makespan (unit fwd/bwd costs, zero-cost updates).
    pub makespan: u64,
    /// Busy worker-slots across all workers.
    pub busy: u64,
    /// Realized bubble: `1 - busy / (workers · makespan)`.
    pub bubble: f64,
    /// Max in-flight forward stash depth observed, per chunk.
    pub max_stash: Vec<usize>,
    /// Realized gradient delay per (chunk id, global mb), in updates.
    pub delays: Vec<(usize, u64, u32)>,
    /// Updates executed per chunk.
    pub updates: Vec<u64>,
    /// Virtual-clock span timeline per worker, in the engine's span
    /// format (1 unit-cost slot = 1 ms), so model and wall-clock
    /// Chrome traces are diffable side by side. Gaps between actions
    /// become `Idle` spans; the cross-copy all-reduce wait before an
    /// update becomes a `Reduce` span.
    pub spans_by_worker: Vec<Vec<crate::trace::Span>>,
}

/// One virtual slot rendered as 1000 µs (1 ms) in the span timeline.
const VSLOT_US: f64 = 1000.0;

/// Append a virtual-clock span to worker `w`'s timeline, inserting an
/// `Idle` span over any gap since the worker's last recorded end.
fn push_vspan(
    buf: &mut Vec<crate::trace::Span>,
    last_end: &mut u64,
    kind: crate::trace::SpanKind,
    chunk: usize,
    mb: i64,
    step: i64,
    start: u64,
    dur: u64,
) {
    use crate::trace::{Span, SpanKind};
    if start > *last_end {
        buf.push(Span {
            kind: SpanKind::Idle,
            chunk: -1,
            mb: -1,
            step: -1,
            ts_us: *last_end as f64 * VSLOT_US,
            dur_us: (start - *last_end) as f64 * VSLOT_US,
            n_disp: 0,
        });
    }
    buf.push(Span {
        kind,
        chunk: chunk as i64,
        mb,
        step,
        ts_us: start as f64 * VSLOT_US,
        dur_us: dur as f64 * VSLOT_US,
        n_disp: 0,
    });
    *last_end = (*last_end).max(start + dur);
}

/// Execute a schedule's per-worker action streams on a virtual clock
/// (unit-cost fwd/bwd, zero-cost updates, real dependency tracking)
/// and validate well-formedness:
///
/// * every expected microbatch gets exactly one fwd and one bwd per
///   chunk of its stream, and the bwd never precedes its fwd;
/// * the in-flight stash depth never exceeds the declared
///   [`Schedule::max_stash`];
/// * dependencies admit an execution at all (a cyclic stream is
///   reported as a deadlock, not an infinite loop);
/// * every chunk performs exactly `n_updates` updates.
pub fn simulate(
    sched: &dyn Schedule,
    p: usize,
    cfg_m: usize,
    n_updates: u64,
) -> Result<ExecStats> {
    let m = sched.effective_m(p, cfg_m);
    let chunks = sched.chunks(p);
    let n_streams = sched.n_streams() as u64;
    let mpu = sched.micro_per_update(p, cfg_m) as u64;
    let n_micro = n_updates * mpu;
    let actions: Vec<Vec<Action>> =
        (0..p).map(|w| sched.worker_actions(p, m, n_updates, w)).collect();

    // chunk lookup tables
    let by_id: HashMap<usize, ChunkSpec> = chunks.iter().map(|c| (c.id, *c)).collect();
    let by_pos: HashMap<(usize, usize), usize> =
        chunks.iter().map(|c| ((c.stream, c.seq), c.id)).collect();
    let mut depth: HashMap<usize, usize> = HashMap::new();
    for c in &chunks {
        let e = depth.entry(c.stream).or_insert(0);
        *e = (*e).max(c.seq + 1);
    }
    for (w, acts) in actions.iter().enumerate() {
        for a in acts {
            let id = match a {
                Action::Fwd { chunk, .. }
                | Action::Bwd { chunk, .. }
                | Action::Update { chunk } => *chunk,
            };
            let c = by_id
                .get(&id)
                .ok_or_else(|| anyhow!("worker {w}: unknown chunk {id}"))?;
            if c.worker != w {
                bail!("worker {w} emits action for chunk {id} owned by {}", c.worker);
            }
        }
    }

    let mut fwd_end: HashMap<(usize, u64), u64> = HashMap::new();
    let mut bwd_end: HashMap<(usize, u64), u64> = HashMap::new();
    let mut cursors = vec![0usize; p];
    let mut free = vec![0u64; p];
    // per-chunk accounting
    let n_chunks = chunks.iter().map(|c| c.id).max().map_or(0, |x| x + 1);
    let mut inflight = vec![0isize; n_chunks];
    let mut max_stash = vec![0usize; n_chunks];
    let mut upd_done = vec![0u64; n_chunks];
    let mut u_at_fwd: HashMap<(usize, u64), u64> = HashMap::new();
    let mut pending_mbs: Vec<Vec<u64>> = vec![Vec::new(); n_chunks]; // since last update
    // per chunk: end times of executed backwards, in stream order, plus
    // how many backwards precede each update in each chunk's stream —
    // the cross-copy all-reduce of update u waits on every copy's
    // feeding backward
    let mut bwd_ends: Vec<Vec<u64>> = vec![Vec::new(); n_chunks];
    let feeds: Vec<Vec<usize>> = {
        let mut per_chunk: Vec<Vec<Action>> = vec![Vec::new(); n_chunks];
        for acts in &actions {
            for a in acts {
                match a {
                    Action::Bwd { chunk, .. } | Action::Update { chunk } => {
                        per_chunk[*chunk].push(*a)
                    }
                    Action::Fwd { .. } => {}
                }
            }
        }
        per_chunk.iter().map(|s| bwds_before_updates(s)).collect()
    };
    let mut delays = Vec::new();
    let mut busy = 0u64;
    let mut makespan = 0u64;
    let mut spans_by_worker: Vec<Vec<crate::trace::Span>> = vec![Vec::new(); p];
    let mut span_last_end = vec![0u64; p];

    let total: usize = actions.iter().map(|a| a.len()).sum();
    let mut done = 0usize;
    while done < total {
        let mut progressed = false;
        for w in 0..p {
            let cur = cursors[w];
            if cur >= actions[w].len() {
                continue;
            }
            let a = actions[w][cur];
            match a {
                Action::Fwd { mb, chunk } => {
                    let c = by_id[&chunk];
                    if mb % n_streams != c.stream as u64 || mb >= n_micro {
                        bail!("chunk {chunk}: fwd of mb {mb} outside its stream");
                    }
                    let dep = if c.seq == 0 {
                        Some(0)
                    } else {
                        fwd_end.get(&(by_pos[&(c.stream, c.seq - 1)], mb)).copied()
                    };
                    let dep = match dep {
                        Some(d) => d,
                        None => continue,
                    };
                    if fwd_end.contains_key(&(chunk, mb)) {
                        bail!("chunk {chunk}: duplicate fwd of mb {mb}");
                    }
                    let start = free[w].max(dep);
                    fwd_end.insert((chunk, mb), start + 1);
                    free[w] = start + 1;
                    busy += 1;
                    makespan = makespan.max(start + 1);
                    inflight[chunk] += 1;
                    max_stash[chunk] = max_stash[chunk].max(inflight[chunk] as usize);
                    u_at_fwd.insert((chunk, mb), upd_done[chunk]);
                    push_vspan(
                        &mut spans_by_worker[w],
                        &mut span_last_end[w],
                        crate::trace::SpanKind::Fwd,
                        chunk,
                        mb as i64,
                        upd_done[chunk] as i64,
                        start,
                        1,
                    );
                }
                Action::Bwd { mb, chunk } => {
                    let c = by_id[&chunk];
                    let own = match fwd_end.get(&(chunk, mb)) {
                        Some(&e) => e,
                        None => {
                            bail!("chunk {chunk}: bwd of mb {mb} precedes its fwd")
                        }
                    };
                    let dn = if c.seq + 1 < depth[&c.stream] {
                        bwd_end.get(&(by_pos[&(c.stream, c.seq + 1)], mb)).copied()
                    } else {
                        Some(0)
                    };
                    let dn = match dn {
                        Some(d) => d,
                        None => continue,
                    };
                    if bwd_end.contains_key(&(chunk, mb)) {
                        bail!("chunk {chunk}: duplicate bwd of mb {mb}");
                    }
                    let start = free[w].max(own).max(dn);
                    bwd_end.insert((chunk, mb), start + 1);
                    free[w] = start + 1;
                    busy += 1;
                    makespan = makespan.max(start + 1);
                    inflight[chunk] -= 1;
                    pending_mbs[chunk].push(mb);
                    bwd_ends[chunk].push(start + 1);
                    push_vspan(
                        &mut spans_by_worker[w],
                        &mut span_last_end[w],
                        crate::trace::SpanKind::Bwd,
                        chunk,
                        mb as i64,
                        upd_done[chunk] as i64,
                        start,
                        1,
                    );
                }
                Action::Update { chunk } => {
                    let c = by_id[&chunk];
                    if pending_mbs[chunk].is_empty() {
                        bail!("chunk {chunk}: update with no accumulated backward");
                    }
                    let u = upd_done[chunk] as usize;
                    // all copies of this part must have scheduled the
                    // backward feeding their update u (blocking
                    // all-reduce sync); retry later otherwise
                    let mut sync = 0u64;
                    let mut pending_copy = false;
                    for o in chunks.iter().filter(|o| o.part == c.part) {
                        let need = feeds[o.id].get(u).copied().unwrap_or(0);
                        if need == 0 {
                            continue;
                        }
                        match bwd_ends[o.id].get(need - 1) {
                            Some(&e) => sync = sync.max(e),
                            None => {
                                pending_copy = true;
                                break;
                            }
                        }
                    }
                    if pending_copy {
                        continue;
                    }
                    if sync > free[w] {
                        // blocking cross-copy all-reduce wait
                        push_vspan(
                            &mut spans_by_worker[w],
                            &mut span_last_end[w],
                            crate::trace::SpanKind::Reduce,
                            chunk,
                            -1,
                            (upd_done[chunk] + 1) as i64,
                            free[w],
                            sync - free[w],
                        );
                    }
                    free[w] = free[w].max(sync);
                    push_vspan(
                        &mut spans_by_worker[w],
                        &mut span_last_end[w],
                        crate::trace::SpanKind::Update,
                        chunk,
                        -1,
                        (upd_done[chunk] + 1) as i64,
                        free[w],
                        0,
                    );
                    let u = upd_done[chunk];
                    for mb in pending_mbs[chunk].drain(..) {
                        let seen = u_at_fwd[&(chunk, mb)];
                        delays.push((chunk, mb, (u - seen) as u32));
                    }
                    upd_done[chunk] += 1;
                }
            }
            cursors[w] = cur + 1;
            done += 1;
            progressed = true;
        }
        if !progressed {
            bail!(
                "schedule deadlock: {} of {total} actions executed, cursors {:?}",
                done,
                cursors
            );
        }
    }

    // coverage: every chunk saw exactly its stream's microbatches
    for c in &chunks {
        let mine: Vec<u64> = (0..n_micro)
            .filter(|mb| mb % n_streams == c.stream as u64)
            .collect();
        for &mb in &mine {
            if !fwd_end.contains_key(&(c.id, mb)) {
                bail!("chunk {}: mb {mb} never forwarded", c.id);
            }
            if !bwd_end.contains_key(&(c.id, mb)) {
                bail!("chunk {}: mb {mb} never backwarded", c.id);
            }
        }
        if fwd_end.keys().filter(|(id, _)| *id == c.id).count() != mine.len() {
            bail!("chunk {}: extra forwards", c.id);
        }
        if upd_done[c.id] != n_updates {
            bail!(
                "chunk {}: {} updates, expected {n_updates}",
                c.id,
                upd_done[c.id]
            );
        }
        let cap = sched.max_stash(p, m);
        if max_stash[c.id] > cap {
            bail!(
                "chunk {}: stash depth {} exceeds declared {cap}",
                c.id,
                max_stash[c.id]
            );
        }
    }

    let slots = (p as u64 * makespan).max(1);
    Ok(ExecStats {
        makespan,
        busy,
        bubble: 1.0 - busy as f64 / slots as f64,
        max_stash,
        delays,
        updates: upd_done,
        spans_by_worker,
    })
}

/// Render an [`ExecStats`] span set as a [`crate::trace::Trace`]
/// (pid 0, one tid per worker) — the model-side counterpart of the
/// engine's wall-clock trace.
pub fn stats_to_trace(stats: &ExecStats) -> crate::trace::Trace {
    let mut tr = crate::trace::Trace::default();
    for (w, spans) in stats.spans_by_worker.iter().enumerate() {
        tr.push_thread(0, w as u64, format!("model/w{w}"), spans.clone());
    }
    tr
}

/// Collapse per-(chunk, microbatch) realized delays into per-chunk
/// rows (chunk id, microbatches observed, max realized delay) — the
/// compact form [`crate::metrics::RunResult::realized_delays`] carries.
pub fn summarize_delays(delays: &[(usize, u64, u32)]) -> Vec<(usize, u64, u32)> {
    let mut map: std::collections::BTreeMap<usize, (u64, u32)> =
        std::collections::BTreeMap::new();
    for &(c, _mb, d) in delays {
        let e = map.entry(c).or_insert((0, 0));
        e.0 += 1;
        e.1 = e.1.max(d);
    }
    map.into_iter().map(|(c, (n, mx))| (c, n, mx)).collect()
}

// ---------------------------------------------------------------------------
// Analytic bubble formulas (SNIPPETS.md snippets 1–2), pinned by unit
// tests here and in `engine.rs`.
// ---------------------------------------------------------------------------

/// GPipe bubble as a fraction of *total* schedule slots:
/// `(P-1)/(M+P-1)` (fill + drain of P-1 slots around M useful ones).
/// A finite 1F1B run of M microbatches pays the same fill/drain.
pub fn gpipe_bubble_fraction(p: usize, m: usize) -> f64 {
    (p as f64 - 1.0) / (m as f64 + p as f64 - 1.0)
}

/// The warmup-drain 1F1B bubble as a fraction of *ideal* (busy) time:
/// `(P-1)/M` — the same overhead as [`gpipe_bubble_fraction`] in the
/// bubble/ideal convention (`total = x/(1+x)`).
pub fn one_f_one_b_bubble_fraction_ideal(p: usize, m: usize) -> f64 {
    (p as f64 - 1.0) / m as f64
}

/// Interleaved-1F1B bubble over ideal time: `(P-1)/(M·V)` — V virtual
/// chunks per worker divide the fill cost (Megatron Fig. 4).
pub fn interleaved_bubble_fraction_ideal(p: usize, m: usize, v: usize) -> f64 {
    (p as f64 - 1.0) / (m as f64 * v as f64)
}

/// [`interleaved_bubble_fraction_ideal`] converted to the
/// bubble/total convention the executor measures.
pub fn interleaved_bubble_fraction_total(p: usize, m: usize, v: usize) -> f64 {
    let x = interleaved_bubble_fraction_ideal(p, m, v);
    x / (1.0 + x)
}

/// Exact interleaved bubble over total slots, valid for *all* M: with
/// fewer microbatches than workers (M < P), each of the V-1 level
/// transitions stalls every worker for `P-M` slots — the next level's
/// first microbatch is still `P-M` ranks upstream when the current
/// level's last one finishes — in both the forward and the backward
/// phase of the wave:
/// `(P-1 + (V-1)·max(P-M,0)) / (M·V + P-1 + (V-1)·max(P-M,0))`.
/// Reduces to [`interleaved_bubble_fraction_total`] when M ≥ P and to
/// [`gpipe_bubble_fraction`] when V = 1; pinned against the unit-cost
/// executor by the conformance harness.
pub fn interleaved_bubble_fraction_exact(p: usize, m: usize, v: usize) -> f64 {
    let stall = (v as f64 - 1.0) * (p as f64 - m as f64).max(0.0);
    let fill = p as f64 - 1.0 + stall;
    fill / (m as f64 * v as f64 + fill)
}

/// Closed-form *estimate* of the AMDP bubble over total slots for a
/// run of M total microbatches (M/2 per direction): the two
/// counter-flowing fills overlap on every worker, so the exposed
/// fill/drain shrinks to roughly `P-2` slots against `2M` useful ones
/// per worker: `(P-2)/(2M+P-2)`. The schedule's declared
/// [`Schedule::bubble_frac`] reports the exact unit-cost executor
/// value instead (no simple closed form exists for the merged
/// bidirectional stream); this estimate serves odd-P fallbacks and
/// back-of-envelope comparisons.
pub fn amdp_bubble_fraction(p: usize, m: usize) -> f64 {
    let fill = (p as f64 - 2.0).max(0.0);
    fill / (2.0 * m as f64 + fill)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds() -> Vec<ScheduleKind> {
        vec![
            ScheduleKind::Gpipe,
            ScheduleKind::OneFOneB,
            ScheduleKind::Interleaved { v: 2 },
            ScheduleKind::Amdp,
        ]
    }

    #[test]
    fn chunk_layouts_cover_parts_and_workers() {
        for kind in kinds() {
            let s = build(kind);
            for p in [2usize, 4, 8] {
                let chunks = s.chunks(p);
                // every part covered by ≥1 chunk, every chunk on a valid worker
                let mut part_seen = vec![0usize; s.n_parts(p)];
                for c in &chunks {
                    assert!(c.worker < p, "{kind:?}");
                    part_seen[c.part] += 1;
                }
                assert!(part_seen.iter().all(|&n| n >= 1), "{kind:?} P={p}");
                // ids unique
                let ids: std::collections::HashSet<_> =
                    chunks.iter().map(|c| c.id).collect();
                assert_eq!(ids.len(), chunks.len(), "{kind:?}");
                // declared chunk delays agree with the stage profile
                let prof = s.delay_profile(p);
                assert_eq!(prof.len(), p);
                for c in &chunks {
                    if s.n_parts(p) == p {
                        assert_eq!(c.delay, prof[c.part], "{kind:?} chunk {}", c.id);
                    } else {
                        assert_eq!(c.delay, 0, "interleaved chunks are sync");
                    }
                }
            }
        }
    }

    #[test]
    fn one_f_one_b_stream_matches_legacy_warmup_pattern() {
        // stage k of P warms up with P-1-k forwards, then alternates
        // fwd-before-bwd — the engine's original hard-coded loop
        let s = OneFOneB;
        let acts = s.worker_actions(4, 1, 6, 1);
        let head: Vec<Action> = acts.iter().take(5).copied().collect();
        assert_eq!(
            head,
            vec![
                Action::Fwd { mb: 0, chunk: 1 },
                Action::Fwd { mb: 1, chunk: 1 },
                Action::Fwd { mb: 2, chunk: 1 },
                Action::Bwd { mb: 0, chunk: 1 },
                Action::Update { chunk: 1 },
            ]
        );
        // last stage: no warmup, strictly F,B,U triples
        let last = s.worker_actions(4, 1, 3, 3);
        assert_eq!(
            last,
            vec![
                Action::Fwd { mb: 0, chunk: 3 },
                Action::Bwd { mb: 0, chunk: 3 },
                Action::Update { chunk: 3 },
                Action::Fwd { mb: 1, chunk: 3 },
                Action::Bwd { mb: 1, chunk: 3 },
                Action::Update { chunk: 3 },
                Action::Fwd { mb: 2, chunk: 3 },
                Action::Bwd { mb: 2, chunk: 3 },
                Action::Update { chunk: 3 },
            ]
        );
    }

    #[test]
    fn executor_accepts_all_schedules_and_counts_updates() {
        for kind in kinds() {
            let s = build(kind);
            let stats = simulate(s.as_ref(), 4, 8, 3).unwrap_or_else(|e| {
                panic!("{kind:?}: {e}");
            });
            assert!(stats.updates.iter().all(|&u| u == 3), "{kind:?}");
            assert!(stats.makespan > 0 && stats.busy > 0, "{kind:?}");
            assert!(stats.bubble >= 0.0 && stats.bubble < 1.0, "{kind:?}");
        }
    }

    #[test]
    fn executor_measured_bubble_matches_analytic_small_grid() {
        // tiny hand-checkable cases (P=2): gpipe/1f1b 1/3, interleaved
        // v=2 m=2 → 1/5, amdp → 0 fill for P=2
        let g = simulate(&Gpipe, 2, 2, 1).unwrap();
        assert!((g.bubble - 1.0 / 3.0).abs() < 1e-12, "{}", g.bubble);
        let f = simulate(&OneFOneB, 2, 0, 2).unwrap();
        assert!((f.bubble - 1.0 / 3.0).abs() < 1e-12, "{}", f.bubble);
        let i = simulate(&Interleaved { v: 2 }, 2, 2, 1).unwrap();
        assert!((i.bubble - 0.2).abs() < 1e-12, "{}", i.bubble);
    }

    #[test]
    fn executor_realized_delays_match_declared_profiles() {
        for kind in kinds() {
            let s = build(kind);
            let p = 4;
            let n_updates = 12;
            let stats = simulate(s.as_ref(), p, 8, n_updates).unwrap();
            let chunks = s.chunks(p);
            let n_streams = s.n_streams() as u64;
            for (chunk, mb, delay) in stats.delays {
                let spec = chunks.iter().find(|c| c.id == chunk).unwrap();
                let local = mb / n_streams; // stream-local index
                if local >= (p - 1) as u64 && local < n_updates - (p as u64) {
                    assert_eq!(
                        delay, spec.delay,
                        "{kind:?} chunk {chunk} mb {mb}: steady-state delay"
                    );
                } else {
                    assert!(
                        delay <= spec.delay,
                        "{kind:?} chunk {chunk} mb {mb}: fill delay clamps"
                    );
                }
            }
        }
    }

    #[test]
    fn executor_rejects_malformed_streams() {
        // a schedule whose worker stream drops one backward
        struct Broken;
        impl Schedule for Broken {
            fn kind(&self) -> ScheduleKind {
                ScheduleKind::Gpipe
            }
            fn chunks(&self, p: usize) -> Vec<ChunkSpec> {
                linear_chunks(p, |_| 0)
            }
            fn effective_m(&self, _p: usize, m: usize) -> usize {
                m.max(1)
            }
            fn micro_per_update(&self, _p: usize, m: usize) -> usize {
                m.max(1)
            }
            fn worker_actions(
                &self,
                p: usize,
                m: usize,
                n: u64,
                w: usize,
            ) -> Vec<Action> {
                let mut a = Gpipe.worker_actions(p, m, n, w);
                if w == 0 {
                    // drop the last backward before the update
                    let i = a
                        .iter()
                        .rposition(|x| matches!(x, Action::Bwd { .. }))
                        .unwrap();
                    a.remove(i);
                }
                a
            }
            fn delay_profile(&self, p: usize) -> Vec<u32> {
                vec![0; p]
            }
            fn bubble_frac(&self, _p: usize, _m: usize) -> f64 {
                0.0
            }
            fn max_stash(&self, _p: usize, m: usize) -> usize {
                m
            }
        }
        assert!(simulate(&Broken, 2, 2, 1).is_err());
    }

    #[test]
    fn amdp_requires_even_p_for_copy_pairing() {
        // odd P puts both copies of the middle stage on one worker —
        // the layout itself shows the collision the engine must reject
        let chunks = Amdp.chunks(3);
        let mid: Vec<_> = chunks.iter().filter(|c| c.part == 1).collect();
        assert_eq!(mid.len(), 2);
        assert_eq!(mid[0].worker, mid[1].worker, "middle stage self-pairs");
        // even P never self-pairs
        for p in [2usize, 4, 6, 8] {
            let chunks = Amdp.chunks(p);
            for s in 0..p {
                let copies: Vec<_> =
                    chunks.iter().filter(|c| c.part == s).collect();
                assert_eq!(copies.len(), 2);
                assert_ne!(copies[0].worker, copies[1].worker, "P={p} stage {s}");
            }
        }
    }

    #[test]
    fn bubble_formula_conventions_agree() {
        // total = ideal/(1+ideal) links the two conventions
        for (p, m) in [(4usize, 8usize), (8, 16), (2, 4)] {
            let ideal = one_f_one_b_bubble_fraction_ideal(p, m);
            let total = gpipe_bubble_fraction(p, m);
            assert!((total - ideal / (1.0 + ideal)).abs() < 1e-12);
        }
        assert!((interleaved_bubble_fraction_ideal(4, 8, 2) - 3.0 / 16.0).abs() < 1e-12);
        assert!((gpipe_bubble_fraction(4, 8) - 3.0 / 11.0).abs() < 1e-12);
        assert!((amdp_bubble_fraction(4, 8) - 2.0 / 18.0).abs() < 1e-12);
        assert_eq!(amdp_bubble_fraction(2, 8), 0.0);
    }

    #[test]
    fn interleaved_exact_bubble_covers_m_below_p() {
        // M ≥ P: the stall term vanishes, both forms agree
        let a = interleaved_bubble_fraction_exact(4, 8, 2);
        let b = interleaved_bubble_fraction_total(4, 8, 2);
        assert!((a - b).abs() < 1e-12);
        // V = 1 degenerates to gpipe
        let a = interleaved_bubble_fraction_exact(6, 4, 1);
        assert!((a - gpipe_bubble_fraction(6, 4)).abs() < 1e-12);
        // M < P: each of the V-1 level transitions stalls P-M slots in
        // each phase; P=6 M=4 V=2 measures exactly 14/30
        let e = interleaved_bubble_fraction_exact(6, 4, 2);
        assert!((e - 14.0 / 30.0).abs() < 1e-12, "{e}");
        let s = simulate(&Interleaved { v: 2 }, 6, 4, 10).unwrap();
        assert!((s.bubble - e).abs() < 1e-12, "{} vs {e}", s.bubble);
    }
}
