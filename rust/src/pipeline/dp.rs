//! Data-parallel gradient reduction shared by the delay-accurate
//! simulator and the threaded 1F1B engine.
//!
//! With `TrainCfg::replicas = R`, R pipeline replicas train on disjoint
//! data shards and average their gradients at every optimizer step.
//! The invariant both consumers rely on: the averaged gradient is a
//! **deterministic fold in replica order** (`g = (((g_0 + g_1) + g_2)
//! + ...) / R`), so the in-process reduction the simulator performs
//! ([`average`]) and the channel-based tree reduction the engine's
//! replica threads perform ([`Reducer::all_reduce`]) produce bit-
//! identical f32 results — which is what lets `replicas = R` at `P = 1`
//! reproduce the sequential large-batch trajectory *exactly* and keeps
//! the engine pinned to the simulator on the DP axis.
//!
//! The engine-side topology is a binary tree over replica ids (node r
//! has children 2r+1, 2r+2): gradient sets flow **up** the tree tagged
//! with their replica id, the root folds them in id order and flows the
//! average **down**. Tagging + sorting at the root (an R-entry sort)
//! keeps the fold order independent of message arrival order, which a
//! partial-sum tree would not.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::tensor::Tensor;

/// Default per-peer reduce wait: long enough that only a genuinely
/// wedged peer — never an injected straggler sleep — trips it.
pub const DEFAULT_REDUCE_TIMEOUT: Duration = Duration::from_secs(120);

/// Average gradient sets in replica order: `out[i]` is the left fold
/// `sets[0][i] + sets[1][i] + ...`, scaled by `1/R`. All sets must have
/// the same parameter shapes. An empty fold is a loud error rather
/// than a panic: with elastic replicas a zero-member group is a
/// reachable (mis)configuration, not a programming bug.
pub fn average(sets: &[Vec<Tensor>]) -> Result<Vec<Tensor>> {
    if sets.is_empty() {
        return Err(anyhow!("dp::average needs at least one gradient set"));
    }
    let inv = 1.0 / sets.len() as f32;
    let mut out = sets[0].clone();
    for set in &sets[1..] {
        for (acc, g) in out.iter_mut().zip(set) {
            debug_assert_eq!(acc.shape, g.shape);
            for (a, &b) in acc.data.iter_mut().zip(&g.data) {
                *a += b;
            }
        }
    }
    for t in out.iter_mut() {
        for a in t.data.iter_mut() {
            *a *= inv;
        }
    }
    Ok(out)
}

/// Mean of per-replica losses, folded in replica order (the loss-side
/// twin of [`average`], so recorded trajectories are deterministic
/// too). Errors on an empty fold for the same reason `average` does.
pub fn mean_loss(losses: &[f32]) -> Result<f32> {
    if losses.is_empty() {
        return Err(anyhow!("dp::mean_loss needs at least one loss"));
    }
    let mut acc = 0.0f32;
    for &l in losses {
        acc += l;
    }
    Ok(acc / losses.len() as f32)
}

/// Scatter restricted per-stage tensor lists back into full-manifest
/// order: `parts` pairs each stage's kept manifest indices with its
/// tensors. Errors unless the index lists partition `0..total` exactly
/// (the property the restrict/merge round-trip tests pin down).
pub fn merge_restricted(
    total: usize,
    parts: &[(Vec<usize>, Vec<Tensor>)],
) -> Result<Vec<Tensor>> {
    let mut out: Vec<Option<Tensor>> = vec![None; total];
    for (keep, tensors) in parts {
        if keep.len() != tensors.len() {
            return Err(anyhow!(
                "merge_restricted: {} indices for {} tensors",
                keep.len(),
                tensors.len()
            ));
        }
        for (&i, t) in keep.iter().zip(tensors) {
            if i >= total {
                return Err(anyhow!("merge_restricted: index {i} out of {total}"));
            }
            if out[i].is_some() {
                return Err(anyhow!("merge_restricted: index {i} covered twice"));
            }
            out[i] = Some(t.clone());
        }
    }
    out.into_iter()
        .enumerate()
        .map(|(i, t)| t.ok_or_else(|| anyhow!("merge_restricted: index {i} uncovered")))
        .collect()
}

/// One gathered subtree: (replica id, that replica's gradient set).
type Gathered = Vec<(usize, Vec<Tensor>)>;

/// One replica's handle into an R-way all-reduce group (binary tree
/// over replica ids). Every participant must call
/// [`Reducer::all_reduce`] once per step, in step lockstep; a dropped
/// handle (replica stopped early, e.g. on divergence) surfaces as an
/// `Err` at its tree neighbours, which the engine treats as a wind-down
/// signal exactly like a closed activation channel.
pub struct Reducer {
    /// Replica id of this handle (0-based, root of the tree is 0).
    pub id: usize,
    /// Group size R.
    pub replicas: usize,
    /// Per-peer wait bound: a peer that neither sends nor hangs up
    /// within this window surfaces as a loud error naming it, instead
    /// of freezing the whole group silently.
    timeout: Duration,
    up_tx: Option<Sender<Gathered>>,
    /// Receivers from direct children, aligned with `child_ids`.
    child_rx: Vec<Receiver<Gathered>>,
    /// Replica ids of the direct children (subtree roots) feeding
    /// `child_rx`, used to name an unresponsive peer in errors.
    child_ids: Vec<usize>,
    down_rx: Option<Receiver<Vec<Tensor>>>,
    down_tx: Vec<Sender<Vec<Tensor>>>,
}

/// Build the handles of one all-reduce group (index = replica id) with
/// the default reduce timeout.
pub fn group(replicas: usize) -> Vec<Reducer> {
    group_with(replicas, DEFAULT_REDUCE_TIMEOUT)
}

/// Build the handles of one all-reduce group with an explicit per-peer
/// reduce timeout (`TrainCfg::reduce_timeout`).
pub fn group_with(replicas: usize, timeout: Duration) -> Vec<Reducer> {
    assert!(replicas >= 1, "dp::group needs at least one replica");
    let mut nodes: Vec<Reducer> = (0..replicas)
        .map(|id| Reducer {
            id,
            replicas,
            timeout,
            up_tx: None,
            child_rx: Vec::new(),
            child_ids: Vec::new(),
            down_rx: None,
            down_tx: Vec::new(),
        })
        .collect();
    for child in 1..replicas {
        let parent = (child - 1) / 2;
        let (utx, urx) = channel::<Gathered>();
        let (dtx, drx) = channel::<Vec<Tensor>>();
        nodes[child].up_tx = Some(utx);
        nodes[child].down_rx = Some(drx);
        nodes[parent].child_rx.push(urx);
        nodes[parent].child_ids.push(child);
        nodes[parent].down_tx.push(dtx);
    }
    nodes
}

impl Reducer {
    /// Wait on one peer channel with the configured bound, mapping both
    /// failure modes to errors that name the peer: a hang-up (dropped
    /// handle — the wind-down signal) and a timeout (a peer that is
    /// alive but no longer making progress, which `recv()` used to wait
    /// on forever).
    fn recv_peer<T>(&self, rx: &Receiver<T>, peer: usize) -> Result<T> {
        rx.recv_timeout(self.timeout).map_err(|e| match e {
            RecvTimeoutError::Disconnected => {
                anyhow!("dp: replica {peer} hung up during all-reduce")
            }
            RecvTimeoutError::Timeout => anyhow!(
                "dp: replica {peer} unresponsive for {:.1}s during all-reduce \
                 (reduce timeout; raise --reduce-timeout-ms if this was a \
                 legitimate stall)",
                self.timeout.as_secs_f64()
            ),
        })
    }

    /// Contribute this replica's gradients and return the group average
    /// (fold in replica-id order, identical to [`average`]). `R = 1` is
    /// a no-op passthrough. An `Err` means a peer replica hung up or
    /// stopped responding within the reduce timeout; the message names
    /// the peer (for a child, the root of its unresponsive subtree).
    pub fn all_reduce(&self, grads: Vec<Tensor>) -> Result<Vec<Tensor>> {
        if self.replicas == 1 {
            return Ok(grads);
        }
        let mut gathered: Gathered = vec![(self.id, grads)];
        for (rx, &peer) in self.child_rx.iter().zip(&self.child_ids) {
            gathered.extend(self.recv_peer(rx, peer)?);
        }
        let avg = match &self.up_tx {
            Some(up) => {
                let parent = (self.id - 1) / 2;
                up.send(gathered)
                    .map_err(|_| anyhow!("dp: replica {parent} hung up during all-reduce"))?;
                self.recv_peer(self.down_rx.as_ref().unwrap(), parent)?
            }
            None => {
                gathered.sort_by_key(|(id, _)| *id);
                let sets: Vec<Vec<Tensor>> =
                    gathered.into_iter().map(|(_, g)| g).collect();
                average(&sets)?
            }
        };
        for (tx, &peer) in self.down_tx.iter().zip(&self.child_ids) {
            tx.send(avg.clone())
                .map_err(|_| anyhow!("dp: replica {peer} hung up during all-reduce"))?;
        }
        Ok(avg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: &[f32]) -> Tensor {
        Tensor::new(vec![v.len()], v.to_vec())
    }

    #[test]
    fn average_folds_in_replica_order() {
        let sets = vec![
            vec![t(&[1.0, 2.0])],
            vec![t(&[3.0, 4.0])],
            vec![t(&[5.0, 6.0])],
        ];
        let avg = average(&sets).unwrap();
        assert_eq!(avg[0].data, vec![3.0, 4.0]);
        assert!((mean_loss(&[1.0, 2.0, 6.0]).unwrap() - 3.0).abs() < 1e-7);
    }

    #[test]
    fn empty_folds_error_instead_of_panicking() {
        assert!(average(&[]).is_err());
        assert!(mean_loss(&[]).is_err());
    }

    #[test]
    fn tree_all_reduce_matches_in_process_average() {
        for r in [1usize, 2, 3, 4, 7, 8] {
            let sets: Vec<Vec<Tensor>> = (0..r)
                .map(|i| {
                    vec![
                        t(&[i as f32 + 0.25, -(i as f32)]),
                        t(&[0.1 * i as f32, 1.0, 2.0]),
                    ]
                })
                .collect();
            let want = average(&sets).unwrap();
            let handles = group(r);
            let mut threads = Vec::new();
            for (h, set) in handles.into_iter().zip(sets.clone()) {
                threads.push(std::thread::spawn(move || h.all_reduce(set).unwrap()));
            }
            for th in threads {
                let got = th.join().unwrap();
                for (a, b) in got.iter().zip(&want) {
                    // bit-identical: same fold order as `average`
                    assert_eq!(a.data, b.data, "R={r}");
                }
            }
        }
    }

    #[test]
    fn all_reduce_repeats_across_steps() {
        let handles = group(3);
        let mut threads = Vec::new();
        for h in handles {
            threads.push(std::thread::spawn(move || {
                let mut out = Vec::new();
                for step in 0..5 {
                    let g = vec![t(&[(h.id + step) as f32])];
                    out.push(h.all_reduce(g).unwrap()[0].data[0]);
                }
                out
            }));
        }
        let want: Vec<f32> =
            (0..5).map(|s| (3 * s + 3) as f32 / 3.0).collect();
        for th in threads {
            assert_eq!(th.join().unwrap(), want);
        }
    }

    #[test]
    fn dropped_peer_surfaces_as_error() {
        let mut handles = group(2);
        let h1 = handles.pop().unwrap();
        drop(handles); // replica 0 (the root) is gone
        let err = h1.all_reduce(vec![t(&[1.0])]).unwrap_err().to_string();
        assert!(err.contains("replica 0"), "{err}");
    }

    #[test]
    fn unresponsive_peer_times_out_loudly_for_all_survivors() {
        // Replica 2 holds its handle open but never contributes — the
        // shape of a worker stalled mid-reduce. Every survivor must
        // error out within the reduce timeout instead of hanging, and
        // the replica waiting on it directly must name it.
        let mut handles = group_with(3, Duration::from_millis(100));
        let h2 = handles.pop().unwrap(); // kept alive, never reduces
        let h1 = handles.pop().unwrap();
        let h0 = handles.pop().unwrap();
        let t0 = std::thread::spawn(move || h0.all_reduce(vec![t(&[1.0])]));
        let t1 = std::thread::spawn(move || h1.all_reduce(vec![t(&[2.0])]));
        let e0 = t0.join().unwrap().unwrap_err().to_string();
        let e1 = t1.join().unwrap().unwrap_err().to_string();
        drop(h2);
        // root 0 waits on child 2 directly and must name it
        assert!(e0.contains("replica 2"), "{e0}");
        // replica 1 waits on its parent (root 0), which went down
        assert!(e1.contains("replica 0"), "{e1}");
    }

    #[test]
    fn merge_restricted_round_trips_and_rejects_bad_covers() {
        let full = vec![t(&[1.0]), t(&[2.0]), t(&[3.0])];
        let parts = vec![
            (vec![0usize, 2], vec![full[0].clone(), full[2].clone()]),
            (vec![1usize], vec![full[1].clone()]),
        ];
        let merged = merge_restricted(3, &parts).unwrap();
        for (a, b) in merged.iter().zip(&full) {
            assert_eq!(a.data, b.data);
        }
        // overlap
        let overlap = vec![
            (vec![0usize, 1], vec![full[0].clone(), full[1].clone()]),
            (vec![1usize], vec![full[1].clone()]),
        ];
        assert!(merge_restricted(3, &overlap).is_err());
        // hole
        let hole = vec![(vec![0usize], vec![full[0].clone()])];
        assert!(merge_restricted(3, &hole).is_err());
        // arity mismatch
        let bad = vec![(vec![0usize, 1], vec![full[0].clone()])];
        assert!(merge_restricted(3, &bad).is_err());
    }
}
