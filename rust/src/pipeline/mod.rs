//! Asynchronous pipeline-parallel execution.
//!
//! * `sim` (this file) — the delay-accurate single-process simulator:
//!   one whole-model `fwdbwd` dispatch per step on mixed-version weights
//!   held in per-parameter stash rings. Reproduces PipeDream's staleness
//!   semantics exactly (DESIGN.md §3) at minimal dispatch overhead; used
//!   by all loss-curve experiments.
//! * `engine` — the real threaded 1F1B pipeline (one OS thread per
//!   stage, per-block executables, weight stashing per microbatch, a
//!   stage-local `Box<dyn Optimizer>` per stage, dense + MoE blocks).
//!   Integration tests pin its loss trajectory to the simulator's for
//!   PipeDream, Nesterov and basis rotation.
//! * `dp` — the data-parallel axis shared by both: `TrainCfg::replicas
//!   = R` pipeline replicas over disjoint shards with a deterministic
//!   replica-order gradient average at every optimizer step (in-process
//!   for the sim, a channel tree-reduce across stage threads for the
//!   engine).

pub mod dp;
pub mod dp_async;
pub mod engine;
pub mod schedule;

use anyhow::{bail, Result};

/// Corpus stream label of the validation split — disjoint from the
/// training stream (1); shared by the simulator and the engine so both
/// sample the same validation batches.
pub const VAL_STREAM: u64 = 999;

use crate::config::{Method, ScheduleKind, StashMode, TrainCfg};
use crate::data::{replica_stream, BatchIter, Corpus, TRAIN_STREAM};
use crate::metrics::{RunResult, StageCounter};
use crate::model::{init_params, StagePartition};
use crate::optim::{self, clip_global_norm, StepCtx};
use crate::runtime::{
    tensor_to_value, tokens_to_value, value_scalar_f32, value_to_tensor, Runtime,
    Value,
};
use crate::tensor::Tensor;

/// Per-parameter ring of stashed weight versions. `front()` is the
/// version a stage with delay τ uses at the current step; during
/// pipeline fill the oldest version is clamped to v0 (exactly like the
/// real schedule's warmup forwards).
pub struct StashRing {
    rings: Vec<std::collections::VecDeque<Tensor>>,
    delays: Vec<u32>,
}

impl StashRing {
    /// Seed every ring with the initial parameter version.
    pub fn new(params: &[Tensor], delays: &[u32]) -> Self {
        let rings = params
            .iter()
            .zip(delays)
            .map(|(p, &d)| {
                let mut q = std::collections::VecDeque::with_capacity(d as usize + 1);
                q.push_back(p.clone());
                q
            })
            .collect();
        StashRing { rings, delays: delays.to_vec() }
    }

    /// The stale view for parameter `i` (version t-1-τ_i, clamped).
    pub fn stale(&self, i: usize) -> &Tensor {
        self.rings[i].front().unwrap()
    }

    /// Record the post-update version of every parameter.
    pub fn push(&mut self, params: &[Tensor]) {
        for ((ring, p), &d) in self.rings.iter_mut().zip(params).zip(&self.delays) {
            ring.push_back(p.clone());
            while ring.len() > d as usize + 1 {
                ring.pop_front();
            }
        }
    }

    /// Total stashed elements (memory accounting).
    pub fn stashed_elems(&self) -> usize {
        self.rings.iter().map(|r| r.iter().map(|t| t.len()).sum::<usize>()).sum()
    }

    /// Snapshot every ring, oldest version first (checkpointing).
    pub fn export(&self) -> Vec<Vec<Tensor>> {
        self.rings.iter().map(|r| r.iter().cloned().collect()).collect()
    }

    /// Replace the ring contents from an [`export`](Self::export)
    /// snapshot of an identically-partitioned run. Each ring must hold
    /// between 1 and delay+1 versions (the invariant `push` maintains).
    pub fn restore(&mut self, rings: Vec<Vec<Tensor>>) -> Result<()> {
        if rings.len() != self.rings.len() {
            bail!(
                "stash snapshot has {} rings, this run has {}",
                rings.len(),
                self.rings.len()
            );
        }
        for ((ring, snap), &d) in self.rings.iter_mut().zip(rings).zip(&self.delays) {
            if snap.is_empty() || snap.len() > d as usize + 1 {
                bail!(
                    "stash ring snapshot holds {} versions, valid range for \
                     delay {d} is 1..={}",
                    snap.len(),
                    d + 1
                );
            }
            *ring = snap.into_iter().collect();
        }
        Ok(())
    }
}

/// PipeMare-style weight predictor: ŵ = w + τ·velocity, with velocity an
/// EMA of recent update deltas (Fig. 15).
pub struct Predictor {
    vel: Vec<Tensor>,
    beta: f32,
}

impl Predictor {
    /// Zero-velocity predictor over the given parameter shapes.
    pub fn new(params: &[Tensor]) -> Self {
        Predictor {
            vel: params.iter().map(|p| Tensor::zeros(&p.shape)).collect(),
            beta: 0.9,
        }
    }

    /// Fold one observed update delta into the velocity EMA.
    pub fn observe(&mut self, before: &[Tensor], after: &[Tensor]) {
        for ((v, b), a) in self.vel.iter_mut().zip(before).zip(after) {
            for ((vi, &bi), &ai) in v.data.iter_mut().zip(&b.data).zip(&a.data) {
                *vi = self.beta * *vi + (1.0 - self.beta) * (ai - bi);
            }
        }
    }

    /// Extrapolate parameter `i` forward by `tau` steps.
    pub fn predict(&self, i: usize, w: &Tensor, tau: u32) -> Tensor {
        let mut out = w.clone();
        out.axpy(tau as f32, &self.vel[i]);
        out
    }
}

/// Train with the delay-accurate simulator. Returns the loss trajectory
/// and counters.
pub fn train_sim(rt: &Runtime, cfg: &TrainCfg) -> Result<RunResult> {
    train_sim_observed(rt, cfg, &mut |_t, _p| {}).map(|(r, _)| r)
}

/// `train_sim` with an observer called after every update with
/// (step, current params), returning the final params — used by the
/// Fig. 11 alignment analysis and by checkpoint-style consumers.
///
/// Data parallelism (`cfg.replicas = R > 1`): every step computes R
/// gradients on disjoint data shards (`data::replica_stream`) against
/// the **same** stale weight views — the replicas stay in parameter
/// lockstep because each applies the identical averaged gradient
/// (`dp::average`, deterministic replica-order fold) — then performs
/// one optimizer update. The recorded loss is the replica mean; at
/// P = 1 this reproduces the sequential large-batch (R x b) trajectory
/// exactly, which the `dp_*` integration tests pin down.
pub fn train_sim_observed(
    rt: &Runtime,
    cfg: &TrainCfg,
    observe: &mut dyn FnMut(u64, &[Tensor]),
) -> Result<(RunResult, Vec<Tensor>)> {
    let man = &rt.manifest;
    let mcfg = rt.cfg().clone();
    let replicas = cfg.dp_replicas();
    // The simulator runs every dispatch on this thread, so the whole
    // kernel budget is available to each kernel in turn.
    let threads = crate::runtime::pool::ThreadCfg::new(cfg.threads).resolve();
    let _budget = crate::runtime::pool::install_budget(threads);
    let sched = schedule::build(cfg.schedule);
    if cfg.schedule == ScheduleKind::Amdp && cfg.stages % 2 != 0 {
        bail!(
            "schedule amdp pairs stage k with stage P-1-k across its two \
             streams and needs an even stage count; got P={} (use an even \
             --stages or another --schedule)",
            cfg.stages
        );
    }
    // Microbatches folded into each optimizer update (gpipe/interleaved
    // accumulate M; 1f1b updates per microbatch; amdp averages one per
    // direction). The per-update gradient is the mean over the draws.
    let draws = sched
        .micro_per_update(cfg.stages, cfg.microbatches as usize)
        .max(1);
    // The staleness model follows the schedule's declared delay
    // profile, not the hard-coded 1F1B P-1-k (identical for 1f1b).
    // Under bounded-skew async DP (`--dp-async --max-skew K`) the DP
    // component composes additively with the PP delay: a replica may
    // fold peer gradients up to K optimizer steps old, so every
    // parameter's modeled delay grows by K and the stash rings serve
    // views that much older. K=0 leaves the profile untouched, which is
    // what makes the skew-0 path bit-exact with synchronous DP.
    let dp_skew = if cfg.dp_async { cfg.max_skew } else { 0 };
    let part = {
        let mut part = StagePartition::new(man, cfg.stages);
        let prof = sched.delay_profile(cfg.stages);
        for (d, &s) in part.delay_of.iter_mut().zip(&part.stage_of) {
            *d = prof[s] + dp_skew;
        }
        part
    };
    let mut params = init_params(man, cfg.seed);
    let mut stash = StashRing::new(&params, &part.delay_of);
    let mut predictor = match cfg.stash {
        StashMode::Predict => Some(Predictor::new(&params)),
        _ => None,
    };
    let mut opt = optim::build(&cfg.method, rt, cfg);
    let corpus = Corpus::new(mcfg.vocab, cfg.seed ^ 0xDA7A);
    let mut train_iters: Vec<BatchIter> = (0..replicas)
        .map(|r| {
            BatchIter::new(
                corpus.clone(),
                mcfg.batch,
                mcfg.seq,
                replica_stream(TRAIN_STREAM, r),
            )
        })
        .collect();
    let mut val_iter = BatchIter::new(corpus, mcfg.batch, mcfg.seq, VAL_STREAM);

    let mut result = RunResult::new(&cfg.method.name(), cfg.stages);
    result.replicas = replicas;
    result.threads = threads;
    result.dp_async = cfg.dp_async;
    result.max_skew = cfg.max_skew;
    result.param_count = man.total_params();
    let mut rep_dispatches = vec![0u64; replicas];

    // Crash-consistent resume: restore params, optimizer state, stash
    // rings, data cursors and recorded losses from a snapshot, then
    // continue the loop from the saved step. Everything the loop reads
    // is either restored here or a pure function of (cfg, t), so the
    // continued trajectory is bit-identical to an uninterrupted run.
    if cfg.checkpoint_every > 0 && cfg.stash == StashMode::Predict {
        bail!(
            "checkpointing does not cover StashMode::Predict: the PipeMare \
             predictor's velocity EMA is live state the snapshot omits; \
             use --stash stash/nostash with --checkpoint-every"
        );
    }
    let mut start_step: u64 = 0;
    if let Some(path) = &cfg.resume {
        if cfg.stash == StashMode::Predict {
            bail!(
                "cannot resume a StashMode::Predict run: the predictor's \
                 velocity EMA is not checkpointed"
            );
        }
        let st = crate::checkpoint::load(std::path::Path::new(path))?;
        st.expect(
            "sim",
            &mcfg.name,
            &cfg.method.name(),
            &cfg.schedule.name(),
            cfg.stages,
            cfg.seed,
            cfg.steps,
        )?;
        if st.replicas != replicas {
            bail!(
                "checkpoint replicas mismatch: saved {}, run wants {replicas} \
                 (the simulator is not elastic; use the engine driver)",
                st.replicas
            );
        }
        if st.dp_mode != cfg.dp_mode() {
            bail!(
                "checkpoint DP mode mismatch: snapshot was taken under {}, \
                 this run uses {} (the skew bound changes the delay model; \
                 resume with the original --dp-async/--max-skew flags)",
                st.dp_mode.as_deref().unwrap_or("sync"),
                cfg.dp_mode().as_deref().unwrap_or("sync")
            );
        }
        if st.params.len() != params.len() {
            bail!(
                "checkpoint holds {} params, model has {}",
                st.params.len(),
                params.len()
            );
        }
        for (p, ts) in params.iter_mut().zip(&st.params) {
            ts.restore_into(p)?;
        }
        let snap = st.stash.as_ref().ok_or_else(|| {
            anyhow::anyhow!("sim checkpoint is missing its stash-ring snapshot")
        })?;
        stash.restore(
            snap.rings
                .iter()
                .map(|ring| ring.iter().map(|ts| ts.to_tensor()).collect())
                .collect(),
        )?;
        if st.opts.len() != 1 {
            bail!(
                "sim checkpoint holds {} optimizer states, expected 1",
                st.opts.len()
            );
        }
        opt.state_import(&st.opts[0])?;
        if st.train_cursors.len() != replicas {
            bail!(
                "checkpoint holds {} data cursors for {replicas} replicas",
                st.train_cursors.len()
            );
        }
        for (it, c) in train_iters.iter_mut().zip(&st.train_cursors) {
            it.restore(c)?;
        }
        if let Some(vc) = &st.val_cursor {
            val_iter.restore(vc)?;
        }
        result.losses = st.losses.clone();
        result.val_losses = st.val_losses.clone();
        if st.dispatches.len() == replicas {
            rep_dispatches.copy_from_slice(&st.dispatches);
        }
        start_step = st.step;
    }
    let t0 = std::time::Instant::now();
    let mut ckpt_latencies: Vec<(u64, f64)> = Vec::new();

    for t in (start_step + 1)..=cfg.steps as u64 {
        // One gradient per replica, all against the same stale views.
        // Schedules with micro_per_update > 1 draw that many
        // consecutive microbatches per replica and average — the
        // gradient-accumulation arity of the real action stream.
        let mut grad_sets: Vec<Vec<Tensor>> = Vec::with_capacity(replicas);
        let mut rep_losses: Vec<f32> = Vec::with_capacity(replicas);
        for (r, train_iter) in train_iters.iter_mut().enumerate() {
            let mut draw_sets: Vec<Vec<Tensor>> = Vec::with_capacity(draws);
            let mut draw_losses: Vec<f32> = Vec::with_capacity(draws);
            for _ in 0..draws {
                let (toks, tgts) = train_iter.next_batch();
                let tok_val = tokens_to_value(&toks, mcfg.batch, mcfg.seq)?;
                let tgt_val = tokens_to_value(&tgts, mcfg.batch, mcfg.seq)?;

                // Assemble forward weights per staleness mode.
                let (exec_name, mut inputs): (&str, Vec<Value>) = match cfg.stash
                {
                    StashMode::Stash => {
                        let ins: Result<Vec<_>> = (0..params.len())
                            .map(|i| tensor_to_value(stash.stale(i)))
                            .collect();
                        ("fwdbwd", ins?)
                    }
                    StashMode::NoStash => {
                        // forward at stale weights, backward ops at current
                        let mut ins = Vec::with_capacity(2 * params.len() + 2);
                        for i in 0..params.len() {
                            ins.push(tensor_to_value(stash.stale(i))?);
                        }
                        for p in &params {
                            ins.push(tensor_to_value(p)?);
                        }
                        ("fwdbwd_split", ins)
                    }
                    StashMode::Predict => {
                        let pred = predictor.as_ref().unwrap();
                        let ins: Result<Vec<_>> = params
                            .iter()
                            .enumerate()
                            .map(|(i, w)| {
                                tensor_to_value(&pred.predict(
                                    i,
                                    w,
                                    part.delay_of[i],
                                ))
                            })
                            .collect();
                        ("fwdbwd", ins?)
                    }
                };
                inputs.push(tok_val);
                inputs.push(tgt_val);

                let outs = rt.exec(exec_name, &inputs)?;
                rep_dispatches[r] += 1;
                draw_losses.push(value_scalar_f32(&outs[0])?);
                draw_sets.push(
                    outs[1..]
                        .iter()
                        .zip(man.params.iter())
                        .map(|(val, p)| value_to_tensor(val, &p.shape))
                        .collect::<Result<_>>()?,
                );
            }
            rep_losses.push(dp::mean_loss(&draw_losses)?);
            grad_sets.push(if draws == 1 {
                draw_sets.pop().unwrap()
            } else {
                dp::average(&draw_sets)?
            });
        }
        let loss = dp::mean_loss(&rep_losses)?;
        if rep_losses.iter().any(|l| !l.is_finite()) {
            result.diverged = true;
            break;
        }
        // All-reduce (averaging) barrier, then clip the reduced grad.
        let mut grads = if replicas == 1 {
            grad_sets.pop().unwrap()
        } else {
            dp::average(&grad_sets)?
        };
        clip_global_norm(&mut grads, cfg.grad_clip);

        // Apply the (delayed) gradient to the *current* weights.
        let before = match cfg.stash {
            StashMode::Predict => Some(params.clone()),
            _ => None,
        };
        let stale_view: Vec<Tensor> = match cfg.method {
            Method::DelayComp { .. } => {
                (0..params.len()).map(|i| stash.stale(i).clone()).collect()
            }
            _ => Vec::new(),
        };
        let ctx = StepCtx {
            t,
            lr: cfg.lr_at(t as u32),
            cfg,
            part: &part,
            stale: if stale_view.is_empty() { None } else { Some(&stale_view) },
            rt,
        };
        opt.step(&ctx, &mut params, &grads)?;
        if let (Some(pred), Some(before)) = (predictor.as_mut(), before.as_ref()) {
            pred.observe(before, &params);
        }
        stash.push(&params);
        observe(t, &params);

        result.losses.push(loss);
        if cfg.eval_every > 0 && (t as u32) % cfg.eval_every == 0 {
            let (vt, vg) = val_iter.next_batch();
            let mut ins: Vec<Value> =
                params.iter().map(tensor_to_value).collect::<Result<_>>()?;
            ins.push(tokens_to_value(&vt, mcfg.batch, mcfg.seq)?);
            ins.push(tokens_to_value(&vg, mcfg.batch, mcfg.seq)?);
            let vouts = rt.exec("eval_loss", &ins)?;
            result.val_losses.push((t as u32, value_scalar_f32(&vouts[0])?));
        }

        // Periodic crash-consistent snapshot (atomic write-rename).
        // Captured *after* the update, stash push and eval, so the
        // snapshot is exactly the loop state entering step t+1.
        if cfg.checkpoint_every > 0 && (t as u32) % cfg.checkpoint_every == 0 {
            let st = crate::checkpoint::RunState {
                version: crate::checkpoint::RUN_STATE_VERSION,
                flavor: "sim".to_string(),
                model: mcfg.name.clone(),
                method: cfg.method.name(),
                schedule: cfg.schedule.name(),
                stages: cfg.stages,
                replicas,
                seed: cfg.seed,
                steps_total: cfg.steps,
                step: t,
                params: params.iter().map(crate::checkpoint::TensorState::of).collect(),
                opts: vec![opt.state_export()?],
                stash: Some(crate::checkpoint::StashSnapshot {
                    rings: stash
                        .export()
                        .iter()
                        .map(|ring| {
                            ring.iter()
                                .map(crate::checkpoint::TensorState::of)
                                .collect()
                        })
                        .collect(),
                }),
                train_cursors: train_iters.iter().map(|it| it.cursor()).collect(),
                val_cursor: Some(val_iter.cursor()),
                losses: result.losses.clone(),
                val_losses: result.val_losses.clone(),
                dispatches: rep_dispatches.clone(),
                dp_mode: cfg.dp_mode(),
                dp_replica_states: None,
            };
            let dir = cfg.checkpoint_dir.clone().unwrap_or_else(|| "checkpoints".into());
            let path = crate::checkpoint::step_path(std::path::Path::new(&dir), t);
            let t_save = std::time::Instant::now();
            crate::checkpoint::save(&path, &st)?;
            ckpt_latencies.push((t, t_save.elapsed().as_secs_f64()));
            if cfg.log_every > 0 {
                crate::trace::progress(format!(
                    "  [ckpt] step {t} -> {}",
                    path.display()
                ));
            }
        }
    }
    result.wall_secs = t0.elapsed().as_secs_f64();
    result.dispatches = rt.total_dispatches();
    result.schedule = cfg.schedule.name();
    // Analytic bubble: per-update M for the synchronous schedules, the
    // whole finite run's microbatch count for the asynchronous ones
    // (their fill/drain amortizes over the run).
    let m_run = match cfg.schedule {
        ScheduleKind::OneFOneB | ScheduleKind::Amdp => {
            cfg.steps as usize * draws
        }
        _ => cfg.microbatches as usize,
    };
    result.bubble_frac_analytic = sched.bubble_frac(cfg.stages, m_run);
    // Deterministic schedule model of this run's action streams: what
    // the engine would execute for the same (P, M, steps), measured on
    // the unit-cost virtual clock.
    if let Ok(stats) = schedule::simulate(
        sched.as_ref(),
        cfg.stages,
        cfg.microbatches as usize,
        cfg.steps as u64,
    ) {
        result.bubble_frac_model = stats.bubble;
        result.realized_delays = schedule::summarize_delays(&stats.delays);
        // Staleness histogram from the virtual-clock delays: the sim
        // has no threaded workers, so the schedule model's realized
        // per-microbatch delays stand in for the engine's measurements
        // (they agree — the engine replays the same action streams).
        let mut hist: std::collections::BTreeMap<usize, Vec<u64>> =
            std::collections::BTreeMap::new();
        for &(c, _mb, d) in &stats.delays {
            let row = hist.entry(c).or_default();
            // The DP-skew component composes additively with the PP
            // delay — the sim genuinely served views that much older.
            let d = d as usize + dp_skew as usize;
            if row.len() <= d {
                row.resize(d + 1, 0);
            }
            row[d] += 1;
        }
        let rows: Vec<(usize, Vec<u64>)> = hist.into_iter().collect();
        // Replicas realize identical modeled delays in the sim; the
        // by-replica rows replicate the model so consumers see one
        // uniform shape across sim and engine results.
        result.staleness_by_replica = (0..replicas)
            .flat_map(|r| {
                rows.iter().map(move |(c, counts)| (r, *c, counts.clone()))
            })
            .collect();
        result.staleness_histogram = rows;
        // Virtual-clock span timeline (model trace): same Chrome span
        // format as the engine's wall-clock trace, 1 ms per unit slot.
        if let Some(path) = &cfg.trace {
            schedule::stats_to_trace(&stats).write_chrome(path)?;
        }
    }
    if let Some(path) = &cfg.metrics {
        let mut reg = crate::metrics::Registry::new();
        reg.inc("dispatches", result.dispatches);
        reg.gauge("bubble_frac_model", result.bubble_frac_model);
        for &(_, secs) in &ckpt_latencies {
            reg.observe("checkpoint_write_s", secs);
        }
        let ckpt_by_step: std::collections::HashMap<u64, f64> =
            ckpt_latencies.iter().copied().collect();
        for (i, &loss) in result.losses.iter().enumerate() {
            let t = i as u64 + 1;
            let mut fields: Vec<(&str, f64)> =
                vec![("loss", loss as f64), ("lr", cfg.lr_at(t as u32) as f64)];
            if let Some(&secs) = ckpt_by_step.get(&t) {
                fields.push(("checkpoint_write_s", secs));
            }
            reg.sample_step(t, &fields);
        }
        reg.write_jsonl(path)?;
    }
    // Per-replica breakdown (the sim is whole-model, so stage = 0).
    // State accounting models the distributed system the sim stands in
    // for — each replica owns a full optimizer-state copy, exactly as
    // on the engine — so the per-replica rows carry the full state and
    // the aggregate is scaled by R to match the engine's sum. (The sim
    // process itself holds a single shared copy.)
    result.optimizer_state_elems = opt.state_elems() * replicas;
    let updates = result.losses.len() as u64;
    for (r, &d) in rep_dispatches.iter().enumerate() {
        result.stage_counters.push(StageCounter {
            replica: r,
            stage: 0,
            dispatches: d,
            optimizer_state_elems: opt.state_elems(),
            updates,
        });
    }
    Ok((result, params))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stash_ring_serves_delayed_versions() {
        let p0 = vec![Tensor::full(&[2], 0.0), Tensor::full(&[2], 0.0)];
        // param 0: delay 2, param 1: delay 0
        let mut ring = StashRing::new(&p0, &[2, 0]);
        for v in 1..=5 {
            let pv = vec![Tensor::full(&[2], v as f32), Tensor::full(&[2], v as f32)];
            ring.push(&pv);
            // param 1 always sees the freshest version
            assert_eq!(ring.stale(1).data[0], v as f32);
        }
        // param 0 sees version 5-2 = 3
        assert_eq!(ring.stale(0).data[0], 3.0);
    }

    #[test]
    fn stash_ring_clamps_during_fill() {
        let p0 = vec![Tensor::full(&[1], 0.0)];
        let mut ring = StashRing::new(&p0, &[3]);
        ring.push(&[Tensor::full(&[1], 1.0)]);
        // only versions {0,1} exist; oldest (0) is served
        assert_eq!(ring.stale(0).data[0], 0.0);
    }

    #[test]
    fn stash_memory_bounded() {
        let p0 = vec![Tensor::zeros(&[10])];
        let mut ring = StashRing::new(&p0, &[2]);
        for v in 0..100 {
            ring.push(&[Tensor::full(&[10], v as f32)]);
        }
        assert_eq!(ring.stashed_elems(), 3 * 10);
    }

    #[test]
    fn predictor_extrapolates_linear_motion() {
        let w0 = vec![Tensor::full(&[1], 0.0)];
        let mut pred = Predictor::new(&w0);
        let mut prev = w0.clone();
        // constant velocity +1 per step
        for v in 1..=50 {
            let cur = vec![Tensor::full(&[1], v as f32)];
            pred.observe(&prev, &cur);
            prev = cur;
        }
        let hat = pred.predict(0, &prev[0], 3);
        // EMA velocity ≈ 1 ⇒ prediction ≈ 50 + 3
        assert!((hat.data[0] - 53.0).abs() < 0.5, "{}", hat.data[0]);
    }
}
