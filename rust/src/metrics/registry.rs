//! Named counters / gauges / histograms plus step-granularity samples,
//! emitted as JSONL (`--metrics out.jsonl`). Dependency-free: rows are
//! built with the in-crate `jsonio` writer, one JSON object per line,
//! each carrying a monotone `step` field.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use crate::jsonio::{self, Json};

/// Value-bucketed histogram for small non-negative quantities
/// (staleness in updates, queue depths). Values `>= OVERFLOW` (e.g.
/// checkpoint latencies in µs) land in the overflow bucket but still
/// contribute to `sum`/`max`, so mean and max stay exact.
#[derive(Clone, Debug, Default)]
pub struct Hist {
    pub counts: Vec<u64>,
    pub overflow: u64,
    pub n: u64,
    pub sum: f64,
    pub max: f64,
}

const OVERFLOW: usize = 256;

impl Hist {
    pub fn observe(&mut self, v: f64) {
        self.n += 1;
        self.sum += v;
        if v > self.max {
            self.max = v;
        }
        let bucket = if v < 0.0 { 0 } else { v.floor() as usize };
        if bucket < OVERFLOW {
            if self.counts.len() <= bucket {
                self.counts.resize(bucket + 1, 0);
            }
            self.counts[bucket] += 1;
        } else {
            self.overflow += 1;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Most-populated integer bucket (steady-state mode); ties break
    /// toward the smaller value.
    pub fn mode(&self) -> Option<usize> {
        self.counts
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(&a.0)))
            .filter(|(_, &c)| c > 0)
            .map(|(i, _)| i)
    }

    pub fn merge(&mut self, other: &Hist) {
        if self.counts.len() < other.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (i, c) in other.counts.iter().enumerate() {
            self.counts[i] += c;
        }
        self.overflow += other.overflow;
        self.n += other.n;
        self.sum += other.sum;
        if other.max > self.max {
            self.max = other.max;
        }
    }
}

/// A run's metric state: named counters (monotone u64), gauges (last
/// value wins), histograms, and an ordered list of per-step JSONL rows.
#[derive(Default)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Hist>,
    rows: Vec<Vec<(String, f64)>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    pub fn observe(&mut self, name: &str, v: f64) {
        self.hists.entry(name.to_string()).or_default().observe(v);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn hist(&self, name: &str) -> Option<&Hist> {
        self.hists.get(name)
    }

    /// Append one step-granularity sample row. `step` is stored as the
    /// first field of the JSONL object.
    pub fn sample_step(&mut self, step: u64, fields: &[(&str, f64)]) {
        let mut row: Vec<(String, f64)> = Vec::with_capacity(fields.len() + 1);
        row.push(("step".to_string(), step as f64));
        for (k, v) in fields {
            row.push((k.to_string(), *v));
        }
        self.rows.push(row);
    }

    /// One JSON object per sampled step, in insertion order.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for row in &self.rows {
            let pairs: Vec<(&str, Json)> = row.iter().map(|(k, v)| (k.as_str(), jsonio::num(*v))).collect();
            out.push_str(&jsonio::obj(pairs).to_string());
            out.push('\n');
        }
        out
    }

    pub fn write_jsonl(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_jsonl().as_bytes())?;
        Ok(())
    }

    /// Final summary: counters + gauges + per-histogram n/mean/max/mode,
    /// as a single JSON object (folded into logs or printed on stderr).
    pub fn summary_json(&self) -> String {
        let mut pairs: Vec<(&str, Json)> = Vec::new();
        let counters: Vec<(&str, Json)> = self
            .counters
            .iter()
            .map(|(k, v)| (k.as_str(), jsonio::num(*v as f64)))
            .collect();
        pairs.push(("counters", jsonio::obj(counters)));
        let gauges: Vec<(&str, Json)> = self
            .gauges
            .iter()
            .map(|(k, v)| (k.as_str(), jsonio::num(*v)))
            .collect();
        pairs.push(("gauges", jsonio::obj(gauges)));
        let hists: Vec<(&str, Json)> = self
            .hists
            .iter()
            .map(|(k, h)| {
                (
                    k.as_str(),
                    jsonio::obj(vec![
                        ("n", jsonio::num(h.n as f64)),
                        ("mean", jsonio::num(h.mean())),
                        ("max", jsonio::num(h.max)),
                        ("mode", h.mode().map(|m| jsonio::num(m as f64)).unwrap_or(Json::Null)),
                    ]),
                )
            })
            .collect();
        pairs.push(("histograms", jsonio::obj(hists)));
        jsonio::obj(pairs).to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_hist_mode_and_mean() {
        let mut h = Hist::default();
        for v in [1.0, 3.0, 3.0, 3.0, 2.0, 0.0] {
            h.observe(v);
        }
        assert_eq!(h.mode(), Some(3));
        assert!((h.mean() - 2.0).abs() < 1e-12);
        assert_eq!(h.n, 6);
        assert_eq!(h.max, 3.0);
        // overflow values keep mean/max exact
        h.observe(1e6);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.max, 1e6);
    }

    #[test]
    fn registry_jsonl_rows_parse_and_are_ordered() {
        let mut r = Registry::new();
        r.inc("dispatches", 5);
        r.gauge("tokens_per_sec", 123.0);
        r.observe("staleness", 2.0);
        r.sample_step(1, &[("loss", 4.0)]);
        r.sample_step(2, &[("loss", 3.5), ("staleness_mean", 1.0)]);
        let jsonl = r.to_jsonl();
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), 2);
        let mut prev = 0u64;
        for line in &lines {
            let p = Json::parse(line).unwrap();
            let step = p.at("step").as_usize() as u64;
            assert!(step > prev);
            prev = step;
        }
        let summary = Json::parse(&r.summary_json()).unwrap();
        assert_eq!(summary.at("counters").at("dispatches").as_usize(), 5);
        assert_eq!(summary.at("histograms").at("staleness").at("mode").as_usize(), 2);
    }

    #[test]
    fn registry_hist_merge() {
        let mut a = Hist::default();
        a.observe(1.0);
        let mut b = Hist::default();
        b.observe(1.0);
        b.observe(4.0);
        a.merge(&b);
        assert_eq!(a.n, 3);
        assert_eq!(a.mode(), Some(1));
        assert_eq!(a.counts[4], 1);
    }
}
