//! Run results, loss-curve utilities (smoothing, iterations-to-target,
//! slowdown ratios) and CSV output for the figure harness.

use std::io::Write;
use std::path::Path;

pub mod registry;
pub use registry::{Hist, Registry};

/// Per-(replica, stage) slice of a run's counters, so dispatch and
/// optimizer-state accounting stays comparable as the data-parallel
/// width R changes. On the engine the rows sum to the corresponding
/// [`RunResult`] aggregates (each worker reports its own runtime and
/// optimizer). The simulator's rows carry the per-replica *training*
/// dispatches only — its aggregate `dispatches` additionally counts
/// eval and optimizer-kernel executions, which are shared work with no
/// per-replica attribution.
#[derive(Clone, Debug, Default, serde::Serialize)]
pub struct StageCounter {
    /// Data-parallel replica id (0-based).
    pub replica: usize,
    /// Pipeline stage id (0-based; the simulator reports stage 0).
    pub stage: usize,
    /// Executable dispatches attributed to this replica x stage.
    pub dispatches: u64,
    /// Optimizer-state f32 elements held by this replica x stage.
    pub optimizer_state_elems: usize,
    /// Optimizer updates performed.
    pub updates: u64,
}

#[derive(Clone, Debug, Default, serde::Serialize)]
pub struct RunResult {
    pub method: String,
    pub stages: usize,
    /// Data-parallel replicas R the run used (1 = no DP).
    pub replicas: usize,
    /// Resolved kernel thread budget the run executed with
    /// (`runtime::pool`; 1 = fully serial). Bit-identical results at
    /// any value — recorded so perf numbers stay attributable.
    pub threads: usize,
    pub losses: Vec<f32>,
    pub val_losses: Vec<(u32, f32)>,
    pub wall_secs: f64,
    pub dispatches: u64,
    pub diverged: bool,
    pub param_count: usize,
    pub optimizer_state_elems: usize,
    /// Per-(replica, stage) counter breakdown (see [`StageCounter`]).
    pub stage_counters: Vec<StageCounter>,
    /// engine-only counters
    pub bubble_frac: f64,
    pub tokens_per_sec: f64,
    /// Pipeline schedule name ("1f1b", "gpipe", "interleaved:2", "amdp").
    pub schedule: String,
    /// Deterministic bubble fraction of the run's action streams on the
    /// unit-cost virtual clock (`pipeline::schedule::simulate`) — the
    /// engine replays the actions it executed; the simulator models the
    /// engine's streams for the same (P, M, steps). Unlike the
    /// wall-clock `bubble_frac`, this is noise-free and test-pinnable.
    pub bubble_frac_model: f64,
    /// The schedule's declared analytic bubble fraction for this run's
    /// (P, M) — what the conformance tests check `bubble_frac_model`
    /// against.
    pub bubble_frac_analytic: f64,
    /// Realized gradient-delay instrumentation, one row per chunk:
    /// (chunk id, microbatches observed, max realized delay in
    /// optimizer updates). Steady-state realized delay equals the
    /// schedule's declared per-chunk delay; fill microbatches clamp
    /// below it, so the max is the steady value once steps > P.
    pub realized_delays: Vec<(usize, u64, u32)>,
    /// Per-(replica, worker) busy/idle span summary from the trace
    /// recorder (engine runs only; empty for the simulator). Busy sums
    /// `Fwd/Bwd/Update/Checkpoint` span seconds, idle sums
    /// `Idle/Reduce`; `sum(idle)/sum(busy+idle)` agrees with the
    /// wall-clock `bubble_frac` because both are fed by the same
    /// `Instant` measurements.
    pub stage_spans: Vec<StageSpan>,
    /// Realized staleness histogram, one row per chunk, merged across
    /// all replicas via [`Hist::merge`]: `(chunk id, counts)` where
    /// `counts[d]` is how many microbatches saw a gradient delay of
    /// exactly `d` optimizer updates. The steady-state mode of each row
    /// equals the schedule's declared per-chunk delay. Per-replica
    /// breakdowns live in `staleness_by_replica` — replicas realize
    /// different delays under elastic kill/join and DP skew.
    pub staleness_histogram: Vec<(usize, Vec<u64>)>,
    /// Per-replica realized staleness rows `(replica, chunk, counts)`
    /// (engine runs; the simulator replicates its model histogram per
    /// replica). `staleness_histogram` is the per-chunk merge of these.
    pub staleness_by_replica: Vec<(usize, usize, Vec<u64>)>,
    /// Whether the run used bounded-skew asynchronous DP (`--dp-async`).
    pub dp_async: bool,
    /// The configured skew bound K (`--max-skew`; meaningful when
    /// `dp_async` is set). Realized skew never exceeds it — see
    /// `replica_counters[..].dp_max_skew`.
    pub max_skew: u32,
    /// Resolved kernel-thread budget per stage worker, indexed
    /// `replica * P + worker` (engine runs only). Sums to `threads`
    /// whenever `threads >= P * R`: the remainder of the division goes
    /// to the first workers instead of being stranded.
    pub worker_budgets: Vec<usize>,
    /// Per-replica throughput and DP-skew counters (engine runs only).
    pub replica_counters: Vec<ReplicaCounter>,
}

/// Per-replica throughput/skew summary (see
/// [`RunResult::replica_counters`]). Under synchronous DP the skew
/// fields are all zero; under `--dp-async` they pin the realized
/// bounded-staleness behavior (`dp_max_skew <= K`, test-enforced).
#[derive(Clone, Debug, Default, serde::Serialize)]
pub struct ReplicaCounter {
    /// Data-parallel replica id (0-based).
    pub replica: usize,
    /// Optimizer updates this replica completed.
    pub updates: u64,
    /// The replica's wall time: max over its stage workers of
    /// busy + idle seconds.
    pub wall_s: f64,
    /// `updates / wall_s` — per-replica throughput, so a straggler
    /// shows up directly instead of hiding in the group aggregate.
    pub steps_per_sec: f64,
    /// Realized DP-skew histogram: `hist[d]` counts folded peer
    /// contributions that were exactly `d` optimizer steps stale.
    pub dp_skew_hist: Vec<u64>,
    /// Largest realized DP skew — never exceeds the configured K.
    pub dp_max_skew: u32,
    /// Reduces where the skew bound forced a blocking wait.
    pub dp_stalls: u64,
}

/// Per-(replica, worker) span-derived timing summary (see
/// [`RunResult::stage_spans`]).
#[derive(Clone, Debug, Default, serde::Serialize)]
pub struct StageSpan {
    pub replica: usize,
    pub worker: usize,
    /// Seconds inside busy spans (`Fwd`/`Bwd`/`Update`/`Checkpoint`).
    pub busy_s: f64,
    /// Seconds inside wait spans (`Idle` recv waits + `Reduce`).
    pub idle_s: f64,
    /// Number of spans recorded on this worker's timeline.
    pub spans: u64,
}

impl RunResult {
    pub fn new(method: &str, stages: usize) -> Self {
        RunResult {
            method: method.to_string(),
            stages,
            replicas: 1,
            ..Default::default()
        }
    }

    pub fn final_loss(&self) -> f32 {
        smoothed(&self.losses, 20).last().copied().unwrap_or(f32::NAN)
    }
}

/// Trailing-window moving average.
pub fn smoothed(xs: &[f32], window: usize) -> Vec<f32> {
    if xs.is_empty() {
        return Vec::new();
    }
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = 0.0f64;
    for (i, &x) in xs.iter().enumerate() {
        acc += x as f64;
        if i >= window {
            acc -= xs[i - window] as f64;
        }
        let n = (i + 1).min(window);
        out.push((acc / n as f64) as f32);
    }
    out
}

/// First step (1-based) at which the smoothed loss reaches `target`.
pub fn iters_to_target(losses: &[f32], target: f32) -> Option<u32> {
    smoothed(losses, 20)
        .iter()
        .position(|&l| l <= target)
        .map(|i| i as u32 + 1)
}

/// Paper's slowdown metric: iterations-to-target at P stages relative to
/// P=1. `None` when either run never reaches the target.
pub fn slowdown(losses_p: &[f32], losses_1: &[f32], target: f32) -> Option<f32> {
    let a = iters_to_target(losses_p, target)? as f32;
    let b = iters_to_target(losses_1, target)? as f32;
    Some(a / b)
}

/// Iteration-reduction headline: how many fewer iterations method A
/// needs than B to reach B's final (smoothed) loss.
pub fn iter_reduction_vs(a: &RunResult, b: &RunResult) -> Option<f32> {
    let target = b.final_loss();
    let ia = iters_to_target(&a.losses, target)? as f32;
    let ib = b.losses.len() as f32;
    Some(1.0 - ia / ib)
}

// ---------------------------------------------------------------------------
// CSV output
// ---------------------------------------------------------------------------

pub struct Csv {
    file: std::fs::File,
}

impl Csv {
    pub fn create(path: impl AsRef<Path>, header: &str) -> std::io::Result<Csv> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut file = std::fs::File::create(path)?;
        writeln!(file, "{header}")?;
        Ok(Csv { file })
    }

    pub fn row(&mut self, cells: &[String]) -> std::io::Result<()> {
        let escaped: Vec<String> = cells.iter().map(|c| csv_escape(c)).collect();
        writeln!(self.file, "{}", escaped.join(","))
    }
}

/// RFC 4180 escaping: cells containing a comma, double quote, or line
/// break are quoted, with embedded quotes doubled. Plain cells pass
/// through unchanged so existing numeric/label output is byte-stable.
pub fn csv_escape(cell: &str) -> String {
    if cell.contains(',') || cell.contains('"') || cell.contains('\n') || cell.contains('\r') {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

/// Write a loss trajectory as step,loss CSV.
pub fn write_losses(path: impl AsRef<Path>, runs: &[&RunResult]) -> std::io::Result<()> {
    let mut csv = Csv::create(path, "method,stages,step,loss")?;
    for r in runs {
        for (i, &l) in r.losses.iter().enumerate() {
            csv.row(&[
                r.method.clone(),
                r.stages.to_string(),
                (i + 1).to_string(),
                format!("{l:.5}"),
            ])?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoothing_window() {
        let xs = vec![4.0, 2.0, 0.0, 0.0];
        let s = smoothed(&xs, 2);
        assert_eq!(s, vec![4.0, 3.0, 1.0, 0.0]);
    }

    #[test]
    fn iters_to_target_finds_first_crossing() {
        let losses: Vec<f32> = (0..100).map(|i| 5.0 - 0.04 * i as f32).collect();
        let it = iters_to_target(&losses, 3.0).unwrap();
        // smoothed lags the raw curve slightly
        assert!(it >= 51 && it <= 80, "{it}");
        assert!(iters_to_target(&losses, 0.5).is_none());
    }

    #[test]
    fn slowdown_ratio() {
        let fast: Vec<f32> = (0..100).map(|i| 5.0 - 0.1 * i as f32).collect();
        let slow: Vec<f32> = (0..400).map(|i| 5.0 - 0.025 * i as f32).collect();
        let s = slowdown(&slow, &fast, 3.0).unwrap();
        assert!(s > 2.5 && s < 5.0, "{s}");
    }

    #[test]
    fn run_result_serializes_to_json() {
        use serde::Serialize;
        let mut r = RunResult::new("adam", 4);
        r.replicas = 2;
        r.losses = vec![4.0, 3.5];
        r.val_losses = vec![(2, 3.75)];
        r.stage_counters.push(StageCounter {
            replica: 1,
            stage: 3,
            dispatches: 7,
            optimizer_state_elems: 10,
            updates: 2,
        });
        let json = r.to_json();
        let parsed = crate::jsonio::Json::parse(&json).unwrap();
        assert_eq!(parsed.at("method").as_str(), "adam");
        assert_eq!(parsed.at("replicas").as_usize(), 2);
        assert_eq!(parsed.at("losses").as_arr().len(), 2);
        let sc = &parsed.at("stage_counters").as_arr()[0];
        assert_eq!(sc.at("replica").as_usize(), 1);
        assert_eq!(sc.at("stage").as_usize(), 3);
        assert_eq!(sc.at("dispatches").as_usize(), 7);
    }

    #[test]
    fn csv_writes(){
        let dir = std::env::temp_dir().join("abrot_csv_test");
        let p = dir.join("x.csv");
        let mut c = Csv::create(&p, "a,b").unwrap();
        c.row(&["1".into(), "2".into()]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
    }

    #[test]
    fn csv_escapes_rfc4180() {
        assert_eq!(csv_escape("plain"), "plain");
        assert_eq!(csv_escape("interleaved:2"), "interleaved:2");
        assert_eq!(csv_escape("a,b"), "\"a,b\"");
        assert_eq!(csv_escape("say \"hi\""), "\"say \"\"hi\"\"\"");
        assert_eq!(csv_escape("two\nlines"), "\"two\nlines\"");

        let dir = std::env::temp_dir().join("abrot_csv_escape_test");
        let p = dir.join("x.csv");
        let mut c = Csv::create(&p, "label,value").unwrap();
        c.row(&["Fwd,chunk=0".into(), "1".into()]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "label,value\n\"Fwd,chunk=0\",1\n");
    }
}
