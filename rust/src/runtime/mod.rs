//! PJRT runtime: load `artifacts/<config>/*.hlo.txt`, compile on the CPU
//! client, execute from the training hot path.
//!
//! * Interchange is HLO **text** (jax ≥0.5 emits 64-bit-id protos that
//!   xla_extension 0.5.1 rejects; the text parser reassigns ids).
//! * All graphs were lowered with `return_tuple=True`, so every
//!   execution returns a 1-tuple literal that we decompose.
//! * Executables are compiled lazily and cached by name.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::jsonio::Json;
use crate::tensor::Tensor;

// ---------------------------------------------------------------------------
// Manifest (emitted by python/compile/aot.py)
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct MoeCfg {
    pub n_experts: usize,
    pub top_k: usize,
}

#[derive(Clone, Debug)]
pub struct ModelCfg {
    pub name: String,
    pub vocab: usize,
    pub seq: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_blocks: usize,
    pub d_ff: usize,
    pub batch: usize,
    pub moe: Option<MoeCfg>,
}

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub kind: String, // embed | gain | matrix | expert
    pub block: i64,   // -1 for global params
    pub rotated: bool,
}

#[derive(Clone, Debug)]
pub struct ShapeClass {
    pub name: String,
    pub count: usize,
    pub m: usize,
    pub n: usize,
}

#[derive(Clone, Debug)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "s32"
}

#[derive(Clone, Debug)]
pub struct ExecSpec {
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub cfg: ModelCfg,
    pub params: Vec<ParamSpec>,
    pub shape_classes: Vec<ShapeClass>,
    pub executables: HashMap<String, ExecSpec>,
}

fn io_spec(j: &Json) -> IoSpec {
    IoSpec {
        shape: j.at("shape").as_arr().iter().map(|x| x.as_usize()).collect(),
        dtype: j.at("dtype").as_str().to_string(),
    }
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest json: {e}"))?;
        let c = j.at("config");
        let moe = if c.at("moe").is_null() {
            None
        } else {
            Some(MoeCfg {
                n_experts: c.at("moe").at("n_experts").as_usize(),
                top_k: c.at("moe").at("top_k").as_usize(),
            })
        };
        let cfg = ModelCfg {
            name: c.at("name").as_str().to_string(),
            vocab: c.at("vocab").as_usize(),
            seq: c.at("seq").as_usize(),
            d_model: c.at("d_model").as_usize(),
            n_heads: c.at("n_heads").as_usize(),
            n_blocks: c.at("n_blocks").as_usize(),
            d_ff: c.at("d_ff").as_usize(),
            batch: c.at("batch").as_usize(),
            moe,
        };
        let params = j
            .at("params")
            .as_arr()
            .iter()
            .map(|p| ParamSpec {
                name: p.at("name").as_str().to_string(),
                shape: p.at("shape").as_arr().iter().map(|x| x.as_usize()).collect(),
                kind: p.at("kind").as_str().to_string(),
                block: p.at("block").as_i64(),
                rotated: p.at("rotated").as_bool(),
            })
            .collect();
        let shape_classes = j
            .at("shape_classes")
            .as_arr()
            .iter()
            .map(|s| ShapeClass {
                name: s.at("name").as_str().to_string(),
                count: s.at("count").as_usize(),
                m: s.at("m").as_usize(),
                n: s.at("n").as_usize(),
            })
            .collect();
        let mut executables = HashMap::new();
        if let Json::Obj(m) = j.at("executables") {
            for (name, e) in m {
                executables.insert(
                    name.clone(),
                    ExecSpec {
                        file: e.at("file").as_str().to_string(),
                        inputs: e.at("inputs").as_arr().iter().map(io_spec).collect(),
                        outputs: e.at("outputs").as_arr().iter().map(io_spec).collect(),
                    },
                );
            }
        }
        Ok(Manifest { cfg, params, shape_classes, executables })
    }

    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.params.iter().position(|p| p.name == name)
    }

    pub fn total_params(&self) -> usize {
        self.params.iter().map(|p| p.shape.iter().product::<usize>()).sum()
    }
}

// ---------------------------------------------------------------------------
// Literal conversion helpers
// ---------------------------------------------------------------------------

/// Tensor → literal with a single memcpy: `create_from_shape_and_
/// untyped_data` builds the shaped literal directly (the obvious
/// vec1+reshape route costs two copies + a reshape literal — §Perf L3:
/// 147 µs → ~30 µs for a 256×256 tensor).
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let bytes = unsafe {
        std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        &t.shape,
        bytes,
    )?)
}

pub fn tokens_to_literal(tokens: &[i32], batch: usize, seq: usize) -> Result<xla::Literal> {
    assert_eq!(tokens.len(), batch * seq);
    let bytes = unsafe {
        std::slice::from_raw_parts(tokens.as_ptr() as *const u8, tokens.len() * 4)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        &[batch, seq],
        bytes,
    )?)
}

pub fn literal_to_tensor(lit: &xla::Literal, shape: &[usize]) -> Result<Tensor> {
    let data = lit.to_vec::<f32>()?;
    Ok(Tensor::new(shape.to_vec(), data))
}

pub fn literal_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.to_vec::<f32>()?[0])
}

// ---------------------------------------------------------------------------
// Runtime
// ---------------------------------------------------------------------------

pub struct Runtime {
    pub client: xla::PjRtClient,
    pub dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
    /// Per-executable dispatch counters (perf accounting).
    pub exec_count: RefCell<HashMap<String, u64>>,
}

impl Runtime {
    /// Open the artifacts directory for one model config.
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            cache: RefCell::new(HashMap::new()),
            exec_count: RefCell::new(HashMap::new()),
        })
    }

    /// Open `<root>/<config>` (e.g. `artifacts/tiny32`).
    pub fn open_config(root: impl AsRef<Path>, config: &str) -> Result<Runtime> {
        Runtime::open(root.as_ref().join(config))
    }

    pub fn cfg(&self) -> &ModelCfg {
        &self.manifest.cfg
    }

    /// Lazily compile (and cache) an executable by manifest name.
    pub fn executable(&self, name: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .manifest
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("no executable {name:?} in manifest"))?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(self.client.compile(&comp)?);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    pub fn has_executable(&self, name: &str) -> bool {
        self.manifest.executables.contains_key(name)
    }

    /// Execute by name; returns the decomposed output tuple as literals.
    pub fn exec(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let spec = self
            .manifest
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("no executable {name:?}"))?;
        if inputs.len() != spec.inputs.len() {
            bail!("{name}: got {} inputs, manifest says {}", inputs.len(), spec.inputs.len());
        }
        let exe = self.executable(name)?;
        *self.exec_count.borrow_mut().entry(name.to_string()).or_insert(0) += 1;
        // execute_b with explicitly-managed device buffers: the crate's
        // literal-taking `execute` leaks its temporary input buffers in
        // the C glue (~input size per dispatch — OOM over long runs;
        // EXPERIMENTS.md §Perf). Our PjRtBuffers are dropped right after.
        let in_bufs: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|l| self.client.buffer_from_host_literal(None, l))
            .collect::<std::result::Result<_, _>>()?;
        let bufs = exe.execute_b::<xla::PjRtBuffer>(&in_bufs)?;
        drop(in_bufs);
        let mut result = bufs[0][0].to_literal_sync()?;
        drop(bufs);
        Ok(result.decompose_tuple()?)
    }

    /// Execute a graph whose outputs are all f32 tensors.
    pub fn exec_tensors(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<Tensor>> {
        let out_specs: Vec<IoSpec> = self
            .manifest
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("no executable {name:?}"))?
            .outputs
            .clone();
        let outs = self.exec(name, inputs)?;
        outs.iter()
            .zip(&out_specs)
            .map(|(lit, os)| literal_to_tensor(lit, &os.shape))
            .collect()
    }

    pub fn total_dispatches(&self) -> u64 {
        self.exec_count.borrow().values().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn manifest_loads_micro() {
        let m = Manifest::load(&artifacts_root().join("micro")).unwrap();
        assert_eq!(m.cfg.name, "micro");
        assert_eq!(m.cfg.n_blocks, 2);
        assert_eq!(m.params[0].name, "tok_emb");
        assert_eq!(m.params[0].shape, vec![64, 16]);
        assert!(m.executables.contains_key("fwdbwd"));
        assert_eq!(m.shape_classes.len(), 4);
        // schema: 2 embeds + 2 blocks * 6 + gf + head
        assert_eq!(m.params.len(), 2 + 2 * 6 + 2);
    }

    #[test]
    fn fwdbwd_runs_and_loss_is_ln_vocab() {
        let rt = Runtime::open(artifacts_root().join("micro")).unwrap();
        let cfg = rt.cfg().clone();
        let params = crate::model::init_params(&rt.manifest, 0);
        let mut inputs: Vec<xla::Literal> =
            params.iter().map(|t| tensor_to_literal(t).unwrap()).collect();
        let toks: Vec<i32> =
            (0..cfg.batch * cfg.seq).map(|i| (i % cfg.vocab) as i32).collect();
        inputs.push(tokens_to_literal(&toks, cfg.batch, cfg.seq).unwrap());
        inputs.push(tokens_to_literal(&toks, cfg.batch, cfg.seq).unwrap());
        let outs = rt.exec("fwdbwd", &inputs).unwrap();
        assert_eq!(outs.len(), 1 + params.len());
        let loss = literal_scalar_f32(&outs[0]).unwrap();
        let expect = (cfg.vocab as f32).ln();
        assert!((loss - expect).abs() < 0.5, "loss {loss} vs ln V {expect}");
        for (lit, p) in outs[1..].iter().zip(&params) {
            let g = literal_to_tensor(lit, &p.shape).unwrap();
            assert!(g.all_finite());
        }
    }

    #[test]
    fn executable_cache_hits() {
        let rt = Runtime::open(artifacts_root().join("micro")).unwrap();
        let a = rt.executable("eval_loss").unwrap();
        let b = rt.executable("eval_loss").unwrap();
        assert!(std::rc::Rc::ptr_eq(&a, &b));
        assert_eq!(rt.total_dispatches(), 0); // compiling is not dispatching
    }

    #[test]
    fn missing_executable_errors() {
        let rt = Runtime::open(artifacts_root().join("micro")).unwrap();
        assert!(rt.exec("nope", &[]).is_err());
    }

    #[test]
    fn input_arity_checked() {
        let rt = Runtime::open(artifacts_root().join("micro")).unwrap();
        assert!(rt.exec("fwdbwd", &[]).is_err());
    }
}
