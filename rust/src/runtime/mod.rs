//! Execution runtime: a pluggable [`Backend`] behind a uniform
//! exec-by-name interface.
//!
//! Two backends implement the same set of named executables (`fwdbwd`,
//! `block_fwd`, `rot_adam_bi_wqkv`, ...):
//!
//! * [`native`] — pure-Rust reference kernels (transformer forward /
//!   backward, batched rotated-Adam / eigen / Muon updates). The
//!   default: zero external dependencies, builds and trains offline.
//! * `pjrt` (cargo feature `pjrt`) — the original HLO path: load
//!   `artifacts/<config>/*.hlo.txt` lowered by `python/compile/aot.py`,
//!   compile on the PJRT CPU client, execute from the training loop.
//!
//! [`Runtime::open`] picks the backend: a directory containing a
//! `manifest.json` uses the artifact manifest (and, when the `pjrt`
//! feature is enabled, the PJRT backend); otherwise the final path
//! component is treated as a built-in model-config name (see
//! [`presets`]) and the native backend is used.
//!
//! Data crosses the backend boundary as [`Value`]s — dense f32 tensors
//! or i32 token grids — never as backend-specific buffer types.

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod pool;
pub mod presets;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::jsonio::Json;
use crate::tensor::Tensor;

// ---------------------------------------------------------------------------
// Manifest: the model/param/executable schema
// ---------------------------------------------------------------------------

/// Mixture-of-Experts settings of a model config.
#[derive(Clone, Debug)]
pub struct MoeCfg {
    /// Number of experts per block.
    pub n_experts: usize,
    /// Experts routed per token.
    pub top_k: usize,
}

/// Model hyperparameters (mirrors `python/compile/configs.py`).
#[derive(Clone, Debug)]
pub struct ModelCfg {
    /// Config name (`micro`, `tiny32`, ...).
    pub name: String,
    /// Vocabulary size.
    pub vocab: usize,
    /// Sequence length.
    pub seq: usize,
    /// Residual width.
    pub d_model: usize,
    /// Attention heads (`d_model % n_heads == 0`).
    pub n_heads: usize,
    /// Transformer blocks.
    pub n_blocks: usize,
    /// MLP hidden width.
    pub d_ff: usize,
    /// Microbatch size.
    pub batch: usize,
    /// `Some` for MoE variants.
    pub moe: Option<MoeCfg>,
}

impl ModelCfg {
    /// Per-head width.
    pub fn head_dim(&self) -> usize {
        debug_assert_eq!(self.d_model % self.n_heads, 0);
        self.d_model / self.n_heads
    }
}

/// One parameter tensor in flatten order.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    /// Name (`tok_emb`, `b3.wqkv`, ...).
    pub name: String,
    /// Tensor shape.
    pub shape: Vec<usize>,
    /// `embed | gain | matrix | expert`.
    pub kind: String,
    /// Owning block index; -1 for global params.
    pub block: i64,
    /// Eligible for basis rotation (attention + MLP projections only).
    pub rotated: bool,
}

impl ParamSpec {
    /// Batched-optimizer slots this parameter contributes to shape
    /// class `class` (0 if it is not a member): expert tensors fold
    /// their expert axis into `shape[0]` slots, plain rotated matrices
    /// contribute one. The single source of truth for the slot
    /// convention shared by `model::class_maps`, [`Manifest::restrict`]
    /// and the preset consistency tests.
    pub fn slots_in_class(&self, class: &str) -> usize {
        if !self.rotated || !self.name.ends_with(&format!(".{class}")) {
            return 0;
        }
        if self.kind == "expert" {
            self.shape[0]
        } else {
            1
        }
    }
}

/// A batch of same-shaped rotated matrices updated by one executable
/// call (e.g. the 32 `wqkv` matrices of `tiny32`).
#[derive(Clone, Debug)]
pub struct ShapeClass {
    /// Class name (`wqkv`, `wo`, `w1`, `w2`, `w1e`, `w2e`).
    pub name: String,
    /// Matrices in the batch (blocks, or blocks x experts for MoE).
    pub count: usize,
    /// Rows.
    pub m: usize,
    /// Columns.
    pub n: usize,
}

/// Input/output tensor spec of an executable.
#[derive(Clone, Debug)]
pub struct IoSpec {
    /// Tensor shape (empty = scalar).
    pub shape: Vec<usize>,
    /// `"f32"` or `"s32"`.
    pub dtype: String,
}

/// One named executable: its artifact file (PJRT only; empty for
/// built-in manifests) and its I/O signature.
#[derive(Clone, Debug)]
pub struct ExecSpec {
    /// HLO text file relative to the artifact dir ("" for native).
    pub file: String,
    /// Input signature.
    pub inputs: Vec<IoSpec>,
    /// Output signature.
    pub outputs: Vec<IoSpec>,
}

/// The full schema one [`Runtime`] serves: model config, parameter
/// flatten order, rotated shape classes and the executable table.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Model hyperparameters.
    pub cfg: ModelCfg,
    /// Parameters in flatten order (the single source of truth every
    /// executable's input order follows).
    pub params: Vec<ParamSpec>,
    /// Rotated-matrix shape classes.
    pub shape_classes: Vec<ShapeClass>,
    /// Executable table.
    pub executables: HashMap<String, ExecSpec>,
}

fn io_spec(j: &Json) -> IoSpec {
    IoSpec {
        shape: j.at("shape").as_arr().iter().map(|x| x.as_usize()).collect(),
        dtype: j.at("dtype").as_str().to_string(),
    }
}

impl Manifest {
    /// Load `manifest.json` from an artifact directory (emitted by
    /// `python/compile/aot.py`).
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest json: {e}"))?;
        let c = j.at("config");
        let moe = if c.at("moe").is_null() {
            None
        } else {
            Some(MoeCfg {
                n_experts: c.at("moe").at("n_experts").as_usize(),
                top_k: c.at("moe").at("top_k").as_usize(),
            })
        };
        let cfg = ModelCfg {
            name: c.at("name").as_str().to_string(),
            vocab: c.at("vocab").as_usize(),
            seq: c.at("seq").as_usize(),
            d_model: c.at("d_model").as_usize(),
            n_heads: c.at("n_heads").as_usize(),
            n_blocks: c.at("n_blocks").as_usize(),
            d_ff: c.at("d_ff").as_usize(),
            batch: c.at("batch").as_usize(),
            moe,
        };
        let params = j
            .at("params")
            .as_arr()
            .iter()
            .map(|p| ParamSpec {
                name: p.at("name").as_str().to_string(),
                shape: p.at("shape").as_arr().iter().map(|x| x.as_usize()).collect(),
                kind: p.at("kind").as_str().to_string(),
                block: p.at("block").as_i64(),
                rotated: p.at("rotated").as_bool(),
            })
            .collect();
        let shape_classes = j
            .at("shape_classes")
            .as_arr()
            .iter()
            .map(|s| ShapeClass {
                name: s.at("name").as_str().to_string(),
                count: s.at("count").as_usize(),
                m: s.at("m").as_usize(),
                n: s.at("n").as_usize(),
            })
            .collect();
        let mut executables = HashMap::new();
        if let Json::Obj(m) = j.at("executables") {
            for (name, e) in m {
                executables.insert(
                    name.clone(),
                    ExecSpec {
                        file: e.at("file").as_str().to_string(),
                        inputs: e.at("inputs").as_arr().iter().map(io_spec).collect(),
                        outputs: e.at("outputs").as_arr().iter().map(io_spec).collect(),
                    },
                );
            }
        }
        Ok(Manifest { cfg, params, shape_classes, executables })
    }

    /// Build the manifest of a built-in model config (no artifacts on
    /// disk needed) — see [`presets`] for the registry.
    pub fn builtin(config: &str) -> Result<Manifest> {
        presets::builtin_manifest(config)
    }

    /// Resolve a model directory the way [`Runtime::open`] does:
    /// `dir/manifest.json` when present, otherwise the built-in config
    /// named by the final path component.
    pub fn resolve(dir: &Path) -> Result<Manifest> {
        if dir.join("manifest.json").exists() {
            return Manifest::load(dir);
        }
        let name = dir
            .file_name()
            .and_then(|s| s.to_str())
            .ok_or_else(|| anyhow!("bad model path {dir:?}"))?;
        Manifest::builtin(name)
    }

    /// Index of a parameter by name.
    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.params.iter().position(|p| p.name == name)
    }

    /// Stage-local view: keep only the parameters at the given manifest
    /// indices (order preserved), recompute the rotated shape classes
    /// for the surviving parameters (classes with no stage-resident
    /// slot are dropped), and regenerate the batched optimizer
    /// executables with the restricted batch counts. Model-graph
    /// executables (per-block engine graphs etc.) are kept as-is; the
    /// whole-model graphs (`fwdbwd`, ...) keep their full-model arity
    /// and must not be dispatched through a restricted manifest.
    ///
    /// This is how each engine stage builds its own optimizer over only
    /// the parameters it owns (`pipeline::engine`).
    ///
    /// Backend note: the regenerated optimizer ExecSpecs have no HLO
    /// artifact file, so they execute on the native backend only; a
    /// PJRT-artifact runtime dispatching one of them errors loudly
    /// ("no HLO artifact") rather than mis-executing a full-batch
    /// graph. Running the engine's matrix optimizers on the PJRT path
    /// would need per-stage-count artifacts from `aot.py`.
    pub fn restrict(&self, keep: &[usize]) -> Manifest {
        let params: Vec<ParamSpec> =
            keep.iter().map(|&i| self.params[i].clone()).collect();
        let shape_classes: Vec<ShapeClass> = self
            .shape_classes
            .iter()
            .filter_map(|sc| {
                let count: usize =
                    params.iter().map(|p| p.slots_in_class(&sc.name)).sum();
                if count == 0 {
                    None
                } else {
                    Some(ShapeClass { count, ..sc.clone() })
                }
            })
            .collect();
        let mut executables = self.executables.clone();
        for sc in &self.shape_classes {
            for name in presets::class_exec_names(&sc.name) {
                executables.remove(&name);
            }
        }
        executables.extend(presets::optimizer_exec_table(&shape_classes));
        Manifest { cfg: self.cfg.clone(), params, shape_classes, executables }
    }

    /// Total scalar parameter count.
    pub fn total_params(&self) -> usize {
        self.params.iter().map(|p| p.shape.iter().product::<usize>()).sum()
    }
}

// ---------------------------------------------------------------------------
// Value: the backend-neutral tensor interchange type
// ---------------------------------------------------------------------------

/// A value crossing the [`Backend`] boundary: a dense f32 tensor or an
/// i32 token grid. Replaces the PJRT-specific `xla::Literal` on every
/// call site; the PJRT backend converts at its own edge.
#[derive(Clone, Debug)]
pub enum Value {
    /// Dense f32 tensor (scalars use an empty shape).
    F32(Tensor),
    /// i32 tensor (token / target grids).
    I32 {
        /// Tensor shape.
        shape: Vec<usize>,
        /// Row-major elements.
        data: Vec<i32>,
    },
}

impl Value {
    /// `"f32"` or `"s32"` (matching [`IoSpec::dtype`]).
    pub fn dtype(&self) -> &'static str {
        match self {
            Value::F32(_) => "f32",
            Value::I32 { .. } => "s32",
        }
    }

    /// Shape of the carried tensor.
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::I32 { shape, .. } => shape,
        }
    }

    /// Borrow as an f32 tensor.
    pub fn as_tensor(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32 { .. } => bail!("expected f32 value, got s32"),
        }
    }

    /// Borrow as i32 elements.
    pub fn as_tokens(&self) -> Result<&[i32]> {
        match self {
            Value::I32 { data, .. } => Ok(data),
            Value::F32(_) => bail!("expected s32 value, got f32"),
        }
    }

    /// Copy out the f32 elements.
    pub fn to_f32(&self) -> Result<Vec<f32>> {
        Ok(self.as_tensor()?.data.clone())
    }
}

/// Wrap a tensor as a [`Value`] (kept `Result`-returning for drop-in
/// compatibility with the old literal-conversion call sites).
pub fn tensor_to_value(t: &Tensor) -> Result<Value> {
    Ok(Value::F32(t.clone()))
}

/// Wrap a `(batch, seq)` token grid as a [`Value`].
pub fn tokens_to_value(tokens: &[i32], batch: usize, seq: usize) -> Result<Value> {
    if tokens.len() != batch * seq {
        bail!("token grid: {} elements for shape [{batch}, {seq}]", tokens.len());
    }
    Ok(Value::I32 { shape: vec![batch, seq], data: tokens.to_vec() })
}

/// Unwrap a [`Value`] into a tensor of the given shape (element count
/// must match; the shape may differ, e.g. flattening a batch axis).
pub fn value_to_tensor(v: &Value, shape: &[usize]) -> Result<Tensor> {
    let t = v.as_tensor()?;
    let want: usize = shape.iter().product();
    if want != t.data.len() {
        bail!("value has {} elements, target shape {shape:?} wants {want}", t.data.len());
    }
    Ok(Tensor::new(shape.to_vec(), t.data.clone()))
}

/// Read a scalar f32 result (e.g. a loss output).
pub fn value_scalar_f32(v: &Value) -> Result<f32> {
    let t = v.as_tensor()?;
    t.data.first().copied().ok_or_else(|| anyhow!("empty value, expected scalar"))
}

// ---------------------------------------------------------------------------
// Backend trait + Runtime facade
// ---------------------------------------------------------------------------

/// A compute backend: executes manifest-named graphs on [`Value`]s.
///
/// Implementations: [`native::NativeBackend`] (pure Rust, default) and
/// `pjrt::PjrtBackend` (HLO artifacts on the PJRT CPU client, cargo
/// feature `pjrt`). The threaded 1F1B engine gives each stage thread
/// its own boxed backend, so backends need not be `Send` or `Sync`.
pub trait Backend {
    /// Short backend tag for logs (`"native"` / `"pjrt"`).
    fn kind(&self) -> &'static str;

    /// Execute `name` with `inputs` in manifest order; returns the
    /// outputs in manifest order. Arity is pre-checked by [`Runtime`].
    fn exec(&self, man: &Manifest, name: &str, inputs: &[Value]) -> Result<Vec<Value>>;
}

/// The coordinator's handle to one model config on one backend:
/// manifest + boxed [`Backend`] + dispatch accounting.
pub struct Runtime {
    /// The schema this runtime serves.
    pub manifest: Manifest,
    backend: Box<dyn Backend>,
    /// Per-executable dispatch counters (perf accounting).
    pub exec_count: RefCell<HashMap<String, u64>>,
}

impl Runtime {
    /// Open a model by directory. `dir/manifest.json` present: use the
    /// artifact manifest (PJRT backend when the `pjrt` feature is on,
    /// native otherwise). Absent: the final path component names a
    /// built-in config served natively — `Runtime::open("artifacts/micro")`
    /// works on a machine that has never run Python.
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref();
        // One predicate decides both the manifest source and the
        // backend, so the two cannot drift apart.
        let from_artifacts = dir.join("manifest.json").exists();
        let manifest = if from_artifacts {
            Manifest::load(dir)?
        } else {
            let name = dir
                .file_name()
                .and_then(|s| s.to_str())
                .ok_or_else(|| anyhow!("bad model path {dir:?}"))?;
            Manifest::builtin(name)?
        };
        #[cfg(feature = "pjrt")]
        let backend: Box<dyn Backend> = if from_artifacts {
            Box::new(pjrt::PjrtBackend::open(dir)?)
        } else {
            Box::new(native::NativeBackend)
        };
        #[cfg(not(feature = "pjrt"))]
        let backend: Box<dyn Backend> = Box::new(native::NativeBackend);
        Ok(Runtime::from_parts(manifest, backend))
    }

    /// Open `<root>/<config>` (e.g. `artifacts/tiny32`).
    pub fn open_config(root: impl AsRef<Path>, config: &str) -> Result<Runtime> {
        Runtime::open(root.as_ref().join(config))
    }

    /// Open a built-in config on the native backend explicitly.
    pub fn native(config: &str) -> Result<Runtime> {
        let manifest = Manifest::builtin(config)?;
        Ok(Runtime::from_parts(manifest, Box::new(native::NativeBackend)))
    }

    /// Assemble from an explicit manifest + backend (used by backend
    /// constructors and tests).
    pub fn from_parts(manifest: Manifest, backend: Box<dyn Backend>) -> Runtime {
        Runtime { manifest, backend, exec_count: RefCell::new(HashMap::new()) }
    }

    /// Rewrap the same backend behind a stage-local manifest (see
    /// [`Manifest::restrict`]); dispatch counters start fresh.
    pub fn restricted(self, keep: &[usize]) -> Runtime {
        let manifest = self.manifest.restrict(keep);
        Runtime::from_parts(manifest, self.backend)
    }

    /// Open a model directory and immediately restrict it to the given
    /// manifest indices — the per-(replica x stage) view every engine
    /// worker thread builds its optimizer over.
    pub fn open_restricted(dir: impl AsRef<Path>, keep: &[usize]) -> Result<Runtime> {
        Ok(Runtime::open(dir)?.restricted(keep))
    }

    /// The model config this runtime serves.
    pub fn cfg(&self) -> &ModelCfg {
        &self.manifest.cfg
    }

    /// Which backend executes dispatches (`"native"` / `"pjrt"`).
    pub fn backend_kind(&self) -> &'static str {
        self.backend.kind()
    }

    /// Whether the manifest lists an executable by this name.
    pub fn has_executable(&self, name: &str) -> bool {
        self.manifest.executables.contains_key(name)
    }

    /// Execute by name; returns the decomposed output tuple.
    pub fn exec(&self, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let spec = self
            .manifest
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("no executable {name:?} in manifest"))?;
        if inputs.len() != spec.inputs.len() {
            bail!("{name}: got {} inputs, manifest says {}", inputs.len(), spec.inputs.len());
        }
        *self.exec_count.borrow_mut().entry(name.to_string()).or_insert(0) += 1;
        self.backend.exec(&self.manifest, name, inputs)
    }

    /// Execute a graph whose outputs are all f32 tensors, reshaped to
    /// the manifest's output specs.
    pub fn exec_tensors(&self, name: &str, inputs: &[Value]) -> Result<Vec<Tensor>> {
        let out_specs: Vec<IoSpec> = self
            .manifest
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("no executable {name:?}"))?
            .outputs
            .clone();
        let outs = self.exec(name, inputs)?;
        outs.iter()
            .zip(&out_specs)
            .map(|(v, os)| value_to_tensor(v, &os.shape))
            .collect()
    }

    /// Total executions dispatched through this runtime.
    pub fn total_dispatches(&self) -> u64 {
        self.exec_count.borrow().values().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn builtin_manifest_micro_schema() {
        let m = Manifest::builtin("micro").unwrap();
        assert_eq!(m.cfg.name, "micro");
        assert_eq!(m.cfg.n_blocks, 2);
        assert_eq!(m.params[0].name, "tok_emb");
        assert_eq!(m.params[0].shape, vec![64, 16]);
        assert!(m.executables.contains_key("fwdbwd"));
        assert_eq!(m.shape_classes.len(), 4);
        // schema: 2 embeds + 2 blocks * 6 + gf + head
        assert_eq!(m.params.len(), 2 + 2 * 6 + 2);
    }

    #[test]
    fn open_without_artifacts_uses_native_backend() {
        // artifacts/micro does not exist in a clean checkout — open()
        // must still serve the built-in config natively.
        let rt = Runtime::open(artifacts_root().join("micro")).unwrap();
        assert_eq!(rt.backend_kind(), "native");
        assert_eq!(rt.cfg().name, "micro");
    }

    #[test]
    fn open_unknown_config_errors() {
        assert!(Runtime::open(artifacts_root().join("no_such_model")).is_err());
    }

    #[test]
    fn fwdbwd_runs_and_loss_is_ln_vocab() {
        let rt = Runtime::open(artifacts_root().join("micro")).unwrap();
        let cfg = rt.cfg().clone();
        let params = crate::model::init_params(&rt.manifest, 0);
        let mut inputs: Vec<Value> =
            params.iter().map(|t| tensor_to_value(t).unwrap()).collect();
        let toks: Vec<i32> =
            (0..cfg.batch * cfg.seq).map(|i| (i % cfg.vocab) as i32).collect();
        inputs.push(tokens_to_value(&toks, cfg.batch, cfg.seq).unwrap());
        inputs.push(tokens_to_value(&toks, cfg.batch, cfg.seq).unwrap());
        let outs = rt.exec("fwdbwd", &inputs).unwrap();
        assert_eq!(outs.len(), 1 + params.len());
        let loss = value_scalar_f32(&outs[0]).unwrap();
        let expect = (cfg.vocab as f32).ln();
        assert!((loss - expect).abs() < 0.5, "loss {loss} vs ln V {expect}");
        for (v, p) in outs[1..].iter().zip(&params) {
            let g = value_to_tensor(v, &p.shape).unwrap();
            assert!(g.all_finite());
        }
    }

    #[test]
    fn dispatch_counter_counts() {
        let rt = Runtime::native("micro").unwrap();
        assert_eq!(rt.total_dispatches(), 0);
        let cfg = rt.cfg().clone();
        let params = crate::model::init_params(&rt.manifest, 0);
        let mut inputs: Vec<Value> =
            params.iter().map(|t| tensor_to_value(t).unwrap()).collect();
        let toks: Vec<i32> = vec![0; cfg.batch * cfg.seq];
        inputs.push(tokens_to_value(&toks, cfg.batch, cfg.seq).unwrap());
        inputs.push(tokens_to_value(&toks, cfg.batch, cfg.seq).unwrap());
        rt.exec("eval_loss", &inputs).unwrap();
        rt.exec("eval_loss", &inputs).unwrap();
        assert_eq!(rt.total_dispatches(), 2);
        assert_eq!(rt.exec_count.borrow()["eval_loss"], 2);
    }

    #[test]
    fn missing_executable_errors() {
        let rt = Runtime::native("micro").unwrap();
        assert!(rt.exec("nope", &[]).is_err());
    }

    #[test]
    fn input_arity_checked() {
        let rt = Runtime::native("micro").unwrap();
        assert!(rt.exec("fwdbwd", &[]).is_err());
    }

    #[test]
    fn restricted_manifest_has_stage_local_classes() {
        // micro: 2 blocks; keep block 1 + gf/head (what stage 1 of a
        // 2-stage pipeline owns).
        let m = Manifest::builtin("micro").unwrap();
        let keep: Vec<usize> = (0..m.params.len())
            .filter(|&i| m.params[i].block == 1 || m.params[i].name == "gf"
                || m.params[i].name == "head")
            .collect();
        let r = m.restrict(&keep);
        assert_eq!(r.params.len(), 6 + 2);
        // each rotated class keeps exactly block 1's slot
        assert_eq!(r.shape_classes.len(), 4);
        for sc in &r.shape_classes {
            assert_eq!(sc.count, 1, "class {}", sc.name);
        }
        // optimizer executables regenerated with the local batch count
        assert_eq!(r.executables["rot_adam_bi_wqkv"].inputs[0].shape[0], 1);
        assert_eq!(r.executables["muon_wo"].inputs[0].shape[0], 1);
        // per-block engine graphs survive untouched
        assert!(r.executables.contains_key("block_fwd"));
        // class maps over the restricted manifest are local + consistent
        let maps = crate::model::class_maps(&r);
        assert_eq!(maps.len(), 4);
        for cm in &maps {
            assert_eq!(cm.slots.len(), 1);
            assert!(r.params[cm.slots[0].param].rotated);
        }
        // keeping only non-rotated params drops every class
        let keep_gf: Vec<usize> = vec![m.param_index("gf").unwrap()];
        let r2 = m.restrict(&keep_gf);
        assert!(r2.shape_classes.is_empty());
        assert!(!r2.executables.contains_key("rot_adam_bi_wqkv"));
    }

    #[test]
    fn restricted_runtime_executes_local_optimizer_graphs() {
        let rt = Runtime::native("micro").unwrap();
        let keep: Vec<usize> = (0..rt.manifest.params.len())
            .filter(|&i| rt.manifest.params[i].block == 0)
            .collect();
        let rt = rt.restricted(&keep);
        assert_eq!(rt.cfg().name, "micro");
        // muon on a 1-slot stack round-trips through the backend
        let (m, n) = (16usize, 48usize);
        let inputs = vec![
            Value::F32(Tensor::zeros(&[1, m, n])),
            Value::F32(Tensor::ones(&[1, m, n])),
            Value::F32(Tensor::zeros(&[1, 8])),
        ];
        let outs = rt.exec_tensors("muon_wqkv", &inputs).unwrap();
        assert_eq!(outs[0].shape, vec![1, m, n]);
        assert!(outs[1].all_finite());
    }

    #[test]
    fn value_roundtrips() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let v = tensor_to_value(&t).unwrap();
        assert_eq!(v.dtype(), "f32");
        assert_eq!(v.shape(), &[2, 3]);
        let back = value_to_tensor(&v, &[3, 2]).unwrap();
        assert_eq!(back.shape, vec![3, 2]);
        assert_eq!(back.data, t.data);
        assert!(value_to_tensor(&v, &[4]).is_err());

        let toks = tokens_to_value(&[1, 2, 3, 4], 2, 2).unwrap();
        assert_eq!(toks.dtype(), "s32");
        assert_eq!(toks.as_tokens().unwrap(), &[1, 2, 3, 4]);
        assert!(toks.as_tensor().is_err());
        assert!(tokens_to_value(&[1, 2, 3], 2, 2).is_err());

        let scalar = Value::F32(Tensor::new(vec![], vec![7.5]));
        assert_eq!(value_scalar_f32(&scalar).unwrap(), 7.5);
    }
}
