//! PJRT/HLO backend (cargo feature `pjrt`): load
//! `artifacts/<config>/*.hlo.txt` lowered by `python/compile/aot.py`,
//! compile on the PJRT CPU client, execute from the training hot path.
//!
//! * Interchange is HLO **text** (jax >= 0.5 emits 64-bit-id protos
//!   that xla_extension 0.5.1 rejects; the text parser reassigns ids).
//! * All graphs were lowered with `return_tuple=True`, so every
//!   execution returns a 1-tuple literal that we decompose.
//! * Executables are compiled lazily and cached by name.
//!
//! The default build links the compile-only `xla` stub in
//! `rust/vendor/xla`; point the `xla` dependency at a real xla-rs
//! checkout (xla_extension 0.5.1) to actually execute artifacts.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use crate::tensor::Tensor;

use super::{Backend, Manifest, Value};

// ---------------------------------------------------------------------------
// Value <-> literal conversion (the PJRT edge of the Backend boundary)
// ---------------------------------------------------------------------------

/// Tensor -> literal with a single memcpy: `create_from_shape_and_
/// untyped_data` builds the shaped literal directly (the obvious
/// vec1+reshape route costs two copies + a reshape literal — measured
/// 147 us -> ~30 us for a 256x256 tensor).
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let bytes = unsafe {
        std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        &t.shape,
        bytes,
    )?)
}

/// Token grid -> s32 literal.
pub fn tokens_to_literal(tokens: &[i32], batch: usize, seq: usize) -> Result<xla::Literal> {
    assert_eq!(tokens.len(), batch * seq);
    let bytes = unsafe {
        std::slice::from_raw_parts(tokens.as_ptr() as *const u8, tokens.len() * 4)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        &[batch, seq],
        bytes,
    )?)
}

fn value_to_literal(v: &Value) -> Result<xla::Literal> {
    match v {
        Value::F32(t) => tensor_to_literal(t),
        Value::I32 { shape, data } => {
            let bytes = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
            };
            Ok(xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::S32,
                shape,
                bytes,
            )?)
        }
    }
}

fn literal_to_value(lit: &xla::Literal, spec: &super::IoSpec) -> Result<Value> {
    match spec.dtype.as_str() {
        "f32" => {
            let data = lit.to_vec::<f32>()?;
            Ok(Value::F32(Tensor::new(spec.shape.clone(), data)))
        }
        "s32" => {
            let data = lit.to_vec::<i32>()?;
            Ok(Value::I32 { shape: spec.shape.clone(), data })
        }
        other => bail!("unsupported output dtype {other:?} in manifest spec"),
    }
}

// ---------------------------------------------------------------------------
// Backend
// ---------------------------------------------------------------------------

/// HLO artifacts + PJRT CPU client, one per (stage) thread — the xla
/// client is not `Send`, which is why the engine boxes a backend per
/// stage instead of sharing one.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl PjrtBackend {
    /// Open the artifacts directory for one model config.
    pub fn open(dir: impl AsRef<Path>) -> Result<PjrtBackend> {
        let dir = dir.as_ref().to_path_buf();
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtBackend { client, dir, cache: RefCell::new(HashMap::new()) })
    }

    /// Lazily compile (and cache) an executable by manifest name.
    fn executable(
        &self,
        man: &Manifest,
        name: &str,
    ) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = man
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("no executable {name:?} in manifest"))?;
        if spec.file.is_empty() {
            bail!("executable {name:?} has no HLO artifact (built-in manifest?)");
        }
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }
}

impl Backend for PjrtBackend {
    fn kind(&self) -> &'static str {
        "pjrt"
    }

    fn exec(&self, man: &Manifest, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let spec = man
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("no executable {name:?}"))?;
        let exe = self.executable(man, name)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(value_to_literal).collect::<Result<_>>()?;
        // execute_b with explicitly-managed device buffers: the crate's
        // literal-taking `execute` leaks its temporary input buffers in
        // the C glue (~input size per dispatch — OOM over long runs).
        // Our PjRtBuffers are dropped right after.
        let in_bufs: Vec<xla::PjRtBuffer> = literals
            .iter()
            .map(|l| self.client.buffer_from_host_literal(None, l))
            .collect::<std::result::Result<_, _>>()?;
        let bufs = exe.execute_b::<xla::PjRtBuffer>(&in_bufs)?;
        drop(in_bufs);
        let mut result = bufs[0][0].to_literal_sync()?;
        drop(bufs);
        let outs = result.decompose_tuple()?;
        outs.iter()
            .zip(&spec.outputs)
            .map(|(lit, os)| literal_to_value(lit, os))
            .collect()
    }
}
