//! Built-in model configs and their derived manifests.
//!
//! Mirrors `python/compile/configs.py` — same names, same
//! hyperparameters, same parameter flatten order — so the native
//! backend serves exactly the schema the Python AOT path would emit,
//! without any `artifacts/` directory on disk.
//!
//! Dense block layout (per block): `g1, wqkv, wo, g2, w1, w2`.
//! MoE   block layout (per block): `g1, wqkv, wo, g2, router, w1e, w2e`.
//! Global layout: `tok_emb, pos_emb, <blocks...>, gf, head`.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use super::{ExecSpec, IoSpec, Manifest, ModelCfg, MoeCfg, ParamSpec, ShapeClass};

fn cfg(
    name: &str,
    vocab: usize,
    seq: usize,
    d_model: usize,
    n_heads: usize,
    n_blocks: usize,
    d_ff: usize,
    batch: usize,
    moe: Option<MoeCfg>,
) -> ModelCfg {
    ModelCfg {
        name: name.to_string(),
        vocab,
        seq,
        d_model,
        n_heads,
        n_blocks,
        d_ff,
        batch,
        moe,
    }
}

/// All built-in configs, in registry order.
pub fn builtin_configs() -> Vec<ModelCfg> {
    vec![
        // Unit/integration-test scale (~40k params).
        cfg("micro", 64, 16, 16, 2, 2, 64, 2, None),
        // Workhorse for the P in {1,4,8,16,32} staleness experiments.
        cfg("tiny32", 256, 48, 48, 4, 32, 192, 4, None),
        // Depth-scaling family (Fig 6): same width, depth = P.
        cfg("tiny4", 256, 48, 48, 4, 4, 192, 4, None),
        cfg("tiny8", 256, 48, 48, 4, 8, 192, 4, None),
        cfg("tiny16", 256, 48, 48, 4, 16, 192, 4, None),
        // Width-scaling pair (Fig 7 analog) at P=8.
        cfg("small", 512, 64, 128, 4, 8, 512, 4, None),
        cfg("wide", 512, 64, 256, 8, 8, 1024, 4, None),
        // End-to-end driver (~13M params).
        cfg("e2e", 2048, 128, 256, 8, 16, 1024, 4, None),
        // Pico family: figure-harness workhorses on a single core.
        cfg("pico4", 128, 32, 32, 4, 4, 128, 2, None),
        cfg("pico8", 128, 32, 32, 4, 8, 128, 2, None),
        cfg("pico16", 128, 32, 32, 4, 16, 128, 2, None),
        cfg("pico32", 128, 32, 32, 4, 32, 128, 2, None),
        cfg("wide8", 128, 32, 96, 4, 8, 384, 2, None),
        // MoE variants (Fig 21).
        cfg("moe_pico", 128, 32, 32, 4, 8, 64, 2, Some(MoeCfg { n_experts: 4, top_k: 2 })),
        cfg("moe_micro", 64, 16, 16, 2, 2, 32, 2, Some(MoeCfg { n_experts: 4, top_k: 2 })),
        cfg("moe_tiny", 256, 48, 48, 4, 8, 96, 4, Some(MoeCfg { n_experts: 8, top_k: 2 })),
    ]
}

/// Names of all built-in configs.
pub fn builtin_names() -> Vec<String> {
    builtin_configs().into_iter().map(|c| c.name).collect()
}

/// Look up one built-in config by name.
pub fn builtin_model_cfg(name: &str) -> Result<ModelCfg> {
    builtin_configs()
        .into_iter()
        .find(|c| c.name == name)
        .ok_or_else(|| {
            anyhow!("unknown model config {name:?}; built-ins: {:?}", builtin_names())
        })
}

/// Build the full manifest (params, shape classes, executables) of a
/// built-in config.
pub fn builtin_manifest(name: &str) -> Result<Manifest> {
    Ok(manifest_from_cfg(&builtin_model_cfg(name)?))
}

/// Parameter flatten order of a config (`configs.ModelConfig.param_schema`).
pub fn param_schema(cfg: &ModelCfg) -> Vec<ParamSpec> {
    let (v, s, d, f) = (cfg.vocab, cfg.seq, cfg.d_model, cfg.d_ff);
    let spec = |name: String, shape: Vec<usize>, kind: &str, block: i64, rotated: bool| {
        ParamSpec { name, shape, kind: kind.to_string(), block, rotated }
    };
    let mut out = vec![
        spec("tok_emb".into(), vec![v, d], "embed", -1, false),
        spec("pos_emb".into(), vec![s, d], "embed", -1, false),
    ];
    for b in 0..cfg.n_blocks {
        let bi = b as i64;
        out.push(spec(format!("b{b}.g1"), vec![d], "gain", bi, false));
        out.push(spec(format!("b{b}.wqkv"), vec![d, 3 * d], "matrix", bi, true));
        out.push(spec(format!("b{b}.wo"), vec![d, d], "matrix", bi, true));
        out.push(spec(format!("b{b}.g2"), vec![d], "gain", bi, false));
        match &cfg.moe {
            None => {
                out.push(spec(format!("b{b}.w1"), vec![d, f], "matrix", bi, true));
                out.push(spec(format!("b{b}.w2"), vec![f, d], "matrix", bi, true));
            }
            Some(moe) => {
                let e = moe.n_experts;
                out.push(spec(format!("b{b}.router"), vec![d, e], "matrix", bi, false));
                out.push(spec(format!("b{b}.w1e"), vec![e, d, f], "expert", bi, true));
                out.push(spec(format!("b{b}.w2e"), vec![e, f, d], "expert", bi, true));
            }
        }
    }
    out.push(spec("gf".into(), vec![d], "gain", -1, false));
    out.push(spec("head".into(), vec![d, v], "matrix", -1, false));
    out
}

/// Rotated-matrix shape classes (`configs.ModelConfig.shape_classes`).
pub fn shape_classes(cfg: &ModelCfg) -> Vec<ShapeClass> {
    let (d, f, l) = (cfg.d_model, cfg.d_ff, cfg.n_blocks);
    let sc = |name: &str, count: usize, m: usize, n: usize| ShapeClass {
        name: name.to_string(),
        count,
        m,
        n,
    };
    match &cfg.moe {
        None => vec![
            sc("wqkv", l, d, 3 * d),
            sc("wo", l, d, d),
            sc("w1", l, d, f),
            sc("w2", l, f, d),
        ],
        Some(moe) => {
            let e = moe.n_experts;
            vec![
                sc("wqkv", l, d, 3 * d),
                sc("wo", l, d, d),
                sc("w1e", l * e, d, f),
                sc("w2e", l * e, f, d),
            ]
        }
    }
}

fn f32s(shape: &[usize]) -> IoSpec {
    IoSpec { shape: shape.to_vec(), dtype: "f32".to_string() }
}

fn s32s(batch: usize, seq: usize) -> IoSpec {
    IoSpec { shape: vec![batch, seq], dtype: "s32".to_string() }
}

fn exec(inputs: Vec<IoSpec>, outputs: Vec<IoSpec>) -> ExecSpec {
    ExecSpec { file: String::new(), inputs, outputs }
}

/// Derive the full manifest — including the executable table the
/// native backend serves — from a model config.
pub fn manifest_from_cfg(cfg: &ModelCfg) -> Manifest {
    let params = param_schema(cfg);
    let classes = shape_classes(cfg);
    let (b, s, d, f, v) = (cfg.batch, cfg.seq, cfg.d_model, cfg.d_ff, cfg.vocab);
    let scalar = f32s(&[]);
    let act = f32s(&[b, s, d]);
    let toks = s32s(b, s);
    let param_specs: Vec<IoSpec> = params.iter().map(|p| f32s(&p.shape)).collect();

    let mut ex: HashMap<String, ExecSpec> = HashMap::new();

    // --- whole-model training graphs (dense + MoE) ---
    let mut fwdbwd_in = param_specs.clone();
    fwdbwd_in.push(toks.clone());
    fwdbwd_in.push(toks.clone());
    let mut fwdbwd_out = vec![scalar.clone()];
    fwdbwd_out.extend(param_specs.clone());
    ex.insert("fwdbwd".into(), exec(fwdbwd_in.clone(), fwdbwd_out.clone()));
    ex.insert("eval_loss".into(), exec(fwdbwd_in.clone(), vec![scalar.clone()]));

    if cfg.moe.is_none() {
        // Split-weight (no-stash) backward: stale forward weights, then
        // current backward weights.
        let mut split_in = param_specs.clone();
        split_in.extend(param_specs.clone());
        split_in.push(toks.clone());
        split_in.push(toks.clone());
        ex.insert("fwdbwd_split".into(), exec(split_in, fwdbwd_out));

        // Hessian-vector product (params, vec, tokens, targets).
        let mut hvp_in = param_specs.clone();
        hvp_in.extend(param_specs.clone());
        hvp_in.push(toks.clone());
        hvp_in.push(toks.clone());
        ex.insert("hvp".into(), exec(hvp_in, param_specs.clone()));
    }

    // --- per-block engine graphs (dense and MoE: the threaded 1F1B
    //     engine executes both block flavours) ---
    ex.insert(
        "embed_fwd".into(),
        exec(vec![f32s(&[v, d]), f32s(&[s, d]), toks.clone()], vec![act.clone()]),
    );
    ex.insert(
        "embed_bwd".into(),
        exec(vec![toks.clone(), act.clone()], vec![f32s(&[v, d]), f32s(&[s, d])]),
    );
    let block_params = match &cfg.moe {
        None => vec![
            f32s(&[d]),
            f32s(&[d, 3 * d]),
            f32s(&[d, d]),
            f32s(&[d]),
            f32s(&[d, f]),
            f32s(&[f, d]),
        ],
        Some(moe) => {
            let e = moe.n_experts;
            vec![
                f32s(&[d]),
                f32s(&[d, 3 * d]),
                f32s(&[d, d]),
                f32s(&[d]),
                f32s(&[d, e]),
                f32s(&[e, d, f]),
                f32s(&[e, f, d]),
            ]
        }
    };
    let mut bf_in = block_params.clone();
    bf_in.push(act.clone());
    ex.insert("block_fwd".into(), exec(bf_in.clone(), vec![act.clone()]));
    let mut bb_in = bf_in;
    bb_in.push(act.clone());
    let mut bb_out = vec![act.clone()];
    bb_out.extend(block_params);
    ex.insert("block_bwd".into(), exec(bb_in, bb_out));
    ex.insert(
        "head_fwdbwd".into(),
        exec(
            vec![f32s(&[d]), f32s(&[d, v]), act.clone(), toks.clone()],
            vec![scalar.clone(), act.clone(), f32s(&[d]), f32s(&[d, v])],
        ),
    );
    // loss-only head (the engine's pipelined validation pass)
    ex.insert(
        "head_loss".into(),
        exec(
            vec![f32s(&[d]), f32s(&[d, v]), act.clone(), toks.clone()],
            vec![scalar.clone()],
        ),
    );

    ex.extend(optimizer_exec_table(&classes));

    Manifest { cfg: cfg.clone(), params, shape_classes: classes, executables: ex }
}

/// Names of the batched optimizer executables serving one shape class.
pub fn class_exec_names(class: &str) -> Vec<String> {
    let mut names = Vec::with_capacity(9);
    for tag in ["bi", "uni"] {
        for kind in ["rot_adam", "soap", "eigen2nd", "eigen1st"] {
            names.push(format!("{kind}_{tag}_{class}"));
        }
    }
    names.push(format!("muon_{class}"));
    names
}

/// The batched per-shape-class optimizer graphs (rot_adam / soap /
/// eigen / muon) for a given class list. Factored out so stage-local
/// manifests (`Manifest::restrict`) can regenerate them with
/// stage-local batch counts.
pub fn optimizer_exec_table(classes: &[ShapeClass]) -> HashMap<String, ExecSpec> {
    let mut ex: HashMap<String, ExecSpec> = HashMap::new();
    for sc in classes {
        let (nb, m, n) = (sc.count, sc.m, sc.n);
        let mat = f32s(&[nb, m, n]);
        let um = f32s(&[nb, m, m]);
        let vn = f32s(&[nb, n, n]);
        let scal = f32s(&[nb, 8]);
        for tag in ["bi", "uni"] {
            ex.insert(
                format!("rot_adam_{tag}_{}", sc.name),
                exec(
                    vec![mat.clone(), mat.clone(), mat.clone(), mat.clone(),
                         um.clone(), vn.clone(), scal.clone()],
                    vec![mat.clone(), mat.clone(), mat.clone()],
                ),
            );
            ex.insert(
                format!("soap_{tag}_{}", sc.name),
                exec(
                    vec![mat.clone(), mat.clone(), mat.clone(), mat.clone(),
                         um.clone(), vn.clone(), scal.clone()],
                    vec![mat.clone(), mat.clone(), mat.clone()],
                ),
            );
            ex.insert(
                format!("eigen2nd_{tag}_{}", sc.name),
                exec(
                    vec![um.clone(), vn.clone(), mat.clone(), um.clone(),
                         vn.clone(), scal.clone()],
                    vec![um.clone(), vn.clone(), um.clone(), vn.clone()],
                ),
            );
            ex.insert(
                format!("eigen1st_{tag}_{}", sc.name),
                exec(
                    vec![mat.clone(), um.clone(), vn.clone(), scal.clone()],
                    vec![um.clone(), vn.clone()],
                ),
            );
        }
        ex.insert(
            format!("muon_{}", sc.name),
            exec(
                vec![mat.clone(), mat.clone(), scal.clone()],
                vec![mat.clone(), mat.clone()],
            ),
        );
    }
    ex
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_builtin_manifests_are_consistent() {
        for c in builtin_configs() {
            let m = manifest_from_cfg(&c);
            assert_eq!(m.cfg.name, c.name);
            // schema size: 2 embeds + per-block params + gf + head
            let per_block = if c.moe.is_some() { 7 } else { 6 };
            assert_eq!(m.params.len(), 2 + c.n_blocks * per_block + 2, "{}", c.name);
            // every rotated class slot count matches the schema
            for sc in &m.shape_classes {
                let slots: usize =
                    m.params.iter().map(|p| p.slots_in_class(&sc.name)).sum();
                assert_eq!(slots, sc.count, "{} class {}", c.name, sc.name);
            }
            assert!(m.executables.contains_key("fwdbwd"));
            assert!(m.executables.contains_key("eval_loss"));
            // per-block engine graphs exist for dense AND MoE configs
            for name in
                ["embed_fwd", "embed_bwd", "block_fwd", "block_bwd", "head_fwdbwd", "head_loss"]
            {
                assert!(m.executables.contains_key(name), "{} missing {name}", c.name);
            }
            let n_bp = if c.moe.is_some() { 7 } else { 6 };
            assert_eq!(m.executables["block_fwd"].inputs.len(), n_bp + 1, "{}", c.name);
            assert_eq!(m.executables["block_bwd"].outputs.len(), n_bp + 1, "{}", c.name);
            if c.moe.is_none() {
                assert!(m.executables.contains_key("fwdbwd_split"));
                assert!(m.executables.contains_key("hvp"));
            }
            assert!(m.executables.contains_key("muon_wqkv"));
            assert!(m.executables.contains_key("rot_adam_bi_wqkv"));
        }
    }

    #[test]
    fn unknown_config_lists_builtins() {
        let err = builtin_model_cfg("nope").unwrap_err().to_string();
        assert!(err.contains("micro"), "{err}");
    }

    #[test]
    fn head_dim_divides() {
        for c in builtin_configs() {
            assert_eq!(c.d_model % c.n_heads, 0, "{}", c.name);
            assert_eq!(c.head_dim() * c.n_heads, c.d_model);
        }
    }
}
