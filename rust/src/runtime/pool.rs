//! Scoped worker pool behind every parallel kernel.
//!
//! Design constraints (ARCHITECTURE §8):
//!
//! * **dependency-free** — `std::thread::scope` only; workers live for
//!   one kernel dispatch and borrow directly from the caller's stack,
//!   so there is no persistent pool state to poison or shut down;
//! * **bit-exact** — the pool only ever splits work across *disjoint*
//!   `&mut` output regions; the per-element accumulation order is owned
//!   by the kernels and never depends on the thread count, so
//!   `--threads 1` and `--threads N` produce identical bits;
//! * **oversubscription-free** — every worker runs with a kernel
//!   budget of 1 (nested kernels execute inline), and the engine
//!   divides the process budget across its P×R stage workers, so
//!   `workers × kernel threads` never exceeds the configured budget.
//!
//! Budget resolution for a kernel dispatched on the current thread:
//! thread-local override ([`install_budget`], used by engine workers
//! and pool workers) → process-wide setting ([`set_global_threads`],
//! installed by the CLI entry points) → auto (`ABROT_THREADS` env
//! override, else `std::thread::available_parallelism()`).

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Thread-count knob threaded from the CLI / `TrainCfg` down to the
/// kernel layer. `0` means auto: the `ABROT_THREADS` env override if
/// set, otherwise `std::thread::available_parallelism()`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ThreadCfg {
    /// Requested kernel threads; 0 = auto.
    pub threads: usize,
}

impl ThreadCfg {
    /// Wrap an explicit request (0 = auto).
    pub fn new(threads: usize) -> Self {
        ThreadCfg { threads }
    }

    /// The concrete thread count this config resolves to.
    pub fn resolve(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            auto_threads()
        }
    }
}

/// The auto thread count: `ABROT_THREADS` (the CI matrix override) if
/// set to a positive integer, else `available_parallelism()`, else 1.
/// Cached after the first call — kernels consult this per dispatch.
pub fn auto_threads() -> usize {
    static CACHE: AtomicUsize = AtomicUsize::new(0);
    let cached = CACHE.load(Ordering::Relaxed);
    if cached > 0 {
        return cached;
    }
    let n = std::env::var("ABROT_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        });
    CACHE.store(n, Ordering::Relaxed);
    n
}

/// Process-wide kernel thread budget; 0 = unset (fall through to auto).
static GLOBAL: AtomicUsize = AtomicUsize::new(0);

/// Install the process-wide kernel thread budget (CLI entry points and
/// the bench binaries call this once at startup).
pub fn set_global_threads(cfg: ThreadCfg) {
    GLOBAL.store(cfg.resolve(), Ordering::Relaxed);
}

thread_local! {
    /// Per-thread kernel budget override; 0 = unset.
    static BUDGET: Cell<usize> = const { Cell::new(0) };
}

/// Scoped per-thread override of the kernel thread budget; restores
/// the previous value on drop. Engine stage workers install
/// `max(1, threads / (P·R))` so stage workers × kernel threads never
/// oversubscribes the machine; pool workers install 1 so nested
/// kernels run inline.
pub struct BudgetGuard {
    prev: usize,
}

/// Install a kernel budget of `n` (clamped to ≥ 1) on the current
/// thread until the returned guard drops.
pub fn install_budget(n: usize) -> BudgetGuard {
    let prev = BUDGET.with(|b| b.replace(n.max(1)));
    BudgetGuard { prev }
}

impl Drop for BudgetGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        BUDGET.with(|b| b.set(prev));
    }
}

/// Thread budget for a kernel dispatched on the current thread:
/// worker-local override → process-wide setting → auto.
pub fn kernel_threads() -> usize {
    let local = BUDGET.with(|b| b.get());
    if local > 0 {
        return local;
    }
    let global = GLOBAL.load(Ordering::Relaxed);
    if global > 0 {
        return global;
    }
    auto_threads()
}

/// The scoped worker pool. Stateless: every [`Pool::scope`] call opens
/// a fresh `std::thread::scope`, so worker lifetimes are bounded by
/// the call and tasks may borrow from the caller's stack.
pub struct Pool;

impl Pool {
    /// Run `tasks` to completion across at most `threads` scoped
    /// workers. Tasks are split into contiguous near-equal groups, one
    /// worker per group; the first group runs on the calling thread.
    ///
    /// With `threads <= 1` every task runs inline on the calling
    /// thread — the exact `--threads 1` path, no scope, no spawns.
    /// A single task also runs inline, but *without* clamping the
    /// caller's kernel budget, so kernels nested under it may still
    /// parallelize.
    pub fn scope<F>(threads: usize, tasks: Vec<F>)
    where
        F: FnOnce() + Send,
    {
        let n = tasks.len();
        if n == 0 {
            return;
        }
        if n == 1 {
            for t in tasks {
                t();
            }
            return;
        }
        let nt = threads.min(n).max(1);
        if nt == 1 {
            for t in tasks {
                t();
            }
            return;
        }
        let per = n.div_ceil(nt);
        let mut groups: Vec<Vec<F>> = Vec::with_capacity(nt);
        let mut it = tasks.into_iter();
        loop {
            let g: Vec<F> = it.by_ref().take(per).collect();
            if g.is_empty() {
                break;
            }
            groups.push(g);
        }
        std::thread::scope(|s| {
            let mut groups = groups.into_iter();
            let first = groups.next().unwrap();
            for g in groups {
                s.spawn(move || {
                    let _b = install_budget(1);
                    for t in g {
                        t();
                    }
                });
            }
            let _b = install_budget(1);
            for t in first {
                t();
            }
        });
    }
}

/// Split `out` into whole-row groups (`row` elements each) across at
/// most `threads` scoped workers and call `f(first_row, rows_slice)`
/// on each group. The groups are disjoint `&mut` regions, so this is
/// safe-Rust data parallelism with no synchronization beyond the scope
/// join; `f` must not touch rows outside its slice.
///
/// With `threads <= 1` (or a single row) `f` is called once with the
/// whole buffer on the calling thread — the exact `--threads 1` path.
pub fn par_rows<F>(threads: usize, out: &mut [f32], row: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert!(row > 0 && out.len() % row == 0);
    let m = out.len() / row;
    if m == 0 {
        return;
    }
    let nt = threads.min(m).max(1);
    if nt == 1 {
        f(0, out);
        return;
    }
    let per = m.div_ceil(nt);
    let fr = &f;
    std::thread::scope(|s| {
        for (g, piece) in out.chunks_mut(per * row).enumerate() {
            s.spawn(move || {
                let _b = install_budget(1);
                fr(g * per, piece);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_cfg_resolves_auto_and_explicit() {
        assert_eq!(ThreadCfg::new(3).resolve(), 3);
        assert!(ThreadCfg::new(0).resolve() >= 1);
        assert_eq!(ThreadCfg::default().threads, 0);
    }

    #[test]
    fn budget_guard_restores_previous_value() {
        let outer = install_budget(5);
        assert_eq!(kernel_threads(), 5);
        {
            let _inner = install_budget(2);
            assert_eq!(kernel_threads(), 2);
        }
        assert_eq!(kernel_threads(), 5);
        drop(outer);
    }

    #[test]
    fn scope_runs_every_task_exactly_once() {
        use std::sync::atomic::AtomicU64;
        for threads in [1usize, 2, 3, 7, 16] {
            let hits = AtomicU64::new(0);
            let tasks: Vec<_> = (0..13)
                .map(|i: u64| {
                    let hits = &hits;
                    move || {
                        hits.fetch_add(1 << (i * 4 % 64), Ordering::Relaxed);
                    }
                })
                .collect();
            Pool::scope(threads, tasks);
            // each task contributes a distinct nibble pattern; the sum
            // is only right if every task ran exactly once
            let want: u64 = (0..13u64).map(|i| 1u64 << (i * 4 % 64)).sum();
            assert_eq!(hits.load(Ordering::Relaxed), want, "threads={threads}");
        }
    }

    #[test]
    fn par_rows_covers_disjoint_rows() {
        for threads in [1usize, 2, 5, 8] {
            let mut out = vec![0.0f32; 7 * 3];
            par_rows(threads, &mut out, 3, |first_row, rows| {
                for (r, row) in rows.chunks_mut(3).enumerate() {
                    for (c, x) in row.iter_mut().enumerate() {
                        *x = (first_row + r) as f32 * 10.0 + c as f32;
                    }
                }
            });
            for i in 0..7 {
                for c in 0..3 {
                    assert_eq!(out[i * 3 + c], i as f32 * 10.0 + c as f32);
                }
            }
        }
    }

    #[test]
    fn workers_run_nested_kernels_inline() {
        // inside a pool worker the kernel budget is 1, so nested
        // parallel regions fall back to the inline path
        let seen = std::sync::Mutex::new(Vec::new());
        let tasks: Vec<_> = (0..4)
            .map(|_| {
                let seen = &seen;
                move || {
                    seen.lock().unwrap().push(kernel_threads());
                }
            })
            .collect();
        Pool::scope(4, tasks);
        assert!(seen.lock().unwrap().iter().all(|&n| n == 1));
    }
}
