//! Pure-Rust reference kernels for the dense decoder-only transformer —
//! the native port of `python/compile/model.py` (and of the reference
//! kernels in `python/compile/kernels/ref.py`): embedding, pre-norm
//! block (RMSNorm → causal attention → RMSNorm → GELU MLP), head loss,
//! and the hand-written backward through all of it.
//!
//! The backward mirrors `model.split_fwdbwd`: forward activations come
//! from `params_fwd`, every weight used *inside* backward ops comes
//! from `params_bwd`. With both sets equal this is exactly the true
//! gradient (`fwdbwd`); with them different it is the deliberately
//! incorrect no-weight-stashing gradient (`fwdbwd_split`, paper
//! Fig. 10).
//!
//! All math is f32, row-major, and runs identically whether invoked as
//! the whole-model `fwdbwd` graph (simulator) or as the per-block
//! `block_fwd`/`block_bwd` graphs (threaded engine) — the engine's
//! backward recomputes the forward from the same weights, so both paths
//! produce bit-identical trajectories, which `engine_matches_sim` pins.

use anyhow::{bail, Result};

use crate::runtime::pool::Pool;
use crate::runtime::ModelCfg;
use crate::tensor::Tensor;

pub const RMS_EPS: f32 = 1e-5;
const GELU_C: f32 = 0.797_884_56; // sqrt(2/pi)
const NEG_INF: f32 = -1e30;

pub const N_BLOCK_PARAMS: usize = 6; // g1, wqkv, wo, g2, w1, w2

// ---------------------------------------------------------------------------
// Small matmul helpers on raw row-major slices
// ---------------------------------------------------------------------------
//
// Thin wrappers over the shared cache-tiled, row-parallel kernels in
// `crate::tensor` — the same kernels `Tensor::matmul` runs, so the
// exact-equality cross-check `mm_variants_agree_with_tensor_matmul`
// holds by construction. The old single-threaded loops (minus their
// NaN-swallowing `av != 0.0` fast path, which is bit-neutral to drop
// for finite data) survive as the `*_ref` oracles.

/// C(m,n) = A(m,k) @ B(k,n).
pub fn mm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    crate::tensor::mm_into(a, b, &mut out, m, k, n);
    out
}

/// Reference loop for [`mm`] (single-threaded, untiled).
pub fn mm_ref(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    crate::tensor::mm_ref_into(a, b, &mut out, m, k, n);
    out
}

/// C(m,n) = A(m,k) @ B(n,k)^T.
pub fn mm_bt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    crate::tensor::mm_bt_into(a, b, &mut out, m, k, n);
    out
}

/// Reference loop for [`mm_bt`].
pub fn mm_bt_ref(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    crate::tensor::mm_bt_ref_into(a, b, &mut out, m, k, n);
    out
}

/// C(m,n) = A(k,m)^T @ B(k,n).
pub fn mm_at(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    crate::tensor::mm_at_into(a, b, &mut out, k, m, n);
    out
}

/// Reference loop for [`mm_at`].
pub fn mm_at_ref(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    crate::tensor::mm_at_ref_into(a, b, &mut out, k, m, n);
    out
}

fn add_into(acc: &mut [f32], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    for (a, &b) in acc.iter_mut().zip(x) {
        *a += b;
    }
}

fn added(a: &[f32], b: &[f32]) -> Vec<f32> {
    a.iter().zip(b).map(|(x, y)| x + y).collect()
}

// ---------------------------------------------------------------------------
// Activation pieces
// ---------------------------------------------------------------------------

pub fn gelu(u: f32) -> f32 {
    0.5 * u * (1.0 + (GELU_C * (u + 0.044715 * u * u * u)).tanh())
}

pub fn gelu_grad(u: f32) -> f32 {
    let t = (GELU_C * (u + 0.044715 * u * u * u)).tanh();
    let dt = (1.0 - t * t) * GELU_C * (1.0 + 3.0 * 0.044715 * u * u);
    0.5 * (1.0 + t) + 0.5 * u * dt
}

/// Per-token RMSNorm scale r = 1/sqrt(mean(x^2) + eps). x: (T, d).
pub fn rms_r(x: &[f32], d: usize) -> Vec<f32> {
    x.chunks_exact(d)
        .map(|row| {
            let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
            1.0 / (ms + RMS_EPS).sqrt()
        })
        .collect()
}

/// y = x * r * g.
pub fn rms_apply(x: &[f32], r: &[f32], g: &[f32], d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    for (t, row) in x.chunks_exact(d).enumerate() {
        let orow = &mut out[t * d..(t + 1) * d];
        for ((o, &xi), &gi) in orow.iter_mut().zip(row).zip(g) {
            *o = xi * r[t] * gi;
        }
    }
    out
}

/// Backward of y = x*r*g: weights from `g_bwd`, activations (x, r) from
/// the forward cache. Returns (dx, dg).
pub fn rms_bwd(
    dy: &[f32],
    g_bwd: &[f32],
    x: &[f32],
    r: &[f32],
    d: usize,
) -> (Vec<f32>, Vec<f32>) {
    let t_len = r.len();
    let mut dx = vec![0.0f32; dy.len()];
    let mut dg = vec![0.0f32; d];
    for t in 0..t_len {
        let xr = &x[t * d..(t + 1) * d];
        let dyr = &dy[t * d..(t + 1) * d];
        let rt = r[t];
        let mut mean = 0.0f32;
        for i in 0..d {
            dg[i] += dyr[i] * xr[i] * rt;
            mean += dyr[i] * g_bwd[i] * xr[i];
        }
        mean /= d as f32;
        let r3 = rt * rt * rt;
        let dxr = &mut dx[t * d..(t + 1) * d];
        for i in 0..d {
            dxr[i] = rt * dyr[i] * g_bwd[i] - xr[i] * r3 * mean;
        }
    }
    (dx, dg)
}

// ---------------------------------------------------------------------------
// Causal multi-head attention
// ---------------------------------------------------------------------------

/// Forward cache of one attention call, laid out per (batch, head):
/// q/k/v are `[b][h][s][hd]`, p is the `[b][h][query][key]` softmax.
pub struct AttnCache {
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub p: Vec<f32>,
}

/// Per-head attention work is ~b·h·s²·hd multiply-adds; below the
/// kernel-layer threshold (or with a single head) the heads run
/// inline on the calling thread.
fn attn_threads(bh: usize, s: usize, hd: usize) -> usize {
    if bh > 1 && bh * s * s * hd >= 32 * 1024 {
        crate::runtime::pool::kernel_threads()
    } else {
        1
    }
}

/// Causal attention over a packed qkv projection. `qkv`: (T, 3*d_model)
/// with T = batch*seq. Returns the head-concatenated context (T, d_model)
/// plus the cache for backward.
///
/// Parallelized per (batch, head): each task owns disjoint `&mut`
/// slices of the q/k/v/p cache plus a contiguous per-head output
/// scratch, and the head-interleaved context rows are scattered
/// serially afterwards (a pure copy). The per-head arithmetic is the
/// reference sequence unchanged, so results are bit-identical to
/// [`attention_fwd_ref`] at any thread count.
pub fn attention_fwd(cfg: &ModelCfg, qkv: &[f32]) -> (Vec<f32>, AttnCache) {
    let (b, s, d) = (cfg.batch, cfg.seq, cfg.d_model);
    let h = cfg.n_heads;
    let hd = cfg.head_dim();
    let scale = 1.0 / (hd as f32).sqrt();
    let bh = b * h;
    let mut q = vec![0.0f32; bh * s * hd];
    let mut k = vec![0.0f32; bh * s * hd];
    let mut v = vec![0.0f32; bh * s * hd];
    let mut p = vec![0.0f32; bh * s * s];
    let mut o_all = vec![0.0f32; bh * s * hd];
    {
        let threads = attn_threads(bh, s, hd);
        let mut tasks = Vec::with_capacity(bh);
        for ((((idx, qm), km), vm), (pm, om)) in q
            .chunks_mut(s * hd)
            .enumerate()
            .zip(k.chunks_mut(s * hd))
            .zip(v.chunks_mut(s * hd))
            .zip(p.chunks_mut(s * s).zip(o_all.chunks_mut(s * hd)))
        {
            tasks.push(move || attn_head_fwd(qkv, qm, km, vm, pm, om, idx, s, d, h, hd, scale));
        }
        Pool::scope(threads, tasks);
    }
    // scatter the contiguous per-head outputs into the
    // head-concatenated (T, d) layout — a pure copy
    let mut oc = vec![0.0f32; b * s * d];
    for bi in 0..b {
        for hi in 0..h {
            let om = &o_all[(bi * h + hi) * s * hd..(bi * h + hi + 1) * s * hd];
            for si in 0..s {
                let row = (bi * s + si) * d + hi * hd;
                oc[row..row + hd].copy_from_slice(&om[si * hd..(si + 1) * hd]);
            }
        }
    }
    (oc, AttnCache { q, k, v, p })
}

/// One (batch, head) slice of the attention forward: gather → scaled
/// causal scores → softmax → context, written into the task's disjoint
/// q/k/v/p/o scratch slices.
#[allow(clippy::too_many_arguments)]
fn attn_head_fwd(
    qkv: &[f32],
    qm: &mut [f32],
    km: &mut [f32],
    vm: &mut [f32],
    pm: &mut [f32],
    om: &mut [f32],
    idx: usize,
    s: usize,
    d: usize,
    h: usize,
    hd: usize,
    scale: f32,
) {
    let (bi, hi) = (idx / h, idx % h);
    // gather per-head q/k/v from the packed (T, 3D) projection
    for si in 0..s {
        let row = (bi * s + si) * 3 * d;
        for j in 0..hd {
            qm[si * hd + j] = qkv[row + hi * hd + j];
            km[si * hd + j] = qkv[row + d + hi * hd + j];
            vm[si * hd + j] = qkv[row + 2 * d + hi * hd + j];
        }
    }
    // att = q k^T * scale, causal mask, row softmax
    let mut att = mm_bt(qm, km, s, hd, s);
    for x in att.iter_mut() {
        *x *= scale;
    }
    for qi in 0..s {
        for ki in (qi + 1)..s {
            att[qi * s + ki] = NEG_INF;
        }
    }
    for qi in 0..s {
        let row = &mut att[qi * s..(qi + 1) * s];
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
        let mut sum = 0.0f32;
        for x in row.iter_mut() {
            *x = (*x - max).exp();
            sum += *x;
        }
        let prow = &mut pm[qi * s..(qi + 1) * s];
        for (pv, &e) in prow.iter_mut().zip(row.iter()) {
            *pv = e / sum;
        }
    }
    // o = p @ v into the contiguous per-head scratch
    crate::tensor::mm_into(pm, vm, om, s, s, hd);
}

/// Reference single-threaded attention forward (the pre-pool loop,
/// running the `*_ref` matmul kernels): the equivalence oracle for
/// [`attention_fwd`].
pub fn attention_fwd_ref(cfg: &ModelCfg, qkv: &[f32]) -> (Vec<f32>, AttnCache) {
    let (b, s, d) = (cfg.batch, cfg.seq, cfg.d_model);
    let h = cfg.n_heads;
    let hd = cfg.head_dim();
    let scale = 1.0 / (hd as f32).sqrt();
    let bh = b * h;
    let mut q = vec![0.0f32; bh * s * hd];
    let mut k = vec![0.0f32; bh * s * hd];
    let mut v = vec![0.0f32; bh * s * hd];
    let mut p = vec![0.0f32; bh * s * s];
    let mut oc = vec![0.0f32; b * s * d];

    for bi in 0..b {
        for hi in 0..h {
            let base = (bi * h + hi) * s * hd;
            for si in 0..s {
                let row = (bi * s + si) * 3 * d;
                for j in 0..hd {
                    q[base + si * hd + j] = qkv[row + hi * hd + j];
                    k[base + si * hd + j] = qkv[row + d + hi * hd + j];
                    v[base + si * hd + j] = qkv[row + 2 * d + hi * hd + j];
                }
            }
            let qm = &q[base..base + s * hd];
            let km = &k[base..base + s * hd];
            let vm = &v[base..base + s * hd];
            let mut att = mm_bt_ref(qm, km, s, hd, s);
            for x in att.iter_mut() {
                *x *= scale;
            }
            for qi in 0..s {
                for ki in (qi + 1)..s {
                    att[qi * s + ki] = NEG_INF;
                }
            }
            let pbase = (bi * h + hi) * s * s;
            for qi in 0..s {
                let row = &mut att[qi * s..(qi + 1) * s];
                let max = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
                let mut sum = 0.0f32;
                for x in row.iter_mut() {
                    *x = (*x - max).exp();
                    sum += *x;
                }
                let prow = &mut p[pbase + qi * s..pbase + (qi + 1) * s];
                for (pv, &e) in prow.iter_mut().zip(row.iter()) {
                    *pv = e / sum;
                }
            }
            let o = mm_ref(&p[pbase..pbase + s * s], vm, s, s, hd);
            for si in 0..s {
                let row = (bi * s + si) * d;
                for j in 0..hd {
                    oc[row + hi * hd + j] = o[si * hd + j];
                }
            }
        }
    }
    (oc, AttnCache { q, k, v, p })
}

/// Backward of [`attention_fwd`]: `doc` is the gradient w.r.t. the
/// head-concatenated context (T, d_model); returns the gradient w.r.t.
/// the packed qkv projection (T, 3*d_model).
///
/// Parallelized like the forward: per-(batch, head) tasks write
/// dq/dk/dv into contiguous disjoint scratch, then a serial pure-copy
/// scatter interleaves them into the packed layout. Bit-identical to
/// [`attention_bwd_ref`] at any thread count.
pub fn attention_bwd(cfg: &ModelCfg, cache: &AttnCache, doc: &[f32]) -> Vec<f32> {
    let (b, s, d) = (cfg.batch, cfg.seq, cfg.d_model);
    let h = cfg.n_heads;
    let hd = cfg.head_dim();
    let scale = 1.0 / (hd as f32).sqrt();
    let bh = b * h;
    let mut dq_all = vec![0.0f32; bh * s * hd];
    let mut dk_all = vec![0.0f32; bh * s * hd];
    let mut dv_all = vec![0.0f32; bh * s * hd];
    {
        let threads = attn_threads(bh, s, hd);
        let mut tasks = Vec::with_capacity(bh);
        for (((idx, dqm), dkm), dvm) in dq_all
            .chunks_mut(s * hd)
            .enumerate()
            .zip(dk_all.chunks_mut(s * hd))
            .zip(dv_all.chunks_mut(s * hd))
        {
            tasks.push(move || attn_head_bwd(cache, doc, dqm, dkm, dvm, idx, s, d, h, hd, scale));
        }
        Pool::scope(threads, tasks);
    }
    // scatter into the packed (T, 3D) layout — a pure copy
    let mut dqkv = vec![0.0f32; b * s * 3 * d];
    for bi in 0..b {
        for hi in 0..h {
            let base = (bi * h + hi) * s * hd;
            for si in 0..s {
                let row = (bi * s + si) * 3 * d;
                let src = base + si * hd;
                dqkv[row + hi * hd..row + hi * hd + hd]
                    .copy_from_slice(&dq_all[src..src + hd]);
                dqkv[row + d + hi * hd..row + d + hi * hd + hd]
                    .copy_from_slice(&dk_all[src..src + hd]);
                dqkv[row + 2 * d + hi * hd..row + 2 * d + hi * hd + hd]
                    .copy_from_slice(&dv_all[src..src + hd]);
            }
        }
    }
    dqkv
}

/// One (batch, head) slice of the attention backward, writing into the
/// task's disjoint dq/dk/dv scratch slices.
#[allow(clippy::too_many_arguments)]
fn attn_head_bwd(
    cache: &AttnCache,
    doc: &[f32],
    dqm: &mut [f32],
    dkm: &mut [f32],
    dvm: &mut [f32],
    idx: usize,
    s: usize,
    d: usize,
    h: usize,
    hd: usize,
    scale: f32,
) {
    let (bi, hi) = (idx / h, idx % h);
    let base = idx * s * hd;
    let pbase = idx * s * s;
    let qm = &cache.q[base..base + s * hd];
    let km = &cache.k[base..base + s * hd];
    let vm = &cache.v[base..base + s * hd];
    let pm = &cache.p[pbase..pbase + s * s];
    // gather the per-head slice of doc
    let mut do_h = vec![0.0f32; s * hd];
    for si in 0..s {
        let row = (bi * s + si) * d;
        do_h[si * hd..(si + 1) * hd]
            .copy_from_slice(&doc[row + hi * hd..row + (hi + 1) * hd]);
    }
    // dv = p^T @ do ; dp = do @ v^T
    crate::tensor::mm_at_into(pm, &do_h, dvm, s, s, hd);
    let dp = mm_bt(&do_h, vm, s, hd, s);
    // softmax backward: datt = p * (dp - rowsum(dp * p))
    let mut datt = vec![0.0f32; s * s];
    for qi in 0..s {
        let prow = &pm[qi * s..(qi + 1) * s];
        let dprow = &dp[qi * s..(qi + 1) * s];
        let mut dot = 0.0f32;
        for (pv, dpv) in prow.iter().zip(dprow) {
            dot += pv * dpv;
        }
        let drow = &mut datt[qi * s..(qi + 1) * s];
        for ((dr, &pv), &dpv) in drow.iter_mut().zip(prow).zip(dprow) {
            *dr = pv * (dpv - dot);
        }
    }
    // dq = datt @ k * scale ; dk = datt^T @ q * scale
    crate::tensor::mm_into(&datt, km, dqm, s, s, hd);
    crate::tensor::mm_at_into(&datt, qm, dkm, s, s, hd);
    for x in dqm.iter_mut() {
        *x *= scale;
    }
    for x in dkm.iter_mut() {
        *x *= scale;
    }
}

/// Reference single-threaded attention backward (the pre-pool loop,
/// running the `*_ref` matmul kernels): the equivalence oracle for
/// [`attention_bwd`].
pub fn attention_bwd_ref(cfg: &ModelCfg, cache: &AttnCache, doc: &[f32]) -> Vec<f32> {
    let (b, s, d) = (cfg.batch, cfg.seq, cfg.d_model);
    let h = cfg.n_heads;
    let hd = cfg.head_dim();
    let scale = 1.0 / (hd as f32).sqrt();
    let mut dqkv = vec![0.0f32; b * s * 3 * d];

    for bi in 0..b {
        for hi in 0..h {
            let base = (bi * h + hi) * s * hd;
            let pbase = (bi * h + hi) * s * s;
            let qm = &cache.q[base..base + s * hd];
            let km = &cache.k[base..base + s * hd];
            let vm = &cache.v[base..base + s * hd];
            let pm = &cache.p[pbase..pbase + s * s];
            let mut do_h = vec![0.0f32; s * hd];
            for si in 0..s {
                let row = (bi * s + si) * d;
                do_h[si * hd..(si + 1) * hd]
                    .copy_from_slice(&doc[row + hi * hd..row + (hi + 1) * hd]);
            }
            let dv = mm_at_ref(pm, &do_h, s, s, hd);
            let dp = mm_bt_ref(&do_h, vm, s, hd, s);
            let mut datt = vec![0.0f32; s * s];
            for qi in 0..s {
                let prow = &pm[qi * s..(qi + 1) * s];
                let dprow = &dp[qi * s..(qi + 1) * s];
                let mut dot = 0.0f32;
                for (pv, dpv) in prow.iter().zip(dprow) {
                    dot += pv * dpv;
                }
                let drow = &mut datt[qi * s..(qi + 1) * s];
                for ((dr, &pv), &dpv) in drow.iter_mut().zip(prow).zip(dprow) {
                    *dr = pv * (dpv - dot);
                }
            }
            let mut dq = mm_ref(&datt, km, s, s, hd);
            let mut dk = mm_at_ref(&datt, qm, s, s, hd);
            for x in dq.iter_mut() {
                *x *= scale;
            }
            for x in dk.iter_mut() {
                *x *= scale;
            }
            for si in 0..s {
                let row = (bi * s + si) * 3 * d;
                for j in 0..hd {
                    dqkv[row + hi * hd + j] = dq[si * hd + j];
                    dqkv[row + d + hi * hd + j] = dk[si * hd + j];
                    dqkv[row + 2 * d + hi * hd + j] = dv[si * hd + j];
                }
            }
        }
    }
    dqkv
}

// ---------------------------------------------------------------------------
// Transformer block (pre-norm, GELU MLP)
// ---------------------------------------------------------------------------

/// Forward activation cache of one block.
pub struct BlockCache {
    pub x_in: Vec<f32>,
    pub r1: Vec<f32>,
    pub a: Vec<f32>,
    pub attn: AttnCache,
    pub oc: Vec<f32>,
    pub x_mid: Vec<f32>,
    pub r2: Vec<f32>,
    pub bnorm: Vec<f32>,
    pub u: Vec<f32>,
    pub gu: Vec<f32>,
}

/// One pre-norm block. `bp` = [g1, wqkv, wo, g2, w1, w2] (schema
/// order); `x_in`: (T, d_model). Returns (x_out, cache).
pub fn block_fwd_cached(cfg: &ModelCfg, bp: &[&Tensor], x_in: &[f32]) -> (Vec<f32>, BlockCache) {
    let (b, s, d, f) = (cfg.batch, cfg.seq, cfg.d_model, cfg.d_ff);
    let t = b * s;
    let (g1, wqkv, wo, g2, w1, w2) = (bp[0], bp[1], bp[2], bp[3], bp[4], bp[5]);

    let r1 = rms_r(x_in, d);
    let a = rms_apply(x_in, &r1, &g1.data, d);
    let qkv = mm(&a, &wqkv.data, t, d, 3 * d);
    let (oc, attn) = attention_fwd(cfg, &qkv);
    let x_mid = added(x_in, &mm(&oc, &wo.data, t, d, d));
    let r2 = rms_r(&x_mid, d);
    let bnorm = rms_apply(&x_mid, &r2, &g2.data, d);
    let u = mm(&bnorm, &w1.data, t, d, f);
    let gu: Vec<f32> = u.iter().map(|&x| gelu(x)).collect();
    let x_out = added(&x_mid, &mm(&gu, &w2.data, t, f, d));
    let cache = BlockCache {
        x_in: x_in.to_vec(),
        r1,
        a,
        attn,
        oc,
        x_mid,
        r2,
        bnorm,
        u,
        gu,
    };
    (x_out, cache)
}

/// Backward through one block: weights from `bp_bwd`, activations from
/// `cache`, upstream gradient `dy`. Returns (dx, [dg1, dwqkv, dwo, dg2,
/// dw1, dw2]).
pub fn block_bwd_from_cache(
    cfg: &ModelCfg,
    bp_bwd: &[&Tensor],
    cache: &BlockCache,
    dy: &[f32],
) -> (Vec<f32>, Vec<Tensor>) {
    let (b, s, d, f) = (cfg.batch, cfg.seq, cfg.d_model, cfg.d_ff);
    let t = b * s;
    let (g1, wqkv, wo, g2, w1, w2) =
        (bp_bwd[0], bp_bwd[1], bp_bwd[2], bp_bwd[3], bp_bwd[4], bp_bwd[5]);

    // MLP branch: x_out = x_mid + gelu(bnorm @ w1) @ w2
    let dw2 = mm_at(&cache.gu, dy, t, f, d);
    let dgu = mm_bt(dy, &w2.data, t, d, f);
    let du: Vec<f32> = dgu
        .iter()
        .zip(&cache.u)
        .map(|(&dg, &u)| dg * gelu_grad(u))
        .collect();
    let dw1 = mm_at(&cache.bnorm, &du, t, d, f);
    let dbnorm = mm_bt(&du, &w1.data, t, f, d);
    let (dx_mid_norm, dg2) = rms_bwd(&dbnorm, &g2.data, &cache.x_mid, &cache.r2, d);
    let dx_mid = added(dy, &dx_mid_norm);

    // Attention branch: x_mid = x_in + oc @ wo
    let dwo = mm_at(&cache.oc, &dx_mid, t, d, d);
    let doc = mm_bt(&dx_mid, &wo.data, t, d, d);
    let dqkv = attention_bwd(cfg, &cache.attn, &doc);
    let dwqkv = mm_at(&cache.a, &dqkv, t, d, 3 * d);
    let da = mm_bt(&dqkv, &wqkv.data, t, 3 * d, d);
    let (dx_in_norm, dg1) = rms_bwd(&da, &g1.data, &cache.x_in, &cache.r1, d);
    let dx = added(&dx_mid, &dx_in_norm);

    let grads = vec![
        Tensor::new(g1.shape.clone(), dg1),
        Tensor::new(wqkv.shape.clone(), dwqkv),
        Tensor::new(wo.shape.clone(), dwo),
        Tensor::new(g2.shape.clone(), dg2),
        Tensor::new(w1.shape.clone(), dw1),
        Tensor::new(w2.shape.clone(), dw2),
    ];
    (dx, grads)
}

// ---------------------------------------------------------------------------
// Embedding and head
// ---------------------------------------------------------------------------

/// x[b,s] = tok_emb[tokens[b,s]] + pos_emb[s]; returns (T, d_model).
pub fn embed_fwd(cfg: &ModelCfg, tok_emb: &Tensor, pos_emb: &Tensor, toks: &[i32]) -> Vec<f32> {
    let (b, s, d) = (cfg.batch, cfg.seq, cfg.d_model);
    let mut x = vec![0.0f32; b * s * d];
    for bi in 0..b {
        for si in 0..s {
            let tok = toks[bi * s + si] as usize;
            let row = &mut x[(bi * s + si) * d..(bi * s + si + 1) * d];
            let te = &tok_emb.data[tok * d..(tok + 1) * d];
            let pe = &pos_emb.data[si * d..(si + 1) * d];
            for ((xo, &t), &p) in row.iter_mut().zip(te).zip(pe) {
                *xo = t + p;
            }
        }
    }
    x
}

/// Backward of the embedding: scatter-add into dtok, batch-sum into
/// dpos.
pub fn embed_bwd(cfg: &ModelCfg, toks: &[i32], dx: &[f32]) -> (Tensor, Tensor) {
    let (b, s, d, v) = (cfg.batch, cfg.seq, cfg.d_model, cfg.vocab);
    let mut dtok = vec![0.0f32; v * d];
    let mut dpos = vec![0.0f32; s * d];
    for bi in 0..b {
        for si in 0..s {
            let tok = toks[bi * s + si] as usize;
            let row = &dx[(bi * s + si) * d..(bi * s + si + 1) * d];
            add_into(&mut dtok[tok * d..(tok + 1) * d], row);
            add_into(&mut dpos[si * d..(si + 1) * d], row);
        }
    }
    (Tensor::new(vec![v, d], dtok), Tensor::new(vec![s, d], dpos))
}

/// Forward-only head loss (eval path): mean cross-entropy of
/// `rmsnorm(x, gf) @ head` against `targets`.
pub fn head_loss(cfg: &ModelCfg, gf: &Tensor, head: &Tensor, x: &[f32], tgts: &[i32]) -> f32 {
    let (d, v) = (cfg.d_model, cfg.vocab);
    let t = cfg.batch * cfg.seq;
    let rf = rms_r(x, d);
    let xf = rms_apply(x, &rf, &gf.data, d);
    let logits = mm(&xf, &head.data, t, d, v);
    let mut loss = 0.0f32;
    for ti in 0..t {
        let row = &logits[ti * v..(ti + 1) * v];
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
        let lse = row.iter().map(|&x| (x - max).exp()).sum::<f32>().ln() + max;
        loss += lse - row[tgts[ti] as usize];
    }
    loss / t as f32
}

/// Head forward+backward with split weights: the loss and `dhead`'s
/// activation side use the forward weights; the matmul/norm transposes
/// inside the backward use the backward weights. Returns
/// (loss, dx, dgf, dhead).
#[allow(clippy::too_many_arguments)]
pub fn head_fwdbwd_split(
    cfg: &ModelCfg,
    gf_f: &Tensor,
    head_f: &Tensor,
    gf_b: &Tensor,
    head_b: &Tensor,
    x: &[f32],
    tgts: &[i32],
) -> (f32, Vec<f32>, Tensor, Tensor) {
    let (d, v) = (cfg.d_model, cfg.vocab);
    let t = cfg.batch * cfg.seq;
    let rf = rms_r(x, d);
    let xf = rms_apply(x, &rf, &gf_f.data, d);
    let logits = mm(&xf, &head_f.data, t, d, v);

    let mut loss = 0.0f32;
    let mut dlogits = vec![0.0f32; t * v];
    let inv_t = 1.0 / t as f32;
    for ti in 0..t {
        let row = &logits[ti * v..(ti + 1) * v];
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
        let mut sum = 0.0f32;
        let drow = &mut dlogits[ti * v..(ti + 1) * v];
        for (dv, &l) in drow.iter_mut().zip(row) {
            *dv = (l - max).exp();
            sum += *dv;
        }
        let lse = sum.ln() + max;
        let tgt = tgts[ti] as usize;
        loss += lse - row[tgt];
        for dv in drow.iter_mut() {
            *dv = *dv / sum * inv_t; // softmax prob / T
        }
        drow[tgt] -= inv_t;
    }
    loss *= inv_t;

    let dhead = mm_at(&xf, &dlogits, t, d, v);
    let dxf = mm_bt(&dlogits, &head_b.data, t, v, d);
    let (dx, dgf) = rms_bwd(&dxf, &gf_b.data, x, &rf, d);
    (
        loss,
        dx,
        Tensor::new(gf_b.shape.clone(), dgf),
        Tensor::new(head_b.shape.clone(), dhead),
    )
}

/// Head forward+backward with a single weight set (the engine's
/// `head_fwdbwd` graph).
pub fn head_fwdbwd(
    cfg: &ModelCfg,
    gf: &Tensor,
    head: &Tensor,
    x: &[f32],
    tgts: &[i32],
) -> (f32, Vec<f32>, Tensor, Tensor) {
    head_fwdbwd_split(cfg, gf, head, gf, head, x, tgts)
}

// ---------------------------------------------------------------------------
// Whole-model graphs (composed from the per-block primitives)
// ---------------------------------------------------------------------------

/// The 6 block parameters of block `b` in schema order.
pub fn block_params(params: &[Tensor], b: usize) -> Vec<&Tensor> {
    params[2 + b * N_BLOCK_PARAMS..2 + (b + 1) * N_BLOCK_PARAMS].iter().collect()
}

fn check_dense(cfg: &ModelCfg) -> Result<()> {
    if cfg.moe.is_some() {
        bail!("dense graph invoked on MoE config {:?}", cfg.name);
    }
    Ok(())
}

/// Whole-model eval loss.
pub fn eval_loss(cfg: &ModelCfg, params: &[Tensor], toks: &[i32], tgts: &[i32]) -> Result<f32> {
    check_dense(cfg)?;
    let mut x = embed_fwd(cfg, &params[0], &params[1], toks);
    for b in 0..cfg.n_blocks {
        let bp = block_params(params, b);
        let (x_out, _) = block_fwd_cached(cfg, &bp, &x);
        x = x_out;
    }
    let n = params.len();
    Ok(head_loss(cfg, &params[n - 2], &params[n - 1], &x, tgts))
}

/// Whole-model loss + gradients with split forward/backward weights
/// (`fwdbwd_split`); `fwdbwd` is the special case `params_fwd ==
/// params_bwd`. Returns (loss, grads in schema order).
pub fn fwdbwd_split(
    cfg: &ModelCfg,
    params_fwd: &[Tensor],
    params_bwd: &[Tensor],
    toks: &[i32],
    tgts: &[i32],
) -> Result<(f32, Vec<Tensor>)> {
    check_dense(cfg)?;
    let n = params_fwd.len();
    // forward with activation caches (weights = fwd)
    let mut x = embed_fwd(cfg, &params_fwd[0], &params_fwd[1], toks);
    let mut caches = Vec::with_capacity(cfg.n_blocks);
    for b in 0..cfg.n_blocks {
        let bp = block_params(params_fwd, b);
        let (x_out, cache) = block_fwd_cached(cfg, &bp, &x);
        caches.push(cache);
        x = x_out;
    }
    // head (loss from fwd weights, backward transposes from bwd ones)
    let (loss, mut dx, dgf, dhead) = head_fwdbwd_split(
        cfg,
        &params_fwd[n - 2],
        &params_fwd[n - 1],
        &params_bwd[n - 2],
        &params_bwd[n - 1],
        &x,
        tgts,
    );
    // blocks in reverse (weights = bwd, activations from the caches)
    let mut block_grads: Vec<Vec<Tensor>> = Vec::with_capacity(cfg.n_blocks);
    for b in (0..cfg.n_blocks).rev() {
        let bp = block_params(params_bwd, b);
        let (dx_new, grads) = block_bwd_from_cache(cfg, &bp, &caches[b], &dx);
        dx = dx_new;
        block_grads.push(grads);
    }
    block_grads.reverse();
    let (dtok, dpos) = embed_bwd(cfg, toks, &dx);

    let mut grads = Vec::with_capacity(n);
    grads.push(dtok);
    grads.push(dpos);
    for bg in block_grads {
        grads.extend(bg);
    }
    grads.push(dgf);
    grads.push(dhead);
    Ok((loss, grads))
}

/// Whole-model loss + true gradients.
pub fn fwdbwd(
    cfg: &ModelCfg,
    params: &[Tensor],
    toks: &[i32],
    tgts: &[i32],
) -> Result<(f32, Vec<Tensor>)> {
    fwdbwd_split(cfg, params, params, toks, tgts)
}

/// Hessian-vector product via central differences of the gradient:
/// `Hv = (g(p + eps v) - g(p - eps v)) / (2 eps)`. The PJRT path lowers
/// an exact forward-over-reverse `hvp` graph; the native backend uses
/// this O(eps^2) finite-difference approximation, which is accurate
/// enough for the Fig. 11 alignment diagnostics it serves.
pub fn hvp(
    cfg: &ModelCfg,
    params: &[Tensor],
    vec: &[Tensor],
    toks: &[i32],
    tgts: &[i32],
) -> Result<Vec<Tensor>> {
    check_dense(cfg)?;
    let vnorm: f32 = vec
        .iter()
        .map(|t| t.data.iter().map(|x| x * x).sum::<f32>())
        .sum::<f32>()
        .sqrt();
    if vnorm == 0.0 {
        return Ok(vec.iter().map(|t| Tensor::zeros(&t.shape)).collect());
    }
    let eps = 1e-2 / vnorm;
    let shift = |sign: f32| -> Vec<Tensor> {
        params
            .iter()
            .zip(vec)
            .map(|(p, v)| {
                let mut q = p.clone();
                q.axpy(sign * eps, v);
                q
            })
            .collect()
    };
    let (_, g_plus) = fwdbwd(cfg, &shift(1.0), toks, tgts)?;
    let (_, g_minus) = fwdbwd(cfg, &shift(-1.0), toks, tgts)?;
    Ok(g_plus
        .iter()
        .zip(&g_minus)
        .map(|(gp, gm)| {
            let data = gp
                .data
                .iter()
                .zip(&gm.data)
                .map(|(&a, &b)| (a - b) / (2.0 * eps))
                .collect();
            Tensor::new(gp.shape.clone(), data)
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngs::Rng;

    fn micro() -> ModelCfg {
        crate::runtime::presets::builtin_model_cfg("micro").unwrap()
    }

    fn randn(rng: &mut Rng, shape: &[usize], std: f32) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, std);
        t
    }

    #[test]
    fn mm_variants_agree_with_tensor_matmul() {
        let mut rng = Rng::new(1);
        let a = randn(&mut rng, &[3, 5], 1.0);
        let b = randn(&mut rng, &[5, 4], 1.0);
        let c = a.matmul(&b);
        assert_eq!(mm(&a.data, &b.data, 3, 5, 4), c.data);
        let bt = b.transpose();
        assert_eq!(mm_bt(&a.data, &bt.data, 3, 5, 4), c.data);
        let at = a.transpose();
        assert_eq!(mm_at(&at.data, &b.data, 5, 3, 4), c.data);
    }

    #[test]
    fn rms_bwd_matches_finite_differences() {
        let mut rng = Rng::new(2);
        let d = 6;
        let t = 4;
        let x = randn(&mut rng, &[t, d], 1.0);
        let g = randn(&mut rng, &[d], 1.0);
        let dy = randn(&mut rng, &[t, d], 1.0);
        let r = rms_r(&x.data, d);
        let (dx, dg) = rms_bwd(&dy.data, &g.data, &x.data, &r, d);
        // loss = sum(dy * rmsnorm(x, g)); check d loss / d x numerically
        let loss = |xd: &[f32], gd: &[f32]| -> f64 {
            let r = rms_r(xd, d);
            let y = rms_apply(xd, &r, gd, d);
            y.iter().zip(&dy.data).map(|(&a, &b)| (a * b) as f64).sum()
        };
        let eps = 1e-3f32;
        for idx in [0usize, 7, 13, 23] {
            let mut xp = x.data.clone();
            let mut xm = x.data.clone();
            xp[idx] += eps;
            xm[idx] -= eps;
            let num = (loss(&xp, &g.data) - loss(&xm, &g.data)) / (2.0 * eps as f64);
            assert!((num - dx[idx] as f64).abs() < 2e-3, "dx[{idx}]: {num} vs {}", dx[idx]);
        }
        for idx in [0usize, 3, 5] {
            let mut gp = g.data.clone();
            let mut gm = g.data.clone();
            gp[idx] += eps;
            gm[idx] -= eps;
            let num = (loss(&x.data, &gp) - loss(&x.data, &gm)) / (2.0 * eps as f64);
            assert!((num - dg[idx] as f64).abs() < 2e-3, "dg[{idx}]: {num} vs {}", dg[idx]);
        }
    }

    #[test]
    fn attention_is_causal() {
        // Changing a *future* token's q/k/v must not change earlier
        // outputs.
        let cfg = micro();
        let t = cfg.batch * cfg.seq;
        let mut rng = Rng::new(3);
        let qkv = randn(&mut rng, &[t, 3 * cfg.d_model], 1.0);
        let (oc1, _) = attention_fwd(&cfg, &qkv.data);
        let mut qkv2 = qkv.data.clone();
        // perturb the last position of batch row 0
        let last = (cfg.seq - 1) * 3 * cfg.d_model;
        for x in qkv2[last..last + 3 * cfg.d_model].iter_mut() {
            *x += 1.0;
        }
        let (oc2, _) = attention_fwd(&cfg, &qkv2);
        let d = cfg.d_model;
        for si in 0..cfg.seq - 1 {
            for j in 0..d {
                assert_eq!(oc1[si * d + j], oc2[si * d + j], "leak at s={si}");
            }
        }
    }

    #[test]
    fn fwdbwd_grads_match_finite_differences() {
        let cfg = micro();
        let man = crate::runtime::presets::manifest_from_cfg(&cfg);
        let params = crate::model::init_params(&man, 5);
        let t = cfg.batch * cfg.seq;
        let toks: Vec<i32> = (0..t).map(|i| ((i * 5 + 1) % cfg.vocab) as i32).collect();
        let tgts: Vec<i32> = (0..t).map(|i| ((i * 3 + 2) % cfg.vocab) as i32).collect();
        let (loss, grads) = fwdbwd(&cfg, &params, &toks, &tgts).unwrap();
        assert!(loss.is_finite());
        // spot-check a handful of coordinates across distinct params
        let mut rng = Rng::new(9);
        let eps = 3e-2f32;
        for pi in [0usize, 2, 3, 4, 6, 7, 14, 15] {
            let idx = rng.below(params[pi].len());
            let mut pp = params.clone();
            pp[pi].data[idx] += eps;
            let lp = eval_loss(&cfg, &pp, &toks, &tgts).unwrap();
            let mut pm = params.clone();
            pm[pi].data[idx] -= eps;
            let lm = eval_loss(&cfg, &pm, &toks, &tgts).unwrap();
            let num = (lp - lm) / (2.0 * eps);
            let ana = grads[pi].data[idx];
            assert!(
                (num - ana).abs() < 2e-3 + 0.05 * ana.abs().max(num.abs()),
                "param {pi} [{idx}]: numeric {num} vs analytic {ana}"
            );
        }
    }

    #[test]
    fn split_equals_fused_when_weights_equal() {
        let cfg = micro();
        let man = crate::runtime::presets::manifest_from_cfg(&cfg);
        let params = crate::model::init_params(&man, 6);
        let t = cfg.batch * cfg.seq;
        let toks: Vec<i32> = (0..t).map(|i| ((i * 7) % cfg.vocab) as i32).collect();
        let (l1, g1) = fwdbwd(&cfg, &params, &toks, &toks).unwrap();
        let (l2, g2) = fwdbwd_split(&cfg, &params, &params, &toks, &toks).unwrap();
        assert_eq!(l1, l2);
        for (a, b) in g1.iter().zip(&g2) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn hvp_is_approximately_linear() {
        // H(2v) == 2 Hv up to the finite-difference error.
        let cfg = micro();
        let man = crate::runtime::presets::manifest_from_cfg(&cfg);
        let params = crate::model::init_params(&man, 7);
        let t = cfg.batch * cfg.seq;
        let toks: Vec<i32> = (0..t).map(|i| ((i * 11) % cfg.vocab) as i32).collect();
        let mut rng = Rng::new(8);
        let v: Vec<Tensor> = params
            .iter()
            .map(|p| {
                let mut t = Tensor::zeros(&p.shape);
                rng.fill_normal(&mut t.data, 1.0);
                t
            })
            .collect();
        let v2: Vec<Tensor> = v.iter().map(|t| t.scale(2.0)).collect();
        let hv = hvp(&cfg, &params, &v, &toks, &toks).unwrap();
        let hv2 = hvp(&cfg, &params, &v2, &toks, &toks).unwrap();
        let norm = |xs: &[Tensor]| -> f64 {
            xs.iter()
                .map(|t| t.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>())
                .sum::<f64>()
                .sqrt()
        };
        let diff: Vec<Tensor> = hv2
            .iter()
            .zip(&hv)
            .map(|(a, b)| a.sub(&b.scale(2.0)))
            .collect();
        let rel = norm(&diff) / norm(&hv2).max(1e-12);
        assert!(rel < 0.15, "relative nonlinearity {rel}");
        assert!(hv.iter().all(|t| t.all_finite()));
    }
}
