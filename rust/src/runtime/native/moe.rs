//! Pure-Rust reference kernels for the Mixture-of-Experts model variant
//! (native port of `python/compile/moe.py`, paper Fig. 21).
//!
//! Each block replaces the dense MLP with a top-k routed expert MLP.
//! Experts are computed densely and combined with the (sparse,
//! renormalized) gate matrix — numerically identical to
//! dispatch/combine at this scale. The routing decision (the top-k
//! mask) is a stop-gradient, gradients flow through the kept
//! probabilities; a Switch-style load-balancing auxiliary loss with
//! coefficient 0.01 is added to the training objective (the reported
//! loss stays plain cross-entropy).

use anyhow::{bail, Result};

use crate::runtime::ModelCfg;
use crate::tensor::Tensor;

use super::dense::{
    attention_bwd, attention_fwd, embed_bwd, embed_fwd, gelu, gelu_grad, head_fwdbwd,
    head_loss, mm, mm_at, mm_bt, rms_apply, rms_bwd, rms_r, AttnCache,
};

pub(super) const AUX_COEF: f32 = 0.01;
const N_BLOCK_PARAMS: usize = 7; // g1, wqkv, wo, g2, router, w1e, w2e

pub(super) struct MoeBlockCache {
    x_in: Vec<f32>,
    r1: Vec<f32>,
    a: Vec<f32>,
    attn: AttnCache,
    oc: Vec<f32>,
    x_mid: Vec<f32>,
    r2: Vec<f32>,
    bnorm: Vec<f32>,
    probs: Vec<f32>,       // (T, E)
    mask: Vec<f32>,        // (T, E) in {0, 1}, stop-gradient
    kept: Vec<f32>,        // (T, E)
    denom: Vec<f32>,       // (T,)
    gates: Vec<f32>,       // (T, E)
    h_pre: Vec<f32>,       // (E, T, F)
    h: Vec<f32>,           // (E, T, F)
    out_e: Vec<f32>,       // (E, T, D)
    frac_tokens: Vec<f32>, // (E,)
    aux: f32,
}

fn block_params(params: &[Tensor], b: usize) -> Vec<&Tensor> {
    params[2 + b * N_BLOCK_PARAMS..2 + (b + 1) * N_BLOCK_PARAMS].iter().collect()
}

fn moe_cfg(cfg: &ModelCfg) -> Result<(usize, usize)> {
    match &cfg.moe {
        Some(m) => Ok((m.n_experts, m.top_k)),
        None => bail!("MoE graph invoked on dense config {:?}", cfg.name),
    }
}

/// One MoE block forward. `bp` = [g1, wqkv, wo, g2, router, w1e, w2e].
/// `pub(super)` so the backend serves it as the per-block `block_fwd`
/// executable the threaded 1F1B engine dispatches on MoE configs.
pub(super) fn block_fwd_cached(
    cfg: &ModelCfg,
    bp: &[&Tensor],
    x_in: &[f32],
) -> Result<(Vec<f32>, MoeBlockCache)> {
    let (b, s, d, f) = (cfg.batch, cfg.seq, cfg.d_model, cfg.d_ff);
    let t = b * s;
    let (e_n, top_k) = moe_cfg(cfg)?;
    let (g1, wqkv, wo, g2, router, w1e, w2e) =
        (bp[0], bp[1], bp[2], bp[3], bp[4], bp[5], bp[6]);

    // attention half — identical to the dense block
    let r1 = rms_r(x_in, d);
    let a = rms_apply(x_in, &r1, &g1.data, d);
    let qkv = mm(&a, &wqkv.data, t, d, 3 * d);
    let (oc, attn) = attention_fwd(cfg, &qkv);
    let x_mid: Vec<f32> = x_in
        .iter()
        .zip(&mm(&oc, &wo.data, t, d, d))
        .map(|(x, y)| x + y)
        .collect();
    let r2 = rms_r(&x_mid, d);
    let bnorm = rms_apply(&x_mid, &r2, &g2.data, d);

    // routing: softmax scores, stop-gradient top-k mask, renormalized
    // dense gates
    let scores = mm(&bnorm, &router.data, t, d, e_n);
    let mut probs = vec![0.0f32; t * e_n];
    for ti in 0..t {
        let row = &scores[ti * e_n..(ti + 1) * e_n];
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
        let mut sum = 0.0f32;
        let prow = &mut probs[ti * e_n..(ti + 1) * e_n];
        for (p, &x) in prow.iter_mut().zip(row) {
            *p = (x - max).exp();
            sum += *p;
        }
        for p in prow.iter_mut() {
            *p /= sum;
        }
    }
    let mut mask = vec![0.0f32; t * e_n];
    let mut remaining = probs.clone();
    for _ in 0..top_k {
        for ti in 0..t {
            let row = &remaining[ti * e_n..(ti + 1) * e_n];
            let mut best = 0usize;
            for (ei, &x) in row.iter().enumerate() {
                if x > row[best] {
                    best = ei;
                }
            }
            mask[ti * e_n + best] += 1.0;
            remaining[ti * e_n + best] -= 1e9;
        }
    }
    let kept: Vec<f32> = probs.iter().zip(&mask).map(|(p, m)| p * m).collect();
    let mut denom = vec![0.0f32; t];
    for ti in 0..t {
        denom[ti] =
            kept[ti * e_n..(ti + 1) * e_n].iter().sum::<f32>() + 1e-9;
    }
    let mut gates = vec![0.0f32; t * e_n];
    for ti in 0..t {
        for ei in 0..e_n {
            gates[ti * e_n + ei] = kept[ti * e_n + ei] / denom[ti];
        }
    }

    // dense expert compute, gate-combined
    let mut h_pre = vec![0.0f32; e_n * t * f];
    let mut h = vec![0.0f32; e_n * t * f];
    let mut out_e = vec![0.0f32; e_n * t * d];
    let mut out = vec![0.0f32; t * d];
    for ei in 0..e_n {
        let w1 = &w1e.data[ei * d * f..(ei + 1) * d * f];
        let w2 = &w2e.data[ei * f * d..(ei + 1) * f * d];
        let hp = mm(&bnorm, w1, t, d, f);
        let hg: Vec<f32> = hp.iter().map(|&x| gelu(x)).collect();
        let oe = mm(&hg, w2, t, f, d);
        for ti in 0..t {
            let g = gates[ti * e_n + ei];
            if g != 0.0 {
                for j in 0..d {
                    out[ti * d + j] += g * oe[ti * d + j];
                }
            }
        }
        h_pre[ei * t * f..(ei + 1) * t * f].copy_from_slice(&hp);
        h[ei * t * f..(ei + 1) * t * f].copy_from_slice(&hg);
        out_e[ei * t * d..(ei + 1) * t * d].copy_from_slice(&oe);
    }

    // Switch-style load-balancing loss
    let mut frac_tokens = vec![0.0f32; e_n];
    let mut frac_probs = vec![0.0f32; e_n];
    for ti in 0..t {
        for ei in 0..e_n {
            if gates[ti * e_n + ei] > 0.0 {
                frac_tokens[ei] += 1.0;
            }
            frac_probs[ei] += probs[ti * e_n + ei];
        }
    }
    for ei in 0..e_n {
        frac_tokens[ei] /= t as f32;
        frac_probs[ei] /= t as f32;
    }
    let aux: f32 = (e_n as f32)
        * frac_tokens.iter().zip(&frac_probs).map(|(a, b)| a * b).sum::<f32>();

    let x_out: Vec<f32> = x_mid.iter().zip(&out).map(|(x, y)| x + y).collect();
    let cache = MoeBlockCache {
        x_in: x_in.to_vec(),
        r1,
        a,
        attn,
        oc,
        x_mid,
        r2,
        bnorm,
        probs,
        mask,
        kept,
        denom,
        gates,
        h_pre,
        h,
        out_e,
        frac_tokens,
        aux,
    };
    Ok((x_out, cache))
}

/// Backward through one MoE block. `daux` is the coefficient the total
/// loss puts on this block's auxiliary loss (AUX_COEF / n_blocks).
/// Returns (dx, [dg1, dwqkv, dwo, dg2, drouter, dw1e, dw2e]).
pub(super) fn block_bwd_from_cache(
    cfg: &ModelCfg,
    bp: &[&Tensor],
    cache: &MoeBlockCache,
    dy: &[f32],
    daux: f32,
) -> Result<(Vec<f32>, Vec<Tensor>)> {
    let (b, s, d, f) = (cfg.batch, cfg.seq, cfg.d_model, cfg.d_ff);
    let t = b * s;
    let (e_n, _) = moe_cfg(cfg)?;
    let (g1, wqkv, wo, g2, router, w1e, w2e) =
        (bp[0], bp[1], bp[2], bp[3], bp[4], bp[5], bp[6]);

    // ---- expert MLP branch: x_out = x_mid + sum_e gates_e * out_e ----
    let mut dgates = vec![0.0f32; t * e_n];
    let mut dw1e = vec![0.0f32; e_n * d * f];
    let mut dw2e = vec![0.0f32; e_n * f * d];
    let mut dbnorm = vec![0.0f32; t * d];
    for ei in 0..e_n {
        let oe = &cache.out_e[ei * t * d..(ei + 1) * t * d];
        let hg = &cache.h[ei * t * f..(ei + 1) * t * f];
        let hp = &cache.h_pre[ei * t * f..(ei + 1) * t * f];
        let w1 = &w1e.data[ei * d * f..(ei + 1) * d * f];
        let w2 = &w2e.data[ei * f * d..(ei + 1) * f * d];
        // dgates and the gated upstream gradient
        let mut dout_e = vec![0.0f32; t * d];
        for ti in 0..t {
            let g = cache.gates[ti * e_n + ei];
            let mut acc = 0.0f32;
            for j in 0..d {
                let dyv = dy[ti * d + j];
                acc += oe[ti * d + j] * dyv;
                dout_e[ti * d + j] = g * dyv;
            }
            dgates[ti * e_n + ei] = acc;
        }
        dw2e[ei * f * d..(ei + 1) * f * d]
            .copy_from_slice(&mm_at(hg, &dout_e, t, f, d));
        let dh = mm_bt(&dout_e, w2, t, d, f);
        let dh_pre: Vec<f32> = dh
            .iter()
            .zip(hp)
            .map(|(&g, &u)| g * gelu_grad(u))
            .collect();
        dw1e[ei * d * f..(ei + 1) * d * f]
            .copy_from_slice(&mm_at(&cache.bnorm, &dh_pre, t, d, f));
        let db = mm_bt(&dh_pre, w1, t, f, d);
        for (acc, &x) in dbnorm.iter_mut().zip(&db) {
            *acc += x;
        }
    }

    // ---- routing backward ----
    // gates = kept / denom (mask is a stop-gradient)
    let mut dprobs = vec![0.0f32; t * e_n];
    for ti in 0..t {
        let dg = &dgates[ti * e_n..(ti + 1) * e_n];
        let kept = &cache.kept[ti * e_n..(ti + 1) * e_n];
        let den = cache.denom[ti];
        let mut num = 0.0f32;
        for (x, k) in dg.iter().zip(kept) {
            num += x * k;
        }
        for ei in 0..e_n {
            let dkept = dg[ei] / den - num / (den * den);
            dprobs[ti * e_n + ei] = dkept * cache.mask[ti * e_n + ei];
        }
    }
    // auxiliary loss: d aux / d probs[t,e] = E * frac_tokens[e] / T
    // (frac_tokens goes through a `> 0` comparison — zero gradient).
    let aux_scale = daux * e_n as f32 / t as f32;
    for ti in 0..t {
        for ei in 0..e_n {
            dprobs[ti * e_n + ei] += aux_scale * cache.frac_tokens[ei];
        }
    }
    // softmax backward
    let mut dscores = vec![0.0f32; t * e_n];
    for ti in 0..t {
        let p = &cache.probs[ti * e_n..(ti + 1) * e_n];
        let dp = &dprobs[ti * e_n..(ti + 1) * e_n];
        let mut dot = 0.0f32;
        for (x, y) in p.iter().zip(dp) {
            dot += x * y;
        }
        for ei in 0..e_n {
            dscores[ti * e_n + ei] = p[ei] * (dp[ei] - dot);
        }
    }
    let drouter = mm_at(&cache.bnorm, &dscores, t, d, e_n);
    let db = mm_bt(&dscores, &router.data, t, e_n, d);
    for (acc, &x) in dbnorm.iter_mut().zip(&db) {
        *acc += x;
    }

    // ---- back through the second norm + attention (as dense) ----
    let (dx_mid_norm, dg2) = rms_bwd(&dbnorm, &g2.data, &cache.x_mid, &cache.r2, d);
    let dx_mid: Vec<f32> = dy.iter().zip(&dx_mid_norm).map(|(a, b)| a + b).collect();
    let dwo = mm_at(&cache.oc, &dx_mid, t, d, d);
    let doc = mm_bt(&dx_mid, &wo.data, t, d, d);
    let dqkv = attention_bwd(cfg, &cache.attn, &doc);
    let dwqkv = mm_at(&cache.a, &dqkv, t, d, 3 * d);
    let da = mm_bt(&dqkv, &wqkv.data, t, 3 * d, d);
    let (dx_in_norm, dg1) = rms_bwd(&da, &g1.data, &cache.x_in, &cache.r1, d);
    let dx: Vec<f32> = dx_mid.iter().zip(&dx_in_norm).map(|(a, b)| a + b).collect();

    let grads = vec![
        Tensor::new(g1.shape.clone(), dg1),
        Tensor::new(wqkv.shape.clone(), dwqkv),
        Tensor::new(wo.shape.clone(), dwo),
        Tensor::new(g2.shape.clone(), dg2),
        Tensor::new(router.shape.clone(), drouter),
        Tensor::new(w1e.shape.clone(), dw1e),
        Tensor::new(w2e.shape.clone(), dw2e),
    ];
    Ok((dx, grads))
}

/// Whole-model MoE eval loss (plain cross-entropy; aux loss excluded,
/// matching `moe.moe_eval_loss`).
pub fn eval_loss(cfg: &ModelCfg, params: &[Tensor], toks: &[i32], tgts: &[i32]) -> Result<f32> {
    let mut x = embed_fwd(cfg, &params[0], &params[1], toks);
    for b in 0..cfg.n_blocks {
        let bp = block_params(params, b);
        let (x_out, _) = block_fwd_cached(cfg, &bp, &x)?;
        x = x_out;
    }
    let n = params.len();
    Ok(head_loss(cfg, &params[n - 2], &params[n - 1], &x, tgts))
}

/// Whole-model MoE loss + gradients. The returned loss is the plain
/// cross-entropy; the gradients are of `ce + 0.01 * mean_blocks(aux)`
/// (matching `moe.moe_fwdbwd`).
pub fn fwdbwd(
    cfg: &ModelCfg,
    params: &[Tensor],
    toks: &[i32],
    tgts: &[i32],
) -> Result<(f32, Vec<Tensor>)> {
    let n = params.len();
    let mut x = embed_fwd(cfg, &params[0], &params[1], toks);
    let mut caches = Vec::with_capacity(cfg.n_blocks);
    for b in 0..cfg.n_blocks {
        let bp = block_params(params, b);
        let (x_out, cache) = block_fwd_cached(cfg, &bp, &x)?;
        caches.push(cache);
        x = x_out;
    }
    let (ce, mut dx, dgf, dhead) =
        head_fwdbwd(cfg, &params[n - 2], &params[n - 1], &x, tgts);
    let daux = AUX_COEF / cfg.n_blocks as f32;
    let mut block_grads: Vec<Vec<Tensor>> = Vec::with_capacity(cfg.n_blocks);
    for b in (0..cfg.n_blocks).rev() {
        let bp = block_params(params, b);
        let (dx_new, grads) = block_bwd_from_cache(cfg, &bp, &caches[b], &dx, daux)?;
        dx = dx_new;
        block_grads.push(grads);
    }
    block_grads.reverse();
    let (dtok, dpos) = embed_bwd(cfg, toks, &dx);

    let mut grads = Vec::with_capacity(n);
    grads.push(dtok);
    grads.push(dpos);
    for bg in block_grads {
        grads.extend(bg);
    }
    grads.push(dgf);
    grads.push(dhead);
    let _total_aux: f32 = caches.iter().map(|c| c.aux).sum();
    Ok((ce, grads))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init_params;
    use crate::runtime::presets;

    fn setup() -> (ModelCfg, Vec<Tensor>, Vec<i32>, Vec<i32>) {
        let cfg = presets::builtin_model_cfg("moe_micro").unwrap();
        let man = presets::manifest_from_cfg(&cfg);
        let params = init_params(&man, 11);
        let t = cfg.batch * cfg.seq;
        let toks: Vec<i32> = (0..t).map(|i| ((i * 5 + 1) % cfg.vocab) as i32).collect();
        let tgts: Vec<i32> = (0..t).map(|i| ((i * 3 + 2) % cfg.vocab) as i32).collect();
        (cfg, params, toks, tgts)
    }

    #[test]
    fn moe_loss_near_ln_vocab_at_init() {
        let (cfg, params, toks, tgts) = setup();
        let loss = eval_loss(&cfg, &params, &toks, &tgts).unwrap();
        let expect = (cfg.vocab as f32).ln();
        assert!((loss - expect).abs() < 0.5, "loss {loss} vs ln V {expect}");
    }

    #[test]
    fn moe_fwdbwd_finite_and_top_k_routes() {
        let (cfg, params, toks, tgts) = setup();
        let (ce, grads) = fwdbwd(&cfg, &params, &toks, &tgts).unwrap();
        assert!(ce.is_finite());
        assert_eq!(grads.len(), params.len());
        for (g, p) in grads.iter().zip(&params) {
            assert_eq!(g.shape, p.shape);
            assert!(g.all_finite());
        }
        // routing: every token keeps exactly top_k experts
        let bp = block_params(&params, 0);
        let x = embed_fwd(&cfg, &params[0], &params[1], &toks);
        let (_, cache) = block_fwd_cached(&cfg, &bp, &x).unwrap();
        let e_n = cfg.moe.as_ref().unwrap().n_experts;
        let k = cfg.moe.as_ref().unwrap().top_k;
        for ti in 0..cfg.batch * cfg.seq {
            let nz = cache.mask[ti * e_n..(ti + 1) * e_n]
                .iter()
                .filter(|&&m| m > 0.0)
                .count();
            assert_eq!(nz, k);
            let gate_sum: f32 =
                cache.gates[ti * e_n..(ti + 1) * e_n].iter().sum();
            assert!((gate_sum - 1.0).abs() < 1e-4, "gates sum {gate_sum}");
        }
    }

    #[test]
    fn moe_router_grads_match_finite_differences() {
        let (cfg, params, toks, tgts) = setup();
        let (_, grads) = fwdbwd(&cfg, &params, &toks, &tgts).unwrap();
        let man = presets::manifest_from_cfg(&cfg);
        // total loss = ce + 0.01 * mean_b(aux): rebuild it for the
        // numeric check
        let total = |ps: &[Tensor]| -> f32 {
            let mut x = embed_fwd(&cfg, &ps[0], &ps[1], &toks);
            let mut aux_sum = 0.0f32;
            for b in 0..cfg.n_blocks {
                let bp = block_params(ps, b);
                let (x_out, cache) = block_fwd_cached(&cfg, &bp, &x).unwrap();
                aux_sum += cache.aux;
                x = x_out;
            }
            let n = ps.len();
            head_loss(&cfg, &ps[n - 2], &ps[n - 1], &x, &tgts)
                + AUX_COEF * aux_sum / cfg.n_blocks as f32
        };
        let eps = 1e-2f32;
        // Spot-check router, expert matrices, a gain and the head. A
        // perturbation can discretely flip a top-k routing decision
        // (the mask is a stop-gradient), so individual coordinates may
        // disagree; require the large majority to match instead.
        let mut checked = 0usize;
        let mut ok = 0usize;
        let mut worst = String::new();
        for name in ["b0.router", "b0.w1e", "b1.w2e", "b0.g2", "head"] {
            let pi = man.param_index(name).unwrap();
            for idx in [0usize, params[pi].len() / 2] {
                let mut pp = params.clone();
                pp[pi].data[idx] += eps;
                let mut pm = params.clone();
                pm[pi].data[idx] -= eps;
                let num = (total(&pp) - total(&pm)) / (2.0 * eps);
                let ana = grads[pi].data[idx];
                checked += 1;
                if (num - ana).abs() < 3e-3 + 0.08 * ana.abs().max(num.abs()) {
                    ok += 1;
                } else {
                    worst = format!("{name}[{idx}]: numeric {num} vs analytic {ana}");
                }
            }
        }
        assert!(ok + 1 >= checked, "{ok}/{checked} matched; e.g. {worst}");
    }
}
