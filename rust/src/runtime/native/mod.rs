//! The native (pure-Rust) compute backend.
//!
//! Serves every manifest executable with reference kernels — no Python,
//! no XLA, no artifacts on disk:
//!
//! * model graphs (`fwdbwd`, `fwdbwd_split`, `eval_loss`, `hvp`, and
//!   the per-block engine graphs) — the `dense` / `moe` submodules,
//!   ports of `python/compile/model.py` and `python/compile/moe.py`;
//! * batched optimizer graphs (`rot_adam_*`, `soap_*`, `eigen1st_*`,
//!   `eigen2nd_*`, `muon_*`) — fused single-pass loops over the stacked
//!   parameter slots, parallelized per slot on the kernel pool, calling
//!   the shared single-matrix reference implementations in
//!   [`crate::optim::reference`] (the same functions the integration
//!   tests cross-check the PJRT path against), so per-slot arithmetic
//!   is bit-identical to the serial reference loop by construction.

pub mod dense;
mod moe;

use anyhow::{anyhow, bail, Result};

use crate::optim::reference::{self, Scalars};
use crate::runtime::pool::Pool;
use crate::tensor::Tensor;

use super::{value_to_tensor, Backend, Manifest, Value};

/// Stateless native backend (each stage thread boxes its own copy).
pub struct NativeBackend;

impl Backend for NativeBackend {
    fn kind(&self) -> &'static str {
        "native"
    }

    fn exec(&self, man: &Manifest, name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
        let cfg = &man.cfg;
        let n = man.params.len();
        match name {
            "fwdbwd" => {
                let params = gather_params(man, inputs, 0)?;
                let toks = inputs[n].as_tokens()?;
                let tgts = inputs[n + 1].as_tokens()?;
                let (loss, grads) = if cfg.moe.is_some() {
                    moe::fwdbwd(cfg, &params, toks, tgts)?
                } else {
                    dense::fwdbwd(cfg, &params, toks, tgts)?
                };
                Ok(loss_and_grads(loss, grads))
            }
            "fwdbwd_split" => {
                let params_fwd = gather_params(man, inputs, 0)?;
                let params_bwd = gather_params(man, inputs, n)?;
                let toks = inputs[2 * n].as_tokens()?;
                let tgts = inputs[2 * n + 1].as_tokens()?;
                let (loss, grads) =
                    dense::fwdbwd_split(cfg, &params_fwd, &params_bwd, toks, tgts)?;
                Ok(loss_and_grads(loss, grads))
            }
            "eval_loss" => {
                let params = gather_params(man, inputs, 0)?;
                let toks = inputs[n].as_tokens()?;
                let tgts = inputs[n + 1].as_tokens()?;
                let loss = if cfg.moe.is_some() {
                    moe::eval_loss(cfg, &params, toks, tgts)?
                } else {
                    dense::eval_loss(cfg, &params, toks, tgts)?
                };
                Ok(vec![scalar(loss)])
            }
            "hvp" => {
                let params = gather_params(man, inputs, 0)?;
                let vecs = gather_params(man, inputs, n)?;
                let toks = inputs[2 * n].as_tokens()?;
                let tgts = inputs[2 * n + 1].as_tokens()?;
                let hv = dense::hvp(cfg, &params, &vecs, toks, tgts)?;
                Ok(hv.into_iter().map(Value::F32).collect())
            }
            "embed_fwd" => {
                let te = inputs[0].as_tensor()?;
                let pe = inputs[1].as_tensor()?;
                let toks = inputs[2].as_tokens()?;
                let x = dense::embed_fwd(cfg, te, pe, toks);
                Ok(vec![act(cfg, x)])
            }
            "embed_bwd" => {
                let toks = inputs[0].as_tokens()?;
                let dx = inputs[1].as_tensor()?;
                let (dtok, dpos) = dense::embed_bwd(cfg, toks, &dx.data);
                Ok(vec![Value::F32(dtok), Value::F32(dpos)])
            }
            "block_fwd" => {
                let nbp = if cfg.moe.is_some() { 7 } else { 6 };
                let bp: Vec<&Tensor> = collect_tensors(&inputs[..nbp])?;
                let x = inputs[nbp].as_tensor()?;
                let x_out = if cfg.moe.is_some() {
                    moe::block_fwd_cached(cfg, &bp, &x.data)?.0
                } else {
                    dense::block_fwd_cached(cfg, &bp, &x.data).0
                };
                Ok(vec![act(cfg, x_out)])
            }
            "block_bwd" => {
                let nbp = if cfg.moe.is_some() { 7 } else { 6 };
                let bp: Vec<&Tensor> = collect_tensors(&inputs[..nbp])?;
                let x = inputs[nbp].as_tensor()?;
                let dy = inputs[nbp + 1].as_tensor()?;
                // checkpoint-style: recompute the forward, then run the
                // backward off the recomputed cache
                let (dx, grads) = if cfg.moe.is_some() {
                    let (_, cache) = moe::block_fwd_cached(cfg, &bp, &x.data)?;
                    // each block carries its share of the Switch
                    // auxiliary loss, exactly as the monolithic MoE
                    // fwdbwd distributes it
                    let daux = moe::AUX_COEF / cfg.n_blocks as f32;
                    moe::block_bwd_from_cache(cfg, &bp, &cache, &dy.data, daux)?
                } else {
                    let (_, cache) = dense::block_fwd_cached(cfg, &bp, &x.data);
                    dense::block_bwd_from_cache(cfg, &bp, &cache, &dy.data)
                };
                let mut out = vec![act(cfg, dx)];
                out.extend(grads.into_iter().map(Value::F32));
                Ok(out)
            }
            "head_fwdbwd" => {
                let gf = inputs[0].as_tensor()?;
                let head = inputs[1].as_tensor()?;
                let x = inputs[2].as_tensor()?;
                let tgts = inputs[3].as_tokens()?;
                let (loss, dx, dgf, dhead) =
                    dense::head_fwdbwd(cfg, gf, head, &x.data, tgts);
                Ok(vec![scalar(loss), act(cfg, dx), Value::F32(dgf), Value::F32(dhead)])
            }
            "head_loss" => {
                let gf = inputs[0].as_tensor()?;
                let head = inputs[1].as_tensor()?;
                let x = inputs[2].as_tensor()?;
                let tgts = inputs[3].as_tokens()?;
                Ok(vec![scalar(dense::head_loss(cfg, gf, head, &x.data, tgts))])
            }
            _ => exec_optimizer(name, inputs),
        }
    }
}

// ---------------------------------------------------------------------------
// Input/output plumbing
// ---------------------------------------------------------------------------

// NOTE: this copies every parameter once more on top of the
// `tensor_to_value` clone at the call sites (the `Value` API is
// backend-neutral and kept drop-in with the old literal conversions).
// At the test-scale configs the native backend serves that is noise;
// a borrow-through `Value` view is the obvious next perf PR if large
// configs move onto this path.
fn gather_params(man: &Manifest, inputs: &[Value], offset: usize) -> Result<Vec<Tensor>> {
    man.params
        .iter()
        .enumerate()
        .map(|(i, p)| value_to_tensor(&inputs[offset + i], &p.shape))
        .collect()
}

fn collect_tensors(inputs: &[Value]) -> Result<Vec<&Tensor>> {
    inputs.iter().map(|v| v.as_tensor()).collect()
}

fn scalar(x: f32) -> Value {
    Value::F32(Tensor::new(vec![], vec![x]))
}

fn act(cfg: &super::ModelCfg, data: Vec<f32>) -> Value {
    Value::F32(Tensor::new(vec![cfg.batch, cfg.seq, cfg.d_model], data))
}

fn loss_and_grads(loss: f32, grads: Vec<Tensor>) -> Vec<Value> {
    let mut out = Vec::with_capacity(1 + grads.len());
    out.push(scalar(loss));
    out.extend(grads.into_iter().map(Value::F32));
    out
}

// ---------------------------------------------------------------------------
// Batched optimizer kernels (rot_adam / soap / eigen / muon)
// ---------------------------------------------------------------------------

pub fn exec_optimizer(name: &str, inputs: &[Value]) -> Result<Vec<Value>> {
    if let Some(rest) = name.strip_prefix("rot_adam_") {
        let (uni, _cls) = parse_geometry(name, rest)?;
        return rotated_update(inputs, uni, false);
    }
    if let Some(rest) = name.strip_prefix("soap_") {
        let (uni, _cls) = parse_geometry(name, rest)?;
        return rotated_update(inputs, uni, true);
    }
    if let Some(rest) = name.strip_prefix("eigen2nd_") {
        let (uni, _cls) = parse_geometry(name, rest)?;
        return eigen2nd(inputs, uni);
    }
    if let Some(rest) = name.strip_prefix("eigen1st_") {
        let (uni, _cls) = parse_geometry(name, rest)?;
        return eigen1st(inputs, uni);
    }
    if name.strip_prefix("muon_").is_some() {
        return muon(inputs);
    }
    bail!("native backend: no implementation for executable {name:?}")
}

fn parse_geometry<'a>(name: &str, rest: &'a str) -> Result<(bool, &'a str)> {
    if let Some(cls) = rest.strip_prefix("bi_") {
        Ok((false, cls))
    } else if let Some(cls) = rest.strip_prefix("uni_") {
        Ok((true, cls))
    } else {
        Err(anyhow!("native backend: bad geometry tag in executable {name:?}"))
    }
}

/// Per-slot scalar row `[lr, beta1, beta2, eps, wd, t, mask, _]`.
fn scalars_row(sc: &Tensor, i: usize) -> (Scalars, f32) {
    let r = &sc.data[i * 8..(i + 1) * 8];
    (
        Scalars { lr: r[0], beta1: r[1], beta2: r[2], eps: r[3], wd: r[4], t: r[5] },
        r[6],
    )
}

/// Threads for a batched optimizer dispatch: one task per stacked slot,
/// inline below the kernel-layer work threshold (micro configs) or for
/// a single slot.
fn opt_threads(nb: usize, slot: usize) -> usize {
    if nb > 1 && nb * slot >= 8 * 1024 {
        crate::runtime::pool::kernel_threads()
    } else {
        1
    }
}

/// Batched rotated-Adam (Algorithm 1) / SOAP update.
///
/// Fused: reads the stacked inputs in place (no unstack copies), writes
/// straight into preallocated stacked outputs through disjoint per-slot
/// `chunks_mut`, and runs one pool task per slot. Each task calls the
/// single-matrix reference update, so the result is bit-identical to
/// the serial unstack/stack loop at any thread count.
fn rotated_update(inputs: &[Value], unilateral: bool, soap: bool) -> Result<Vec<Value>> {
    let w = inputs[0].as_tensor()?;
    let g = inputs[1].as_tensor()?;
    let m = inputs[2].as_tensor()?;
    let vt = inputs[3].as_tensor()?;
    let u = inputs[4].as_tensor()?;
    let v = inputs[5].as_tensor()?;
    let sc = inputs[6].as_tensor()?;
    let nb = w.shape[0];
    let slot = w.data.len() / nb;
    let mut w_new = Tensor::zeros(&w.shape);
    let mut m_new = Tensor::zeros(&m.shape);
    let mut vt_new = Tensor::zeros(&vt.shape);
    {
        let threads = opt_threads(nb, slot);
        let mut tasks = Vec::with_capacity(nb);
        for ((i, wo), (mo, vo)) in w_new
            .data
            .chunks_mut(slot)
            .enumerate()
            .zip(m_new.data.chunks_mut(slot).zip(vt_new.data.chunks_mut(slot)))
        {
            tasks.push(move || {
                let (s, _mask) = scalars_row(sc, i);
                let (wi, mi, vi) = if soap {
                    reference::soap_update(
                        &w.index_axis0(i),
                        &g.index_axis0(i),
                        &m.index_axis0(i),
                        &vt.index_axis0(i),
                        &u.index_axis0(i),
                        &v.index_axis0(i),
                        s,
                        unilateral,
                    )
                } else {
                    reference::rotated_adam(
                        &w.index_axis0(i),
                        &g.index_axis0(i),
                        &m.index_axis0(i),
                        &vt.index_axis0(i),
                        &u.index_axis0(i),
                        &v.index_axis0(i),
                        s,
                        unilateral,
                    )
                };
                wo.copy_from_slice(&wi.data);
                mo.copy_from_slice(&mi.data);
                vo.copy_from_slice(&vi.data);
            });
        }
        Pool::scope(threads, tasks);
    }
    Ok(vec![Value::F32(w_new), Value::F32(m_new), Value::F32(vt_new)])
}

/// Which sides rotate: bilateral rotates both, unilateral only the
/// smaller dimension (paper section 3.2).
fn sides(m: usize, n: usize, unilateral: bool) -> (bool, bool) {
    if !unilateral {
        (true, true)
    } else if m <= n {
        (true, false)
    } else {
        (false, true)
    }
}

/// Batched Algorithm 2, S=2nd: Fisher-factor EMAs always advance, bases
/// refresh where mask = 1. Fused + per-slot parallel like
/// [`rotated_update`].
fn eigen2nd(inputs: &[Value], unilateral: bool) -> Result<Vec<Value>> {
    let l = inputs[0].as_tensor()?;
    let r = inputs[1].as_tensor()?;
    let g = inputs[2].as_tensor()?;
    let u = inputs[3].as_tensor()?;
    let v = inputs[4].as_tensor()?;
    let sc = inputs[5].as_tensor()?;
    let nb = g.shape[0];
    let ls = l.data.len() / nb;
    let rs = r.data.len() / nb;
    let us = u.data.len() / nb;
    let vs = v.data.len() / nb;
    let mut l_new = Tensor::zeros(&l.shape);
    let mut r_new = Tensor::zeros(&r.shape);
    let mut u_new = Tensor::zeros(&u.shape);
    let mut v_new = Tensor::zeros(&v.shape);
    {
        let threads = opt_threads(nb, g.data.len() / nb);
        let mut tasks = Vec::with_capacity(nb);
        for ((i, (lo, ro)), (uo, vo)) in l_new
            .data
            .chunks_mut(ls)
            .zip(r_new.data.chunks_mut(rs))
            .enumerate()
            .zip(u_new.data.chunks_mut(us).zip(v_new.data.chunks_mut(vs)))
        {
            tasks.push(move || {
                let (s, mask) = scalars_row(sc, i);
                let gi = g.index_axis0(i);
                let (mm, nn) = gi.dims2();
                let (left, right) = sides(mm, nn, unilateral);
                if left {
                    let li = l
                        .index_axis0(i)
                        .scale(s.beta2)
                        .add(&gi.matmul(&gi.transpose()).scale(1.0 - s.beta2));
                    if mask >= 0.5 {
                        uo.copy_from_slice(
                            &reference::power_qr(&li, &u.index_axis0(i)).data,
                        );
                    } else {
                        uo.copy_from_slice(&u.data[i * us..(i + 1) * us]);
                    }
                    lo.copy_from_slice(&li.data);
                } else {
                    lo.copy_from_slice(&l.data[i * ls..(i + 1) * ls]);
                    uo.copy_from_slice(&u.data[i * us..(i + 1) * us]);
                }
                if right {
                    let ri = r
                        .index_axis0(i)
                        .scale(s.beta2)
                        .add(&gi.transpose().matmul(&gi).scale(1.0 - s.beta2));
                    if mask >= 0.5 {
                        vo.copy_from_slice(
                            &reference::power_qr(&ri, &v.index_axis0(i)).data,
                        );
                    } else {
                        vo.copy_from_slice(&v.data[i * vs..(i + 1) * vs]);
                    }
                    ro.copy_from_slice(&ri.data);
                } else {
                    ro.copy_from_slice(&r.data[i * rs..(i + 1) * rs]);
                    vo.copy_from_slice(&v.data[i * vs..(i + 1) * vs]);
                }
            });
        }
        Pool::scope(threads, tasks);
    }
    Ok(vec![
        Value::F32(l_new),
        Value::F32(r_new),
        Value::F32(u_new),
        Value::F32(v_new),
    ])
}

/// Batched Algorithm 2, S=1st: momentum outer products, no EMA storage.
/// Fused + per-slot parallel like [`rotated_update`].
fn eigen1st(inputs: &[Value], unilateral: bool) -> Result<Vec<Value>> {
    let m = inputs[0].as_tensor()?;
    let u = inputs[1].as_tensor()?;
    let v = inputs[2].as_tensor()?;
    let sc = inputs[3].as_tensor()?;
    let nb = m.shape[0];
    let us = u.data.len() / nb;
    let vs = v.data.len() / nb;
    let mut u_new = Tensor::zeros(&u.shape);
    let mut v_new = Tensor::zeros(&v.shape);
    {
        let threads = opt_threads(nb, m.data.len() / nb);
        let mut tasks = Vec::with_capacity(nb);
        for ((i, uo), vo) in u_new
            .data
            .chunks_mut(us)
            .enumerate()
            .zip(v_new.data.chunks_mut(vs))
        {
            tasks.push(move || {
                let (_, mask) = scalars_row(sc, i);
                let mi = m.index_axis0(i);
                let (mm, nn) = mi.dims2();
                let (left, right) = sides(mm, nn, unilateral);
                if left && mask >= 0.5 {
                    uo.copy_from_slice(
                        &reference::power_qr(&mi.matmul(&mi.transpose()), &u.index_axis0(i))
                            .data,
                    );
                } else {
                    uo.copy_from_slice(&u.data[i * us..(i + 1) * us]);
                }
                if right && mask >= 0.5 {
                    vo.copy_from_slice(
                        &reference::power_qr(&mi.transpose().matmul(&mi), &v.index_axis0(i))
                            .data,
                    );
                } else {
                    vo.copy_from_slice(&v.data[i * vs..(i + 1) * vs]);
                }
            });
        }
        Pool::scope(threads, tasks);
    }
    Ok(vec![Value::F32(u_new), Value::F32(v_new)])
}

/// Batched Muon: momentum accumulation + Newton-Schulz
/// orthogonalization. Returns (mom', O); the optimizer applies the
/// spectral-scaled step. Fused + per-slot parallel like
/// [`rotated_update`].
fn muon(inputs: &[Value]) -> Result<Vec<Value>> {
    let mom = inputs[0].as_tensor()?;
    let g = inputs[1].as_tensor()?;
    let sc = inputs[2].as_tensor()?;
    let nb = mom.shape[0];
    let slot = mom.data.len() / nb;
    let mut mom_new = Tensor::zeros(&mom.shape);
    let mut orth = Tensor::zeros(&mom.shape);
    {
        let threads = opt_threads(nb, slot);
        let mut tasks = Vec::with_capacity(nb);
        for ((i, mo), oo) in mom_new
            .data
            .chunks_mut(slot)
            .enumerate()
            .zip(orth.data.chunks_mut(slot))
        {
            tasks.push(move || {
                let beta = sc.data[i * 8 + 1];
                let mi = mom.index_axis0(i).scale(beta).add(&g.index_axis0(i));
                oo.copy_from_slice(&reference::ns_orthonormalize(&mi).data);
                mo.copy_from_slice(&mi.data);
            });
        }
        Pool::scope(threads, tasks);
    }
    Ok(vec![Value::F32(mom_new), Value::F32(orth)])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rngs::Rng;
    use crate::runtime::Runtime;
    use crate::tensor::{stack, unstack};

    fn stack_tensors(ts: &[Tensor]) -> Tensor {
        let refs: Vec<&Tensor> = ts.iter().collect();
        stack(&refs)
    }

    fn randn(rng: &mut Rng, shape: &[usize]) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, 1.0);
        t
    }

    #[test]
    fn engine_and_sim_graphs_compose_identically() {
        // fwdbwd composed of embed/block/head graphs through the
        // backend must reproduce the monolithic fwdbwd bit-for-bit —
        // the property the threaded engine's equivalence rests on.
        let rt = Runtime::native("micro").unwrap();
        let cfg = rt.cfg().clone();
        let man = &rt.manifest;
        let params = crate::model::init_params(man, 3);
        let t = cfg.batch * cfg.seq;
        let toks: Vec<i32> = (0..t).map(|i| ((i * 7 + 2) % cfg.vocab) as i32).collect();
        let tgts: Vec<i32> = (0..t).map(|i| ((i * 5 + 1) % cfg.vocab) as i32).collect();

        let (loss_mono, grads_mono) = dense::fwdbwd(&cfg, &params, &toks, &tgts).unwrap();

        // per-block composition (what the engine threads execute)
        let mut x = dense::embed_fwd(&cfg, &params[0], &params[1], &toks);
        let mut xs = Vec::new();
        for b in 0..cfg.n_blocks {
            xs.push(x.clone());
            let bp = dense::block_params(&params, b);
            let (x_out, _) = dense::block_fwd_cached(&cfg, &bp, &x);
            x = x_out;
        }
        let n = params.len();
        let (loss_eng, mut dx, dgf, dhead) =
            dense::head_fwdbwd(&cfg, &params[n - 2], &params[n - 1], &x, &tgts);
        assert_eq!(loss_mono, loss_eng);
        assert_eq!(grads_mono[n - 2].data, dgf.data);
        assert_eq!(grads_mono[n - 1].data, dhead.data);
        for b in (0..cfg.n_blocks).rev() {
            let bp = dense::block_params(&params, b);
            let (_, cache) = dense::block_fwd_cached(&cfg, &bp, &xs[b]);
            let (dx_new, grads) = dense::block_bwd_from_cache(&cfg, &bp, &cache, &dx);
            dx = dx_new;
            for (j, g) in grads.iter().enumerate() {
                assert_eq!(
                    grads_mono[2 + b * 6 + j].data, g.data,
                    "block {b} grad {j} differs"
                );
            }
        }
        let (dtok, dpos) = dense::embed_bwd(&cfg, &toks, &dx);
        assert_eq!(grads_mono[0].data, dtok.data);
        assert_eq!(grads_mono[1].data, dpos.data);
    }

    #[test]
    fn moe_engine_and_sim_graphs_compose_identically() {
        // The per-block MoE composition (embed/block/head graphs, what
        // the engine threads execute) must reproduce the monolithic MoE
        // fwdbwd bit-for-bit, including the per-block share of the
        // Switch auxiliary gradient.
        let rt = Runtime::native("moe_micro").unwrap();
        let cfg = rt.cfg().clone();
        let man = &rt.manifest;
        let params = crate::model::init_params(man, 3);
        let t = cfg.batch * cfg.seq;
        let toks: Vec<i32> = (0..t).map(|i| ((i * 7 + 2) % cfg.vocab) as i32).collect();
        let tgts: Vec<i32> = (0..t).map(|i| ((i * 5 + 1) % cfg.vocab) as i32).collect();

        let (loss_mono, grads_mono) = moe::fwdbwd(&cfg, &params, &toks, &tgts).unwrap();

        let bp_of = |b: usize| -> Vec<&Tensor> {
            params[2 + b * 7..2 + (b + 1) * 7].iter().collect()
        };
        let mut x = dense::embed_fwd(&cfg, &params[0], &params[1], &toks);
        let mut xs = Vec::new();
        for b in 0..cfg.n_blocks {
            xs.push(x.clone());
            let (x_out, _) = moe::block_fwd_cached(&cfg, &bp_of(b), &x).unwrap();
            x = x_out;
        }
        let n = params.len();
        let (loss_eng, mut dx, dgf, dhead) =
            dense::head_fwdbwd(&cfg, &params[n - 2], &params[n - 1], &x, &tgts);
        assert_eq!(loss_mono, loss_eng);
        assert_eq!(grads_mono[n - 2].data, dgf.data);
        assert_eq!(grads_mono[n - 1].data, dhead.data);
        let daux = moe::AUX_COEF / cfg.n_blocks as f32;
        for b in (0..cfg.n_blocks).rev() {
            let (_, cache) = moe::block_fwd_cached(&cfg, &bp_of(b), &xs[b]).unwrap();
            let (dx_new, grads) =
                moe::block_bwd_from_cache(&cfg, &bp_of(b), &cache, &dx, daux).unwrap();
            dx = dx_new;
            for (j, g) in grads.iter().enumerate() {
                assert_eq!(
                    grads_mono[2 + b * 7 + j].data, g.data,
                    "moe block {b} grad {j} differs"
                );
            }
        }
        let (dtok, dpos) = dense::embed_bwd(&cfg, &toks, &dx);
        assert_eq!(grads_mono[0].data, dtok.data);
        assert_eq!(grads_mono[1].data, dpos.data);
    }

    #[test]
    fn native_rot_adam_matches_reference() {
        let mut rng = Rng::new(42);
        let (nb, m, n) = (2usize, 6usize, 10usize);
        let mk = |rng: &mut Rng| -> Vec<Tensor> {
            (0..nb).map(|_| randn(rng, &[m, n])).collect()
        };
        let w = mk(&mut rng);
        let g = mk(&mut rng);
        let mo = mk(&mut rng);
        let vt: Vec<Tensor> = mk(&mut rng).iter().map(|t| t.map(f32::abs)).collect();
        let u: Vec<Tensor> =
            (0..nb).map(|_| reference::cgs2_qr(&randn(&mut rng, &[m, m]))).collect();
        let v: Vec<Tensor> =
            (0..nb).map(|_| reference::cgs2_qr(&randn(&mut rng, &[n, n]))).collect();
        let s = Scalars { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, wd: 0.01, t: 3.0 };
        let mut sc = Tensor::zeros(&[nb, 8]);
        for i in 0..nb {
            sc.data[i * 8..(i + 1) * 8].copy_from_slice(&s.to_row(1.0));
        }
        let inputs = vec![
            Value::F32(stack_tensors(&w)),
            Value::F32(stack_tensors(&g)),
            Value::F32(stack_tensors(&mo)),
            Value::F32(stack_tensors(&vt)),
            Value::F32(stack_tensors(&u)),
            Value::F32(stack_tensors(&v)),
            Value::F32(sc),
        ];
        let outs = rotated_update(&inputs, false, false).unwrap();
        let w_out = unstack(outs[0].as_tensor().unwrap());
        for i in 0..nb {
            let (wr, _, _) =
                reference::rotated_adam(&w[i], &g[i], &mo[i], &vt[i], &u[i], &v[i], s, false);
            assert_eq!(w_out[i].data, wr.data);
        }
    }

    #[test]
    fn eigen2nd_mask_gates_basis_not_ema() {
        let mut rng = Rng::new(5);
        let (m, n) = (5usize, 7usize);
        let g = randn(&mut rng, &[m, n]);
        let u = reference::cgs2_qr(&randn(&mut rng, &[m, m]));
        let v = reference::cgs2_qr(&randn(&mut rng, &[n, n]));
        let l = Tensor::zeros(&[m, m]);
        let r = Tensor::zeros(&[n, n]);
        let s = Scalars { lr: 0.0, beta1: 0.9, beta2: 0.99, eps: 0.0, wd: 0.0, t: 1.0 };
        let mut sc = Tensor::zeros(&[1, 8]);
        sc.data.copy_from_slice(&s.to_row(0.0)); // mask = 0
        let inputs = vec![
            Value::F32(stack_tensors(std::slice::from_ref(&l))),
            Value::F32(stack_tensors(std::slice::from_ref(&r))),
            Value::F32(stack_tensors(std::slice::from_ref(&g))),
            Value::F32(stack_tensors(std::slice::from_ref(&u))),
            Value::F32(stack_tensors(std::slice::from_ref(&v))),
            Value::F32(sc),
        ];
        let outs = eigen2nd(&inputs, false).unwrap();
        // EMA advanced even with mask=0 ...
        let l_new = &unstack(outs[0].as_tensor().unwrap())[0];
        let expect = g.matmul(&g.transpose()).scale(0.01);
        assert!(l_new.sub(&expect).max_abs() < 1e-5);
        // ... but the bases did not move
        assert_eq!(unstack(outs[2].as_tensor().unwrap())[0].data, u.data);
        assert_eq!(unstack(outs[3].as_tensor().unwrap())[0].data, v.data);
    }

    #[test]
    fn unknown_executable_is_a_clear_error() {
        let err = exec_optimizer("totally_unknown", &[]).unwrap_err().to_string();
        assert!(err.contains("totally_unknown"), "{err}");
    }
}
