"""MoE variant (paper Fig. 21): routing, gating, grads, aux loss."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, moe
from compile.configs import MOE_MICRO, MoeConfig, ModelConfig


@pytest.fixture(scope="module")
def setup():
    cfg = MOE_MICRO
    p = model.init_params(cfg, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (cfg.batch, cfg.seq), 0,
                             cfg.vocab)
    tgt = jax.random.randint(jax.random.PRNGKey(2), (cfg.batch, cfg.seq), 0,
                             cfg.vocab)
    return cfg, p, tok, tgt


def test_schema_has_expert_tensors(setup):
    cfg, p, _, _ = setup
    names = [n for (n, *_r) in cfg.param_schema()]
    assert "b0.router" in names and "b0.w1e" in names and "b0.w2e" in names
    E = cfg.moe.n_experts
    for arr, (_n, shape, kind, _b, _r) in zip(p, cfg.param_schema()):
        if kind == "expert":
            assert arr.shape[0] == E


def test_fwdbwd_shapes_and_finiteness(setup):
    cfg, p, tok, tgt = setup
    out = moe.moe_fwdbwd(cfg, p, tok, tgt)
    assert len(out) == 1 + len(p)
    assert np.isfinite(float(out[0]))
    for g, w in zip(out[1:], p):
        assert g.shape == w.shape
        assert np.isfinite(np.array(g)).all()


def test_router_and_experts_receive_gradient(setup):
    cfg, p, tok, tgt = setup
    out = moe.moe_fwdbwd(cfg, p, tok, tgt)
    schema = cfg.param_schema()
    for i, (n, _s, kind, _b, _r) in enumerate(schema):
        if kind == "expert" or n.endswith(".router"):
            assert float(np.abs(np.array(out[1 + i])).max()) > 0, n


def test_gates_top2_sparse():
    cfg = MOE_MICRO
    rng = np.random.default_rng(0)
    D, E = cfg.d_model, cfg.moe.n_experts
    router = jnp.array(rng.standard_normal((D, E)), dtype=jnp.float32)
    w1e = jnp.array(0.1 * rng.standard_normal((E, D, cfg.d_ff)),
                    dtype=jnp.float32)
    w2e = jnp.array(0.1 * rng.standard_normal((E, cfg.d_ff, D)),
                    dtype=jnp.float32)
    x = jnp.array(rng.standard_normal((cfg.batch, cfg.seq, D)),
                  dtype=jnp.float32)
    out, aux = moe.moe_mlp(cfg, router, w1e, w2e, x)
    assert out.shape == x.shape
    assert float(aux) > 0


def test_topk_equals_dense_when_k_is_E():
    """With top_k == n_experts the routed MLP equals the fully dense
    gate-weighted mixture — validates the dispatch-free implementation."""
    cfg = ModelConfig("moe_all", vocab=64, seq=8, d_model=16, n_heads=2,
                      n_blocks=1, d_ff=32, batch=2, moe=MoeConfig(4, 4))
    rng = np.random.default_rng(1)
    E, D, F = 4, 16, 32
    router = jnp.array(rng.standard_normal((D, E)), dtype=jnp.float32)
    w1e = jnp.array(0.1 * rng.standard_normal((E, D, F)), dtype=jnp.float32)
    w2e = jnp.array(0.1 * rng.standard_normal((E, F, D)), dtype=jnp.float32)
    x = jnp.array(rng.standard_normal((2, 8, D)), dtype=jnp.float32)
    out, _ = moe.moe_mlp(cfg, router, w1e, w2e, x)
    probs = jax.nn.softmax(x @ router, axis=-1)
    h = jnp.einsum("bsd,edf->bsef", x, w1e)
    dense = jnp.einsum("bsef,efd->bsed", model.gelu(h), w2e)
    want = jnp.einsum("bsed,bse->bsd", dense, probs)
    np.testing.assert_allclose(np.array(out), np.array(want), rtol=1e-4,
                               atol=1e-5)


def test_moe_trains(setup):
    cfg, p, tok, tgt = setup
    p = [jnp.array(x) for x in p]
    loss0 = float(moe.moe_fwdbwd(cfg, p, tok, tgt)[0])
    for _ in range(10):
        out = moe.moe_fwdbwd(cfg, p, tok, tgt)
        p = [w - 1e-2 * g for w, g in zip(p, out[1:])]
    assert float(moe.moe_fwdbwd(cfg, p, tok, tgt)[0]) < loss0
