"""L1 Pallas kernels vs pure-jnp oracles (the core correctness signal).

hypothesis sweeps shapes; every kernel must match ``ref.py`` to fp32
tolerance on every generated case.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.adam_step import adam_direction, vmem_bytes as adam_vmem
from compile.kernels.attention import causal_attention, vmem_bytes as att_vmem
from compile.kernels.matmul import batched_matmul, matmul, pick_block, \
    vmem_bytes as mm_vmem
from compile.kernels.rotated_adam import rotated_adam_step, soap_step

DIMS = st.sampled_from([1, 2, 3, 4, 8, 12, 16, 48, 63, 100, 144])


def _scalars(t=3.0):
    return jnp.array([1e-3, 0.9, 0.999, 1e-8, 0.01, t, 1.0, 0.0],
                     dtype=jnp.float32)


class TestPickBlock:
    @given(st.integers(1, 4096))
    @settings(max_examples=60, deadline=None)
    def test_divides_and_bounded(self, d):
        b = pick_block(d)
        assert 1 <= b <= min(d, 128)
        assert d % b == 0

    def test_mxu_sized_when_possible(self):
        assert pick_block(256) == 128
        assert pick_block(128) == 128
        assert pick_block(48) == 16
        assert pick_block(192) == 64


class TestMatmul:
    @given(m=DIMS, k=DIMS, n=DIMS)
    @settings(max_examples=25, deadline=None)
    def test_matches_ref(self, m, k, n):
        rng = np.random.default_rng(m * 10007 + k * 101 + n)
        a = rng.standard_normal((m, k)).astype(np.float32)
        b = rng.standard_normal((k, n)).astype(np.float32)
        got = np.array(matmul(jnp.array(a), jnp.array(b)))
        np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-4)

    def test_batched(self):
        rng = np.random.default_rng(0)
        a = rng.standard_normal((5, 32, 48)).astype(np.float32)
        b = rng.standard_normal((5, 48, 16)).astype(np.float32)
        got = np.array(batched_matmul(jnp.array(a), jnp.array(b)))
        np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-4)

    def test_vmem_under_tpu_budget(self):
        # One grid step of the largest shape class must fit VMEM (16 MiB).
        assert mm_vmem(1024, 4096, 1024) < 16 * 2 ** 20


class TestAdamDirection:
    @given(m=st.sampled_from([4, 16, 48]), n=st.sampled_from([4, 16, 144]),
           t=st.integers(1, 1000))
    @settings(max_examples=15, deadline=None)
    def test_matches_ref(self, m, n, t):
        rng = np.random.default_rng(m + n + t)
        g = rng.standard_normal((m, n)).astype(np.float32)
        mm = rng.standard_normal((m, n)).astype(np.float32)
        v = np.abs(rng.standard_normal((m, n))).astype(np.float32)
        sc = _scalars(float(t))
        d, vn = adam_direction(jnp.array(g), jnp.array(mm), jnp.array(v), sc)
        dr, vr = ref.adam_direction_ref(jnp.array(g), jnp.array(mm),
                                        jnp.array(v), sc)
        np.testing.assert_allclose(np.array(d), np.array(dr), rtol=1e-5,
                                   atol=1e-6)
        np.testing.assert_allclose(np.array(vn), np.array(vr), rtol=1e-5,
                                   atol=1e-6)

    def test_vmem_budget(self):
        assert adam_vmem(4096, 4096) < 16 * 2 ** 20


class TestAttention:
    @given(h=st.sampled_from([1, 2, 4]), s=st.sampled_from([8, 16, 48]),
           hd=st.sampled_from([4, 8, 16]))
    @settings(max_examples=12, deadline=None)
    def test_matches_ref(self, h, s, hd):
        rng = np.random.default_rng(h * 31 + s * 7 + hd)
        q, k, v = (rng.standard_normal((h, s, hd)).astype(np.float32)
                   for _ in range(3))
        got = np.array(causal_attention(jnp.array(q), jnp.array(k),
                                        jnp.array(v)))
        want = np.array(ref.attention_ref(jnp.array(q), jnp.array(k),
                                          jnp.array(v)))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_causality(self):
        """Changing future keys/values must not affect earlier outputs."""
        rng = np.random.default_rng(7)
        q, k, v = (rng.standard_normal((2, 16, 8)).astype(np.float32)
                   for _ in range(3))
        o1 = np.array(causal_attention(jnp.array(q), jnp.array(k),
                                       jnp.array(v)))
        k2, v2 = k.copy(), v.copy()
        k2[:, 12:], v2[:, 12:] = 99.0, -99.0
        o2 = np.array(causal_attention(jnp.array(q), jnp.array(k2),
                                       jnp.array(v2)))
        np.testing.assert_allclose(o1[:, :12], o2[:, :12], rtol=1e-4,
                                   atol=1e-5)

    def test_vmem_budget(self):
        assert att_vmem(2048, 128) < 16 * 2 ** 20


class TestRotatedAdam:
    def _case(self, m, n, seed=0):
        rng = np.random.default_rng(seed)
        w, g, mm = (rng.standard_normal((m, n)).astype(np.float32)
                    for _ in range(3))
        v = np.abs(rng.standard_normal((m, n))).astype(np.float32)
        u = np.linalg.qr(rng.standard_normal((m, m)))[0].astype(np.float32)
        vv = np.linalg.qr(rng.standard_normal((n, n)))[0].astype(np.float32)
        return tuple(jnp.array(x) for x in (w, g, mm, v, u, vv))

    @pytest.mark.parametrize("m,n", [(16, 16), (16, 48), (48, 16)])
    @pytest.mark.parametrize("uni", [False, True])
    def test_matches_ref(self, m, n, uni):
        args = self._case(m, n, seed=m * n)
        sc = _scalars()
        got = rotated_adam_step(*args, sc, unilateral=uni)
        want = ref.rotated_adam_ref(*args, sc, unilateral=uni)
        for a, b in zip(got, want):
            np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-5,
                                       atol=1e-6)

    def test_identity_rotation_is_plain_adam(self):
        """U=V=I ⇒ basis rotation degenerates to standard Adam."""
        m, n = 16, 32
        rng = np.random.default_rng(3)
        w, g, mm = (rng.standard_normal((m, n)).astype(np.float32)
                    for _ in range(3))
        v = np.abs(rng.standard_normal((m, n))).astype(np.float32)
        sc = _scalars()
        got = rotated_adam_step(
            jnp.array(w), jnp.array(g), jnp.array(mm), jnp.array(v),
            jnp.eye(m), jnp.eye(n), sc)
        # plain adam reference
        m_new = 0.9 * mm + 0.1 * g
        v_new = 0.999 * v + 0.001 * g * g
        mhat = m_new / (1 - 0.9 ** 3)
        vhat = v_new / (1 - 0.999 ** 3)
        w_new = w - 1e-3 * (mhat / (np.sqrt(vhat) + 1e-8) + 0.01 * w)
        np.testing.assert_allclose(np.array(got[0]), w_new, rtol=1e-5,
                                   atol=1e-6)

    def test_rotation_equivariance(self):
        """Appendix C: Adam run in the rotated space == basis-rotation
        update projected back, for any fixed orthogonal U, V."""
        m, n = 16, 16
        w, g, mm, v, u, vv = self._case(m, n, seed=11)
        sc = _scalars(t=1.0)
        zero_m = jnp.zeros_like(mm)
        # basis-rotation step from fresh state
        w1, _, _ = rotated_adam_step(w, g, zero_m, jnp.zeros_like(v), u, vv,
                                     sc)
        # the same step computed natively in the rotated space
        wr = u.T @ w @ vv
        gr = u.T @ g @ vv
        m_new = 0.1 * gr
        v_new = 0.001 * gr * gr
        mhat = m_new / (1 - 0.9)
        vhat = v_new / (1 - 0.999)
        wr_new = wr - 1e-3 * (mhat / (jnp.sqrt(vhat) + 1e-8))
        w1_rotated_back = u @ wr_new @ vv.T - 1e-3 * 0.01 * w
        np.testing.assert_allclose(np.array(w1), np.array(w1_rotated_back),
                                   rtol=1e-4, atol=1e-5)

    @pytest.mark.parametrize("uni", [False, True])
    def test_soap_matches_ref(self, uni):
        args = self._case(16, 48, seed=5)
        sc = _scalars()
        got = soap_step(*args, sc, unilateral=uni)
        want = ref.soap_update_ref(*args, sc, unilateral=uni)
        for a, b in zip(got, want):
            np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-5,
                                       atol=1e-6)
