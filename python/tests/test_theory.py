"""Executable checks of the paper's theoretical claims.

* Theorem 3.1: for a Kronecker-factored empirical Fisher,
  ‖H_{U,V}‖₁,₁ ≤ ‖H_U‖₁,₁ ≤ ‖H‖₁,₁ with U,V the eigenvectors of
  E[GGᵀ], E[GᵀG], and the bilateral rotation attains the global minimum
  (diagonal form).
* Appendix B: with locally-consistent update directions and dominant
  signal, the delayed Adam trajectory tracks the un-delayed one; under
  basis misalignment on an ill-conditioned quadratic it diverges much
  further (the Fig. 3 mechanism).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st


def norm11(h):
    return np.abs(h).sum()


def _orth(rng, n):
    return np.linalg.qr(rng.standard_normal((n, n)))[0]


class TestTheorem31:
    @given(seed=st.integers(0, 10_000))
    @settings(max_examples=20, deadline=None)
    def test_rotation_ordering(self, seed):
        rng = np.random.default_rng(seed)
        m, n = 4, 6
        # Kronecker-factored H = A ⊗ B, A = V ΛA Vᵀ, B = U ΛB Uᵀ.
        va, ua = _orth(rng, n), _orth(rng, m)
        la = np.diag(rng.uniform(0.1, 3.0, n))
        lb = np.diag(rng.uniform(0.1, 3.0, m))
        a = va @ la @ va.T
        b = ua @ lb @ ua.T
        h = np.kron(a, b)
        h_u = np.kron(a, ua.T @ b @ ua)          # unilateral rotation
        h_uv = np.kron(va.T @ a @ va, ua.T @ b @ ua)  # bilateral
        assert norm11(h_uv) <= norm11(h_u) + 1e-8
        assert norm11(h_u) <= norm11(h) + 1e-8

    def test_bilateral_attains_diagonal_minimum(self):
        rng = np.random.default_rng(0)
        m, n = 3, 4
        va, ua = _orth(rng, n), _orth(rng, m)
        a = va @ np.diag(rng.uniform(0.5, 2.0, n)) @ va.T
        b = ua @ np.diag(rng.uniform(0.5, 2.0, m)) @ ua.T
        h_uv = np.kron(va.T @ a @ va, ua.T @ b @ ua)
        # diagonal ⇒ (1,1)-norm equals trace-norm of eigenvalues
        off = np.abs(h_uv - np.diag(np.diag(h_uv))).sum()
        assert off < 1e-8 * norm11(h_uv) + 1e-8
        # random other rotations can only do worse
        for s in range(5):
            r1, r2 = _orth(rng, m), _orth(rng, n)
            h_rot = np.kron(r2.T @ a @ r2, r1.T @ b @ r1)
            assert norm11(h_uv) <= norm11(h_rot) + 1e-8


def _adam(h, x0, steps, lr, delay, beta2=0.1, rotate=None):
    """Adam (β1=0) on ½xᵀHx with gradient delay, optional basis rotation.

    Returns the iterate history (steps+1, d)."""
    d = len(x0)
    x = x0.copy()
    v = np.zeros(d)
    eps = 1e-8
    hist = [x0.copy()]
    xs = [x0.copy()] * (delay + 1)
    for t in range(steps):
        x_stale = xs[0]
        g = h @ x_stale
        if rotate is not None:
            g = rotate.T @ g
        v = beta2 * v + (1 - beta2) * g * g
        step = g / (np.sqrt(v) + eps)
        if rotate is not None:
            step = rotate @ step
        x = x - lr * step
        xs = xs[1:] + [x.copy()]
        hist.append(x.copy())
    return np.array(hist)


def _tail_loss(h, tr, k=20):
    return np.mean([0.5 * x @ h @ x for x in tr[-k:]])


class TestDelayMechanism:
    LAM = np.diag([100.0, 1.0])
    Q = np.array([[1.0, 1.0], [-1.0, 1.0]]) / np.sqrt(2)
    X0 = np.array([3.0, 0.5])

    def test_misalignment_amplifies_delay_penalty(self):
        """Fig. 3 mechanism: same ill-conditioned quadratic, aligned vs
        45°-rotated Hessian; delay hurts far more when misaligned."""
        h_mis = self.Q @ self.LAM @ self.Q.T
        kw = dict(steps=400, lr=0.05, delay=3, beta2=0.5)
        la = _tail_loss(self.LAM, _adam(self.LAM, self.X0, **kw))
        lm = _tail_loss(h_mis, _adam(h_mis, self.X0, **kw))
        assert lm > 2.0 * la, (lm, la)

    def test_basis_rotation_restores_delay_robustness(self):
        """Rotating Adam's coordinates by the Hessian eigenbasis under
        delay recovers the aligned-case loss — the paper's core fix."""
        h_mis = self.Q @ self.LAM @ self.Q.T
        kw = dict(steps=400, lr=0.05, delay=3, beta2=0.5)
        la = _tail_loss(self.LAM, _adam(self.LAM, self.X0, **kw))
        lm = _tail_loss(h_mis, _adam(h_mis, self.X0, **kw))
        lrot = _tail_loss(h_mis, _adam(h_mis, self.X0, rotate=self.Q, **kw))
        assert lrot < 0.6 * lm, (lrot, lm)
        assert abs(lrot - la) < 0.25 * la, (lrot, la)

    def test_rotation_equivariance_no_delay(self):
        """Without delay, rotated Adam on the misaligned quadratic equals
        Adam on the aligned one (Appendix C equivalence), exactly."""
        h_mis = self.Q @ self.LAM @ self.Q.T
        kw = dict(steps=200, lr=0.05, delay=0, beta2=0.5)
        la = _tail_loss(self.LAM, _adam(self.LAM, self.X0, **kw))
        lrot = _tail_loss(h_mis, _adam(h_mis, self.X0, rotate=self.Q, **kw))
        assert abs(lrot - la) < 1e-6 * max(la, 1.0)

    def test_delayed_tracks_undelayed_when_aligned(self):
        """Appendix B stability: aligned + smooth trajectory ⇒ delayed
        iterates stay close to the un-delayed ones."""
        h = np.diag([100.0, 1.0])
        x0 = np.array([1.0, 1.0])
        t0 = _adam(h, x0, steps=60, lr=0.02, delay=0)
        t2 = _adam(h, x0, steps=60, lr=0.02, delay=2)
        gap = np.linalg.norm(t0[-1] - t2[-1])
        assert gap < 0.2
