"""Batched optimizer graphs (the exported L2 update executables) vs refs."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import optim_graphs as og
from compile.kernels import ref


def _orth(rng, n):
    return np.linalg.qr(rng.standard_normal((n, n)))[0].astype(np.float32)


def _batch(rng, nb, m, n):
    w, g, mm = (rng.standard_normal((nb, m, n)).astype(np.float32)
                for _ in range(3))
    vt = np.abs(rng.standard_normal((nb, m, n))).astype(np.float32)
    u = np.stack([_orth(rng, m) for _ in range(nb)])
    v = np.stack([_orth(rng, n) for _ in range(nb)])
    sc = np.tile(np.array([1e-3, 0.9, 0.999, 1e-8, 0.01, 3.0, 1.0, 0.0],
                          dtype=np.float32), (nb, 1))
    return tuple(jnp.array(x) for x in (w, g, mm, vt, u, v, sc))


@pytest.mark.parametrize("m,n", [(16, 48), (48, 16), (16, 16)])
@pytest.mark.parametrize("uni", [False, True])
def test_rot_adam_batched(m, n, uni):
    rng = np.random.default_rng(m * 100 + n + uni)
    w, g, mm, vt, u, v, sc = _batch(rng, 3, m, n)
    got = og.rot_adam_batched(w, g, mm, vt, u, v, sc, unilateral=uni)
    for i in range(3):
        want = ref.rotated_adam_ref(w[i], g[i], mm[i], vt[i], u[i], v[i],
                                    sc[i], unilateral=uni)
        for a, b in zip((got[0][i], got[1][i], got[2][i]), want):
            np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-5,
                                       atol=1e-6)


@pytest.mark.parametrize("uni", [False, True])
def test_soap_batched(uni):
    rng = np.random.default_rng(77 + uni)
    w, g, mm, vt, u, v, sc = _batch(rng, 2, 16, 48)
    got = og.soap_batched(w, g, mm, vt, u, v, sc, unilateral=uni)
    for i in range(2):
        want = ref.soap_update_ref(w[i], g[i], mm[i], vt[i], u[i], v[i],
                                   sc[i], unilateral=uni)
        for a, b in zip((got[0][i], got[1][i], got[2][i]), want):
            np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-5,
                                       atol=1e-6)


@pytest.mark.parametrize("m,n", [(16, 48), (48, 16)])
@pytest.mark.parametrize("uni", [False, True])
def test_eigen2nd_batched(m, n, uni):
    rng = np.random.default_rng(m + n + uni)
    w, g, mm, vt, u, v, sc = _batch(rng, 2, m, n)
    ll = jnp.einsum("bij,bkj->bik", g, g)
    rr = jnp.einsum("bji,bjk->bik", g, g)
    got = og.eigen2nd_batched(ll, rr, g, u, v, sc, unilateral=uni)
    for i in range(2):
        want = ref.eigen2nd_ref(ll[i], rr[i], g[i], u[i], v[i], sc[i, 2],
                                unilateral=uni)
        for a, b in zip((got[0][i], got[1][i], got[2][i], got[3][i]), want):
            np.testing.assert_allclose(np.array(a), np.array(b), rtol=2e-4,
                                       atol=2e-4)


def test_eigen1st_batched():
    rng = np.random.default_rng(5)
    w, g, mm, vt, u, v, sc = _batch(rng, 2, 16, 48)
    got = og.eigen1st_batched(mm, u, v, sc)
    for i in range(2):
        want = ref.eigen1st_ref(mm[i], u[i], v[i])
        for a, b in zip((got[0][i], got[1][i]), want):
            np.testing.assert_allclose(np.array(a), np.array(b), rtol=2e-4,
                                       atol=2e-4)


def test_eigen_mask_freezes_basis():
    """mask=0 must leave U,V untouched (stage-aware frequency gating)."""
    rng = np.random.default_rng(9)
    w, g, mm, vt, u, v, sc = _batch(rng, 2, 16, 16)
    sc = sc.at[:, 6].set(jnp.array([1.0, 0.0]))
    ll = jnp.einsum("bij,bkj->bik", g, g)
    rr = jnp.einsum("bji,bjk->bik", g, g)
    _, _, un, vn = og.eigen2nd_batched(ll, rr, g, u, v, sc)
    assert not np.allclose(np.array(un[0]), np.array(u[0]))
    np.testing.assert_array_equal(np.array(un[1]), np.array(u[1]))
    np.testing.assert_array_equal(np.array(vn[1]), np.array(v[1]))


def test_ns_orthonormalize_precision():
    rng = np.random.default_rng(3)
    for n in (8, 16, 48):
        x = rng.standard_normal((n, 4 * n)).astype(np.float32)
        spd = (x @ x.T / (4 * n)).astype(np.float32)
        y = np.array(og.ns_orthonormalize(jnp.array(spd @ _orth(rng, n))))
        err = np.abs(y @ y.T - np.eye(n)).max()
        assert err < 1e-3, (n, err)


def test_cgs2_qr_orthonormal_and_spans():
    rng = np.random.default_rng(21)
    x = rng.standard_normal((24, 24)).astype(np.float32)
    q = np.array(og.cgs2_qr(jnp.array(x)))
    assert np.abs(q @ q.T - np.eye(24)).max() < 1e-4
    # same column space: projector onto span(x) reproduces x
    assert np.abs(q @ (q.T @ x) - x).max() < 1e-3


def test_eigenbasis_estimation_diagonalizes():
    """Repeated Algorithm-2 steps must converge U to the eigenbasis of a
    fixed SPD statistic: off-diagonal mass of UᵀLU → small. This is the
    property QR has and a symmetric/polar orthonormalization lacks.
    """
    rng = np.random.default_rng(12)
    n = 16
    q = _orth(rng, n)
    lam = np.diag(np.linspace(10.0, 0.5, n)).astype(np.float32)
    ll = q @ lam @ q.T
    u = _orth(rng, n)
    for _ in range(60):
        u = np.array(og.power_qr(jnp.array(ll), jnp.array(u)))
    d = u.T @ ll @ u
    off = np.abs(d - np.diag(np.diag(d))).sum()
    total = np.abs(d).sum()
    assert off / total < 0.05, off / total


def test_muon_batched():
    rng = np.random.default_rng(8)
    w, g, mm, vt, u, v, sc = _batch(rng, 2, 16, 48)
    mom, o = og.muon_batched(mm, g, sc)
    for i in range(2):
        want_m, want_o = ref.muon_ref(mm[i], g[i], sc[i, 1])
        np.testing.assert_allclose(np.array(mom[i]), np.array(want_m),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.array(o[i]), np.array(want_o),
                                   rtol=2e-4, atol=2e-4)
    # orthogonalized direction has ~unit singular values
    oo = np.array(o[0]) @ np.array(o[0]).T
    assert np.abs(oo - np.eye(16)).max() < 1e-2


def test_impl_equivalence_jnp_vs_pallas():
    """The jnp (CPU production) and Pallas (TPU authoring) lowerings of
    the rotated update must agree to fp32 tolerance."""
    rng = np.random.default_rng(55)
    w, g, mm, vt, u, v, sc = _batch(rng, 2, 16, 48)
    og.set_impl("pallas")
    a = og.rot_adam_batched(w, g, mm, vt, u, v, sc)
    og.set_impl("jnp")
    b = og.rot_adam_batched(w, g, mm, vt, u, v, sc)
    og.set_impl("pallas")
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.array(x), np.array(y), rtol=1e-5,
                                   atol=1e-6)


def test_impl_equivalence_eigen_and_muon():
    rng = np.random.default_rng(56)
    w, g, mm, vt, u, v, sc = _batch(rng, 2, 16, 16)
    ll = jnp.einsum("bij,bkj->bik", g, g)
    rr = jnp.einsum("bji,bjk->bik", g, g)
    og.set_impl("pallas")
    a = og.eigen2nd_batched(ll, rr, g, u, v, sc)
    am = og.muon_batched(mm, g, sc)
    og.set_impl("jnp")
    b = og.eigen2nd_batched(ll, rr, g, u, v, sc)
    bm = og.muon_batched(mm, g, sc)
    og.set_impl("pallas")
    for x, y in zip(list(a) + list(am), list(b) + list(bm)):
        np.testing.assert_allclose(np.array(x), np.array(y), rtol=5e-4,
                                   atol=5e-4)
