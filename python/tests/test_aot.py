"""AOT export path: manifest integrity + HLO text well-formedness."""

import json
import os

import pytest

from compile import aot
from compile.configs import MICRO, MOE_MICRO


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts_micro"))
    aot.export_config(MICRO, out)
    return out


def test_manifest_structure(exported):
    man = json.load(open(os.path.join(exported, "manifest.json")))
    assert man["config"]["name"] == "micro"
    assert man["config"]["n_blocks"] == 2
    names = {p["name"] for p in man["params"]}
    assert {"tok_emb", "pos_emb", "gf", "head", "b0.wqkv"} <= names
    assert len(man["shape_classes"]) == 4
    for ex in man["executables"].values():
        assert os.path.exists(os.path.join(exported, ex["file"]))
        assert ex["inputs"] and ex["outputs"]


def test_core_executables_present(exported):
    man = json.load(open(os.path.join(exported, "manifest.json")))
    exes = set(man["executables"])
    need = {"fwdbwd", "eval_loss", "fwdbwd_split", "hvp", "embed_fwd",
            "embed_bwd", "block_fwd", "block_bwd", "head_fwdbwd"}
    assert need <= exes
    for cls in ("wqkv", "wo", "w1", "w2"):
        for g in (f"rot_adam_bi_{cls}", f"rot_adam_uni_{cls}",
                  f"soap_bi_{cls}", f"eigen2nd_bi_{cls}",
                  f"eigen1st_uni_{cls}", f"muon_{cls}"):
            assert g in exes, g


def test_hlo_text_is_parseable_module(exported):
    man = json.load(open(os.path.join(exported, "manifest.json")))
    for name, ex in man["executables"].items():
        text = open(os.path.join(exported, ex["file"])).read()
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_no_custom_calls(exported):
    """The xla_extension 0.5.1 CPU client can only run core HLO — any
    custom-call (LAPACK QR, FFI, Mosaic) would fail at compile time."""
    man = json.load(open(os.path.join(exported, "manifest.json")))
    for name, ex in man["executables"].items():
        text = open(os.path.join(exported, ex["file"])).read()
        assert "custom-call" not in text, name


def test_fwdbwd_signature_matches_schema(exported):
    man = json.load(open(os.path.join(exported, "manifest.json")))
    fb = man["executables"]["fwdbwd"]
    n_params = len(man["params"])
    assert len(fb["inputs"]) == n_params + 2
    assert fb["inputs"][-1]["dtype"] == "s32"
    # outputs: loss + one grad per param
    assert len(fb["outputs"]) == 1 + n_params
    assert fb["outputs"][0]["shape"] == []
    for pspec, ospec in zip(man["params"], fb["outputs"][1:]):
        assert pspec["shape"] == ospec["shape"]


def test_moe_export(tmp_path):
    out = str(tmp_path / "moe")
    aot.export_config(MOE_MICRO, out)
    man = json.load(open(os.path.join(out, "manifest.json")))
    assert man["config"]["moe"]["n_experts"] == 4
    assert "fwdbwd" in man["executables"]
    # expert shape classes fold E into the batch axis
    cls = {c["name"]: c for c in man["shape_classes"]}
    assert cls["w1e"]["count"] == MOE_MICRO.n_blocks * 4


def test_pallas_attention_variant_exports(tmp_path):
    out = str(tmp_path / "pattn")
    aot.export_config(MICRO, out, pallas_attn=True)
    man = json.load(open(os.path.join(out, "manifest.json")))
    text = open(
        os.path.join(out, man["executables"]["eval_loss"]["file"])).read()
    assert "custom-call" not in text
