"""L2 model graphs: shapes, autodiff-vs-manual backward, block composition."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import MICRO, get_config


@pytest.fixture(scope="module")
def setup():
    cfg = MICRO
    key = jax.random.PRNGKey(0)
    p = model.init_params(cfg, key)
    tok = jax.random.randint(jax.random.PRNGKey(1), (cfg.batch, cfg.seq), 0,
                             cfg.vocab)
    tgt = jax.random.randint(jax.random.PRNGKey(2), (cfg.batch, cfg.seq), 0,
                             cfg.vocab)
    return cfg, p, tok, tgt


def test_param_schema_shapes(setup):
    cfg, p, _, _ = setup
    for arr, (_n, shape, _k, _b, _r) in zip(p, cfg.param_schema()):
        assert arr.shape == shape


def test_forward_shape_and_loss(setup):
    cfg, p, tok, tgt = setup
    logits = model.forward(cfg, p, tok)
    assert logits.shape == (cfg.batch, cfg.seq, cfg.vocab)
    loss = model.loss_fn(cfg, p, tok, tgt)
    # fresh init ⇒ loss ≈ ln(vocab)
    assert abs(float(loss) - np.log(cfg.vocab)) < 0.3


def test_fwdbwd_returns_all_grads(setup):
    cfg, p, tok, tgt = setup
    out = model.fwdbwd(cfg, p, tok, tgt)
    assert len(out) == 1 + len(p)
    for g, w in zip(out[1:], p):
        assert g.shape == w.shape
        assert np.isfinite(np.array(g)).all()


def test_split_bwd_equals_autodiff_when_same_weights(setup):
    cfg, p, tok, tgt = setup
    auto = model.fwdbwd(cfg, p, tok, tgt)
    manual = model.split_fwdbwd(cfg, p, p, tok, tgt)
    assert abs(float(auto[0]) - float(manual[0])) < 1e-6
    for a, b in zip(auto[1:], manual[1:]):
        np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-4,
                                   atol=1e-6)


def test_split_bwd_differs_with_stale_backward_weights(setup):
    """With w_bwd ≠ w_fwd the gradient must be (measurably) incorrect —
    that is the no-stashing pathology of Fig. 10."""
    cfg, p, tok, tgt = setup
    key = jax.random.PRNGKey(9)
    p_bwd = [x + 0.05 * jax.random.normal(jax.random.fold_in(key, i),
                                          x.shape) for i, x in enumerate(p)]
    auto = model.fwdbwd(cfg, p, tok, tgt)
    manual = model.split_fwdbwd(cfg, p, p_bwd, tok, tgt)
    # loss is the forward loss — identical
    assert abs(float(auto[0]) - float(manual[0])) < 1e-6
    # at least one matrix grad deviates
    devs = [float(np.abs(np.array(a) - np.array(b)).max())
            for a, b in zip(auto[1:], manual[1:])]
    assert max(devs) > 1e-3


def test_blocks_compose_to_forward(setup):
    """embed_fwd ∘ block_fwd^L ∘ head == whole-model loss (engine path)."""
    cfg, p, tok, tgt = setup
    te, pe, blocks, gf, head = model.split_params(cfg, p)
    (x,) = model.embed_fwd(cfg, te, pe, tok)
    for bp in blocks:
        (x,) = model.block_fwd(cfg, *bp, x)
    loss, dx, dgf, dhead = model.head_fwdbwd(cfg, gf, head, x, tgt)
    want = model.loss_fn(cfg, p, tok, tgt)
    assert abs(float(loss) - float(want)) < 1e-6


def test_block_bwd_matches_autodiff(setup):
    """Per-block backward (engine) chains to the whole-model gradient."""
    cfg, p, tok, tgt = setup
    auto = model.fwdbwd(cfg, p, tok, tgt)
    te, pe, blocks, gf, head = model.split_params(cfg, p)
    # forward keeping stage inputs
    (x,) = model.embed_fwd(cfg, te, pe, tok)
    xs = [x]
    for bp in blocks:
        (x,) = model.block_fwd(cfg, *bp, x)
        xs.append(x)
    loss, dx, dgf, dhead = model.head_fwdbwd(cfg, gf, head, xs[-1], tgt)
    grads_blocks = []
    for bp, x_in in zip(reversed(blocks), reversed(xs[:-1])):
        out = model.block_bwd(cfg, *bp, x_in, dx)
        dx = out[0]
        grads_blocks.append(out[1:])
    grads_blocks.reverse()
    dtok, dpos = model.embed_bwd(cfg, tok, dx)
    flat = [dtok, dpos]
    for gb in grads_blocks:
        flat.extend(gb)
    flat.extend([dgf, dhead])
    for a, b in zip(auto[1:], flat):
        np.testing.assert_allclose(np.array(a), np.array(b), rtol=1e-4,
                                   atol=1e-6)


def test_hvp_matches_finite_difference(setup):
    cfg, p, tok, tgt = setup
    key = jax.random.PRNGKey(4)
    v = [jax.random.normal(jax.random.fold_in(key, i), x.shape)
         for i, x in enumerate(p)]
    hv = model.hvp(cfg, p, v, tok, tgt)
    eps = 1e-3

    def grad_at(q):
        return jax.grad(lambda pp: model.loss_fn(cfg, pp, tok, tgt))(q)

    gp = grad_at([x + eps * t for x, t in zip(p, v)])
    gm = grad_at([x - eps * t for x, t in zip(p, v)])
    fd = [(a - b) / (2 * eps) for a, b in zip(gp, gm)]
    # compare on the largest-magnitude entries (fd is noisy in f32)
    hv_cat = np.concatenate([np.ravel(np.array(x)) for x in hv])
    fd_cat = np.concatenate([np.ravel(np.array(x)) for x in fd])
    denom = np.abs(fd_cat).max()
    assert denom > 0
    err = np.abs(hv_cat - fd_cat).max() / denom
    assert err < 0.05, err


def test_mixed_version_weights_change_gradient(setup):
    """The staleness mechanism: feeding per-stage stale weights into
    fwdbwd yields a different gradient than fresh weights — the exact
    PipeDream-with-stashing semantics exercised by the Rust simulator."""
    cfg, p, tok, tgt = setup
    stale = [x - 0.02 if i < 5 else x for i, x in enumerate(p)]
    g_fresh = model.fwdbwd(cfg, p, tok, tgt)
    g_stale = model.fwdbwd(cfg, stale, tok, tgt)
    assert float(np.abs(np.array(g_fresh[3]) -
                        np.array(g_stale[3])).max()) > 0


def test_tiny_adam_training_reduces_loss(setup):
    """A handful of plain-Adam steps on one batch reduces the loss —
    sanity that the graph is trainable end to end."""
    cfg, p, tok, tgt = setup
    p = [jnp.array(x) for x in p]
    m = [jnp.zeros_like(x) for x in p]
    v = [jnp.zeros_like(x) for x in p]
    loss0 = None
    for t in range(1, 11):
        out = model.fwdbwd(cfg, p, tok, tgt)
        if loss0 is None:
            loss0 = float(out[0])
        for i, g in enumerate(out[1:]):
            m[i] = 0.9 * m[i] + 0.1 * g
            v[i] = 0.999 * v[i] + 0.001 * g * g
            mh = m[i] / (1 - 0.9 ** t)
            vh = v[i] / (1 - 0.999 ** t)
            p[i] = p[i] - 3e-3 * mh / (jnp.sqrt(vh) + 1e-8)
    out = model.fwdbwd(cfg, p, tok, tgt)
    assert float(out[0]) < loss0 - 0.3


def test_gelu_grad_matches_autodiff():
    u = jnp.linspace(-4, 4, 101)
    auto = jax.vmap(jax.grad(lambda x: model.gelu(x)))(u)
    np.testing.assert_allclose(np.array(model.gelu_grad(u)), np.array(auto),
                               rtol=1e-4, atol=1e-5)


def test_rmsnorm_normalizes():
    x = jnp.array(np.random.default_rng(0).standard_normal((4, 8, 16)),
                  dtype=jnp.float32)
    y = model.rmsnorm(x, jnp.ones(16))
    rms = np.sqrt(np.mean(np.array(y) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-2)
