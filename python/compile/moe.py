"""L2 — Mixture-of-Experts model variant (paper Fig. 21, nanoMoE-style).

Each block replaces the dense MLP with a top-k routed expert MLP
(8 experts, top-2 by default). Routing and expert compute stay inside a
single stage, so the pipeline schedule — and hence the staleness
semantics — are identical to the dense model; basis rotation applies to
each expert's matrices independently (expert axis folded into the
batched optimizer executables' leading dim).

At this scale experts are computed densely and masked by the (sparse)
gate matrix — numerically identical to dispatch/combine and far simpler
to lower. A standard load-balancing auxiliary loss (Switch-style) with
coefficient 0.01 is added, as in nanoMoE.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .model import (attention, embed_apply, gelu, head_loss, rmsnorm,
                    split_params, _heads, _unheads)

AUX_COEF = 0.01


def _topk_mask(probs, k):
    """Dense {0,1} mask of the k largest entries along the last axis.

    Implemented as k iterated argmaxes instead of ``jax.lax.top_k``: the
    xla_extension 0.5.1 HLO text parser predates the dedicated ``topk``
    instruction, while argmax lowers to a plain reduce (DESIGN.md §5).
    """
    e = probs.shape[-1]
    remaining = probs
    mask = jnp.zeros_like(probs)
    for _ in range(k):
        idx = jnp.argmax(remaining, axis=-1)
        hot = jax.nn.one_hot(idx, e, dtype=probs.dtype)
        mask = mask + hot
        remaining = remaining - hot * 1e9
    return mask


def moe_mlp(cfg: ModelConfig, router, w1e, w2e, x):
    """Top-k routed expert MLP. x: (B,S,D). Returns (out, aux_loss)."""
    E = cfg.moe.n_experts
    k = cfg.moe.top_k
    scores = x @ router                                   # (B,S,E)
    probs = jax.nn.softmax(scores, axis=-1)
    mask = jax.lax.stop_gradient(_topk_mask(probs, k))    # routing decision
    kept = probs * mask
    # Renormalized dense gates (gradients flow through the kept probs).
    gates = kept / (jnp.sum(kept, axis=-1, keepdims=True) + 1e-9)
    # Dense expert compute: (B,S,E,F) -> (B,S,E,D), gate-combined.
    h = jnp.einsum("bsd,edf->bsef", x, w1e)
    h = gelu(h)
    out_e = jnp.einsum("bsef,efd->bsed", h, w2e)
    out = jnp.einsum("bsed,bse->bsd", out_e, gates)
    # Switch-style load-balancing loss.
    frac_tokens = jnp.mean(gates > 0.0, axis=(0, 1)).astype(jnp.float32)
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return out, aux


def moe_block_apply(cfg: ModelConfig, bp, x):
    """bp = (g1, wqkv, wo, g2, router, w1e, w2e)."""
    g1, wqkv, wo, g2, router, w1e, w2e = bp
    a = rmsnorm(x, g1)
    qkv = a @ wqkv
    q, k, v = jnp.split(qkv, 3, axis=-1)
    o = attention(cfg, _heads(cfg, q), _heads(cfg, k), _heads(cfg, v))
    x = x + _unheads(cfg, o) @ wo
    bnorm = rmsnorm(x, g2)
    mlp, aux = moe_mlp(cfg, router, w1e, w2e, bnorm)
    return x + mlp, aux


def moe_loss_fn(cfg: ModelConfig, params, tokens, targets):
    tok_emb, pos_emb, blocks, gf, head = split_params(cfg, params)
    x = embed_apply(cfg, tok_emb, pos_emb, tokens)
    aux_total = 0.0
    for bp in blocks:
        x, aux = moe_block_apply(cfg, bp, x)
        aux_total = aux_total + aux
    ce = head_loss(cfg, gf, head, x, targets)
    return ce + AUX_COEF * aux_total / cfg.n_blocks, ce


def moe_fwdbwd(cfg: ModelConfig, params, tokens, targets):
    """(ce_loss, grads...) — grads of total (ce + aux) loss."""

    def total(p):
        tot, ce = moe_loss_fn(cfg, p, tokens, targets)
        return tot, ce

    (tot, ce), grads = jax.value_and_grad(total, has_aux=True)(list(params))
    return (ce, *grads)


def moe_eval_loss(cfg: ModelConfig, params, tokens, targets):
    _, ce = moe_loss_fn(cfg, params, tokens, targets)
    return (ce,)
