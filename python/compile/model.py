"""L2 — decoder-only Transformer (nanoGPT-style) in JAX.

Everything here is *build-time only*: graphs are lowered by ``aot.py`` to
HLO text and executed from the Rust coordinator. Params travel as a flat
list in the ``configs.ModelConfig.param_schema()`` order.

Graphs exported from this module:

* ``loss_fn`` / ``fwdbwd``      — whole-model loss + grads (autodiff).
  The coordinator feeds *mixed-version* per-stage weights, which yields
  exactly the PipeDream-with-stashing gradient (DESIGN.md §3).
* ``split_fwdbwd``              — hand-written backward where forward
  activations come from ``w_fwd`` but every weight used *inside* the
  backward ops comes from ``w_bwd``: the incorrect gradient of
  asynchronous training **without weight stashing** (paper Fig. 10).
  Validated against ``jax.grad`` when ``w_fwd == w_bwd``.
* ``embed_fwd/block_fwd/block_bwd/head_fwdbwd/embed_bwd`` — per-block
  building blocks for the real threaded 1F1B engine (backward recomputes
  its forward internally, checkpoint-style, so activations never cross
  the artifact boundary).
* ``hvp``                       — Hessian-vector product for the
  Cauchy-trace Hessian (1,1)-norm estimator (paper Fig. 11).
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.attention import causal_attention

RMS_EPS = 1e-5
_GELU_C = 0.7978845608028654  # sqrt(2/pi)

N_BLOCK_PARAMS = 6  # g1, wqkv, wo, g2, w1, w2


# ---------------------------------------------------------------------------
# Parameter plumbing
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key):
    """Normal(0, 0.02) init, residual projections scaled by 1/sqrt(2L)."""
    params = []
    for name, shape, kind, _blk, _rot in cfg.param_schema():
        key, sub = jax.random.split(key)
        if kind == "gain":
            params.append(jnp.ones(shape, jnp.float32))
        else:
            std = 0.02
            if name.endswith((".wo", ".w2", ".w2e")):
                std = 0.02 / (2.0 * cfg.n_blocks) ** 0.5
            params.append(std * jax.random.normal(sub, shape, jnp.float32))
    return params


def split_params(cfg: ModelConfig, params):
    """flat list -> (tok_emb, pos_emb, [per-block tuples], gf, head)."""
    tok_emb, pos_emb = params[0], params[1]
    n = N_BLOCK_PARAMS if cfg.moe is None else 7
    blocks = []
    for b in range(cfg.n_blocks):
        o = 2 + b * n
        blocks.append(tuple(params[o:o + n]))
    gf, head = params[-2], params[-1]
    return tok_emb, pos_emb, blocks, gf, head


# ---------------------------------------------------------------------------
# Forward pieces
# ---------------------------------------------------------------------------

def rmsnorm(x, g):
    r = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + RMS_EPS)
    return x * r * g


def gelu(u):
    return 0.5 * u * (1.0 + jnp.tanh(_GELU_C * (u + 0.044715 * u * u * u)))


def gelu_grad(u):
    t = jnp.tanh(_GELU_C * (u + 0.044715 * u ** 3))
    dt = (1.0 - t * t) * _GELU_C * (1.0 + 3 * 0.044715 * u * u)
    return 0.5 * (1.0 + t) + 0.5 * u * dt


def _heads(cfg, x):
    b, s, d = x.shape
    return x.reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)


def _unheads(cfg, x):
    b, h, s, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * hd)


def attention(cfg, q, k, v, pallas_attn=False):
    """q,k,v: (B,H,S,hd) -> (B,H,S,hd) causal attention."""
    if pallas_attn:
        return jax.vmap(causal_attention)(q, k, v)
    scale = 1.0 / float(cfg.head_dim) ** 0.5
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    mask = jnp.tril(jnp.ones((cfg.seq, cfg.seq), dtype=bool))
    att = jnp.where(mask[None, None], att, -1e30)
    p = jax.nn.softmax(att, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def block_apply(cfg: ModelConfig, bp, x, pallas_attn=False):
    """One pre-norm transformer block. bp = (g1,wqkv,wo,g2,w1,w2)."""
    g1, wqkv, wo, g2, w1, w2 = bp
    a = rmsnorm(x, g1)
    qkv = a @ wqkv
    q, k, v = jnp.split(qkv, 3, axis=-1)
    o = attention(cfg, _heads(cfg, q), _heads(cfg, k), _heads(cfg, v),
                  pallas_attn)
    x = x + _unheads(cfg, o) @ wo
    bnorm = rmsnorm(x, g2)
    x = x + gelu(bnorm @ w1) @ w2
    return x


def embed_apply(cfg, tok_emb, pos_emb, tokens):
    return tok_emb[tokens] + pos_emb[None, :, :]


def head_loss(cfg, gf, head, x, targets):
    xf = rmsnorm(x, gf)
    logits = xf @ head
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def forward(cfg: ModelConfig, params, tokens, pallas_attn=False):
    tok_emb, pos_emb, blocks, gf, head = split_params(cfg, params)
    x = embed_apply(cfg, tok_emb, pos_emb, tokens)
    for bp in blocks:
        x = block_apply(cfg, bp, x, pallas_attn)
    xf = rmsnorm(x, gf)
    return xf @ head


def loss_fn(cfg: ModelConfig, params, tokens, targets, pallas_attn=False):
    tok_emb, pos_emb, blocks, gf, head = split_params(cfg, params)
    x = embed_apply(cfg, tok_emb, pos_emb, tokens)
    for bp in blocks:
        x = block_apply(cfg, bp, x, pallas_attn)
    return head_loss(cfg, gf, head, x, targets)


def fwdbwd(cfg: ModelConfig, params, tokens, targets, pallas_attn=False):
    """(loss, grads...) — the per-step training graph."""
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, tokens, targets, pallas_attn))(list(params))
    return (loss, *grads)


def eval_loss(cfg: ModelConfig, params, tokens, targets):
    return (loss_fn(cfg, params, tokens, targets),)


# ---------------------------------------------------------------------------
# Hand-written split-weight backward (no weight stashing, Fig. 10)
# ---------------------------------------------------------------------------

def _rms_cache(x):
    return jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + RMS_EPS)


def _rms_bwd(dy, g_bwd, x_fwd, r_fwd):
    """Backward of y = x*r*g with weight from w_bwd, activations from w_fwd."""
    dg = jnp.sum(dy * x_fwd * r_fwd, axis=(0, 1))
    gdy = dy * g_bwd
    dx = r_fwd * gdy - x_fwd * (r_fwd ** 3) * jnp.mean(
        gdy * x_fwd, axis=-1, keepdims=True)
    return dx, dg


def split_fwdbwd(cfg: ModelConfig, params_fwd, params_bwd, tokens, targets):
    """Incorrect gradient of async training *without* weight stashing.

    Forward (and all cached activations) use ``params_fwd`` — the stale
    weights each stage had at forward time. The backward ops use
    ``params_bwd`` — the weights at backward time (already updated) —
    exactly what happens when stashing is disabled (Gaunt et al. 2017;
    Huo et al. 2018). Returns (loss_fwd, grads...) in schema order.
    """
    te_f, pe_f, blocks_f, gf_f, head_f = split_params(cfg, params_fwd)
    _, _, blocks_b, gf_b, head_b = split_params(cfg, params_bwd)
    scale = 1.0 / float(cfg.head_dim) ** 0.5
    mask = jnp.tril(jnp.ones((cfg.seq, cfg.seq), dtype=bool))

    # ---- forward with activation cache (weights = w_fwd) ----
    x = embed_apply(cfg, te_f, pe_f, tokens)
    caches = []
    for (g1, wqkv, wo, g2, w1, w2) in blocks_f:
        x_in = x
        r1 = _rms_cache(x_in)
        a = x_in * r1 * g1
        qkv = a @ wqkv
        q, k, v = (_heads(cfg, t) for t in jnp.split(qkv, 3, axis=-1))
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
        att = jnp.where(mask[None, None], att, -1e30)
        p = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", p, v)
        oc = _unheads(cfg, o)
        x_mid = x_in + oc @ wo
        r2 = _rms_cache(x_mid)
        bnorm = x_mid * r2 * g2
        u = bnorm @ w1
        gu = gelu(u)
        x = x_mid + gu @ w2
        caches.append((x_in, r1, a, q, k, v, p, oc, x_mid, r2, bnorm, u, gu))
    x_last = x
    rf = _rms_cache(x_last)
    xf = x_last * rf * gf_f
    logits = xf @ head_f
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    loss = jnp.mean(nll)

    # ---- backward (weights = w_bwd, activations from the fwd cache) ----
    n_tok = cfg.batch * cfg.seq
    onehot = jax.nn.one_hot(targets, cfg.vocab, dtype=jnp.float32)
    dlogits = (jnp.exp(logp) - onehot) / n_tok
    dhead = jnp.einsum("bsd,bsv->dv", xf, dlogits)
    dxf = dlogits @ head_b.T
    dx, dgf = _rms_bwd(dxf, gf_b, x_last, rf)

    grads_blocks = []
    for (bp_b, cache) in zip(reversed(blocks_b), reversed(caches)):
        g1b, wqkvb, wob, g2b, w1b, w2b = bp_b
        (x_in, r1, a, q, k, v, p, oc, x_mid, r2, bnorm, u, gu) = cache
        # MLP branch: x = x_mid + gelu(bnorm@w1) @ w2
        dw2 = jnp.einsum("bsf,bsd->fd", gu, dx)
        dgu = dx @ w2b.T
        du = dgu * gelu_grad(u)
        dw1 = jnp.einsum("bsd,bsf->df", bnorm, du)
        dbnorm = du @ w1b.T
        dx_mid_norm, dg2 = _rms_bwd(dbnorm, g2b, x_mid, r2)
        dx_mid = dx + dx_mid_norm
        # Attention branch: x_mid = x_in + oc @ wo
        dwo = jnp.einsum("bsd,bse->de", oc, dx_mid)
        doc = dx_mid @ wob.T
        do = _heads(cfg, doc)
        dv = jnp.einsum("bhqk,bhqd->bhkd", p, do)
        dp = jnp.einsum("bhqd,bhkd->bhqk", do, v)
        datt = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
        dq = jnp.einsum("bhqk,bhkd->bhqd", datt, k) * scale
        dk = jnp.einsum("bhqk,bhqd->bhkd", datt, q) * scale
        dqkv = jnp.concatenate(
            [_unheads(cfg, t) for t in (dq, dk, dv)], axis=-1)
        dwqkv = jnp.einsum("bsd,bse->de", a, dqkv)
        da = dqkv @ wqkvb.T
        dx_in_norm, dg1 = _rms_bwd(da, g1b, x_in, r1)
        dx = dx_mid + dx_in_norm
        grads_blocks.append((dg1, dwqkv, dwo, dg2, dw1, dw2))
    grads_blocks.reverse()

    dpos = jnp.sum(dx, axis=0)
    dtok = jnp.zeros_like(te_f).at[tokens].add(dx)

    flat = [dtok, dpos]
    for gb in grads_blocks:
        flat.extend(gb)
    flat.extend([dgf, dhead])
    return (loss, *flat)


# ---------------------------------------------------------------------------
# Per-block engine graphs (backward recomputes forward internally)
# ---------------------------------------------------------------------------

def embed_fwd(cfg, tok_emb, pos_emb, tokens):
    return (embed_apply(cfg, tok_emb, pos_emb, tokens),)


def embed_bwd(cfg, tokens, dx):
    dtok = jnp.zeros((cfg.vocab, cfg.d_model), jnp.float32).at[tokens].add(dx)
    dpos = jnp.sum(dx, axis=0)
    return (dtok, dpos)


def block_fwd(cfg, g1, wqkv, wo, g2, w1, w2, x):
    return (block_apply(cfg, (g1, wqkv, wo, g2, w1, w2), x),)


def block_bwd(cfg, g1, wqkv, wo, g2, w1, w2, x, dy):
    """(dx, dparams...) — recomputes the forward inside (checkpoint-style)."""
    bp = (g1, wqkv, wo, g2, w1, w2)

    def f(bp_, x_):
        return block_apply(cfg, bp_, x_)

    _, vjp = jax.vjp(f, bp, x)
    dbp, dx = vjp(dy)
    return (dx, *dbp)


def head_fwdbwd(cfg, gf, head, x, targets):
    """(loss, dx, dgf, dhead) for the last stage."""

    def f(gf_, head_, x_):
        return head_loss(cfg, gf_, head_, x_, targets)

    loss, (dgf, dhead, dx) = jax.value_and_grad(
        f, argnums=(0, 1, 2))(gf, head, x)
    return (loss, dx, dgf, dhead)


# ---------------------------------------------------------------------------
# Hessian-vector product (Fig. 11 Hessian (1,1)-norm estimation)
# ---------------------------------------------------------------------------

def hvp(cfg: ModelConfig, params, vec, tokens, targets):
    """H·v via forward-over-reverse; vec in schema order."""

    def g(p):
        return jax.grad(lambda q: loss_fn(cfg, q, tokens, targets))(p)

    _, hv = jax.jvp(g, (list(params),), (list(vec),))
    return tuple(hv)
