"""L1 Pallas tiled matmul — the rotation workhorse.

Every basis-rotation projection (``Uᵀ G``, ``G V``, ``U X Vᵀ`` …) in the
exported optimizer graphs goes through this kernel so the paper's compute
hot-spot lives at the Pallas layer and lowers into the same HLO module as
the surrounding L2 graph.

TPU mapping (DESIGN.md §Hardware-Adaptation): blocks are the largest
divisor ≤ 128 of each dim so full tiles feed the 128×128 MXU systolic
array; K is the innermost grid axis so the f32 accumulator tile stays
resident in VMEM while A/B tiles stream HBM→VMEM (double-buffered by the
Mosaic pipeline). On this image the kernel executes with
``interpret=True`` (CPU PJRT cannot run Mosaic custom-calls) — numerics
identical, scheduling simulated; see DESIGN.md §Perf for the static
VMEM/MXU analysis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def pick_block(d: int, cap: int = 128) -> int:
    """Largest divisor of ``d`` not exceeding ``cap`` (prefer powers of 2)."""
    if d <= 0:
        return 1
    b = 1
    while b * 2 <= cap and d % (b * 2) == 0:
        b *= 2
    if b == 1:
        for c in range(min(d, cap), 0, -1):
            if d % c == 0:
                return c
    return min(b, d)


def _mm_kernel(a_ref, b_ref, o_ref, *, n_k: int):
    """Grid = (M/bm, N/bn, K/bk), K innermost.

    The output tile's index map ignores the K axis, so ``o_ref`` stays
    resident (VMEM) across the K loop and acts as the accumulator.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def matmul_spec(m: int, k: int, n: int):
    """(grid, in_specs, out_spec, n_k) for an (m,k)x(k,n) matmul."""
    bm, bk, bn = pick_block(m), pick_block(k), pick_block(n)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    out_spec = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
    return grid, in_specs, out_spec, n_k


def vmem_bytes(m: int, k: int, n: int) -> int:
    """Static VMEM footprint estimate of one grid step (f32)."""
    bm, bk, bn = pick_block(m), pick_block(k), pick_block(n)
    # A tile + B tile (double-buffered) + resident accumulator tile.
    return 4 * (2 * (bm * bk + bk * bn) + bm * bn)


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul(a: jax.Array, b: jax.Array, interpret: bool = True) -> jax.Array:
    """C = A @ B via the tiled Pallas kernel. A: (m,k), B: (k,n)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    grid, in_specs, out_spec, n_k = matmul_spec(m, k, n)
    return pl.pallas_call(
        functools.partial(_mm_kernel, n_k=n_k),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32))


def batched_matmul(a: jax.Array, b: jax.Array, interpret: bool = True):
    """C[i] = A[i] @ B[i] for stacked (NB,m,k) x (NB,k,n)."""
    return jax.vmap(lambda x, y: matmul(x, y, interpret=interpret))(a, b)
