"""Pallas L1 kernels + pure-jnp reference oracles."""
