"""L1 fused basis-rotation Adam update (paper Algorithm 1, lines 8–11).

Composition of the two Pallas kernels:

* ``matmul.matmul``    — rotations ``Uᵀ·``, ``·V``, and the back-projection
* ``adam_step``        — fused rotated-space moment update + direction

so the entire hot path of the paper's contribution lowers to Pallas ops
inside the exported HLO. The momentum update (line 4) happens in the
*original* space, matching Algorithm 1 (and differing from SOAP, which
accumulates in the rotated space — see ``soap_step`` and Appendix G).
"""

import functools

import jax
import jax.numpy as jnp

from .adam_step import adam_direction
from .matmul import matmul


def _rot(x, u, v, interpret):
    """x̃ = Uᵀ x V; u or v may be None (unilateral geometry)."""
    y = x if u is None else matmul(u.T, x, interpret=interpret)
    if v is not None:
        y = matmul(y, v, interpret=interpret)
    return y


def _unrot(x, u, v, interpret):
    """x = U x̃ Vᵀ; u or v may be None (unilateral geometry)."""
    y = x if u is None else matmul(u, x, interpret=interpret)
    if v is not None:
        y = matmul(y, v.T, interpret=interpret)
    return y


def _pick_uv(u, vv, unilateral, shape):
    """Unilateral geometry rotates the *smaller* dimension (paper §3.2)."""
    if not unilateral:
        return u, vv
    m, n = shape
    return (u, None) if m <= n else (None, vv)


@functools.partial(jax.jit, static_argnames=("unilateral", "interpret"))
def rotated_adam_step(w, g, m, v, u, vv, scalars, *, unilateral=False,
                      interpret=True):
    """One basis-rotation Adam step for a single matrix.

    Args:
      w:  (m,n) weights.
      g:  (m,n) (possibly delayed) gradient.
      m:  (m,n) first moment, original space.
      v:  (m,n) second moment, rotated space.
      u:  (m,m) left rotation.
      vv: (n,n) right rotation (ignored when unilateral).
      scalars: (8,) [lr, beta1, beta2, eps, wd, t, _, _].

    Returns (w', m', v').
    """
    beta1 = scalars[1]
    lr, wd = scalars[0], scalars[4]
    m_new = beta1 * m + (1.0 - beta1) * g
    uu, vvv = _pick_uv(u, vv, unilateral, w.shape)
    g_rot = _rot(g, uu, vvv, interpret)
    m_rot = _rot(m_new, uu, vvv, interpret)
    direction, v_new = adam_direction(g_rot, m_rot, v, scalars,
                                      interpret=interpret)
    upd = _unrot(direction, uu, vvv, interpret)
    w_new = w - lr * (upd + wd * w)
    return w_new, m_new, v_new


@functools.partial(jax.jit, static_argnames=("unilateral", "interpret"))
def soap_step(w, g, m_rot, v, u, vv, scalars, *, unilateral=False,
              interpret=True):
    """SOAP variant: first moment accumulated in the *rotated* space."""
    beta1 = scalars[1]
    lr, wd = scalars[0], scalars[4]
    uu, vvv = _pick_uv(u, vv, unilateral, w.shape)
    g_rot = _rot(g, uu, vvv, interpret)
    m_new = beta1 * m_rot + (1.0 - beta1) * g_rot
    direction, v_new = adam_direction(g_rot, m_new, v, scalars,
                                      interpret=interpret)
    upd = _unrot(direction, uu, vvv, interpret)
    w_new = w - lr * (upd + wd * w)
    return w_new, m_new, v_new
