"""L1 Pallas blocked causal attention (flash-style).

Used by the ``--pallas-attn`` model variant: queries are blocked over the
grid; keys/values stream through VMEM with an online softmax (running
max + denominator held in the accumulator tile), mirroring the
HBM↔VMEM schedule FlashAttention expresses with CUDA threadblocks —
re-thought for the TPU memory hierarchy per DESIGN.md §Hardware-Adaptation.

Because the KV stream is the innermost grid axis, the (bq × hd) output
tile, the running row-max and the running denominator stay VMEM-resident
for the whole pass. interpret=True on this image.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import pick_block

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, rm_ref, rd_ref, *,
                 scale: float, bq: int, bk: int, n_k: int):
    """Grid = (H, S/bq, S/bk): online-softmax accumulation over KV blocks."""
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        rm_ref[...] = jnp.full_like(rm_ref, NEG_INF)
        rd_ref[...] = jnp.zeros_like(rd_ref)

    q = q_ref[0]  # (bq, hd)
    k = k_ref[0]  # (bk, hd)
    v = v_ref[0]  # (bk, hd)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    # Causal mask between absolute positions.
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    s = jnp.where(qpos >= kpos, s, NEG_INF)

    m_prev = rm_ref[...]          # (bq, 1)
    d_prev = rd_ref[...]          # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    d_new = d_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = o_ref[...] * alpha[None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )[None]
    rm_ref[...] = m_new
    rd_ref[...] = d_new

    @pl.when(ki == n_k - 1)
    def _final():
        o_ref[...] = o_ref[...] / rd_ref[...][None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def causal_attention(q, k, v, interpret: bool = True):
    """Blocked causal attention. q,k,v: (H, S, hd) → (H, S, hd)."""
    h, s, hd = q.shape
    scale = 1.0 / float(hd) ** 0.5
    bq = pick_block(s, 64)
    bk = pick_block(s, 64)
    n_k = s // bk
    grid = (h, s // bq, n_k)
    q_spec = pl.BlockSpec((1, bq, hd), lambda hh, qi, ki: (hh, qi, 0))
    kv_spec = pl.BlockSpec((1, bk, hd), lambda hh, qi, ki: (hh, ki, 0))
    o_spec = pl.BlockSpec((1, bq, hd), lambda hh, qi, ki: (hh, qi, 0))
    rm_spec = pl.BlockSpec((bq, 1), lambda hh, qi, ki: (qi, 0))
    out = pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale, bq=bq, bk=bk, n_k=n_k),
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=[o_spec, rm_spec, rm_spec],
        out_shape=[
            jax.ShapeDtypeStruct((h, s, hd), jnp.float32),
            jax.ShapeDtypeStruct((s, 1), jnp.float32),
            jax.ShapeDtypeStruct((s, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[0]


def vmem_bytes(s: int, hd: int) -> int:
    """Static VMEM estimate per grid step (f32)."""
    bq = pick_block(s, 64)
    bk = pick_block(s, 64)
    # q tile + 2 kv tiles (double-buffered) + o tile + running stats + p.
    return 4 * (bq * hd + 2 * 2 * bk * hd + bq * hd + 2 * bq + bq * bk)
