"""Pure-jnp oracles for every L1 kernel and L2 optimizer graph.

These are the correctness ground truth: pytest checks each Pallas kernel
and each exported optimizer graph against these, and the Rust integration
tests cross-check the HLO path against independent Rust implementations.
"""

import jax.numpy as jnp


def matmul_ref(a, b):
    return a @ b


def adam_direction_ref(g_rot, m_rot, v, scalars):
    lr, beta1, beta2, eps, wd, t = (scalars[i] for i in range(6))
    v_new = beta2 * v + (1.0 - beta2) * g_rot * g_rot
    mhat = m_rot / (1.0 - beta1**t)
    vhat = v_new / (1.0 - beta2**t)
    return mhat / (jnp.sqrt(vhat) + eps), v_new


def _uni_side(m, n):
    """Unilateral geometry rotates the *smaller* dimension (paper 3.2)."""
    return "left" if m <= n else "right"


def _rot(x, u, vv, unilateral):
    if unilateral:
        if _uni_side(*x.shape) == "left":
            return u.T @ x
        return x @ vv
    return u.T @ x @ vv


def _unrot(x, u, vv, unilateral):
    if unilateral:
        if _uni_side(*x.shape) == "left":
            return u @ x
        return x @ vv.T
    return u @ x @ vv.T


def rotated_adam_ref(w, g, m, v, u, vv, scalars, *, unilateral=False):
    """Reference for Algorithm 1 (one step, given fixed U, V).

    m is the *original-space* momentum (updated here with beta1);
    v is the *rotated-space* second moment.
    Returns (w_new, m_new, v_new).
    """
    lr, beta1, beta2, eps, wd, t = (scalars[i] for i in range(6))
    m_new = beta1 * m + (1.0 - beta1) * g
    g_rot = _rot(g, u, vv, unilateral)
    m_rot = _rot(m_new, u, vv, unilateral)
    direction, v_new = adam_direction_ref(g_rot, m_rot, v, scalars)
    upd = _unrot(direction, u, vv, unilateral)
    w_new = w - lr * (upd + wd * w)
    return w_new, m_new, v_new


def soap_update_ref(w, g, m_rot, v, u, vv, scalars, *, unilateral=False):
    """SOAP variant: momentum accumulated in the rotated space."""
    lr, beta1, beta2, eps, wd, t = (scalars[i] for i in range(6))
    g_rot = _rot(g, u, vv, unilateral)
    m_new = beta1 * m_rot + (1.0 - beta1) * g_rot
    direction, v_new = adam_direction_ref(g_rot, m_new, v, scalars)
    upd = _unrot(direction, u, vv, unilateral)
    w_new = w - lr * (upd + wd * w)
    return w_new, m_new, v_new


def ns_orthonormalize_ref(x, quintic: int = 4, cubic: int = 4):
    """Newton-Schulz polar orthonormalization: quintic (Muon
    coefficients) to lift small singular values, then cubic to polish to
    machine-precision orthogonality. Substitutes the paper's
    power-iteration QR (DESIGN.md S5).
    """
    a, b, c = 3.4445, -4.7750, 2.0315
    m, n = x.shape
    transpose = m > n
    y = x.T if transpose else x
    y = y / (jnp.linalg.norm(y) + 1e-7)
    for _ in range(quintic):
        s = y @ y.T
        y = a * y + (b * s + c * (s @ s)) @ y
    for _ in range(cubic):
        s = y @ y.T
        y = 1.5 * y - 0.5 * (s @ y)
    return y.T if transpose else y


def cgs2_qr_ref(x):
    """Q of classical Gram-Schmidt with reorthogonalization (CGS2)."""
    import numpy as _np
    x = _np.asarray(x, dtype=_np.float32)
    q = _np.zeros_like(x)
    for j in range(x.shape[1]):
        a = x[:, j].copy()
        for _ in range(2):
            a = a - q @ (q.T @ a)
        q[:, j] = a / (_np.linalg.norm(a) + 1e-30)
    return jnp.asarray(q)


def eigen_update_ref(stat, basis):
    """One power-iteration step + QR: U' = qr(S U).Q (paper Alg. 2).

    Ridge matches ``optim_graphs.power_qr`` (rank-deficient statistics).
    """
    import numpy as _np
    n = stat.shape[0]
    ridge = 1e-3 * _np.trace(_np.asarray(stat)) / n + 1e-12
    return cgs2_qr_ref(_np.asarray(stat @ basis) + ridge * _np.asarray(basis))


def eigen2nd_ref(ll, rr, g, u, v, beta2, *, unilateral=False):
    left = not unilateral or _uni_side(*g.shape) == "left"
    right = not unilateral or _uni_side(*g.shape) == "right"
    ll_new, u_new, rr_new, v_new = ll, u, rr, v
    if left:
        ll_new = beta2 * ll + (1.0 - beta2) * (g @ g.T)
        u_new = eigen_update_ref(ll_new, u)
    if right:
        rr_new = beta2 * rr + (1.0 - beta2) * (g.T @ g)
        v_new = eigen_update_ref(rr_new, v)
    return ll_new, rr_new, u_new, v_new


def eigen1st_ref(m, u, v, *, unilateral=False):
    left = not unilateral or _uni_side(*m.shape) == "left"
    right = not unilateral or _uni_side(*m.shape) == "right"
    u_new, v_new = u, v
    if left:
        u_new = eigen_update_ref(m @ m.T, u)
    if right:
        v_new = eigen_update_ref(m.T @ m, v)
    return u_new, v_new


def muon_ref(mom, g, beta):
    """Muon: momentum + NS-orthogonalized direction. Returns (mom', O)."""
    mom_new = beta * mom + g
    o = ns_orthonormalize_ref(mom_new)
    return mom_new, o


def attention_ref(q, k, v):
    """Causal multi-head attention. q,k,v: (H, S, hd)."""
    hd = q.shape[-1]
    s = q.shape[-2]
    att = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(
        jnp.float32(hd)
    )
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    att = jnp.where(mask[None], att, -1e30)
    p = jnp.exp(att - att.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", p, v)
