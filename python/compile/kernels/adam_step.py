"""L1 Pallas fused element-wise Adam update.

Fuses the second-moment EMA, bias correction, adaptive scaling and the
weight/decay step into one VMEM-resident pass so moments never round-trip
to HBM between ops. Operates in the (possibly rotated) coordinate system:
the caller passes gradients/momentum already projected by the rotation
matmuls (see ``rotated_adam.py``); with identity rotation this is plain
Adam.

Signature (all same 2-D shape, f32):
    ``(g̃, m̃, v, w?, scalars) -> (upd | w', v')``

Scalars are passed via a small prefetch-style (8,)-vector because Pallas
scalar plumbing on the interpret path is simplest as an array operand:
``[lr, beta1, beta2, eps, wd, t, _, _]``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import pick_block

N_SCALARS = 8


def _adam_kernel(s_ref, gt_ref, mt_ref, v_ref, upd_ref, v_out_ref):
    """One VMEM tile: v' = b2 v + (1-b2) g̃²; upd = m̂ / (sqrt(v̂)+eps)."""
    lr = s_ref[0]  # noqa: F841 — applied by the caller in original space
    beta1 = s_ref[1]
    beta2 = s_ref[2]
    eps = s_ref[3]
    t = s_ref[5]
    g = gt_ref[...]
    m = mt_ref[...]
    v = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    # Bias correction (PyTorch-Adam convention, as used in the paper's
    # experimental setup; Alg. 1 elides it for brevity).
    mhat = m / (1.0 - beta1**t)
    vhat = v / (1.0 - beta2**t)
    upd_ref[...] = mhat / (jnp.sqrt(vhat) + eps)
    v_out_ref[...] = v


@functools.partial(jax.jit, static_argnames=("interpret",))
def adam_direction(g_rot, m_rot, v, scalars, interpret: bool = True):
    """Fused rotated-space Adam direction.

    Returns ``(direction, v_new)`` where direction is the rotated-space
    update ``m̂/(sqrt(v̂)+eps)`` — the caller projects it back with the
    rotation matmuls and applies lr/weight-decay in original space.
    """
    m, n = g_rot.shape
    bm, bn = pick_block(m), pick_block(n)
    grid = (m // bm, n // bn)
    tile = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    out = pl.pallas_call(
        _adam_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((N_SCALARS,), lambda i, j: (0,)),
            tile,
            tile,
            tile,
        ],
        out_specs=[tile, tile],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((m, n), jnp.float32),
        ],
        interpret=interpret,
    )(scalars, g_rot, m_rot, v)
    return out[0], out[1]


def vmem_bytes(m: int, n: int) -> int:
    """Static per-grid-step VMEM footprint (f32): 3 in + 2 out tiles."""
    bm, bn = pick_block(m), pick_block(n)
    return 4 * (5 * bm * bn + N_SCALARS)
