"""L2 — batched optimizer-update graphs (the paper's Algorithms 1 & 2).

All rotated-matrix parameters of a given shape class (e.g. the 32
``wqkv`` matrices of `tiny32`) are updated by ONE executable call with a
leading batch axis, so the Rust hot loop makes ~4 dispatches per step
instead of ~128. Per-matrix learning rates (PipeDream-LR is stage-wise)
and per-matrix eigen-update masks (stage-aware rotation frequency) are
passed as (NB,) vectors.

Every matmul inside these graphs is the L1 Pallas kernel
(`kernels.matmul`), and the rotated-space moment update is the L1 fused
Adam kernel (`kernels.adam_step`) — the paper's compute hot-spot lowers
to Pallas ops inside the exported HLO.

Eigenbasis estimation (Algorithm 2) is exactly the paper's one
power-iteration step + QR — with QR realized as twice-reorthogonalized
classical Gram–Schmidt (CGS2) in pure jnp ops, because jax-0.8's
``jnp.linalg.qr`` lowers to LAPACK FFI custom-calls that xla_extension
0.5.1 cannot execute. CGS2 keeps the triangular column ordering that
makes orthogonal (simultaneous) iteration converge to the eigenbasis —
a symmetric/polar orthonormalization would *not* (its fixed points are
not attracting per-column), which pytest's
``test_eigenbasis_estimation_diagonalizes`` guards against.
Newton–Schulz remains for Muon, where it is the authentic method.
"""

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.adam_step import adam_direction
from .kernels.matmul import matmul

# 4 quintic (Muon-coefficient) steps lift small singular values fast,
# then 4 cubic steps polish to machine-precision orthogonality — the
# quintic alone plateaus at ~0.3 off-orthogonality, too loose for a
# rotation basis.
NS_QUINTIC, NS_CUBIC = 4, 4
_NS_A, _NS_B, _NS_C = 3.4445, -4.7750, 2.0315


# ---------------------------------------------------------------------------
# Implementation switch: 'pallas' routes every matmul / fused-Adam step
# through the L1 kernels (the TPU-authoring path; interpret-mode on this
# image). 'jnp' emits the same math as native XLA dots — the CPU
# *production* lowering: interpret-mode Pallas expands each grid cell
# into an XLA While iteration, which measured 45 s/step on tiny32 vs
# sub-second for the jnp lowering (EXPERIMENTS.md §Perf). Numerical
# equivalence of the two lowerings is pinned by pytest
# (test_impl_equivalence) and by the Rust integration tests.
# ---------------------------------------------------------------------------

IMPL = "pallas"


def set_impl(impl: str):
    global IMPL
    assert impl in ("pallas", "jnp"), impl
    IMPL = impl


# ---------------------------------------------------------------------------
# Building blocks (single matrix; batched via vmap at export)
# ---------------------------------------------------------------------------

def _mm(a, b):
    if IMPL == "pallas":
        return matmul(a, b, interpret=True)
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def _adam_direction(g_rot, m_rot, vt, sc):
    if IMPL == "pallas":
        return adam_direction(g_rot, m_rot, vt, sc, interpret=True)
    beta1, beta2, eps, t = sc[1], sc[2], sc[3], sc[5]
    v_new = beta2 * vt + (1.0 - beta2) * g_rot * g_rot
    mhat = m_rot / (1.0 - beta1**t)
    vhat = v_new / (1.0 - beta2**t)
    return mhat / (jnp.sqrt(vhat) + eps), v_new


def ns_orthonormalize(x):
    """Newton–Schulz quintic polar factor (Muon coefficients), Pallas mms."""
    m, n = x.shape
    transpose = m > n
    y = x.T if transpose else x
    y = y / (jnp.linalg.norm(y) + 1e-7)
    for _ in range(NS_QUINTIC):
        s = _mm(y, y.T)
        y = _NS_A * y + _mm(_NS_B * s + _NS_C * _mm(s, s), y)
    for _ in range(NS_CUBIC):
        s = _mm(y, y.T)
        y = 1.5 * y - 0.5 * _mm(s, y)
    return y.T if transpose else y


def cgs2_qr(x):
    """Q factor of x via classical Gram–Schmidt with reorthogonalization.

    Column-ordered like LAPACK QR (up to sign), so orthogonal iteration
    U' = qr(S·U).Q converges to the eigenbasis of SPD S. Lowers to a
    plain HLO While loop — no custom calls.
    """
    def body(j, q):
        a = lax.dynamic_slice_in_dim(x, j, 1, axis=1)[:, 0]
        for _ in range(2):  # CGS2: second pass restores orthogonality
            a = a - q @ (q.T @ a)
        a = a / (jnp.linalg.norm(a) + 1e-30)
        return lax.dynamic_update_slice_in_dim(q, a[:, None], j, axis=1)

    return lax.fori_loop(0, x.shape[1], body, jnp.zeros_like(x))


def power_qr(stat, basis):
    """One power-iteration step + QR: the paper's Eigenbasis-Estimation
    primitive (Algorithm 2's ``Power``).

    A scale-aware ridge (δI shifts eigenvalues uniformly — eigenvectors
    are unchanged) keeps the iteration well-defined when the
    statistic is rank-deficient — e.g. E[GᵀG] of a wide matrix (rank ≤
    min(m,n)) or the near-zero Fisher EMA in the first training steps:
    null-space columns then decay toward the previous basis instead of
    normalized fp noise.
    """
    n = stat.shape[0]
    ridge = 1e-3 * jnp.trace(stat) / n + 1e-12
    return cgs2_qr(_mm(stat, basis) + ridge * basis)


def _uni_side(m: int, n: int) -> str:
    """Unilateral geometry rotates the *smaller* dimension (paper §3.2)."""
    return "left" if m <= n else "right"


def _rotate(x, u, v):
    """x̃ = Uᵀ x V; u or v may be None for unilateral geometry."""
    y = x if u is None else _mm(u.T, x)
    return y if v is None else _mm(y, v)


def _unrotate(x, u, v):
    y = x if u is None else _mm(u, x)
    return y if v is None else _mm(y, v.T)


def _pick_uv(u, v, unilateral, shape):
    if not unilateral:
        return u, v
    if _uni_side(*shape) == "left":
        return u, None
    return None, v


def _rot_adam_one(w, g, m, vt, u, v, sc, unilateral):
    """Algorithm 1 lines 3–11 for one matrix. sc=(8,) scalar vector."""
    lr, beta1, wd = sc[0], sc[1], sc[4]
    m_new = beta1 * m + (1.0 - beta1) * g
    uu, vv = _pick_uv(u, v, unilateral, w.shape)
    g_rot = _rotate(g, uu, vv)
    m_rot = _rotate(m_new, uu, vv)
    direction, vt_new = _adam_direction(g_rot, m_rot, vt, sc)
    upd = _unrotate(direction, uu, vv)
    w_new = w - lr * (upd + wd * w)
    return w_new, m_new, vt_new


def _soap_one(w, g, m_rot, vt, u, v, sc, unilateral):
    """SOAP: momentum accumulated in the rotated space (Appendix G)."""
    lr, beta1, wd = sc[0], sc[1], sc[4]
    uu, vv = _pick_uv(u, v, unilateral, w.shape)
    g_rot = _rotate(g, uu, vv)
    m_new = beta1 * m_rot + (1.0 - beta1) * g_rot
    direction, vt_new = _adam_direction(g_rot, m_new, vt, sc)
    upd = _unrotate(direction, uu, vv)
    w_new = w - lr * (upd + wd * w)
    return w_new, m_new, vt_new


def _eigen2nd_one(ll, rr, g, u, v, mask, beta2, unilateral):
    """Algorithm 2, S=2nd: Fisher-factor EMAs + power step + orthonorm.

    ``mask`` in {0,1} gates the basis refresh per matrix (stage-aware
    frequency allocation): EMAs always update, bases only when mask=1.
    """
    left = not unilateral or _uni_side(*g.shape) == "left"
    right = not unilateral or _uni_side(*g.shape) == "right"
    ll_new, u_new = ll, u
    rr_new, v_new = rr, v
    if left:
        ll_new = beta2 * ll + (1.0 - beta2) * _mm(g, g.T)
        u_pow = power_qr(ll_new, u)
        u_new = mask * u_pow + (1.0 - mask) * u
    if right:
        rr_new = beta2 * rr + (1.0 - beta2) * _mm(g.T, g)
        v_pow = power_qr(rr_new, v)
        v_new = mask * v_pow + (1.0 - mask) * v
    return ll_new, rr_new, u_new, v_new


def _eigen1st_one(m, u, v, mask, unilateral):
    """Algorithm 2, S=1st: momentum outer-products, no L/R storage."""
    left = not unilateral or _uni_side(*m.shape) == "left"
    right = not unilateral or _uni_side(*m.shape) == "right"
    u_new, v_new = u, v
    if left:
        u_pow = power_qr(_mm(m, m.T), u)
        u_new = mask * u_pow + (1.0 - mask) * u
    if right:
        v_pow = power_qr(_mm(m.T, m), v)
        v_new = mask * v_pow + (1.0 - mask) * v
    return u_new, v_new


def _muon_one(mom, g, beta):
    mom_new = beta * mom + g
    o = ns_orthonormalize(mom_new)
    return mom_new, o


# ---------------------------------------------------------------------------
# Batched exported graphs. NB matrices of shape (m, n) per call.
# Scalar layout per matrix i: sc[i] = [lr, beta1, beta2, eps, wd, t, mask, _]
# ---------------------------------------------------------------------------

def rot_adam_batched(w, g, m, vt, u, v, sc, *, unilateral=False):
    f = lambda wi, gi, mi, vti, ui, vi, sci: _rot_adam_one(
        wi, gi, mi, vti, ui, vi, sci, unilateral)
    return jax.vmap(f)(w, g, m, vt, u, v, sc)


def soap_batched(w, g, m_rot, vt, u, v, sc, *, unilateral=False):
    f = lambda wi, gi, mi, vti, ui, vi, sci: _soap_one(
        wi, gi, mi, vti, ui, vi, sci, unilateral)
    return jax.vmap(f)(w, g, m_rot, vt, u, v, sc)


def eigen2nd_batched(ll, rr, g, u, v, sc, *, unilateral=False):
    f = lambda li, ri, gi, ui, vi, sci: _eigen2nd_one(
        li, ri, gi, ui, vi, sci[6], sci[2], unilateral)
    return jax.vmap(f)(ll, rr, g, u, v, sc)


def eigen1st_batched(m, u, v, sc, *, unilateral=False):
    f = lambda mi, ui, vi, sci: _eigen1st_one(mi, ui, vi, sci[6], unilateral)
    return jax.vmap(f)(m, u, v, sc)


def muon_batched(mom, g, sc):
    """Returns (mom', O). Rust applies W -= lr * sqrt(max(m,n)) * O."""
    f = lambda mi, gi, sci: _muon_one(mi, gi, sci[1])
    return jax.vmap(f)(mom, g, sc)
