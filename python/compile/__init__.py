"""Build-time compile path: L2 JAX graphs + L1 Pallas kernels."""
