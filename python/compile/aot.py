"""AOT export: lower every L2 graph for a config to HLO **text** + manifest.

This is the only python entry point of the whole system; after
``make artifacts`` the Rust binary is self-contained.

Interchange format is HLO *text*, not serialized HloModuleProto:
jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
0.5.1 (the version behind the published ``xla`` crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --config tiny32 --out ../artifacts/tiny32
    python -m compile.aot --all --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, moe, optim_graphs as og
from .configs import ModelConfig, all_configs, get_config

F32 = "f32"
S32 = "s32"
_DTYPES = {F32: jnp.float32, S32: jnp.int32}

# Max block count for which the (memory-hungry, jvp-over-grad) HVP graph
# is exported — Fig. 11 runs on a mid-size config.
HVP_MAX_BLOCKS = 8


def spec(shape, dtype=F32):
    return {"shape": list(shape), "dtype": dtype}


def _sds(s):
    return jax.ShapeDtypeStruct(tuple(s["shape"]), _DTYPES[s["dtype"]])


def lower_to_hlo_text(fn, in_specs):
    # keep_unused: unilateral rotation graphs and the split-weight
    # backward legitimately ignore some inputs; the manifest promises
    # the full signature, so DCE of parameters must be disabled.
    lowered = jax.jit(fn, keep_unused=True).lower(*[_sds(s) for s in in_specs])
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def out_specs_of(fn, in_specs):
    outs = jax.eval_shape(fn, *[_sds(s) for s in in_specs])
    res = []
    for o in jax.tree_util.tree_leaves(outs):
        dt = F32 if o.dtype == jnp.float32 else S32
        res.append(spec(o.shape, dt))
    return res


class Exporter:
    def __init__(self, cfg: ModelConfig, out_dir: str):
        self.cfg = cfg
        self.out_dir = out_dir
        self.manifest = {
            "config": {
                "name": cfg.name,
                "vocab": cfg.vocab,
                "seq": cfg.seq,
                "d_model": cfg.d_model,
                "n_heads": cfg.n_heads,
                "n_blocks": cfg.n_blocks,
                "d_ff": cfg.d_ff,
                "batch": cfg.batch,
                "moe": None if cfg.moe is None else {
                    "n_experts": cfg.moe.n_experts,
                    "top_k": cfg.moe.top_k,
                },
            },
            "params": [
                {"name": n, "shape": list(s), "kind": k, "block": b,
                 "rotated": r}
                for (n, s, k, b, r) in cfg.param_schema()
            ],
            "shape_classes": [
                {"name": n, "count": c, "m": m, "n": nn}
                for (n, c, m, nn) in cfg.shape_classes()
            ],
            "executables": {},
        }
        os.makedirs(out_dir, exist_ok=True)

    def export(self, name, fn, in_specs, input_names=None):
        print(f"  [{self.cfg.name}] lowering {name} "
              f"({len(in_specs)} inputs)...", flush=True)
        text = lower_to_hlo_text(fn, in_specs)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        self.manifest["executables"][name] = {
            "file": fname,
            "inputs": in_specs,
            "input_names": input_names or [],
            "outputs": out_specs_of(fn, in_specs),
        }

    def finish(self):
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"  [{self.cfg.name}] manifest with "
              f"{len(self.manifest['executables'])} executables")


def param_specs(cfg):
    return [spec(s) for (_n, s, _k, _b, _r) in cfg.param_schema()]


def export_config(cfg: ModelConfig, out_dir: str, pallas_attn: bool = False):
    ex = Exporter(cfg, out_dir)
    ps = param_specs(cfg)
    names = [n for (n, *_rest) in cfg.param_schema()]
    tok = spec((cfg.batch, cfg.seq), S32)
    B, S, D, V = cfg.batch, cfg.seq, cfg.d_model, cfg.vocab

    if cfg.moe is None:
        # The Pallas attention kernel has no registered VJP, so it is used
        # on the inference path (eval_loss) when requested; fwdbwd always
        # differentiates the jnp attention (numerically identical — the
        # kernel is pytest-verified against the same reference).
        ex.export(
            "fwdbwd",
            lambda *a: model.fwdbwd(cfg, list(a[:-2]), a[-2], a[-1]),
            ps + [tok, tok],
            names + ["tokens", "targets"],
        )
        ex.export(
            "eval_loss",
            lambda *a: (model.loss_fn(cfg, list(a[:-2]), a[-2], a[-1],
                                      pallas_attn),),
            ps + [tok, tok],
        )
        ex.export(
            "fwdbwd_split",
            lambda *a: model.split_fwdbwd(
                cfg, list(a[: len(ps)]), list(a[len(ps): 2 * len(ps)]),
                a[-2], a[-1]),
            ps + ps + [tok, tok],
        )
        if cfg.n_blocks <= HVP_MAX_BLOCKS:
            ex.export(
                "hvp",
                lambda *a: model.hvp(
                    cfg, list(a[: len(ps)]), list(a[len(ps): 2 * len(ps)]),
                    a[-2], a[-1]),
                ps + ps + [tok, tok],
            )
        # ---- per-block engine graphs ----
        x = spec((B, S, D))
        blk = [spec(s) for (_n, s, _k, b, _r) in cfg.param_schema() if b == 0]
        ex.export("embed_fwd",
                  lambda te, pe, t: model.embed_fwd(cfg, te, pe, t),
                  [spec((V, D)), spec((S, D)), tok])
        ex.export("embed_bwd",
                  lambda t, dx: model.embed_bwd(cfg, t, dx),
                  [tok, x])
        ex.export("block_fwd",
                  lambda *a: model.block_fwd(cfg, *a),
                  blk + [x])
        ex.export("block_bwd",
                  lambda *a: model.block_bwd(cfg, *a),
                  blk + [x, x])
        ex.export("head_fwdbwd",
                  lambda gf, hd, xx, tg: model.head_fwdbwd(cfg, gf, hd, xx,
                                                           tg),
                  [spec((D,)), spec((D, V)), x, tok])
    else:
        ex.export(
            "fwdbwd",
            lambda *a: moe.moe_fwdbwd(cfg, list(a[:-2]), a[-2], a[-1]),
            ps + [tok, tok],
            names + ["tokens", "targets"],
        )
        ex.export(
            "eval_loss",
            lambda *a: moe.moe_eval_loss(cfg, list(a[:-2]), a[-2], a[-1]),
            ps + [tok, tok],
        )

    # ---- batched optimizer graphs per rotated shape class ----
    # CPU production artifacts use the jnp lowering of the optimizer
    # graphs (same math as the L1 Pallas kernels; interpret-mode Pallas
    # is orders of magnitude slower under CPU PJRT — see optim_graphs).
    og.set_impl("jnp")
    for (cname, count, m, n) in cfg.shape_classes():
        nb = count
        mat = spec((nb, m, n))
        uu = spec((nb, m, m))
        vv = spec((nb, n, n))
        ll = spec((nb, m, m))
        rr = spec((nb, n, n))
        sc = spec((nb, 8))
        for uni, tag in ((False, "bi"), (True, "uni")):
            ex.export(
                f"rot_adam_{tag}_{cname}",
                lambda w, g, mm, vt, u, v, s, _u=uni: og.rot_adam_batched(
                    w, g, mm, vt, u, v, s, unilateral=_u),
                [mat, mat, mat, mat, uu, vv, sc],
            )
            ex.export(
                f"soap_{tag}_{cname}",
                lambda w, g, mm, vt, u, v, s, _u=uni: og.soap_batched(
                    w, g, mm, vt, u, v, s, unilateral=_u),
                [mat, mat, mat, mat, uu, vv, sc],
            )
            ex.export(
                f"eigen2nd_{tag}_{cname}",
                lambda l, r, g, u, v, s, _u=uni: og.eigen2nd_batched(
                    l, r, g, u, v, s, unilateral=_u),
                [ll, rr, mat, uu, vv, sc],
            )
            ex.export(
                f"eigen1st_{tag}_{cname}",
                lambda mm, u, v, s, _u=uni: og.eigen1st_batched(
                    mm, u, v, s, unilateral=_u),
                [mat, uu, vv, sc],
            )
        ex.export(
            f"muon_{cname}",
            lambda mom, g, s: og.muon_batched(mom, g, s),
            [mat, mat, sc],
        )
    # The micro config additionally carries the Pallas lowering of one
    # rotated-update class so the Rust integration tests can pin the
    # jnp-vs-Pallas numerical equivalence on the PJRT execution path.
    if cfg.name == "micro":
        og.set_impl("pallas")
        (cname, count, m, n) = cfg.shape_classes()[0]
        mat = spec((count, m, n))
        uu = spec((count, m, m))
        vv = spec((count, n, n))
        sc = spec((count, 8))
        ex.export(
            f"rot_adam_bi_{cname}_pallas",
            lambda w, g, mm, vt, u, v, s: og.rot_adam_batched(
                w, g, mm, vt, u, v, s, unilateral=False),
            [mat, mat, mat, mat, uu, vv, sc],
        )
    og.set_impl("pallas")
    ex.finish()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", required=True)
    ap.add_argument("--pallas-attn", action="store_true",
                    help="use the Pallas attention kernel in fwdbwd")
    args = ap.parse_args()
    if args.all:
        for name, cfg in sorted(all_configs().items()):
            export_config(cfg, os.path.join(args.out, name))
    else:
        cfg = get_config(args.config)
        export_config(cfg, args.out, pallas_attn=args.pallas_attn)


if __name__ == "__main__":
    main()
