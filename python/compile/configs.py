"""Model configurations shared between the compile path and the Rust
coordinator (mirrored in ``rust/src/config``).

The *param schema* defined here is the single source of truth for the
flattening order of parameters in every exported executable. The Rust
side reads it from ``manifest.json`` — never hard-code offsets twice.

Dense block layout (per block):  ``g1, wqkv, wo, g2, w1, w2``
MoE   block layout (per block):  ``g1, wqkv, wo, g2, router, w1e, w2e``
Global layout: ``tok_emb, pos_emb, <blocks...>, gf, head``
"""

from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class MoeConfig:
    n_experts: int = 8
    top_k: int = 2


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    seq: int
    d_model: int
    n_heads: int
    n_blocks: int
    d_ff: int
    batch: int
    moe: Optional[MoeConfig] = None

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_schema(self):
        """[(name, shape, kind, block_idx, rotated)] in flatten order.

        ``rotated`` marks 2-D matrices eligible for basis rotation
        (attention + MLP projections; embeddings / head / gains are
        excluded, following the paper, Appendix D.2).
        """
        V, S, D, F = self.vocab, self.seq, self.d_model, self.d_ff
        out = [
            ("tok_emb", (V, D), "embed", -1, False),
            ("pos_emb", (S, D), "embed", -1, False),
        ]
        for b in range(self.n_blocks):
            out.append((f"b{b}.g1", (D,), "gain", b, False))
            out.append((f"b{b}.wqkv", (D, 3 * D), "matrix", b, True))
            out.append((f"b{b}.wo", (D, D), "matrix", b, True))
            out.append((f"b{b}.g2", (D,), "gain", b, False))
            if self.moe is None:
                out.append((f"b{b}.w1", (D, F), "matrix", b, True))
                out.append((f"b{b}.w2", (F, D), "matrix", b, True))
            else:
                E = self.moe.n_experts
                out.append((f"b{b}.router", (D, E), "matrix", b, False))
                out.append((f"b{b}.w1e", (E, D, F), "expert", b, True))
                out.append((f"b{b}.w2e", (E, F, D), "expert", b, True))
        out.append(("gf", (D,), "gain", -1, False))
        out.append(("head", (D, V), "matrix", -1, False))
        return out

    def shape_classes(self):
        """Rotated-matrix shape classes batched across blocks.

        Returns [(class_name, count, m, n)] — each class gets one set of
        batched optimizer executables (rot_adam / eigen / muon / soap).
        MoE experts fold the expert axis into the batch axis.
        """
        D, F, L = self.d_model, self.d_ff, self.n_blocks
        if self.moe is None:
            return [
                ("wqkv", L, D, 3 * D),
                ("wo", L, D, D),
                ("w1", L, D, F),
                ("w2", L, F, D),
            ]
        E = self.moe.n_experts
        return [
            ("wqkv", L, D, 3 * D),
            ("wo", L, D, D),
            ("w1e", L * E, D, F),
            ("w2e", L * E, F, D),
        ]


_CFGS = {}


def _reg(c: ModelConfig) -> ModelConfig:
    _CFGS[c.name] = c
    return c


# Unit/integration-test scale. ~40k params.
MICRO = _reg(ModelConfig("micro", vocab=64, seq=16, d_model=16, n_heads=2,
                         n_blocks=2, d_ff=64, batch=2))
# Workhorse for the P in {1,4,8,16,32} staleness experiments: depth 32
# mirrors the paper's 32-block 95M model with width shrunk for the
# single-core CPU testbed. ~1.0M params.
TINY32 = _reg(ModelConfig("tiny32", vocab=256, seq=48, d_model=48, n_heads=4,
                          n_blocks=32, d_ff=192, batch=4))
# Depth-scaling family (Fig 6): same width, depth = P.
TINY4 = _reg(ModelConfig("tiny4", vocab=256, seq=48, d_model=48, n_heads=4,
                         n_blocks=4, d_ff=192, batch=4))
TINY8 = _reg(ModelConfig("tiny8", vocab=256, seq=48, d_model=48, n_heads=4,
                         n_blocks=8, d_ff=192, batch=4))
TINY16 = _reg(ModelConfig("tiny16", vocab=256, seq=48, d_model=48, n_heads=4,
                          n_blocks=16, d_ff=192, batch=4))
# Width-scaling pair (Fig 7 "0.1B vs 1B" analog) at P=8.
SMALL = _reg(ModelConfig("small", vocab=512, seq=64, d_model=128, n_heads=4,
                         n_blocks=8, d_ff=512, batch=4))
WIDE = _reg(ModelConfig("wide", vocab=512, seq=64, d_model=256, n_heads=8,
                        n_blocks=8, d_ff=1024, batch=4))
# End-to-end driver: largest trainable-on-one-core config (~13M params).
E2E = _reg(ModelConfig("e2e", vocab=2048, seq=128, d_model=256, n_heads=8,
                       n_blocks=16, d_ff=1024, batch=4))
# Pico family: the figure-harness workhorses on the single-core CPU
# testbed — depth mirrors the paper's 32-block model, width shrunk so a
# full method x P sweep finishes in minutes (DESIGN.md S5).
PICO4 = _reg(ModelConfig("pico4", vocab=128, seq=32, d_model=32, n_heads=4,
                         n_blocks=4, d_ff=128, batch=2))
PICO8 = _reg(ModelConfig("pico8", vocab=128, seq=32, d_model=32, n_heads=4,
                         n_blocks=8, d_ff=128, batch=2))
PICO16 = _reg(ModelConfig("pico16", vocab=128, seq=32, d_model=32, n_heads=4,
                          n_blocks=16, d_ff=128, batch=2))
PICO32 = _reg(ModelConfig("pico32", vocab=128, seq=32, d_model=32, n_heads=4,
                          n_blocks=32, d_ff=128, batch=2))
# Width-scaling pair at P=8 for the CPU harness (Fig 7 analog).
WIDE8 = _reg(ModelConfig("wide8", vocab=128, seq=32, d_model=96, n_heads=4,
                         n_blocks=8, d_ff=384, batch=2))
# MoE at pico scale (Fig 21 harness default).
MOE_PICO = _reg(ModelConfig("moe_pico", vocab=128, seq=32, d_model=32,
                            n_heads=4, n_blocks=8, d_ff=64, batch=2,
                            moe=MoeConfig(4, 2)))
# MoE generalization (Fig 21): 8 experts, top-2.
MOE_MICRO = _reg(ModelConfig("moe_micro", vocab=64, seq=16, d_model=16,
                             n_heads=2, n_blocks=2, d_ff=32, batch=2,
                             moe=MoeConfig(4, 2)))
MOE_TINY = _reg(ModelConfig("moe_tiny", vocab=256, seq=48, d_model=48,
                            n_heads=4, n_blocks=8, d_ff=96, batch=4,
                            moe=MoeConfig(8, 2)))


def get_config(name: str) -> ModelConfig:
    try:
        return _CFGS[name]
    except KeyError:
        raise KeyError(f"unknown config {name!r}; have {sorted(_CFGS)}")


def all_configs():
    return dict(_CFGS)
